"""AOT pipeline: variants lower to parseable, deterministic HLO text."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot, model


def test_variant_shapes_sane():
    for name, v, e, k in model.VARIANTS:
        assert v >= 2 and e >= 2 and k >= 1, name


def test_lower_small_variant_to_hlo_text():
    lowered = model.lower_variant(8, 32, 16)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # Entry computation should mention the padded parameter shapes.
    assert "f32[8,32]" in text, "incidence (V,E) parameter missing"
    assert "f32[16,8]" in text, "b (K,V) parameter missing"


def test_lowering_deterministic():
    lowered1 = aot.to_hlo_text(model.lower_variant(8, 32, 16))
    lowered2 = aot.to_hlo_text(model.lower_variant(8, 32, 16))
    assert lowered1 == lowered2


def test_aot_main_writes_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--variants", "small"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert "small" in manifest
    hlo = (out / manifest["small"]["file"]).read_text()
    assert "HloModule" in hlo
