"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and dtypes; assert_allclose against ref. This is
the CORE correctness signal for the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import mcmf_kernels as K
from compile.kernels import ref

DIMS = st.tuples(
    st.integers(min_value=1, max_value=24),  # K groups
    st.integers(min_value=2, max_value=40),  # E edges
    st.integers(min_value=2, max_value=12),  # V nodes
)
# x64 is disabled in this jax build (the AOT artifacts are f32 anyway);
# sweep f32 and bf16 — the two dtypes the TPU mapping cares about.
DTYPES = st.sampled_from([jnp.float32, jnp.bfloat16])


def rand(rng, shape, dtype, lo=-2.0, hi=2.0):
    return jnp.asarray(rng.uniform(lo, hi, shape), dtype)


def tol(dtype):
    return dict(rtol=2e-5, atol=2e-5) if dtype == jnp.float32 else dict(rtol=3e-2, atol=3e-2)


@settings(max_examples=40, deadline=None)
@given(dims=DIMS, dtype=DTYPES, seed=st.integers(0, 2**31 - 1))
def test_dual_step_matches_ref(dims, dtype, seed):
    k, e, v = dims
    rng = np.random.default_rng(seed)
    f_bar = rand(rng, (k, e), dtype)
    a_t = rand(rng, (e, v), dtype, -1.0, 1.0)
    b = rand(rng, (k, v), dtype)
    y1 = rand(rng, (k, v), dtype)
    lam_bar = float(rng.uniform(0, 2))
    sigma = rand(rng, (k, v), dtype, 0.01, 1.0)
    got = K.dual_step(f_bar, a_t, b, y1, lam_bar, sigma)
    want = ref.dual_step(f_bar, a_t, b, y1, lam_bar, sigma)
    assert_allclose(np.asarray(got), np.asarray(want), **tol(dtype))


@settings(max_examples=40, deadline=None)
@given(dims=DIMS, dtype=DTYPES, seed=st.integers(0, 2**31 - 1))
def test_primal_step_matches_ref(dims, dtype, seed):
    k, e, v = dims
    rng = np.random.default_rng(seed)
    f = rand(rng, (k, e), dtype, 0.0, 2.0)
    y1 = rand(rng, (k, v), dtype)
    a = rand(rng, (v, e), dtype, -1.0, 1.0)
    y2 = rand(rng, (e,), dtype, 0.0, 1.0)
    tau = rand(rng, (k, e), dtype, 0.01, 1.0)
    got = K.primal_step(f, y1, a, y2, tau)
    want = ref.primal_step(f, y1, a, y2, tau)
    assert_allclose(np.asarray(got), np.asarray(want), **tol(dtype))
    assert np.all(np.asarray(got) >= 0.0), "projection must keep f nonnegative"


@settings(max_examples=40, deadline=None)
@given(dims=DIMS, dtype=DTYPES, seed=st.integers(0, 2**31 - 1))
def test_capacity_step_matches_ref(dims, dtype, seed):
    k, e, _ = dims
    rng = np.random.default_rng(seed)
    f_bar = rand(rng, (k, e), dtype, 0.0, 2.0)
    c = rand(rng, (e,), dtype, 0.1, 2.0)
    y2 = rand(rng, (e,), dtype, 0.0, 1.0)
    sigma = float(rng.uniform(0.01, 1.0))
    got = K.capacity_step(f_bar, c, y2, sigma)
    want = ref.capacity_step(f_bar, c, y2, sigma)
    assert_allclose(np.asarray(got), np.asarray(want), **tol(dtype))
    assert np.all(np.asarray(got) >= 0.0)


@settings(max_examples=30, deadline=None)
@given(dims=DIMS, seed=st.integers(0, 2**31 - 1))
def test_lambda_step_matches_ref(dims, seed):
    k, _, v = dims
    rng = np.random.default_rng(seed)
    y1 = rand(rng, (k, v), jnp.float32)
    b = rand(rng, (k, v), jnp.float32)
    lam = float(rng.uniform(0, 2))
    tau = float(rng.uniform(0.01, 1.0))
    got = K.lambda_step(lam, y1, b, tau)
    want = ref.lambda_step(lam, y1, b, tau)
    assert_allclose(float(got), float(want), rtol=1e-5, atol=1e-6)


def test_kernels_zero_input_identity():
    """Zero flows and duals: dual step returns y1 - sigma*lam*b."""
    k, e, v = 3, 6, 4
    f = jnp.zeros((k, e))
    a_t = jnp.zeros((e, v))
    b = jnp.ones((k, v))
    y1 = jnp.zeros((k, v))
    out = K.dual_step(f, a_t, b, y1, 2.0, 0.5)
    assert_allclose(np.asarray(out), -np.ones((k, v)), rtol=1e-6)


def test_kernels_are_jittable_inside_loop():
    """The kernels must lower inside lax.fori_loop (the L2 pattern)."""
    k, e, v = 4, 8, 3
    a = jnp.zeros((v, e), jnp.float32)
    b = jnp.zeros((k, v), jnp.float32)

    def body(_, f):
        y1 = K.dual_step(f, a.T, b, jnp.zeros((k, v)), 0.0, 0.1)
        return K.primal_step(f, y1, a, jnp.zeros((e,)), 0.1)

    out = jax.jit(lambda f: jax.lax.fori_loop(0, 3, body, f))(jnp.ones((k, e)))
    assert out.shape == (k, e)
