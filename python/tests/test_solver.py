"""L2 correctness: the PDHG max-concurrent-flow solver vs scipy's exact LP.

Random instances on random strongly-connected digraphs are solved both by
``model.pdhg_mcmf`` and by ``scipy.optimize.linprog`` (HiGHS) on the exact
edge-based LP; the PDHG lambda must be feasible and close to optimal.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from scipy.optimize import linprog

from compile import model

FULL_MESH_3 = [(0, 1, 10.0), (1, 0, 10.0), (1, 2, 10.0), (2, 1, 10.0), (0, 2, 10.0), (2, 0, 10.0)]


def linprog_mcmf(a, b, c):
    """Exact max concurrent flow via HiGHS. Variables [f_11..f_KE, lam]."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    c = np.asarray(c, np.float64)
    v, e = a.shape
    k = b.shape[0]
    n = k * e + 1
    # Equalities: A f_k - lam b_k = 0  (K*V rows)
    a_eq = np.zeros((k * v, n))
    for g in range(k):
        a_eq[g * v : (g + 1) * v, g * e : (g + 1) * e] = a
        a_eq[g * v : (g + 1) * v, -1] = -b[g]
    b_eq = np.zeros(k * v)
    # Inequalities: sum_k f_k <= c
    a_ub = np.zeros((e, n))
    for g in range(k):
        a_ub[:, g * e : (g + 1) * e] = np.eye(e)
    b_ub = c
    cost = np.zeros(n)
    cost[-1] = -1.0  # maximize lam
    res = linprog(cost, A_ub=a_ub, b_ub=b_ub, A_eq=a_eq, b_eq=b_eq, bounds=(0, None))
    assert res.status == 0, res.message
    return res.x[-1]


def run_case(num_nodes, edges, groups, iters=1200):
    a, b, c = model.build_instance(num_nodes, edges, groups)
    f, lam, res = model.pdhg_mcmf(a, b, c, iters)
    lam = float(lam)
    # Feasibility of the returned flows.
    usage = np.asarray(jnp.sum(f, axis=0))
    assert np.all(usage <= np.asarray(c) + 1e-3 * float(jnp.max(c)) + 1e-6)
    opt = linprog_mcmf(a, b, c)
    return lam, opt


def test_single_group_full_mesh():
    lam, opt = run_case(3, FULL_MESH_3, [(0, 1, 40.0)])
    assert abs(opt - 0.5) < 1e-6
    assert lam >= 0.93 * opt and lam <= opt * 1.001, (lam, opt)


def test_two_groups_share():
    lam, opt = run_case(3, FULL_MESH_3, [(0, 1, 40.0), (0, 1, 40.0)])
    assert abs(opt - 0.25) < 1e-6
    assert lam >= 0.93 * opt and lam <= opt * 1.001, (lam, opt)


def test_fig1_joint_instance():
    """Figure 1's two-coflow instance: groups of coflow-2 (A->B and C->B)."""
    lam, opt = run_case(3, FULL_MESH_3, [(0, 1, 40.0), (2, 1, 200.0)])
    assert lam >= 0.90 * opt and lam <= opt * 1.001, (lam, opt)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_random_instances_near_optimal(seed):
    rng = np.random.default_rng(seed)
    v = int(rng.integers(3, 6))
    # Ring (strong connectivity) + random chords.
    edges = []
    for u in range(v):
        edges.append((u, (u + 1) % v, float(rng.uniform(2, 20))))
        edges.append(((u + 1) % v, u, float(rng.uniform(2, 20))))
    for _ in range(int(rng.integers(0, 4))):
        u, w = rng.choice(v, 2, replace=False)
        edges.append((int(u), int(w), float(rng.uniform(2, 20))))
    k = int(rng.integers(1, 4))
    groups = []
    for _ in range(k):
        s, d = rng.choice(v, 2, replace=False)
        groups.append((int(s), int(d), float(rng.uniform(5, 100))))
    lam, opt = run_case(v, edges, groups, iters=2500)
    assert opt > 0
    assert lam <= opt * 1.01, f"infeasible-looking lam {lam} > opt {opt}"
    assert lam >= 0.85 * opt, f"lam {lam} too far from opt {opt} (seed {seed})"


def test_zero_volume_group_padding():
    """Padding rows (zero b) must not poison the solve."""
    a, b, c = model.build_instance(3, FULL_MESH_3, [(0, 1, 40.0), (0, 1, 0.0)])
    f, lam, _ = model.pdhg_mcmf(a, b, c, 1000)
    assert abs(float(lam) - 0.5) < 0.05
    # Zero-volume group's flow must stay ~0 after projection.
    assert float(jnp.sum(f[1])) < 1e-3


def test_iters_is_runtime_input():
    """The iteration count is a traced input: same lowered fn, two counts."""
    import jax

    a, b, c = model.build_instance(3, FULL_MESH_3, [(0, 1, 40.0)])
    fn = jax.jit(model.pdhg_mcmf)
    l1 = float(fn(a, b, c, 10)[1])
    l2 = float(fn(a, b, c, 500)[1])
    assert l2 >= l1 - 1e-6
    assert abs(l2 - 0.5) < 0.02
