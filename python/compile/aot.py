"""AOT lowering: jax -> HLO text artifacts for the rust PJRT runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly. See /opt/xla-example/README.md.

Usage: ``python -m compile.aot --out-dir ../artifacts``
Writes one ``mcmf_<name>.hlo.txt`` per shape variant plus ``manifest.json``
describing the shapes (consumed by ``rust/src/runtime``).
"""

import argparse
import json
import os

from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--variants",
        default=",".join(name for name, *_ in model.VARIANTS),
        help="comma-separated subset of variants to build",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    wanted = set(args.variants.split(","))

    manifest = {}
    for name, v, e, k in model.VARIANTS:
        if name not in wanted:
            continue
        lowered = model.lower_variant(v, e, k)
        text = to_hlo_text(lowered)
        fname = f"mcmf_{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {"file": fname, "v": v, "e": e, "k": k}
        print(f"wrote {path} ({len(text)} chars, V={v} E={e} K={k})")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
