"""Layer-2: the PDHG max-concurrent-flow solver as a jax computation.

This is the compute graph the rust coordinator executes per scheduling
round (when launched with ``--solver=jax``): one call solves Optimization
(1) for one coflow on the residual WAN. Shapes are fixed per artifact
variant (the runtime pads instances and selects the smallest fitting
variant); the iteration count is a runtime input so the same artifact
serves quick scheduling rounds and high-accuracy solves.

Inputs (all f32, padded):
    a      (V, E)  node-edge incidence (+1 leaves, -1 enters, 0 padding)
    b      (K, V)  vol_k * (one_hot(src_k) - one_hot(dst_k)); zero rows pad
    c      (E,)    residual capacities (0 for padding edges)
    iters  ()      int32 PDHG iterations

Outputs:
    f      (K, E)  edge flow rates per group (raw PDHG iterate, scaled onto
                   capacities — the rust side peels paths and re-trims)
    lam    ()      feasible equal-progress rate extracted from f
    res    ()      final primal residual norm (diagnostics)
"""

import jax
import jax.numpy as jnp

from compile.kernels import mcmf_kernels as K
from compile.kernels import ref

# Artifact variants: (name, V, E, K).
VARIANTS = (
    ("small", 8, 32, 16),
    ("swan", 8, 16, 32),
    ("large", 32, 128, 64),
)


def pdhg_mcmf(a, b, c, iters):
    """Run PDHG and return a feasibility-projected solution.

    Inputs are normalized internally (capacities and volumes to O(1)) so the
    preconditioned iteration converges at the same rate regardless of the
    instance's units; outputs are rescaled back.
    """
    v, e = a.shape
    k = b.shape[0]
    dt = a.dtype

    # --- Normalization: c_hat = c / c_max, b_hat = b / vol_max. ---
    c_max = jnp.maximum(jnp.max(c), 1e-9)
    vols_in = jnp.sum(jnp.maximum(b, 0.0), axis=1)
    vol_max = jnp.maximum(jnp.max(vols_in), 1e-9)
    c = c / c_max
    b = b / vol_max

    a_t = a.T
    tau_f, sigma_y1, sigma_y2, tau_lam = ref.preconditioners(a, b)
    tau_f = jnp.broadcast_to(tau_f[None, :], (k, e)).astype(dt)

    def body(_, st):
        f, f_prev, lam, lam_prev, y1, y2 = st
        f_bar = 2.0 * f - f_prev
        lam_bar = 2.0 * lam - lam_prev
        y1 = K.dual_step(f_bar, a_t, b, y1, lam_bar, sigma_y1.astype(dt))
        y2 = K.capacity_step(f_bar, c, y2, sigma_y2)
        f_next = K.primal_step(f, y1, a, y2, tau_f)
        lam_next = K.lambda_step(lam, y1, b, tau_lam)
        return f_next, f, lam_next, lam, y1, y2

    f0 = jnp.zeros((k, e), dt)
    y1 = jnp.zeros((k, v), dt)
    y2 = jnp.zeros((e,), dt)
    lam0 = jnp.asarray(0.0, dt)
    st = (f0, f0, lam0, lam0, y1, y2)
    f, _, lam_var, _, y1, y2 = jax.lax.fori_loop(0, iters, body, st)

    vols = jnp.sum(jnp.maximum(b, 0.0), axis=1)  # (K,) normalized volumes
    f_feas, lam = ref.project_feasible(f, a, b, c, vols)
    # Primal residual: conservation violation of the projected iterate.
    div = f_feas @ a.T
    res = jnp.linalg.norm(div - lam * b) / (1.0 + jnp.linalg.norm(lam * b))
    # --- Undo normalization: rates scale with c_max; λ = rate/vol. ---
    return f_feas * c_max, lam * c_max / vol_max, res


def example_args(v, e, k):
    """ShapeDtypeStructs for AOT lowering."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((v, e), f32),
        jax.ShapeDtypeStruct((k, v), f32),
        jax.ShapeDtypeStruct((e,), f32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )


def lower_variant(v, e, k):
    """Lower the jitted solver for one shape variant."""
    return jax.jit(pdhg_mcmf).lower(*example_args(v, e, k))


def build_instance(num_nodes, edges, groups):
    """Helper for tests: build padded (a, b, c) arrays from an edge list
    ``[(u, v, cap)]`` and groups ``[(src, dst, vol)]`` without padding."""
    import numpy as np

    e = len(edges)
    a = np.zeros((num_nodes, e), np.float32)
    c = np.zeros((e,), np.float32)
    for i, (u, w, cap) in enumerate(edges):
        a[u, i] = 1.0
        a[w, i] = -1.0
        c[i] = cap
    b = np.zeros((len(groups), num_nodes), np.float32)
    for g, (s, d, vol) in enumerate(groups):
        b[g, s] = vol
        b[g, d] = -vol
    return jnp.asarray(a), jnp.asarray(b), jnp.asarray(c)
