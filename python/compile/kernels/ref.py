"""Pure-jnp reference oracle for the PDHG max-concurrent-flow kernels.

Layer-1 correctness contract: every Pallas kernel in ``mcmf_kernels.py``
must match these functions up to float tolerance (pytest + hypothesis sweep
shapes and dtypes).

Problem (edge-based Optimization (1), §3.1.1 of the Terra paper):

    maximize    lambda
    subject to  A @ f_k == lambda * b_k     (flow conservation per group)
                sum_k f_k <= c              (joint edge capacities)
                f >= 0, lambda >= 0

with A in {-1,0,1}^{V x E} the node-edge incidence matrix
(+1 = edge leaves node, -1 = edge enters node) and
b_k = vol_k * (one_hot(src_k) - one_hot(dst_k)).
"""

import jax.numpy as jnp


def dual_step(f_bar, a_t, b, y1, lam_bar, sigma):
    """Dual ascent on the flow-conservation multipliers.

    ``y1' = y1 + sigma * (f_bar @ A^T - lam_bar * b)``

    Shapes: f_bar (K,E), a_t (E,V), b (K,V), y1 (K,V), sigma (K,V)/scalar.
    """
    div = f_bar @ a_t
    return y1 + sigma * (div - lam_bar * b)


def primal_step(f, y1, a, y2, tau):
    """Projected primal descent on the edge flows.

    ``f' = relu(f - tau * (y1 @ A + y2))``

    Shapes: f (K,E), y1 (K,V), a (V,E), y2 (E,), tau (K,E)/scalar.
    """
    grad = y1 @ a + y2[None, :]
    return jnp.maximum(f - tau * grad, 0.0)


def capacity_step(f_bar, c, y2, sigma):
    """Dual ascent on the projected capacity multipliers.

    ``y2' = max(0, y2 + sigma * (sum_k f_bar - c))``
    """
    usage = jnp.sum(f_bar, axis=0)
    return jnp.maximum(y2 + sigma * (usage - c), 0.0)


def lambda_step(lam, y1, b, tau):
    """Gradient step on lambda.

    ``dL/dlam = -1 - sum(b * y1)``, so projected descent is
    ``lam' = max(0, lam + tau * (1 + sum(b * y1)))``.
    """
    g = 1.0 + jnp.sum(b * y1)
    return jnp.maximum(lam + tau * g, 0.0)


def preconditioners(a, b):
    """Pock-Chambolle diagonal step sizes from the stacked operator.

    Returns (tau_f (E,), sigma_y1 (K,V), sigma_y2 scalar, tau_lam scalar).
    """
    k = b.shape[0]
    deg = jnp.sum(jnp.abs(a), axis=1)  # (V,)
    # Column of f_{k,e}: two incidence entries (|A| column sum) + 1 cap row.
    col_f = jnp.sum(jnp.abs(a), axis=0) + 1.0  # (E,)
    tau_f = 1.0 / col_f
    # Row (k, v): deg(v) incidence entries + |b_kv| lambda entry.
    sigma_y1 = 1.0 / jnp.maximum(deg[None, :] + jnp.abs(b), 1e-6)
    # Capacity row e: K flow entries.
    sigma_y2 = 1.0 / float(max(k, 1))
    # Lambda column: sum |b|.
    tau_lam = 1.0 / jnp.maximum(jnp.sum(jnp.abs(b)), 1e-6)
    return tau_f, sigma_y1, sigma_y2, tau_lam


def pdhg_solve_ref(a, b, c, iters=2000):
    """Full PDHG reference solver (pure jnp, no Pallas).

    Returns the raw iterate ``(f, lam_var)``; use ``project_feasible`` for a
    guaranteed-feasible solution.
    """
    v, e = a.shape
    k = b.shape[0]
    a_t = a.T
    tau_f, sigma_y1, sigma_y2, tau_lam = preconditioners(a, b)
    f = jnp.zeros((k, e), a.dtype)
    y1 = jnp.zeros((k, v), a.dtype)
    y2 = jnp.zeros((e,), a.dtype)
    lam = jnp.asarray(0.0, a.dtype)
    f_prev, lam_prev = f, lam
    for _ in range(iters):
        f_bar = 2.0 * f - f_prev
        lam_bar = 2.0 * lam - lam_prev
        y1 = dual_step(f_bar, a_t, b, y1, lam_bar, sigma_y1)
        y2 = capacity_step(f_bar, c, y2, sigma_y2)
        f_prev, lam_prev = f, lam
        f = primal_step(f, y1, a, y2, tau_f[None, :])
        lam = lambda_step(lam, y1, b, tau_lam)
    return f, lam


def project_feasible(f, a, b, c, vols):
    """Turn a raw PDHG iterate into a feasible equal-progress solution.

    1. scale flows onto capacities;
    2. per-group deliverable rate = min(net outflow at src, net inflow at
       dst) — conservative under small conservation violations;
    3. lambda = worst group's progress.

    Mirrors the rust runtime's path-peeling post-processing; used by tests.
    Returns ``(f_scaled, lambda)``.
    """
    usage = jnp.sum(f, axis=0)
    theta = jnp.min(jnp.where(usage > 1e-9, c / jnp.maximum(usage, 1e-12), jnp.inf))
    theta = jnp.clip(theta, 0.0, 1.0)
    f = f * theta
    div = f @ a.T  # (K,V) net outflow per node
    dst_rate = jnp.sum(jnp.maximum(-div, 0.0) * (b < 0), axis=1)
    src_rate = jnp.sum(jnp.maximum(div, 0.0) * (b > 0), axis=1)
    rate = jnp.minimum(dst_rate, src_rate)
    lam = jnp.min(jnp.where(vols > 0, rate / jnp.maximum(vols, 1e-12), jnp.inf))
    return f, lam
