"""Layer-1 Pallas kernels: the PDHG iteration's dense linear algebra.

The per-coflow minimum-CCT LP (Optimization (1)) reduces to max concurrent
flow; its PDHG iteration is dominated by two incidence-matrix products,
``f_bar @ A^T`` (K,E)x(E,V) and ``y1 @ A`` (K,V)x(V,E), each fused here with
the following elementwise update so the iterate never round-trips to HBM.

TPU mapping (DESIGN.md §Hardware-Adaptation): at the padded sizes
(K=64, E=128, V=32, f32) the full state is ~100 KB — it fits VMEM in one
block, so each kernel is a single-grid pallas_call whose matmul feeds the
MXU and whose epilogue runs on the VPU. ``interpret=True`` everywhere: the
CPU PJRT plugin cannot execute Mosaic custom-calls, and interpret mode
lowers to plain HLO so the same artifact runs under the rust runtime.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dual_kernel(f_bar_ref, a_t_ref, b_ref, y1_ref, scal_ref, sigma_ref, out_ref):
    """y1' = y1 + sigma * (f_bar @ A^T - lam_bar * b)   (fused MXU+VPU)."""
    div = f_bar_ref[...] @ a_t_ref[...]
    lam_bar = scal_ref[0]
    out_ref[...] = y1_ref[...] + sigma_ref[...] * (div - lam_bar * b_ref[...])


def dual_step(f_bar, a_t, b, y1, lam_bar, sigma):
    """Pallas version of :func:`ref.dual_step`."""
    k, v = y1.shape
    sigma = jnp.broadcast_to(jnp.asarray(sigma, y1.dtype), (k, v))
    scal = jnp.reshape(jnp.asarray(lam_bar, y1.dtype), (1,))
    return pl.pallas_call(
        _dual_kernel,
        out_shape=jax.ShapeDtypeStruct((k, v), y1.dtype),
        interpret=True,
    )(f_bar, a_t, b, y1, scal, sigma)


def _primal_kernel(f_ref, y1_ref, a_ref, y2_ref, tau_ref, out_ref):
    """f' = relu(f - tau * (y1 @ A + y2))   (fused MXU+VPU)."""
    grad = y1_ref[...] @ a_ref[...] + y2_ref[...][None, :]
    out_ref[...] = jnp.maximum(f_ref[...] - tau_ref[...] * grad, 0.0)


def primal_step(f, y1, a, y2, tau):
    """Pallas version of :func:`ref.primal_step`."""
    k, e = f.shape
    tau = jnp.broadcast_to(jnp.asarray(tau, f.dtype), (k, e))
    return pl.pallas_call(
        _primal_kernel,
        out_shape=jax.ShapeDtypeStruct((k, e), f.dtype),
        interpret=True,
    )(f, y1, a, y2, tau)


def _capacity_kernel(f_bar_ref, c_ref, y2_ref, sigma_ref, out_ref):
    """y2' = max(0, y2 + sigma * (sum_k f_bar - c))   (VPU reduction)."""
    usage = jnp.sum(f_bar_ref[...], axis=0)
    out_ref[...] = jnp.maximum(y2_ref[...] + sigma_ref[0] * (usage - c_ref[...]), 0.0)


def capacity_step(f_bar, c, y2, sigma):
    """Pallas version of :func:`ref.capacity_step`."""
    (e,) = y2.shape
    sig = jnp.reshape(jnp.asarray(sigma, y2.dtype), (1,))
    return pl.pallas_call(
        _capacity_kernel,
        out_shape=jax.ShapeDtypeStruct((e,), y2.dtype),
        interpret=True,
    )(f_bar, c, y2, sig)


@functools.partial(jax.jit, static_argnames=())
def lambda_step(lam, y1, b, tau):
    """Scalar update — too small for a kernel; plain jnp (fuses into XLA).

    ``dL/dlam = -1 - sum(b * y1)`` so the projected descent step is
    ``lam' = max(0, lam + tau * (1 + sum(b * y1)))``.
    """
    g = 1.0 + jnp.sum(b * y1)
    return jnp.maximum(lam + tau * g, 0.0)
