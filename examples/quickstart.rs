//! Quickstart: run a small BigBench-style workload on the SWAN topology
//! under Terra and per-flow fair sharing, and print the factor of
//! improvement — a miniature of the paper's headline experiment.
//!
//! ```sh
//! cargo run --release --example quickstart -- --jobs 30 --seed 42
//! ```

use terra::baselines::FairPolicy;
use terra::net::topologies;
use terra::scheduler::TerraPolicy;
use terra::sim::{foi, SimConfig, Simulation};
use terra::util::cli::Args;
use terra::workloads::{WorkloadGen, WorkloadKind};

fn main() {
    terra::util::logger::init();
    let args = Args::from_env();
    let jobs = args.get_usize("jobs", 30);
    let seed = args.get_u64("seed", 42);

    let wan = topologies::swan();
    println!(
        "WAN: SWAN ({} datacenters, {} links)",
        wan.num_nodes(),
        wan.num_undirected()
    );

    let gen_jobs = |seed| WorkloadGen::new(WorkloadKind::BigBench, seed).jobs(&wan, jobs);

    let mut terra_sim =
        Simulation::new(wan.clone(), Box::new(TerraPolicy::default()), SimConfig::default());
    let terra_rep = terra_sim.run_jobs(gen_jobs(seed));

    let mut fair_sim =
        Simulation::new(wan.clone(), Box::new(FairPolicy::per_flow()), SimConfig::default());
    let fair_rep = fair_sim.run_jobs(gen_jobs(seed));

    println!("\n{:<12} {:>12} {:>12} {:>12} {:>12}", "policy", "avg JCT", "p95 JCT", "avg CCT", "util");
    for rep in [&fair_rep, &terra_rep] {
        println!(
            "{:<12} {:>11.1}s {:>11.1}s {:>11.1}s {:>11.1}%",
            rep.policy,
            rep.avg_jct(),
            rep.p95_jct(),
            rep.avg_cct(),
            rep.utilization() * 100.0
        );
    }
    println!(
        "\nFactor of improvement (Terra vs per-flow): avg JCT {:.2}x, p95 JCT {:.2}x, avg CCT {:.2}x",
        foi(fair_rep.avg_jct(), terra_rep.avg_jct()),
        foi(fair_rep.p95_jct(), terra_rep.p95_jct()),
        foi(fair_rep.avg_cct(), terra_rep.avg_cct()),
    );
    for rep in [&fair_rep, &terra_rep] {
        println!(
            "{} controller: {} rounds, {} LP solves, {:.1} ms/round ({:.2}s total)",
            rep.policy,
            rep.rounds,
            rep.lp_solves,
            1e3 * rep.round_time_s / rep.rounds.max(1) as f64,
            rep.round_time_s,
        );
    }
}
