//! Real-bytes testbed demo: controller + 3 agents over loopback TCP
//! (persistent multipath connections, token-bucket rates, SDN rule table),
//! transferring an actual coflow through the §5.2 client API.
//!
//! ```sh
//! cargo run --release --example testbed_overlay -- --gbit 6
//! ```

use terra::api::TerraClient;
use terra::net::topologies;
use terra::overlay::protocol::FlowSpec;
use terra::overlay::{Agent, Controller, TestbedConfig, BYTES_PER_GBPS};
use terra::scheduler::terra::{TerraConfig, TerraPolicy};
use terra::util::cli::Args;

fn main() {
    terra::util::logger::init();
    let args = Args::from_env();
    let gbit = args.get_f64("gbit", 6.0);
    let wan = topologies::fig1a();
    let n = wan.num_nodes();

    let policy = TerraPolicy::new(TerraConfig { alpha: 0.0, k: 3, ..Default::default() });
    let handle = Controller::spawn(TestbedConfig { wan, k: 3 }, Box::new(policy)).unwrap();
    let agents: Vec<Agent> = (0..n).map(|dc| Agent::spawn(dc, handle.addr).unwrap()).collect();
    assert!(handle.wait_ready(n, std::time::Duration::from_secs(10)));
    let (rules, updates) = handle.rule_stats();
    println!("overlay up: {n} agents, k=3 persistent paths/pair, {rules} rules/switch max ({updates} installs)");

    let mut client = TerraClient::connect(handle.addr).unwrap();
    // Coflow: two FlowGroups into DC1 (B), à la Figure 1.
    let flows = [
        FlowSpec { id: 0, src_dc: 0, dst_dc: 1, bytes: (gbit * BYTES_PER_GBPS) as u64 },
        FlowSpec { id: 1, src_dc: 2, dst_dc: 1, bytes: (gbit * 2.0 * BYTES_PER_GBPS) as u64 },
    ];
    let cid = client.submit_coflow(&flows, None).unwrap() as u64;
    println!("submitted coflow {cid}: {gbit} Gbit A->B + {} Gbit C->B", gbit * 2.0);

    // Sample throughput at the receiving agent while it runs (Fig 10 style).
    let t0 = std::time::Instant::now();
    let mut last = (0u64, 0u64);
    loop {
        std::thread::sleep(std::time::Duration::from_millis(250));
        let now = (agents[1].received_bytes(cid, 0), agents[1].received_bytes(cid, 2));
        let gbps = |d: u64| d as f64 / BYTES_PER_GBPS / 0.25;
        println!(
            "  t={:4.1}s  A->B {:5.1} Gbps   C->B {:5.1} Gbps",
            t0.elapsed().as_secs_f64(),
            gbps(now.0 - last.0),
            gbps(now.1 - last.1)
        );
        last = now;
        if let terra::overlay::protocol::CoflowStatus::Done { cct_s } =
            client.check_status(cid).unwrap()
        {
            println!("coflow done: CCT {cct_s:.3}s, aggregate rate {:.1} Gbps", gbit * 3.0 / cct_s);
            break;
        }
        if t0.elapsed().as_secs_f64() > 60.0 {
            println!("timeout");
            break;
        }
    }
    let (rules2, updates2) = handle.rule_stats();
    println!("rule table unchanged during transfer: {}", (rules2, updates2) == (rules, updates));
    for a in agents {
        a.shutdown();
    }
    handle.shutdown();
}
