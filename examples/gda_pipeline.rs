//! **End-to-end driver** (DESIGN.md: the required full-system validation):
//! run the paper's headline experiment at small scale — a BigBench-like DAG
//! workload (default 120 jobs, scale factors 40-100) on the SWAN WAN under
//! Terra and all five baselines, reporting the Table-3-style factors of
//! improvement, utilization, slowdowns, and controller overheads.
//!
//! ```sh
//! cargo run --release --example gda_pipeline -- --jobs 120 --topology swan
//! ```
//!
//! Results of the recorded run live in EXPERIMENTS.md.

use terra::baselines;
use terra::net::topologies;
use terra::scheduler::terra::TerraPolicy;
use terra::sim::{foi, SimConfig, Simulation};
use terra::util::bench::Table;
use terra::util::cli::Args;
use terra::workloads::{WorkloadConfig, WorkloadGen, WorkloadKind};

fn main() {
    terra::util::logger::init();
    let args = Args::from_env();
    let n = args.get_usize("jobs", 120);
    let seed = args.get_u64("seed", 42);
    let topo = args.get_or("topology", "swan");
    let wan = topologies::by_name(topo).expect("unknown topology");
    let kind = WorkloadKind::by_name(args.get_or("workload", "bigbench")).unwrap();

    let mk_jobs = || {
        let mut cfg = WorkloadConfig::new(kind, seed);
        cfg.machines_per_dc = 100;
        WorkloadGen::with_config(cfg).jobs(&wan, n)
    };
    println!(
        "workload: {} x {} jobs on {topo} ({} DCs / {} links), total WAN volume {:.0} Gbit",
        kind.name(),
        n,
        wan.num_nodes(),
        wan.num_undirected(),
        mk_jobs().iter().map(|j| j.total_volume()).sum::<f64>()
    );

    let mut results = Vec::new();
    for pname in ["terra", "per-flow", "multipath", "varys", "swan-mcf", "rapier"] {
        let policy: Box<dyn terra::scheduler::Policy> = if pname == "terra" {
            Box::new(TerraPolicy::default())
        } else {
            baselines::by_name(pname).unwrap()
        };
        let t0 = std::time::Instant::now();
        let mut sim = Simulation::new(wan.clone(), policy, SimConfig::default());
        let rep = sim.run_jobs(mk_jobs());
        println!(
            "  ran {pname:<10} wall {:6.2}s  rounds {:5}  LPs {:6}",
            t0.elapsed().as_secs_f64(),
            rep.rounds,
            rep.lp_solves
        );
        results.push(rep);
    }

    let terra_rep = &results[0];
    let mut tab = Table::new(&[
        "policy", "avg JCT", "p95 JCT", "avg CCT", "util", "slowdown", "FoI(avg)", "FoI(p95)",
    ]);
    for rep in &results {
        tab.row(&[
            rep.policy.clone(),
            format!("{:.0}s", rep.avg_jct()),
            format!("{:.0}s", rep.p95_jct()),
            format!("{:.1}s", rep.avg_cct()),
            format!("{:.1}%", rep.utilization() * 100.0),
            format!("{:.2}x", rep.avg_slowdown()),
            format!("{:.2}x", foi(rep.avg_jct(), terra_rep.avg_jct())),
            format!("{:.2}x", foi(rep.p95_jct(), terra_rep.p95_jct())),
        ]);
    }
    tab.print(&format!("GDA pipeline on {topo}: Terra vs 5 baselines (headline metric: FoI avg JCT)"));
    println!(
        "\nTerra controller: {:.2} ms/round over {} rounds; every job finished: {}",
        1e3 * terra_rep.round_time_s / terra_rep.rounds.max(1) as f64,
        terra_rep.rounds,
        terra_rep.unfinished() == 0
    );
}
