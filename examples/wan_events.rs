//! WAN-event reaction demo (Figures 9+10): two jobs share the WAN; a link
//! fails mid-transfer and later recovers. Terra preempts the lower-priority
//! job to protect the smaller one, reschedules it when capacity returns,
//! and adds the restored path back — all application-aware (§6.5).
//!
//! ```sh
//! cargo run --release --example wan_events
//! ```

use terra::coflow::{Flow, GB};
use terra::net::{topologies, LinkEvent};
use terra::scheduler::terra::{TerraConfig, TerraPolicy};
use terra::sim::{Job, SimConfig, Simulation};
use terra::util::cli::Args;

fn main() {
    terra::util::logger::init();
    let args = Args::from_env();
    let fail_t = args.get_f64("fail-at", 3.0);
    let recover_t = args.get_f64("recover-at", 20.0);

    let wan = topologies::swan();
    let policy = TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() });
    let mut sim = Simulation::new(wan, Box::new(policy), SimConfig::default());
    // Job 1 small (higher priority under SRTF), Job 2 large; both LA -> NY.
    sim.add_job(Job::map_reduce(1, 0.0, 0.0, vec![Flow { id: 0, src_dc: 1, dst_dc: 0, volume: 20.0 * GB }]));
    sim.add_job(Job::map_reduce(2, 0.0, 0.0, vec![Flow { id: 0, src_dc: 1, dst_dc: 0, volume: 60.0 * GB }]));
    sim.add_wan_event(fail_t, LinkEvent::Fail(0, 1));
    sim.add_wan_event(recover_t, LinkEvent::Recover(0, 1));

    println!("t(s)   job1(Gbps) job2(Gbps)   event");
    for step in 0..40 {
        let t = step as f64;
        sim.run_until(t);
        let ev = if (t - fail_t).abs() < 0.5 {
            "<- NY-LA link FAILS (Terra preempts job 2)"
        } else if (t - recover_t).abs() < 0.5 {
            "<- link RECOVERS (job 2 gets the path back)"
        } else {
            ""
        };
        println!("{t:5.1}  {:9.1}  {:9.1}   {ev}", sim.coflow_rate(1), sim.coflow_rate(2));
    }
    let rep = sim.run();
    println!(
        "\nJCTs: job1 {:.1}s (protected), job2 {:.1}s; all transfers completed: {}",
        rep.jobs[0].jct().unwrap_or(f64::NAN),
        rep.jobs[1].jct().unwrap_or(f64::NAN),
        rep.unfinished() == 0
    );
}
