//! Deadline admission control demo (§3.2, §6.4): submit a mix of
//! deadline-bearing coflows; Terra admits only those whose deadline is
//! achievable, never preempts admitted ones, and dilates them to finish
//! exactly on time — freeing bandwidth for best-effort coflows.
//!
//! ```sh
//! cargo run --release --example deadline_admission -- --jobs 40 --d 4
//! ```

use terra::net::topologies;
use terra::scheduler::terra::TerraPolicy;
use terra::sim::{SimConfig, Simulation};
use terra::util::bench::Table;
use terra::util::cli::Args;
use terra::workloads::{assign_deadlines, WorkloadConfig, WorkloadGen, WorkloadKind};

fn main() {
    terra::util::logger::init();
    let args = Args::from_env();
    let n = args.get_usize("jobs", 40);
    let seed = args.get_u64("seed", 42);
    let wan = topologies::swan();

    let mut tab = Table::new(&["d", "admitted", "met (terra)", "met (per-flow)", "ratio"]);
    for d in [2.0, 3.0, 4.0, 5.0, 6.0] {
        let mk = || {
            let cfg = WorkloadConfig::new(WorkloadKind::BigBench, seed);
            let mut jobs = WorkloadGen::with_config(cfg).jobs(&wan, n);
            assign_deadlines(&mut jobs, &wan, d);
            jobs
        };
        let mut terra_sim =
            Simulation::new(wan.clone(), Box::new(TerraPolicy::default()), SimConfig::default());
        let t = terra_sim.run_jobs(mk());
        let mut fair_sim = Simulation::new(
            wan.clone(),
            terra::baselines::by_name("per-flow").unwrap(),
            SimConfig::default(),
        );
        let f = fair_sim.run_jobs(mk());
        let admitted = t.coflows.iter().filter(|c| c.deadline.is_some() && c.admitted).count();
        let total = t.coflows.iter().filter(|c| c.deadline.is_some()).count();
        tab.row(&[
            format!("{d:.0}x"),
            format!("{admitted}/{total}"),
            format!("{:.0}%", t.deadline_met_fraction() * 100.0),
            format!("{:.0}%", f.deadline_met_fraction() * 100.0),
            format!("{:.2}x", t.deadline_met_fraction() / f.deadline_met_fraction().max(1e-9)),
        ]);
    }
    tab.print("Deadline admission: coflows meeting d x min-CCT deadlines (paper Fig 8)");
}
