//! Minimal offline stand-in for the `anyhow` crate, covering the subset the
//! `terra` crate uses: [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]
//! macros, and the [`Context`] extension trait. Errors carry a message and
//! an optional context chain; no backtraces.

use std::fmt;

/// A type-erased error: a message plus outer context frames (most recent
/// first, matching anyhow's Display of the top frame).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    fn wrap<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

/// Attach context to errors (`Result`) or missing values (`Option`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macros_and_context() {
        let e: Error = anyhow!("bad {}", 7);
        assert_eq!(e.to_string(), "bad 7");
        let r: Result<()> = (|| bail!("fail {}", "now"))();
        assert_eq!(r.unwrap_err().to_string(), "fail now");
        let io: std::io::Result<()> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "deep"));
        let wrapped = io.context("outer").unwrap_err();
        assert_eq!(wrapped.to_string(), "outer");
        assert!(format!("{wrapped:?}").contains("deep"));
        let missing: Option<u32> = None;
        assert!(missing.with_context(|| "absent").is_err());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let n: u32 = "x".parse()?;
            Ok(n)
        }
        assert!(inner().is_err());
    }
}
