//! Minimal offline stand-in for the `log` facade crate, covering the subset
//! `terra` uses: the five level macros, the [`Log`] trait, [`set_logger`] /
//! [`set_max_level`], and the [`Level`]/[`LevelFilter`] orderings.

use std::fmt;
use std::sync::atomic::{AtomicPtr, AtomicUsize, Ordering};

/// Log verbosity of one record. Ordered `Error < Warn < Info < Debug <
/// Trace` (more verbose is "greater"), matching the real crate.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        // `pad` honors width/alignment flags (loggers print `{:5}`).
        f.pad(s)
    }
}

/// Maximum-verbosity filter installed via [`set_max_level`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata of a record (level + target module path).
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus pre-formatted arguments.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// Backend trait implemented by loggers.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

/// Returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0);
static LOGGER: AtomicPtr<()> = AtomicPtr::new(std::ptr::null_mut());

/// Install the global logger. Fails (harmlessly) if one is already set.
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    // Box the wide pointer so it fits one AtomicPtr slot.
    let boxed: Box<&'static dyn Log> = Box::new(logger);
    let raw = Box::into_raw(boxed) as *mut ();
    match LOGGER.compare_exchange(
        std::ptr::null_mut(),
        raw,
        Ordering::SeqCst,
        Ordering::SeqCst,
    ) {
        Ok(_) => Ok(()),
        Err(_) => {
            // Lost the race: reclaim the box and report the conflict.
            unsafe { drop(Box::from_raw(raw as *mut &'static dyn Log)) };
            Err(SetLoggerError(()))
        }
    }
}

/// Set the global maximum level; records above it are skipped.
pub fn set_max_level(level: LevelFilter) {
    MAX_LEVEL.store(level as usize, Ordering::SeqCst);
}

/// Current global maximum level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::SeqCst) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

fn logger() -> Option<&'static dyn Log> {
    let raw = LOGGER.load(Ordering::SeqCst);
    if raw.is_null() {
        None
    } else {
        Some(*unsafe { &*(raw as *const &'static dyn Log) })
    }
}

#[doc(hidden)]
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments) {
    if level > max_level() {
        return;
    }
    if let Some(l) = logger() {
        let record = Record { metadata: Metadata { level, target }, args };
        if l.enabled(record.metadata()) {
            l.log(&record);
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_log($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static HITS: AtomicUsize = AtomicUsize::new(0);

    struct Counter;
    impl Log for Counter {
        fn enabled(&self, metadata: &Metadata) -> bool {
            metadata.level() <= LevelFilter::Info
        }
        fn log(&self, record: &Record) {
            if self.enabled(record.metadata()) {
                HITS.fetch_add(1, Ordering::SeqCst);
            }
        }
        fn flush(&self) {}
    }

    #[test]
    fn filters_by_level() {
        static C: Counter = Counter;
        let _ = set_logger(&C);
        set_max_level(LevelFilter::Info);
        info!("counted {}", 1);
        debug!("not counted");
        assert!(HITS.load(Ordering::SeqCst) >= 1);
        assert!(Level::Warn <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
    }
}
