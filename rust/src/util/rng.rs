//! Deterministic, seedable pseudo-random number generation.
//!
//! The environment does not ship the `rand` crate, so we implement PCG32
//! (O'Neill 2014) with a SplitMix64 seeder. All simulation and workload
//! generation in this crate is deterministic given a seed, which the paper's
//! evaluation methodology (repeated runs over fixed traces) relies on.

/// PCG32 generator: 64-bit state, 32-bit output, period 2^64.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

/// SplitMix64 step, used to derive well-distributed seeds.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Pcg32 {
    /// Create a generator from a seed; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let initstate = splitmix64(&mut sm);
        let initseq = splitmix64(&mut sm) | 1;
        let mut rng = Pcg32 { state: 0, inc: initseq };
        rng.state = rng.state.wrapping_add(initstate);
        rng.next_u32();
        rng
    }

    /// Derive an independent child stream (for per-job / per-module RNGs).
    pub fn fork(&mut self, tag: u64) -> Pcg32 {
        let s = (self.next_u64() ^ tag).wrapping_mul(PCG_MULT);
        Pcg32::new(s)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift with rejection for unbiased output.
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul128(x, n);
            if lo >= n || lo >= x.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Uniform float in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Exponentially distributed with the given mean.
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // avoid ln(0)
        -mean * u.ln()
    }

    /// Bounded Pareto on `[lo, hi]` with tail index `alpha` — used for the
    /// heavy-tailed Facebook coflow volumes.
    pub fn pareto(&mut self, lo: f64, hi: f64, alpha: f64) -> f64 {
        let u = self.f64();
        let la = lo.powf(alpha);
        let ha = hi.powf(alpha);
        (-(u * ha - u * la - ha) / (ha * la)).powf(-1.0 / alpha)
    }

    /// Log-normal via Box-Muller.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.gaussian()).exp()
    }

    /// Standard normal via Box-Muller.
    pub fn gaussian(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Random boolean with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Choose a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `n` distinct indices from `[0, m)`.
    pub fn sample_indices(&mut self, m: usize, n: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..m).collect();
        self.shuffle(&mut idx);
        idx.truncate(n.min(m));
        idx
    }
}

#[inline]
fn mul128(a: u64, b: u64) -> (u64, u64) {
    let r = (a as u128) * (b as u128);
    ((r >> 64) as u64, r as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg32::new(7);
        let mut b = Pcg32::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg32::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_smoke() {
        let mut r = Pcg32::new(11);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Pcg32::new(5);
        let n = 200_000;
        let s: f64 = (0..n).map(|_| r.exp(3.0)).sum();
        let mean = s / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn pareto_bounded() {
        let mut r = Pcg32::new(9);
        for _ in 0..10_000 {
            let x = r.pareto(1.0, 1000.0, 1.1);
            assert!(x >= 0.999 && x <= 1000.001, "x={x}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Pcg32::new(21);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }
}
