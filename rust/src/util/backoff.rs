//! Seeded exponential backoff with jitter, and a small per-peer circuit
//! breaker — the shared retry substrate for every dial loop in the overlay
//! (controller reconnects, peer data connections).
//!
//! Why not a fixed delay: a fleet of agents losing the same controller (or
//! the same peer) all retry in lockstep, and a 200 ms constant turns an
//! outage into a synchronized connect storm the moment the target returns.
//! Exponential growth bounds the aggregate attempt rate during a long
//! outage; jitter decorrelates the fleet; the seed keeps every delay
//! sequence reproducible in tests ([`crate::util::rng::Pcg32`] underneath —
//! no wall-clock entropy anywhere).
//!
//! The jitter policy is "equal jitter": for attempt `n` the delay is drawn
//! uniformly from `[cap/2, cap)` where `cap = min(base·2ⁿ, max)`. The lower
//! half is kept deterministic so the expected delay still doubles per
//! attempt (full jitter can collapse to ~0 and re-synchronize retries), and
//! the upper half spreads the fleet.

use crate::util::rng::Pcg32;
use std::time::Duration;

/// Exponential backoff schedule with equal jitter. One instance per dial
/// loop; [`Backoff::reset`] on success.
#[derive(Clone, Debug)]
pub struct Backoff {
    base: Duration,
    max: Duration,
    attempt: u32,
    rng: Pcg32,
}

impl Backoff {
    /// A schedule starting at `base` and capping at `max`. `seed` pins the
    /// jitter stream; derive it from a stable identity (dc id, peer id) so
    /// distinct dialers jitter independently but reproducibly.
    pub fn new(seed: u64, base: Duration, max: Duration) -> Backoff {
        Backoff { base, max: max.max(base), attempt: 0, rng: Pcg32::new(seed) }
    }

    /// The delay to sleep before the next attempt. Guaranteed within
    /// `[cap/2, cap]` for `cap = min(base·2^attempt, max)`, so the lower
    /// bound is monotone non-decreasing until the cap is reached and the
    /// delay never exceeds `max`.
    pub fn next_delay(&mut self) -> Duration {
        let cap = self.cap();
        self.attempt = self.attempt.saturating_add(1);
        let half = cap / 2.0;
        Duration::from_secs_f64(half + self.rng.uniform(0.0, half))
    }

    /// Number of delays handed out since the last reset.
    pub fn attempts(&self) -> u32 {
        self.attempt
    }

    /// Back to the base schedule — call on a successful attempt.
    pub fn reset(&mut self) {
        self.attempt = 0;
    }

    fn cap(&self) -> f64 {
        let exp = self.attempt.min(32); // 2^32 × base saturates any max
        (self.base.as_secs_f64() * (1u64 << exp) as f64).min(self.max.as_secs_f64())
    }
}

/// Consecutive failures before a [`CircuitBreaker`] opens.
pub const BREAKER_THRESHOLD: u32 = 3;

/// Per-peer circuit breaker over a [`Backoff`] schedule. Closed passes
/// every attempt through; after [`BREAKER_THRESHOLD`] consecutive failures
/// it opens and refuses attempts for the schedule's current delay, then
/// admits exactly one half-open probe whose outcome either closes the
/// breaker (and resets the schedule) or re-opens it for the next, longer
/// cooldown. Time is passed in by the caller (seconds on any monotone
/// clock) so the policy is unit-testable without sleeping.
#[derive(Clone, Debug)]
pub struct CircuitBreaker {
    backoff: Backoff,
    consecutive_failures: u32,
    /// `Some(t)` while open: no attempt before `t`. The first attempt at or
    /// after `t` is the half-open probe.
    open_until: Option<f64>,
    /// True while the single half-open probe is outstanding.
    probing: bool,
}

impl CircuitBreaker {
    pub fn new(seed: u64, base: Duration, max: Duration) -> CircuitBreaker {
        CircuitBreaker {
            backoff: Backoff::new(seed, base, max),
            consecutive_failures: 0,
            open_until: None,
            probing: false,
        }
    }

    /// May the caller dial now? Closed → always; open → only once the
    /// cooldown expired, and then only the single half-open probe until its
    /// outcome is recorded.
    pub fn allow(&mut self, now_s: f64) -> bool {
        match self.open_until {
            None => true,
            Some(t) => {
                if self.probing || now_s < t {
                    return false;
                }
                self.probing = true;
                true
            }
        }
    }

    /// Record a successful attempt: breaker closes, schedule resets.
    pub fn record_success(&mut self) {
        self.consecutive_failures = 0;
        self.open_until = None;
        self.probing = false;
        self.backoff.reset();
    }

    /// Record a failed attempt at `now_s`. Opens the breaker once the
    /// consecutive-failure threshold is reached (a failed half-open probe
    /// re-opens immediately, with the next longer delay).
    pub fn record_failure(&mut self, now_s: f64) {
        self.consecutive_failures = self.consecutive_failures.saturating_add(1);
        self.probing = false;
        if self.consecutive_failures >= BREAKER_THRESHOLD {
            let cooldown = self.backoff.next_delay().as_secs_f64();
            self.open_until = Some(now_s + cooldown);
        }
    }

    /// True while attempts are being refused (cooldown running or a probe
    /// outstanding).
    pub fn is_open(&self) -> bool {
        self.open_until.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(x: u64) -> Duration {
        Duration::from_millis(x)
    }

    /// Every delay stays within [cap/2, cap] for the attempt's cap, and
    /// never exceeds the configured max.
    #[test]
    fn delays_are_bounded_by_schedule_and_max() {
        let base = ms(100);
        let max = ms(5_000);
        let mut b = Backoff::new(42, base, max);
        for attempt in 0..20u32 {
            let cap = (base.as_secs_f64() * (1u64 << attempt.min(32)) as f64)
                .min(max.as_secs_f64());
            let d = b.next_delay().as_secs_f64();
            assert!(d >= cap / 2.0 - 1e-12, "attempt {attempt}: {d} < {}", cap / 2.0);
            assert!(d <= cap + 1e-12, "attempt {attempt}: {d} > {cap}");
            assert!(d <= max.as_secs_f64() + 1e-12);
        }
    }

    /// The deterministic lower half makes the floor of the schedule
    /// monotone non-decreasing up to the cap — no early attempt can draw a
    /// longer delay than a later attempt's guaranteed minimum would allow
    /// to shrink back below.
    #[test]
    fn lower_bound_is_monotone_until_capped() {
        let mut b = Backoff::new(7, ms(50), ms(10_000));
        let mut prev_floor = 0.0;
        for _ in 0..12 {
            let cap = {
                let attempt = b.attempts();
                (0.05 * (1u64 << attempt) as f64).min(10.0)
            };
            let floor = cap / 2.0;
            assert!(floor >= prev_floor, "floor regressed: {floor} < {prev_floor}");
            prev_floor = floor;
            let d = b.next_delay().as_secs_f64();
            assert!(d >= floor - 1e-12);
        }
    }

    /// Same seed ⇒ identical delay sequence; distinct seeds decorrelate.
    #[test]
    fn seeded_delays_are_deterministic() {
        let mut a = Backoff::new(99, ms(100), ms(4_000));
        let mut b = Backoff::new(99, ms(100), ms(4_000));
        let mut c = Backoff::new(100, ms(100), ms(4_000));
        let mut all_equal_c = true;
        for _ in 0..16 {
            let (da, db, dc) = (a.next_delay(), b.next_delay(), c.next_delay());
            assert_eq!(da, db, "same seed must replay the same schedule");
            all_equal_c &= da == dc;
        }
        assert!(!all_equal_c, "distinct seeds should jitter differently");
    }

    /// Reset returns to the base cap.
    #[test]
    fn reset_restarts_the_schedule() {
        let mut b = Backoff::new(5, ms(100), ms(10_000));
        for _ in 0..6 {
            b.next_delay();
        }
        b.reset();
        let d = b.next_delay().as_secs_f64();
        assert!(d <= 0.1 + 1e-12, "post-reset delay back at the base cap: {d}");
    }

    /// Breaker lifecycle: closed through THRESHOLD-1 failures, opens on the
    /// THRESHOLDth, refuses during cooldown, admits exactly one half-open
    /// probe, and a probe success closes it again.
    #[test]
    fn breaker_opens_half_opens_and_closes() {
        let mut cb = CircuitBreaker::new(3, ms(100), ms(1_000));
        let mut now = 0.0;
        for _ in 0..BREAKER_THRESHOLD - 1 {
            assert!(cb.allow(now));
            cb.record_failure(now);
            assert!(!cb.is_open(), "below threshold must stay closed");
        }
        assert!(cb.allow(now));
        cb.record_failure(now);
        assert!(cb.is_open(), "threshold reached: breaker open");
        assert!(!cb.allow(now), "open breaker refuses immediately");
        now += 0.01;
        assert!(!cb.allow(now), "still cooling down");
        now += 1.0; // past any first-cooldown delay (≤ base·2^THRESHOLD ≤ 1 s)
        assert!(cb.allow(now), "cooldown over: half-open probe admitted");
        assert!(!cb.allow(now), "only ONE probe until its outcome lands");
        cb.record_success();
        assert!(!cb.is_open());
        assert!(cb.allow(now), "closed again after the probe succeeded");
    }

    /// A failed half-open probe re-opens with a longer cooldown.
    #[test]
    fn failed_probe_reopens_with_longer_cooldown() {
        let mut cb = CircuitBreaker::new(11, ms(100), ms(60_000));
        let mut now = 0.0;
        for _ in 0..BREAKER_THRESHOLD {
            cb.record_failure(now);
        }
        let first_open = cb.open_until.unwrap();
        now = first_open;
        assert!(cb.allow(now));
        cb.record_failure(now);
        assert!(cb.is_open(), "failed probe re-opens");
        let second_cooldown = cb.open_until.unwrap() - now;
        // The schedule advanced, so the guaranteed floor grew past the
        // first cooldown's cap/2.
        assert!(
            second_cooldown >= first_open - 0.0,
            "cooldowns come from an advancing schedule"
        );
        assert!(!cb.allow(now + second_cooldown / 2.0));
        assert!(cb.allow(now + second_cooldown + 1e-9));
    }

    /// Determinism end to end: two breakers with the same seed observe the
    /// same failure times and produce identical open windows.
    #[test]
    fn breaker_is_deterministic_given_seed() {
        let mut a = CircuitBreaker::new(77, ms(100), ms(8_000));
        let mut b = CircuitBreaker::new(77, ms(100), ms(8_000));
        for i in 0..10 {
            let t = i as f64 * 0.5;
            a.allow(t);
            b.allow(t);
            a.record_failure(t);
            b.record_failure(t);
            assert_eq!(a.open_until, b.open_until, "step {i}");
        }
    }
}
