//! A miniature property-based testing harness (the environment has no
//! `proptest`). Runs a property over many seeded-random cases and, on
//! failure, retries with a smaller "size" parameter to report a small
//! counterexample. Used by `rust/tests/prop_invariants.rs` for the
//! coordinator invariants (capacity feasibility, flow conservation,
//! Lemma 3.1, scheduler dominance, simulator conservation).

use crate::util::rng::Pcg32;

/// Configuration for a property run.
pub struct PropConfig {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed; each case forks a child RNG.
    pub seed: u64,
    /// Maximum "size" hint passed to the generator (cases sweep 1..=max_size).
    pub max_size: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 100, seed: 0xC0FFEE, max_size: 24 }
    }
}

/// Run `gen` to build a case of the given size, then `check` it.
/// `check` returns `Err(reason)` to fail. Panics with the counterexample's
/// seed, size, and Debug rendering on failure (after attempting to find a
/// smaller failing size).
pub fn forall<T: std::fmt::Debug>(
    cfg: PropConfig,
    gen: impl Fn(&mut Pcg32, usize) -> T,
    check: impl Fn(&T) -> Result<(), String>,
) {
    let mut root = Pcg32::new(cfg.seed);
    let mut failure: Option<(u64, usize, T, String)> = None;
    for case in 0..cfg.cases {
        let child_seed = root.next_u64();
        let size = 1 + (case * cfg.max_size / cfg.cases.max(1)) % cfg.max_size;
        let mut rng = Pcg32::new(child_seed);
        let input = gen(&mut rng, size);
        if let Err(reason) = check(&input) {
            // Shrink pass: same seed, smaller sizes.
            let mut best = (child_seed, size, input, reason);
            for s in 1..size {
                let mut rng = Pcg32::new(child_seed);
                let small = gen(&mut rng, s);
                if let Err(r) = check(&small) {
                    best = (child_seed, s, small, r);
                    break;
                }
            }
            failure = Some(best);
            break;
        }
    }
    if let Some((seed, size, input, reason)) = failure {
        panic!(
            "property failed (seed={seed:#x}, size={size}): {reason}\ncounterexample: {input:#?}"
        );
    }
}

/// Convenience: assert two floats are within a relative-or-absolute epsilon.
pub fn close(a: f64, b: f64, eps: f64) -> Result<(), String> {
    let tol = eps * (1.0 + a.abs().max(b.abs()));
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{a} !~= {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_true_property() {
        forall(
            PropConfig { cases: 50, ..Default::default() },
            |rng, size| (0..size).map(|_| rng.f64()).collect::<Vec<_>>(),
            |xs| {
                if xs.iter().all(|x| (0.0..1.0).contains(x)) {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_false_property() {
        forall(
            PropConfig { cases: 50, ..Default::default() },
            |rng, size| (0..size).map(|_| rng.below(10)).collect::<Vec<_>>(),
            |xs| {
                if xs.len() < 5 {
                    Ok(())
                } else {
                    Err("too long".into())
                }
            },
        );
    }

    #[test]
    fn close_tolerance() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6).is_ok());
        assert!(close(1.0, 1.1, 1e-6).is_err());
    }
}
