//! Small self-contained substrates the offline build environment lacks:
//! a seedable RNG, JSON emit/parse, descriptive statistics, a mini
//! property-testing harness, a CLI argument parser, and a benchmark harness
//! used by the `harness = false` benches.

pub mod backoff;
pub mod bench;
pub mod cli;
pub mod json;
pub mod logger;
pub mod prop;
pub mod rng;
pub mod stats;
