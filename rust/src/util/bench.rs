//! Benchmark harness for the `harness = false` benches (the environment has
//! no `criterion`). Provides warmup + timed iterations with mean/p50/p95
//! reporting, and a table printer that renders the paper-style rows each
//! bench regenerates.

use std::time::Instant;

/// Measure a closure: `warmup` untimed runs, then `iters` timed runs.
/// Returns per-iteration durations in seconds.
pub fn time_n(warmup: usize, iters: usize, mut f: impl FnMut()) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    out
}

/// Report a timing series under a label, criterion-style.
pub fn report(label: &str, secs: &[f64]) {
    use crate::util::stats;
    let mean = stats::mean(secs);
    let p50 = stats::percentile(secs, 50.0);
    let p95 = stats::percentile(secs, 95.0);
    println!(
        "{label:<48} mean {:>10}  p50 {:>10}  p95 {:>10}  (n={})",
        fmt_dur(mean),
        fmt_dur(p50),
        fmt_dur(p95),
        secs.len()
    );
}

/// Human-readable duration.
pub fn fmt_dur(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Simple fixed-width table printer for paper-style result tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {title} ==");
        let hdr: Vec<String> =
            self.headers.iter().zip(&widths).map(|(h, w)| format!("{h:<w$}")).collect();
        println!("{}", hdr.join("  "));
        println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for row in &self.rows {
            let line: Vec<String> =
                row.iter().zip(&widths).map(|(c, w)| format!("{c:<w$}")).collect();
            println!("{}", line.join("  "));
        }
    }
}

/// True when the bench should run in scaled-down mode (default for
/// `cargo bench`); set `TERRA_BENCH_FULL=1` for full paper-scale runs.
pub fn quick_mode() -> bool {
    std::env::var("TERRA_BENCH_FULL").map(|v| v != "1").unwrap_or(true)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_n_counts() {
        let mut n = 0;
        let t = time_n(2, 5, || n += 1);
        assert_eq!(t.len(), 5);
        assert_eq!(n, 7);
        assert!(t.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn fmt_dur_ranges() {
        assert!(fmt_dur(2.5).ends_with('s'));
        assert!(fmt_dur(2.5e-3).ends_with("ms"));
        assert!(fmt_dur(2.5e-6).ends_with("us"));
        assert!(fmt_dur(2.5e-9).ends_with("ns"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["x".to_string()]);
    }
}
