//! Tiny CLI argument parser (the environment has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional arguments,
//! which is all the `terra` launcher and the benches need.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut out = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn parses_mixed() {
        let a = args(&["simulate", "--topology", "swan", "--jobs=100", "extra", "--verbose"]);
        assert_eq!(a.positional, vec!["simulate", "extra"]);
        assert_eq!(a.get("topology"), Some("swan"));
        assert_eq!(a.get_usize("jobs", 0), 100);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn flag_before_flag() {
        let a = args(&["--fast", "--solver", "gk"]);
        assert!(a.flag("fast"));
        assert_eq!(a.get("solver"), Some("gk"));
    }

    #[test]
    fn defaults() {
        let a = args(&[]);
        assert_eq!(a.get_f64("alpha", 0.1), 0.1);
        assert_eq!(a.get_or("topology", "swan"), "swan");
    }
}
