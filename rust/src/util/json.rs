//! Minimal JSON support (the environment has no `serde`/`serde_json`).
//!
//! Used for (a) the overlay control protocol between the Terra controller and
//! agents, and (b) machine-readable experiment/benchmark output. Supports the
//! full JSON data model with a recursive-descent parser and a writer.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Build an object from `(key, value)` pairs.
    pub fn from_pairs<K: Into<String>>(pairs: impl IntoIterator<Item = (K, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.into(), v)).collect())
    }

    /// Insert into an object. Returns `Some(self)` for chaining when `self`
    /// is an object; returns `None` and leaves `self` untouched otherwise
    /// (it never panics — like `HashMap::insert`, the return value may be
    /// ignored when the receiver is statically known to be an object).
    pub fn set(&mut self, key: &str, val: Json) -> Option<&mut Self> {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => return None,
        }
        Some(self)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(JsonError::Trailing(p.i));
        }
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum JsonError {
    #[error("unexpected end of input")]
    Eof,
    #[error("unexpected byte at offset {0}")]
    Unexpected(usize),
    #[error("trailing data at offset {0}")]
    Trailing(usize),
    #[error("invalid number at offset {0}")]
    BadNumber(usize),
    #[error("invalid string escape at offset {0}")]
    BadEscape(usize),
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8, JsonError> {
        self.b.get(self.i).copied().ok_or(JsonError::Eof)
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            Err(JsonError::Unexpected(self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(JsonError::Unexpected(self.i))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(JsonError::Unexpected(self.i)),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(JsonError::Unexpected(self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(JsonError::Unexpected(self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(JsonError::Eof);
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| JsonError::BadEscape(self.i))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError::BadEscape(self.i))?;
                            self.i += 4;
                            // Surrogate pairs: only handle BMP + valid pairs.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 =
                                        std::str::from_utf8(&self.b[self.i + 2..self.i + 6])
                                            .map_err(|_| JsonError::BadEscape(self.i))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| JsonError::BadEscape(self.i))?;
                                    self.i += 6;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c).ok_or(JsonError::BadEscape(self.i))?
                                } else {
                                    return Err(JsonError::BadEscape(self.i));
                                }
                            } else {
                                char::from_u32(cp).ok_or(JsonError::BadEscape(self.i))?
                            };
                            s.push(ch);
                        }
                        _ => return Err(JsonError::BadEscape(self.i)),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence starting at c.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    if start + len > self.b.len() {
                        return Err(JsonError::Eof);
                    }
                    self.i = start + len;
                    let chunk = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|_| JsonError::Unexpected(start))?;
                    s.push_str(chunk);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let s =
            std::str::from_utf8(&self.b[start..self.i]).map_err(|_| JsonError::BadNumber(start))?;
        s.parse::<f64>().map(Json::Num).map_err(|_| JsonError::BadNumber(start))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut o = Json::from_pairs([
            ("name", Json::from("terra")),
            ("pi", 3.25.into()),
            ("n", 42u64.into()),
            ("flag", true.into()),
        ]);
        o.set("xs", vec![1.0, 2.0, 3.0].into());
        let s = o.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back, o);
    }

    #[test]
    fn set_on_non_object_is_a_safe_no_op() {
        // `set` must not panic on non-objects: it reports failure instead.
        let non_objects =
            [Json::Null, Json::Bool(true), Json::Num(3.0), Json::Str("x".into()), Json::Arr(vec![])];
        for mut v in non_objects {
            let before = v.clone();
            assert!(v.set("k", 1u64.into()).is_none());
            assert_eq!(v, before, "non-object mutated by set");
        }
        // Objects chain through the Some branch.
        let mut o = Json::obj();
        let _ = o.set("a", 1u64.into()).and_then(|o| o.set("b", 2u64.into()));
        assert_eq!(o.get("a").and_then(Json::as_u64), Some(1));
        assert_eq!(o.get("b").and_then(Json::as_u64), Some(2));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": null}, "x\ny"], "c": -1.5e2}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_f64().unwrap(), -150.0);
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64().unwrap(), 1.0);
        assert_eq!(arr[2].as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parse_unicode_escape() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("{} garbage").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ok");
    }
}
