//! Descriptive statistics used by the evaluation harnesses: means,
//! percentiles, CDFs, and Pearson's correlation coefficient (the paper uses
//! `r` to relate factors of improvement to job sizes in §6.3).

/// Mean of a slice; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via linear interpolation on sorted data; `p` in `[0, 100]`.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    // total_cmp, not partial_cmp().unwrap(): a single NaN sample must not
    // panic a whole report (NaNs sort last and only perturb the top end).
    v.sort_by(f64::total_cmp);
    percentile_sorted(&v, p)
}

/// Percentile on already-sorted data.
pub fn percentile_sorted(v: &[f64], p: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    if v.len() == 1 {
        return v[0];
    }
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Median (p50).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Pearson's correlation coefficient between two equal-length series.
/// Returns 0.0 when either series is constant or lengths mismatch.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    if xs.len() != ys.len() || xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    sxy / (sxx * syy).sqrt()
}

/// An empirical CDF: `points()` yields `(x, F(x))` pairs suitable for
/// plotting the paper's Figure 7.
pub struct Ecdf {
    sorted: Vec<f64>,
}

impl Ecdf {
    pub fn new(mut xs: Vec<f64>) -> Self {
        xs.sort_by(f64::total_cmp);
        Ecdf { sorted: xs }
    }

    /// Fraction of samples `<= x`.
    pub fn eval(&self, x: f64) -> f64 {
        if self.sorted.is_empty() {
            return 0.0;
        }
        let idx = self.sorted.partition_point(|&v| v <= x);
        idx as f64 / self.sorted.len() as f64
    }

    /// `(value, cumulative fraction)` pairs.
    pub fn points(&self) -> Vec<(f64, f64)> {
        let n = self.sorted.len();
        self.sorted
            .iter()
            .enumerate()
            .map(|(i, &x)| (x, (i + 1) as f64 / n as f64))
            .collect()
    }
}

/// Accumulates samples and reports summary rows for benches/experiments.
#[derive(Default, Clone)]
pub struct Summary {
    pub samples: Vec<f64>,
}

impl Summary {
    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
    }
    pub fn mean(&self) -> f64 {
        mean(&self.samples)
    }
    pub fn p(&self, p: f64) -> f64 {
        percentile(&self.samples, p)
    }
    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }
    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }
    pub fn n(&self) -> usize {
        self.samples.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 95.0) - 3.85).abs() < 1e-12);
    }

    #[test]
    fn percentile_survives_nan_samples() {
        // Regression: the sort used partial_cmp().unwrap(), so one NaN
        // sample panicked the whole report. With total_cmp, NaNs sort
        // last and low/mid percentiles stay untouched.
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!(percentile(&xs, 100.0).is_nan());
        // Ecdf had the same sort; NaN-last keeps eval() well-defined.
        let e = Ecdf::new(vec![2.0, f64::NAN, 1.0]);
        assert!((e.eval(1.5) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let zs = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &zs) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&xs, &[1.0, 1.0, 1.0, 1.0]), 0.0);
    }

    #[test]
    fn ecdf_eval() {
        let e = Ecdf::new(vec![1.0, 2.0, 3.0, 4.0]);
        assert!((e.eval(0.5) - 0.0).abs() < 1e-12);
        assert!((e.eval(2.0) - 0.5).abs() < 1e-12);
        assert!((e.eval(10.0) - 1.0).abs() < 1e-12);
        assert_eq!(e.points().len(), 4);
    }
}
