//! The enforcement plane (§4, §5): a logically centralized Terra controller
//! plus one Terra agent per datacenter, connected over **persistent TCP
//! connections** that form an application-layer multipath overlay.
//!
//! This is the repo's "testbed": agents move real bytes over loopback TCP,
//! the controller runs the same [`crate::scheduler::Policy`] logic as the
//! simulator, and link capacities are enforced by per-(transfer, path)
//! token buckets at the sending agents (standing in for the paper's
//! VLAN + `tc` setup). SD-WAN interaction is modelled by
//! [`rules::RuleTable`], which counts the forwarding rules the controller
//! would install — rules change only at (re)initialization and on
//! failures, never per transfer (§4.3).
//!
//! Data-plane properties reproduced from §5.1:
//! - one persistent connection per ⟨agent pair, path⟩, reused by all
//!   coflows;
//! - a FlowGroup is striped across its paths at controller-assigned rates;
//! - out-of-order chunks (different paths, heterogeneous latency) are
//!   reassembled and delivered **in order** to the application;
//! - agents passively sample achieved per-path throughput and report it
//!   (`telemetry_report`); under a non-oracle
//!   [`crate::net::telemetry::TelemetryConfig`] the controller fuses the
//!   samples into per-edge capacity *beliefs* and issues `probe_request`
//!   bursts for edges gone stale — scheduling on estimates rather than an
//!   oracle's truth.

pub mod agent;
pub mod controller;
pub mod protocol;
pub mod rules;

pub use agent::Agent;
pub use controller::{Controller, ControllerHandle, DeltaStats, TelemetryStats, TestbedConfig};
pub use protocol::{CoflowStatus, FlowSpec, TelemetrySample};

/// Bytes per second in one emulated "Gbps" (the testbed scales real
/// loopback throughput; 1 emulated Gbps = 12.5 real MB/s by default so a
/// 5-node testbed fits comfortably in loopback bandwidth).
pub const BYTES_PER_GBPS: f64 = 12_500_000.0;
