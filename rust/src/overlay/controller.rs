//! The Terra controller (§4.1): accepts coflow submissions from job
//! masters, keeps the global WAN + coflow view, runs the scheduling-routing
//! policy on every event, and pushes ⟨path, rate⟩ vectors to the agents.
//!
//! All round machinery (active table, ρ filtering, clamping, Γ-cache,
//! feasibility) lives in the shared [`crate::engine::RoundEngine`] — the
//! exact same engine the flow-level simulator drives, which is the paper's
//! §6.1 "same controller logic in testbed and simulation" methodology. This
//! module owns only the testbed concerns: TCP sessions, agent rate pushes,
//! SDN rule emulation, and wall-clock bookkeeping.

use super::protocol::{self, CoflowStatus, FlowSpec, ResyncEntry, TelemetrySample, PROBE_COFLOW};
use super::rules::RuleTable;
use crate::coflow::{Coflow, CoflowId, Flow, ServiceClass};
use crate::engine::{EngineConfig, RoundEngine, ShardedEngine, SitePartition, WanReaction};
use crate::net::telemetry::{self, TelemetryConfig};
use crate::net::{LinkEvent, Wan};
use crate::scheduler::{CoflowRates, CoflowState, Policy, RoundTrigger};
use crate::util::json::Json;
use std::collections::{HashMap, VecDeque};
use std::io::ErrorKind;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Convert testbed bytes to policy-layer Gbit so that an emulated 1 Gbps
/// link moves 1 "Gbit" per second of wall-clock.
fn bytes_to_gbit(bytes: u64) -> f64 {
    bytes as f64 / super::BYTES_PER_GBPS
}

/// Remaining-volume floor for groups the agents have not yet confirmed:
/// keeps the policy allocating a trickle until `group_done` arrives (§6.4
/// feedback-loop approximation).
const ESTIMATE_FLOOR_GBIT: f64 = 1e-6;

/// Testbed configuration.
pub struct TestbedConfig {
    pub wan: Wan,
    /// Paths per datacenter pair (persistent connections per agent pair).
    pub k: usize,
    /// Worker threads for parallel component solves (see
    /// [`EngineConfig::workers`]); results are bit-identical for any value.
    pub workers: usize,
    /// WAN telemetry & capacity estimation. The oracle default keeps the
    /// controller scheduling on injected truth exactly as before; any
    /// other estimator makes it fuse agents' `telemetry_report` samples
    /// (and its own `probe_request` results) into capacity beliefs.
    pub telemetry: TelemetryConfig,
    /// Control-plane shards ([`EngineConfig::shards`]): `1` (default) is
    /// the single-engine loop, bit-identical to previous behavior; `> 1`
    /// runs shard rounds concurrently and pushes each shard's rates as its
    /// solve completes (pipelined enforcement).
    pub shards: usize,
    /// Agent liveness deadline: an agent whose control channel has been
    /// silent this long (agents emit a telemetry report every ~250 ms, so
    /// this is a miss budget of deadline/250 ms flushes) is declared down —
    /// its connection is evicted, its site's edges are failed in the
    /// engine, and its coflows park with achieved bytes preserved until it
    /// reconnects. The generous default keeps partial fake-agent testbeds
    /// (which never speak) alive through protocol tests; chaos tests dial
    /// it down.
    pub liveness_deadline: Duration,
}

impl TestbedConfig {
    pub fn new(wan: Wan, k: usize) -> TestbedConfig {
        TestbedConfig {
            wan,
            k,
            workers: crate::engine::default_workers(),
            telemetry: TelemetryConfig::default(),
            shards: 1,
            liveness_deadline: Duration::from_secs(30),
        }
    }

    pub fn with_workers(mut self, workers: usize) -> TestbedConfig {
        self.workers = workers;
        self
    }

    pub fn with_telemetry(mut self, telemetry: TelemetryConfig) -> TestbedConfig {
        self.telemetry = telemetry;
        self
    }

    pub fn with_shards(mut self, shards: usize) -> TestbedConfig {
        self.shards = shards;
        self
    }

    pub fn with_liveness_deadline(mut self, deadline: Duration) -> TestbedConfig {
        self.liveness_deadline = deadline;
        self
    }
}

/// Outbound-queue capacity per agent; an agent that falls this far behind
/// is not draining its control channel, so the queue is dropped wholesale
/// and the agent flagged for a full-table resync.
const AGENT_TX_CAP: usize = 1024;

/// Idle-channel heartbeat period. Agents treat control-channel silence
/// past their deadline (~4× this) as controller death and enter degraded
/// mode, so the controller must emit *something* even when no rounds run.
const HEARTBEAT_INTERVAL: Duration = Duration::from_millis(500);

struct TxQueue {
    buf: VecDeque<Json>,
    /// True while the writer thread holds a popped frame it has not yet
    /// finished writing (so `flush` doesn't report an empty-but-in-flight
    /// queue as drained).
    writing: bool,
    /// Set on writer exit (socket error) or owner drop; sends are refused.
    closed: bool,
}

struct TxShared {
    q: Mutex<TxQueue>,
    cv: Condvar,
    /// The agent's delta baseline can no longer be trusted (a write failed
    /// or the queue overflowed): the next rate push must be a full-table
    /// sync instead of a delta.
    needs_full_sync: AtomicBool,
    cap: usize,
}

/// Bounded asynchronous writer for one agent's control channel: round
/// enforcement enqueues frames and returns immediately; a per-agent thread
/// drains the queue to the socket off the round path. A write error closes
/// the queue, counts in [`DeltaStats::write_errors`], and flags the agent
/// for a full sync on next contact instead of being silently swallowed.
struct AgentTx {
    shared: Arc<TxShared>,
    /// Writer thread handle plus a socket clone, kept so [`AgentTx::retire`]
    /// can break a blocked write (socket shutdown) and then join the
    /// writer — guaranteeing no frame from a superseded connection is
    /// still in flight when its successor's baseline goes out.
    writer: Option<std::thread::JoinHandle<()>>,
    stream: Option<TcpStream>,
}

impl AgentTx {
    fn new(cap: usize) -> AgentTx {
        AgentTx {
            shared: Arc::new(TxShared {
                q: Mutex::new(TxQueue {
                    buf: VecDeque::new(),
                    writing: false,
                    closed: false,
                }),
                cv: Condvar::new(),
                needs_full_sync: AtomicBool::new(false),
                cap,
            }),
            writer: None,
            stream: None,
        }
    }

    /// Start the drain thread over the agent's (cloned) control stream.
    fn start_writer(&mut self, stream: TcpStream, dc: usize, write_errors: Arc<AtomicUsize>) {
        self.stream = stream.try_clone().ok();
        let shared = self.shared.clone();
        self.writer =
            Some(std::thread::spawn(move || writer_loop(stream, dc, shared, write_errors)));
    }

    /// Retire a superseded connection's queue atomically: close it, drop
    /// every pending frame (all stale relative to the successor's full
    /// sync), shut the socket down to break a writer blocked mid-write,
    /// and join the writer. After this returns, nothing from this
    /// connection can interleave with frames on the new socket.
    fn retire(&mut self) {
        {
            let mut q = self.shared.q.lock().unwrap();
            q.buf.clear();
            q.closed = true;
        }
        self.shared.cv.notify_all();
        if let Some(s) = self.stream.take() {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        if let Some(h) = self.writer.take() {
            let _ = h.join();
        }
    }

    /// Enqueue a frame; returns false when the channel is closed or the
    /// frame was dropped. An overflow drops the whole queue (everything in
    /// it is stale relative to the full sync the flag now forces).
    fn send(&self, msg: Json) -> bool {
        let mut q = self.shared.q.lock().unwrap();
        if q.closed {
            return false;
        }
        if q.buf.len() >= self.shared.cap {
            q.buf.clear();
            self.shared.needs_full_sync.store(true, Ordering::Relaxed);
            return false;
        }
        q.buf.push_back(msg);
        self.shared.cv.notify_all();
        true
    }

    /// Wait (bounded) until every queued frame has been written. Used to
    /// order cross-agent dependencies — a receiver's `expect` must be on
    /// the wire before the sender's `transfer` starts data flowing.
    fn flush(&self, timeout: Duration) -> bool {
        let t0 = Instant::now();
        let mut q = self.shared.q.lock().unwrap();
        while (!q.buf.is_empty() || q.writing) && !q.closed {
            let Some(rem) = timeout.checked_sub(t0.elapsed()) else { return false };
            let (g, _) = self.shared.cv.wait_timeout(q, rem).unwrap();
            q = g;
        }
        q.buf.is_empty() && !q.writing
    }

    /// Consume the pending-full-sync flag.
    fn take_full_sync_flag(&self) -> bool {
        self.shared.needs_full_sync.swap(false, Ordering::Relaxed)
    }
}

impl Drop for AgentTx {
    fn drop(&mut self) {
        let mut q = self.shared.q.lock().unwrap();
        q.closed = true;
        self.shared.cv.notify_all();
    }
}

fn writer_loop(
    mut stream: TcpStream,
    dc: usize,
    shared: Arc<TxShared>,
    write_errors: Arc<AtomicUsize>,
) {
    loop {
        let msg = {
            let mut q = shared.q.lock().unwrap();
            loop {
                if let Some(m) = q.buf.pop_front() {
                    q.writing = true;
                    break Some(m);
                }
                if q.closed {
                    break None;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        let Some(msg) = msg else { return };
        let res = protocol::write_msg(&mut stream, &msg);
        let mut q = shared.q.lock().unwrap();
        q.writing = false;
        if let Err(e) = res {
            // The control channel is broken: everything queued behind the
            // failed frame is undeliverable. Close the queue and force a
            // full sync when the agent next contacts us (sync_request or
            // reconnect) — never silently drop enforcement state.
            log::warn!("controller: rate push to agent {dc} failed ({e}); will full-sync");
            q.buf.clear();
            q.closed = true;
            shared.needs_full_sync.store(true, Ordering::Relaxed);
            write_errors.fetch_add(1, Ordering::Relaxed);
            shared.cv.notify_all();
            return;
        }
        shared.cv.notify_all();
    }
}

struct AgentConn {
    tx: AgentTx,
    data_addr: String,
    /// Delta-enforcement state (per control connection): monotone sequence
    /// number stamped on every `rates_delta`/`rates_full` push, and the
    /// last rate vector pushed per (coflow, dst) FlowGroup. A round pushes
    /// only the entries whose rates changed plus an explicit revoke list;
    /// reconnects and sequence gaps fall back to a full-table sync.
    seq: u64,
    sent: HashMap<(CoflowId, usize), Vec<f64>>,
    /// Connection generation: bumped on every (re)`hello` for the dc.
    /// Readers and rate pushes check it against [`State::agent_gen`] so a
    /// superseded connection can neither mutate state nor receive frames.
    gen: u64,
    /// Wall-clock instant of the last message received from this agent
    /// (any op; agents emit a telemetry report every ~250 ms, which doubles
    /// as their heartbeat). The liveness scan declares the agent down once
    /// this ages past [`TestbedConfig::liveness_deadline`].
    last_rx: Instant,
}

/// Control-plane traffic counters for the delta protocol.
#[derive(Clone, Copy, Debug, Default)]
pub struct DeltaStats {
    /// Full-table syncs sent (agent (re)connects + explicit requests).
    pub full_syncs: usize,
    /// Incremental `rates_delta` messages sent.
    pub delta_msgs: usize,
    /// FlowGroup rate entries carried in those deltas.
    pub delta_entries: usize,
    /// Revoked (withdrawn) FlowGroup entries.
    pub delta_revokes: usize,
    /// Control-channel write failures (agent writer threads). Each one
    /// closed an agent's outbound queue and flagged it for a full sync.
    pub write_errors: usize,
}

/// Data-plane liveness counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct LivenessStats {
    /// Agents declared down after missing the liveness deadline. Each one
    /// evicted the agent's connection (writer retired, queue dropped) and
    /// parked its coflows in the engine.
    pub down_events: usize,
    /// Previously-down agents that reconnected and were re-admitted (their
    /// parked coflows resumed from achieved bytes).
    pub up_events: usize,
}

/// Telemetry-plane traffic counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct TelemetryStats {
    /// `telemetry_report` messages received from agents.
    pub reports: usize,
    /// Individual samples fused into the estimator (0 under the oracle,
    /// which ignores reports).
    pub samples: usize,
    /// `probe_request`s issued for stale edges.
    pub probes_sent: usize,
}

/// Testbed-side metadata per coflow; scheduling state (groups, remaining,
/// rates) lives in the engine.
struct CoMeta {
    submitted: Instant,
    finished: Option<Instant>,
    /// Absolute deadline on the controller clock (epoch seconds).
    deadline_abs: Option<f64>,
    admitted: bool,
    total_bytes: u64,
}

struct State {
    engine: ShardedEngine,
    k: usize,
    agents: HashMap<usize, AgentConn>,
    /// Latest live connection generation per dc (see [`AgentConn::gen`]).
    agent_gen: HashMap<usize, u64>,
    next_gen: u64,
    coflows: HashMap<CoflowId, CoMeta>,
    next_id: CoflowId,
    rules: RuleTable,
    peers_sent: bool,
    delta: DeltaStats,
    telemetry: TelemetryStats,
    liveness: LivenessStats,
    liveness_deadline: Duration,
    /// Per-edge wall-clock time of the last probe_request, so a stale edge
    /// is probed once per staleness window rather than on every report.
    last_probe_req: Vec<f64>,
    /// The *emulated* ground-truth capacity per edge: base capacity,
    /// overridden by injected WAN events. Loopback has no real link
    /// capacity, so measurements (probe bursts especially) are clamped to
    /// this — a probe must not "measure" kernel-buffer drain rates and
    /// erase an injected degradation.
    truth_caps: Vec<f64>,
    epoch: Instant,
    /// Wall-clock instant of the last remaining-volume drain.
    last_drain: Instant,
    /// Total agent control-channel write failures (shared with the agent
    /// writer threads, surfaced via [`DeltaStats::write_errors`]).
    write_errors: Arc<AtomicUsize>,
}

impl State {
    fn now_s(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// Update remaining-volume estimates from elapsed wall time x current
    /// rates (the controller's feedback-loop approximation, §6.4).
    fn drain_to_now(&mut self) {
        let now = Instant::now();
        let dt = now.duration_since(self.last_drain).as_secs_f64();
        self.last_drain = now;
        self.engine.drain(dt, ESTIMATE_FLOOR_GBIT);
    }
}

/// Handle to a running controller (owns its threads).
pub struct ControllerHandle {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
    state: Arc<Mutex<State>>,
}

/// The controller itself (spawn-only API).
pub struct Controller;

impl Controller {
    /// Start a controller for `cfg.wan`, expecting one agent per
    /// datacenter. Returns once the control socket is listening.
    pub fn spawn(cfg: TestbedConfig, policy: Box<dyn Policy>) -> std::io::Result<ControllerHandle> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let num_nodes = cfg.wan.num_nodes();
        let k = cfg.k;
        let engine = ShardedEngine::with_k(
            cfg.wan,
            policy,
            EngineConfig {
                check_feasibility: false,
                workers: cfg.workers,
                telemetry: cfg.telemetry,
                shards: cfg.shards,
                ..Default::default()
            },
            cfg.k,
        );
        let num_edges = engine.wan().num_edges();
        let truth_caps: Vec<f64> =
            engine.wan().links().iter().map(|l| l.base_capacity).collect();
        let mut rules = RuleTable::new(num_nodes);
        rules.install_paths(engine.wan(), engine.paths());
        let state = Arc::new(Mutex::new(State {
            engine,
            k,
            agents: HashMap::new(),
            agent_gen: HashMap::new(),
            next_gen: 1,
            coflows: HashMap::new(),
            next_id: 1,
            rules,
            peers_sent: false,
            delta: DeltaStats::default(),
            telemetry: TelemetryStats::default(),
            liveness: LivenessStats::default(),
            liveness_deadline: cfg.liveness_deadline,
            last_probe_req: vec![f64::NEG_INFINITY; num_edges],
            truth_caps,
            epoch: Instant::now(),
            last_drain: Instant::now(),
            write_errors: Arc::new(AtomicUsize::new(0)),
        }));
        let stop = Arc::new(AtomicBool::new(false));
        let mut threads = Vec::new();
        {
            let stop = stop.clone();
            let state = state.clone();
            threads.push(std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((s, _)) => {
                            s.set_nodelay(true).ok();
                            let state = state.clone();
                            let stop = stop.clone();
                            std::thread::spawn(move || serve_conn(s, state, stop));
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            }));
        }
        // Heartbeat + liveness: keep every agent's control channel audibly
        // alive even when no scheduling rounds run (agents tell "idle
        // controller" from "dead controller" by silence, not socket
        // errors), and scan the other direction — an agent whose channel
        // has been silent past the liveness deadline is declared down and
        // its traffic parked. The scan runs every loop tick (50 ms) so
        // detection latency is deadline + O(tick), not deadline + 500 ms.
        {
            let stop = stop.clone();
            let state = state.clone();
            threads.push(std::thread::spawn(move || {
                let hb = Json::from_pairs([("op", Json::from("hb"))]);
                let mut last = Instant::now();
                while !stop.load(Ordering::Relaxed) {
                    std::thread::sleep(Duration::from_millis(50));
                    let mut st = state.lock().unwrap();
                    let deadline = st.liveness_deadline;
                    let mut dead: Vec<usize> = st
                        .agents
                        .iter()
                        .filter(|(_, a)| a.last_rx.elapsed() > deadline)
                        .map(|(&dc, _)| dc)
                        .collect();
                    dead.sort_unstable();
                    for dc in dead {
                        declare_agent_down(&mut st, dc);
                    }
                    if last.elapsed() < HEARTBEAT_INTERVAL {
                        continue;
                    }
                    last = Instant::now();
                    for a in st.agents.values_mut() {
                        a.tx.send(hb.clone());
                    }
                }
            }));
        }
        Ok(ControllerHandle { addr, stop, threads, state })
    }
}

impl ControllerHandle {
    /// Block until `n` agents registered and the overlay is wired. Peer
    /// wiring only ever happens once *every* datacenter has an agent, so
    /// it is required only when the caller waits for the full fleet —
    /// partial testbeds (fake-agent protocol tests) would otherwise spin
    /// the whole timeout on a condition that cannot become true.
    pub fn wait_ready(&self, n: usize, timeout: Duration) -> bool {
        let t0 = Instant::now();
        while t0.elapsed() < timeout {
            {
                let st = self.state.lock().unwrap();
                let wired = st.peers_sent || n < st.engine.wan().num_nodes();
                if st.agents.len() >= n && wired {
                    return true;
                }
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        false
    }

    /// Emulated SDN rule statistics (max rules per switch, total updates).
    pub fn rule_stats(&self) -> (usize, usize) {
        let st = self.state.lock().unwrap();
        (st.rules.max_per_switch(), st.rules.updates)
    }

    /// Inject a WAN event (link failure / recovery / bandwidth change).
    /// Returns the engine's ρ-dampened classification (parity tests compare
    /// it against the simulator's reaction to the same stream).
    pub fn inject_wan_event(&self, ev: LinkEvent) -> WanReaction {
        let mut st = self.state.lock().unwrap();
        apply_wan_event(&mut st, &ev)
    }

    /// Current WAN capacity epoch of the shared engine (parity/golden
    /// tests).
    pub fn epoch(&self) -> u64 {
        let st = self.state.lock().unwrap();
        st.engine.epoch()
    }

    /// Current total receive rate estimate per coflow is kept agent-side;
    /// the controller exposes its scheduled rates instead (Fig 10 uses the
    /// agent counters).
    pub fn scheduled_rate(&self, id: CoflowId) -> f64 {
        let st = self.state.lock().unwrap();
        st.engine.coflow_rate(id)
    }

    /// The per-(group, path) rates the engine allocated to a coflow in the
    /// last round (used by the sim↔controller parity tests).
    pub fn allocation(&self, id: CoflowId) -> Option<CoflowRates> {
        let st = self.state.lock().unwrap();
        st.engine.coflow_rates(id)
    }

    /// Scheduling rounds executed so far.
    pub fn rounds(&self) -> usize {
        let st = self.state.lock().unwrap();
        st.engine.rounds()
    }

    /// Delta-protocol traffic counters (full syncs, delta messages, delta
    /// entries, revokes, write errors) — what the enforcement plane
    /// actually shipped.
    pub fn delta_stats(&self) -> DeltaStats {
        let st = self.state.lock().unwrap();
        let mut d = st.delta;
        d.write_errors = st.write_errors.load(Ordering::Relaxed);
        d
    }

    /// Telemetry-plane counters: reports received, samples fused, probes
    /// issued.
    pub fn telemetry_stats(&self) -> TelemetryStats {
        let st = self.state.lock().unwrap();
        st.telemetry
    }

    /// Liveness counters: agents declared down / re-admitted.
    pub fn liveness_stats(&self) -> LivenessStats {
        let st = self.state.lock().unwrap();
        st.liveness
    }

    /// Whether the controller currently holds `dc`'s site as down (its
    /// agent missed the liveness deadline and has not reconnected).
    pub fn agent_down(&self, dc: usize) -> bool {
        let st = self.state.lock().unwrap();
        st.engine.site_down(dc)
    }

    /// Coflows currently parked because an endpoint site is down.
    pub fn parked_coflows(&self) -> usize {
        let st = self.state.lock().unwrap();
        st.engine.parked_down_count()
    }

    /// Total remaining volume (Gbit) the engine currently holds for a
    /// coflow — `None` once it finished (or was never admitted). The chaos
    /// tests use this to prove crash reconstruction preserved progress:
    /// after a kill/restart, remaining must reflect the bytes the agents
    /// actually achieved, not the original volume.
    pub fn coflow_remaining_gbit(&self, id: CoflowId) -> Option<f64> {
        let st = self.state.lock().unwrap();
        st.engine.get(id).map(|c| c.total_remaining())
    }

    /// The engine's believed capacity of the directed edge `(u, v)` — what
    /// the scheduler currently plans against (equals truth under the
    /// oracle).
    pub fn believed_capacity(&self, u: usize, v: usize) -> Option<f64> {
        let st = self.state.lock().unwrap();
        let e = st.engine.wan().edge_between(u, v)?;
        Some(st.engine.wan().link(e).avail())
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Nudge the acceptor.
        let _ = TcpStream::connect(self.addr);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Serve one inbound connection: the first message decides whether it is an
/// agent (`hello`) or a job-master client session.
fn serve_conn(mut s: TcpStream, state: Arc<Mutex<State>>, stop: Arc<AtomicBool>) {
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        let msg = match protocol::read_msg(&mut s) {
            Ok(Some(m)) => m,
            _ => return,
        };
        let op = msg.get("op").and_then(|o| o.as_str()).unwrap_or("").to_string();
        match op.as_str() {
            "hello" => {
                let (Some(dc), Some(addr)) = (
                    msg.get("dc").and_then(|x| x.as_u64()),
                    msg.get("data_addr").and_then(|x| x.as_str()),
                ) else {
                    log::warn!("controller: malformed hello, dropping connection");
                    return;
                };
                let dc = dc as usize;
                let gen;
                {
                    let mut st = state.lock().unwrap();
                    // A dc outside the WAN would corrupt the agent table
                    // (and the len == num_nodes readiness check): drop it.
                    if dc >= st.engine.wan().num_nodes() {
                        log::warn!("controller: hello from out-of-range dc {dc}, dropping");
                        return;
                    }
                    let ctrl = match s.try_clone() {
                        Ok(c) => c,
                        Err(_) => return,
                    };
                    let mut tx = AgentTx::new(AGENT_TX_CAP);
                    tx.start_writer(ctrl, dc, st.write_errors.clone());
                    // Atomically retire any predecessor connection before
                    // the new baseline goes out: close + drain-drop its
                    // queue, break a blocked writer, join it. Without
                    // this, frames queued for the old socket could
                    // interleave with (or outrun) the new `rates_full`.
                    if let Some(mut old) = st.agents.remove(&dc) {
                        old.tx.retire();
                    }
                    gen = st.next_gen;
                    st.next_gen += 1;
                    st.agent_gen.insert(dc, gen);
                    st.agents.insert(
                        dc,
                        AgentConn {
                            tx,
                            data_addr: addr.to_string(),
                            seq: 0,
                            sent: HashMap::new(),
                            gen,
                            last_rx: Instant::now(),
                        },
                    );
                    // A site previously declared down is coming back:
                    // restore its edges and un-park its coflows (in id
                    // order, resuming from achieved bytes) before the
                    // baseline sync goes out.
                    let was_down = st.engine.site_down(dc);
                    if was_down {
                        let now_s = st.now_s();
                        st.engine.set_site_up(dc, now_s);
                        st.liveness.up_events += 1;
                        let (wan, paths) =
                            (st.engine.wan().clone(), st.engine.paths().clone());
                        st.rules.reinstall(&wan, &paths);
                    }
                    // Fresh connection, empty delta baseline: the very
                    // first frame on the new socket is a full-table sync
                    // so a (re)connected agent converges immediately.
                    full_sync_agent(&mut st, dc);
                    if st.agents.len() == st.engine.wan().num_nodes() {
                        resend_peers(&mut st);
                        st.peers_sent = true;
                    } else if was_down {
                        // Partial fleet (another site may still be dark):
                        // the returning agent needs its peer table now,
                        // and the survivors need its new data address.
                        resend_peers(&mut st);
                    }
                    if was_down {
                        resend_transfer_state(&mut st, dc);
                        reallocate(&mut st, RoundTrigger::WanChange);
                    }
                }
                // Stay on this connection reading agent events.
                agent_reader(s, dc, gen, state, stop);
                return;
            }
            "submit" => {
                let reply = handle_submit(&msg, &state);
                let _ = protocol::write_msg(&mut s, &reply);
            }
            "status" => {
                let id = msg.get("cid").and_then(|x| x.as_u64()).unwrap_or(0);
                let st = state.lock().unwrap();
                let status = coflow_status(&st, id);
                let _ = protocol::write_msg(&mut s, &status.to_json());
            }
            "update" => {
                let reply = handle_update(&msg, &state);
                let _ = protocol::write_msg(&mut s, &reply);
            }
            "wan_event" => {
                // Client-initiated WAN event injection (testing).
                if let Some(ev) = parse_event(&msg) {
                    let mut st = state.lock().unwrap();
                    apply_wan_event(&mut st, &ev);
                }
                let ok = Json::from_pairs([("ok", Json::from(true))]);
                let _ = protocol::write_msg(&mut s, &ok);
            }
            _ => {
                let err =
                    Json::from_pairs([("error", Json::from(format!("unknown op {op}")))]);
                let _ = protocol::write_msg(&mut s, &err);
            }
        }
    }
}

/// Declare an agent down: evict its connection outright (retire the
/// writer, drop the queue — a dead socket must not linger flagged
/// full-sync forever), fail the site's edges in the engine so every coflow
/// with an endpoint there parks with achieved bytes preserved, and
/// re-solve the survivors around the hole.
fn declare_agent_down(st: &mut State, dc: usize) {
    let Some(mut conn) = st.agents.remove(&dc) else { return };
    st.agent_gen.remove(&dc);
    conn.tx.retire();
    st.peers_sent = false;
    st.liveness.down_events += 1;
    log::warn!("controller: agent {dc} missed its liveness deadline; parking its traffic");
    // Credit progress up to now *before* the park zeroes the victim's
    // rates: the parked remaining (and the eventual reset re-arm budget)
    // must reflect achieved bytes, not the volume at the last round.
    st.drain_to_now();
    let now_s = st.now_s();
    let reaction = st.engine.set_site_down(dc, SitePartition::Full, now_s);
    if reaction == WanReaction::Structural {
        let (wan, paths) = (st.engine.wan().clone(), st.engine.paths().clone());
        st.rules.reinstall(&wan, &paths);
        resend_peers(st);
        reallocate(st, RoundTrigger::WanChange);
    }
}

/// Re-arm the data plane for a reconnected (previously down) agent. A
/// restarted agent process lost its transfer table, and the surviving far
/// ends of its groups hold reassembly state (contiguous-frontier offsets)
/// a fresh sender can never align with — so every unfinished group
/// touching the site is restarted *on both endpoints* with `reset`-flagged
/// `expect`/`transfer` messages sized from the engine's remaining
/// estimates. The receiver's frontier crossing its target is what
/// completes a group, so the sender budget is padded up slightly: overshoot
/// past the receiver's target is revoked after `group_done`, while an
/// undershoot would stall the group forever.
fn resend_transfer_state(st: &mut State, dc: usize) {
    let mut groups: Vec<(CoflowId, usize, usize, u64, Option<f64>)> = Vec::new();
    st.engine.visit_allocations(|cs, _| {
        for (gi, g) in cs.groups.iter().enumerate() {
            if g.src != dc && g.dst != dc {
                continue;
            }
            let rem = cs.remaining.get(gi).copied().unwrap_or(0.0);
            if rem <= ESTIMATE_FLOOR_GBIT {
                continue;
            }
            let bytes = (rem * super::BYTES_PER_GBPS) as u64;
            groups.push((cs.id, g.src, g.dst, bytes.max(1), cs.rate_floor()));
        }
    });
    groups.sort_unstable_by_key(|&(id, src, dst, _, _)| (id, src, dst));
    // Receiver expectations first (same discipline as fresh submissions):
    // a reset target must be armed before the reset sender starts.
    for &(id, src, dst, bytes, _) in &groups {
        if let Some(a) = st.agents.get_mut(&dst) {
            let m = Json::from_pairs([
                ("op", Json::from("expect")),
                ("coflow", id.into()),
                ("src", src.into()),
                ("bytes", bytes.into()),
                ("reset", Json::from(true)),
            ]);
            a.tx.send(m);
        }
    }
    let mut dsts: Vec<usize> = groups.iter().map(|&(_, _, d, _, _)| d).collect();
    dsts.sort_unstable();
    dsts.dedup();
    for dst in dsts {
        if let Some(a) = st.agents.get(&dst) {
            a.tx.flush(Duration::from_secs(2));
        }
    }
    for &(id, src, dst, bytes, floor) in &groups {
        if let Some(a) = st.agents.get_mut(&src) {
            // Pad the sender budget ~3% + a chunk past the receiver's
            // target so drain-estimate skew cannot leave the frontier
            // short of it.
            let padded = bytes + bytes / 32 + 65_536;
            let mut m = Json::from_pairs([
                ("op", Json::from("transfer")),
                ("coflow", id.into()),
                ("dst", dst.into()),
                ("bytes", padded.into()),
                ("reset", Json::from(true)),
            ]);
            if let Some(f) = floor {
                m.set("floor_gbps", f.into());
            }
            a.tx.send(m);
        }
    }
}

/// Route a WAN event through the engine's ρ-dampened filter and react:
/// structural events reinstall rules and rewire peers before the round;
/// sub-ρ fluctuations push the clamped rates without re-optimizing.
fn apply_wan_event(st: &mut State, ev: &LinkEvent) -> WanReaction {
    // Record the emulated ground truth this event establishes (telemetry
    // readings are clamped to it — see `State::truth_caps`).
    match *ev {
        LinkEvent::Fail(u, v) => {
            for (a, b) in [(u, v), (v, u)] {
                if let Some(e) = st.engine.wan().edge_between(a, b) {
                    st.truth_caps[e] = 0.0;
                }
            }
        }
        LinkEvent::Recover(u, v) => {
            for (a, b) in [(u, v), (v, u)] {
                if let Some(e) = st.engine.wan().edge_between(a, b) {
                    st.truth_caps[e] = st.engine.wan().link(e).base_capacity;
                }
            }
        }
        LinkEvent::SetBandwidth(u, v, gbps) => {
            if let Some(e) = st.engine.wan().edge_between(u, v) {
                st.truth_caps[e] = gbps.max(0.0).min(st.engine.wan().link(e).base_capacity);
            }
        }
    }
    let now = st.now_s();
    let reaction = st.engine.handle_wan_event_at(ev, now);
    match reaction {
        WanReaction::Structural => {
            let (wan, paths) = (st.engine.wan().clone(), st.engine.paths().clone());
            st.rules.reinstall(&wan, &paths);
            resend_peers(st);
            reallocate(st, RoundTrigger::WanChange);
        }
        WanReaction::Reoptimize => reallocate(st, RoundTrigger::WanChange),
        WanReaction::Clamped => push_rates(st),
    }
    reaction
}

fn parse_event(msg: &Json) -> Option<LinkEvent> {
    let kind = msg.get("kind")?.as_str()?;
    let u = msg.get("u")?.as_u64()? as usize;
    let v = msg.get("v")?.as_u64()? as usize;
    match kind {
        "fail" => Some(LinkEvent::Fail(u, v)),
        "recover" => Some(LinkEvent::Recover(u, v)),
        "bw" => Some(LinkEvent::SetBandwidth(u, v, msg.get("gbps")?.as_f64()?)),
        _ => None,
    }
}

/// Push the peer table (data addresses + connections per path) to agents.
fn resend_peers(st: &mut State) {
    let peers: Vec<Json> = st
        .agents
        .iter()
        .map(|(dc, a)| {
            Json::from_pairs([
                ("dc", Json::from(*dc)),
                ("addr", a.data_addr.clone().into()),
                ("k", st.k.into()),
            ])
        })
        .collect();
    let msg = Json::from_pairs([("op", Json::from("peers")), ("peers", Json::Arr(peers))]);
    for a in st.agents.values_mut() {
        a.tx.send(msg.clone());
    }
}

/// Reader for agent events (group completions, full-sync requests,
/// resync-state reports). Malformed messages are logged and dropped —
/// never unwrapped. Each message is processed under the state lock only
/// after confirming this connection is still the dc's live generation; a
/// superseded reader exits instead of mutating state a successor owns.
fn agent_reader(
    mut s: TcpStream,
    dc: usize,
    my_gen: u64,
    state: Arc<Mutex<State>>,
    stop: Arc<AtomicBool>,
) {
    s.set_read_timeout(Some(Duration::from_millis(100))).ok();
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        let msg = match protocol::read_msg_resumable(&mut s, &stop) {
            Ok(Some(m)) => m,
            _ => return,
        };
        let mut st = state.lock().unwrap();
        if st.agent_gen.get(&dc) != Some(&my_gen) {
            log::info!("controller: superseded connection reader for dc {dc} exiting");
            return;
        }
        // Anything the agent says proves it alive.
        if let Some(a) = st.agents.get_mut(&dc) {
            a.last_rx = Instant::now();
        }
        match msg.get("op").and_then(|o| o.as_str()) {
            Some("group_done") => {
                let (Some(coflow), Some(src), Some(dst)) = (
                    msg.get("coflow").and_then(|x| x.as_u64()),
                    msg.get("src").and_then(|x| x.as_u64()),
                    msg.get("dst").and_then(|x| x.as_u64()),
                ) else {
                    log::warn!("controller: malformed group_done from dc {dc}, dropped");
                    continue;
                };
                // Duplicate-delivery guard: agents replay buffered
                // completions after reconnects, and a `group_done` for a
                // coflow the controller already saw finish must be a
                // no-op — no double-complete, no spurious round, and no
                // resurrecting an entry `take_finished` removed.
                if st.coflows.get(&coflow).is_some_and(|m| m.finished.is_some()) {
                    continue;
                }
                let coflow_finished =
                    st.engine.complete_group(coflow, src as usize, dst as usize);
                if coflow_finished {
                    if let Some(meta) = st.coflows.get_mut(&coflow) {
                        if meta.finished.is_none() {
                            meta.finished = Some(Instant::now());
                        }
                    }
                    st.engine.take_finished();
                }
                let trigger = if coflow_finished {
                    RoundTrigger::CoflowFinish
                } else {
                    RoundTrigger::FlowGroupFinish
                };
                reallocate(&mut st, trigger);
            }
            // The agent detected a sequence gap (or reconnected behind a
            // NAT rebinding): resynchronize its full rate table.
            Some("sync_request") => full_sync_agent(&mut st, dc),
            Some("telemetry_report") => handle_telemetry_report(&mut st, dc, &msg),
            // The agent reconnected with live transfer state — possibly
            // to a restarted controller that has to rebuild its world.
            Some("resync_state") => handle_resync_state(&mut st, dc, &msg),
            _ => {}
        }
    }
}

/// Rebuild scheduling state from one agent's `resync_state` report. For
/// every live (coflow, dst) transfer the agent holds, either reconcile the
/// engine's remaining-volume estimate to the agent's byte counters (the
/// sender is ground truth) or — after a controller crash — re-create the
/// coflow entirely from the report, with volume = achieved + remaining so
/// progress is preserved and nothing restarts from zero. Entries are
/// processed sorted by (coflow, dst), and shard ownership is rebuilt in
/// coflow-id order afterwards (ids are assigned monotonically at
/// submission, so id order *is* arrival order): the post-recovery sharding
/// is a function of the reconstructed coflow set alone, not of the order
/// in which agents happened to reconnect. Buffered telemetry samples are
/// fused afterwards so the recovered controller also inherits the capacity
/// evidence gathered during its outage.
///
/// Known limitation (documented in DESIGN.md): deadlines and in-flight
/// rate deltas at crash time are not replayed — allocations are re-derived
/// by a fresh round over the reconstructed state, and a recovered coflow's
/// deadline is lost (it is scheduled as a regular coflow).
fn handle_resync_state(st: &mut State, dc: usize, msg: &Json) {
    let n = st.engine.wan().num_nodes();
    let now_s = st.now_s();
    let mut entries: Vec<ResyncEntry> = msg
        .get("entries")
        .and_then(|e| e.as_arr())
        .map(|arr| arr.iter().filter_map(ResyncEntry::from_json).collect())
        .unwrap_or_default();
    entries.sort_by_key(|e| (e.coflow, e.dst_dc));
    let mut touched: Vec<CoflowId> = Vec::new();
    for e in &entries {
        if e.dst_dc >= n || e.dst_dc == dc || e.remaining_bytes == 0 {
            continue;
        }
        // A coflow this controller already saw complete must not be
        // resurrected by a stale resync replay (the agent's report can
        // race its own buffered `group_done`).
        if st.coflows.get(&e.coflow).is_some_and(|m| m.finished.is_some()) {
            continue;
        }
        let rem_gbit = bytes_to_gbit(e.remaining_bytes).max(ESTIMATE_FLOOR_GBIT);
        let vol_gbit = bytes_to_gbit(e.achieved_bytes + e.remaining_bytes).max(rem_gbit);
        st.next_id = st.next_id.max(e.coflow + 1);
        touched.push(e.coflow);
        if st.engine.get(e.coflow).is_some() {
            let co = st.engine.get_mut(e.coflow).unwrap();
            if let Some(gi) =
                co.groups.iter().position(|g| g.src == dc && g.dst == e.dst_dc)
            {
                co.groups[gi].volume = co.groups[gi].volume.max(vol_gbit);
                co.remaining[gi] = rem_gbit;
            } else {
                co.groups.push(crate::coflow::FlowGroup {
                    src: dc,
                    dst: e.dst_dc,
                    volume: vol_gbit,
                    num_flows: 1,
                });
                co.remaining.push(rem_gbit);
            }
            st.engine.mark_dirty(e.coflow);
        } else {
            let spec = Coflow::new(
                e.coflow,
                vec![Flow { id: 0, src_dc: dc, dst_dc: e.dst_dc, volume: vol_gbit }],
            );
            let mut cs = CoflowState::from_coflow(&spec);
            cs.arrival = now_s;
            cs.admitted = true;
            cs.remaining[0] = rem_gbit;
            st.engine.insert(cs);
        }
        // Testbed metadata: re-created when the crash lost it. The
        // deadline is gone (known limitation); total volume is recomputed
        // from the engine below once every group is in.
        st.coflows.entry(e.coflow).or_insert_with(|| CoMeta {
            submitted: Instant::now(),
            finished: None,
            deadline_abs: None,
            admitted: true,
            total_bytes: 0,
        });
    }
    touched.sort_unstable();
    touched.dedup();
    for &id in &touched {
        let vol_bytes = st
            .engine
            .get(id)
            .map(|c| {
                c.groups.iter().map(|g| (g.volume * super::BYTES_PER_GBPS) as u64).sum::<u64>()
            })
            .unwrap_or(0);
        if let Some(meta) = st.coflows.get_mut(&id) {
            meta.finished = None;
            meta.total_bytes = meta.total_bytes.max(vol_bytes);
        }
    }
    let changed = !touched.is_empty();
    if changed {
        // Deterministic shard re-formation: re-admit in arrival (= id)
        // order regardless of which agent resynced first.
        st.engine.readmit_in_id_order();
    }
    // Telemetry the agent buffered while we were gone: fuse it, then let
    // the belief refresh and/or the reconstruction trigger one round.
    let mut need_round = changed;
    if !st.engine.telemetry().is_oracle() {
        if let Some(samples) = msg.get("samples").and_then(|s| s.as_arr()) {
            if !samples.is_empty() {
                fuse_telemetry_samples(st, dc, samples);
            }
        }
        match st.engine.refresh_beliefs() {
            Some(WanReaction::Structural) | Some(WanReaction::Reoptimize) => need_round = true,
            Some(WanReaction::Clamped) if !need_round => push_rates(st),
            _ => {}
        }
    }
    if need_round {
        reallocate(st, RoundTrigger::CoflowArrival);
    }
}

/// Fuse one agent's achieved-throughput report into the capacity
/// estimator, issue probes for edges gone stale, and push any resulting
/// belief change through the engine's ρ gate (re-optimizing or re-clamping
/// exactly like an oracle WAN event would). Reports are counted but
/// otherwise ignored under the oracle.
fn handle_telemetry_report(st: &mut State, dc: usize, msg: &Json) {
    st.telemetry.reports += 1;
    if st.engine.telemetry().is_oracle() {
        return;
    }
    if let Some(samples) = msg.get("samples").and_then(|s| s.as_arr()) {
        fuse_telemetry_samples(st, dc, samples);
    }
    let now = st.now_s();
    request_probes(st, now);
    match st.engine.refresh_beliefs() {
        Some(WanReaction::Structural) | Some(WanReaction::Reoptimize) => {
            reallocate(st, RoundTrigger::WanChange);
        }
        Some(WanReaction::Clamped) => push_rates(st),
        None => {}
    }
}

/// Fuse one batch of agent samples into the capacity estimator. Shared by
/// live `telemetry_report` handling and crash-recovery `resync_state`
/// replay (agents buffer samples while the controller is down).
fn fuse_telemetry_samples(st: &mut State, dc: usize, samples: &[Json]) {
    let now = st.now_s();
    {
        // Aggregate the report per edge before fusing: one agent commonly
        // drives several transfers over the same out-edge, and the edge's
        // capacity evidence is their *sum* — fusing each transfer's share
        // individually would read a fairly-split healthy link as a
        // collapsed one. (Edges shared by *different* source agents are
        // still fused per report — a known approximation; the simulator
        // aggregates globally.)
        let mut passive: HashMap<usize, (f64, f64)> = HashMap::new(); // edge -> (achieved, alloc)
        let mut probes: HashMap<usize, f64> = HashMap::new(); // edge -> best measurement
        // Edges some sample's stall watchdog flagged: the agent saw N
        // consecutive zero-progress windows on an allocated path, which is
        // affirmative outage evidence — unlike a plain zero-achieved
        // startup window, which says nothing.
        let mut stalled: std::collections::HashSet<usize> = std::collections::HashSet::new();
        for sj in samples {
            let Some(s) = TelemetrySample::from_json(sj) else {
                log::warn!("controller: malformed telemetry sample from dc {dc}, dropped");
                continue;
            };
            // Network-supplied indices: an out-of-range dst would panic
            // the path lookup (same hardening rule as hello/submit).
            if s.dst_dc >= st.engine.wan().num_nodes() || s.dst_dc == dc {
                continue;
            }
            if !s.gbps.is_finite()
                || s.gbps < 0.0
                || !s.alloc_gbps.is_finite()
                || (!s.probe && s.coflow == PROBE_COFLOW)
            {
                continue;
            }
            // Map the agent's ⟨dst, path⟩ onto WAN edges. A path sample
            // bounds every edge on the path (simple tomography: the
            // bottleneck is not attributable from one sample, so the
            // observation applies path-wide; repeated samples sort the
            // edges out as allocations shift).
            let Some(p) = st.engine.paths().get(dc, s.dst_dc).get(s.path) else { continue };
            st.telemetry.samples += 1;
            for &e in &p.edges {
                if s.probe {
                    let best = probes.entry(e).or_insert(0.0);
                    *best = best.max(s.gbps);
                } else {
                    let (ach, alloc) = passive.entry(e).or_insert((0.0, 0.0));
                    *ach += s.gbps;
                    *alloc += s.alloc_gbps.max(0.0);
                    if s.stalled && s.alloc_gbps > 0.0 {
                        stalled.insert(e);
                    }
                }
            }
        }
        let mut edges: Vec<usize> = passive.keys().chain(probes.keys()).copied().collect();
        edges.sort_unstable();
        edges.dedup();
        for e in edges {
            // Emulated ground truth is a hard ceiling (base capacity,
            // lowered by injected events): loopback probe bursts drain
            // into kernel buffers at absurd rates, and a probe must not
            // "measure" past the capacity the testbed is emulating.
            let ceiling = st.truth_caps.get(e).copied().unwrap_or(f64::INFINITY);
            if let Some((ach, alloc)) = passive.get(&e) {
                // Capped only when the edge's *total* achieved rate fell
                // well short of a nonzero total allocation that spanned
                // the window (startup windows report alloc 0), and some
                // bytes actually moved — an unopened connection says
                // nothing about the link. Exception: a stall-flagged path
                // (watchdog-confirmed zero progress under a live
                // allocation) is capped evidence even at zero achieved —
                // that is precisely the gray outage the zero-bytes guard
                // would otherwise hide from the estimator.
                let capped = *alloc > 0.0
                    && ((*ach > 0.0 && *ach < alloc * 0.9) || stalled.contains(&e));
                st.engine.observe_edge(e, ach.min(ceiling), capped, now);
            }
            if let Some(m) = probes.get(&e) {
                st.engine.probe_edge(e, m.min(ceiling), now);
            }
        }
    }
}

/// Ask source agents to probe edges whose belief has gone stale (idle or
/// censored links age without informative samples). Each stale edge is
/// probed on its *direct* path — the only path whose measurement
/// attributes to the edge alone — at most once per staleness window.
fn request_probes(st: &mut State, now: f64) {
    let probe_after = st.engine.telemetry().probe_after_s;
    if probe_after <= 0.0 {
        return;
    }
    let stale =
        telemetry::stale_edges(st.engine.estimator(), st.engine.wan(), now, probe_after);
    for e in stale {
        if now - st.last_probe_req.get(e).copied().unwrap_or(f64::NEG_INFINITY) < probe_after {
            continue;
        }
        let (src, dst) = {
            let l = st.engine.wan().link(e);
            (l.src, l.dst)
        };
        let Some(pi) = st
            .engine
            .paths()
            .get(src, dst)
            .iter()
            .position(|p| p.edges.len() == 1 && p.edges[0] == e)
        else {
            continue; // no direct path survives (e.g. after failures)
        };
        let Some(a) = st.agents.get_mut(&src) else { continue };
        let m = Json::from_pairs([
            ("op", Json::from("probe_request")),
            ("dst", dst.into()),
            ("path", pi.into()),
        ]);
        if a.tx.send(m) {
            st.telemetry.probes_sent += 1;
            st.last_probe_req[e] = now;
        }
    }
}

fn coflow_status(st: &State, id: CoflowId) -> CoflowStatus {
    match st.coflows.get(&id) {
        None => CoflowStatus::Unknown,
        Some(meta) if !meta.admitted => CoflowStatus::Rejected,
        Some(meta) => match meta.finished {
            Some(t) => {
                CoflowStatus::Done { cct_s: t.duration_since(meta.submitted).as_secs_f64() }
            }
            None => {
                let total = meta.total_bytes;
                let remaining: f64 =
                    st.engine.get(id).map(|c| c.total_remaining()).unwrap_or(0.0);
                let delivered = total.saturating_sub(
                    (remaining * super::BYTES_PER_GBPS) as u64,
                );
                CoflowStatus::Running { delivered, total }
            }
        },
    }
}

fn handle_submit(msg: &Json, state: &Arc<Mutex<State>>) -> Json {
    let flows: Vec<FlowSpec> = msg
        .get("flows")
        .and_then(|f| f.as_arr())
        .map(|arr| arr.iter().filter_map(FlowSpec::from_json).collect())
        .unwrap_or_default();
    let deadline = msg.get("deadline").and_then(|d| d.as_f64());
    // Malformed classes are rejected outright — silently downgrading a
    // stream to batch would drop its rate floor on the floor.
    let Some(class) = protocol::class_from_json(msg.get("class")) else {
        return Json::from_pairs([("error", Json::from("malformed service class"))]);
    };
    let mut st = state.lock().unwrap();
    // A flow endpoint outside the WAN would index out of the path sets in
    // the next scheduling round: reject the submission instead of panicking
    // later on network-supplied input.
    let n = st.engine.wan().num_nodes();
    if flows.iter().any(|f| f.src_dc >= n || f.dst_dc >= n) {
        return Json::from_pairs([("error", Json::from("flow endpoint out of range"))]);
    }
    if let ServiceClass::MlSync { tree, .. } = &class {
        if tree.participants().iter().any(|&p| p >= n) {
            return Json::from_pairs([("error", Json::from("tree node out of range"))]);
        }
    }
    let id = st.next_id;
    st.next_id += 1;

    let coflow_flows: Vec<Flow> = flows
        .iter()
        .map(|f| Flow {
            id: f.id,
            src_dc: f.src_dc,
            dst_dc: f.dst_dc,
            volume: bytes_to_gbit(f.bytes),
        })
        .collect();
    let mut spec = Coflow::new(id, coflow_flows).with_class(class);
    if let Some(d) = deadline {
        spec = spec.with_deadline(d);
    }
    let mut cstate = CoflowState::from_coflow(&spec);
    // Absolute deadline on the controller's clock.
    let now_s = st.now_s();
    cstate.arrival = now_s;
    let deadline_abs = deadline.map(|d| now_s + d);
    cstate.deadline = deadline_abs;

    // Admission control against up-to-date remaining estimates: deadline
    // coflows per §3.2/§5.2 (returns -1 when the deadline cannot be met),
    // streams against the believed headroom left by already-admitted
    // floors.
    let mut admitted = true;
    if cstate.deadline.is_some() || cstate.rate_floor().is_some() {
        st.drain_to_now();
        admitted = st.engine.admit(now_s, &cstate);
    }
    let total_bytes: u64 = flows.iter().map(|f| f.bytes).sum();
    st.coflows.insert(
        id,
        CoMeta {
            submitted: Instant::now(),
            finished: None,
            deadline_abs,
            admitted,
            total_bytes,
        },
    );
    if !admitted {
        return Json::from_pairs([("cid", Json::from(-1i64))]);
    }

    // All-intra-DC (or zero-byte) submissions coalesce to zero FlowGroups:
    // done on arrival, never inserted (an empty coflow would otherwise sit
    // in the active table forever waiting for a group_done).
    if cstate.done() {
        if let Some(meta) = st.coflows.get_mut(&id) {
            meta.finished = Some(Instant::now());
        }
        return Json::from_pairs([("cid", Json::from(id))]);
    }

    cstate.admitted = true;
    st.engine.insert(cstate);

    // Wire transfers: receiver expectations first, then sender starts.
    send_transfer_msgs(&mut st, id, &flows);
    reallocate(&mut st, RoundTrigger::CoflowArrival);
    Json::from_pairs([("cid", Json::from(id))])
}

fn handle_update(msg: &Json, state: &Arc<Mutex<State>>) -> Json {
    let id = msg.get("cid").and_then(|x| x.as_u64()).unwrap_or(0);
    let flows: Vec<FlowSpec> = msg
        .get("flows")
        .and_then(|f| f.as_arr())
        .map(|arr| arr.iter().filter_map(FlowSpec::from_json).collect())
        .unwrap_or_default();
    let mut st = state.lock().unwrap();
    let n = st.engine.wan().num_nodes();
    if flows.iter().any(|f| f.src_dc >= n || f.dst_dc >= n) {
        return Json::from_pairs([("error", Json::from("flow endpoint out of range"))]);
    }
    match st.coflows.get(&id) {
        None => {
            return Json::from_pairs([("error", Json::from("unknown coflow"))]);
        }
        // A deadline-rejected coflow must never re-enter scheduling via
        // update (§3.2 admission is final; clients were handed cid = -1).
        Some(meta) if !meta.admitted => {
            return Json::from_pairs([("error", Json::from("coflow was rejected"))]);
        }
        Some(_) => {}
    }
    // Extend existing groups / add new ones (§5.2 updateCoflow). A coflow
    // that already finished gets a fresh engine entry holding only the new
    // volume (the old groups are fully transferred).
    {
        let deadline_abs = st.coflows[&id].deadline_abs;
        if st.engine.get(id).is_none() {
            let mut revived = CoflowState::from_coflow(&Coflow::new(id, Vec::new()));
            revived.arrival = st.now_s();
            revived.deadline = deadline_abs;
            revived.admitted = true;
            st.engine.insert(revived);
        }
        let co = st.engine.get_mut(id).unwrap();
        for f in &flows {
            let gbit = bytes_to_gbit(f.bytes);
            if f.src_dc == f.dst_dc || gbit <= 0.0 {
                continue;
            }
            if let Some(gi) =
                co.groups.iter().position(|g| g.src == f.src_dc && g.dst == f.dst_dc)
            {
                co.groups[gi].volume += gbit;
                co.groups[gi].num_flows += 1;
                co.remaining[gi] += gbit;
            } else {
                co.groups.push(crate::coflow::FlowGroup {
                    src: f.src_dc,
                    dst: f.dst_dc,
                    volume: gbit,
                    num_flows: 1,
                });
                co.remaining.push(gbit);
            }
        }
        st.engine.mark_dirty(id);
        let meta = st.coflows.get_mut(&id).unwrap();
        meta.finished = None;
        meta.total_bytes += flows.iter().map(|f| f.bytes).sum::<u64>();
    }
    send_transfer_msgs(&mut st, id, &flows);
    reallocate(&mut st, RoundTrigger::CoflowArrival);
    Json::from_pairs([("cid", Json::from(id))])
}

/// Send `expect` to destination agents and `transfer` to source agents.
/// Receiver expectations must be on the wire before any sender starts
/// (unsolicited data chunks have no byte target to complete against), so
/// with asynchronous writers the destination queues are flushed between
/// the two waves.
fn send_transfer_msgs(st: &mut State, id: CoflowId, flows: &[FlowSpec]) {
    // Aggregate by (src, dst) — FlowGroup granularity on the wire too.
    let mut by_pair: HashMap<(usize, usize), u64> = HashMap::new();
    for f in flows {
        if f.src_dc != f.dst_dc && f.bytes > 0 {
            *by_pair.entry((f.src_dc, f.dst_dc)).or_default() += f.bytes;
        }
    }
    for (&(src, dst), &bytes) in &by_pair {
        if let Some(a) = st.agents.get_mut(&dst) {
            let m = Json::from_pairs([
                ("op", Json::from("expect")),
                ("coflow", id.into()),
                ("src", src.into()),
                ("bytes", bytes.into()),
            ]);
            a.tx.send(m);
        }
    }
    let mut dsts: Vec<usize> = by_pair.keys().map(|&(_, d)| d).collect();
    dsts.sort_unstable();
    dsts.dedup();
    for dst in dsts {
        if let Some(a) = st.agents.get(&dst) {
            // Bounded: a dead receiver socket fails over to the
            // write-error full-sync path regardless.
            a.tx.flush(Duration::from_secs(2));
        }
    }
    // Streams carry their per-FlowGroup floor to the source agent so it
    // can keep honoring the guarantee locally in degraded mode.
    let floor = st.engine.get(id).and_then(|c| c.rate_floor());
    for (&(src, dst), &bytes) in &by_pair {
        if let Some(a) = st.agents.get_mut(&src) {
            let mut m = Json::from_pairs([
                ("op", Json::from("transfer")),
                ("coflow", id.into()),
                ("dst", dst.into()),
                ("bytes", bytes.into()),
            ]);
            if let Some(f) = floor {
                m.set("floor_gbps", f.into());
            }
            a.tx.send(m);
        }
    }
}

/// One scheduling round: drain remaining-volume estimates, run the engine's
/// round, and push the new rate vectors to the source agents. With a
/// sharded engine the enforcement is pipelined: each shard's changed rates
/// are pushed the moment its solve completes (while other shards are still
/// solving); the trailing [`push_rates`] sweep then ships only what the
/// per-shard pushes could not know — revocations and spill-engine rates.
fn reallocate(st: &mut State, trigger: RoundTrigger) {
    st.drain_to_now();
    let now_s = st.now_s();
    if st.engine.num_shards() > 1 {
        let State { engine, agents, agent_gen, delta, .. } = st;
        engine.round_with(now_s, trigger, |_, shard| {
            push_shard_rates(agents, agent_gen, delta, shard);
        });
    } else {
        st.engine.round(now_s, trigger);
    }
    push_rates(st);
}

/// The rate table each source agent should currently hold:
/// (coflow, dst) → per-path Gbps from the engine's live allocation.
fn desired_rate_tables(st: &State) -> HashMap<usize, HashMap<(CoflowId, usize), Vec<f64>>> {
    let mut desired: HashMap<usize, HashMap<(CoflowId, usize), Vec<f64>>> = HashMap::new();
    st.engine.visit_allocations(|cs, rates| {
        for (gi, g) in cs.groups.iter().enumerate() {
            let path_rates: Vec<f64> = rates.and_then(|r| r.get(gi)).cloned().unwrap_or_default();
            desired.entry(g.src).or_default().insert((cs.id, g.dst), path_rates);
        }
    });
    desired
}

/// Pipelined per-shard enforcement: push the FlowGroup rate vectors this
/// shard's just-finished solve changed, updating each agent's delta
/// baseline in place. Revocations are deliberately left to the trailing
/// global sweep — a single shard cannot know whether a (coflow, dst) entry
/// vanished or merely lives on another shard now.
fn push_shard_rates(
    agents: &mut HashMap<usize, AgentConn>,
    agent_gen: &HashMap<usize, u64>,
    delta: &mut DeltaStats,
    shard: &RoundEngine,
) {
    let mut desired: HashMap<usize, HashMap<(CoflowId, usize), Vec<f64>>> = HashMap::new();
    for cs in shard.active() {
        let rates = shard.alloc().rates.get(&cs.id);
        for (gi, g) in cs.groups.iter().enumerate() {
            let path_rates: Vec<f64> = rates.and_then(|r| r.get(gi)).cloned().unwrap_or_default();
            desired.entry(g.src).or_default().insert((cs.id, g.dst), path_rates);
        }
    }
    for (dc, want) in desired {
        let Some(conn) = agents.get_mut(&dc) else { continue };
        // Never address a superseded connection: a conn whose generation
        // no longer matches the dc's live generation is being replaced
        // (its successor's hello holds the baseline).
        if agent_gen.get(&dc) != Some(&conn.gen) {
            continue;
        }
        let mut changed: Vec<(CoflowId, usize)> = want
            .iter()
            .filter(|(k, v)| conn.sent.get(*k) != Some(*v))
            .map(|(&k, _)| k)
            .collect();
        changed.sort_unstable();
        if changed.is_empty() {
            continue;
        }
        conn.seq += 1;
        let updates: Vec<Json> =
            changed.iter().map(|k| rate_entry_json(k, &want[k])).collect();
        let m = Json::from_pairs([
            ("op", Json::from("rates_delta")),
            ("seq", conn.seq.into()),
            ("updates", Json::Arr(updates)),
            ("revoke", Json::Arr(Vec::new())),
        ]);
        delta.delta_msgs += 1;
        delta.delta_entries += changed.len();
        conn.tx.send(m);
        for k in changed {
            conn.sent.insert(k, want[&k].clone());
        }
    }
}

fn rate_entry_json(key: &(CoflowId, usize), rates: &[f64]) -> Json {
    Json::from_pairs([
        ("coflow", Json::from(key.0)),
        ("dst", key.1.into()),
        ("rates", Json::Arr(rates.iter().map(|&r| Json::Num(r)).collect())),
    ])
}

/// Delta enforcement: push each source agent only the FlowGroup rate
/// vectors that changed since its last push, plus an explicit revoke list
/// for withdrawn entries, under a per-agent sequence number. Agents whose
/// table is unchanged get **no message at all** — with component-decomposed
/// rounds, a round that re-solved one component touches only that
/// component's senders, so WAN control traffic is O(changed flows) instead
/// of O(all flows).
fn push_rates(st: &mut State) {
    let mut desired = desired_rate_tables(st);
    let State { agents, agent_gen, delta, .. } = st;
    for (&dc, conn) in agents.iter_mut() {
        if agent_gen.get(&dc) != Some(&conn.gen) {
            continue;
        }
        // Take (not clone) the agent's table; when nothing changed we drop
        // it — `conn.sent` is provably identical in that case.
        let want = desired.remove(&dc).unwrap_or_default();
        // A failed write or queue overflow invalidated this agent's delta
        // baseline: resynchronize the full table instead of diffing
        // against state it may never have received.
        if conn.tx.take_full_sync_flag() {
            send_full_table(conn, delta, want);
            continue;
        }
        let mut changed: Vec<(CoflowId, usize)> = want
            .iter()
            .filter(|(k, v)| conn.sent.get(*k) != Some(*v))
            .map(|(&k, _)| k)
            .collect();
        changed.sort_unstable();
        let mut revoked: Vec<(CoflowId, usize)> =
            conn.sent.keys().filter(|k| !want.contains_key(*k)).copied().collect();
        revoked.sort_unstable();
        if changed.is_empty() && revoked.is_empty() {
            continue;
        }
        conn.seq += 1;
        let updates: Vec<Json> =
            changed.iter().map(|k| rate_entry_json(k, &want[k])).collect();
        let revoke: Vec<Json> = revoked
            .iter()
            .map(|k| Json::from_pairs([("coflow", Json::from(k.0)), ("dst", k.1.into())]))
            .collect();
        let m = Json::from_pairs([
            ("op", Json::from("rates_delta")),
            ("seq", conn.seq.into()),
            ("updates", Json::Arr(updates)),
            ("revoke", Json::Arr(revoke)),
        ]);
        delta.delta_msgs += 1;
        delta.delta_entries += changed.len();
        delta.delta_revokes += revoked.len();
        conn.tx.send(m);
        conn.sent = want;
    }
}

/// Ship an agent's complete rate table under a fresh sequence number and
/// reset its delta baseline to it.
fn send_full_table(
    conn: &mut AgentConn,
    delta: &mut DeltaStats,
    want: HashMap<(CoflowId, usize), Vec<f64>>,
) {
    let mut keys: Vec<(CoflowId, usize)> = want.keys().copied().collect();
    keys.sort_unstable();
    conn.seq += 1;
    let entries: Vec<Json> = keys.iter().map(|k| rate_entry_json(k, &want[k])).collect();
    let m = Json::from_pairs([
        ("op", Json::from("rates_full")),
        ("seq", conn.seq.into()),
        ("entries", Json::Arr(entries)),
    ]);
    delta.full_syncs += 1;
    conn.tx.send(m);
    conn.sent = want;
}

/// Full-table sync for one agent: everything it should hold, under a fresh
/// baseline sequence number. Sent on (re)connect and on `sync_request`
/// (the agent saw a sequence gap).
fn full_sync_agent(st: &mut State, dc: usize) {
    let mut desired = desired_rate_tables(st);
    let State { agents, delta, .. } = st;
    let Some(conn) = agents.get_mut(&dc) else { return };
    let want = desired.remove(&dc).unwrap_or_default();
    // The sync supersedes any pending invalidation.
    conn.tx.take_full_sync_flag();
    send_full_table(conn, delta, want);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn json_frame(i: usize) -> Json {
        let mut o = Json::obj();
        o.set("i", Json::from(i));
        o
    }

    #[test]
    fn tx_overflow_drops_queue_and_flags_full_sync() {
        // No writer thread: nothing drains, so the cap is hit exactly.
        let tx = AgentTx::new(2);
        assert!(tx.send(json_frame(0)));
        assert!(tx.send(json_frame(1)));
        // Third frame overflows: the whole queue is dropped (it is stale
        // relative to the full sync the flag now forces).
        assert!(!tx.send(json_frame(2)));
        assert!(tx.shared.q.lock().unwrap().buf.is_empty());
        assert!(tx.take_full_sync_flag());
        // The flag is consumed by the read.
        assert!(!tx.take_full_sync_flag());
        // The queue stays usable after an overflow (not closed).
        assert!(tx.send(json_frame(3)));
    }

    #[test]
    fn tx_closed_queue_refuses_sends() {
        let tx = AgentTx::new(8);
        tx.shared.q.lock().unwrap().closed = true;
        assert!(!tx.send(json_frame(0)));
        assert!(tx.shared.q.lock().unwrap().buf.is_empty());
        // A closed queue never set the full-sync flag by itself; the
        // writer that closed it is responsible for that.
        assert!(!tx.take_full_sync_flag());
    }

    #[test]
    fn tx_flush_semantics() {
        let tx = AgentTx::new(8);
        // Empty queue: flush succeeds immediately.
        assert!(tx.flush(Duration::from_millis(10)));
        // Queued frame with no writer: flush times out unsatisfied.
        assert!(tx.send(json_frame(0)));
        assert!(!tx.flush(Duration::from_millis(10)));
        // Closed with a frame still queued: flush wakes but reports the
        // queue undrained.
        tx.shared.q.lock().unwrap().closed = true;
        assert!(!tx.flush(Duration::from_millis(10)));
    }

    #[test]
    fn tx_drop_closes_queue_for_writer() {
        let tx = AgentTx::new(8);
        let shared = tx.shared.clone();
        drop(tx);
        assert!(shared.q.lock().unwrap().closed);
    }
}
