//! Terra agent (§4.1, §5.1): per-datacenter daemon that transfers data on
//! behalf of jobs over persistent multipath TCP connections at
//! controller-assigned rates.
//!
//! Sender side: each outgoing FlowGroup transfer is striped across the k
//! persistent connections to the destination agent; a token bucket per
//! ⟨transfer, path⟩ enforces the controller's rate (the `tc` stand-in).
//! Receiver side: chunks arrive out of order across paths; the agent
//! buffers them and advances an in-order frontier, delivering only
//! contiguous data to the application (§5.1 "Handling WAN Latency
//! Heterogeneity") and reports FlowGroup completion to the controller.

use super::protocol::{self, DataHeader, TelemetrySample, CHUNK_BYTES, PROBE_COFLOW};
use super::BYTES_PER_GBPS;
use crate::util::json::Json;
use std::collections::{BTreeMap, HashMap};
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// How often the sender flushes achieved-throughput samples to the
/// controller (`telemetry_report`).
const TELEMETRY_INTERVAL: Duration = Duration::from_millis(250);
/// Probe burst size (chunks) when the controller issues a `probe_request`.
const PROBE_CHUNKS: usize = 4;

/// Sender-side state of one outgoing transfer (one FlowGroup direction).
struct Outgoing {
    coflow: u64,
    remaining: u64,
    offset: u64,
    /// Token-bucket budget (bytes) and rate (bytes/s) per path.
    budget: Vec<f64>,
    rate: Vec<f64>,
    /// Bytes actually written per path since the last telemetry flush —
    /// the *achieved* throughput the controller's estimator feeds on.
    window: Vec<f64>,
    /// Full telemetry windows elapsed since the last rate change. A
    /// sample from a window the current rate did not span entirely
    /// (transfer or rate arrived mid-window) must not be compared against
    /// the allocation — the shortfall is startup, not the link.
    rate_windows: u32,
}

/// Receiver-side reassembly state of one incoming transfer.
struct Incoming {
    expected: u64,
    /// In-order frontier: all bytes < frontier delivered to the app.
    frontier: u64,
    /// Out-of-order chunks keyed by offset (the paper buffers to a block
    /// device; we model it in memory).
    pending: BTreeMap<u64, u32>,
    /// Total bytes received (for throughput sampling).
    received: Arc<AtomicU64>,
}

/// A Terra agent. Spawn with [`Agent::spawn`]; threads run until
/// [`Agent::shutdown`].
pub struct Agent {
    pub dc: usize,
    pub data_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
    out: Arc<Mutex<HashMap<(u64, usize), Outgoing>>>,
    /// Persistent data connections per destination dc: one per path.
    conns: Arc<Mutex<HashMap<usize, Vec<TcpStream>>>>,
    /// Receive counters per (coflow, src_dc) for throughput sampling.
    rx_counters: Arc<Mutex<HashMap<(u64, usize), Arc<AtomicU64>>>>,
}

impl Agent {
    /// Start an agent for datacenter `dc`, registering with the controller
    /// at `controller_addr`.
    pub fn spawn(dc: usize, controller_addr: std::net::SocketAddr) -> std::io::Result<Agent> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let data_addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let out: Arc<Mutex<HashMap<(u64, usize), Outgoing>>> = Arc::default();
        let conns: Arc<Mutex<HashMap<usize, Vec<TcpStream>>>> = Arc::default();
        let rx_counters: Arc<Mutex<HashMap<(u64, usize), Arc<AtomicU64>>>> = Arc::default();
        let incoming: Arc<Mutex<HashMap<(u64, usize), Incoming>>> = Arc::default();

        // Control channel.
        let mut ctrl = TcpStream::connect(controller_addr)?;
        let hello = Json::from_pairs([
            ("op", Json::from("hello")),
            ("dc", dc.into()),
            ("data_addr", data_addr.to_string().into()),
        ]);
        protocol::write_msg(&mut ctrl, &hello)?;
        let ctrl_tx = Arc::new(Mutex::new(ctrl.try_clone()?));

        let mut threads = Vec::new();

        // Data listener: accept persistent connections from peers.
        {
            let stop = stop.clone();
            let incoming = incoming.clone();
            let rx_counters = rx_counters.clone();
            let ctrl_tx = ctrl_tx.clone();
            listener.set_nonblocking(true)?;
            threads.push(std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((s, _)) => {
                            s.set_nonblocking(false).ok();
                            let stop = stop.clone();
                            let incoming = incoming.clone();
                            let rx_counters = rx_counters.clone();
                            let ctrl_tx = ctrl_tx.clone();
                            std::thread::spawn(move || {
                                recv_loop(s, dc, stop, incoming, rx_counters, ctrl_tx);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            }));
        }

        // Control reader: controller commands. Tracks the delta-protocol
        // sequence number; a gap (lost or reordered push) triggers a
        // `sync_request`, answered by a `rates_full` that rebaselines.
        {
            let stop = stop.clone();
            let out = out.clone();
            let conns = conns.clone();
            let incoming = incoming.clone();
            let rx_counters = rx_counters.clone();
            let ctrl_tx = ctrl_tx.clone();
            ctrl.set_read_timeout(Some(Duration::from_millis(100)))?;
            threads.push(std::thread::spawn(move || {
                // None until the first rates_full lands.
                let mut last_seq: Option<u64> = None;
                while !stop.load(Ordering::Relaxed) {
                    let msg = match protocol::read_msg_resumable(&mut ctrl, &stop) {
                        Ok(Some(m)) => m,
                        _ => break,
                    };
                    match msg.get("op").and_then(|o| o.as_str()) {
                        Some("rates_full") => {
                            apply_rates_full(&msg, &out);
                            last_seq = msg.get("seq").and_then(|x| x.as_u64());
                        }
                        Some("rates_delta") => {
                            let seq = msg.get("seq").and_then(|x| x.as_u64());
                            match (last_seq, seq) {
                                (Some(prev), Some(s)) if s == prev + 1 => {
                                    apply_rates_delta(&msg, &out);
                                    last_seq = Some(s);
                                }
                                _ => {
                                    // Gap or unsynced: drop the delta and
                                    // ask for the full table.
                                    log::warn!(
                                        "agent {dc}: rate-delta gap \
                                         ({last_seq:?} -> {seq:?}), requesting full sync"
                                    );
                                    let req = Json::from_pairs([(
                                        "op",
                                        Json::from("sync_request"),
                                    )]);
                                    let mut tx = ctrl_tx.lock().unwrap();
                                    let _ = protocol::write_msg(&mut tx, &req);
                                }
                            }
                        }
                        Some("probe_request") => handle_probe(dc, &msg, &conns, &ctrl_tx),
                        _ => handle_ctrl(&msg, &out, &conns, &incoming, &rx_counters),
                    }
                }
            }));
        }

        // Sender: token-bucket pacing loop, plus periodic telemetry
        // flushes (achieved bytes per ⟨transfer, path⟩ → `telemetry_report`).
        {
            let stop = stop.clone();
            let out = out.clone();
            let conns = conns.clone();
            let ctrl_tx = ctrl_tx.clone();
            threads.push(std::thread::spawn(move || {
                let mut last = Instant::now();
                let mut last_report = Instant::now();
                let payload = vec![0u8; CHUNK_BYTES];
                while !stop.load(Ordering::Relaxed) {
                    std::thread::sleep(Duration::from_millis(4));
                    let now = Instant::now();
                    let dt = now.duration_since(last).as_secs_f64();
                    last = now;
                    send_tick(dc, dt, &payload, &out, &conns);
                    let window = now.duration_since(last_report);
                    if window >= TELEMETRY_INTERVAL {
                        last_report = now;
                        flush_telemetry(window.as_secs_f64(), &out, &ctrl_tx);
                    }
                }
            }));
        }

        Ok(Agent { dc, data_addr, stop, threads, out, conns, rx_counters })
    }

    /// Bytes received so far for (coflow, src_dc) — throughput sampling for
    /// the failure case study (Fig 10).
    pub fn received_bytes(&self, coflow: u64, src_dc: usize) -> u64 {
        self.rx_counters
            .lock()
            .unwrap()
            .get(&(coflow, src_dc))
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Outstanding bytes still to send from this agent.
    pub fn backlog(&self) -> u64 {
        self.out.lock().unwrap().values().map(|o| o.remaining).sum()
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Close data connections to unblock readers.
        self.conns.lock().unwrap().clear();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Apply a controller command.
fn handle_ctrl(
    msg: &Json,
    out: &Arc<Mutex<HashMap<(u64, usize), Outgoing>>>,
    conns: &Arc<Mutex<HashMap<usize, Vec<TcpStream>>>>,
    incoming: &Arc<Mutex<HashMap<(u64, usize), Incoming>>>,
    rx_counters: &Arc<Mutex<HashMap<(u64, usize), Arc<AtomicU64>>>>,
) {
    match msg.get("op").and_then(|o| o.as_str()) {
        // Establish persistent connections: one per path to each peer.
        Some("peers") => {
            if let Some(arr) = msg.get("peers").and_then(|p| p.as_arr()) {
                let mut c = conns.lock().unwrap();
                for peer in arr {
                    let (Some(dst), Some(addr), Some(k)) = (
                        peer.get("dc").and_then(|x| x.as_u64()),
                        peer.get("addr").and_then(|x| x.as_str()),
                        peer.get("k").and_then(|x| x.as_u64()),
                    ) else {
                        log::warn!("agent: malformed peer entry dropped");
                        continue;
                    };
                    // Sanity-cap k: a corrupt value must not spin this
                    // thread opening unbounded connections.
                    let k = k.min(1024);
                    let entry = c.entry(dst as usize).or_default();
                    while entry.len() < k as usize {
                        match TcpStream::connect(addr) {
                            Ok(s) => {
                                s.set_nodelay(true).ok();
                                entry.push(s);
                            }
                            Err(e) => {
                                log::warn!("agent: connect {addr}: {e}");
                                break;
                            }
                        }
                    }
                }
            }
        }
        // Start an outgoing transfer.
        Some("transfer") => {
            let (Some(coflow), Some(dst), Some(bytes)) = (
                msg.get("coflow").and_then(|x| x.as_u64()),
                msg.get("dst").and_then(|x| x.as_u64()),
                msg.get("bytes").and_then(|x| x.as_u64()),
            ) else {
                return;
            };
            let k = conns.lock().unwrap().get(&(dst as usize)).map(|v| v.len()).unwrap_or(0);
            let mut o = out.lock().unwrap();
            let e = o.entry((coflow, dst as usize)).or_insert(Outgoing {
                coflow,
                remaining: 0,
                offset: 0,
                budget: vec![0.0; k],
                rate: vec![0.0; k],
                window: vec![0.0; k],
                rate_windows: 0,
            });
            e.remaining += bytes;
        }
        // Expect an incoming transfer (receiver side).
        Some("expect") => {
            let (Some(coflow), Some(src), Some(bytes)) = (
                msg.get("coflow").and_then(|x| x.as_u64()),
                msg.get("src").and_then(|x| x.as_u64()),
                msg.get("bytes").and_then(|x| x.as_u64()),
            ) else {
                return;
            };
            let counter = Arc::new(AtomicU64::new(0));
            rx_counters.lock().unwrap().insert((coflow, src as usize), counter.clone());
            let mut inc = incoming.lock().unwrap();
            let e = inc.entry((coflow, src as usize)).or_insert(Incoming {
                expected: 0,
                frontier: 0,
                pending: BTreeMap::new(),
                received: counter,
            });
            // Saturating: if data raced ahead of the expectation the entry
            // already exists with the unsolicited u64::MAX sentinel, and a
            // plain add would overflow.
            e.expected = e.expected.saturating_add(bytes);
        }
        // Update rates for (coflow, dst): one rate per path, Gbps (legacy
        // single-entry form; delta pushes batch the same payload).
        Some("rates") => apply_rate_entry(msg, out),
        _ => {}
    }
}

/// Apply one (coflow, dst, rates) entry — shared by the legacy `rates` op,
/// `rates_delta` updates, and `rates_full` entries. Non-finite or negative
/// rates from a (possibly corrupt) peer sanitize to 0 rather than feeding
/// the token buckets garbage.
///
/// The vector is stored exactly as sent — **not** truncated to the number
/// of currently-open peer connections. Deltas are pushed once, so a rate
/// vector cut down to an early `k = 0` (connections not yet established)
/// would never be repaired by a rebroadcast the way the legacy per-round
/// push repaired it; `send_tick` instead pairs rates with whatever
/// connections exist at each tick.
fn apply_rate_entry(entry: &Json, out: &Arc<Mutex<HashMap<(u64, usize), Outgoing>>>) {
    let (Some(coflow), Some(dst), Some(rates)) = (
        entry.get("coflow").and_then(|x| x.as_u64()),
        entry.get("dst").and_then(|x| x.as_u64()),
        entry.get("rates").and_then(|x| x.as_arr()),
    ) else {
        log::warn!("agent: malformed rate entry dropped");
        return;
    };
    let mut o = out.lock().unwrap();
    if let Some(e) = o.get_mut(&(coflow, dst as usize)) {
        let new_rate: Vec<f64> = rates
            .iter()
            .map(|r| r.as_f64().unwrap_or(0.0))
            .map(|r| if r.is_finite() && r > 0.0 { r } else { 0.0 })
            .collect();
        // The sample-stability clock restarts only on a genuine rate
        // change; a redundant re-push (full sync after reconnect) must
        // not suppress another window of capacity-capped evidence.
        if new_rate != e.rate {
            e.rate_windows = 0;
            e.rate = new_rate;
        }
        if e.budget.len() < e.rate.len() {
            e.budget.resize(e.rate.len(), 0.0);
        }
        if e.window.len() < e.rate.len() {
            e.window.resize(e.rate.len(), 0.0);
        }
    }
}

/// `rates_delta`: apply the changed entries, zero the revoked ones.
fn apply_rates_delta(msg: &Json, out: &Arc<Mutex<HashMap<(u64, usize), Outgoing>>>) {
    if let Some(updates) = msg.get("updates").and_then(|x| x.as_arr()) {
        for e in updates {
            apply_rate_entry(e, out);
        }
    }
    if let Some(revoke) = msg.get("revoke").and_then(|x| x.as_arr()) {
        let mut o = out.lock().unwrap();
        for r in revoke {
            let (Some(coflow), Some(dst)) = (
                r.get("coflow").and_then(|x| x.as_u64()),
                r.get("dst").and_then(|x| x.as_u64()),
            ) else {
                continue;
            };
            if let Some(e) = o.get_mut(&(coflow, dst as usize)) {
                for rate in &mut e.rate {
                    *rate = 0.0;
                }
            }
        }
    }
}

/// `rates_full`: rebaseline — zero every held rate, then apply the full
/// table (entries absent from it stay revoked).
fn apply_rates_full(msg: &Json, out: &Arc<Mutex<HashMap<(u64, usize), Outgoing>>>) {
    {
        let mut o = out.lock().unwrap();
        for e in o.values_mut() {
            for rate in &mut e.rate {
                *rate = 0.0;
            }
        }
    }
    if let Some(entries) = msg.get("entries").and_then(|x| x.as_arr()) {
        for e in entries {
            apply_rate_entry(e, out);
        }
    }
}

/// One pacing tick: move token-bucket budget into sent chunks.
fn send_tick(
    src_dc: usize,
    dt: f64,
    payload: &[u8],
    out: &Arc<Mutex<HashMap<(u64, usize), Outgoing>>>,
    conns: &Arc<Mutex<HashMap<usize, Vec<TcpStream>>>>,
) {
    let mut out = out.lock().unwrap();
    let mut conns = conns.lock().unwrap();
    for ((_, dst), o) in out.iter_mut() {
        if o.remaining == 0 {
            continue;
        }
        let Some(streams) = conns.get_mut(dst) else { continue };
        for (p, stream) in streams.iter_mut().enumerate() {
            if o.remaining == 0 {
                break;
            }
            let rate_bps = o.rate.get(p).copied().unwrap_or(0.0) * BYTES_PER_GBPS;
            if rate_bps <= 0.0 {
                continue;
            }
            // Connections can outnumber the budget vector when peers came
            // up after the transfer/rates arrived; grow it on demand.
            if o.budget.len() <= p {
                o.budget.resize(p + 1, 0.0);
            }
            if o.window.len() <= p {
                o.window.resize(p + 1, 0.0);
            }
            // Cap the bucket at one tick's worth plus a chunk to avoid
            // long-idle bursts defeating the shaper.
            o.budget[p] = (o.budget[p] + rate_bps * dt).min(rate_bps * 0.1 + CHUNK_BYTES as f64);
            while o.budget[p] >= 1.0 && o.remaining > 0 {
                let len = (CHUNK_BYTES as u64).min(o.remaining).min(o.budget[p] as u64);
                if len == 0 {
                    break;
                }
                let hdr = DataHeader {
                    coflow: o.coflow,
                    src_dc: src_dc as u32,
                    offset: o.offset,
                    len: len as u32,
                };
                if stream.write_all(&hdr.encode()).is_err()
                    || stream.write_all(&payload[..len as usize]).is_err()
                {
                    break;
                }
                o.offset += len;
                o.remaining -= len;
                o.budget[p] -= len as f64;
                o.window[p] += len as f64;
            }
        }
    }
    out.retain(|_, o| o.remaining > 0 || o.offset == 0);
}

/// Flush the achieved-bytes windows as a `telemetry_report`: one sample
/// per ⟨transfer, path⟩ that was allocated a rate or moved bytes this
/// window. Rates are already in emulated Gbps, so achieved bytes convert
/// through [`BYTES_PER_GBPS`] for apples-to-apples comparison. A report
/// goes out every interval even with zero samples — the heartbeat is what
/// drives the controller's staleness scan, so an idle agent must keep
/// reporting or its edges could never be probed.
fn flush_telemetry(
    window_s: f64,
    out: &Arc<Mutex<HashMap<(u64, usize), Outgoing>>>,
    ctrl_tx: &Arc<Mutex<TcpStream>>,
) {
    if window_s <= 0.0 {
        return;
    }
    let mut samples: Vec<Json> = Vec::new();
    {
        let mut o = out.lock().unwrap();
        for ((coflow, dst), e) in o.iter_mut() {
            // Only a window the current rate spanned entirely may be
            // compared against the allocation; otherwise the sample is a
            // lower bound only (alloc = 0 → the controller cannot read a
            // startup shortfall as link capacity).
            let stable = e.rate_windows > 0;
            e.rate_windows = e.rate_windows.saturating_add(1);
            for p in 0..e.window.len() {
                let achieved = e.window[p];
                let alloc = e.rate.get(p).copied().unwrap_or(0.0);
                e.window[p] = 0.0;
                if achieved <= 0.0 && alloc <= 0.0 {
                    continue;
                }
                samples.push(
                    TelemetrySample {
                        coflow: *coflow,
                        dst_dc: *dst,
                        path: p,
                        gbps: achieved / window_s / BYTES_PER_GBPS,
                        alloc_gbps: if stable { alloc } else { 0.0 },
                        probe: false,
                    }
                    .to_json(),
                );
            }
        }
    }
    let msg = Json::from_pairs([
        ("op", Json::from("telemetry_report")),
        ("samples", Json::Arr(samples)),
    ]);
    let mut tx = ctrl_tx.lock().unwrap();
    let _ = protocol::write_msg(&mut tx, &msg);
}

/// Controller-requested active probe: burst a few probe chunks (reserved
/// coflow id [`PROBE_COFLOW`], dropped by the receiver) on one persistent
/// connection and report the measured drain rate. On loopback this is an
/// optimistic upper bound (the kernel buffers absorb the burst); the
/// controller clamps probe readings to the edge's provisioned base
/// capacity before fusing them.
fn handle_probe(
    src_dc: usize,
    msg: &Json,
    conns: &Arc<Mutex<HashMap<usize, Vec<TcpStream>>>>,
    ctrl_tx: &Arc<Mutex<TcpStream>>,
) {
    let (Some(dst), Some(path)) = (
        msg.get("dst").and_then(|x| x.as_u64()),
        msg.get("path").and_then(|x| x.as_u64()),
    ) else {
        log::warn!("agent {src_dc}: malformed probe_request dropped");
        return;
    };
    let chunks =
        msg.get("chunks").and_then(|x| x.as_u64()).unwrap_or(PROBE_CHUNKS as u64).clamp(1, 64);
    let payload = vec![0u8; CHUNK_BYTES];
    let gbps = {
        let mut c = conns.lock().unwrap();
        let Some(stream) =
            c.get_mut(&(dst as usize)).and_then(|v| v.get_mut(path as usize))
        else {
            return; // no such connection (yet); the edge stays stale
        };
        let t0 = Instant::now();
        for i in 0..chunks {
            let hdr = DataHeader {
                coflow: PROBE_COFLOW,
                src_dc: src_dc as u32,
                offset: i * CHUNK_BYTES as u64,
                len: CHUNK_BYTES as u32,
            };
            if stream.write_all(&hdr.encode()).is_err()
                || stream.write_all(&payload).is_err()
            {
                return;
            }
        }
        if stream.flush().is_err() {
            return;
        }
        let dt = t0.elapsed().as_secs_f64().max(1e-9);
        (chunks as f64 * CHUNK_BYTES as f64) / dt / BYTES_PER_GBPS
    };
    let sample = TelemetrySample {
        coflow: PROBE_COFLOW,
        dst_dc: dst as usize,
        path: path as usize,
        gbps,
        alloc_gbps: 0.0,
        probe: true,
    };
    let msg = Json::from_pairs([
        ("op", Json::from("telemetry_report")),
        ("samples", Json::Arr(vec![sample.to_json()])),
    ]);
    let mut tx = ctrl_tx.lock().unwrap();
    let _ = protocol::write_msg(&mut tx, &msg);
}

/// Receive loop for one persistent data connection.
fn recv_loop(
    mut stream: TcpStream,
    my_dc: usize,
    stop: Arc<AtomicBool>,
    incoming: Arc<Mutex<HashMap<(u64, usize), Incoming>>>,
    rx_counters: Arc<Mutex<HashMap<(u64, usize), Arc<AtomicU64>>>>,
    ctrl_tx: Arc<Mutex<TcpStream>>,
) {
    let mut hdr_buf = [0u8; DataHeader::SIZE];
    let mut payload = vec![0u8; CHUNK_BYTES];
    stream.set_read_timeout(Some(Duration::from_millis(100))).ok();
    while !stop.load(Ordering::Relaxed) {
        match protocol::read_full(&mut stream, &mut hdr_buf, &stop) {
            Ok(true) => {}
            _ => break,
        }
        let Ok(hdr) = DataHeader::decode(&hdr_buf) else { break };
        // A frame claiming more than the chunk size is corrupt (or
        // malicious): indexing the reassembly buffer with it would panic.
        // Drop the connection instead.
        if hdr.len as usize > CHUNK_BYTES {
            log::warn!("agent {my_dc}: frame len {} exceeds chunk cap, dropping peer", hdr.len);
            break;
        }
        match protocol::read_full(&mut stream, &mut payload[..hdr.len as usize], &stop) {
            Ok(true) => {}
            _ => break,
        }
        // Probe chunks exist only to be measured by the sender: no
        // reassembly, no counters, no completion accounting.
        if hdr.coflow == PROBE_COFLOW {
            continue;
        }
        let key = (hdr.coflow, hdr.src_dc as usize);
        let mut done = false;
        {
            let mut inc = incoming.lock().unwrap();
            let entry = inc.entry(key).or_insert_with(|| {
                let counter = Arc::new(AtomicU64::new(0));
                rx_counters.lock().unwrap().insert(key, counter.clone());
                Incoming {
                    expected: u64::MAX,
                    frontier: 0,
                    pending: BTreeMap::new(),
                    received: counter,
                }
            });
            entry.received.fetch_add(hdr.len as u64, Ordering::Relaxed);
            // In-order delivery: advance the frontier, buffer the rest.
            if hdr.offset == entry.frontier {
                entry.frontier += hdr.len as u64;
                while let Some((&off, &len)) = entry.pending.first_key_value() {
                    if off == entry.frontier {
                        entry.frontier += len as u64;
                        entry.pending.remove(&off);
                    } else {
                        break;
                    }
                }
            } else if hdr.offset > entry.frontier {
                entry.pending.insert(hdr.offset, hdr.len);
            } // duplicates below the frontier are dropped
            if entry.frontier >= entry.expected {
                done = true;
                inc.remove(&key);
            }
        }
        if done {
            let msg = Json::from_pairs([
                ("op", Json::from("group_done")),
                ("coflow", hdr.coflow.into()),
                ("src", (hdr.src_dc as u64).into()),
                ("dst", my_dc.into()),
            ]);
            let mut tx = ctrl_tx.lock().unwrap();
            let _ = protocol::write_msg(&mut tx, &msg);
        }
    }
}
