//! Terra agent (§4.1, §5.1): per-datacenter daemon that transfers data on
//! behalf of jobs over persistent multipath TCP connections at
//! controller-assigned rates.
//!
//! Sender side: each outgoing FlowGroup transfer is striped across the k
//! persistent connections to the destination agent; a token bucket per
//! ⟨transfer, path⟩ enforces the controller's rate (the `tc` stand-in).
//! Receiver side: chunks arrive out of order across paths; the agent
//! buffers them and advances an in-order frontier, delivering only
//! contiguous data to the application (§5.1 "Handling WAN Latency
//! Heterogeneity") and reports FlowGroup completion to the controller.
//!
//! Fault tolerance: the agent survives the controller. When the control
//! channel goes silent past [`HEARTBEAT_DEADLINE`] the agent enters
//! *degraded mode* — a conservative local fair-share of the last-known
//! allocation envelope per destination — and keeps draining. Meanwhile a
//! session loop retries the controller address; on reconnect it sends a
//! `resync_state` report (live transfers with achieved/remaining bytes,
//! last-assigned rates, and telemetry samples buffered while down) so a
//! restarted controller can rebuild its world without restarting any
//! transfer from zero. Degraded mode ends when the new session's
//! `rates_full` baseline lands.

use super::protocol::{self, DataHeader, ResyncEntry, TelemetrySample, CHUNK_BYTES, PROBE_COFLOW};
use super::BYTES_PER_GBPS;
use crate::util::backoff::{Backoff, CircuitBreaker};
use crate::util::json::Json;
use std::collections::{BTreeMap, HashMap};
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// How often the sender flushes achieved-throughput samples to the
/// controller (`telemetry_report`).
const TELEMETRY_INTERVAL: Duration = Duration::from_millis(250);
/// Probe burst size (chunks) when the controller issues a `probe_request`.
const PROBE_CHUNKS: usize = 4;
/// Control-channel silence (no frame of any kind — the controller
/// heartbeats every ~500 ms even when idle) after which the agent assumes
/// the controller is gone and enters degraded mode.
const HEARTBEAT_DEADLINE: Duration = Duration::from_secs(2);
/// Fraction of the last-known per-destination allocation envelope that
/// degraded mode spends. Deliberately conservative: the envelope was
/// feasible when assigned, but the WAN may have degraded since, and
/// without the controller nobody re-checks feasibility.
const DEGRADED_SCALE: f64 = 0.5;
/// Controller-reconnect backoff bounds: the dial loop sleeps a seeded
/// exponential-with-jitter delay between attempts (see
/// [`crate::util::backoff`]) instead of a fixed pause, so a fleet losing
/// the same controller does not hammer it in lockstep the moment it
/// returns. The cap is kept small enough that chaos tests bound their
/// recovery waits.
const RECONNECT_BASE: Duration = Duration::from_millis(100);
const RECONNECT_MAX: Duration = Duration::from_secs(2);
/// Peer data-connection dial policy: consecutive failures trip a per-peer
/// circuit breaker (threshold [`crate::util::backoff::BREAKER_THRESHOLD`])
/// whose cooldowns follow the same seeded backoff schedule.
const PEER_DIAL_BASE: Duration = Duration::from_millis(100);
const PEER_DIAL_MAX: Duration = Duration::from_secs(2);
/// How often the sender thread tops up missing peer connections (a peer
/// that was down when the `peers` table arrived is re-dialed from here,
/// without controller involvement).
const PEER_TOPUP_INTERVAL: Duration = Duration::from_millis(100);
/// Consecutive zero-progress telemetry windows on an allocated path before
/// the stall watchdog flags the ⟨transfer, path⟩ as stalled in its samples
/// (4 × 250 ms ≈ 1 s of confirmed zero progress).
const STALL_WINDOWS: u32 = 4;
/// Cap on telemetry samples buffered while disconnected (oldest dropped);
/// they ship inside the `resync_state` report on reconnect.
const MAX_BUFFERED_SAMPLES: usize = 4096;

/// Process-wide count of poisoned-lock recoveries (see [`lock_recover`]).
static POISON_RECOVERIES: AtomicU64 = AtomicU64::new(0);

/// Lock a mutex, recovering from poisoning instead of propagating it. A
/// panicking helper thread used to poison `out`/`conns`/`ctrl_tx` and take
/// the whole agent down with it — precisely when degraded mode should be
/// engaging. The guarded maps are plain collections whose invariants hold
/// between statements, so the data is usable after a recovery; the event
/// is logged and counted rather than silently absorbed.
fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| {
        let n = POISON_RECOVERIES.fetch_add(1, Ordering::Relaxed) + 1;
        log::warn!("agent: recovered a poisoned lock (process-wide total {n})");
        e.into_inner()
    })
}

/// Process-wide count of locks recovered from poisoning (a panicked thread
/// died while holding one). Nonzero means a thread was lost to a panic but
/// the agent kept running.
pub fn lock_poison_recoveries() -> u64 {
    POISON_RECOVERIES.load(Ordering::Relaxed)
}

/// Writable half of the control channel; `None` while disconnected (sends
/// fail fast instead of writing into a dead socket).
type CtrlTx = Arc<Mutex<Option<TcpStream>>>;

/// Send one control frame if connected. Returns false when disconnected or
/// the write failed (the session loop will reconnect; callers buffer or
/// drop as appropriate).
fn ctrl_send(ctrl_tx: &CtrlTx, msg: &Json) -> bool {
    let mut guard = lock_recover(ctrl_tx);
    let Some(s) = guard.as_mut() else { return false };
    match protocol::write_msg(s, msg) {
        Ok(()) => true,
        Err(e) => {
            log::warn!("agent: control write failed ({e}); awaiting reconnect");
            *guard = None;
            false
        }
    }
}

/// Control traffic that could not be delivered while disconnected.
#[derive(Default)]
struct PendingCtrl {
    /// Telemetry samples captured while down (capped, oldest dropped);
    /// shipped inside the next `resync_state`.
    samples: Vec<Json>,
    /// Undeliverable event messages (`group_done`) replayed after resync —
    /// a completion observed during an outage must still reach the
    /// restarted controller or the coflow would never be marked done.
    msgs: Vec<Json>,
}

/// Sender-side state of one outgoing transfer (one FlowGroup direction).
struct Outgoing {
    coflow: u64,
    remaining: u64,
    offset: u64,
    /// Token-bucket budget (bytes) and *enforced* rate (Gbps) per path.
    /// Normally `rate == alloc`; degraded mode overwrites `rate` with a
    /// local fair-share while `alloc` keeps the controller's envelope.
    budget: Vec<f64>,
    rate: Vec<f64>,
    /// Last controller-assigned per-path rates (Gbps): the allocation
    /// envelope degraded mode must stay within, and what `resync_state`
    /// reports to a restarted controller.
    alloc: Vec<f64>,
    /// Bytes actually written per path since the last telemetry flush —
    /// the *achieved* throughput the controller's estimator feeds on.
    window: Vec<f64>,
    /// Full telemetry windows elapsed since the last rate change. A
    /// sample from a window the current rate did not span entirely
    /// (transfer or rate arrived mid-window) must not be compared against
    /// the allocation — the shortfall is startup, not the link.
    rate_windows: u32,
    /// Minimum-rate floor (Gbps) for stream-class transfers, 0 for every
    /// other class. Carried on the `transfer` op so degraded mode can keep
    /// honoring the guarantee locally: floors are reserved off the top of
    /// the degraded envelope before the batch fair-share.
    floor_gbps: f64,
    /// Stall watchdog: consecutive telemetry windows per path in which a
    /// live allocation moved zero bytes. At [`STALL_WINDOWS`] the path's
    /// samples carry the stall flag — affirmative outage evidence the
    /// controller's estimator treats as capacity-capped even at zero
    /// achieved throughput.
    stall_windows: Vec<u32>,
}

/// Data-plane dial state shared by the control handler (which learns peer
/// targets from the `peers` op) and the sender thread (which periodically
/// tops up missing connections): retained targets per destination plus a
/// per-peer circuit breaker over a seeded backoff schedule, so a dead peer
/// is re-dialed at a bounded, decorrelated rate instead of on every
/// control push.
struct PeerState {
    /// dst dc → (data address, connections wanted).
    targets: Mutex<HashMap<usize, (String, usize)>>,
    breakers: Mutex<HashMap<usize, CircuitBreaker>>,
    /// Monotone clock origin for breaker cooldowns.
    epoch: Instant,
}

impl PeerState {
    fn new() -> PeerState {
        PeerState {
            targets: Mutex::new(HashMap::new()),
            breakers: Mutex::new(HashMap::new()),
            epoch: Instant::now(),
        }
    }

    fn now_s(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }
}

/// Receiver-side reassembly state of one incoming transfer.
struct Incoming {
    expected: u64,
    /// In-order frontier: all bytes < frontier delivered to the app.
    frontier: u64,
    /// Out-of-order chunks keyed by offset (the paper buffers to a block
    /// device; we model it in memory).
    pending: BTreeMap<u64, u32>,
    /// Total bytes received (for throughput sampling).
    received: Arc<AtomicU64>,
}

/// A Terra agent. Spawn with [`Agent::spawn`]; threads run until
/// [`Agent::shutdown`].
pub struct Agent {
    pub dc: usize,
    pub data_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
    out: Arc<Mutex<HashMap<(u64, usize), Outgoing>>>,
    /// Persistent data connections per destination dc: one per path.
    conns: Arc<Mutex<HashMap<usize, Vec<TcpStream>>>>,
    /// Receive counters per (coflow, src_dc) for throughput sampling.
    rx_counters: Arc<Mutex<HashMap<(u64, usize), Arc<AtomicU64>>>>,
    /// True while draining on local fair-share rates without a controller.
    degraded: Arc<AtomicBool>,
    /// Where reconnect attempts go — re-read on every attempt, so a
    /// restarted controller on a new address is reachable once
    /// [`Agent::redirect_controller`] updates it (the DNS/VIP re-resolution
    /// stand-in; production agents would re-resolve a name).
    controller_addr: Arc<Mutex<std::net::SocketAddr>>,
}

impl Agent {
    /// Start an agent for datacenter `dc`, registering with the controller
    /// at `controller_addr`.
    pub fn spawn(dc: usize, controller_addr: std::net::SocketAddr) -> std::io::Result<Agent> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let data_addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let out: Arc<Mutex<HashMap<(u64, usize), Outgoing>>> = Arc::default();
        let conns: Arc<Mutex<HashMap<usize, Vec<TcpStream>>>> = Arc::default();
        let rx_counters: Arc<Mutex<HashMap<(u64, usize), Arc<AtomicU64>>>> = Arc::default();
        let incoming: Arc<Mutex<HashMap<(u64, usize), Incoming>>> = Arc::default();
        let pending: Arc<Mutex<PendingCtrl>> = Arc::default();
        let peers = Arc::new(PeerState::new());
        let degraded = Arc::new(AtomicBool::new(false));
        let ctrl_addr = Arc::new(Mutex::new(controller_addr));

        // Control channel: the first connection is made synchronously so
        // spawn fails fast when no controller is listening; later
        // reconnects happen inside the session loop.
        let mut ctrl = TcpStream::connect(controller_addr)?;
        protocol::write_msg(&mut ctrl, &hello_msg(dc, data_addr))?;
        ctrl.set_read_timeout(Some(Duration::from_millis(100)))?;
        let ctrl_tx: CtrlTx = Arc::new(Mutex::new(Some(ctrl.try_clone()?)));
        let last_rx = Arc::new(Mutex::new(Instant::now()));

        let mut threads = Vec::new();

        // Data listener: accept persistent connections from peers.
        {
            let stop = stop.clone();
            let incoming = incoming.clone();
            let rx_counters = rx_counters.clone();
            let ctrl_tx = ctrl_tx.clone();
            let pending = pending.clone();
            listener.set_nonblocking(true)?;
            threads.push(std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((s, _)) => {
                            s.set_nonblocking(false).ok();
                            let stop = stop.clone();
                            let incoming = incoming.clone();
                            let rx_counters = rx_counters.clone();
                            let ctrl_tx = ctrl_tx.clone();
                            let pending = pending.clone();
                            std::thread::spawn(move || {
                                recv_loop(s, dc, stop, incoming, rx_counters, ctrl_tx, pending);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            }));
        }

        // Control session loop: run the reader until the socket dies, then
        // reconnect (hello + resync_state) and run the next session. The
        // loop — not any single connection — is the agent's lifetime tie
        // to the controller.
        {
            let stop = stop.clone();
            let out = out.clone();
            let conns = conns.clone();
            let incoming = incoming.clone();
            let rx_counters = rx_counters.clone();
            let ctrl_tx = ctrl_tx.clone();
            let last_rx = last_rx.clone();
            let degraded = degraded.clone();
            let pending = pending.clone();
            let ctrl_addr = ctrl_addr.clone();
            let peers = peers.clone();
            threads.push(std::thread::spawn(move || {
                let mut stream = Some(ctrl);
                while !stop.load(Ordering::Relaxed) {
                    let s = match stream.take() {
                        Some(s) => s,
                        None => {
                            let Some(s) = reconnect(dc, data_addr, &ctrl_addr, &stop) else {
                                break; // stop raised while down
                            };
                            let Ok(tx_half) = s.try_clone() else { continue };
                            *lock_recover(&ctrl_tx) = Some(tx_half);
                            send_resync(dc, &out, &pending, &ctrl_tx);
                            s
                        }
                    };
                    *lock_recover(&last_rx) = Instant::now();
                    ctrl_session(
                        s, dc, &stop, &out, &conns, &incoming, &rx_counters, &ctrl_tx,
                        &last_rx, &degraded, &peers,
                    );
                    *lock_recover(&ctrl_tx) = None;
                }
            }));
        }

        // Sender: token-bucket pacing loop, periodic telemetry flushes
        // (achieved bytes per ⟨transfer, path⟩ → `telemetry_report`), and
        // the degraded-mode watchdog.
        {
            let stop = stop.clone();
            let out = out.clone();
            let conns = conns.clone();
            let ctrl_tx = ctrl_tx.clone();
            let last_rx = last_rx.clone();
            let degraded = degraded.clone();
            let pending = pending.clone();
            let peers = peers.clone();
            threads.push(std::thread::spawn(move || {
                let mut last = Instant::now();
                let mut last_report = Instant::now();
                let mut last_topup = Instant::now();
                let payload = vec![0u8; CHUNK_BYTES];
                while !stop.load(Ordering::Relaxed) {
                    std::thread::sleep(Duration::from_millis(4));
                    let now = Instant::now();
                    let dt = now.duration_since(last).as_secs_f64();
                    last = now;
                    send_tick(dc, dt, &payload, &out, &conns);
                    // Re-dial any missing peer connections (breaker-gated):
                    // a peer that was unreachable when its table entry
                    // arrived is wired up from here once it returns.
                    if now.duration_since(last_topup) >= PEER_TOPUP_INTERVAL {
                        last_topup = now;
                        top_up_peer_conns(dc, &peers, &conns);
                    }
                    // Watchdog: controller silent past the deadline (it
                    // heartbeats when idle, so silence means it is gone).
                    if !degraded.load(Ordering::Relaxed)
                        && lock_recover(&last_rx).elapsed() >= HEARTBEAT_DEADLINE
                    {
                        degraded.store(true, Ordering::Relaxed);
                        enter_degraded(dc, &out);
                    }
                    let window = now.duration_since(last_report);
                    if window >= TELEMETRY_INTERVAL {
                        last_report = now;
                        flush_telemetry(window.as_secs_f64(), &out, &ctrl_tx, &pending);
                    }
                }
            }));
        }

        Ok(Agent {
            dc,
            data_addr,
            stop,
            threads,
            out,
            conns,
            rx_counters,
            degraded,
            controller_addr: ctrl_addr,
        })
    }

    /// Bytes received so far for (coflow, src_dc) — throughput sampling for
    /// the failure case study (Fig 10).
    pub fn received_bytes(&self, coflow: u64, src_dc: usize) -> u64 {
        lock_recover(&self.rx_counters)
            .get(&(coflow, src_dc))
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Outstanding bytes still to send from this agent.
    pub fn backlog(&self) -> u64 {
        lock_recover(&self.out).values().map(|o| o.remaining).sum()
    }

    /// True while the agent is draining on local fair-share rates because
    /// the controller went silent past the heartbeat deadline.
    pub fn is_degraded(&self) -> bool {
        self.degraded.load(Ordering::Relaxed)
    }

    /// Point reconnect attempts at a new controller address (the DNS/VIP
    /// re-resolution stand-in: a restarted controller may listen
    /// elsewhere). Takes effect on the next attempt; an established
    /// session is not torn down.
    pub fn redirect_controller(&self, addr: std::net::SocketAddr) {
        *lock_recover(&self.controller_addr) = addr;
    }

    /// The (allocation envelope, enforced rate) vectors currently held for
    /// one outgoing transfer — the chaos tests use this to check that
    /// degraded-mode rates stay within the last-known envelope.
    pub fn outgoing_rates(&self, coflow: u64, dst: usize) -> Option<(Vec<f64>, Vec<f64>)> {
        lock_recover(&self.out).get(&(coflow, dst)).map(|o| (o.alloc.clone(), o.rate.clone()))
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Close data connections to unblock readers.
        lock_recover(&self.conns).clear();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn hello_msg(dc: usize, data_addr: std::net::SocketAddr) -> Json {
    Json::from_pairs([
        ("op", Json::from("hello")),
        ("dc", dc.into()),
        ("data_addr", data_addr.to_string().into()),
    ])
}

/// Retry the controller address until a connection with a delivered
/// `hello` exists (returned with the read timeout set) or stop is raised.
/// Attempts are paced by a seeded exponential backoff with jitter (fresh
/// schedule per outage, seeded from the dc id so a fleet decorrelates
/// deterministically), and the sleep is chunked so a raised stop flag is
/// honored within ~25 ms even mid-cooldown.
fn reconnect(
    dc: usize,
    data_addr: std::net::SocketAddr,
    ctrl_addr: &Arc<Mutex<std::net::SocketAddr>>,
    stop: &AtomicBool,
) -> Option<TcpStream> {
    let mut backoff = Backoff::new(0xA6E7 ^ dc as u64, RECONNECT_BASE, RECONNECT_MAX);
    loop {
        if stop.load(Ordering::Relaxed) {
            return None;
        }
        let addr = *lock_recover(ctrl_addr);
        if let Ok(mut s) = TcpStream::connect(addr) {
            s.set_nodelay(true).ok();
            if protocol::write_msg(&mut s, &hello_msg(dc, data_addr)).is_ok()
                && s.set_read_timeout(Some(Duration::from_millis(100))).is_ok()
            {
                log::info!(
                    "agent {dc}: reconnected to controller at {addr} \
                     (attempt {})",
                    backoff.attempts() + 1
                );
                return Some(s);
            }
        }
        let delay = backoff.next_delay();
        let t0 = Instant::now();
        while t0.elapsed() < delay && !stop.load(Ordering::Relaxed) {
            std::thread::sleep(Duration::from_millis(25));
        }
    }
}

/// Ship the agent's live world to a freshly (re)connected controller: one
/// `resync_state` with every in-flight outgoing transfer (achieved and
/// remaining bytes plus the last-assigned rates, sorted by (coflow, dst)
/// for determinism) and the telemetry buffered while down, followed by any
/// undeliverable completions observed during the outage.
fn send_resync(
    dc: usize,
    out: &Arc<Mutex<HashMap<(u64, usize), Outgoing>>>,
    pending: &Arc<Mutex<PendingCtrl>>,
    ctrl_tx: &CtrlTx,
) {
    let entries: Vec<Json> = {
        let o = lock_recover(out);
        let mut keys: Vec<(u64, usize)> = o.keys().copied().collect();
        keys.sort_unstable();
        keys.iter()
            .filter_map(|k| {
                let e = o.get(k)?;
                if e.remaining == 0 {
                    return None;
                }
                Some(
                    ResyncEntry {
                        coflow: k.0,
                        dst_dc: k.1,
                        remaining_bytes: e.remaining,
                        achieved_bytes: e.offset,
                        rates: e.alloc.clone(),
                    }
                    .to_json(),
                )
            })
            .collect()
    };
    let (samples, msgs) = {
        let mut p = lock_recover(pending);
        (std::mem::take(&mut p.samples), std::mem::take(&mut p.msgs))
    };
    let msg = Json::from_pairs([
        ("op", Json::from("resync_state")),
        ("dc", dc.into()),
        ("entries", Json::Arr(entries)),
        ("samples", Json::Arr(samples)),
    ]);
    if !ctrl_send(ctrl_tx, &msg) {
        // Session died under us; completions must survive to the next try.
        lock_recover(pending).msgs = msgs;
        return;
    }
    for (i, m) in msgs.iter().enumerate() {
        if !ctrl_send(ctrl_tx, m) {
            lock_recover(pending).msgs = msgs[i..].to_vec();
            return;
        }
    }
}

/// One control session: read controller frames until the socket dies or
/// stop is raised. Tracks the delta-protocol sequence number; a gap (lost
/// or reordered push) triggers a `sync_request`, answered by a
/// `rates_full` that rebaselines. Every inbound frame — heartbeats
/// included — feeds the degraded-mode watchdog via `last_rx`.
#[allow(clippy::too_many_arguments)]
fn ctrl_session(
    mut ctrl: TcpStream,
    dc: usize,
    stop: &Arc<AtomicBool>,
    out: &Arc<Mutex<HashMap<(u64, usize), Outgoing>>>,
    conns: &Arc<Mutex<HashMap<usize, Vec<TcpStream>>>>,
    incoming: &Arc<Mutex<HashMap<(u64, usize), Incoming>>>,
    rx_counters: &Arc<Mutex<HashMap<(u64, usize), Arc<AtomicU64>>>>,
    ctrl_tx: &CtrlTx,
    last_rx: &Arc<Mutex<Instant>>,
    degraded: &Arc<AtomicBool>,
    peers: &Arc<PeerState>,
) {
    // None until the first rates_full lands.
    let mut last_seq: Option<u64> = None;
    while !stop.load(Ordering::Relaxed) {
        let msg = match protocol::read_msg_resumable(&mut ctrl, stop) {
            Ok(Some(m)) => m,
            _ => return,
        };
        *lock_recover(last_rx) = Instant::now();
        match msg.get("op").and_then(|o| o.as_str()) {
            Some("rates_full") => {
                apply_rates_full(&msg, out, conns);
                last_seq = msg.get("seq").and_then(|x| x.as_u64());
                // The baseline re-anchors enforcement to the controller:
                // degraded fair-share ends here.
                if degraded.swap(false, Ordering::Relaxed) {
                    log::info!("agent {dc}: rates_full received, leaving degraded mode");
                }
            }
            Some("rates_delta") => {
                let seq = msg.get("seq").and_then(|x| x.as_u64());
                match (last_seq, seq) {
                    (Some(prev), Some(s)) if s == prev + 1 => {
                        apply_rates_delta(&msg, out, conns);
                        last_seq = Some(s);
                    }
                    _ => {
                        // Gap or unsynced: drop the delta and ask for the
                        // full table.
                        log::warn!(
                            "agent {dc}: rate-delta gap \
                             ({last_seq:?} -> {seq:?}), requesting full sync"
                        );
                        let req = Json::from_pairs([("op", Json::from("sync_request"))]);
                        ctrl_send(ctrl_tx, &req);
                    }
                }
            }
            Some("probe_request") => handle_probe(dc, &msg, conns, ctrl_tx),
            Some("hb") => {} // heartbeat: last_rx update above is the point
            _ => handle_ctrl(dc, &msg, out, conns, incoming, rx_counters, peers),
        }
    }
}

/// Enter degraded mode: replace every active transfer's enforced rates
/// with a local allocation carved from the last-known per-destination
/// envelope. For each destination, the envelope is the per-path sum of
/// the controller-assigned rates across this agent's active transfers,
/// and the degraded budget is [`DEGRADED_SCALE`] × its total — strictly
/// inside what the controller last proved feasible. Stream floors are
/// reserved off the top of that budget first (each floored transfer gets
/// its floor, spread across paths proportionally to the envelope); the
/// remaining budget is fair-shared among the floorless transfers. When
/// the budget cannot cover the floors, they all scale down by the same
/// factor (logged) — the guarantee degrades gracefully instead of one
/// stream starving another. Transfers the controller never rated stay at
/// zero (nothing is known to be safe for them).
fn enter_degraded(dc: usize, out: &Arc<Mutex<HashMap<(u64, usize), Outgoing>>>) {
    #[derive(Default)]
    struct DstEnv {
        /// Per-path summed controller allocation.
        env: Vec<f64>,
        /// Active transfers without a rate floor.
        unfloored: usize,
        /// Summed rate floors of active floored transfers.
        floors: f64,
    }
    let mut o = lock_recover(out);
    let mut envelope: HashMap<usize, DstEnv> = HashMap::new();
    for ((_, dst), e) in o.iter() {
        if e.remaining == 0 {
            continue;
        }
        let d = envelope.entry(*dst).or_default();
        if d.env.len() < e.alloc.len() {
            d.env.resize(e.alloc.len(), 0.0);
        }
        for (p, r) in e.alloc.iter().enumerate() {
            d.env[p] += r.max(0.0);
        }
        if e.floor_gbps > 0.0 {
            d.floors += e.floor_gbps;
        } else {
            d.unfloored += 1;
        }
    }
    for (dst, d) in envelope.iter() {
        let budget: f64 = d.env.iter().sum::<f64>() * DEGRADED_SCALE;
        if d.floors > budget + 1e-12 {
            log::warn!(
                "agent {dc}: degraded budget to dc {dst} ({budget:.3} Gbps) cannot cover \
                 stream floors ({:.3} Gbps); floors scaled down proportionally",
                d.floors
            );
        }
    }
    let mut active = 0usize;
    for ((_, dst), e) in o.iter_mut() {
        if e.remaining == 0 {
            continue;
        }
        let Some(d) = envelope.get(dst) else { continue };
        let env_total: f64 = d.env.iter().sum();
        let share: Vec<f64> = if env_total <= 0.0 {
            vec![0.0; d.env.len()]
        } else {
            let budget = env_total * DEGRADED_SCALE;
            let floor_scale = if d.floors > budget { budget / d.floors } else { 1.0 };
            // This transfer's total degraded rate: its (possibly scaled)
            // floor, or an equal share of whatever the floors left over.
            let total = if e.floor_gbps > 0.0 {
                e.floor_gbps * floor_scale
            } else {
                (budget - d.floors * floor_scale).max(0.0) / d.unfloored.max(1) as f64
            };
            d.env.iter().map(|c| c / env_total * total).collect()
        };
        if e.budget.len() < share.len() {
            e.budget.resize(share.len(), 0.0);
        }
        if e.window.len() < share.len() {
            e.window.resize(share.len(), 0.0);
        }
        if e.stall_windows.len() < share.len() {
            e.stall_windows.resize(share.len(), 0);
        }
        e.rate = share;
        e.rate_windows = 0;
        active += 1;
    }
    log::warn!(
        "agent {dc}: controller silent for {HEARTBEAT_DEADLINE:?}, degraded fair-share \
         engaged for {active} active transfers"
    );
}

/// Apply a controller command.
fn handle_ctrl(
    dc: usize,
    msg: &Json,
    out: &Arc<Mutex<HashMap<(u64, usize), Outgoing>>>,
    conns: &Arc<Mutex<HashMap<usize, Vec<TcpStream>>>>,
    incoming: &Arc<Mutex<HashMap<(u64, usize), Incoming>>>,
    rx_counters: &Arc<Mutex<HashMap<(u64, usize), Arc<AtomicU64>>>>,
    peers: &Arc<PeerState>,
) {
    match msg.get("op").and_then(|o| o.as_str()) {
        // Record the peer targets and fill the pools. Dialing is delegated
        // to `top_up_peer_conns` so a peer that is down when the push
        // arrives (its breaker open) gets retried from the sender thread
        // instead of leaving the pool short forever.
        Some("peers") => {
            if let Some(arr) = msg.get("peers").and_then(|p| p.as_arr()) {
                {
                    let mut t = lock_recover(&peers.targets);
                    for peer in arr {
                        let (Some(dst), Some(addr), Some(k)) = (
                            peer.get("dc").and_then(|x| x.as_u64()),
                            peer.get("addr").and_then(|x| x.as_str()),
                            peer.get("k").and_then(|x| x.as_u64()),
                        ) else {
                            log::warn!("agent: malformed peer entry dropped");
                            continue;
                        };
                        // Sanity-cap k: a corrupt value must not spin this
                        // thread opening unbounded connections.
                        t.insert(dst as usize, (addr.to_string(), k.min(1024) as usize));
                    }
                }
                top_up_peer_conns(dc, peers, conns);
            }
        }
        // Start an outgoing transfer.
        Some("transfer") => {
            let (Some(coflow), Some(dst), Some(bytes)) = (
                msg.get("coflow").and_then(|x| x.as_u64()),
                msg.get("dst").and_then(|x| x.as_u64()),
                msg.get("bytes").and_then(|x| x.as_u64()),
            ) else {
                return;
            };
            let k = lock_recover(conns).get(&(dst as usize)).map(|v| v.len()).unwrap_or(0);
            let reset = msg.get("reset").and_then(|x| x.as_bool()).unwrap_or(false);
            let mut o = lock_recover(out);
            let e = o.entry((coflow, dst as usize)).or_insert(Outgoing {
                coflow,
                remaining: 0,
                offset: 0,
                budget: vec![0.0; k],
                rate: vec![0.0; k],
                alloc: vec![0.0; k],
                window: vec![0.0; k],
                rate_windows: 0,
                stall_windows: vec![0; k],
                floor_gbps: 0.0,
            });
            if reset {
                // Re-arm after an endpoint restart: the controller replaces
                // the transfer outright (offsets restart at 0 and the peer's
                // reassembly state was reset in lockstep), so adding onto a
                // survivor's remaining/offset would double-count.
                e.remaining = bytes;
                e.offset = 0;
                e.rate_windows = 0;
                for w in e.stall_windows.iter_mut() {
                    *w = 0;
                }
            } else {
                e.remaining += bytes;
            }
            // Stream-class transfers carry their rate floor; sanitize
            // network-supplied values the same way rates are.
            let floor = msg.get("floor_gbps").and_then(|x| x.as_f64()).unwrap_or(0.0);
            if floor.is_finite() && floor > 0.0 {
                e.floor_gbps = floor;
            }
        }
        // Expect an incoming transfer (receiver side).
        Some("expect") => {
            let (Some(coflow), Some(src), Some(bytes)) = (
                msg.get("coflow").and_then(|x| x.as_u64()),
                msg.get("src").and_then(|x| x.as_u64()),
                msg.get("bytes").and_then(|x| x.as_u64()),
            ) else {
                return;
            };
            let counter = Arc::new(AtomicU64::new(0));
            lock_recover(rx_counters).insert((coflow, src as usize), counter.clone());
            let mut inc = lock_recover(incoming);
            if msg.get("reset").and_then(|x| x.as_bool()).unwrap_or(false) {
                // Re-arm after an endpoint restart: the sender restarts
                // offsets at 0, so a surviving frontier > 0 would drop its
                // chunks forever. Replace the reassembly state wholesale —
                // the controller re-sized `bytes` to the remaining work.
                inc.insert(
                    (coflow, src as usize),
                    Incoming {
                        expected: bytes,
                        frontier: 0,
                        pending: BTreeMap::new(),
                        received: counter,
                    },
                );
            } else {
                let e = inc.entry((coflow, src as usize)).or_insert(Incoming {
                    expected: 0,
                    frontier: 0,
                    pending: BTreeMap::new(),
                    received: counter,
                });
                // Saturating: if data raced ahead of the expectation the
                // entry already exists with the unsolicited u64::MAX
                // sentinel, and a plain add would overflow.
                e.expected = e.expected.saturating_add(bytes);
            }
        }
        // Update rates for (coflow, dst): one rate per path, Gbps (legacy
        // single-entry form; delta pushes batch the same payload).
        Some("rates") => {
            apply_rate_entry(msg, out);
            trim_conns(out, conns);
        }
        _ => {}
    }
}

/// Bring every per-destination data pool up to its wanted size, gated by
/// the peer's circuit breaker: after [`BREAKER_THRESHOLD`] consecutive
/// dial failures the peer is skipped until its backoff cooldown expires,
/// then probed with a single dial. Breakers are seeded per (src, dst) so
/// a fleet of agents recovering from the same partition decorrelates
/// deterministically. Pools also shrink here when the wanted path count
/// went down, or idle sockets leak and `send_tick` keeps addressing stale
/// path indices. The peers lock is never held across a dial.
fn top_up_peer_conns(
    my_dc: usize,
    peers: &PeerState,
    conns: &Arc<Mutex<HashMap<usize, Vec<TcpStream>>>>,
) {
    let mut targets: Vec<(usize, String, usize)> = {
        let t = lock_recover(&peers.targets);
        t.iter().map(|(dst, (addr, k))| (*dst, addr.clone(), *k)).collect()
    };
    targets.sort_unstable_by_key(|&(dst, _, _)| dst);
    for (dst, addr, k) in targets {
        {
            let mut c = lock_recover(conns);
            let entry = c.entry(dst).or_default();
            entry.truncate(k);
            if entry.len() >= k {
                continue;
            }
        }
        loop {
            let deficit = {
                let c = lock_recover(conns);
                k.saturating_sub(c.get(&dst).map(|v| v.len()).unwrap_or(0))
            };
            if deficit == 0 {
                break;
            }
            let now = peers.now_s();
            {
                let mut b = lock_recover(&peers.breakers);
                let brk = b.entry(dst).or_insert_with(|| {
                    CircuitBreaker::new(
                        0x9eed ^ ((my_dc as u64) << 32) ^ dst as u64,
                        PEER_DIAL_BASE,
                        PEER_DIAL_MAX,
                    )
                });
                if !brk.allow(now) {
                    break; // cooling down; the periodic top-up retries
                }
            }
            match TcpStream::connect(addr.as_str()) {
                Ok(s) => {
                    s.set_nodelay(true).ok();
                    lock_recover(conns).entry(dst).or_default().push(s);
                    if let Some(b) = lock_recover(&peers.breakers).get_mut(&dst) {
                        b.record_success();
                    }
                }
                Err(e) => {
                    log::warn!("agent {my_dc}: connect {addr}: {e}");
                    let now = peers.now_s();
                    if let Some(b) = lock_recover(&peers.breakers).get_mut(&dst) {
                        b.record_failure(now);
                    }
                    break;
                }
            }
        }
    }
}

/// Apply one (coflow, dst, rates) entry — shared by the legacy `rates` op,
/// `rates_delta` updates, and `rates_full` entries. Non-finite or negative
/// rates from a (possibly corrupt) peer sanitize to 0 rather than feeding
/// the token buckets garbage.
///
/// The vector is stored exactly as sent — **not** truncated to the number
/// of currently-open peer connections. Deltas are pushed once, so a rate
/// vector cut down to an early `k = 0` (connections not yet established)
/// would never be repaired by a rebroadcast the way the legacy per-round
/// push repaired it; `send_tick` instead pairs rates with whatever
/// connections exist at each tick.
fn apply_rate_entry(entry: &Json, out: &Arc<Mutex<HashMap<(u64, usize), Outgoing>>>) {
    let (Some(coflow), Some(dst), Some(rates)) = (
        entry.get("coflow").and_then(|x| x.as_u64()),
        entry.get("dst").and_then(|x| x.as_u64()),
        entry.get("rates").and_then(|x| x.as_arr()),
    ) else {
        log::warn!("agent: malformed rate entry dropped");
        return;
    };
    let mut o = lock_recover(out);
    if let Some(e) = o.get_mut(&(coflow, dst as usize)) {
        let new_rate: Vec<f64> = rates
            .iter()
            .map(|r| r.as_f64().unwrap_or(0.0))
            .map(|r| if r.is_finite() && r > 0.0 { r } else { 0.0 })
            .collect();
        // The sample-stability clock restarts only on a genuine rate
        // change; a redundant re-push (full sync after reconnect) must
        // not suppress another window of capacity-capped evidence.
        if new_rate != e.rate {
            e.rate_windows = 0;
            e.rate = new_rate.clone();
        }
        // A controller push is by definition the new allocation envelope.
        e.alloc = new_rate;
        if e.budget.len() < e.rate.len() {
            e.budget.resize(e.rate.len(), 0.0);
        }
        if e.window.len() < e.rate.len() {
            e.window.resize(e.rate.len(), 0.0);
        }
        if e.stall_windows.len() < e.rate.len() {
            e.stall_windows.resize(e.rate.len(), 0);
        }
    }
}

/// Shrink per-destination connection pools a structural path change left
/// oversized: the pool trims to the longest rate vector any transfer to
/// that destination currently holds (the controller sizes rate vectors to
/// the live path count). Destinations with no rated transfer are left
/// alone — their pools may still carry probes.
fn trim_conns(
    out: &Arc<Mutex<HashMap<(u64, usize), Outgoing>>>,
    conns: &Arc<Mutex<HashMap<usize, Vec<TcpStream>>>>,
) {
    let wants: HashMap<usize, usize> = {
        let o = lock_recover(out);
        let mut w: HashMap<usize, usize> = HashMap::new();
        for ((_, dst), e) in o.iter() {
            if e.rate.is_empty() {
                continue;
            }
            let want = w.entry(*dst).or_insert(0);
            *want = (*want).max(e.rate.len());
        }
        w
    };
    let mut c = lock_recover(conns);
    for (dst, want) in wants {
        if want == 0 {
            continue;
        }
        if let Some(streams) = c.get_mut(&dst) {
            if streams.len() > want {
                log::info!(
                    "agent: trimming pool to dc {dst} from {} to {want} paths",
                    streams.len()
                );
                streams.truncate(want);
            }
        }
    }
}

/// `rates_delta`: apply the changed entries, zero the revoked ones.
fn apply_rates_delta(
    msg: &Json,
    out: &Arc<Mutex<HashMap<(u64, usize), Outgoing>>>,
    conns: &Arc<Mutex<HashMap<usize, Vec<TcpStream>>>>,
) {
    if let Some(updates) = msg.get("updates").and_then(|x| x.as_arr()) {
        for e in updates {
            apply_rate_entry(e, out);
        }
    }
    if let Some(revoke) = msg.get("revoke").and_then(|x| x.as_arr()) {
        let mut o = lock_recover(out);
        for r in revoke {
            let (Some(coflow), Some(dst)) = (
                r.get("coflow").and_then(|x| x.as_u64()),
                r.get("dst").and_then(|x| x.as_u64()),
            ) else {
                continue;
            };
            if let Some(e) = o.get_mut(&(coflow, dst as usize)) {
                for rate in &mut e.rate {
                    *rate = 0.0;
                }
                for rate in &mut e.alloc {
                    *rate = 0.0;
                }
            }
        }
    }
    trim_conns(out, conns);
}

/// `rates_full`: rebaseline — zero every held rate, then apply the full
/// table (entries absent from it stay revoked).
fn apply_rates_full(
    msg: &Json,
    out: &Arc<Mutex<HashMap<(u64, usize), Outgoing>>>,
    conns: &Arc<Mutex<HashMap<usize, Vec<TcpStream>>>>,
) {
    {
        let mut o = lock_recover(out);
        for e in o.values_mut() {
            for rate in &mut e.rate {
                *rate = 0.0;
            }
            for rate in &mut e.alloc {
                *rate = 0.0;
            }
        }
    }
    if let Some(entries) = msg.get("entries").and_then(|x| x.as_arr()) {
        for e in entries {
            apply_rate_entry(e, out);
        }
    }
    trim_conns(out, conns);
}

/// One pacing tick: move token-bucket budget into sent chunks.
fn send_tick(
    src_dc: usize,
    dt: f64,
    payload: &[u8],
    out: &Arc<Mutex<HashMap<(u64, usize), Outgoing>>>,
    conns: &Arc<Mutex<HashMap<usize, Vec<TcpStream>>>>,
) {
    let mut out = lock_recover(out);
    let mut conns = lock_recover(conns);
    for ((_, dst), o) in out.iter_mut() {
        if o.remaining == 0 {
            continue;
        }
        let Some(streams) = conns.get_mut(dst) else { continue };
        for (p, stream) in streams.iter_mut().enumerate() {
            if o.remaining == 0 {
                break;
            }
            let rate_bps = o.rate.get(p).copied().unwrap_or(0.0) * BYTES_PER_GBPS;
            if rate_bps <= 0.0 {
                continue;
            }
            // Connections can outnumber the budget vector when peers came
            // up after the transfer/rates arrived; grow it on demand.
            if o.budget.len() <= p {
                o.budget.resize(p + 1, 0.0);
            }
            if o.window.len() <= p {
                o.window.resize(p + 1, 0.0);
            }
            if o.stall_windows.len() <= p {
                o.stall_windows.resize(p + 1, 0);
            }
            // Cap the bucket at one tick's worth plus a chunk to avoid
            // long-idle bursts defeating the shaper.
            o.budget[p] = (o.budget[p] + rate_bps * dt).min(rate_bps * 0.1 + CHUNK_BYTES as f64);
            while o.budget[p] >= 1.0 && o.remaining > 0 {
                let len = (CHUNK_BYTES as u64).min(o.remaining).min(o.budget[p] as u64);
                if len == 0 {
                    break;
                }
                let hdr = DataHeader {
                    coflow: o.coflow,
                    src_dc: src_dc as u32,
                    offset: o.offset,
                    len: len as u32,
                };
                if stream.write_all(&hdr.encode()).is_err()
                    || stream.write_all(&payload[..len as usize]).is_err()
                {
                    break;
                }
                o.offset += len;
                o.remaining -= len;
                o.budget[p] -= len as f64;
                o.window[p] += len as f64;
            }
        }
    }
    out.retain(|_, o| o.remaining > 0 || o.offset == 0);
}

/// Flush the achieved-bytes windows as a `telemetry_report`: one sample
/// per ⟨transfer, path⟩ that was allocated a rate or moved bytes this
/// window. Rates are already in emulated Gbps, so achieved bytes convert
/// through [`BYTES_PER_GBPS`] for apples-to-apples comparison. A report
/// goes out every interval even with zero samples — the heartbeat is what
/// drives the controller's staleness scan, so an idle agent must keep
/// reporting or its edges could never be probed. While disconnected the
/// samples are buffered (capped) and ship inside the next `resync_state`,
/// so a restarted controller inherits the evidence gathered during its
/// outage.
fn flush_telemetry(
    window_s: f64,
    out: &Arc<Mutex<HashMap<(u64, usize), Outgoing>>>,
    ctrl_tx: &CtrlTx,
    pending: &Arc<Mutex<PendingCtrl>>,
) {
    if window_s <= 0.0 {
        return;
    }
    let mut samples: Vec<Json> = Vec::new();
    {
        let mut o = lock_recover(out);
        for ((coflow, dst), e) in o.iter_mut() {
            // Only a window the current rate spanned entirely may be
            // compared against the allocation; otherwise the sample is a
            // lower bound only (alloc = 0 → the controller cannot read a
            // startup shortfall as link capacity).
            let stable = e.rate_windows > 0;
            e.rate_windows = e.rate_windows.saturating_add(1);
            if e.stall_windows.len() < e.window.len() {
                e.stall_windows.resize(e.window.len(), 0);
            }
            for p in 0..e.window.len() {
                let achieved = e.window[p];
                let alloc = e.rate.get(p).copied().unwrap_or(0.0);
                e.window[p] = 0.0;
                // Stall watchdog: a live allocation with work left that
                // moved zero bytes for STALL_WINDOWS consecutive stable
                // windows flags the sample, so the controller can treat the
                // path as capped even though achieved-at-zero evidence is
                // otherwise discarded (the gray-outage case).
                let stalled = if stable && achieved <= 0.0 && alloc > 0.0 && e.remaining > 0 {
                    e.stall_windows[p] = e.stall_windows[p].saturating_add(1);
                    e.stall_windows[p] >= STALL_WINDOWS
                } else {
                    e.stall_windows[p] = 0;
                    false
                };
                if achieved <= 0.0 && alloc <= 0.0 {
                    continue;
                }
                samples.push(
                    TelemetrySample {
                        coflow: *coflow,
                        dst_dc: *dst,
                        path: p,
                        gbps: achieved / window_s / BYTES_PER_GBPS,
                        alloc_gbps: if stable { alloc } else { 0.0 },
                        probe: false,
                        stalled,
                    }
                    .to_json(),
                );
            }
        }
    }
    let msg = Json::from_pairs([
        ("op", Json::from("telemetry_report")),
        ("samples", Json::Arr(samples.clone())),
    ]);
    if !ctrl_send(ctrl_tx, &msg) && !samples.is_empty() {
        let mut p = lock_recover(pending);
        p.samples.extend(samples);
        if p.samples.len() > MAX_BUFFERED_SAMPLES {
            let excess = p.samples.len() - MAX_BUFFERED_SAMPLES;
            p.samples.drain(..excess);
        }
    }
}

/// Controller-requested active probe: burst a few probe chunks (reserved
/// coflow id [`PROBE_COFLOW`], dropped by the receiver) on one persistent
/// connection and report the measured drain rate. On loopback this is an
/// optimistic upper bound (the kernel buffers absorb the burst); the
/// controller clamps probe readings to the edge's provisioned base
/// capacity before fusing them.
fn handle_probe(
    src_dc: usize,
    msg: &Json,
    conns: &Arc<Mutex<HashMap<usize, Vec<TcpStream>>>>,
    ctrl_tx: &CtrlTx,
) {
    let (Some(dst), Some(path)) = (
        msg.get("dst").and_then(|x| x.as_u64()),
        msg.get("path").and_then(|x| x.as_u64()),
    ) else {
        log::warn!("agent {src_dc}: malformed probe_request dropped");
        return;
    };
    let chunks =
        msg.get("chunks").and_then(|x| x.as_u64()).unwrap_or(PROBE_CHUNKS as u64).clamp(1, 64);
    let payload = vec![0u8; CHUNK_BYTES];
    let gbps = {
        let mut c = lock_recover(conns);
        let Some(stream) =
            c.get_mut(&(dst as usize)).and_then(|v| v.get_mut(path as usize))
        else {
            return; // no such connection (yet); the edge stays stale
        };
        let t0 = Instant::now();
        for i in 0..chunks {
            let hdr = DataHeader {
                coflow: PROBE_COFLOW,
                src_dc: src_dc as u32,
                offset: i * CHUNK_BYTES as u64,
                len: CHUNK_BYTES as u32,
            };
            if stream.write_all(&hdr.encode()).is_err()
                || stream.write_all(&payload).is_err()
            {
                return;
            }
        }
        if stream.flush().is_err() {
            return;
        }
        let dt = t0.elapsed().as_secs_f64().max(1e-9);
        (chunks as f64 * CHUNK_BYTES as f64) / dt / BYTES_PER_GBPS
    };
    let sample = TelemetrySample {
        coflow: PROBE_COFLOW,
        dst_dc: dst as usize,
        path: path as usize,
        gbps,
        alloc_gbps: 0.0,
        probe: true,
        stalled: false,
    };
    let msg = Json::from_pairs([
        ("op", Json::from("telemetry_report")),
        ("samples", Json::Arr(vec![sample.to_json()])),
    ]);
    // Probe readings are transient; if disconnected they are simply lost.
    ctrl_send(ctrl_tx, &msg);
}

/// Receive loop for one persistent data connection.
fn recv_loop(
    mut stream: TcpStream,
    my_dc: usize,
    stop: Arc<AtomicBool>,
    incoming: Arc<Mutex<HashMap<(u64, usize), Incoming>>>,
    rx_counters: Arc<Mutex<HashMap<(u64, usize), Arc<AtomicU64>>>>,
    ctrl_tx: CtrlTx,
    pending: Arc<Mutex<PendingCtrl>>,
) {
    let mut hdr_buf = [0u8; DataHeader::SIZE];
    let mut payload = vec![0u8; CHUNK_BYTES];
    stream.set_read_timeout(Some(Duration::from_millis(100))).ok();
    while !stop.load(Ordering::Relaxed) {
        match protocol::read_full(&mut stream, &mut hdr_buf, &stop) {
            Ok(true) => {}
            _ => break,
        }
        let Ok(hdr) = DataHeader::decode(&hdr_buf) else { break };
        // A frame claiming more than the chunk size is corrupt (or
        // malicious): indexing the reassembly buffer with it would panic.
        // Drop the connection instead.
        if hdr.len as usize > CHUNK_BYTES {
            log::warn!("agent {my_dc}: frame len {} exceeds chunk cap, dropping peer", hdr.len);
            break;
        }
        match protocol::read_full(&mut stream, &mut payload[..hdr.len as usize], &stop) {
            Ok(true) => {}
            _ => break,
        }
        // Probe chunks exist only to be measured by the sender: no
        // reassembly, no counters, no completion accounting.
        if hdr.coflow == PROBE_COFLOW {
            continue;
        }
        let key = (hdr.coflow, hdr.src_dc as usize);
        let mut done = false;
        {
            let mut inc = lock_recover(&incoming);
            let entry = inc.entry(key).or_insert_with(|| {
                let counter = Arc::new(AtomicU64::new(0));
                lock_recover(&rx_counters).insert(key, counter.clone());
                Incoming {
                    expected: u64::MAX,
                    frontier: 0,
                    pending: BTreeMap::new(),
                    received: counter,
                }
            });
            entry.received.fetch_add(hdr.len as u64, Ordering::Relaxed);
            // In-order delivery: advance the frontier, buffer the rest.
            if hdr.offset == entry.frontier {
                entry.frontier += hdr.len as u64;
                while let Some((&off, &len)) = entry.pending.first_key_value() {
                    if off == entry.frontier {
                        entry.frontier += len as u64;
                        entry.pending.remove(&off);
                    } else {
                        break;
                    }
                }
            } else if hdr.offset > entry.frontier {
                entry.pending.insert(hdr.offset, hdr.len);
            } // duplicates below the frontier are dropped
            if entry.frontier >= entry.expected {
                done = true;
                inc.remove(&key);
            }
        }
        if done {
            let msg = Json::from_pairs([
                ("op", Json::from("group_done")),
                ("coflow", hdr.coflow.into()),
                ("src", (hdr.src_dc as u64).into()),
                ("dst", my_dc.into()),
            ]);
            // A completion during a controller outage must not vanish: it
            // is buffered and replayed right after the resync report.
            if !ctrl_send(&ctrl_tx, &msg) {
                lock_recover(&pending).msgs.push(msg);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_outgoing(remaining: u64, alloc: Vec<f64>) -> Outgoing {
        let k = alloc.len();
        Outgoing {
            coflow: 1,
            remaining,
            offset: 0,
            budget: vec![0.0; k],
            rate: alloc.clone(),
            alloc,
            window: vec![0.0; k],
            rate_windows: 0,
            stall_windows: vec![0; k],
            floor_gbps: 0.0,
        }
    }

    /// Regression (satellite of the crash-recovery issue): a helper thread
    /// panicking while holding `out` used to poison the lock and kill every
    /// subsequent accessor — exactly when degraded mode should engage. The
    /// drain path must survive and the recovery must be counted.
    #[test]
    fn poisoned_lock_is_recovered_not_fatal() {
        let out: Arc<Mutex<HashMap<(u64, usize), Outgoing>>> = Arc::default();
        out.lock().unwrap().insert((1, 1), mk_outgoing(1 << 20, vec![1.0]));
        let before = lock_poison_recoveries();
        let poisoner = out.clone();
        let _ = std::thread::spawn(move || {
            let _guard = poisoner.lock().unwrap();
            panic!("simulated sender-thread panic while holding the out lock");
        })
        .join();
        assert!(out.lock().is_err(), "lock should be poisoned by the panicked thread");
        // The drain loop's tick path must keep working on the same data.
        let conns: Arc<Mutex<HashMap<usize, Vec<TcpStream>>>> = Arc::default();
        let payload = vec![0u8; CHUNK_BYTES];
        send_tick(0, 0.004, &payload, &out, &conns);
        assert_eq!(lock_recover(&out).len(), 1, "transfer state survived the poison");
        assert!(
            lock_poison_recoveries() > before,
            "recovery must be observable via the counter"
        );
    }

    /// Degraded fair-share stays strictly within the last-known allocation
    /// envelope: per path, the sum of enforced rates across transfers to a
    /// destination is DEGRADED_SCALE × the summed controller allocation.
    #[test]
    fn degraded_rates_are_fair_share_within_envelope() {
        let out: Arc<Mutex<HashMap<(u64, usize), Outgoing>>> = Arc::default();
        {
            let mut o = out.lock().unwrap();
            o.insert((1, 2), mk_outgoing(1 << 20, vec![4.0, 2.0]));
            o.insert((7, 2), mk_outgoing(1 << 20, vec![2.0, 0.0]));
            // Finished transfer: must not receive degraded rate.
            o.insert((9, 2), mk_outgoing(0, vec![8.0, 8.0]));
            // Other destination, never rated: stays at zero.
            o.insert((1, 3), mk_outgoing(1 << 20, vec![0.0]));
        }
        enter_degraded(0, &out);
        let o = out.lock().unwrap();
        // Envelope to dc 2 is [6, 2] over 2 active transfers: each gets
        // [6/2, 2/2] × 0.5 = [1.5, 0.5].
        for key in [(1u64, 2usize), (7, 2)] {
            assert_eq!(o[&key].rate, vec![1.5, 0.5], "fair share for {key:?}");
            // Envelope itself is untouched (needed for resync reporting).
            assert!(o[&key].alloc.iter().sum::<f64>() > 0.0);
        }
        let total: f64 = [(1u64, 2usize), (7, 2)].iter().map(|k| o[k].rate[0]).sum();
        assert!(total <= 6.0 * DEGRADED_SCALE + 1e-12, "within envelope: {total}");
        assert_eq!(o[&(9, 2)].rate, vec![8.0, 8.0], "finished transfer untouched");
        assert_eq!(o[&(1, 3)].rate, vec![0.0], "unrated transfer stays silent");
    }

    /// Degraded mode honors stream floors locally: the floor comes off the
    /// top of the degraded budget, the batch transfer splits the surplus,
    /// and everything stays inside DEGRADED_SCALE × envelope.
    #[test]
    fn degraded_floors_reserved_before_fair_share() {
        let out: Arc<Mutex<HashMap<(u64, usize), Outgoing>>> = Arc::default();
        {
            let mut o = out.lock().unwrap();
            let mut stream = mk_outgoing(1 << 20, vec![4.0, 2.0]);
            stream.floor_gbps = 2.5;
            o.insert((1, 2), stream);
            o.insert((7, 2), mk_outgoing(1 << 20, vec![2.0, 0.0]));
        }
        enter_degraded(0, &out);
        let o = out.lock().unwrap();
        // Envelope to dc 2 sums to 8 Gbps → degraded budget 4. The
        // stream's 2.5 floor is reserved first, spread ∝ [6, 2]/8; the
        // batch transfer gets the 1.5 surplus.
        let s: f64 = o[&(1, 2)].rate.iter().sum();
        assert!((s - 2.5).abs() < 1e-9, "stream floor honored: {s}");
        assert!((o[&(1, 2)].rate[0] - 2.5 * 0.75).abs() < 1e-9);
        let b: f64 = o[&(7, 2)].rate.iter().sum();
        assert!((b - 1.5).abs() < 1e-9, "batch gets the surplus: {b}");
        assert!(s + b <= 8.0 * DEGRADED_SCALE + 1e-9, "within the degraded budget");
    }

    /// When the degraded budget cannot cover the floors, they all scale
    /// down by the same factor instead of one stream starving another.
    #[test]
    fn degraded_infeasible_floors_scale_down_together() {
        let out: Arc<Mutex<HashMap<(u64, usize), Outgoing>>> = Arc::default();
        {
            let mut o = out.lock().unwrap();
            for (id, floor) in [(1u64, 6.0), (2, 2.0)] {
                let mut s = mk_outgoing(1 << 20, vec![2.0, 2.0]);
                s.floor_gbps = floor;
                o.insert((id, 3), s);
            }
        }
        enter_degraded(0, &out);
        let o = out.lock().unwrap();
        // Envelope sums to 8 → budget 4, floors sum to 8 → scale ×0.5.
        let a: f64 = o[&(1, 3)].rate.iter().sum();
        let b: f64 = o[&(2, 3)].rate.iter().sum();
        assert!((a - 3.0).abs() < 1e-9, "{a}");
        assert!((b - 1.0).abs() < 1e-9, "{b}");
        assert!(a + b <= 8.0 * DEGRADED_SCALE + 1e-9, "within the degraded budget");
    }

    /// Satellite: the data-connection pool must shrink when a rate push
    /// shows the path count went down (it previously only ever grew).
    #[test]
    fn rate_push_trims_oversized_connection_pool() {
        // Four real loopback connections to a scratch listener.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let conns: Arc<Mutex<HashMap<usize, Vec<TcpStream>>>> = Arc::default();
        {
            let mut c = conns.lock().unwrap();
            let pool: Vec<TcpStream> =
                (0..4).map(|_| TcpStream::connect(addr).unwrap()).collect();
            c.insert(2, pool);
            c.insert(5, vec![TcpStream::connect(addr).unwrap()]);
        }
        let out: Arc<Mutex<HashMap<(u64, usize), Outgoing>>> = Arc::default();
        out.lock().unwrap().insert((1, 2), mk_outgoing(1 << 20, vec![0.0; 4]));
        // Rate push sized for 2 paths: the pool to dc 2 must trim to 2.
        let entry = Json::from_pairs([
            ("coflow", Json::from(1u64)),
            ("dst", 2usize.into()),
            ("rates", Json::Arr(vec![Json::Num(1.0), Json::Num(1.0)])),
        ]);
        apply_rate_entry(&entry, &out);
        trim_conns(&out, &conns);
        let c = conns.lock().unwrap();
        assert_eq!(c[&2].len(), 2, "pool trimmed to the pushed path count");
        assert_eq!(c[&5].len(), 1, "unrated destination untouched");
    }

    /// The resync report covers exactly the live transfers, sorted, with
    /// the allocation envelope (not the degraded enforcement rate).
    #[test]
    fn resync_report_carries_live_transfers_and_buffered_state() {
        let out: Arc<Mutex<HashMap<(u64, usize), Outgoing>>> = Arc::default();
        {
            let mut o = out.lock().unwrap();
            let mut t = mk_outgoing(500_000, vec![3.0, 1.0]);
            t.offset = 250_000;
            t.rate = vec![0.75, 0.25]; // degraded enforcement
            o.insert((4, 1), t);
            o.insert((2, 3), mk_outgoing(1_000_000, vec![2.0]));
            o.insert((9, 0), mk_outgoing(0, vec![5.0])); // finished: excluded
        }
        let pending: Arc<Mutex<PendingCtrl>> = Arc::default();
        pending.lock().unwrap().samples.push(Json::obj());
        // Disconnected ctrl_tx: send fails, completions must be retained.
        let ctrl_tx: CtrlTx = Arc::new(Mutex::new(None));
        pending.lock().unwrap().msgs.push(Json::obj());
        send_resync(0, &out, &pending, &ctrl_tx);
        assert_eq!(
            pending.lock().unwrap().msgs.len(),
            1,
            "undelivered completions survive a failed resync"
        );
        // Now capture what a live socket would have received.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let reader = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut msgs = Vec::new();
            while let Ok(Some(m)) = protocol::read_msg(&mut s) {
                msgs.push(m);
            }
            msgs
        });
        let sock = TcpStream::connect(addr).unwrap();
        *ctrl_tx.lock().unwrap() = Some(sock);
        send_resync(0, &out, &pending, &ctrl_tx);
        *ctrl_tx.lock().unwrap() = None; // closes the write half
        let msgs = reader.join().unwrap();
        assert!(!msgs.is_empty());
        let resync = &msgs[0];
        assert_eq!(resync.get("op").and_then(|o| o.as_str()), Some("resync_state"));
        let entries: Vec<ResyncEntry> = resync
            .get("entries")
            .and_then(|e| e.as_arr())
            .unwrap()
            .iter()
            .filter_map(ResyncEntry::from_json)
            .collect();
        assert_eq!(entries.len(), 2, "finished transfer excluded");
        assert_eq!((entries[0].coflow, entries[0].dst_dc), (2, 3), "sorted by (coflow, dst)");
        assert_eq!((entries[1].coflow, entries[1].dst_dc), (4, 1));
        assert_eq!(entries[1].achieved_bytes, 250_000);
        assert_eq!(entries[1].remaining_bytes, 500_000);
        assert_eq!(entries[1].rates, vec![3.0, 1.0], "envelope, not degraded rate");
        assert_eq!(msgs.len(), 2, "buffered completion replayed after the report");
    }

    /// Re-arm protocol: a plain `transfer`/`expect` is additive (retries of
    /// the original group command must stack), while `reset: true` replaces
    /// the endpoint state wholesale — offsets restart at zero on the sender
    /// and the receiver's reassembly frontier drops with them, so a
    /// restarted endpoint can never deadlock against a survivor's frontier.
    #[test]
    fn reset_flag_replaces_transfer_and_expect_state() {
        let out: Arc<Mutex<HashMap<(u64, usize), Outgoing>>> = Arc::default();
        let conns: Arc<Mutex<HashMap<usize, Vec<TcpStream>>>> = Arc::default();
        let incoming: Arc<Mutex<HashMap<(u64, usize), Incoming>>> = Arc::default();
        let rx_counters: Arc<Mutex<HashMap<(u64, usize), Arc<AtomicU64>>>> = Arc::default();
        let peers = Arc::new(PeerState::new());
        let transfer = |bytes: u64, reset: bool| {
            let mut m = Json::from_pairs([
                ("op", Json::from("transfer")),
                ("coflow", Json::from(7u64)),
                ("dst", Json::from(2u64)),
                ("bytes", Json::from(bytes)),
            ]);
            if reset {
                m.set("reset", Json::from(true));
            }
            handle_ctrl(0, &m, &out, &conns, &incoming, &rx_counters, &peers);
        };
        transfer(1000, false);
        transfer(1000, false);
        {
            let mut o = out.lock().unwrap();
            let e = o.get_mut(&(7, 2)).unwrap();
            assert_eq!(e.remaining, 2000, "plain transfers are additive");
            // Simulate progress: a re-arm must discard it.
            e.offset = 500;
            e.remaining = 1500;
        }
        transfer(1600, true);
        {
            let o = out.lock().unwrap();
            let e = &o[&(7, 2)];
            assert_eq!(e.remaining, 1600, "reset replaces remaining");
            assert_eq!(e.offset, 0, "reset restarts the offset stream");
        }

        let expect = |bytes: u64, reset: bool| {
            let mut m = Json::from_pairs([
                ("op", Json::from("expect")),
                ("coflow", Json::from(7u64)),
                ("src", Json::from(3u64)),
                ("bytes", Json::from(bytes)),
            ]);
            if reset {
                m.set("reset", Json::from(true));
            }
            handle_ctrl(0, &m, &out, &conns, &incoming, &rx_counters, &peers);
        };
        expect(1000, false);
        expect(1000, false);
        {
            let mut inc = incoming.lock().unwrap();
            let e = inc.get_mut(&(7, 3)).unwrap();
            assert_eq!(e.expected, 2000, "plain expects are additive");
            e.frontier = 700;
            e.pending.insert(900, 100);
        }
        expect(1300, true);
        {
            let inc = incoming.lock().unwrap();
            let e = &inc[&(7, 3)];
            assert_eq!(e.expected, 1300, "reset replaces the target");
            assert_eq!(e.frontier, 0, "reset drops the survivor frontier");
            assert!(e.pending.is_empty(), "buffered out-of-order chunks dropped");
        }
    }

    /// Stall watchdog: a path holding a live allocation and unfinished work
    /// that moves zero bytes for [`STALL_WINDOWS`] consecutive stable
    /// windows flags its telemetry sample; any progress resets the counter.
    #[test]
    fn stalled_paths_are_flagged_after_consecutive_idle_windows() {
        let out: Arc<Mutex<HashMap<(u64, usize), Outgoing>>> = Arc::default();
        {
            let mut t = mk_outgoing(1 << 20, vec![2.0]);
            t.rate_windows = 1; // rate already spanned a full window
            out.lock().unwrap().insert((1, 2), t);
        }
        // Disconnected ctrl_tx: every flush lands in the pending buffer.
        let ctrl_tx: CtrlTx = Arc::new(Mutex::new(None));
        let pending: Arc<Mutex<PendingCtrl>> = Arc::default();
        let last_stall = |p: &Arc<Mutex<PendingCtrl>>| {
            let p = p.lock().unwrap();
            p.samples
                .last()
                .and_then(|s| s.get("stall"))
                .and_then(|x| x.as_bool())
                .unwrap_or(false)
        };
        for i in 0..STALL_WINDOWS {
            flush_telemetry(0.25, &out, &ctrl_tx, &pending);
            assert!(
                !last_stall(&pending) || i + 1 >= STALL_WINDOWS,
                "no stall flag before the threshold (window {i})"
            );
        }
        assert!(last_stall(&pending), "threshold window carries the stall flag");
        // Progress clears the counter: the next idle window is unflagged.
        out.lock().unwrap().get_mut(&(1, 2)).unwrap().window[0] = 1e6;
        flush_telemetry(0.25, &out, &ctrl_tx, &pending);
        assert!(!last_stall(&pending), "progress clears the stall state");
        flush_telemetry(0.25, &out, &ctrl_tx, &pending);
        assert!(!last_stall(&pending), "counter restarted from zero after progress");
    }
}
