//! Emulated SD-WAN rule tables (§4.3 "Minimizing Rule Updates in the WAN").
//!
//! Terra installs forwarding rules only when persistent overlay connections
//! are (re)initialized — one rule per switch per ⟨datacenter pair, path,
//! direction⟩ — and never touches them on reschedules. This module tracks
//! what a FloodLight controller would install so tests and benches can
//! verify the paper's claims (e.g. ≤ 168 rules per switch on SWAN, zero
//! updates during steady-state scheduling).

use crate::net::paths::PathSet;
use crate::net::Wan;

/// Rule table across all emulated switches (one switch per datacenter).
#[derive(Clone, Debug, Default)]
pub struct RuleTable {
    /// Rules installed per switch.
    pub per_switch: Vec<usize>,
    /// Cumulative rule install/remove operations.
    pub updates: usize,
}

impl RuleTable {
    pub fn new(num_switches: usize) -> RuleTable {
        RuleTable { per_switch: vec![0; num_switches], updates: 0 }
    }

    /// Install forwarding rules for every persistent path in `paths`: each
    /// path needs a rule at every switch it traverses (source included, so
    /// the overlay can stripe onto it; destination delivery needs none).
    pub fn install_paths(&mut self, wan: &Wan, paths: &PathSet) {
        for u in 0..wan.num_nodes() {
            for v in 0..wan.num_nodes() {
                if u == v {
                    continue;
                }
                for p in paths.get(u, v) {
                    for &e in &p.edges {
                        let sw = wan.link(e).src;
                        self.per_switch[sw] += 1;
                        self.updates += 1;
                    }
                }
            }
        }
    }

    /// Tear down rules for paths crossing a failed link and install the
    /// recomputed path set's rules (returns ops performed). Called only on
    /// WAN structural events (§4.4).
    pub fn reinstall(&mut self, wan: &Wan, paths: &PathSet) -> usize {
        let before = self.updates;
        let removed: usize = self.per_switch.iter().sum();
        self.updates += removed;
        self.per_switch.iter_mut().for_each(|c| *c = 0);
        self.install_paths(wan, paths);
        self.updates - before
    }

    pub fn max_per_switch(&self) -> usize {
        self.per_switch.iter().copied().max().unwrap_or(0)
    }

    pub fn total(&self) -> usize {
        self.per_switch.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::topologies;

    #[test]
    fn swan_rule_count_bounded() {
        // Paper: up to 168 rules per switch for SWAN with k = 15.
        let wan = topologies::swan();
        let paths = PathSet::compute(&wan, 15);
        let mut rt = RuleTable::new(wan.num_nodes());
        rt.install_paths(&wan, &paths);
        assert!(rt.max_per_switch() > 0);
        assert!(
            rt.max_per_switch() <= 168,
            "max rules/switch = {} exceeds the paper's bound",
            rt.max_per_switch()
        );
    }

    #[test]
    fn steady_state_needs_no_updates() {
        let wan = topologies::swan();
        let paths = PathSet::compute(&wan, 15);
        let mut rt = RuleTable::new(wan.num_nodes());
        rt.install_paths(&wan, &paths);
        let after_init = rt.updates;
        // Scheduling rounds do not touch rules — nothing to call here;
        // the invariant is that only reinstall() mutates the table.
        assert_eq!(rt.updates, after_init);
    }

    #[test]
    fn reinstall_counts_ops() {
        let mut wan = topologies::swan();
        let paths = PathSet::compute(&wan, 3);
        let mut rt = RuleTable::new(wan.num_nodes());
        rt.install_paths(&wan, &paths);
        let t = rt.total();
        assert!(t > 0);
        wan.apply_event(&crate::net::LinkEvent::Fail(0, 1));
        let paths2 = PathSet::compute(&wan, 3);
        let ops = rt.reinstall(&wan, &paths2);
        assert!(ops >= t, "teardown + reinstall should count");
        assert!(rt.total() > 0);
    }
}
