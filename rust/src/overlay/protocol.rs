//! Wire protocol for the overlay: length-prefixed JSON control messages and
//! binary data frames.
//!
//! Control channel (agent <-> controller, client <-> controller): a 4-byte
//! little-endian length followed by a JSON document. Data channel (agent ->
//! agent persistent connections): a fixed 28-byte header followed by the
//! chunk payload.

use crate::coflow::{AggTree, ServiceClass};
use crate::util::json::Json;
use std::io::{Read, Write};
use std::net::TcpStream;

/// Data-frame header magic.
pub const DATA_MAGIC: u32 = 0x7E44_AA01;
/// Chunk payload size for striping transfers across paths.
pub const CHUNK_BYTES: usize = 64 * 1024;
/// Reserved coflow id for active-probe data frames: receivers drop probe
/// chunks without reassembly or completion accounting (real coflow ids
/// start at 1).
pub const PROBE_COFLOW: u64 = 0;
/// Maximum control-message body size, enforced symmetrically: readers
/// reject larger frames, and [`write_msg`] refuses to emit them — a body
/// whose length overflows the u32 prefix (or merely exceeds the peer's
/// cap) would otherwise silently truncate the prefix and desync the frame
/// stream.
pub const MAX_MSG_BYTES: usize = 64 * 1024 * 1024;

/// A flow in a coflow submission (§5.2 API).
#[derive(Clone, Debug, PartialEq)]
pub struct FlowSpec {
    pub id: u64,
    pub src_dc: usize,
    pub dst_dc: usize,
    /// Bytes to transfer.
    pub bytes: u64,
}

impl FlowSpec {
    pub fn to_json(&self) -> Json {
        Json::from_pairs([
            ("id", Json::from(self.id)),
            ("src", self.src_dc.into()),
            ("dst", self.dst_dc.into()),
            ("bytes", self.bytes.into()),
        ])
    }

    pub fn from_json(j: &Json) -> Option<FlowSpec> {
        Some(FlowSpec {
            id: j.get("id")?.as_u64()?,
            src_dc: j.get("src")?.as_u64()? as usize,
            dst_dc: j.get("dst")?.as_u64()? as usize,
            bytes: j.get("bytes")?.as_u64()?,
        })
    }
}

/// Serialize a coflow's service class for the `submit_coflow` message.
/// `Batch` (and `Deadline`, which is a tag derived from the separate
/// `deadline` field rather than independent wire state) returns `None` —
/// the `class` key is simply absent, so class-free clients and the
/// pre-class controller interoperate byte-identically.
pub fn class_to_json(class: &ServiceClass) -> Option<Json> {
    match class {
        ServiceClass::Batch | ServiceClass::Deadline => None,
        ServiceClass::Stream { rate_floor_gbps } => Some(Json::from_pairs([
            ("kind", Json::from("stream")),
            ("floor_gbps", (*rate_floor_gbps).into()),
        ])),
        ServiceClass::MlSync { tree, iteration_gbit } => Some(Json::from_pairs([
            ("kind", Json::from("ml-sync")),
            ("root", Json::from(tree.root as u64)),
            (
                "edges",
                Json::Arr(
                    tree.edges
                        .iter()
                        .map(|&(c, p)| {
                            Json::Arr(vec![Json::from(c as u64), Json::from(p as u64)])
                        })
                        .collect(),
                ),
            ),
            ("iter_gbit", (*iteration_gbit).into()),
        ])),
    }
}

/// Parse the optional `class` field of a `submit_coflow` message. A missing
/// field is `Batch`; a present-but-malformed one (unknown kind, bad floor,
/// malformed edge list) is `None` so the controller rejects the submission
/// instead of silently downgrading a stream to batch.
pub fn class_from_json(j: Option<&Json>) -> Option<ServiceClass> {
    let Some(j) = j else { return Some(ServiceClass::Batch) };
    match j.get("kind")?.as_str()? {
        "stream" => {
            let floor = j.get("floor_gbps")?.as_f64()?;
            if !floor.is_finite() || floor <= 0.0 {
                return None;
            }
            Some(ServiceClass::Stream { rate_floor_gbps: floor })
        }
        "ml-sync" => {
            let root = j.get("root")?.as_u64()? as usize;
            let edges = j
                .get("edges")?
                .as_arr()?
                .iter()
                .map(|e| {
                    let pair = e.as_arr()?;
                    if pair.len() != 2 {
                        return None;
                    }
                    Some((pair[0].as_u64()? as usize, pair[1].as_u64()? as usize))
                })
                .collect::<Option<Vec<_>>>()?;
            let iteration_gbit = j.get("iter_gbit").and_then(|x| x.as_f64()).unwrap_or(0.0);
            Some(ServiceClass::MlSync { tree: AggTree { root, edges }, iteration_gbit })
        }
        _ => None,
    }
}

/// Coflow status reported by `check_status` (§5.2).
#[derive(Clone, Debug, PartialEq)]
pub enum CoflowStatus {
    Pending,
    Running { delivered: u64, total: u64 },
    Done { cct_s: f64 },
    Rejected,
    Unknown,
}

impl CoflowStatus {
    pub fn to_json(&self) -> Json {
        match self {
            CoflowStatus::Pending => Json::from_pairs([("state", Json::from("pending"))]),
            CoflowStatus::Running { delivered, total } => Json::from_pairs([
                ("state", Json::from("running")),
                ("delivered", (*delivered).into()),
                ("total", (*total).into()),
            ]),
            CoflowStatus::Done { cct_s } => Json::from_pairs([
                ("state", Json::from("done")),
                ("cct_s", (*cct_s).into()),
            ]),
            CoflowStatus::Rejected => Json::from_pairs([("state", Json::from("rejected"))]),
            CoflowStatus::Unknown => Json::from_pairs([("state", Json::from("unknown"))]),
        }
    }

    pub fn from_json(j: &Json) -> CoflowStatus {
        match j.get("state").and_then(|s| s.as_str()) {
            Some("pending") => CoflowStatus::Pending,
            Some("running") => CoflowStatus::Running {
                delivered: j.get("delivered").and_then(|x| x.as_u64()).unwrap_or(0),
                total: j.get("total").and_then(|x| x.as_u64()).unwrap_or(0),
            },
            Some("done") => CoflowStatus::Done {
                cct_s: j.get("cct_s").and_then(|x| x.as_f64()).unwrap_or(0.0),
            },
            Some("rejected") => CoflowStatus::Rejected,
            _ => CoflowStatus::Unknown,
        }
    }
}

/// One achieved-throughput sample in a `telemetry_report` (agent →
/// controller): what the source agent measured on one ⟨transfer, path⟩
/// over the last reporting window, plus the rate it was *allocated* there
/// — the controller needs both to tell a capacity-capped sample (achieved
/// well below allocated: the path limited us, a direct capacity reading)
/// from a censored one (achieved ≈ allocated: capacity is merely ≥
/// achieved). Probe samples (`probe = true`, `coflow = PROBE_COFLOW`)
/// come from controller-requested `probe_request` bursts on idle paths.
#[derive(Clone, Debug, PartialEq)]
pub struct TelemetrySample {
    pub coflow: u64,
    pub dst_dc: usize,
    /// Path index within the source agent's connection set to `dst_dc`.
    pub path: usize,
    /// Achieved throughput over the window, in emulated Gbps.
    pub gbps: f64,
    /// Rate the controller had allocated on that path (Gbps); 0 for
    /// probes.
    pub alloc_gbps: f64,
    pub probe: bool,
    /// Set by the sender's stall watchdog: this ⟨transfer, path⟩ made zero
    /// progress for several consecutive windows despite a live allocation
    /// — affirmative outage evidence, unlike an ordinary zero-achieved
    /// window. The key is omitted on the wire when false, so samples from
    /// (and to) older builds parse unchanged.
    pub stalled: bool,
}

impl TelemetrySample {
    pub fn to_json(&self) -> Json {
        let mut j = Json::from_pairs([
            ("coflow", Json::from(self.coflow)),
            ("dst", self.dst_dc.into()),
            ("path", self.path.into()),
            ("gbps", self.gbps.into()),
            ("alloc", self.alloc_gbps.into()),
            ("probe", self.probe.into()),
        ]);
        if self.stalled {
            j.set("stall", Json::from(true));
        }
        j
    }

    pub fn from_json(j: &Json) -> Option<TelemetrySample> {
        Some(TelemetrySample {
            coflow: j.get("coflow")?.as_u64()?,
            dst_dc: j.get("dst")?.as_u64()? as usize,
            path: j.get("path")?.as_u64()? as usize,
            gbps: j.get("gbps")?.as_f64()?,
            alloc_gbps: j.get("alloc").and_then(|x| x.as_f64()).unwrap_or(0.0),
            probe: j.get("probe").and_then(|x| x.as_bool()).unwrap_or(false),
            stalled: j.get("stall").and_then(|x| x.as_bool()).unwrap_or(false),
        })
    }
}

/// One sender-side transfer in an agent's `resync_state` report: what the
/// agent knows about a live outgoing FlowGroup when it reconnects to a
/// (possibly restarted) controller. `achieved_bytes`/`remaining_bytes` let
/// the controller rebuild remaining-volume state without restarting the
/// transfer from zero; `rates` is the last controller-assigned per-path
/// allocation (the envelope the agent's degraded mode stayed within).
#[derive(Clone, Debug, PartialEq)]
pub struct ResyncEntry {
    pub coflow: u64,
    pub dst_dc: usize,
    /// Bytes still to send for this (coflow, dst) FlowGroup.
    pub remaining_bytes: u64,
    /// Bytes already written to the data connections (the send offset).
    pub achieved_bytes: u64,
    /// Last controller-assigned per-path rates, in emulated Gbps.
    pub rates: Vec<f64>,
}

impl ResyncEntry {
    pub fn to_json(&self) -> Json {
        Json::from_pairs([
            ("coflow", Json::from(self.coflow)),
            ("dst", self.dst_dc.into()),
            ("remaining", self.remaining_bytes.into()),
            ("achieved", self.achieved_bytes.into()),
            ("rates", Json::Arr(self.rates.iter().map(|&r| Json::Num(r)).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Option<ResyncEntry> {
        Some(ResyncEntry {
            coflow: j.get("coflow")?.as_u64()?,
            dst_dc: j.get("dst")?.as_u64()? as usize,
            remaining_bytes: j.get("remaining")?.as_u64()?,
            achieved_bytes: j.get("achieved")?.as_u64()?,
            rates: j
                .get("rates")?
                .as_arr()?
                .iter()
                .map(|r| r.as_f64().unwrap_or(0.0))
                .collect(),
        })
    }
}

/// Write one length-prefixed JSON message. Oversized bodies (anything a
/// reader would reject, including > 4 GiB bodies whose length prefix would
/// wrap) fail *before* any byte hits the wire, keeping the frame stream
/// intact.
pub fn write_msg(stream: &mut TcpStream, msg: &Json) -> std::io::Result<()> {
    let body = msg.to_string().into_bytes();
    if body.len() > MAX_MSG_BYTES {
        return Err(std::io::Error::other(format!(
            "control message too large to send: {} bytes > cap {MAX_MSG_BYTES}",
            body.len()
        )));
    }
    let len = (body.len() as u32).to_le_bytes();
    stream.write_all(&len)?;
    stream.write_all(&body)?;
    stream.flush()
}

/// Read one length-prefixed JSON message (None on clean EOF).
pub fn read_msg(stream: &mut TcpStream) -> std::io::Result<Option<Json>> {
    let mut len_buf = [0u8; 4];
    match stream.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e),
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_MSG_BYTES {
        return Err(std::io::Error::other("control message too large"));
    }
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body)?;
    let text = String::from_utf8(body).map_err(std::io::Error::other)?;
    Json::parse(&text).map(Some).map_err(std::io::Error::other)
}

/// Fill `buf` completely, tolerating read timeouts (progress is preserved
/// across `WouldBlock`/`TimedOut`, unlike `read_exact`). Returns false on
/// clean EOF before any byte, or when `stop` is raised mid-wait.
pub fn read_full(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stop: &std::sync::atomic::AtomicBool,
) -> std::io::Result<bool> {
    use std::sync::atomic::Ordering;
    let mut filled = 0usize;
    while filled < buf.len() {
        if stop.load(Ordering::Relaxed) {
            return Ok(false);
        }
        match stream.read(&mut buf[filled..]) {
            Ok(0) => {
                return if filled == 0 {
                    Ok(false)
                } else {
                    Err(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "EOF mid-frame",
                    ))
                }
            }
            Ok(n) => filled += n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// Read one length-prefixed JSON message with timeout-resumable reads.
/// Returns `None` on clean EOF or stop.
pub fn read_msg_resumable(
    stream: &mut TcpStream,
    stop: &std::sync::atomic::AtomicBool,
) -> std::io::Result<Option<Json>> {
    let mut len_buf = [0u8; 4];
    if !read_full(stream, &mut len_buf, stop)? {
        return Ok(None);
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_MSG_BYTES {
        return Err(std::io::Error::other("control message too large"));
    }
    let mut body = vec![0u8; len];
    if !read_full(stream, &mut body, stop)? {
        return Ok(None);
    }
    let text = String::from_utf8(body).map_err(std::io::Error::other)?;
    Json::parse(&text).map(Some).map_err(std::io::Error::other)
}

/// Data frame header: transfer identity + sequencing for reassembly.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DataHeader {
    pub coflow: u64,
    pub src_dc: u32,
    /// Byte offset of this chunk within the transfer (reassembly key).
    pub offset: u64,
    pub len: u32,
}

impl DataHeader {
    pub const SIZE: usize = 28;

    pub fn encode(&self) -> [u8; Self::SIZE] {
        let mut b = [0u8; Self::SIZE];
        b[0..4].copy_from_slice(&DATA_MAGIC.to_le_bytes());
        b[4..12].copy_from_slice(&self.coflow.to_le_bytes());
        b[12..16].copy_from_slice(&self.src_dc.to_le_bytes());
        b[16..24].copy_from_slice(&self.offset.to_le_bytes());
        b[24..28].copy_from_slice(&self.len.to_le_bytes());
        b
    }

    pub fn decode(b: &[u8; Self::SIZE]) -> std::io::Result<DataHeader> {
        let magic = u32::from_le_bytes(b[0..4].try_into().unwrap());
        if magic != DATA_MAGIC {
            return Err(std::io::Error::other("bad data frame magic"));
        }
        Ok(DataHeader {
            coflow: u64::from_le_bytes(b[4..12].try_into().unwrap()),
            src_dc: u32::from_le_bytes(b[12..16].try_into().unwrap()),
            offset: u64::from_le_bytes(b[16..24].try_into().unwrap()),
            len: u32::from_le_bytes(b[24..28].try_into().unwrap()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};

    #[test]
    fn flow_spec_roundtrip() {
        let f = FlowSpec { id: 3, src_dc: 1, dst_dc: 4, bytes: 123456 };
        assert_eq!(FlowSpec::from_json(&f.to_json()), Some(f));
    }

    #[test]
    fn status_roundtrip() {
        for s in [
            CoflowStatus::Pending,
            CoflowStatus::Running { delivered: 10, total: 100 },
            CoflowStatus::Done { cct_s: 1.5 },
            CoflowStatus::Rejected,
        ] {
            assert_eq!(CoflowStatus::from_json(&s.to_json()), s);
        }
    }

    #[test]
    fn service_class_roundtrip() {
        // Batch and Deadline put nothing on the wire; an absent key parses
        // back to Batch (Deadline is re-derived from the deadline field).
        assert_eq!(class_to_json(&ServiceClass::Batch), None);
        assert_eq!(class_to_json(&ServiceClass::Deadline), None);
        assert_eq!(class_from_json(None), Some(ServiceClass::Batch));

        let stream = ServiceClass::Stream { rate_floor_gbps: 1.25 };
        let j = class_to_json(&stream).unwrap();
        assert_eq!(class_from_json(Some(&j)), Some(stream));

        let ml = ServiceClass::MlSync {
            tree: AggTree { root: 2, edges: vec![(0, 2), (1, 2), (3, 1)] },
            iteration_gbit: 12.5,
        };
        let j = class_to_json(&ml).unwrap();
        assert_eq!(class_from_json(Some(&j)), Some(ml));

        // Malformed classes must be rejected, not downgraded to Batch.
        assert_eq!(class_from_json(Some(&Json::obj())), None);
        let bad_kind = Json::from_pairs([("kind", Json::from("bulk"))]);
        assert_eq!(class_from_json(Some(&bad_kind)), None);
        for bad_floor in [0.0, -1.0, f64::NAN] {
            let j = Json::from_pairs([
                ("kind", Json::from("stream")),
                ("floor_gbps", bad_floor.into()),
            ]);
            assert_eq!(class_from_json(Some(&j)), None, "floor {bad_floor}");
        }
        let bad_edges = Json::from_pairs([
            ("kind", Json::from("ml-sync")),
            ("root", Json::from(0u64)),
            ("edges", Json::Arr(vec![Json::Arr(vec![Json::from(1u64)])])),
        ]);
        assert_eq!(class_from_json(Some(&bad_edges)), None);
    }

    #[test]
    fn telemetry_sample_roundtrip() {
        let s = TelemetrySample {
            coflow: 7,
            dst_dc: 2,
            path: 1,
            gbps: 3.25,
            alloc_gbps: 5.0,
            probe: false,
            stalled: false,
        };
        // The stall key is omitted when false — old-format wire compat.
        assert!(s.to_json().get("stall").is_none());
        assert_eq!(TelemetrySample::from_json(&s.to_json()), Some(s));
        let st = TelemetrySample {
            coflow: 9,
            dst_dc: 1,
            path: 0,
            gbps: 0.0,
            alloc_gbps: 2.0,
            probe: false,
            stalled: true,
        };
        assert_eq!(TelemetrySample::from_json(&st.to_json()), Some(st));
        let p = TelemetrySample {
            coflow: PROBE_COFLOW,
            dst_dc: 0,
            path: 0,
            gbps: 12.0,
            alloc_gbps: 0.0,
            probe: true,
            stalled: false,
        };
        assert_eq!(TelemetrySample::from_json(&p.to_json()), Some(p));
        assert_eq!(TelemetrySample::from_json(&Json::obj()), None);
    }

    #[test]
    fn resync_entry_roundtrip() {
        let e = ResyncEntry {
            coflow: 11,
            dst_dc: 3,
            remaining_bytes: 1_000_000,
            achieved_bytes: 250_000,
            rates: vec![2.5, 0.0, 1.0],
        };
        assert_eq!(ResyncEntry::from_json(&e.to_json()), Some(e));
        assert_eq!(ResyncEntry::from_json(&Json::obj()), None);
    }

    #[test]
    fn header_roundtrip() {
        let h = DataHeader { coflow: 9, src_dc: 2, offset: 1 << 33, len: 65536 };
        assert_eq!(DataHeader::decode(&h.encode()).unwrap(), h);
        let mut bad = h.encode();
        bad[0] = 0;
        assert!(DataHeader::decode(&bad).is_err());
    }

    /// Regression: `write_msg` used to cast `body.len() as u32` unchecked —
    /// an oversized body silently truncated the length prefix and desynced
    /// the stream. It must now fail cleanly with nothing written.
    #[test]
    fn write_msg_rejects_oversized_body() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            // The peer must observe a clean EOF — not a garbled frame.
            assert!(read_msg(&mut s).unwrap().is_none());
        });
        let mut c = TcpStream::connect(addr).unwrap();
        // The JSON encoding (quotes + key) pushes this just past the cap.
        let msg = Json::from_pairs([("blob", Json::from("x".repeat(MAX_MSG_BYTES)))]);
        let err = write_msg(&mut c, &msg).unwrap_err();
        assert!(err.to_string().contains("too large"), "{err}");
        // The connection is still usable for well-sized messages.
        drop(c);
        t.join().unwrap();
    }

    #[test]
    fn msg_roundtrip_over_tcp() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let msg = read_msg(&mut s).unwrap().unwrap();
            write_msg(&mut s, &msg).unwrap(); // echo
            assert!(read_msg(&mut s).unwrap().is_none()); // EOF
        });
        let mut c = TcpStream::connect(addr).unwrap();
        let msg = Json::from_pairs([("op", Json::from("hello")), ("dc", 3u64.into())]);
        write_msg(&mut c, &msg).unwrap();
        let echo = read_msg(&mut c).unwrap().unwrap();
        assert_eq!(echo, msg);
        drop(c);
        t.join().unwrap();
    }
}
