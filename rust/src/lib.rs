//! # Terra: Scalable Cross-Layer GDA Optimizations (reproduction)
//!
//! Terra co-optimizes application-level **coflow scheduling** with WAN-level
//! **multipath routing** for geo-distributed analytics (GDA). This crate is a
//! full reproduction of the system described in You & Chowdhury, *"Terra:
//! Scalable Cross-Layer GDA Optimizations"* (2019), including:
//!
//! - the FlowGroup-coalesced joint scheduling-routing algorithm
//!   ([`scheduler`], [`lp`]),
//! - the shared incremental round engine driving it from both planes
//!   ([`engine`]),
//! - the WAN substrate with the paper's three topologies ([`net`]),
//! - the flow-level simulator used for the paper's large-scale evaluation
//!   ([`sim`]),
//! - the five baselines it compares against ([`baselines`]),
//! - the overlay-based enforcement plane (controller + agents over persistent
//!   TCP connections, [`overlay`]),
//! - the workload generators for BigBench / TPC-DS / TPC-H / Facebook traces
//!   ([`workloads`]), and
//! - an AOT-compiled JAX/Pallas LP solver executed from rust via PJRT
//!   ([`runtime`]).
//!
//! See `DESIGN.md` for the architecture and the per-experiment index, and
//! `EXPERIMENTS.md` for reproduction results.
//!
//! ## Quick start
//!
//! ```no_run
//! use terra::net::topologies;
//! use terra::sim::{Simulation, SimConfig};
//! use terra::scheduler::TerraPolicy;
//! use terra::workloads::{WorkloadKind, WorkloadGen};
//!
//! let wan = topologies::swan();
//! let jobs = WorkloadGen::new(WorkloadKind::BigBench, 42).jobs(&wan, 20);
//! let mut sim = Simulation::new(wan, Box::new(TerraPolicy::default()), SimConfig::default());
//! let report = sim.run_jobs(jobs);
//! println!("avg JCT: {:.2}s", report.avg_jct());
//! ```

pub mod api;
pub mod baselines;
pub mod coflow;
pub mod engine;
pub mod experiments;
pub mod lp;
pub mod net;
pub mod overlay;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod util;
pub mod workloads;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
