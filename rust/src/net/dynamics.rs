//! WAN dynamics: seeded, deterministic generators of [`LinkEvent`] streams.
//!
//! Terra's headline claim is fast reaction to WAN uncertainty — "large
//! bandwidth fluctuations and failures" (§3.1.3, Fig 10) — but hand-injected
//! single events only exercise one reaction at a time. This module
//! *generates* realistic event streams from composable models so the
//! simulator, the overlay controller, and the scenario sweep
//! ([`crate::experiments::scenario_sweep`]) can replay thousands of
//! distinct-but-reproducible WAN histories:
//!
//! - [`DynamicsModel::Diurnal`] — sinusoidal available-bandwidth swings with
//!   per-edge random phase and Gaussian jitter (high-priority background
//!   traffic ramping up and down, §2.2);
//! - [`DynamicsModel::MarkovFailure`] — per-link alternating-renewal on/off
//!   process (exponential time-to-failure and time-to-repair);
//! - [`DynamicsModel::RegionalOutage`] — correlated failures: every link
//!   touching one site goes down together and recovers together;
//! - [`DynamicsModel::Maintenance`] — scheduled one-link-at-a-time
//!   half-capacity drains (SWAN-style planned updates), announced or
//!   unannounced; announced windows additionally emit
//!   [`AnnouncedWindow`]s that feed the capacity estimator as priors;
//! - [`DynamicsModel::GrayFailure`] — a link that stays "up" but
//!   fluctuates violently around a low mean (the estimator's stress test);
//! - [`DynamicsModel::TraceReplay`] — replay a flat-file trace
//!   ([`parse_trace`]).
//!
//! ## Determinism and ordering guarantees
//!
//! Given the same `(wan, profile, horizon, seed)`, [`generate`] returns a
//! byte-identical event stream. Every model's [`Pcg32`] stream is derived
//! *purely* from `(seed, model position)` and every per-edge sub-stream
//! purely from `(model seed, edge id)` — key-derived via SplitMix64, never
//! by advancing a shared parent stream — so appending a model to a profile
//! or adding a link to a topology never perturbs the streams of the
//! existing models/edges. Events are
//! sorted by timestamp with a *stable* sort, so equal-timestamp events
//! (deliberate for correlated regional outages) keep their emission order:
//! models in profile order, then edges in id order, then time order. All
//! timestamps are finite and non-negative; recovery events may land shortly
//! past the horizon so the stream never strands a link down forever.

use super::topology::{LinkEvent, NodeId, Wan};
use crate::util::rng::{splitmix64, Pcg32};

/// Key-derived child seed: a pure function of `(root, tag)`, independent of
/// any RNG stream position.
fn child_seed(root: u64, tag: u64) -> u64 {
    let mut s = root ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    splitmix64(&mut s)
}

/// A timestamped WAN event, consumable by `sim::Simulation::add_wan_event`
/// and `overlay::ControllerHandle::inject_wan_event` (both feed the shared
/// `engine::RoundEngine`).
#[derive(Clone, Debug, PartialEq)]
pub struct TimedLinkEvent {
    pub t: f64,
    pub ev: LinkEvent,
}

/// An announced maintenance window: the operator tells the controller in
/// advance that the directed edge `(u, v)` will run at `gbps` over
/// `[start_t, end_t)`. Consumed by the telemetry subsystem as an
/// authoritative capacity prior; unannounced drains emit only the
/// [`LinkEvent`]s and must be *discovered* through sampling.
#[derive(Clone, Debug, PartialEq)]
pub struct AnnouncedWindow {
    /// When the announcement lands at the controller.
    pub announce_t: f64,
    /// When the drain takes effect.
    pub start_t: f64,
    /// When capacity restores to base.
    pub end_t: f64,
    pub u: NodeId,
    pub v: NodeId,
    /// Capacity (Gbps) of the directed edge during the window.
    pub gbps: f64,
}

/// A generated dynamics stream: the WAN truth events plus any maintenance
/// announcements (empty for profiles without announced windows).
#[derive(Clone, Debug, Default)]
pub struct DynamicsStream {
    pub events: Vec<TimedLinkEvent>,
    pub announcements: Vec<AnnouncedWindow>,
}

/// One composable dynamics model. Parameters are in seconds / fractions.
#[derive(Clone, Debug)]
pub enum DynamicsModel {
    /// Sinusoidal bandwidth fluctuation: each *directed* edge is sampled
    /// every `interval_s`, emitting `SetBandwidth(u, v, base · m(t))` with
    /// `m(t) = 1 − amplitude · (0.5 + 0.5 · sin(2π(t + φ)/period))` plus
    /// `jitter`-scaled Gaussian noise, clamped to `[0.05, 1.0]`. Each edge
    /// gets its own phase φ and sample-start offset, so timestamps are
    /// (almost surely) distinct and both directions fluctuate
    /// independently.
    Diurnal { period_s: f64, amplitude: f64, jitter: f64, interval_s: f64 },
    /// Per-link alternating renewal process: up-time ~ Exp(`mtbf_s`), then
    /// `Fail(u, v)`, down-time ~ Exp(`mttr_s`), then `Recover(u, v)`.
    MarkovFailure { mtbf_s: f64, mttr_s: f64 },
    /// Correlated regional outages: outage arrivals ~ Exp(`mtbo_s`); each
    /// picks a site uniformly and fails *all* links touching it at the same
    /// timestamp, recovering them together `outage_s` later.
    RegionalOutage { mtbo_s: f64, outage_s: f64 },
    /// Scheduled maintenance: one undirected link at a time is drained to
    /// `frac ×` base capacity (both directions) for `window_s`, then
    /// restored. Windows start at `period_s / 2` and recur every
    /// `period_s`, cycling through the links in edge order — `window_s` is
    /// clamped to `period_s` so drains never overlap (one link at a time,
    /// SWAN-style planned updates). When `announced`, each window also
    /// emits an [`AnnouncedWindow`] `lead_s` ahead of the drain, which the
    /// telemetry subsystem consumes as an authoritative capacity prior;
    /// unannounced drains must be discovered by sampling. Deterministic:
    /// the schedule uses no randomness at all.
    Maintenance { period_s: f64, window_s: f64, frac: f64, announced: bool, lead_s: f64 },
    /// Gray failure: a directed edge stays *up* but its available
    /// bandwidth collapses to around `low_frac ×` base and churns
    /// violently there. Episodes arrive per-edge ~ Exp(`mtbg_s`), last
    /// `episode_s`, and emit a `SetBandwidth` every `churn_interval_s`
    /// with multiplier `low_frac · (1 + churn_amp · N(0,1))` clamped to
    /// `[0.01, 1.0]`; the episode ends with a restore to base. No `Fail`
    /// is ever emitted — the pathology is that the link *looks* healthy.
    GrayFailure {
        mtbg_s: f64,
        episode_s: f64,
        low_frac: f64,
        churn_interval_s: f64,
        churn_amp: f64,
    },
    /// Replay a fixed event list (e.g. from [`parse_trace`]) verbatim. The
    /// horizon does *not* truncate traces: dropping a trailing recovery
    /// would strand a link down, violating the no-stranding guarantee —
    /// the trace author controls its extent.
    TraceReplay { events: Vec<TimedLinkEvent> },
}

/// A named composition of dynamics models.
#[derive(Clone, Debug)]
pub struct DynamicsProfile {
    pub name: String,
    pub models: Vec<DynamicsModel>,
}

impl DynamicsProfile {
    /// No dynamics at all — the static-WAN baseline.
    pub fn calm() -> DynamicsProfile {
        DynamicsProfile { name: "calm".into(), models: Vec::new() }
    }

    /// Slow sinusoidal bandwidth swings only.
    pub fn diurnal() -> DynamicsProfile {
        DynamicsProfile {
            name: "diurnal".into(),
            models: vec![DynamicsModel::Diurnal {
                period_s: 300.0,
                amplitude: 0.4,
                jitter: 0.05,
                interval_s: 75.0,
            }],
        }
    }

    /// Bandwidth swings plus independent per-link failures.
    pub fn flaky() -> DynamicsProfile {
        DynamicsProfile {
            name: "flaky".into(),
            models: vec![
                DynamicsModel::Diurnal {
                    period_s: 300.0,
                    amplitude: 0.3,
                    jitter: 0.05,
                    interval_s: 75.0,
                },
                DynamicsModel::MarkovFailure { mtbf_s: 4000.0, mttr_s: 45.0 },
            ],
        }
    }

    /// Mild bandwidth swings plus correlated whole-site outages.
    pub fn regional() -> DynamicsProfile {
        DynamicsProfile {
            name: "regional".into(),
            models: vec![
                DynamicsModel::Diurnal {
                    period_s: 300.0,
                    amplitude: 0.2,
                    jitter: 0.03,
                    interval_s: 90.0,
                },
                DynamicsModel::RegionalOutage { mtbo_s: 400.0, outage_s: 30.0 },
            ],
        }
    }

    /// Pure gray failures: links stay "up" while their bandwidth churns
    /// violently around a low mean — the capacity estimator's stress test
    /// (hold-down exists for exactly this flapping).
    pub fn gray() -> DynamicsProfile {
        DynamicsProfile {
            name: "gray".into(),
            models: vec![DynamicsModel::GrayFailure {
                mtbg_s: 240.0,
                episode_s: 60.0,
                low_frac: 0.15,
                churn_interval_s: 4.0,
                churn_amp: 0.5,
            }],
        }
    }

    /// Announced scheduled maintenance: one link at a time drains to half
    /// capacity, with the window announced 15 s ahead (the announcement
    /// feeds the estimator as a prior).
    pub fn maintenance() -> DynamicsProfile {
        DynamicsProfile {
            name: "maintenance".into(),
            models: vec![DynamicsModel::Maintenance {
                period_s: 120.0,
                window_s: 60.0,
                frac: 0.5,
                announced: true,
                lead_s: 15.0,
            }],
        }
    }

    /// The same maintenance schedule with no announcements: the estimator
    /// has to *discover* each drain through sampling.
    pub fn maintenance_unannounced() -> DynamicsProfile {
        DynamicsProfile {
            name: "maintenance-unannounced".into(),
            models: vec![DynamicsModel::Maintenance {
                period_s: 120.0,
                window_s: 60.0,
                frac: 0.5,
                announced: false,
                lead_s: 0.0,
            }],
        }
    }

    pub fn by_name(name: &str) -> Option<DynamicsProfile> {
        match name.to_ascii_lowercase().as_str() {
            "calm" | "none" | "static" => Some(DynamicsProfile::calm()),
            "diurnal" => Some(DynamicsProfile::diurnal()),
            "flaky" => Some(DynamicsProfile::flaky()),
            "regional" => Some(DynamicsProfile::regional()),
            "gray" | "grey" => Some(DynamicsProfile::gray()),
            "maintenance" => Some(DynamicsProfile::maintenance()),
            "maintenance-unannounced" | "maintenance_unannounced" => {
                Some(DynamicsProfile::maintenance_unannounced())
            }
            _ => None,
        }
    }

    /// The built-in profiles swept by default (calm baseline included).
    pub fn all() -> Vec<DynamicsProfile> {
        vec![
            DynamicsProfile::calm(),
            DynamicsProfile::diurnal(),
            DynamicsProfile::flaky(),
            DynamicsProfile::regional(),
            DynamicsProfile::gray(),
            DynamicsProfile::maintenance(),
        ]
    }
}

/// Generate the profile's event stream over `[0, horizon_s)` (recoveries
/// may trail slightly past the horizon). Deterministic given all arguments;
/// see the module docs for the ordering guarantees.
pub fn generate(
    wan: &Wan,
    profile: &DynamicsProfile,
    horizon_s: f64,
    seed: u64,
) -> Vec<TimedLinkEvent> {
    generate_stream(wan, profile, horizon_s, seed).events
}

/// [`generate`] plus maintenance announcements: the full stream a telemetry
/// -aware driver consumes. `events` are byte-identical to [`generate`]'s
/// output for the same arguments; `announcements` is sorted by announce
/// time and empty unless the profile contains an announced
/// [`DynamicsModel::Maintenance`].
pub fn generate_stream(
    wan: &Wan,
    profile: &DynamicsProfile,
    horizon_s: f64,
    seed: u64,
) -> DynamicsStream {
    let root = seed ^ 0xD1_4A_11C5;
    let mut out: Vec<TimedLinkEvent> = Vec::new();
    let mut ann: Vec<AnnouncedWindow> = Vec::new();
    for (mi, model) in profile.models.iter().enumerate() {
        model.emit(wan, horizon_s, child_seed(root, mi as u64 + 1), &mut out, &mut ann);
    }
    out.retain(|e| e.t.is_finite() && e.t >= 0.0);
    // Stable sort: equal timestamps (correlated outages) keep emission order.
    out.sort_by(|a, b| a.t.total_cmp(&b.t));
    ann.sort_by(|a, b| a.announce_t.total_cmp(&b.announce_t));
    DynamicsStream { events: out, announcements: ann }
}

impl DynamicsModel {
    /// Append this model's events over `[0, horizon_s)` (recoveries may
    /// trail past the horizon). `mseed` is the model's key-derived seed;
    /// per-edge streams derive from it by edge id only. Announced
    /// maintenance windows additionally append to `ann`.
    fn emit(
        &self,
        wan: &Wan,
        horizon_s: f64,
        mseed: u64,
        out: &mut Vec<TimedLinkEvent>,
        ann: &mut Vec<AnnouncedWindow>,
    ) {
        match self {
            DynamicsModel::Diurnal { period_s, amplitude, jitter, interval_s } => {
                let period = period_s.max(1e-6);
                let interval = interval_s.max(1e-3);
                for (e, link) in wan.links().iter().enumerate() {
                    let mut lr = Pcg32::new(child_seed(mseed, e as u64 + 1));
                    let phase = lr.uniform(0.0, period);
                    // Per-edge start offset keeps timestamps distinct
                    // across edges.
                    let mut t = lr.uniform(0.05 * interval, interval);
                    let base = link.base_capacity;
                    while t < horizon_s {
                        let wave =
                            0.5 + 0.5 * (std::f64::consts::TAU * (t + phase) / period).sin();
                        let m = (1.0 - amplitude * wave + jitter * lr.gaussian()).clamp(0.05, 1.0);
                        out.push(TimedLinkEvent {
                            t,
                            ev: LinkEvent::SetBandwidth(link.src, link.dst, base * m),
                        });
                        t += interval;
                    }
                }
            }
            DynamicsModel::MarkovFailure { mtbf_s, mttr_s } => {
                for (e, link) in wan.links().iter().enumerate() {
                    // One process per undirected link (Fail/Recover hit
                    // both directions).
                    if link.src >= link.dst {
                        continue;
                    }
                    let mut lr = Pcg32::new(child_seed(mseed, e as u64 + 1));
                    let mut t = lr.exp(mtbf_s.max(1e-3));
                    while t < horizon_s {
                        out.push(TimedLinkEvent { t, ev: LinkEvent::Fail(link.src, link.dst) });
                        // Always emit the recovery, even past the horizon:
                        // a generated stream must never strand a link down
                        // forever.
                        let rec = t + lr.exp(mttr_s.max(1e-3));
                        out.push(TimedLinkEvent {
                            t: rec,
                            ev: LinkEvent::Recover(link.src, link.dst),
                        });
                        t = rec + lr.exp(mtbf_s.max(1e-3));
                    }
                }
            }
            DynamicsModel::RegionalOutage { mtbo_s, outage_s } => {
                if wan.num_nodes() == 0 {
                    return;
                }
                let mut rng = Pcg32::new(child_seed(mseed, 0));
                let mut t = rng.exp(mtbo_s.max(1e-3));
                while t < horizon_s {
                    let site: NodeId = rng.below(wan.num_nodes());
                    let rec = t + outage_s.max(1e-3);
                    for link in wan.links() {
                        // One Fail/Recover per undirected link touching the
                        // site, all sharing the outage timestamp (the
                        // correlation is the point).
                        if link.src < link.dst && (link.src == site || link.dst == site) {
                            out.push(TimedLinkEvent {
                                t,
                                ev: LinkEvent::Fail(link.src, link.dst),
                            });
                            out.push(TimedLinkEvent {
                                t: rec,
                                ev: LinkEvent::Recover(link.src, link.dst),
                            });
                        }
                    }
                    t = rec + rng.exp(mtbo_s.max(1e-3));
                }
            }
            DynamicsModel::Maintenance { period_s, window_s, frac, announced, lead_s } => {
                // Deterministic schedule, no RNG: windows at period/2 +
                // i·period, cycling through undirected links in edge order.
                let undirected: Vec<usize> = (0..wan.num_edges())
                    .filter(|&e| wan.link(e).src < wan.link(e).dst)
                    .collect();
                if undirected.is_empty() {
                    return;
                }
                let period = period_s.max(1e-3);
                let window = window_s.max(1e-3).min(period);
                let frac = frac.clamp(0.0, 1.0);
                let mut t = period * 0.5;
                let mut i = 0usize;
                while t < horizon_s {
                    let e = undirected[i % undirected.len()];
                    let (u, v) = (wan.link(e).src, wan.link(e).dst);
                    for (a, b) in [(u, v), (v, u)] {
                        let Some(de) = wan.edge_between(a, b) else { continue };
                        let base = wan.link(de).base_capacity;
                        out.push(TimedLinkEvent {
                            t,
                            ev: LinkEvent::SetBandwidth(a, b, base * frac),
                        });
                        // Always restore, even past the horizon: a stream
                        // must never strand a link at drained capacity.
                        out.push(TimedLinkEvent {
                            t: t + window,
                            ev: LinkEvent::SetBandwidth(a, b, base),
                        });
                        if *announced {
                            ann.push(AnnouncedWindow {
                                announce_t: (t - lead_s).max(0.0),
                                start_t: t,
                                end_t: t + window,
                                u: a,
                                v: b,
                                gbps: base * frac,
                            });
                        }
                    }
                    t += period;
                    i += 1;
                }
            }
            DynamicsModel::GrayFailure {
                mtbg_s,
                episode_s,
                low_frac,
                churn_interval_s,
                churn_amp,
            } => {
                for (e, link) in wan.links().iter().enumerate() {
                    let mut lr = Pcg32::new(child_seed(mseed, e as u64 + 1));
                    let base = link.base_capacity;
                    let mut t = lr.exp(mtbg_s.max(1e-3));
                    while t < horizon_s {
                        let end = t + episode_s.max(1e-3);
                        let mut s = t;
                        while s < end {
                            let m = (low_frac * (1.0 + churn_amp * lr.gaussian()))
                                .clamp(0.01, 1.0);
                            out.push(TimedLinkEvent {
                                t: s,
                                ev: LinkEvent::SetBandwidth(link.src, link.dst, base * m),
                            });
                            s += churn_interval_s.max(1e-3);
                        }
                        // The episode ends with a full restore (possibly
                        // past the horizon — no stranding at the low mean).
                        out.push(TimedLinkEvent {
                            t: end,
                            ev: LinkEvent::SetBandwidth(link.src, link.dst, base),
                        });
                        t = end + lr.exp(mtbg_s.max(1e-3));
                    }
                }
            }
            DynamicsModel::TraceReplay { events } => {
                out.extend(events.iter().cloned());
            }
        }
    }
}

/// Parse a flat-file WAN trace. One event per line:
///
/// ```text
/// # comments and blank lines are skipped
/// 12.5 fail 0 1
/// 30.0 recover 0 1
/// 45.25 bw 2 3 7.5
/// ```
pub fn parse_trace(text: &str) -> Result<Vec<TimedLinkEvent>, String> {
    let mut out = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split_whitespace().collect();
        let err = |what: &str| format!("trace line {}: {what}: {line:?}", lineno + 1);
        if fields.len() < 2 {
            return Err(err("expected `<t> <kind> ...`"));
        }
        let t: f64 = fields[0].parse().map_err(|_| err("bad timestamp"))?;
        if !t.is_finite() || t < 0.0 {
            return Err(err("timestamp must be finite and non-negative"));
        }
        let node = |i: usize| -> Result<usize, String> {
            fields.get(i).ok_or_else(|| err("missing node"))?.parse().map_err(|_| err("bad node"))
        };
        let ev = match (fields[1], fields.len()) {
            ("fail", 4) => LinkEvent::Fail(node(2)?, node(3)?),
            ("recover", 4) => LinkEvent::Recover(node(2)?, node(3)?),
            ("bw", 5) => {
                let gbps: f64 = fields[4].parse().map_err(|_| err("bad gbps"))?;
                if !gbps.is_finite() || gbps < 0.0 {
                    return Err(err("gbps must be finite and non-negative"));
                }
                LinkEvent::SetBandwidth(node(2)?, node(3)?, gbps)
            }
            _ => return Err(err("expected `fail u v`, `recover u v`, or `bw u v gbps`")),
        };
        out.push(TimedLinkEvent { t, ev });
    }
    out.sort_by(|a, b| a.t.total_cmp(&b.t));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::topologies;

    #[test]
    fn deterministic_given_seed() {
        let wan = topologies::swan();
        for profile in DynamicsProfile::all() {
            let a = generate(&wan, &profile, 200.0, 7);
            let b = generate(&wan, &profile, 200.0, 7);
            assert_eq!(a, b, "profile {} not deterministic", profile.name);
        }
    }

    #[test]
    fn distinct_seeds_differ() {
        let wan = topologies::swan();
        let a = generate(&wan, &DynamicsProfile::diurnal(), 300.0, 1);
        let b = generate(&wan, &DynamicsProfile::diurnal(), 300.0, 2);
        assert!(!a.is_empty());
        assert_ne!(a, b);
    }

    #[test]
    fn calm_is_empty_and_streams_sorted() {
        let wan = topologies::swan();
        assert!(generate(&wan, &DynamicsProfile::calm(), 1000.0, 3).is_empty());
        for profile in DynamicsProfile::all() {
            let evs = generate(&wan, &profile, 500.0, 11);
            for w in evs.windows(2) {
                assert!(w[0].t <= w[1].t, "unsorted: {w:?}");
            }
            for e in &evs {
                assert!(e.t.is_finite() && e.t >= 0.0);
            }
        }
    }

    #[test]
    fn diurnal_stays_within_base_capacity() {
        let wan = topologies::swan();
        let profile = DynamicsProfile {
            name: "d".into(),
            models: vec![DynamicsModel::Diurnal {
                period_s: 60.0,
                amplitude: 0.5,
                jitter: 0.1,
                interval_s: 5.0,
            }],
        };
        let evs = generate(&wan, &profile, 120.0, 9);
        assert!(!evs.is_empty());
        for e in &evs {
            let LinkEvent::SetBandwidth(u, v, gbps) = &e.ev else {
                panic!("diurnal must emit only SetBandwidth, got {e:?}");
            };
            let eid = wan.edge_between(*u, *v).expect("event on real edge");
            let base = wan.link(eid).base_capacity;
            assert!(
                *gbps >= 0.05 * base - 1e-9 && *gbps <= base + 1e-9,
                "gbps {gbps} outside [0.05, 1.0] x base {base}"
            );
        }
    }

    #[test]
    fn markov_alternates_fail_recover_per_link() {
        let wan = topologies::swan();
        let profile = DynamicsProfile {
            name: "m".into(),
            models: vec![DynamicsModel::MarkovFailure { mtbf_s: 40.0, mttr_s: 10.0 }],
        };
        let evs = generate(&wan, &profile, 600.0, 5);
        assert!(!evs.is_empty(), "mtbf 40s over 600s must fail something");
        use std::collections::HashMap;
        let mut down: HashMap<(usize, usize), bool> = HashMap::new();
        for e in &evs {
            match e.ev {
                LinkEvent::Fail(u, v) => {
                    assert!(!down.get(&(u, v)).copied().unwrap_or(false), "double fail {u}-{v}");
                    down.insert((u, v), true);
                }
                LinkEvent::Recover(u, v) => {
                    let was_down = down.get(&(u, v)).copied().unwrap_or(false);
                    assert!(was_down, "recover while up {u}-{v}");
                    down.insert((u, v), false);
                }
                _ => panic!("markov must emit only fail/recover"),
            }
        }
        // Nothing stranded down at stream end.
        assert!(down.values().all(|d| !d), "link left down: {down:?}");
    }

    #[test]
    fn regional_outages_are_correlated() {
        let wan = topologies::swan();
        let profile = DynamicsProfile {
            name: "r".into(),
            models: vec![DynamicsModel::RegionalOutage { mtbo_s: 50.0, outage_s: 10.0 }],
        };
        let evs = generate(&wan, &profile, 600.0, 13);
        let fails: Vec<&TimedLinkEvent> =
            evs.iter().filter(|e| matches!(e.ev, LinkEvent::Fail(..))).collect();
        assert!(!fails.is_empty());
        // Group fails by timestamp: each group must share a common site.
        let mut i = 0;
        while i < fails.len() {
            let t = fails[i].t;
            let mut group = Vec::new();
            while i < fails.len() && fails[i].t == t {
                if let LinkEvent::Fail(u, v) = fails[i].ev {
                    group.push((u, v));
                }
                i += 1;
            }
            let (u0, v0) = group[0];
            let common = group.iter().all(|&(u, v)| u == u0 || v == u0);
            let common2 = group.iter().all(|&(u, v)| u == v0 || v == v0);
            assert!(common || common2, "outage group shares no site: {group:?}");
        }
    }

    #[test]
    fn maintenance_drains_one_link_at_a_time_and_restores() {
        let wan = topologies::swan();
        let stream =
            generate_stream(&wan, &DynamicsProfile::maintenance(), 600.0, 0 /* unused */);
        assert!(!stream.events.is_empty());
        assert!(!stream.announcements.is_empty(), "announced profile must announce");
        // Track drained undirected links over time: never more than one.
        use std::collections::HashSet;
        let mut drained: HashSet<(usize, usize)> = HashSet::new();
        for e in &stream.events {
            let LinkEvent::SetBandwidth(u, v, gbps) = e.ev else {
                panic!("maintenance must emit only SetBandwidth");
            };
            let key = (u.min(v), u.max(v));
            let eid = wan.edge_between(u, v).unwrap();
            let base = wan.link(eid).base_capacity;
            if gbps < base - 1e-9 {
                assert!((gbps - 0.5 * base).abs() < 1e-9, "drain must be half capacity");
                drained.insert(key);
                assert!(drained.len() <= 1, "two links drained at once at t={}", e.t);
            } else {
                drained.remove(&key);
            }
        }
        assert!(drained.is_empty(), "links left drained: {drained:?}");
        // Every announcement leads its window and matches the drain level.
        for a in &stream.announcements {
            assert!(a.announce_t <= a.start_t && a.start_t < a.end_t);
            let eid = wan.edge_between(a.u, a.v).unwrap();
            assert!((a.gbps - 0.5 * wan.link(eid).base_capacity).abs() < 1e-9);
        }
        // The unannounced twin has identical events and no announcements.
        let un = generate_stream(&wan, &DynamicsProfile::maintenance_unannounced(), 600.0, 0);
        assert_eq!(un.events, stream.events);
        assert!(un.announcements.is_empty());
    }

    #[test]
    fn gray_failure_stays_up_and_churns_low() {
        let wan = topologies::swan();
        let stream = generate_stream(&wan, &DynamicsProfile::gray(), 1200.0, 5);
        assert!(!stream.events.is_empty(), "1200 s must produce gray episodes");
        assert!(stream.announcements.is_empty());
        let mut low_samples = 0usize;
        for e in &stream.events {
            let LinkEvent::SetBandwidth(u, v, gbps) = e.ev else {
                panic!("gray failure must never emit Fail/Recover: {e:?}");
            };
            let eid = wan.edge_between(u, v).unwrap();
            let base = wan.link(eid).base_capacity;
            assert!(gbps >= 0.01 * base - 1e-9 && gbps <= base + 1e-9, "{gbps} vs base {base}");
            if gbps < 0.5 * base {
                low_samples += 1;
            }
        }
        assert!(low_samples > 0, "gray episodes must actually collapse bandwidth");
        // Determinism, like every other model.
        let again = generate_stream(&wan, &DynamicsProfile::gray(), 1200.0, 5);
        assert_eq!(stream.events, again.events);
    }

    #[test]
    fn generate_matches_generate_stream_events() {
        let wan = topologies::swan();
        for profile in DynamicsProfile::all() {
            let a = generate(&wan, &profile, 300.0, 11);
            let b = generate_stream(&wan, &profile, 300.0, 11).events;
            assert_eq!(a, b, "profile {}", profile.name);
        }
    }

    #[test]
    fn trace_roundtrip_and_errors() {
        let text = "# demo\n0.5 fail 0 1\n\n2 bw 1 2 7.5\n10 recover 0 1\n";
        let evs = parse_trace(text).unwrap();
        assert_eq!(
            evs,
            vec![
                TimedLinkEvent { t: 0.5, ev: LinkEvent::Fail(0, 1) },
                TimedLinkEvent { t: 2.0, ev: LinkEvent::SetBandwidth(1, 2, 7.5) },
                TimedLinkEvent { t: 10.0, ev: LinkEvent::Recover(0, 1) },
            ]
        );
        assert!(parse_trace("abc fail 0 1").is_err());
        assert!(parse_trace("1.0 explode 0 1").is_err());
        assert!(parse_trace("1.0 bw 0 1").is_err());
        assert!(parse_trace("-1 fail 0 1").is_err());
        // Replay is verbatim — the horizon must NOT truncate a trace (the
        // recovery at t=10 > horizon=5 must survive, or link 0-1 would be
        // stranded down).
        let wan = topologies::fig1a();
        let profile = DynamicsProfile {
            name: "t".into(),
            models: vec![DynamicsModel::TraceReplay { events: evs.clone() }],
        };
        let replayed = generate(&wan, &profile, 5.0, 0);
        assert_eq!(replayed, evs);
    }
}
