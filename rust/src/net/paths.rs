//! Shortest-path machinery: Dijkstra and Yen's k-shortest loopless paths.
//!
//! Terra restricts each FlowGroup to the k shortest paths between its
//! datacenter pair (k = 15 by default, §4.3) and re-computes the viable path
//! sets when the WAN changes (§4.4).

use super::topology::{EdgeId, NodeId, Wan};
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// A loopless path: edge ids plus the summed latency metric.
#[derive(Clone, Debug, PartialEq)]
pub struct Path {
    pub edges: Vec<EdgeId>,
    pub latency_ms: f64,
}

impl Path {
    pub fn nodes(&self, wan: &Wan) -> Vec<NodeId> {
        let mut ns = Vec::with_capacity(self.edges.len() + 1);
        if let Some(&e0) = self.edges.first() {
            ns.push(wan.link(e0).src);
        }
        for &e in &self.edges {
            ns.push(wan.link(e).dst);
        }
        ns
    }

    /// Bottleneck available capacity along the path.
    pub fn bottleneck(&self, wan: &Wan) -> f64 {
        self.edges.iter().map(|&e| wan.link(e).avail()).fold(f64::INFINITY, f64::min)
    }

    pub fn hops(&self) -> usize {
        self.edges.len()
    }
}

#[derive(PartialEq)]
struct HeapEntry {
    dist: f64,
    node: NodeId,
}

impl Eq for HeapEntry {}
impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap on distance, then on node id: equal-distance nodes pop
        // in id order so tie-breaking never depends on heap internals.
        other
            .dist
            .total_cmp(&self.dist)
            .then_with(|| other.node.cmp(&self.node))
    }
}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Dijkstra shortest path by latency over up links, with optional banned
/// edges/nodes (used by Yen's spur computation). Returns `None` when `dst`
/// is unreachable.
pub fn dijkstra(
    wan: &Wan,
    src: NodeId,
    dst: NodeId,
    banned_edges: &[bool],
    banned_nodes: &[bool],
) -> Option<Path> {
    let n = wan.num_nodes();
    let mut dist = vec![f64::INFINITY; n];
    let mut prev_edge: Vec<Option<EdgeId>> = vec![None; n];
    let mut heap = BinaryHeap::new();
    dist[src] = 0.0;
    heap.push(HeapEntry { dist: 0.0, node: src });
    while let Some(HeapEntry { dist: d, node: u }) = heap.pop() {
        if u == dst {
            break;
        }
        if d > dist[u] {
            continue;
        }
        for &e in wan.out_edges(u) {
            let l = wan.link(e);
            if !l.up || l.avail() <= 0.0 || banned_edges.get(e).copied().unwrap_or(false) {
                continue;
            }
            let v = l.dst;
            if banned_nodes.get(v).copied().unwrap_or(false) {
                continue;
            }
            let nd = d + l.latency_ms;
            if nd < dist[v] - 1e-12 {
                dist[v] = nd;
                prev_edge[v] = Some(e);
                heap.push(HeapEntry { dist: nd, node: v });
            } else if (nd - dist[v]).abs() <= 1e-12 {
                // Equal-cost tie: keep the predecessor with the smaller
                // node id, so ties resolve to the same (low-node-id) route
                // in both directions and across runs.
                if let Some(pe) = prev_edge[v] {
                    if u < wan.link(pe).src {
                        prev_edge[v] = Some(e);
                    }
                }
            }
        }
    }
    if dist[dst].is_infinite() {
        return None;
    }
    let mut edges = Vec::new();
    let mut cur = dst;
    while cur != src {
        let e = prev_edge[cur]?;
        edges.push(e);
        cur = wan.link(e).src;
    }
    edges.reverse();
    Some(Path { edges, latency_ms: dist[dst] })
}

/// Yen's algorithm: up to `k` loopless shortest paths from `src` to `dst`
/// ordered by `(latency, node-id sequence)`. Returns fewer when the graph
/// has fewer distinct paths. The ordering is fully deterministic: among
/// the enumerated paths, equal-latency ties are broken by lexicographic
/// node sequence, and Dijkstra itself prefers the lower-node-id
/// predecessor on exact-cost ties (a local rule — it yields the
/// lexicographically-smallest route when equal-cost alternatives differ in
/// one intermediate node, as in ring-like topologies, though not for
/// arbitrarily long equal-cost detours).
pub fn k_shortest_paths(wan: &Wan, src: NodeId, dst: NodeId, k: usize) -> Vec<Path> {
    if src == dst || k == 0 {
        return Vec::new();
    }
    let no_edges = vec![false; wan.num_edges()];
    let no_nodes = vec![false; wan.num_nodes()];
    let first = match dijkstra(wan, src, dst, &no_edges, &no_nodes) {
        Some(p) => p,
        None => return Vec::new(),
    };
    let mut found = vec![first];
    let mut candidates: Vec<Path> = Vec::new();
    for _ in 1..k {
        let last = found.last().unwrap().clone();
        let last_nodes = last.nodes(wan);
        // Spur from each node of the previous path.
        for i in 0..last.edges.len() {
            let spur_node = last_nodes[i];
            let root_edges = &last.edges[..i];
            let mut banned_edges = vec![false; wan.num_edges()];
            // Ban edges that would recreate an already-found path with the
            // same root.
            for p in found.iter().chain(candidates.iter()) {
                if p.edges.len() > i && p.edges[..i] == *root_edges {
                    banned_edges[p.edges[i]] = true;
                }
            }
            // Ban root nodes (looplessness).
            let mut banned_nodes = vec![false; wan.num_nodes()];
            for &nd in &last_nodes[..i] {
                banned_nodes[nd] = true;
            }
            if let Some(spur) = dijkstra(wan, spur_node, dst, &banned_edges, &banned_nodes) {
                let mut edges = root_edges.to_vec();
                edges.extend(&spur.edges);
                let latency_ms: f64 = edges.iter().map(|&e| wan.link(e).latency_ms).sum();
                let cand = Path { edges, latency_ms };
                if !found.contains(&cand) && !candidates.contains(&cand) {
                    candidates.push(cand);
                }
            }
        }
        if candidates.is_empty() {
            break;
        }
        // Pop the best candidate; equal-latency candidates tie-break by
        // their node sequence, so the k-list order is stable across runs
        // and independent of spur enumeration order.
        let best = candidates
            .iter()
            .enumerate()
            .min_by(|a, b| {
                a.1.latency_ms
                    .total_cmp(&b.1.latency_ms)
                    .then_with(|| a.1.nodes(wan).cmp(&b.1.nodes(wan)))
            })
            .map(|(i, _)| i)
            .unwrap();
        found.push(candidates.swap_remove(best));
    }
    // Yen discovers in non-decreasing latency; this stable sort only
    // normalizes the order *within* equal-latency runs to the node-sequence
    // order, so the returned list is a pure function of the graph.
    found.sort_by(|a, b| {
        a.latency_ms.total_cmp(&b.latency_ms).then_with(|| a.nodes(wan).cmp(&b.nodes(wan)))
    });
    found
}

/// Path sets for every ordered datacenter pair: `paths[u][v]` holds up to `k`
/// paths. Recomputed on topology changes (§4.4).
#[derive(Clone, Debug, Default)]
pub struct PathSet {
    pub k: usize,
    pub paths: Vec<Vec<Vec<Path>>>,
}

impl PathSet {
    pub fn compute(wan: &Wan, k: usize) -> PathSet {
        let n = wan.num_nodes();
        let mut paths = vec![vec![Vec::new(); n]; n];
        for u in 0..n {
            for (v, slot) in paths[u].iter_mut().enumerate() {
                if u != v {
                    *slot = k_shortest_paths(wan, u, v, k);
                }
            }
        }
        PathSet { k, paths }
    }

    pub fn get(&self, u: NodeId, v: NodeId) -> &[Path] {
        &self.paths[u][v]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fig 1a-style topology: A, B, C fully meshed.
    fn fig1a() -> Wan {
        let mut w = Wan::new();
        let a = w.add_node("A", 0.0, 0.0);
        let b = w.add_node("B", 0.0, 1.0);
        let c = w.add_node("C", 1.0, 0.0);
        w.add_link(a, b, 10.0, Some(1.0));
        w.add_link(b, c, 10.0, Some(1.0));
        w.add_link(a, c, 10.0, Some(1.0));
        w
    }

    #[test]
    fn dijkstra_direct() {
        let w = fig1a();
        let p = dijkstra(&w, 0, 1, &vec![false; 6], &vec![false; 3]).unwrap();
        assert_eq!(p.hops(), 1);
        assert!((p.latency_ms - 1.0).abs() < 1e-9);
        assert_eq!(p.nodes(&w), vec![0, 1]);
    }

    #[test]
    fn dijkstra_respects_down_links() {
        let mut w = fig1a();
        w.apply_event(&crate::net::LinkEvent::Fail(0, 1));
        let p = dijkstra(&w, 0, 1, &vec![false; 6], &vec![false; 3]).unwrap();
        assert_eq!(p.hops(), 2); // A -> C -> B
        assert_eq!(p.nodes(&w), vec![0, 2, 1]);
    }

    #[test]
    fn yen_finds_both_paths() {
        let w = fig1a();
        let ps = k_shortest_paths(&w, 0, 1, 5);
        assert_eq!(ps.len(), 2); // direct + via C; no more loopless options
        assert_eq!(ps[0].hops(), 1);
        assert_eq!(ps[1].hops(), 2);
        assert!(ps[0].latency_ms <= ps[1].latency_ms);
    }

    #[test]
    fn yen_k1_is_dijkstra() {
        let w = fig1a();
        let ps = k_shortest_paths(&w, 0, 2, 1);
        assert_eq!(ps.len(), 1);
        assert_eq!(ps[0].hops(), 1);
    }

    #[test]
    fn yen_on_square_with_diagonal() {
        // 4-node ring + diagonal gives >= 3 loopless A->C paths.
        let mut w = Wan::new();
        for (i, name) in ["A", "B", "C", "D"].iter().enumerate() {
            w.add_node(name, 0.0, i as f64);
        }
        w.add_link(0, 1, 10.0, Some(1.0));
        w.add_link(1, 2, 10.0, Some(1.0));
        w.add_link(2, 3, 10.0, Some(1.0));
        w.add_link(3, 0, 10.0, Some(1.0));
        w.add_link(0, 2, 10.0, Some(5.0)); // slow diagonal
        let ps = k_shortest_paths(&w, 0, 2, 10);
        assert_eq!(ps.len(), 3);
        // paths sorted by latency: A-B-C (2), A-D-C (2), A-C (5)
        assert!(ps[0].latency_ms <= ps[1].latency_ms && ps[1].latency_ms <= ps[2].latency_ms);
        assert_eq!(ps[2].hops(), 1);
        // All loopless.
        for p in &ps {
            let nodes = p.nodes(&w);
            let mut dedup = nodes.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), nodes.len(), "loop in {nodes:?}");
        }
    }

    #[test]
    fn pathset_covers_all_pairs() {
        let w = fig1a();
        let ps = PathSet::compute(&w, 3);
        for u in 0..3 {
            for v in 0..3 {
                if u != v {
                    assert!(!ps.get(u, v).is_empty());
                }
            }
        }
        assert!(ps.get(1, 1).is_empty());
    }

    /// 4-node ring with uniform latencies: the two 0→2 routes (via 1, via
    /// 3) are exactly equal-cost, so only the tie-break decides the order.
    fn uniform_ring() -> Wan {
        let mut w = Wan::new();
        for (i, name) in ["A", "B", "C", "D"].iter().enumerate() {
            w.add_node(name, 0.0, i as f64);
        }
        w.add_link(0, 1, 10.0, Some(1.0));
        w.add_link(1, 2, 10.0, Some(1.0));
        w.add_link(2, 3, 10.0, Some(1.0));
        w.add_link(3, 0, 10.0, Some(1.0));
        w
    }

    #[test]
    fn equal_cost_ties_break_by_node_sequence() {
        let w = uniform_ring();
        let ps = k_shortest_paths(&w, 0, 2, 5);
        assert_eq!(ps.len(), 2);
        // Lexicographically smaller node sequence first: via B (node 1),
        // then via D (node 3).
        assert_eq!(ps[0].nodes(&w), vec![0, 1, 2]);
        assert_eq!(ps[1].nodes(&w), vec![0, 3, 2]);
    }

    #[test]
    fn tie_break_is_stable_across_runs_and_directions() {
        let w = uniform_ring();
        let forward = k_shortest_paths(&w, 0, 2, 5);
        for _ in 0..20 {
            assert_eq!(k_shortest_paths(&w, 0, 2, 5), forward, "run-to-run divergence");
        }
        // Reverse direction resolves the same ties: each reverse path is
        // the mirror of the forward path at the same rank.
        let reverse = k_shortest_paths(&w, 2, 0, 5);
        assert_eq!(forward.len(), reverse.len());
        for (f, r) in forward.iter().zip(&reverse) {
            let mut mirrored = r.nodes(&w);
            mirrored.reverse();
            assert_eq!(f.nodes(&w), mirrored, "directions disagree on a tie");
            assert!((f.latency_ms - r.latency_ms).abs() < 1e-12);
        }
        // Full path sets agree with the pairwise calls (PathSet is just a
        // cache of them).
        let ps = PathSet::compute(&w, 5);
        assert_eq!(ps.get(0, 2), &forward[..]);
    }

    #[test]
    fn unreachable_returns_empty() {
        let mut w = Wan::new();
        w.add_node("A", 0.0, 0.0);
        w.add_node("B", 0.0, 1.0);
        assert!(k_shortest_paths(&w, 0, 1, 3).is_empty());
    }

    #[test]
    fn path_bottleneck() {
        let mut w = Wan::new();
        let a = w.add_node("A", 0.0, 0.0);
        let b = w.add_node("B", 0.0, 1.0);
        let c = w.add_node("C", 0.0, 2.0);
        w.add_link(a, b, 10.0, Some(1.0));
        w.add_link(b, c, 3.0, Some(1.0));
        let p = dijkstra(&w, 0, 2, &vec![false; 4], &vec![false; 3]).unwrap();
        assert!((p.bottleneck(&w) - 3.0).abs() < 1e-9);
    }
}
