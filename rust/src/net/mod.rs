//! WAN substrate: the inter-datacenter network model Terra optimizes over.
//!
//! The paper models the WAN as a directed graph `G = (V, E)` where nodes are
//! datacenters and a logical link `(u, v)` aggregates all physical links
//! between `u` and `v` with their cumulative bandwidth (§3.1). This module
//! provides the graph type, the three evaluation topologies (SWAN, G-Scale,
//! AT&T), geographic latencies, gravity-model capacity estimation, k-shortest
//! path computation (Yen's algorithm), the WAN event model (link
//! failures / bandwidth fluctuations), and seeded generators of realistic
//! WAN dynamics streams ([`dynamics`]).

pub mod dynamics;
pub mod paths;
pub mod telemetry;
pub mod topologies;
pub mod topology;

pub use dynamics::{AnnouncedWindow, DynamicsModel, DynamicsProfile, TimedLinkEvent};
pub use telemetry::{CapacityEstimator, EstimatorKind, TelemetryConfig};
pub use topology::{EdgeId, LinkEvent, NodeId, Wan};
pub use paths::Path;
