//! Active-probe planning: which edges deserve a probe *now*.
//!
//! Passive sampling only sees edges that carry traffic, and even there the
//! observation is censored by the sender's own allocation. Edges that are
//! idle — or whose senders are allocated far below capacity — age without
//! informative observations; once an edge's belief is older than the
//! configured staleness threshold, the controller should spend a probe on
//! it. The planner is shared by the simulator (which "probes" by reading
//! ground truth) and the overlay controller (which asks the source agent to
//! burst probe chunks on the edge's direct path).

use super::CapacityEstimator;
use crate::net::{EdgeId, Wan};

/// Edges whose belief has had no informative observation for at least
/// `probe_after_s`, restricted to up links (a failed link is structurally
/// known to be down — probing it is wasted work). Ascending edge order, so
/// probe issue order is deterministic. Returns nothing for oracle
/// estimators or a non-positive threshold.
pub fn stale_edges(
    est: &CapacityEstimator,
    wan: &Wan,
    now: f64,
    probe_after_s: f64,
) -> Vec<EdgeId> {
    if est.is_oracle() || probe_after_s <= 0.0 {
        return Vec::new();
    }
    (0..wan.num_edges())
        .filter(|&e| {
            wan.link(e).up
                && !est.is_pinned(e, now)
                && now - est.last_obs(e) >= probe_after_s
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::telemetry::{EstimatorKind, TelemetryConfig};
    use crate::net::{topologies, LinkEvent};

    #[test]
    fn stale_edges_age_and_reset_on_observation() {
        let wan = topologies::fig1a();
        let cfg = TelemetryConfig {
            estimator: EstimatorKind::Ewma { alpha: 0.3 },
            ..TelemetryConfig::oracle()
        };
        let mut est = CapacityEstimator::new(&cfg, &wan.capacities());
        // At t=10 with threshold 5, everything is stale.
        let stale = stale_edges(&est, &wan, 10.0, 5.0);
        assert_eq!(stale.len(), wan.num_edges());
        assert!(stale.windows(2).all(|w| w[0] < w[1]), "must be ascending");
        // Observing edge 0 freshens it.
        est.probe(0, 9.0, 10.0);
        assert!(!stale_edges(&est, &wan, 11.0, 5.0).contains(&0));
        // Down links are never probed.
        let mut wan2 = wan.clone();
        wan2.apply_event(&LinkEvent::Fail(0, 1));
        let e = wan2.edge_between(0, 1).unwrap();
        assert!(!stale_edges(&est, &wan2, 100.0, 5.0).contains(&e));
        // Nor are edges pinned by an announced prior — probing them would
        // be wasted (the estimator ignores the result anyway).
        est.prior_hold(1, 5.0, 10.0, 200.0);
        assert!(!stale_edges(&est, &wan, 100.0, 5.0).contains(&1));
        assert!(stale_edges(&est, &wan, 300.0, 5.0).contains(&1), "pin must expire");
    }

    #[test]
    fn oracle_and_disabled_probing_return_nothing() {
        let wan = topologies::fig1a();
        let est = CapacityEstimator::new(&TelemetryConfig::oracle(), &wan.capacities());
        assert!(stale_edges(&est, &wan, 100.0, 5.0).is_empty());
        let cfg = TelemetryConfig {
            estimator: EstimatorKind::Ewma { alpha: 0.3 },
            ..TelemetryConfig::oracle()
        };
        let est = CapacityEstimator::new(&cfg, &wan.capacities());
        assert!(stale_edges(&est, &wan, 100.0, 0.0).is_empty());
    }
}
