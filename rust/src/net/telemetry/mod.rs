//! WAN telemetry & capacity estimation: schedule on **beliefs**, not
//! oracles.
//!
//! Every earlier revision of this repo cheated on the paper's hardest
//! operational problem: `net/dynamics` handed the scheduler the *true* new
//! capacity of every link, so ρ-dampening and re-optimization were evaluated
//! against an oracle no real deployment has. Gauging runtime WAN bandwidth
//! is itself the hard problem in GDA (WANify), and allocation quality
//! degrades sharply when the controller's bandwidth view lags reality
//! (Aljoby et al.). This module closes that gap:
//!
//! - agents (and the simulator standing in for them) **passively sample**
//!   achieved per-path throughput — which is capped by their own allocation,
//!   the classic *you cannot see capacity you are not using* problem;
//! - controllers optionally issue **active probes** for edges whose belief
//!   has gone stale (the probe path exists precisely to see past the
//!   allocation cap on idle links);
//! - a per-edge [`CapacityEstimator`] fuses those samples into a capacity
//!   *belief* — a mean with an uncertainty band — under a pluggable
//!   [`EstimatorKind`] (`Oracle`, `Ewma`, `KalmanLite`, `HoldDown`);
//! - the scheduler consumes `cap_used = max(0, mean − k·σ)`: the
//!   **headroom factor** `k` trades utilization for feasibility under
//!   estimation error (allocations computed against an optimistic belief
//!   oversubscribe the real link and stall).
//!
//! [`EstimatorKind::Oracle`] is the default and is **bit-identical** to the
//! pre-telemetry behavior: every observation is a no-op, belief refreshes
//! report nothing, and WAN events flow straight into the engine's WAN
//! exactly as before — all committed golden traces survive un-re-blessed.

pub mod estimator;
pub mod probe;

pub use estimator::{CapacityEstimator, EstimatorKind};
pub use probe::stale_edges;

/// Telemetry / estimation knobs shared by the simulator, the overlay
/// controller, and the engine.
#[derive(Clone, Debug)]
pub struct TelemetryConfig {
    /// How observations fuse into capacity beliefs.
    pub estimator: EstimatorKind,
    /// Headroom factor `k`: the scheduler uses `max(0, mean − k·σ)` as an
    /// edge's capacity, keeping allocations feasible under estimation
    /// error. 0 schedules on the raw mean.
    pub headroom_k: f64,
    /// Passive-sampling period (simulated seconds on the sim plane, wall
    /// seconds on the testbed plane).
    pub sample_interval_s: f64,
    /// Probe an edge when its belief has had no observation for this long
    /// (idle edges are invisible to passive sampling). `0` disables active
    /// probing.
    pub probe_after_s: f64,
}

impl TelemetryConfig {
    /// The oracle default: truth flows straight through, estimation is
    /// inert, behavior is bit-identical to the pre-telemetry engine.
    pub fn oracle() -> TelemetryConfig {
        TelemetryConfig {
            estimator: EstimatorKind::Oracle,
            headroom_k: 0.0,
            sample_interval_s: 1.0,
            probe_after_s: 5.0,
        }
    }

    /// Named estimator presets for sweeps and the CLI: `oracle`, `ewma`,
    /// `kalman`, `holddown`.
    pub fn by_name(name: &str) -> Option<TelemetryConfig> {
        let estimator = match name.to_ascii_lowercase().as_str() {
            "oracle" | "none" | "truth" => EstimatorKind::Oracle,
            "ewma" => EstimatorKind::Ewma { alpha: 0.3 },
            "kalman" | "kalmanlite" | "kalman-lite" => {
                EstimatorKind::KalmanLite { process_var: 0.5, obs_var: 1.0 }
            }
            "holddown" | "hold-down" => EstimatorKind::HoldDown { hysteresis: 0.3, alpha: 0.3 },
            _ => return None,
        };
        let headroom_k = if matches!(estimator, EstimatorKind::Oracle) { 0.0 } else { 1.0 };
        Some(TelemetryConfig { estimator, headroom_k, ..TelemetryConfig::oracle() })
    }

    /// All preset names, in sweep order.
    pub fn preset_names() -> [&'static str; 4] {
        ["oracle", "ewma", "kalman", "holddown"]
    }

    pub fn is_oracle(&self) -> bool {
        matches!(self.estimator, EstimatorKind::Oracle)
    }
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig::oracle()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve_and_default_is_oracle() {
        assert!(TelemetryConfig::default().is_oracle());
        for name in TelemetryConfig::preset_names() {
            let cfg = TelemetryConfig::by_name(name).unwrap();
            assert_eq!(cfg.is_oracle(), name == "oracle", "{name}");
        }
        assert!(TelemetryConfig::by_name("nope").is_none());
    }
}
