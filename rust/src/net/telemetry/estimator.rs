//! Per-edge capacity belief fusion.
//!
//! The estimator keeps one belief per directed WAN edge: a capacity mean,
//! an uncertainty (variance), and the time of the last informative
//! observation. Observations arrive in three flavors:
//!
//! - **capped throughput** (`observe` with `capped = true`): the sender
//!   asked for more than it achieved — the link limited it, so the achieved
//!   rate *is* a direct capacity measurement;
//! - **censored throughput** (`observe` with `capped = false`): the sender
//!   achieved everything it asked for — the sample is only a *lower bound*
//!   (capacity ≥ achieved). A lower bound above the current mean raises the
//!   belief; one below it carries no information and deliberately does
//!   **not** refresh the observation clock, so the edge ages toward the
//!   probe threshold (you cannot see capacity you are not using);
//! - **probes / priors** (`probe`, `prior`): direct measurements from
//!   active probing or announced maintenance windows.
//!
//! [`EstimatorKind::Oracle`] disables all of it: every method is a no-op
//! and the scheduler keeps consuming ground truth, bit-identical to the
//! pre-telemetry engine.

use super::TelemetryConfig;

/// Consecutive out-of-band samples a [`EstimatorKind::HoldDown`] belief
/// requires before committing to a new level.
const HOLD_COUNT: u32 = 3;

/// How observations fuse into a belief. All parameters are unitless or in
/// Gbps² as noted.
#[derive(Clone, Debug, PartialEq)]
pub enum EstimatorKind {
    /// Ground truth flows straight through; estimation is inert. The
    /// default, bit-identical to the pre-telemetry engine.
    Oracle,
    /// Exponentially weighted moving average with EW variance tracking.
    /// Reacts in O(1/α) samples; jittery under gray failures.
    Ewma { alpha: f64 },
    /// One-dimensional Kalman filter: `process_var` (Gbps²/s) grows the
    /// prediction variance between observations, `obs_var` (Gbps²) is the
    /// measurement noise. Smooths jitter while staying responsive after
    /// long gaps (stale beliefs have high variance, so the next sample
    /// moves them a lot).
    KalmanLite { process_var: f64, obs_var: f64 },
    /// EWMA with hysteresis: fluctuation within `hysteresis` (fractional)
    /// of the mean is smoothed; a larger level shift must persist for
    /// [`HOLD_COUNT`] consecutive samples on the same side before the
    /// belief jumps. Damps gray-failure flapping at the cost of reaction
    /// latency.
    HoldDown { hysteresis: f64, alpha: f64 },
}

/// One edge's capacity belief.
#[derive(Clone, Debug)]
struct Belief {
    mean: f64,
    var: f64,
    /// Time of the last informative observation (censored low samples do
    /// not count — see the module docs).
    last_obs_t: f64,
    /// Hold-down candidate level and its consecutive-sample count.
    pending: f64,
    pending_n: u32,
    /// While `now < pinned_until`, the belief is held by an announced
    /// prior ([`CapacityEstimator::prior_hold`]): samples and probes are
    /// ignored — the operator's announcement outranks measurements for
    /// its stated window (otherwise a pre-drain prior would be "corrected"
    /// back to base by the first probe of the still-undrained link).
    pinned_until: f64,
}

/// Per-edge capacity beliefs with dirty-tracking, sized to a WAN's directed
/// edge set. See the module docs for the observation model.
#[derive(Clone, Debug)]
pub struct CapacityEstimator {
    kind: EstimatorKind,
    headroom_k: f64,
    beliefs: Vec<Belief>,
    /// Edges whose belief changed since the last [`Self::take_dirty`].
    dirty: Vec<bool>,
    any_dirty: bool,
    /// Latest observation timestamp seen (monotone); lets callers without a
    /// clock (structural resets) stamp sensibly.
    clock: f64,
}

impl CapacityEstimator {
    /// Build an estimator with `initial_caps` (the WAN's current available
    /// capacities) as the prior belief, variance 0.
    pub fn new(cfg: &TelemetryConfig, initial_caps: &[f64]) -> CapacityEstimator {
        let beliefs = if cfg.is_oracle() {
            Vec::new()
        } else {
            initial_caps
                .iter()
                .map(|&c| Belief {
                    mean: c,
                    var: 0.0,
                    last_obs_t: 0.0,
                    pending: 0.0,
                    pending_n: 0,
                    pinned_until: f64::NEG_INFINITY,
                })
                .collect()
        };
        let dirty = vec![false; beliefs.len()];
        CapacityEstimator {
            kind: cfg.estimator.clone(),
            headroom_k: cfg.headroom_k,
            beliefs,
            dirty,
            any_dirty: false,
            clock: 0.0,
        }
    }

    pub fn is_oracle(&self) -> bool {
        matches!(self.kind, EstimatorKind::Oracle)
    }

    pub fn kind(&self) -> &EstimatorKind {
        &self.kind
    }

    /// Latest observation timestamp seen.
    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// Passive throughput sample on edge `e`: `achieved` Gbps, with
    /// `capped = true` when the link limited the sender (achieved < asked).
    /// Ignored while the edge is pinned by an announced prior.
    pub fn observe(&mut self, e: usize, achieved: f64, capped: bool, now: f64) {
        if self.is_oracle() || e >= self.beliefs.len() || !achieved.is_finite() || achieved < 0.0 {
            return;
        }
        self.clock = self.clock.max(now);
        if self.is_pinned(e, now) {
            return;
        }
        if capped {
            self.fuse(e, achieved, now);
        } else if achieved > self.beliefs[e].mean {
            // Censored sample above the mean: capacity is at least this.
            self.fuse(e, achieved, now);
        }
        // Censored sample at or below the mean: no information, and no
        // clock refresh — let the edge age toward the probe threshold.
    }

    /// Active probe result (or any other direct capacity measurement).
    /// Ignored while the edge is pinned by an announced prior.
    pub fn probe(&mut self, e: usize, measured: f64, now: f64) {
        if self.is_oracle() || e >= self.beliefs.len() || !measured.is_finite() || measured < 0.0 {
            return;
        }
        self.clock = self.clock.max(now);
        if self.is_pinned(e, now) {
            return;
        }
        self.fuse(e, measured, now);
    }

    /// Authoritative prior (operator-fed measurement): the belief jumps to
    /// `gbps` with zero variance — the operator told us.
    pub fn prior(&mut self, e: usize, gbps: f64, now: f64) {
        self.prior_hold(e, gbps, now, now);
    }

    /// [`CapacityEstimator::prior`] that additionally **pins** the belief
    /// until `hold_until`: samples and probes on the edge are ignored for
    /// the window's stated duration — an announced pre-drain must not be
    /// "corrected" back to base by a probe of the not-yet-drained link.
    pub fn prior_hold(&mut self, e: usize, gbps: f64, now: f64, hold_until: f64) {
        if self.is_oracle() || e >= self.beliefs.len() || !gbps.is_finite() || gbps < 0.0 {
            return;
        }
        self.clock = self.clock.max(now);
        let b = &mut self.beliefs[e];
        b.mean = gbps;
        b.var = 0.0;
        b.pending_n = 0;
        b.last_obs_t = now;
        b.pinned_until = if hold_until.is_finite() { hold_until } else { now };
        self.mark_dirty(e);
    }

    /// True while edge `e`'s belief is held by an announced prior.
    pub fn is_pinned(&self, e: usize, now: f64) -> bool {
        self.beliefs.get(e).map(|b| now < b.pinned_until).unwrap_or(false)
    }

    /// Reset one edge's belief (structural recovery restores base
    /// capacity; the event itself is observable, so the belief is
    /// authoritative). Clears any announced-window pin — the window's
    /// premise died with the failure.
    pub fn reset_edge(&mut self, e: usize, cap: f64, now: f64) {
        self.prior(e, cap, now);
    }

    /// Current belief mean for edge `e` (Gbps).
    pub fn mean(&self, e: usize) -> f64 {
        self.beliefs.get(e).map(|b| b.mean).unwrap_or(0.0)
    }

    /// Current belief standard deviation for edge `e` (Gbps).
    pub fn sigma(&self, e: usize) -> f64 {
        self.beliefs.get(e).map(|b| b.var.max(0.0).sqrt()).unwrap_or(0.0)
    }

    /// The capacity the scheduler should plan against:
    /// `max(0, mean − k·σ)` — the headroom keeps allocations feasible under
    /// estimation error.
    pub fn cap_used(&self, e: usize) -> f64 {
        (self.mean(e) - self.headroom_k * self.sigma(e)).max(0.0)
    }

    /// Timestamp of the last informative observation on edge `e`.
    pub fn last_obs(&self, e: usize) -> f64 {
        self.beliefs.get(e).map(|b| b.last_obs_t).unwrap_or(0.0)
    }

    /// Drain the edges whose belief changed since the last call, in
    /// ascending edge order (deterministic refresh order).
    pub fn take_dirty(&mut self) -> Vec<usize> {
        if !self.any_dirty {
            return Vec::new();
        }
        self.any_dirty = false;
        let mut out = Vec::new();
        for (e, d) in self.dirty.iter_mut().enumerate() {
            if *d {
                *d = false;
                out.push(e);
            }
        }
        out
    }

    fn mark_dirty(&mut self, e: usize) {
        self.dirty[e] = true;
        self.any_dirty = true;
    }

    /// Fuse a direct capacity measurement `x` into edge `e`'s belief.
    fn fuse(&mut self, e: usize, x: f64, now: f64) {
        let b = &mut self.beliefs[e];
        match self.kind {
            EstimatorKind::Oracle => return,
            EstimatorKind::Ewma { alpha } => {
                let d = x - b.mean;
                b.mean += alpha * d;
                b.var = (1.0 - alpha) * (b.var + alpha * d * d);
            }
            EstimatorKind::KalmanLite { process_var, obs_var } => {
                let dt = (now - b.last_obs_t).max(0.0);
                let var = b.var + process_var * dt;
                let gain = var / (var + obs_var.max(1e-12));
                b.mean += gain * (x - b.mean);
                b.var = (1.0 - gain) * var;
            }
            EstimatorKind::HoldDown { hysteresis, alpha } => {
                let rel = (x - b.mean).abs() / b.mean.max(1e-9);
                if rel < hysteresis {
                    // In-band: smooth, and drop any pending level shift —
                    // the link came back inside the band.
                    let d = x - b.mean;
                    b.mean += alpha * d;
                    b.var = (1.0 - alpha) * (b.var + alpha * d * d);
                    b.pending_n = 0;
                } else {
                    let same_side = b.pending_n > 0
                        && (x - b.mean).signum() == (b.pending - b.mean).signum();
                    if same_side {
                        b.pending += alpha * (x - b.pending);
                        b.pending_n += 1;
                    } else {
                        b.pending = x;
                        b.pending_n = 1;
                    }
                    if b.pending_n >= HOLD_COUNT {
                        let d = b.pending - b.mean;
                        b.mean = b.pending;
                        b.var = (1.0 - alpha) * (b.var + alpha * d * d);
                        b.pending_n = 0;
                    } else {
                        // Out-of-band but unconfirmed: belief unchanged.
                        b.last_obs_t = now;
                        return;
                    }
                }
            }
        }
        b.mean = b.mean.max(0.0);
        b.last_obs_t = now;
        self.mark_dirty(e);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(kind: EstimatorKind, k: f64) -> TelemetryConfig {
        TelemetryConfig { estimator: kind, headroom_k: k, ..TelemetryConfig::oracle() }
    }

    #[test]
    fn oracle_is_inert() {
        let mut est = CapacityEstimator::new(&TelemetryConfig::oracle(), &[10.0, 10.0]);
        est.observe(0, 3.0, true, 1.0);
        est.probe(1, 3.0, 1.0);
        est.prior(0, 3.0, 1.0);
        assert!(est.take_dirty().is_empty());
        assert_eq!(est.mean(0), 0.0); // no beliefs held at all
    }

    /// EWMA convergence bound: after n direct samples of a step to `target`,
    /// the residual error is (1-α)^n of the step.
    #[test]
    fn ewma_converges_within_geometric_bound() {
        let alpha = 0.3;
        let mut est = CapacityEstimator::new(&cfg(EstimatorKind::Ewma { alpha }, 0.0), &[10.0]);
        let target = 4.0;
        for i in 0..12 {
            est.observe(0, target, true, i as f64);
            let bound = (10.0 - target) * (1.0f64 - alpha).powi(i as i32 + 1) + 1e-9;
            assert!(
                (est.mean(0) - target).abs() <= bound,
                "sample {i}: mean {} bound {bound}",
                est.mean(0)
            );
        }
        // Variance collapses once samples agree, so cap_used approaches the
        // mean.
        for i in 12..40 {
            est.observe(0, target, true, i as f64);
        }
        assert!(est.sigma(0) < 0.2, "sigma={}", est.sigma(0));
    }

    /// Kalman convergence: repeated samples of a step pull the mean within
    /// 5% in a handful of observations, and a long observation gap inflates
    /// variance so the next sample moves the belief sharply.
    #[test]
    fn kalman_converges_and_gap_inflates_variance() {
        let kind = EstimatorKind::KalmanLite { process_var: 0.5, obs_var: 1.0 };
        let mut est = CapacityEstimator::new(&cfg(kind, 0.0), &[10.0]);
        for i in 0..10 {
            est.observe(0, 4.0, true, 1.0 + i as f64);
        }
        assert!((est.mean(0) - 4.0).abs() < 0.2, "mean={}", est.mean(0));
        let sigma_settled = est.sigma(0);
        // 60 s of silence, then one wildly different sample: the stale
        // belief must move most of the way in a single update.
        est.probe(0, 9.0, 71.0);
        assert!((est.mean(0) - 9.0).abs() < 1.0, "stale belief too sticky: {}", est.mean(0));
        assert!(est.sigma(0) > sigma_settled, "variance must grow over the gap");
    }

    /// Hold-down hysteresis under a step change: in-band jitter never moves
    /// the belief out of band, an out-of-band step commits only after
    /// HOLD_COUNT consecutive confirmations, and alternating spikes
    /// (gray-failure flapping) never commit.
    #[test]
    fn holddown_hysteresis_under_step_and_flap() {
        let kind = EstimatorKind::HoldDown { hysteresis: 0.3, alpha: 0.5 };
        let mut est = CapacityEstimator::new(&cfg(kind.clone(), 0.0), &[10.0]);
        // In-band jitter (±10%) smooths gently.
        for (i, x) in [9.5, 10.4, 9.7, 10.2].iter().enumerate() {
            est.observe(0, *x, true, i as f64);
        }
        assert!((est.mean(0) - 10.0).abs() < 0.6, "mean={}", est.mean(0));
        // A 60% drop must NOT commit on the first or second sample...
        est.observe(0, 4.0, true, 10.0);
        est.observe(0, 4.0, true, 11.0);
        assert!(est.mean(0) > 8.0, "committed too early: {}", est.mean(0));
        // ...but must commit on the third consecutive confirmation.
        est.observe(0, 4.0, true, 12.0);
        assert!((est.mean(0) - 4.0).abs() < 0.5, "did not commit: {}", est.mean(0));

        // Flapping: alternating far-high / far-low samples switch sides
        // every observation, so the pending count never reaches HOLD_COUNT
        // and the belief holds its level.
        let mut est = CapacityEstimator::new(&cfg(kind, 0.0), &[10.0]);
        for i in 0..12 {
            let x = if i % 2 == 0 { 2.0 } else { 18.0 };
            est.observe(0, x, true, i as f64);
        }
        assert!((est.mean(0) - 10.0).abs() < 1e-9, "flapping moved the belief: {}", est.mean(0));
    }

    #[test]
    fn censored_samples_only_raise_and_do_not_refresh_clock() {
        let mut est =
            CapacityEstimator::new(&cfg(EstimatorKind::Ewma { alpha: 0.5 }, 0.0), &[10.0]);
        // Uncapped achieved 3 Gbps on a believed-10 link: no information.
        est.observe(0, 3.0, false, 5.0);
        assert_eq!(est.mean(0), 10.0);
        assert_eq!(est.last_obs(0), 0.0, "censored low sample must not look fresh");
        // Uncapped achieved 14 Gbps: capacity is at least that — raise.
        est.observe(0, 14.0, false, 6.0);
        assert!(est.mean(0) > 10.0);
        assert_eq!(est.last_obs(0), 6.0);
    }

    /// A held prior (announced maintenance) outranks measurements for its
    /// window: samples and probes are ignored until the pin expires, then
    /// fusion resumes.
    #[test]
    fn held_prior_pins_belief_against_samples_and_probes() {
        let mut est =
            CapacityEstimator::new(&cfg(EstimatorKind::Ewma { alpha: 0.5 }, 0.0), &[10.0]);
        est.prior_hold(0, 5.0, 10.0, 20.0);
        assert!(est.is_pinned(0, 15.0));
        // A probe of the not-yet-drained link must NOT "correct" the
        // announced pre-drain back to base.
        est.probe(0, 10.0, 15.0);
        est.observe(0, 9.0, true, 16.0);
        assert_eq!(est.mean(0), 5.0, "pinned belief moved");
        // After the window the pin expires and fusion resumes.
        assert!(!est.is_pinned(0, 20.0));
        est.probe(0, 10.0, 21.0);
        assert!(est.mean(0) > 5.0);
        // Plain priors don't pin.
        est.prior(0, 4.0, 30.0);
        assert!(!est.is_pinned(0, 30.0));
        est.probe(0, 8.0, 31.0);
        assert!(est.mean(0) > 4.0);
    }

    #[test]
    fn headroom_subtracts_sigma_and_floors_at_zero() {
        let mut est =
            CapacityEstimator::new(&cfg(EstimatorKind::Ewma { alpha: 0.5 }, 2.0), &[10.0]);
        // Noisy samples create variance; cap_used must sit below the mean.
        for (i, x) in [6.0, 12.0, 5.0, 13.0].iter().enumerate() {
            est.observe(0, *x, true, i as f64);
        }
        assert!(est.sigma(0) > 0.5);
        assert!(est.cap_used(0) < est.mean(0));
        assert!(est.cap_used(0) >= 0.0);
        // A prior collapses the band.
        est.prior(0, 5.0, 10.0);
        assert_eq!(est.cap_used(0), 5.0);
        assert!(est.take_dirty().contains(&0));
    }
}
