//! The WAN graph: datacenters (nodes) and directed logical links with
//! per-direction capacity, geographic latency, and up/down state.

use std::collections::HashMap;

/// Datacenter index.
pub type NodeId = usize;
/// Directed-edge index into [`Wan::links`].
pub type EdgeId = usize;

/// One directed logical link. A physical bidirectional WAN link is modelled
/// as two directed edges (capacities can diverge under fluctuation events).
#[derive(Clone, Debug)]
pub struct Link {
    pub src: NodeId,
    pub dst: NodeId,
    /// Currently available capacity in Gbps (excludes high-priority
    /// background traffic, per §2.2).
    pub capacity: f64,
    /// Nominal capacity in Gbps (recovery restores this).
    pub base_capacity: f64,
    /// Propagation latency in milliseconds.
    pub latency_ms: f64,
    /// False when the link has failed.
    pub up: bool,
}

impl Link {
    /// Capacity as seen by the optimizer: zero when down.
    #[inline]
    pub fn avail(&self) -> f64 {
        if self.up {
            self.capacity
        } else {
            0.0
        }
    }
}

/// WAN-level events Terra reacts to (§3.1.3 event category 4).
#[derive(Clone, Debug, PartialEq)]
pub enum LinkEvent {
    /// Link (u, v) failed in both directions.
    Fail(NodeId, NodeId),
    /// Link (u, v) recovered to base capacity in both directions.
    Recover(NodeId, NodeId),
    /// Available bandwidth on the directed edge (u, v) changed to `gbps`
    /// (e.g. high-priority background traffic ramped up or down).
    SetBandwidth(NodeId, NodeId, f64),
}

/// The WAN graph.
#[derive(Clone, Debug, Default)]
pub struct Wan {
    /// Human-readable datacenter names (sites/cities).
    pub names: Vec<String>,
    /// `(latitude, longitude)` per node, for geographic latencies and the
    /// gravity capacity model.
    pub coords: Vec<(f64, f64)>,
    links: Vec<Link>,
    /// Outgoing edge ids per node.
    out_edges: Vec<Vec<EdgeId>>,
    /// Incoming edge ids per node.
    in_edges: Vec<Vec<EdgeId>>,
    edge_index: HashMap<(NodeId, NodeId), EdgeId>,
}

impl Wan {
    pub fn new() -> Wan {
        Wan::default()
    }

    /// Add a datacenter. `lat`/`lon` in degrees.
    pub fn add_node(&mut self, name: &str, lat: f64, lon: f64) -> NodeId {
        let id = self.names.len();
        self.names.push(name.to_string());
        self.coords.push((lat, lon));
        self.out_edges.push(Vec::new());
        self.in_edges.push(Vec::new());
        id
    }

    pub fn num_nodes(&self) -> usize {
        self.names.len()
    }

    pub fn num_edges(&self) -> usize {
        self.links.len()
    }

    /// Number of undirected physical links.
    pub fn num_undirected(&self) -> usize {
        self.links.len() / 2
    }

    /// Add one directed edge. Prefer [`Wan::add_link`] for physical links.
    pub fn add_edge(&mut self, src: NodeId, dst: NodeId, capacity: f64, latency_ms: f64) -> EdgeId {
        assert!(src != dst, "self loops not allowed");
        assert!(
            !self.edge_index.contains_key(&(src, dst)),
            "duplicate logical link {src}->{dst}: aggregate capacities instead"
        );
        let id = self.links.len();
        self.links.push(Link {
            src,
            dst,
            capacity,
            base_capacity: capacity,
            latency_ms,
            up: true,
        });
        self.out_edges[src].push(id);
        self.in_edges[dst].push(id);
        self.edge_index.insert((src, dst), id);
        id
    }

    /// Add a bidirectional physical link as two directed edges with the given
    /// per-direction capacity. Latency defaults to the geographic distance
    /// between the endpoints when `latency_ms` is `None`.
    pub fn add_link(
        &mut self,
        u: NodeId,
        v: NodeId,
        capacity: f64,
        latency_ms: Option<f64>,
    ) -> (EdgeId, EdgeId) {
        let lat = latency_ms.unwrap_or_else(|| self.geo_latency_ms(u, v));
        (self.add_edge(u, v, capacity, lat), self.add_edge(v, u, capacity, lat))
    }

    /// Propagation latency from great-circle distance at ~2/3 c.
    pub fn geo_latency_ms(&self, u: NodeId, v: NodeId) -> f64 {
        let km = haversine_km(self.coords[u], self.coords[v]);
        // 1 ms per 100 km of fiber at 2e5 km/s, floor of 0.5 ms.
        (km / 200.0).max(0.5)
    }

    #[inline]
    pub fn link(&self, e: EdgeId) -> &Link {
        &self.links[e]
    }

    pub fn links(&self) -> &[Link] {
        &self.links
    }

    pub fn edge_between(&self, u: NodeId, v: NodeId) -> Option<EdgeId> {
        self.edge_index.get(&(u, v)).copied()
    }

    pub fn out_edges(&self, u: NodeId) -> &[EdgeId] {
        &self.out_edges[u]
    }

    pub fn in_edges(&self, u: NodeId) -> &[EdgeId] {
        &self.in_edges[u]
    }

    /// Vector of currently-available capacities, indexed by `EdgeId`.
    /// This is the optimizer's view of the network.
    pub fn capacities(&self) -> Vec<f64> {
        self.links.iter().map(|l| l.avail()).collect()
    }

    /// Total currently-available capacity (Gbps) over all directed edges.
    pub fn total_capacity(&self) -> f64 {
        self.links.iter().map(|l| l.avail()).sum()
    }

    pub fn set_capacity(&mut self, e: EdgeId, gbps: f64) {
        self.links[e].capacity = gbps.max(0.0);
    }

    /// Set one directed edge's up/down state. Unlike [`LinkEvent::Fail`] /
    /// [`LinkEvent::Recover`] this acts on a single direction — the agent-
    /// liveness machinery marks a down site's *incident directed* edges
    /// failed (possibly one direction only, for asymmetric partitions).
    /// Bringing an edge back up restores base capacity, matching recovery
    /// semantics (any fluctuated value from the down period is stale).
    pub fn set_edge_up(&mut self, e: EdgeId, up: bool) {
        self.links[e].up = up;
        if up {
            self.links[e].capacity = self.links[e].base_capacity;
        }
    }

    /// Apply a WAN event; returns the fractional bandwidth change it caused
    /// on the most-affected edge (used against the ρ re-optimization
    /// threshold, §3.1.3).
    pub fn apply_event(&mut self, ev: &LinkEvent) -> f64 {
        match *ev {
            LinkEvent::Fail(u, v) => {
                for (a, b) in [(u, v), (v, u)] {
                    if let Some(e) = self.edge_between(a, b) {
                        self.links[e].up = false;
                    }
                }
                1.0
            }
            LinkEvent::Recover(u, v) => {
                for (a, b) in [(u, v), (v, u)] {
                    if let Some(e) = self.edge_between(a, b) {
                        self.links[e].up = true;
                        self.links[e].capacity = self.links[e].base_capacity;
                    }
                }
                1.0
            }
            LinkEvent::SetBandwidth(u, v, gbps) => {
                if let Some(e) = self.edge_between(u, v) {
                    let old = self.links[e].capacity.max(1e-9);
                    self.links[e].capacity = gbps.max(0.0);
                    if self.links[e].up {
                        ((gbps - old) / old).abs()
                    } else {
                        // Fluctuation on a failed link: the stored capacity
                        // is updated, but the optimizer-visible (available)
                        // capacity stays 0 either way — not a change worth
                        // reacting to. (Recovery resets to base capacity.)
                        0.0
                    }
                } else {
                    0.0
                }
            }
        }
    }

    /// Assign capacities with the gravity model (used for G-Scale and ATT,
    /// §6.1): capacity of (u, v) proportional to `w_u * w_v / dist(u,v)^2`,
    /// scaled so the largest link gets `max_gbps`, snapped up to the nearest
    /// 10 Gbps with a floor of `min_gbps`.
    pub fn gravity_capacities(&mut self, weights: &[f64], max_gbps: f64, min_gbps: f64) {
        assert_eq!(weights.len(), self.num_nodes());
        let mut raw: Vec<f64> = Vec::with_capacity(self.links.len());
        for l in &self.links {
            let d = haversine_km(self.coords[l.src], self.coords[l.dst]).max(50.0);
            raw.push(weights[l.src] * weights[l.dst] / (d * d));
        }
        let m = raw.iter().cloned().fold(0.0f64, f64::max).max(1e-12);
        for (l, r) in self.links.iter_mut().zip(&raw) {
            let c = (r / m * max_gbps).max(min_gbps);
            let snapped = ((c / 10.0).ceil() * 10.0).min(max_gbps);
            l.capacity = snapped;
            l.base_capacity = snapped;
        }
    }

    /// True if every node can reach every other node over up links.
    pub fn is_connected(&self) -> bool {
        let n = self.num_nodes();
        if n == 0 {
            return true;
        }
        let mut seen = vec![false; n];
        let mut stack = vec![0usize];
        seen[0] = true;
        while let Some(u) = stack.pop() {
            for &e in &self.out_edges[u] {
                let l = &self.links[e];
                if l.up && !seen[l.dst] {
                    seen[l.dst] = true;
                    stack.push(l.dst);
                }
            }
        }
        seen.into_iter().all(|s| s)
    }
}

/// Great-circle distance between `(lat, lon)` pairs in km.
pub fn haversine_km(a: (f64, f64), b: (f64, f64)) -> f64 {
    let (la1, lo1) = (a.0.to_radians(), a.1.to_radians());
    let (la2, lo2) = (b.0.to_radians(), b.1.to_radians());
    let dla = la2 - la1;
    let dlo = lo2 - lo1;
    let h = (dla / 2.0).sin().powi(2) + la1.cos() * la2.cos() * (dlo / 2.0).sin().powi(2);
    2.0 * 6371.0 * h.sqrt().asin()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> Wan {
        let mut w = Wan::new();
        let a = w.add_node("A", 0.0, 0.0);
        let b = w.add_node("B", 0.0, 10.0);
        let c = w.add_node("C", 10.0, 0.0);
        w.add_link(a, b, 10.0, Some(5.0));
        w.add_link(b, c, 10.0, Some(5.0));
        w.add_link(a, c, 5.0, Some(5.0));
        w
    }

    #[test]
    fn builds_directed_pairs() {
        let w = triangle();
        assert_eq!(w.num_nodes(), 3);
        assert_eq!(w.num_edges(), 6);
        assert_eq!(w.num_undirected(), 3);
        let e = w.edge_between(0, 1).unwrap();
        assert_eq!(w.link(e).capacity, 10.0);
        assert_eq!(w.out_edges(0).len(), 2);
        assert_eq!(w.in_edges(0).len(), 2);
    }

    #[test]
    fn fail_and_recover() {
        let mut w = triangle();
        assert!(w.is_connected());
        w.apply_event(&LinkEvent::Fail(0, 1));
        let e = w.edge_between(0, 1).unwrap();
        assert_eq!(w.link(e).avail(), 0.0);
        assert!(w.is_connected()); // still connected via C
        w.apply_event(&LinkEvent::Fail(0, 2));
        assert!(!w.is_connected());
        w.apply_event(&LinkEvent::Recover(0, 1));
        assert!(w.is_connected());
        assert_eq!(w.link(e).avail(), 10.0);
    }

    #[test]
    fn bandwidth_fluctuation_fraction() {
        let mut w = triangle();
        let frac = w.apply_event(&LinkEvent::SetBandwidth(0, 1, 5.0));
        assert!((frac - 0.5).abs() < 1e-9);
        let e = w.edge_between(0, 1).unwrap();
        assert_eq!(w.link(e).capacity, 5.0);
        // Reverse direction untouched.
        let er = w.edge_between(1, 0).unwrap();
        assert_eq!(w.link(er).capacity, 10.0);
    }

    #[test]
    fn fluctuation_on_down_link_is_not_a_change() {
        let mut w = triangle();
        w.apply_event(&LinkEvent::Fail(0, 1));
        // Available capacity is 0 before and after: frac must be 0 so the
        // ρ filter never re-optimizes for an invisible change.
        let frac = w.apply_event(&LinkEvent::SetBandwidth(0, 1, 2.0));
        assert_eq!(frac, 0.0);
        let e = w.edge_between(0, 1).unwrap();
        assert_eq!(w.link(e).avail(), 0.0);
        // Recovery discards the fluctuated value and restores base.
        w.apply_event(&LinkEvent::Recover(0, 1));
        assert_eq!(w.link(e).avail(), 10.0);
    }

    #[test]
    fn haversine_sane() {
        // New York (40.7,-74.0) to Los Angeles (34.05,-118.25) ~ 3940 km
        let d = haversine_km((40.7, -74.0), (34.05, -118.25));
        assert!((3800.0..4100.0).contains(&d), "d={d}");
    }

    #[test]
    fn gravity_scales_and_floors() {
        let mut w = triangle();
        w.gravity_capacities(&[1.0, 1.0, 1.0], 100.0, 10.0);
        for l in w.links() {
            assert!(l.capacity >= 10.0 && l.capacity <= 100.0);
        }
    }

    #[test]
    #[should_panic(expected = "duplicate logical link")]
    fn rejects_duplicate_edge() {
        let mut w = triangle();
        w.add_edge(0, 1, 1.0, 1.0);
    }
}
