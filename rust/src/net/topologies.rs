//! The three evaluation WAN topologies (§6.1):
//!
//! 1. **SWAN** — Microsoft's inter-DC WAN, 5 datacenters / 7 links
//!    ([Hong et al., SIGCOMM'13, Fig 8]).
//! 2. **G-Scale** — Google's B4 inter-DC WAN, 12 datacenters / 19 links
//!    ([Jain et al., SIGCOMM'13, Fig 1]).
//! 3. **ATT** — AT&T's North-America MPLS backbone from the Topology Zoo,
//!    25 nodes / 56 links, one datacenter per node.
//!
//! Site coordinates approximate the published maps; latencies derive from
//! great-circle distances, and capacities for G-Scale/ATT use the gravity
//! model (§6.1), as in the paper.

use super::topology::Wan;

/// Per-direction capacity used for SWAN links in simulation (Gbps).
pub const SWAN_SIM_GBPS: f64 = 10.0;
/// Per-direction capacity used for SWAN links on the emulation testbed
/// (the paper's testbed caps VLANs at 1 Gbps).
pub const SWAN_TESTBED_GBPS: f64 = 1.0;

/// Microsoft SWAN: 5 DCs, 7 inter-DC links with uniform per-direction
/// capacity `gbps`. Sites follow the paper's testbed narrative (US coasts +
/// Europe/Asia mix is not disclosed; we use the commonly-cited layout of two
/// US, one EU, two APAC sites).
pub fn swan_with_capacity(gbps: f64) -> Wan {
    let mut w = Wan::new();
    let ny = w.add_node("NY", 40.71, -74.00);
    let la = w.add_node("LA", 34.05, -118.24);
    let tx = w.add_node("TX", 32.78, -96.80);
    let fl = w.add_node("FL", 25.76, -80.19);
    let wa = w.add_node("WA", 47.61, -122.33);
    // 7 physical links forming the SWAN Figure-8 mesh.
    w.add_link(ny, la, gbps, None);
    w.add_link(ny, tx, gbps, None);
    w.add_link(ny, fl, gbps, None);
    w.add_link(la, tx, gbps, None);
    w.add_link(la, wa, gbps, None);
    w.add_link(tx, fl, gbps, None);
    w.add_link(wa, tx, gbps, None);
    w
}

/// SWAN with simulation capacities (10 Gbps per direction).
pub fn swan() -> Wan {
    swan_with_capacity(SWAN_SIM_GBPS)
}

/// Google G-Scale (B4): 12 datacenters / 19 links. Site list follows the B4
/// paper's world map (6 North America, 3 Europe, 3 Asia); capacities from the
/// gravity model scaled to 100 Gbps max.
pub fn gscale() -> Wan {
    let mut w = Wan::new();
    let dalles = w.add_node("TheDalles-OR", 45.59, -121.18);
    let council = w.add_node("CouncilBluffs-IA", 41.26, -95.86);
    let berkeley = w.add_node("BerkeleyCounty-SC", 33.19, -80.01);
    let lenoir = w.add_node("Lenoir-NC", 35.91, -81.54);
    let mayes = w.add_node("MayesCounty-OK", 36.30, -95.23);
    let douglas = w.add_node("DouglasCounty-GA", 33.75, -84.75);
    let hamina = w.add_node("Hamina-FI", 60.57, 27.20);
    let ghlin = w.add_node("StGhislain-BE", 50.45, 3.82);
    let dublin = w.add_node("Dublin-IE", 53.35, -6.26);
    let singapore = w.add_node("Singapore", 1.35, 103.82);
    let taiwan = w.add_node("Changhua-TW", 24.08, 120.54);
    let hk = w.add_node("HongKong", 22.32, 114.17);
    // 19 links: US mesh, transatlantic, Europe ring, transpacific, Asia ring.
    let links = [
        (dalles, council),
        (dalles, mayes),
        (council, mayes),
        (council, lenoir),
        (mayes, douglas),
        (lenoir, douglas),
        (lenoir, berkeley),
        (douglas, berkeley),
        (dalles, taiwan),     // transpacific north
        (dalles, hk),         // transpacific south
        (taiwan, hk),
        (taiwan, singapore),
        (hk, singapore),
        (berkeley, ghlin),    // transatlantic south
        (lenoir, dublin),     // transatlantic north
        (dublin, ghlin),
        (ghlin, hamina),
        (dublin, hamina),
        (hamina, singapore),  // Europe-Asia
    ];
    for (u, v) in links {
        w.add_link(u, v, 0.0, None);
    }
    debug_assert_eq!(w.num_undirected(), 19);
    let weights = vec![1.0; w.num_nodes()];
    w.gravity_capacities(&weights, 100.0, 10.0);
    w
}

/// AT&T North-America MPLS backbone (Topology Zoo "ATT NA"): 25 nodes / 56
/// links, one datacenter attached per node (§6.1). City list and adjacency
/// approximate the published dataset; capacities from the gravity model with
/// metro-population weights.
pub fn att() -> Wan {
    let mut w = Wan::new();
    // (name, lat, lon, metro population in millions — gravity weight)
    let cities: [(&str, f64, f64, f64); 25] = [
        ("Seattle", 47.61, -122.33, 4.0),
        ("Portland", 45.52, -122.68, 2.5),
        ("Sacramento", 38.58, -121.49, 2.4),
        ("SanFrancisco", 37.77, -122.42, 4.7),
        ("SanJose", 37.34, -121.89, 2.0),
        ("LosAngeles", 34.05, -118.24, 13.2),
        ("SanDiego", 32.72, -117.16, 3.3),
        ("Phoenix", 33.45, -112.07, 4.9),
        ("SaltLake", 40.76, -111.89, 1.2),
        ("Denver", 39.74, -104.99, 2.9),
        ("Dallas", 32.78, -96.80, 7.6),
        ("Houston", 29.76, -95.37, 7.1),
        ("SanAntonio", 29.42, -98.49, 2.6),
        ("KansasCity", 39.10, -94.58, 2.2),
        ("StLouis", 38.63, -90.20, 2.8),
        ("Chicago", 41.88, -87.63, 9.5),
        ("Minneapolis", 44.98, -93.27, 3.7),
        ("Detroit", 42.33, -83.05, 4.3),
        ("Cleveland", 41.50, -81.69, 2.1),
        ("Atlanta", 33.75, -84.39, 6.1),
        ("Miami", 25.76, -80.19, 6.2),
        ("Orlando", 28.54, -81.38, 2.7),
        ("WashingtonDC", 38.91, -77.04, 6.4),
        ("Philadelphia", 39.95, -75.17, 6.2),
        ("NewYork", 40.71, -74.00, 19.8),
    ];
    for (name, lat, lon, _) in cities {
        w.add_node(name, lat, lon);
    }
    let names: Vec<String> = w.names.clone();
    let idx = move |name: &str| names.iter().position(|n| n == name).unwrap();
    // 56 physical links (regional meshes + long-haul trunks), mirroring the
    // Topology Zoo ATT graph's density and diameter.
    let links: [(&str, &str); 56] = [
        // West coast chain + mesh
        ("Seattle", "Portland"),
        ("Seattle", "SaltLake"),
        ("Seattle", "SanFrancisco"),
        ("Portland", "Sacramento"),
        ("Sacramento", "SanFrancisco"),
        ("Sacramento", "SaltLake"),
        ("SanFrancisco", "SanJose"),
        ("SanJose", "LosAngeles"),
        ("SanFrancisco", "LosAngeles"),
        ("LosAngeles", "SanDiego"),
        ("SanDiego", "Phoenix"),
        ("LosAngeles", "Phoenix"),
        // Mountain / southwest
        ("Phoenix", "Dallas"),
        ("Phoenix", "Denver"),
        ("SaltLake", "Denver"),
        ("Denver", "KansasCity"),
        ("Denver", "Dallas"),
        ("SaltLake", "KansasCity"),
        // Texas triangle
        ("Dallas", "Houston"),
        ("Dallas", "SanAntonio"),
        ("Houston", "SanAntonio"),
        ("Houston", "Atlanta"),
        ("Dallas", "Atlanta"),
        ("Dallas", "StLouis"),
        ("Dallas", "KansasCity"),
        // Midwest
        ("KansasCity", "StLouis"),
        ("KansasCity", "Chicago"),
        ("StLouis", "Chicago"),
        ("StLouis", "Atlanta"),
        ("Chicago", "Minneapolis"),
        ("Minneapolis", "Seattle"),
        ("Minneapolis", "KansasCity"),
        ("Chicago", "Detroit"),
        ("Detroit", "Cleveland"),
        ("Chicago", "Cleveland"),
        ("Cleveland", "NewYork"),
        ("Cleveland", "WashingtonDC"),
        ("Chicago", "NewYork"),
        // Southeast
        ("Atlanta", "Miami"),
        ("Atlanta", "Orlando"),
        ("Orlando", "Miami"),
        ("Atlanta", "WashingtonDC"),
        ("Atlanta", "Orlando2"),
        // East corridor
        ("WashingtonDC", "Philadelphia"),
        ("Philadelphia", "NewYork"),
        ("WashingtonDC", "NewYork"),
        ("NewYork", "Chicago2"),
        ("Miami", "Houston"),
        ("Miami", "WashingtonDC"),
        ("Orlando", "WashingtonDC"),
        // Long-haul express trunks
        ("SanFrancisco", "Chicago"),
        ("SanFrancisco", "NewYork"),
        ("LosAngeles", "Dallas"),
        ("LosAngeles", "Denver"),
        ("Seattle", "Chicago"),
        ("Denver", "Chicago"),
    ];
    for (a, b) in links {
        // A couple of entries are deliberate aliases to keep exactly 56
        // links without duplicating an existing pair.
        let (a, b) = match (a, b) {
            ("Atlanta", "Orlando2") => ("Cleveland", "Philadelphia"),
            ("NewYork", "Chicago2") => ("Minneapolis", "Detroit"),
            pair => pair,
        };
        let (u, v) = (idx(a), idx(b));
        w.add_link(u, v, 0.0, None);
    }
    debug_assert_eq!(w.num_undirected(), 56);
    let weights: Vec<f64> = cities.iter().map(|c| c.3).collect();
    w.gravity_capacities(&weights, 100.0, 10.0);
    w
}

/// The 3-datacenter full mesh of the paper's Figure 1a: links A–B, B–C, A–C
/// at 10 Gbps per direction (1 GB ≈ 8 Gbit, so a 5 GB FlowGroup needs 4 s at
/// full rate — matching the paper's arithmetic).
pub fn fig1a() -> Wan {
    let mut w = Wan::new();
    let a = w.add_node("A", 37.77, -122.42);
    let b = w.add_node("B", 41.88, -87.63);
    let c = w.add_node("C", 40.71, -74.00);
    w.add_link(a, b, 10.0, None);
    w.add_link(b, c, 10.0, None);
    w.add_link(a, c, 10.0, None);
    w
}

/// Look up a topology by CLI name.
pub fn by_name(name: &str) -> Option<Wan> {
    match name.to_ascii_lowercase().as_str() {
        "swan" => Some(swan()),
        "swan-testbed" => Some(swan_with_capacity(SWAN_TESTBED_GBPS)),
        "gscale" | "g-scale" | "b4" => Some(gscale()),
        "att" | "at&t" => Some(att()),
        "fig1a" => Some(fig1a()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::paths::PathSet;

    #[test]
    fn swan_shape() {
        let w = swan();
        assert_eq!(w.num_nodes(), 5);
        assert_eq!(w.num_undirected(), 7);
        assert!(w.is_connected());
    }

    #[test]
    fn gscale_shape() {
        let w = gscale();
        assert_eq!(w.num_nodes(), 12);
        assert_eq!(w.num_undirected(), 19);
        assert!(w.is_connected());
        for l in w.links() {
            assert!(l.capacity >= 10.0 && l.capacity <= 100.0);
        }
    }

    #[test]
    fn att_shape() {
        let w = att();
        assert_eq!(w.num_nodes(), 25);
        assert_eq!(w.num_undirected(), 56);
        assert!(w.is_connected());
    }

    #[test]
    fn att_has_path_diversity() {
        let w = att();
        // Coast-to-coast should have >= 5 loopless paths (paper finds the
        // k-threshold between 5 and 10 on ATT, Fig 12).
        let ps = crate::net::paths::k_shortest_paths(&w, 0, 24, 10);
        assert!(ps.len() >= 5, "only {} paths", ps.len());
    }

    #[test]
    fn latencies_geographic() {
        let w = swan();
        let e = w.edge_between(0, 1).unwrap(); // NY-LA
        assert!(w.link(e).latency_ms > 10.0, "NY-LA should be tens of ms");
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("swan").is_some());
        assert!(by_name("GSCALE").is_some());
        assert!(by_name("att").is_some());
        assert!(by_name("fig1a").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn pathsets_nonempty_all_pairs() {
        for w in [swan(), gscale()] {
            let ps = PathSet::compute(&w, 3);
            for u in 0..w.num_nodes() {
                for v in 0..w.num_nodes() {
                    if u != v {
                        assert!(!ps.get(u, v).is_empty(), "{u}->{v}");
                    }
                }
            }
        }
    }
}
