//! The Terra policy: Pseudocode 1 (offline ALLOCBANDWIDTH /
//! MINIMIZECCTOFFLINE) and Pseudocode 2 (ONARRIVAL admission + ordering),
//! §3.1–§3.2.
//!
//! Each round:
//! 1. scale the WAN down by `(1 - α)` (starvation freedom),
//! 2. compute each coflow's standalone Γ (its minimum CCT via
//!    Optimization (1)),
//! 3. order coflows — deadline-admitted first (decreasing D, then
//!    increasing Γ), then SRTF by increasing Γ,
//! 4. sequentially give each coflow its minimum-CCT multipath allocation on
//!    the residual WAN; deadline coflows are dilated by `Γ/D` so they finish
//!    exactly on time (§3.2),
//! 5. work conservation: coflows that could not be scheduled in their
//!    entirety (`C_Failed`) get a max-min MCF share of the leftover first,
//!    then everything else (Pseudocode 1 lines 14–15) — this also hands out
//!    the α starvation share.

use super::*;
use crate::lp::flat::CachedCsr;
use crate::lp::gk::Warm;
use crate::lp::{self, gk, maxmin, SolverKind, SolverRepr};
use std::time::Instant;

/// Terra configuration knobs (paper defaults, §6.1).
#[derive(Clone, Debug)]
pub struct TerraConfig {
    /// Starvation share: fraction of WAN capacity reserved for preempted /
    /// unscheduled coflows (α = 0.1).
    pub alpha: f64,
    /// Deadline relaxation factor: admit iff Γ ≤ η·D (Pseudocode 2 line 7).
    pub eta: f64,
    /// Bandwidth-fluctuation threshold for re-optimization (ρ = 0.25):
    /// smaller changes are ignored by the driver.
    pub rho: f64,
    /// Paths per datacenter pair (k = 15).
    pub k: usize,
    /// LP backend for Optimization (1).
    pub solver: SolverKind,
    /// GK data representation: flat CSR with workspace reuse (default), or
    /// the jagged reference path (bit-identical results; kept for the
    /// equivalence suite and the scaling benches' baseline axis).
    pub repr: SolverRepr,
}

impl Default for TerraConfig {
    fn default() -> Self {
        TerraConfig {
            alpha: DEFAULT_ALPHA,
            eta: DEFAULT_ETA,
            rho: DEFAULT_RHO,
            k: DEFAULT_K,
            solver: SolverKind::Gk,
            repr: SolverRepr::Flat,
        }
    }
}

/// The Terra scheduling-routing policy.
#[derive(Default)]
pub struct TerraPolicy {
    pub cfg: TerraConfig,
    /// Optional AOT-compiled JAX/PDHG LP backend (loaded from
    /// `artifacts/`); falls back to the native solver when a solve does not
    /// fit a variant or degenerates.
    pub jax: Option<std::sync::Arc<crate::runtime::JaxSolver>>,
    stats: RoundStats,
}

impl TerraPolicy {
    pub fn new(cfg: TerraConfig) -> TerraPolicy {
        TerraPolicy { cfg, jax: None, stats: RoundStats::default() }
    }

    /// Use the PJRT-executed artifact for Optimization (1).
    pub fn with_jax(mut self, solver: std::sync::Arc<crate::runtime::JaxSolver>) -> TerraPolicy {
        self.jax = Some(solver);
        self
    }

    pub fn with_alpha(alpha: f64) -> TerraPolicy {
        TerraPolicy::new(TerraConfig { alpha, ..Default::default() })
    }

    pub fn with_k(k: usize) -> TerraPolicy {
        TerraPolicy::new(TerraConfig { k, ..Default::default() })
    }

    /// Whether solves run on the flat CSR path: the flat representation is
    /// selected, the backend is GK (simplex and the PJRT artifact consume
    /// jagged instances), and the caller supplied a workspace.
    fn flat_mode(&self, ws: &Option<&mut SolverWorkspace>) -> bool {
        self.cfg.repr == SolverRepr::Flat
            && self.cfg.solver == SolverKind::Gk
            && self.jax.is_none()
            && ws.is_some()
    }

    /// Solve Optimization (1) for one coflow on `caps`; instrumented. A
    /// `warm` rate matrix (full group-indexed, from the previous round)
    /// seeds the GK solver's feasible-candidate early exit. With a
    /// workspace, the solve runs on the coflow's cached flat CSR block
    /// (built at most once per epoch × group-shape) and performs no
    /// allocations beyond the output rates; without one it falls back to a
    /// per-call jagged instance (admission control, legacy `allocate`).
    fn solve_min_cct(
        &mut self,
        cf: &CoflowState,
        caps: &[f64],
        net: &NetView,
        warm: Option<&CoflowRates>,
        ws: Option<&mut SolverWorkspace>,
        epoch: u64,
    ) -> Option<(lp::McfSolution, Vec<usize>)> {
        if self.flat_mode(&ws) {
            let ws = ws.unwrap();
            let SolverWorkspace { gk: gk_ws, builder, edge_map, csr, .. } = ws;
            let entry = ensure_csr(csr, builder, edge_map, cf, caps, net, self.cfg.k, epoch)?;
            let w = match warm {
                Some(w) => Warm::Indexed(w, &entry.index),
                None => Warm::None,
            };
            let t0 = Instant::now();
            let sol = gk::solve_flat(&entry.flat, gk::DEFAULT_EPSILON, w, gk_ws);
            self.stats.lp_solves += 1;
            self.stats.lp_time_s += t0.elapsed().as_secs_f64();
            return sol.map(|s| (s, entry.index.clone()));
        }
        let (inst, index) = build_instance(&cf.groups, &cf.remaining, caps, net, self.cfg.k);
        if inst.groups.is_empty() {
            return None;
        }
        // Project the warm rates from the full group list onto the
        // instance's unfinished-group subset.
        let projected: Option<Vec<Vec<f64>>> = warm.map(|w| {
            index.iter().map(|&gi| w.get(gi).cloned().unwrap_or_default()).collect()
        });
        let t0 = Instant::now();
        let repr = self.cfg.repr;
        let sol = match &self.jax {
            Some(jax) => jax.solve(net.wan, &inst).or_else(|| {
                lp::max_concurrent_repr(&inst, self.cfg.solver, projected.as_deref(), repr)
            }),
            None => lp::max_concurrent_repr(&inst, self.cfg.solver, projected.as_deref(), repr),
        };
        self.stats.lp_solves += 1;
        self.stats.lp_time_s += t0.elapsed().as_secs_f64();
        sol.map(|s| (s, index))
    }

    /// One full round of Pseudocode 1, optionally with the engine's
    /// incremental context (Γ-cache for the ordering solves, previous
    /// allocation as warm starts for the per-coflow allocation solves).
    #[allow(clippy::too_many_arguments)]
    fn run_round(
        &mut self,
        now: f64,
        coflows: &[CoflowState],
        net: &NetView,
        mut cache: Option<&mut crate::engine::GammaCache>,
        warm: Option<&Allocation>,
        mut ws: Option<&mut SolverWorkspace>,
        epoch: u64,
    ) -> Allocation {
        let flat_mode = self.flat_mode(&ws);
        let round_start = Instant::now();
        let mut alloc = Allocation::default();
        let caps_full = net.wan.capacities();
        // Line 2 of Pseudocode 1: scale down by (1 - α).
        let mut scaled: Vec<f64> = caps_full.iter().map(|c| c * (1.0 - self.cfg.alpha)).collect();

        // Two-level floor filling, level 1 (stream service class): reserve
        // every stream's per-group rate floor *before* the batch machinery
        // sees the WAN, so Γ-ordering and max-min filling distribute only
        // the surplus. Floors that don't fit surface as shortfall Gbps in
        // the round stats — never as a silent clamp. Class-free rounds skip
        // this entirely (`scaled` untouched, bit-identical path).
        let mut streams: Vec<usize> = Vec::new();
        if coflows.iter().any(|c| c.rate_floor().is_some() && !c.done()) {
            let (demands, floors, owners) = stream_floor_demands(
                coflows.iter().enumerate().map(|(i, c)| (i, c)),
                net,
                self.cfg.k,
            );
            let (reserved, shortfall) = maxmin::reserve_floors(&mut scaled, &demands, &floors);
            for (di, &(i, gi)) in owners.iter().enumerate() {
                let cf = &coflows[i];
                let entry =
                    alloc.rates.entry(cf.id).or_insert_with(|| vec![Vec::new(); cf.groups.len()]);
                entry[gi] = reserved[di].clone();
                if !streams.contains(&i) {
                    streams.push(i);
                }
            }
            self.stats.floor_shortfall_gbps += shortfall.iter().sum::<f64>();
        }

        // Standalone Γ per coflow (for the SRTF order). With a cache, each
        // Γ is an LP solve only on a miss — i.e. once per (coflow, WAN
        // epoch); continuous drain is handled by the cache's homogeneity
        // rescale and discrete changes by dirty-set invalidation.
        let mut order: Vec<(usize, f64)> = Vec::with_capacity(coflows.len());
        for (i, cf) in coflows.iter().enumerate() {
            // Streams never enter Γ/SRTF ordering: they are not racing to
            // complete, their floor is already reserved, and their huge
            // lifetime volumes would distort SRTF for everyone else.
            if cf.rate_floor().is_some() {
                continue;
            }
            let total_rem = cf.total_remaining();
            let cached = cache.as_deref().and_then(|c| c.lookup(cf.id, total_rem));
            let gamma = match cached {
                Some(g) => {
                    self.stats.gamma_cache_hits += 1;
                    g
                }
                None => {
                    let g = self
                        .solve_min_cct(
                            cf,
                            &scaled,
                            net,
                            warm.and_then(|a| a.rates.get(&cf.id)),
                            ws.as_deref_mut(),
                            epoch,
                        )
                        .map(|(s, _)| s.gamma())
                        .unwrap_or(f64::INFINITY);
                    if let Some(c) = cache.as_deref_mut() {
                        c.store(cf.id, total_rem, g);
                    }
                    g
                }
            };
            order.push((i, gamma));
        }
        // Pseudocode 2 line 9: decreasing D_i (deadline-admitted first),
        // then increasing Γ_i.
        order.sort_by(|a, b| {
            let (ca, cb) = (&coflows[a.0], &coflows[b.0]);
            match (ca.deadline, cb.deadline) {
                (Some(da), Some(db)) => db.total_cmp(&da).then(a.1.total_cmp(&b.1)),
                (Some(_), None) => std::cmp::Ordering::Less,
                (None, Some(_)) => std::cmp::Ordering::Greater,
                (None, None) => a.1.total_cmp(&b.1),
            }
        });

        // Sequential minimum-CCT allocation on the residual WAN.
        let mut residual = scaled.clone();
        let mut failed: Vec<usize> = Vec::new();
        let mut scheduled: Vec<usize> = Vec::new();
        for &(i, _) in &order {
            let cf = &coflows[i];
            if cf.done() {
                continue;
            }
            let solved = self.solve_min_cct(
                cf,
                &residual,
                net,
                warm.and_then(|a| a.rates.get(&cf.id)),
                ws.as_deref_mut(),
                epoch,
            );
            match solved {
                Some((mut sol, index)) => {
                    // Deadline dilation (§3.2): completing earlier than D has
                    // no benefit; stretch to the deadline and free bandwidth.
                    if let Some(d) = cf.deadline {
                        let d_rem = d - now;
                        let gamma = sol.gamma();
                        if d_rem > gamma {
                            sol.scale(gamma / d_rem);
                        }
                    }
                    // Subtract usage from the residual.
                    if flat_mode {
                        // The coflow's CSR block is in the workspace (the
                        // solve above just used it); no instance rebuild and
                        // no global-edge-count allocation.
                        let w = ws.as_deref_mut().expect("flat_mode implies ws");
                        let SolverWorkspace { gk: gk_ws, csr, .. } = w;
                        let block = &csr.get(&cf.id).expect("block built by solve").flat;
                        block.subtract_usage(&sol.rates, &mut residual, &mut gk_ws.usage);
                    } else {
                        let (inst, _) = build_instance(
                            &cf.groups,
                            &cf.remaining,
                            &residual,
                            net,
                            self.cfg.k,
                        );
                        for (u, r) in
                            inst.edge_usage(&sol.rates).iter().zip(residual.iter_mut())
                        {
                            *r = (*r - u).max(0.0);
                        }
                    }
                    alloc.rates.insert(cf.id, expand_rates(cf.groups.len(), &index, &sol.rates));
                    scheduled.push(i);
                }
                None => failed.push(i),
            }
        }

        // Work conservation (Pseudocode 1 lines 14–15) on everything left,
        // including the α starvation share. C_Failed gets priority. Streams
        // participate too (appended after the batch coflows): their floor
        // is a minimum, not a cap, so they may burst into the surplus.
        scheduled.extend(streams);
        let mut used = alloc_usage(&alloc, coflows, net, caps_full.len());
        let mut leftover: Vec<f64> =
            caps_full.iter().zip(&used).map(|(c, u)| (c - u).max(0.0)).collect();
        for pass in [&failed[..], &scheduled[..]] {
            // Deadline coflows gain nothing from finishing early; bonus
            // bandwidth goes to deadline-free coflows only.
            let members: Vec<usize> =
                pass.iter().copied().filter(|&i| coflows[i].deadline.is_none()).collect();
            if members.is_empty() {
                continue;
            }
            let mut owners = Vec::new(); // (coflow idx, group idx)
            if flat_mode {
                // Flat path: the combined instance is a concatenation of the
                // members' cached CSR blocks (no nested path-list cloning),
                // and the filling levels reuse it in place.
                let w = ws.as_deref_mut().expect("flat_mode implies ws");
                let SolverWorkspace { gk: gk_ws, builder, edge_map, csr, wc, wc_builder } = w;
                wc_builder.clear();
                let mut weights: Vec<f64> = Vec::new();
                for &i in &members {
                    let cf = &coflows[i];
                    let Some(entry) =
                        ensure_csr(csr, builder, edge_map, cf, &leftover, net, self.cfg.k, epoch)
                    else {
                        continue;
                    };
                    for &gi in &entry.index {
                        owners.push((i, gi));
                        weights.push(cf.remaining[gi]);
                    }
                    wc_builder.push_block(&entry.flat, &entry.flat.vols);
                }
                if wc_builder.is_empty() {
                    continue;
                }
                let t0 = Instant::now();
                wc_builder.finish_into(&leftover, edge_map, wc);
                let bonus = maxmin::max_min_rates_ws(wc, &weights, gk_ws);
                self.stats.lp_solves += 1;
                self.stats.lp_time_s += t0.elapsed().as_secs_f64();
                for (di, &(ci, gi)) in owners.iter().enumerate() {
                    let cf = &coflows[ci];
                    let entry = alloc
                        .rates
                        .entry(cf.id)
                        .or_insert_with(|| vec![Vec::new(); cf.groups.len()]);
                    let dst = &mut entry[gi];
                    let src = &bonus[di];
                    if dst.len() < src.len() {
                        dst.resize(src.len(), 0.0);
                    }
                    for (d, s) in dst.iter_mut().zip(src) {
                        *d += *s;
                    }
                    // Track usage so the second pass sees the reduced
                    // leftover (demand `di`'s paths live in the wc CSR).
                    for (pi, &r) in src.iter().enumerate() {
                        if r > 0.0 {
                            let p = wc.paths(di).start + pi;
                            for &le in wc.edges(p) {
                                let e = wc.global_edges[le as usize] as usize;
                                used[e] += r;
                                leftover[e] = (leftover[e] - r).max(0.0);
                            }
                        }
                    }
                }
                continue;
            }
            let mut demands = Vec::new();
            for &i in &members {
                let cf = &coflows[i];
                let (inst, index) =
                    build_instance(&cf.groups, &cf.remaining, &leftover, net, self.cfg.k);
                for (ii, g) in inst.groups.into_iter().enumerate() {
                    demands.push(g);
                    owners.push((i, index[ii]));
                }
            }
            if demands.is_empty() {
                continue;
            }
            let t0 = Instant::now();
            let weights: Vec<f64> = demands.iter().map(|d| d.volume).collect();
            let bonus = maxmin::max_min_rates_with(&leftover, &demands, &weights, self.cfg.repr);
            self.stats.lp_solves += 1;
            self.stats.lp_time_s += t0.elapsed().as_secs_f64();
            for (di, &(ci, gi)) in owners.iter().enumerate() {
                let cf = &coflows[ci];
                let entry = alloc
                    .rates
                    .entry(cf.id)
                    .or_insert_with(|| vec![Vec::new(); cf.groups.len()]);
                let dst = &mut entry[gi];
                let src = &bonus[di];
                if dst.len() < src.len() {
                    dst.resize(src.len(), 0.0);
                }
                for (d, s) in dst.iter_mut().zip(src) {
                    *d += *s;
                }
                // Track usage so the second pass sees the reduced leftover.
                for (p, &r) in src.iter().enumerate() {
                    if r > 0.0 {
                        for &e in &demands[di].paths[p] {
                            used[e] += r;
                            leftover[e] = (leftover[e] - r).max(0.0);
                        }
                    }
                }
            }
        }

        self.stats.round_time_s += round_start.elapsed().as_secs_f64();
        alloc
    }
}

impl Policy for TerraPolicy {
    fn name(&self) -> &'static str {
        "terra"
    }

    fn k_paths(&self) -> usize {
        self.cfg.k
    }

    fn allocate(
        &mut self,
        now: f64,
        _trigger: RoundTrigger,
        coflows: &[CoflowState],
        net: &NetView,
    ) -> Allocation {
        self.run_round(now, coflows, net, None, None, None, 0)
    }

    /// Incremental entry point: reuse cached standalone Γ solves within a
    /// WAN capacity epoch, warm-start GK from the previous allocation, and
    /// run every solve on the workspace's cached flat CSR blocks.
    fn allocate_with(
        &mut self,
        now: f64,
        ctx: RoundCtx<'_>,
        coflows: &[CoflowState],
        net: &NetView,
    ) -> Allocation {
        let epoch = ctx.epoch;
        self.run_round(now, coflows, net, Some(ctx.cache), ctx.warm, Some(ctx.ws), epoch)
    }

    /// Terra's allocation is a pure function of its configuration: forks
    /// share the (stateless) PJRT artifact handle and start with fresh
    /// instrumentation, so the engine can solve independent components on
    /// parallel workers with results bit-identical to the sequential order.
    fn fork(&self) -> Option<Box<dyn Policy>> {
        Some(Box::new(TerraPolicy {
            cfg: self.cfg.clone(),
            jax: self.jax.clone(),
            stats: RoundStats::default(),
        }))
    }

    /// Class-aware admission. Deadline coflows follow Pseudocode 2: admit
    /// iff the minimum CCT on the guaranteed-residual WAN stays within η·D.
    /// Stream coflows admit iff their full rate floor fits the residual
    /// headroom after the α reservation and the floors already promised to
    /// admitted streams — an admitted stream's floor is a guarantee, so
    /// over-admitting floors would manufacture violations by construction.
    fn admit(
        &mut self,
        now: f64,
        candidate: &CoflowState,
        coflows: &[CoflowState],
        net: &NetView,
    ) -> bool {
        if candidate.rate_floor().is_some() {
            let mut residual: Vec<f64> =
                net.wan.capacities().iter().map(|c| c * (1.0 - self.cfg.alpha)).collect();
            let (demands, floors, _) = stream_floor_demands(
                coflows
                    .iter()
                    .enumerate()
                    .filter(|(_, c)| c.admitted && !c.done()),
                net,
                self.cfg.k,
            );
            let _ = maxmin::reserve_floors(&mut residual, &demands, &floors);
            let (cand_demands, cand_floors, _) =
                stream_floor_demands(std::iter::once((0, candidate)), net, self.cfg.k);
            let (_, shortfall) = maxmin::reserve_floors(&mut residual, &cand_demands, &cand_floors);
            return shortfall.iter().all(|&s| s <= 1e-9);
        }
        let Some(deadline) = candidate.deadline else { return true };
        // Defense in depth for the invalid-deadline fix: a non-finite
        // absolute deadline reaching admission (e.g. straight off the wire,
        // bypassing `Coflow::with_deadline`) is treated as "no deadline".
        if !deadline.is_finite() {
            log::warn!(
                "coflow {}: non-finite deadline reached admission; treating as none",
                candidate.id
            );
            return true;
        }
        let caps_full = net.wan.capacities();
        let mut residual: Vec<f64> =
            caps_full.iter().map(|c| c * (1.0 - self.cfg.alpha)).collect();
        // Subtract the reserved rates of already-admitted deadline coflows
        // (they are guaranteed; Pseudocode 2 line 4).
        let mut admitted: Vec<&CoflowState> = coflows
            .iter()
            .filter(|c| c.admitted && c.deadline.is_some() && !c.done())
            .collect();
        // The filter above guarantees `deadline.is_some()`; total_cmp on
        // the inner f64 keeps the sort NaN-safe.
        admitted.sort_by(|a, b| b.deadline.unwrap_or(0.0).total_cmp(&a.deadline.unwrap_or(0.0)));
        for cf in admitted {
            if let Some((mut sol, index)) = self.solve_min_cct(cf, &residual, net, None, None, 0)
            {
                let d_rem = cf.deadline.unwrap() - now;
                let gamma = sol.gamma();
                if d_rem > gamma {
                    sol.scale(gamma / d_rem);
                }
                let (inst, _) =
                    build_instance(&cf.groups, &cf.remaining, &residual, net, self.cfg.k);
                let _ = index;
                for (u, r) in inst.edge_usage(&sol.rates).iter().zip(residual.iter_mut()) {
                    *r = (*r - u).max(0.0);
                }
            }
        }
        match self.solve_min_cct(candidate, &residual, net, None, None, 0) {
            Some((sol, _)) => sol.gamma() <= self.cfg.eta * (deadline - now) + 1e-9,
            None => false,
        }
    }

    fn take_stats(&mut self) -> RoundStats {
        std::mem::take(&mut self.stats)
    }
}

/// Collect the floor-bearing (stream) coflows' unfinished FlowGroups as
/// `GroupDemand`s with parallel per-group floors and `(coflow idx, group
/// idx)` owners, in slice (= arrival) order — the deterministic reservation
/// order for two-level filling and stream admission.
fn stream_floor_demands<'a>(
    coflows: impl Iterator<Item = (usize, &'a CoflowState)>,
    net: &NetView,
    k: usize,
) -> (Vec<lp::GroupDemand>, Vec<f64>, Vec<(usize, usize)>) {
    let mut demands = Vec::new();
    let mut floors = Vec::new();
    let mut owners = Vec::new();
    for (i, cf) in coflows {
        let Some(floor) = cf.rate_floor() else { continue };
        for (gi, (g, &rem)) in cf.groups.iter().zip(&cf.remaining).enumerate() {
            if rem <= 1e-9 {
                continue;
            }
            let paths: Vec<Vec<usize>> =
                net.paths.get(g.src, g.dst).iter().take(k).map(|p| p.edges.clone()).collect();
            demands.push(lp::GroupDemand { volume: rem, paths });
            floors.push(floor);
            owners.push((i, gi));
        }
    }
    (demands, floors, owners)
}

/// Get (or rebuild) `cf`'s cached flat CSR block in the workspace and
/// refresh its capacities/volumes for a solve on `caps`. A block is fresh
/// iff it was built under the same WAN-capacity epoch (k-path sets are a
/// pure function of the epoch's WAN) and the coflow's unfinished-group set
/// is unchanged; within an epoch, re-preparing a cached block is a capacity
/// gather plus a volume copy — no path-list traversal, no allocation.
/// Returns `None` when the coflow has no unfinished groups.
#[allow(clippy::too_many_arguments)]
fn ensure_csr<'a>(
    csr: &'a mut std::collections::HashMap<crate::coflow::CoflowId, CachedCsr>,
    builder: &mut lp::flat::FlatBuilder,
    edge_map: &mut lp::flat::EdgeMap,
    cf: &CoflowState,
    caps: &[f64],
    net: &NetView,
    k: usize,
    epoch: u64,
) -> Option<&'a mut CachedCsr> {
    let entry = csr.entry(cf.id).or_default();
    let mut fresh = entry.epoch == epoch && !entry.index.is_empty();
    if fresh {
        let mut it = entry.index.iter().copied();
        for (gi, &rem) in cf.remaining.iter().enumerate() {
            if rem <= 1e-9 {
                continue;
            }
            if it.next() != Some(gi) {
                fresh = false;
                break;
            }
        }
        if fresh && it.next().is_some() {
            fresh = false;
        }
    }
    if fresh {
        entry.flat.set_caps(caps);
        entry.flat.set_vols(entry.index.iter().map(|&gi| cf.remaining[gi]));
    } else {
        builder.clear();
        entry.index.clear();
        for (gi, (g, &rem)) in cf.groups.iter().zip(&cf.remaining).enumerate() {
            if rem <= 1e-9 {
                continue;
            }
            entry.index.push(gi);
            builder.push_group(
                rem,
                net.paths.get(g.src, g.dst).iter().take(k).map(|p| p.edges.as_slice()),
            );
        }
        if entry.index.is_empty() {
            return None;
        }
        builder.finish_into(caps, edge_map, &mut entry.flat);
        entry.epoch = epoch;
    }
    Some(entry)
}

/// Edge usage of an allocation (helper; also used by the simulator's
/// feasibility debug check).
pub fn alloc_usage(
    alloc: &Allocation,
    coflows: &[CoflowState],
    net: &NetView,
    num_edges: usize,
) -> Vec<f64> {
    alloc.edge_usage(coflows, net, num_edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coflow::{Coflow, Flow, GB};
    use crate::net::paths::PathSet;
    use crate::net::topologies;

    fn state(id: u64, flows: Vec<(usize, usize, f64)>) -> CoflowState {
        let flows = flows
            .into_iter()
            .enumerate()
            .map(|(i, (s, d, v))| Flow { id: i as u64, src_dc: s, dst_dc: d, volume: v })
            .collect();
        CoflowState::from_coflow(&Coflow::new(id, flows))
    }

    /// Figure 1: Coflow-1 = 5 GB A->B; Coflow-2 = 5 GB A->B + 25 GB C->B.
    /// Terra's joint solution reaches ~7.15 s average CCT (vs 14 fair,
    /// 10.6 multipath, 12 coflow-only).
    #[test]
    fn fig1_joint_optimum() {
        let wan = topologies::fig1a();
        let paths = PathSet::compute(&wan, 3);
        let net = NetView { wan: &wan, paths: &paths };
        let c1 = state(1, vec![(0, 1, 5.0 * GB)]);
        let c2 = state(2, vec![(0, 1, 5.0 * GB), (2, 1, 25.0 * GB)]);
        let mut terra = TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() });
        let alloc = terra.allocate(0.0, RoundTrigger::Initial, &[c1.clone(), c2.clone()], &net);

        // Feasibility.
        let usage = alloc.edge_usage(&[c1.clone(), c2.clone()], &net, wan.num_edges());
        for (u, c) in usage.iter().zip(wan.capacities()) {
            assert!(*u <= c + 1e-6, "over capacity");
        }
        // Coflow-1 is smaller => scheduled first at its minimum CCT (2 s via
        // both paths: 40 Gbit over 20 Gbps).
        let r1: f64 = alloc.rates[&1][0].iter().sum();
        assert!(r1 > 15.0, "coflow1 rate {r1}");
        // Coflow-2 should still make progress (work conservation).
        let r2: f64 = alloc.rates[&2].iter().flatten().sum();
        assert!(r2 > 0.0);
    }

    #[test]
    fn deadline_dilation_frees_bandwidth() {
        let wan = topologies::fig1a();
        let paths = PathSet::compute(&wan, 3);
        let net = NetView { wan: &wan, paths: &paths };
        let mut cf = state(1, vec![(0, 1, 5.0 * GB)]);
        cf.deadline = Some(8.0); // minimum CCT is 2 s at alpha=0
        cf.admitted = true;
        let mut terra = TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() });
        let alloc = terra.allocate(0.0, RoundTrigger::Initial, &[cf.clone()], &net);
        let rate: f64 = alloc.rates[&1][0].iter().sum();
        // Dilated to finish at the deadline: 40 Gbit / 8 s = 5 Gbps.
        assert!((rate - 5.0).abs() < 0.5, "rate={rate}");
    }

    #[test]
    fn admission_rejects_impossible_deadline() {
        let wan = topologies::fig1a();
        let paths = PathSet::compute(&wan, 3);
        let net = NetView { wan: &wan, paths: &paths };
        let mut terra = TerraPolicy::default();
        let mut cf = state(1, vec![(0, 1, 100.0 * GB)]); // needs 40 s at 20 Gbps
        cf.deadline = Some(5.0);
        assert!(!terra.admit(0.0, &cf, &[], &net));
        cf.deadline = Some(500.0);
        assert!(terra.admit(0.0, &cf, &[], &net));
    }

    #[test]
    fn admission_protects_admitted() {
        let wan = topologies::fig1a();
        let paths = PathSet::compute(&wan, 3);
        let net = NetView { wan: &wan, paths: &paths };
        let mut terra = TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() });
        // Admitted coflow consumes most of A->B for 10 s.
        let mut big = state(1, vec![(0, 1, 25.0 * GB)]); // 200 Gbit / 20 Gbps = 10 s min
        big.deadline = Some(10.0);
        big.admitted = true;
        assert!(terra.admit(0.0, &big, &[], &net));
        // A second coflow on the same pair with a tight deadline must be
        // rejected: the admitted one leaves nothing.
        let mut tight = state(2, vec![(0, 1, 10.0 * GB)]);
        tight.deadline = Some(4.5);
        assert!(!terra.admit(0.0, &tight, &[big.clone()], &net));
        // Admission is deliberately conservative (Pseudocode 2 solves on the
        // *current* residual, not a time-expanded schedule): even a loose
        // deadline on the saturated pair is rejected...
        let mut loose = state(3, vec![(0, 1, 10.0 * GB)]);
        loose.deadline = Some(60.0);
        assert!(!terra.admit(0.0, &loose, &[big.clone()], &net));
        // ...but a coflow in an uncontended *direction* admits fine: big
        // saturates links toward B, leaving B->C untouched.
        let mut other = state(4, vec![(1, 2, 5.0 * GB)]);
        other.deadline = Some(30.0);
        assert!(terra.admit(0.0, &other, &[big], &net));
    }

    #[test]
    fn alpha_reserves_headroom() {
        let wan = topologies::fig1a();
        let paths = PathSet::compute(&wan, 3);
        let net = NetView { wan: &wan, paths: &paths };
        let c1 = state(1, vec![(0, 1, 5.0 * GB)]);
        let mut terra = TerraPolicy::new(TerraConfig { alpha: 0.5, ..Default::default() });
        let alloc = terra.allocate(0.0, RoundTrigger::Initial, &[c1.clone()], &net);
        // With work conservation the single coflow still gets the full WAN.
        let r: f64 = alloc.rates[&1][0].iter().sum();
        assert!(r > 15.0, "work conservation should fill alpha share, r={r}");
    }

    fn stream_state(id: u64, flows: Vec<(usize, usize, f64)>, floor: f64) -> CoflowState {
        let flows = flows
            .into_iter()
            .enumerate()
            .map(|(i, (s, d, v))| Flow { id: i as u64, src_dc: s, dst_dc: d, volume: v })
            .collect();
        CoflowState::from_coflow(
            &Coflow::new(id, flows)
                .with_class(crate::coflow::ServiceClass::Stream { rate_floor_gbps: floor }),
        )
    }

    /// A stream's floor is reserved before batch filling: the batch coflow
    /// loses exactly the floor, the stream gets at least it, and the whole
    /// allocation stays feasible.
    #[test]
    fn stream_floor_reserved_before_batch() {
        let wan = topologies::fig1a();
        let paths = PathSet::compute(&wan, 3);
        let net = NetView { wan: &wan, paths: &paths };
        let batch = state(1, vec![(0, 1, 50.0 * GB)]);
        let stream = stream_state(2, vec![(0, 1, 100.0 * GB)], 4.0);
        let mut terra = TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() });
        let all = [batch.clone(), stream.clone()];
        let alloc = terra.allocate(0.0, RoundTrigger::Initial, &all, &net);
        let stream_rate: f64 = alloc.rates[&2].iter().flatten().sum();
        assert!(stream_rate >= 4.0 - 1e-6, "floor not honored: {stream_rate}");
        let usage = alloc.edge_usage(&all, &net, wan.num_edges());
        for (u, c) in usage.iter().zip(wan.capacities()) {
            assert!(*u <= c + 1e-6, "over capacity");
        }
        // Work conservation still fills the WAN for the batch coflow.
        let batch_rate: f64 = alloc.rates[&1].iter().flatten().sum();
        assert!(batch_rate > 0.0);
        assert_eq!(terra.take_stats().floor_shortfall_gbps, 0.0);
    }

    /// An infeasible floor surfaces as shortfall in the round stats rather
    /// than being silently clamped away.
    #[test]
    fn infeasible_floor_surfaces_as_shortfall() {
        let wan = topologies::fig1a();
        let paths = PathSet::compute(&wan, 3);
        let net = NetView { wan: &wan, paths: &paths };
        // fig1a links are 10 Gbps; a 500 Gbps floor cannot fit anywhere.
        let stream = stream_state(1, vec![(0, 1, 100.0 * GB)], 500.0);
        let mut terra = TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() });
        let alloc = terra.allocate(0.0, RoundTrigger::Initial, &[stream.clone()], &net);
        let st = terra.take_stats();
        assert!(st.floor_shortfall_gbps > 0.0, "shortfall={}", st.floor_shortfall_gbps);
        // What capacity there was *is* still reserved (best effort).
        let got: f64 = alloc.rates[&1].iter().flatten().sum();
        assert!(got > 0.0);
        let usage = alloc.edge_usage(&[stream], &net, wan.num_edges());
        for (u, c) in usage.iter().zip(wan.capacities()) {
            assert!(*u <= c + 1e-6);
        }
    }

    /// Stream admission: floors admit while they fit the headroom and are
    /// rejected once admitted streams have claimed it.
    #[test]
    fn stream_admission_respects_headroom() {
        let wan = topologies::fig1a();
        let paths = PathSet::compute(&wan, 3);
        let net = NetView { wan: &wan, paths: &paths };
        let mut terra = TerraPolicy::default();
        // 0->1 offers 20 Gbps across both paths; α=0.1 leaves 18.
        let s1 = stream_state(1, vec![(0, 1, 100.0 * GB)], 8.0);
        assert!(terra.admit(0.0, &s1, &[], &net));
        let mut admitted = s1.clone();
        admitted.admitted = true;
        // A second 12 Gbps floor no longer fits next to the admitted 8.
        let s2 = stream_state(2, vec![(0, 1, 100.0 * GB)], 12.0);
        assert!(!terra.admit(0.0, &s2, &[admitted.clone()], &net));
        // A modest floor still fits.
        let s3 = stream_state(3, vec![(0, 1, 100.0 * GB)], 2.0);
        assert!(terra.admit(0.0, &s3, &[admitted], &net));
    }

    #[test]
    fn stats_count_lps() {
        let wan = topologies::fig1a();
        let paths = PathSet::compute(&wan, 3);
        let net = NetView { wan: &wan, paths: &paths };
        let c1 = state(1, vec![(0, 1, 5.0 * GB)]);
        let c2 = state(2, vec![(2, 1, 5.0 * GB)]);
        let mut terra = TerraPolicy::default();
        let _ = terra.allocate(0.0, RoundTrigger::Initial, &[c1, c2], &net);
        let st = terra.take_stats();
        assert!(st.lp_solves >= 4, "2 sort + 2 alloc solves, got {}", st.lp_solves);
        assert!(st.round_time_s > 0.0);
        // Drained.
        assert_eq!(terra.take_stats().lp_solves, 0);
    }
}
