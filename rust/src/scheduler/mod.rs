//! Terra's joint scheduling-routing algorithm (§3) and the policy interface
//! shared with the baselines (§6.1).
//!
//! A **policy** is invoked on every scheduling round — coflow arrival,
//! FlowGroup/coflow completion, or a significant WAN event (§3.1.3) — and
//! produces a rate allocation: for every active coflow, for every FlowGroup,
//! a rate per path of the FlowGroup's k-shortest-path set. The flow-level
//! simulator ([`crate::sim`]) and the overlay controller
//! ([`crate::overlay`]) both drive policies through this interface, which
//! mirrors how the paper runs the same controller logic in testbed and
//! simulation (§6.1).

pub mod terra;

pub use terra::TerraPolicy;

use crate::coflow::{CoflowId, FlowGroup, ServiceClass};
use crate::engine::GammaCache;
use crate::lp::{GroupDemand, McfInstance, SolverWorkspace};
use crate::net::paths::PathSet;
use crate::net::Wan;
use std::collections::HashMap;

/// Scheduler-facing view of one active coflow.
#[derive(Clone, Debug)]
pub struct CoflowState {
    pub id: CoflowId,
    pub arrival: f64,
    /// Absolute deadline (arrival + D_i), if any.
    pub deadline: Option<f64>,
    /// True once admitted by deadline admission control; admitted coflows
    /// are never preempted (§3.2).
    pub admitted: bool,
    /// Coalesced FlowGroups (fixed order; `remaining` is parallel).
    pub groups: Vec<FlowGroup>,
    /// Remaining volume per FlowGroup in Gbit.
    pub remaining: Vec<f64>,
    /// Traffic class driving admission, ordering, and floor reservation.
    /// `Batch` for everything class-free (structural default).
    pub class: ServiceClass,
}

impl CoflowState {
    pub fn from_coflow(c: &crate::coflow::Coflow) -> CoflowState {
        let groups = c.flow_groups();
        let remaining = groups.iter().map(|g| g.volume).collect();
        // Deadline-bearing batch coflows are the Deadline class; the tag is
        // derived so pre-class call sites need no change.
        let class = match (&c.class, c.deadline) {
            (ServiceClass::Batch, Some(_)) => ServiceClass::Deadline,
            (cls, _) => cls.clone(),
        };
        CoflowState {
            id: c.id,
            arrival: c.arrival,
            deadline: c.deadline.map(|d| c.arrival + d),
            admitted: false,
            groups,
            remaining,
            class,
        }
    }

    pub fn total_remaining(&self) -> f64 {
        self.remaining.iter().sum()
    }

    pub fn done(&self) -> bool {
        self.remaining.iter().all(|&r| r <= 1e-9)
    }

    /// The per-FlowGroup rate floor this coflow must sustain, if its class
    /// has one.
    pub fn rate_floor(&self) -> Option<f64> {
        self.class.rate_floor()
    }
}

/// Immutable network view handed to policies each round.
pub struct NetView<'a> {
    pub wan: &'a Wan,
    pub paths: &'a PathSet,
}

/// Rates per coflow: `rates[group_idx][path_idx]` in Gbps, with path indices
/// aligned to `NetView::paths.get(src, dst)` truncated to the policy's k.
pub type CoflowRates = Vec<Vec<f64>>;

/// One round's allocation decision.
#[derive(Clone, Debug, Default)]
pub struct Allocation {
    pub rates: HashMap<CoflowId, CoflowRates>,
}

impl Allocation {
    /// Aggregate per-edge usage (for utilization metrics and feasibility
    /// checks).
    pub fn edge_usage(
        &self,
        coflows: &[CoflowState],
        net: &NetView,
        num_edges: usize,
    ) -> Vec<f64> {
        let mut usage = vec![0.0; num_edges];
        for cf in coflows {
            let Some(rates) = self.rates.get(&cf.id) else { continue };
            for (gi, g) in cf.groups.iter().enumerate() {
                let paths = net.paths.get(g.src, g.dst);
                for (pi, &r) in
                    rates.get(gi).map(|v| v.as_slice()).unwrap_or(&[]).iter().enumerate()
                {
                    if r <= 0.0 {
                        continue;
                    }
                    if let Some(p) = paths.get(pi) {
                        for &e in &p.edges {
                            usage[e] += r;
                        }
                    }
                }
            }
        }
        usage
    }
}

/// Per-round instrumentation (paper §6.6 reports LPs/round and time/round).
#[derive(Clone, Debug, Default)]
pub struct RoundStats {
    pub lp_solves: usize,
    pub lp_time_s: f64,
    pub round_time_s: f64,
    /// Standalone-Γ solves answered from the [`GammaCache`] instead of an
    /// LP solve (incremental re-optimization).
    pub gamma_cache_hits: usize,
    /// Edge-connected components the engine re-solved (dirty components).
    pub component_solves: usize,
    /// Components whose previous allocation was carried forward unchanged
    /// (no member arrival/departure/completion, no qualifying WAN change on
    /// their edges).
    pub component_reuses: usize,
    /// Coflows moved between engine shards by the sharded front-end
    /// (cross-shard arrivals / edge-set changes). Always 0 single-shard.
    pub shard_migrations: usize,
    /// Stream rate-floor Gbps the two-level filling could **not** reserve
    /// this round (summed over rounds and violating groups). Infeasible
    /// floors surface here instead of being silently clamped.
    pub floor_shortfall_gbps: f64,
}

impl RoundStats {
    pub fn merge(&mut self, other: &RoundStats) {
        self.lp_solves += other.lp_solves;
        self.lp_time_s += other.lp_time_s;
        self.round_time_s += other.round_time_s;
        self.gamma_cache_hits += other.gamma_cache_hits;
        self.component_solves += other.component_solves;
        self.component_reuses += other.component_reuses;
        self.shard_migrations += other.shard_migrations;
        self.floor_shortfall_gbps += other.floor_shortfall_gbps;
    }
}

/// Why the round was triggered — Terra's online algorithm reacts to event
/// categories differently (§3.1.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundTrigger {
    CoflowArrival,
    FlowGroupFinish,
    CoflowFinish,
    WanChange,
    Initial,
}

/// Incremental-re-optimization context handed to cache-aware policies by
/// the [`crate::engine::RoundEngine`] on every round.
pub struct RoundCtx<'a> {
    /// Why this round fired.
    pub trigger: RoundTrigger,
    /// WAN capacity epoch the round runs under; bumped by qualifying WAN
    /// events, at which point every cached Γ is stale.
    pub epoch: u64,
    /// Cross-round cache of standalone min-CCT solves.
    pub cache: &'a mut GammaCache,
    /// Previous round's allocation for warm-starting iterative solvers, or
    /// `None` right after structural WAN changes (stale path indices).
    pub warm: Option<&'a Allocation>,
    /// Persistent solver workspace (flat CSR block cache + GK scratch).
    /// Engine-owned, one per solver worker; policies reuse it for
    /// allocation-free solves and cache per-coflow CSR blocks in it.
    pub ws: &'a mut SolverWorkspace,
}

/// The scheduling-routing policy interface implemented by Terra and all
/// five baselines.
// `Send + Sync`: engine shards holding forked policies run on scoped
// threads and hand shared `&RoundEngine` views back to the enforcement
// pipeline; every implementation is plain owned data (mutation only via
// `&mut self`), so the bound costs nothing.
pub trait Policy: Send + Sync {
    fn name(&self) -> &'static str;

    /// Compute this round's allocation. `coflows` contains only unfinished
    /// coflows (deadline-rejected ones never appear).
    fn allocate(
        &mut self,
        now: f64,
        trigger: RoundTrigger,
        coflows: &[CoflowState],
        net: &NetView,
    ) -> Allocation;

    /// Cache-aware entry point used by the [`crate::engine::RoundEngine`].
    /// Policies that can reuse work across rounds (Γ-cache hits, warm
    /// starts) override this; the default ignores the context and performs
    /// a cold [`Policy::allocate`].
    fn allocate_with(
        &mut self,
        now: f64,
        ctx: RoundCtx<'_>,
        coflows: &[CoflowState],
        net: &NetView,
    ) -> Allocation {
        self.allocate(now, ctx.trigger, coflows, net)
    }

    /// Deadline admission control (§3.2). Default: admit everything.
    fn admit(
        &mut self,
        _now: f64,
        _candidate: &CoflowState,
        _admitted: &[CoflowState],
        _net: &NetView,
    ) -> bool {
        true
    }

    /// Drain instrumentation recorded since the last call.
    fn take_stats(&mut self) -> RoundStats {
        RoundStats::default()
    }

    /// Number of paths per datacenter pair this policy uses (drives PathSet
    /// precomputation in the driver).
    fn k_paths(&self) -> usize {
        DEFAULT_K
    }

    /// Clone this policy for a parallel solver worker. Policies whose
    /// allocation is a pure function of their configuration (no carried
    /// per-round state beyond instrumentation) return a fresh instance; the
    /// engine then solves independent components concurrently, each worker
    /// driving its own fork. `None` (the default) keeps component solves
    /// sequential for this policy.
    fn fork(&self) -> Option<Box<dyn Policy>> {
        None
    }
}

/// Paper defaults (§6.1): k = 15 paths, α = 0.1 starvation share,
/// ρ = 25 % re-optimization threshold, η = 1.2 deadline relaxation.
pub const DEFAULT_K: usize = 15;
pub const DEFAULT_ALPHA: f64 = 0.1;
pub const DEFAULT_RHO: f64 = 0.25;
pub const DEFAULT_ETA: f64 = 1.2;

/// Build the Optimization (1) instance for one coflow's unfinished groups on
/// the given residual capacities. Returns the instance plus the mapping from
/// instance-group index to `groups` index.
pub fn build_instance(
    groups: &[FlowGroup],
    remaining: &[f64],
    caps: &[f64],
    net: &NetView,
    k: usize,
) -> (McfInstance, Vec<usize>) {
    let mut demands = Vec::new();
    let mut index = Vec::new();
    for (gi, (g, &rem)) in groups.iter().zip(remaining).enumerate() {
        if rem <= 1e-9 {
            continue;
        }
        let paths: Vec<Vec<usize>> =
            net.paths.get(g.src, g.dst).iter().take(k).map(|p| p.edges.clone()).collect();
        demands.push(GroupDemand { volume: rem, paths });
        index.push(gi);
    }
    (McfInstance { cap: caps.to_vec(), groups: demands }, index)
}

/// Expand an instance-indexed rate vector back to the coflow's full group
/// list (unfinished groups get their computed path-rates, finished stay
/// empty).
pub fn expand_rates(num_groups: usize, index: &[usize], rates: &[Vec<f64>]) -> CoflowRates {
    let mut out: CoflowRates = vec![Vec::new(); num_groups];
    for (ii, &gi) in index.iter().enumerate() {
        out[gi] = rates[ii].clone();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coflow::{Coflow, Flow};
    use crate::net::topologies;

    #[test]
    fn coflow_state_from_coflow() {
        let c = Coflow::new(
            7,
            vec![
                Flow { id: 0, src_dc: 0, dst_dc: 1, volume: 4.0 },
                Flow { id: 1, src_dc: 0, dst_dc: 1, volume: 4.0 },
                Flow { id: 2, src_dc: 2, dst_dc: 1, volume: 8.0 },
            ],
        )
        .with_arrival(10.0)
        .with_deadline(5.0);
        let st = CoflowState::from_coflow(&c);
        assert_eq!(st.groups.len(), 2);
        assert_eq!(st.deadline, Some(15.0));
        assert!((st.total_remaining() - 16.0).abs() < 1e-9);
        assert!(!st.done());
    }

    #[test]
    fn build_instance_skips_finished_groups() {
        let wan = topologies::fig1a();
        let paths = PathSet::compute(&wan, 3);
        let net = NetView { wan: &wan, paths: &paths };
        let groups = vec![
            FlowGroup { src: 0, dst: 1, volume: 10.0, num_flows: 1 },
            FlowGroup { src: 2, dst: 1, volume: 10.0, num_flows: 1 },
        ];
        let remaining = vec![0.0, 5.0];
        let (inst, idx) = build_instance(&groups, &remaining, &wan.capacities(), &net, 15);
        assert_eq!(inst.groups.len(), 1);
        assert_eq!(idx, vec![1]);
        assert!((inst.groups[0].volume - 5.0).abs() < 1e-9);
        assert!(!inst.groups[0].paths.is_empty());
    }

    #[test]
    fn expand_rates_roundtrip() {
        let out = expand_rates(3, &[2], &[vec![0.5]]);
        assert_eq!(out.len(), 3);
        assert!(out[0].is_empty() && out[1].is_empty());
        assert_eq!(out[2], vec![0.5]);
    }

    #[test]
    fn edge_usage_aggregates() {
        let wan = topologies::fig1a();
        let paths = PathSet::compute(&wan, 3);
        let net = NetView { wan: &wan, paths: &paths };
        let c = Coflow::new(1, vec![Flow { id: 0, src_dc: 0, dst_dc: 1, volume: 10.0 }]);
        let st = CoflowState::from_coflow(&c);
        let mut alloc = Allocation::default();
        alloc.rates.insert(1, vec![vec![3.0, 2.0]]); // direct + 2-hop
        let usage = alloc.edge_usage(&[st], &net, wan.num_edges());
        let direct = &paths.get(0, 1)[0];
        assert!((usage[direct.edges[0]] - 3.0).abs() < 1e-9);
        let total: f64 = usage.iter().sum();
        assert!((total - (3.0 + 2.0 * 2.0)).abs() < 1e-9); // 2-hop path hits 2 edges
    }
}
