//! The `terra` launcher: simulation, paper reproduction, and a real
//! controller+agents testbed over loopback TCP.
//!
//! ```text
//! terra simulate  --topology swan --workload bigbench --policy terra --jobs 100
//! terra reproduce --table3 | --fig6 | --fig8 | --fig11 | --fig12 | --fig13 | --fig14 | --fig1 | --fig2 | --alpha | --all
//! terra sweep     --seed 7 --jobs 6 [--profiles calm,flaky] [--policies terra,per-flow]
//! terra testbed   --topology fig1a --gbit 4
//! terra topology  --name att
//! ```

use terra::baselines;
use terra::net::topologies;
use terra::scheduler::terra::TerraPolicy;
use terra::sim::{SimConfig, Simulation};
use terra::util::bench::Table;
use terra::util::cli::Args;
use terra::workloads::{WorkloadConfig, WorkloadGen, WorkloadKind};

fn main() {
    terra::util::logger::init();
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("simulate") => simulate(&args),
        Some("reproduce") => reproduce(&args),
        Some("sweep") => sweep(&args),
        Some("testbed") => testbed(&args),
        Some("topology") => topology_info(&args),
        _ => {
            eprintln!(
                "usage: terra <simulate|reproduce|sweep|testbed|topology> [--options]\n\
                 \n\
                 simulate  --topology swan|gscale|att --workload bigbench|tpcds|tpch|fb\n\
                 \u{20}          --policy terra|per-flow|multipath|varys|swan-mcf|rapier\n\
                 \u{20}          --jobs N --seed S [--solver jax] [--k K] [--alpha A]\n\
                 \u{20}          [--workers W] [--shards S]\n\
                 reproduce --all | --fig1 --fig2 --fig6 --fig8 --fig11 --fig12 --fig13\n\
                 \u{20}          --fig14 --table3 --alpha [--jobs N] [--seed S]\n\
                 sweep     [--jobs N] [--seed S] [--horizon SECS] [--deadlines D]\n\
                 \u{20}          [--topology T] [--workload W] [--profiles a,b] [--policies x,y]\n\
                 \u{20}          [--out BENCH_scenarios.json]   (workload x topology x policy\n\
                 \u{20}          x WAN-dynamics scenario sweep; identical seed => identical\n\
                 \u{20}          event streams)\n\
                 \u{20}          --estimation [--estimators oracle,ewma,kalman,holddown]\n\
                 \u{20}          (capacity-estimation sweep: profiles x estimators, writes\n\
                 \u{20}          BENCH_estimation.json with MAPE / reaction latency / CCT\n\
                 \u{20}          inflation vs oracle; deadlines default to 3x min CCT)\n\
                 \u{20}          --recovery [--kill T] [--restart T]\n\
                 \u{20}          (controller-chaos sweep: profiles x {{always-up, resync,\n\
                 \u{20}          from-zero}}, writes BENCH_recovery.json with preserved\n\
                 \u{20}          in-flight fraction / degraded drain / CCT inflation)\n\
                 \u{20}          --agent-chaos [--kill T] [--restart T] [--site N]\n\
                 \u{20}          [--detection SECS]\n\
                 \u{20}          (data-plane chaos sweep: profiles x {{always-up, agent-kill,\n\
                 \u{20}          partition}}, writes BENCH_agent_chaos.json with detection\n\
                 \u{20}          latency / parked coflows / stall time / CCT inflation)\n\
                 \u{20}          --multitenant [--streams N] [--ml-jobs N] [--ml-iters N]\n\
                 \u{20}          (service-class sweep: batch + streams + geo-ML sync sharing\n\
                 \u{20}          one WAN per dynamics profile, writes BENCH_multitenant.json\n\
                 \u{20}          with per-class CCT / violation-seconds / iteration time)\n\
                 \u{20}          --saturation [--quick] [--shards 1,2] [--estimator E]\n\
                 \u{20}          [--interarrival poisson|pareto|lognormal] [--lambda0 L]\n\
                 \u{20}          [--max-lambda L] [--warmup S] [--measure S] [--drain S]\n\
                 \u{20}          (open-loop saturation sweep: ramp + bisect arrivals to the\n\
                 \u{20}          max-sustainable-coflows/s knee per topology x profile x\n\
                 \u{20}          policy x shard-count cell, writes BENCH_saturation.json)\n\
                 testbed   --topology fig1a --gbit VOLUME [--shards S]\n\
                 \u{20}          (real TCP overlay demo)\n\
                 topology  --name swan|gscale|att|fig1a"
            );
            std::process::exit(2);
        }
    }
}

fn simulate(args: &Args) {
    let topo = args.get_or("topology", "swan");
    let wan = topologies::by_name(topo).unwrap_or_else(|| {
        eprintln!("unknown topology {topo}");
        std::process::exit(2);
    });
    let kind = WorkloadKind::by_name(args.get_or("workload", "bigbench")).unwrap_or_else(|| {
        eprintln!("unknown workload");
        std::process::exit(2);
    });
    let pname = args.get_or("policy", "terra");
    let policy: Box<dyn terra::scheduler::Policy> = if pname == "terra" {
        let mut cfg = terra::scheduler::terra::TerraConfig::default();
        cfg.alpha = args.get_f64("alpha", cfg.alpha);
        cfg.k = args.get_usize("k", cfg.k);
        let mut p = TerraPolicy::new(cfg);
        if args.get("solver") == Some("jax") {
            match terra::runtime::JaxSolver::load("artifacts") {
                Ok(s) => p = p.with_jax(std::sync::Arc::new(s)),
                Err(e) => {
                    eprintln!("failed to load JAX artifacts ({e}); using native solver");
                }
            }
        }
        Box::new(p)
    } else {
        baselines::by_name(pname).unwrap_or_else(|| {
            eprintln!("unknown policy {pname}");
            std::process::exit(2);
        })
    };
    let n = args.get_usize("jobs", 100);
    let seed = args.get_u64("seed", 42);
    let mut cfg = WorkloadConfig::new(kind, seed);
    cfg.machines_per_dc = args.get_usize("machines", 100);
    cfg.arrival_scale = args.get_f64("arrival-scale", 1.0);
    let jobs = WorkloadGen::with_config(cfg).jobs(&wan, n);
    let sim_cfg = SimConfig {
        workers: args.get_usize("workers", terra::engine::default_workers()),
        shards: args.get_usize("shards", 1),
        ..Default::default()
    };
    let mut sim = Simulation::new(wan, policy, sim_cfg);
    let rep = sim.run_jobs(jobs);
    println!(
        "policy={} jobs={} avg_jct={:.1}s p95_jct={:.1}s avg_cct={:.2}s util={:.1}% \
         rounds={} lps={} ms/round={:.2} makespan={:.0}s unfinished={}",
        rep.policy,
        rep.jobs.len(),
        rep.avg_jct(),
        rep.p95_jct(),
        rep.avg_cct(),
        rep.utilization() * 100.0,
        rep.rounds,
        rep.lp_solves,
        1e3 * rep.round_time_s / rep.rounds.max(1) as f64,
        rep.makespan,
        rep.unfinished(),
    );
}

fn reproduce(args: &Args) {
    let jobs = args.get_usize("jobs", 60);
    let seed = args.get_u64("seed", 42);
    let all = args.flag("all");
    use terra::experiments as exp;

    if all || args.flag("fig1") {
        let mut t = Table::new(&["policy", "avg CCT (s)", "paper (s)"]);
        let paper = [("per-flow", 14.0), ("multipath", 10.6), ("varys", 12.0), ("terra", 7.15)];
        for (name, cct) in exp::fig1_motivation() {
            let p = paper.iter().find(|(n, _)| *n == name).map(|(_, v)| *v).unwrap_or(0.0);
            t.row(&[name, format!("{cct:.2}"), format!("{p:.2}")]);
        }
        t.print("Figure 1: motivating example (avg CCT, 2 coflows on 3-DC mesh)");
    }
    if all || args.flag("fig2") {
        let mut t = Table::new(&["scenario", "avg CCT (s)", "paper (s)"]);
        let rows = exp::fig2_reopt();
        t.row(&["no-failure".into(), format!("{:.2}", rows[0].1), "8.00".into()]);
        t.row(&["failure+reopt".into(), format!("{:.2}", rows[1].1), "14.00".into()]);
        t.print("Figure 2: application-aware re-optimization under link failure");
    }
    if all || args.flag("fig6") {
        let mut t =
            Table::new(&["workload", "FoI avg JCT", "FoI p95 JCT", "FoI avg CCT", "FoI util"]);
        for r in exp::fig6_testbed(jobs.min(40), seed) {
            t.row(&[
                r.workload,
                format!("{:.2}x", r.foi_avg_jct),
                format!("{:.2}x", r.foi_p95_jct),
                format!("{:.2}x", r.foi_avg_cct),
                format!("{:.2}x", r.foi_util),
            ]);
        }
        t.print("Figure 6 + Table 2: testbed-style Terra vs per-flow on SWAN (paper: 1.55-3.43x avg, 2.12-8.49x p95, util 1.32-1.76x)");
    }
    if all || args.flag("fig8") {
        let mut t = Table::new(&["d", "terra met", "per-flow met", "ratio"]);
        for r in exp::fig8_deadlines(jobs.min(50), seed, "per-flow") {
            t.row(&[
                format!("{:.0}", r.d),
                format!("{:.0}%", r.terra_met * 100.0),
                format!("{:.0}%", r.baseline_met * 100.0),
                format!("{:.2}x", r.terra_met / r.baseline_met.max(1e-9)),
            ]);
        }
        t.print("Figure 8: deadlines met (paper: 2.82-4.29x testbed, 1.07-2.31x sim)");
    }
    if all || args.flag("fig11") {
        let mut t = Table::new(&["topology", "policy", "rounds", "LPs/round", "ms/round"]);
        for r in exp::fig11_overhead(jobs.min(30), seed) {
            t.row(&[
                r.topology,
                r.policy,
                r.rounds.to_string(),
                format!("{:.1}", r.lp_per_round),
                format!("{:.2}", r.ms_per_round),
            ]);
        }
        t.print("Figures 3+11 / §6.6: scheduling overhead (paper: Terra 74ms SWAN..589ms ATT; Rapier 26-29x worse)");
    }
    if all || args.flag("fig12") {
        let mut t = Table::new(&["k", "FoI avg JCT", "FoI util"]);
        for r in exp::fig12_paths(jobs.min(30), seed, WorkloadKind::BigBench) {
            t.row(&[
                r.k.to_string(),
                format!("{:.2}x", r.foi_avg_jct),
                format!("{:.2}x", r.foi_util),
            ]);
        }
        t.print("Figure 12: path-count sensitivity on ATT (gains flatten at k=5-10)");
    }
    if all || args.flag("fig13") {
        let mut t = Table::new(&["arrival scale", "FoI avg JCT"]);
        for r in exp::fig13_load(jobs.min(40), seed) {
            t.row(&[format!("{:.1}x", r.arrival_scale), format!("{:.2}x", r.foi_avg_jct)]);
        }
        t.print("Figure 13: load scaling (higher load => higher FoI)");
    }
    if all || args.flag("fig14") {
        let mut t = Table::new(&["machines/DC", "FoI avg JCT"]);
        for r in exp::fig14_machines(jobs.min(40), seed) {
            t.row(&[r.machines.to_string(), format!("{:.2}x", r.foi_avg_jct)]);
        }
        t.print("Figure 14: computation vs communication (more machines => higher FoI)");
    }
    if all || args.flag("alpha") {
        let mut t = Table::new(&["alpha", "avg JCT (s)"]);
        for (a, jct) in exp::alpha_sensitivity(jobs.min(40), seed) {
            t.row(&[format!("{a:.1}"), format!("{jct:.1}")]);
        }
        t.print("§6.7: alpha sensitivity (paper: alpha=0.2 is 2.3% worse than 0.1)");
    }
    if all || args.flag("table3") {
        let filter = args.get("topology");
        let mut t = Table::new(&[
            "topology", "workload", "baseline", "FoI avg", "FoI p95", "util FoI", "slowdown T/B",
            "corr(vol,FoI)",
        ]);
        for r in exp::table3(jobs, seed, filter) {
            t.row(&[
                r.topology,
                r.workload,
                r.baseline,
                format!("{:.2}x", r.foi_avg_jct),
                format!("{:.2}x", r.foi_p95_jct),
                format!("{:.2}x", 1.0 / r.foi_util.max(1e-12)),
                format!("{:.2}/{:.2}", r.terra_slowdown, r.baseline_slowdown),
                format!("{:.2}", r.volume_corr),
            ]);
        }
        t.print("Tables 3+4 / §6.3: Terra vs 5 baselines across <topology, workload>");
    }
}

/// The workload × topology × policy × WAN-dynamics scenario sweep. Writes
/// machine-readable results to `BENCH_scenarios.json` (or `--out`).
/// `--estimation` switches to the capacity-estimation sweep
/// (profiles × estimators → `BENCH_estimation.json`).
fn sweep(args: &Args) {
    use terra::experiments as exp;
    if args.flag("estimation") || args.get("estimation").is_some() {
        return estimation_sweep(args);
    }
    if args.flag("recovery") || args.get("recovery").is_some() {
        return recovery_sweep(args);
    }
    if args.flag("agent-chaos") || args.get("agent-chaos").is_some() {
        return agent_chaos_sweep(args);
    }
    if args.flag("multitenant") || args.get("multitenant").is_some() {
        return multitenant_sweep(args);
    }
    if args.flag("saturation") || args.get("saturation").is_some() {
        return saturation_sweep(args);
    }
    let defaults = exp::SweepConfig::default();
    let list = |v: &str| -> Vec<String> { v.split(',').map(|s| s.trim().to_string()).collect() };
    let cfg = exp::SweepConfig {
        jobs: args.get_usize("jobs", defaults.jobs),
        seed: args.get_u64("seed", defaults.seed),
        horizon_s: args.get_f64("horizon", defaults.horizon_s),
        deadline_d: args.get_f64("deadlines", defaults.deadline_d),
        topology: args.get("topology").map(|s| s.to_string()),
        workload: args.get("workload").map(|s| s.to_string()),
        profiles: args.get("profiles").map(list).unwrap_or(defaults.profiles),
        policies: args.get("policies").map(list).unwrap_or(defaults.policies),
        shards: args.get_usize("shards", defaults.shards),
    };
    let rows = exp::scenario_sweep(&cfg);
    let mut t = Table::new(&[
        "topology", "workload", "policy", "profile", "avg CCT", "p99 CCT", "met", "rounds",
        "WAN ev", "WAN rds", "react ms", "unfin",
    ]);
    for r in &rows {
        t.row(&[
            r.topology.clone(),
            r.workload.clone(),
            r.policy.clone(),
            r.profile.clone(),
            format!("{:.1}s", r.avg_cct),
            format!("{:.1}s", r.p99_cct),
            format!("{:.0}%", r.deadline_met * 100.0),
            r.rounds.to_string(),
            r.wan_events.to_string(),
            r.wan_rounds.to_string(),
            format!("{:.2}", r.reaction_ms_avg),
            r.unfinished.to_string(),
        ]);
    }
    t.print(&format!(
        "Scenario sweep: {} rows (seed {}, {} jobs, horizon {:.0}s)",
        rows.len(),
        cfg.seed,
        cfg.jobs,
        cfg.horizon_s
    ));
    let out = args.get_or("out", "BENCH_scenarios.json");
    match std::fs::write(out, format!("{}\n", exp::scenarios_json(&cfg, &rows))) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => {
            eprintln!("failed to write {out}: {e}");
            std::process::exit(1);
        }
    }
}

/// The estimation sweep: dynamics profiles × capacity estimators on one
/// ⟨topology, workload⟩, writing `BENCH_estimation.json` (or `--out`).
fn estimation_sweep(args: &Args) {
    use terra::experiments as exp;
    let defaults = exp::EstimationSweepConfig::default();
    let list = |v: &str| -> Vec<String> { v.split(',').map(|s| s.trim().to_string()).collect() };
    let cfg = exp::EstimationSweepConfig {
        jobs: args.get_usize("jobs", defaults.jobs),
        seed: args.get_u64("seed", defaults.seed),
        horizon_s: args.get_f64("horizon", defaults.horizon_s),
        deadline_d: args.get_f64("deadlines", defaults.deadline_d),
        topology: args.get_or("topology", &defaults.topology).to_string(),
        workload: args.get_or("workload", &defaults.workload).to_string(),
        profiles: args.get("profiles").map(list).unwrap_or(defaults.profiles),
        estimators: args.get("estimators").map(list).unwrap_or(defaults.estimators),
    };
    let rows = exp::estimation_sweep(&cfg);
    let mut t = Table::new(&[
        "profile", "estimator", "avg CCT", "vs oracle", "MAPE", "react s", "stale", "probes",
        "met", "unfin",
    ]);
    for r in &rows {
        t.row(&[
            r.profile.clone(),
            r.estimator.clone(),
            format!("{:.1}s", r.avg_cct),
            format!("{:.2}x", r.cct_vs_oracle),
            format!("{:.1}%", r.est_mape * 100.0),
            format!("{:.2}", r.stale_reaction_s_avg),
            format!("{}/{}", r.stale_resolved, r.stale_events),
            r.est_probes.to_string(),
            format!("{:.0}%", r.deadline_met * 100.0),
            r.unfinished.to_string(),
        ]);
    }
    t.print(&format!(
        "Estimation sweep: {} rows on {}/{} (seed {}, {} jobs, horizon {:.0}s, deadlines {:.1}x)",
        rows.len(),
        cfg.topology,
        cfg.workload,
        cfg.seed,
        cfg.jobs,
        cfg.horizon_s,
        cfg.deadline_d
    ));
    let out = args.get_or("out", "BENCH_estimation.json");
    match std::fs::write(out, format!("{}\n", exp::estimation_json(&cfg, &rows))) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => {
            eprintln!("failed to write {out}: {e}");
            std::process::exit(1);
        }
    }
}

/// The controller-chaos recovery sweep: dynamics profiles × controller
/// availability modes (always-up, resync, from-zero) on one
/// ⟨topology, workload⟩, writing `BENCH_recovery.json` (or `--out`).
fn recovery_sweep(args: &Args) {
    use terra::experiments as exp;
    let defaults = exp::RecoverySweepConfig::default();
    let list = |v: &str| -> Vec<String> { v.split(',').map(|s| s.trim().to_string()).collect() };
    let cfg = exp::RecoverySweepConfig {
        jobs: args.get_usize("jobs", defaults.jobs),
        seed: args.get_u64("seed", defaults.seed),
        horizon_s: args.get_f64("horizon", defaults.horizon_s),
        topology: args.get_or("topology", &defaults.topology).to_string(),
        workload: args.get_or("workload", &defaults.workload).to_string(),
        profiles: args.get("profiles").map(list).unwrap_or(defaults.profiles),
        kill_t: args.get_f64("kill", defaults.kill_t),
        restart_t: args.get_f64("restart", defaults.restart_t),
    };
    let rows = exp::recovery_sweep(&cfg);
    let mut t = Table::new(&[
        "profile", "mode", "avg CCT", "vs up", "preserved", "degraded Gbit", "down s",
        "recover ms", "unfin",
    ]);
    for r in &rows {
        t.row(&[
            r.profile.clone(),
            r.mode.clone(),
            format!("{:.1}s", r.avg_cct),
            format!("{:.2}x", r.cct_vs_always_up),
            format!("{:.0}%", r.preserved_fraction * 100.0),
            format!("{:.1}", r.drained_degraded_gbit),
            format!("{:.1}", r.downtime_s),
            format!("{:.2}", r.recovery_round_ms),
            r.unfinished.to_string(),
        ]);
    }
    t.print(&format!(
        "Recovery sweep: {} rows on {}/{} (seed {}, {} jobs, kill {:.0}s, restart {:.0}s)",
        rows.len(),
        cfg.topology,
        cfg.workload,
        cfg.seed,
        cfg.jobs,
        cfg.kill_t,
        cfg.restart_t
    ));
    let out = args.get_or("out", "BENCH_recovery.json");
    match std::fs::write(out, format!("{}\n", exp::recovery_json(&cfg, &rows))) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => {
            eprintln!("failed to write {out}: {e}");
            std::process::exit(1);
        }
    }
}

/// The data-plane chaos sweep: dynamics profiles × data-plane failure
/// modes (always-up, agent-kill, partition) on one ⟨topology, workload⟩,
/// writing `BENCH_agent_chaos.json` (or `--out`).
fn agent_chaos_sweep(args: &Args) {
    use terra::experiments as exp;
    let defaults = exp::AgentChaosSweepConfig::default();
    let list = |v: &str| -> Vec<String> { v.split(',').map(|s| s.trim().to_string()).collect() };
    let cfg = exp::AgentChaosSweepConfig {
        jobs: args.get_usize("jobs", defaults.jobs),
        seed: args.get_u64("seed", defaults.seed),
        horizon_s: args.get_f64("horizon", defaults.horizon_s),
        topology: args.get_or("topology", &defaults.topology).to_string(),
        workload: args.get_or("workload", &defaults.workload).to_string(),
        profiles: args.get("profiles").map(list).unwrap_or(defaults.profiles),
        kill_t: args.get_f64("kill", defaults.kill_t),
        restart_t: args.get_f64("restart", defaults.restart_t),
        site: args.get_usize("site", defaults.site),
        detection_s: args.get_f64("detection", defaults.detection_s),
    };
    let rows = exp::agent_chaos_sweep(&cfg);
    let mut t = Table::new(&[
        "profile", "mode", "avg CCT", "vs up", "downs", "detect s", "parked", "stall s",
        "unfin",
    ]);
    for r in &rows {
        t.row(&[
            r.profile.clone(),
            r.mode.clone(),
            format!("{:.1}s", r.avg_cct),
            format!("{:.2}x", r.cct_vs_always_up),
            r.agent_downs.to_string(),
            format!("{:.1}", r.detection_s),
            r.parked.to_string(),
            format!("{:.1}", r.stall_s),
            r.unfinished.to_string(),
        ]);
    }
    t.print(&format!(
        "Agent-chaos sweep: {} rows on {}/{} (seed {}, {} jobs, site {}, kill {:.0}s, \
         heal {:.0}s, detect {:.1}s)",
        rows.len(),
        cfg.topology,
        cfg.workload,
        cfg.seed,
        cfg.jobs,
        cfg.site,
        cfg.kill_t,
        cfg.restart_t,
        cfg.detection_s
    ));
    let out = args.get_or("out", "BENCH_agent_chaos.json");
    match std::fs::write(out, format!("{}\n", exp::agent_chaos_json(&cfg, &rows))) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => {
            eprintln!("failed to write {out}: {e}");
            std::process::exit(1);
        }
    }
}

/// The multi-tenant service-class sweep: batch + streaming + geo-ML jobs
/// sharing one ⟨topology, workload⟩ per dynamics profile, writing
/// `BENCH_multitenant.json` (or `--out`).
fn multitenant_sweep(args: &Args) {
    use terra::experiments as exp;
    let defaults = exp::MultitenantSweepConfig::default();
    let list = |v: &str| -> Vec<String> { v.split(',').map(|s| s.trim().to_string()).collect() };
    let cfg = exp::MultitenantSweepConfig {
        jobs: args.get_usize("jobs", defaults.jobs),
        streams: args.get_usize("streams", defaults.streams),
        ml_jobs: args.get_usize("ml-jobs", defaults.ml_jobs),
        ml_iters: args.get_usize("ml-iters", defaults.ml_iters),
        seed: args.get_u64("seed", defaults.seed),
        horizon_s: args.get_f64("horizon", defaults.horizon_s),
        topology: args.get_or("topology", &defaults.topology).to_string(),
        workload: args.get_or("workload", &defaults.workload).to_string(),
        profiles: args.get("profiles").map(list).unwrap_or(defaults.profiles),
    };
    let rows = exp::multitenant_sweep(&cfg);
    let mut t = Table::new(&[
        "profile", "class", "coflows", "rejected", "avg CCT", "violation s", "reshapes",
        "shortfall", "unfin",
    ]);
    for r in &rows {
        t.row(&[
            r.profile.clone(),
            r.class.clone(),
            r.coflows.to_string(),
            r.rejected.to_string(),
            format!("{:.1}s", r.avg_cct),
            format!("{:.1}", r.violation_s),
            r.tree_reshapes.to_string(),
            format!("{:.1}", r.floor_shortfall_gbps),
            r.unfinished.to_string(),
        ]);
    }
    t.print(&format!(
        "Multitenant sweep: {} rows on {}/{} (seed {}, {} batch + {} streams + {}x{} ML iters)",
        rows.len(),
        cfg.topology,
        cfg.workload,
        cfg.seed,
        cfg.jobs,
        cfg.streams,
        cfg.ml_jobs,
        cfg.ml_iters
    ));
    let out = args.get_or("out", "BENCH_multitenant.json");
    match std::fs::write(out, format!("{}\n", exp::multitenant_json(&cfg, &rows))) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => {
            eprintln!("failed to write {out}: {e}");
            std::process::exit(1);
        }
    }
}

/// The open-loop saturation sweep: ramp + bisect the arrival rate to the
/// knee of every ⟨topology, profile, policy, shard count⟩ cell, writing
/// `BENCH_saturation.json` (or `--out`). `--quick` starts from the
/// CI-sized config.
fn saturation_sweep(args: &Args) {
    use terra::experiments as exp;
    let defaults = if args.flag("quick") {
        exp::SaturationSweepConfig::quick()
    } else {
        exp::SaturationSweepConfig::default()
    };
    let list = |v: &str| -> Vec<String> { v.split(',').map(|s| s.trim().to_string()).collect() };
    let shard_list = |v: &str| -> Vec<usize> {
        v.split(',').filter_map(|s| s.trim().parse::<usize>().ok()).collect()
    };
    let cfg = exp::SaturationSweepConfig {
        seed: args.get_u64("seed", defaults.seed),
        workload: args.get_or("workload", &defaults.workload).to_string(),
        estimator: args.get_or("estimator", &defaults.estimator).to_string(),
        interarrival: args.get_or("interarrival", &defaults.interarrival).to_string(),
        streams: args.get_usize("streams", defaults.streams),
        profile_samples: args.get_usize("profile-samples", defaults.profile_samples),
        warmup_s: args.get_f64("warmup", defaults.warmup_s),
        measure_s: args.get_f64("measure", defaults.measure_s),
        drain_s: args.get_f64("drain", defaults.drain_s),
        deadline_d: args.get_f64("deadlines", defaults.deadline_d),
        lambda0: args.get_f64("lambda0", defaults.lambda0),
        growth: args.get_f64("growth", defaults.growth),
        max_lambda: args.get_f64("max-lambda", defaults.max_lambda),
        bisect_iters: args.get_usize("bisect", defaults.bisect_iters),
        p99_slowdown_limit: args.get_f64("slowdown-limit", defaults.p99_slowdown_limit),
        miss_limit: args.get_f64("miss-limit", defaults.miss_limit),
        topologies: args.get("topology").map(list).unwrap_or(defaults.topologies),
        policies: args.get("policies").map(list).unwrap_or(defaults.policies),
        profiles: args.get("profiles").map(list).unwrap_or(defaults.profiles),
        shard_counts: args.get("shards").map(shard_list).unwrap_or(defaults.shard_counts),
    };
    let rows = exp::saturation_sweep(&cfg);
    let mut t = Table::new(&[
        "topology", "profile", "policy", "shards", "knee/s", "sat", "evals", "p99 slow", "miss",
        "backlog", "MAPE", "unfin",
    ]);
    for r in &rows {
        let sat = if r.saturated { "y" } else { ">=cap" };
        t.row(&[
            r.topology.clone(),
            r.profile.clone(),
            r.policy.clone(),
            r.shards.to_string(),
            format!("{:.3}", r.knee_lambda),
            sat.to_string(),
            r.evals.to_string(),
            format!("{:.1}", r.p99_slowdown),
            format!("{:.0}%", r.miss_rate * 100.0),
            format!("{:.0}", r.backlog_p99),
            format!("{:.1}%", r.est_mape * 100.0),
            r.unfinished.to_string(),
        ]);
    }
    t.print(&format!(
        "Saturation sweep: {} cells, workload {} (seed {}, {} interarrival, {:.0}/{:.0}/{:.0}s \
         warmup/measure/drain, estimator {})",
        rows.len(),
        cfg.workload,
        cfg.seed,
        cfg.interarrival,
        cfg.warmup_s,
        cfg.measure_s,
        cfg.drain_s,
        cfg.estimator
    ));
    let out = args.get_or("out", "BENCH_saturation.json");
    match std::fs::write(out, format!("{}\n", exp::saturation_json(&cfg, &rows))) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => {
            eprintln!("failed to write {out}: {e}");
            std::process::exit(1);
        }
    }
}

fn testbed(args: &Args) {
    use terra::api::TerraClient;
    use terra::overlay::protocol::FlowSpec;
    use terra::overlay::{Agent, Controller, TestbedConfig, BYTES_PER_GBPS};
    let topo = args.get_or("topology", "fig1a");
    let wan = topologies::by_name(topo).expect("unknown topology");
    let n = wan.num_nodes();
    let k = args.get_usize("k", 3);
    let workers = args.get_usize("workers", terra::engine::default_workers());
    let shards = args.get_usize("shards", 1);
    let handle = Controller::spawn(
        TestbedConfig::new(wan, k).with_workers(workers).with_shards(shards),
        Box::new(TerraPolicy::default()),
    )
    .expect("controller");
    println!("controller at {}", handle.addr);
    let agents: Vec<Agent> = (0..n).map(|dc| Agent::spawn(dc, handle.addr).unwrap()).collect();
    assert!(handle.wait_ready(n, std::time::Duration::from_secs(10)));
    println!("{n} agents ready; overlay wired (k={k})");
    let gbit = args.get_f64("gbit", 4.0);
    let mut client = TerraClient::connect(handle.addr).unwrap();
    let flows = [FlowSpec { id: 0, src_dc: 0, dst_dc: 1, bytes: (gbit * BYTES_PER_GBPS) as u64 }];
    let t0 = std::time::Instant::now();
    let cid = client.submit_coflow(&flows, None).unwrap();
    println!("submitted coflow {cid} ({gbit} Gbit DC0 -> DC1)");
    let cct = client.wait_done(cid as u64, 120.0).unwrap();
    println!(
        "coflow finished: CCT {cct:.3}s (wall {:.3}s), effective rate {:.2} Gbps",
        t0.elapsed().as_secs_f64(),
        gbit / cct
    );
    let (max_rules, updates) = handle.rule_stats();
    println!("SDN rules: max {max_rules}/switch, {updates} updates total");
    for a in agents {
        a.shutdown();
    }
    handle.shutdown();
}

fn topology_info(args: &Args) {
    let name = args.get_or("name", "swan");
    let wan = topologies::by_name(name).expect("unknown topology");
    println!(
        "{name}: {} datacenters, {} links ({} directed edges), total capacity {:.0} Gbps",
        wan.num_nodes(),
        wan.num_undirected(),
        wan.num_edges(),
        wan.total_capacity()
    );
    for (i, n) in wan.names.iter().enumerate() {
        println!("  [{i:2}] {n}");
    }
    let paths = terra::net::paths::PathSet::compute(&wan, 15);
    let mut counts: Vec<f64> = Vec::new();
    for u in 0..wan.num_nodes() {
        for v in 0..wan.num_nodes() {
            if u != v {
                counts.push(paths.get(u, v).len() as f64);
            }
        }
    }
    println!(
        "k<=15 shortest paths per pair: mean {:.1}, min {:.0}, max {:.0}",
        terra::util::stats::mean(&counts),
        counts.iter().cloned().fold(f64::INFINITY, f64::min),
        counts.iter().cloned().fold(0.0f64, f64::max),
    );
}
