//! The **RoundEngine**: the one shared implementation of Terra's scheduling
//! round, driven by both the flow-level simulator ([`crate::sim`]) and the
//! overlay controller ([`crate::overlay`]).
//!
//! Terra's online algorithm (§3.1.3) re-runs joint routing + scheduling on
//! every coflow arrival, FlowGroup/coflow completion, and significant WAN
//! event. The engine owns everything that decision loop needs:
//!
//! - the WAN view and its k-shortest-path sets (recomputed on structural
//!   events, §4.4),
//! - the active-coflow table ([`CoflowState`]s, with incremental draining),
//! - ρ-dampened WAN-event filtering: sub-threshold bandwidth fluctuations
//!   clamp the current allocation instead of re-optimizing (§3.1.3),
//! - round triggering and execution through the [`Policy`] interface,
//! - allocation feasibility checking (debug/tests),
//! - per-round instrumentation ([`RoundStats`]),
//! - **incremental re-optimization**: a [`GammaCache`] of standalone
//!   min-CCT solves keyed by `(coflow, WAN capacity epoch)` with dirty-set
//!   invalidation, plus warm-starting of the GK solver from the previous
//!   round's allocation,
//! - **component-decomposed rounds**: the active set is partitioned into
//!   edge-connected components ([`crate::lp::decompose`]) — coflows whose
//!   k-path sets share no WAN edge are independent commodities — and only
//!   the components dirtied by an arrival, departure, group completion, or
//!   a qualifying WAN event on one of *their* edges are re-solved; every
//!   untouched component's allocation is carried forward from the live
//!   allocation ([`ComponentCache`]), turning round latency from O(all
//!   coflows) into O(changed components),
//! - **flat solver workspaces & parallel component solves**: the engine
//!   owns one [`SolverWorkspace`] per worker (flat CSR block caches + GK
//!   scratch, see [`crate::lp::flat`]), and — because each component solve
//!   is a pure function of its own subnetwork — runs dirty components
//!   concurrently on `EngineConfig::workers` threads with a deterministic
//!   first-member-order merge: allocations are bit-identical for any
//!   worker count.
//!
//! Drivers differ only in how they learn about time and events: the
//! simulator advances virtual time and feeds completions from its event
//! heap; the controller drains by wall-clock time and feeds agent reports.
//! Both call the same [`RoundEngine`] entry points, which is what keeps the
//! two planes behaviorally identical (the §6.1 methodology) and is enforced
//! by the `integration_engine` parity test.

pub mod cache;
pub mod sharded;

pub use cache::{ComponentCache, GammaCache};
pub use sharded::{ShardedEngine, SitePartition};

use crate::coflow::CoflowId;
use crate::lp;
use crate::lp::decompose::{self, DecomposeScratch};
use crate::lp::SolverWorkspace;
use crate::net::paths::PathSet;
use crate::net::telemetry::{CapacityEstimator, TelemetryConfig};
use crate::net::{EdgeId, LinkEvent, NodeId, Wan};
use crate::scheduler::{
    build_instance, Allocation, CoflowState, NetView, Policy, RoundCtx, RoundStats, RoundTrigger,
};
use std::collections::HashMap;

/// Default worker-thread count for parallel component solves: one per
/// available core (the solves are CPU-bound and share nothing).
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Engine knobs shared by both drivers.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Bandwidth-fluctuation threshold ρ for re-optimization (§3.1.3);
    /// events below it clamp instead of re-optimizing.
    pub rho: f64,
    /// Assert allocation feasibility after every round (tests/debug).
    pub check_feasibility: bool,
    /// Disable the Γ-cache and GK warm starts (cold per-round solves, the
    /// pre-incremental behavior; used by the round-latency benchmarks).
    pub cold: bool,
    /// Partition rounds into edge-connected components and re-solve only
    /// dirty ones (the default). `false` keeps the incremental caches but
    /// solves the full active set monolithically every round — used by the
    /// scaling benchmarks and the decomposition-equivalence property test.
    /// Ignored when `cold` is set.
    pub decompose: bool,
    /// Worker threads for dirty-component solves within a round. Since PR 3
    /// made GK decomposition-invariant, each component solve is a pure
    /// function of its own subnetwork, so components solve concurrently and
    /// merge in deterministic first-member order: any `workers` value
    /// produces bit-identical allocations, and `1` reproduces the
    /// sequential path exactly. Defaults to [`default_workers`]. Only
    /// applies to decomposed rounds with a forkable policy
    /// ([`crate::scheduler::Policy::fork`]).
    pub workers: usize,
    /// WAN telemetry & capacity estimation ([`crate::net::telemetry`]).
    /// With the default [`TelemetryConfig::oracle`], the engine consumes
    /// ground-truth capacities exactly as before (bit-identical); any other
    /// estimator makes the engine's WAN a **belief**: drivers feed
    /// throughput samples / probes via [`RoundEngine::observe_edge`] and
    /// friends, and [`RoundEngine::refresh_beliefs`] pushes belief changes
    /// through the same ρ-dampened gate that ground-truth fluctuations
    /// used to take.
    pub telemetry: TelemetryConfig,
    /// Control-plane shards for the scale-out front-end
    /// ([`ShardedEngine`]): `> 1` splits the active set across that many
    /// engine shards by edge ownership, each running its round
    /// concurrently. `1` (the default) is the plain single-engine loop —
    /// `ShardedEngine` then delegates every call verbatim, bit-identical
    /// to previous behavior. Direct [`RoundEngine`] users ignore it.
    pub shards: usize,
    /// A cross-shard arrival migrates the coflows needed to merge its
    /// edge-component into one owning shard; an arrival that would migrate
    /// more than this many coflows is parked in the front-end's spill
    /// engine and served by the two-level residual solve instead.
    pub migrate_cap: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            rho: crate::scheduler::DEFAULT_RHO,
            check_feasibility: cfg!(debug_assertions),
            cold: false,
            decompose: true,
            workers: default_workers(),
            telemetry: TelemetryConfig::default(),
            shards: 1,
            migrate_cap: usize::MAX,
        }
    }
}

/// What [`RoundEngine::handle_wan_event`] did with an event; tells the
/// driver whether (and why) to run a round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WanReaction {
    /// Topology changed (fail/recover): paths recomputed, epoch bumped —
    /// run a round; the controller must also reinstall rules/peers.
    Structural,
    /// Capacity fluctuation ≥ ρ: epoch bumped — run a round.
    Reoptimize,
    /// Sub-ρ fluctuation: current allocation clamped back to feasibility,
    /// no round needed (and the Γ-cache stays warm).
    Clamped,
}

impl WanReaction {
    /// The round trigger this reaction implies, if any.
    pub fn trigger(&self) -> Option<RoundTrigger> {
        match self {
            WanReaction::Structural | WanReaction::Reoptimize => Some(RoundTrigger::WanChange),
            WanReaction::Clamped => None,
        }
    }
}

/// The shared round engine. See the module docs for responsibilities.
pub struct RoundEngine {
    wan: Wan,
    paths: PathSet,
    policy: Box<dyn Policy>,
    cfg: EngineConfig,
    k: usize,
    active: Vec<CoflowState>,
    alloc: Allocation,
    cache: GammaCache,
    /// False after a structural event until the next round: the previous
    /// allocation's path indices no longer match the path sets, so it must
    /// not seed warm starts.
    warm_valid: bool,
    /// Per-edge available-capacity snapshot taken at the last epoch bump.
    /// Individually ignorable fluctuations must not be collectively
    /// ignorable: once some edge's capacity has slid ≥ ρ away from this
    /// snapshot through sub-ρ steps, the accumulated drift is treated
    /// exactly like one qualifying event — epoch bump *and* a
    /// re-optimization round.
    epoch_caps: Vec<f64>,
    /// Validity metadata for per-component allocation reuse.
    comp_cache: ComponentCache,
    /// Per-edge capacity beliefs ([`crate::net::telemetry`]). Inert under
    /// the oracle default; otherwise `wan` holds `cap_used` beliefs and
    /// this is their source of truth.
    estimator: CapacityEstimator,
    /// Persistent solver workspaces (flat CSR block caches + GK scratch),
    /// one per worker; `workspaces[0]` serves sequential and monolithic
    /// rounds. Swept alongside the component cache when coflows depart.
    workspaces: Vec<SolverWorkspace>,
    /// Zero-realloc partition state: the per-coflow edge-set buffers and
    /// the union-find/components scratch, reused every round.
    item_edges_buf: Vec<Vec<usize>>,
    decomp: DecomposeScratch,
    /// True when `decomp` no longer mirrors the active table: membership
    /// changed (insert / departure / migration), some coflow's edge set
    /// changed (group completion, update, dirty mark), or a structural
    /// event recomputed paths. Decomposed rounds rebuild the partition only
    /// then; steady-state rounds (pure drains, sub-ρ clamps, capacity
    /// fluctuations) reuse the standing partition as-is.
    partition_stale: bool,
    /// Classification scratch (member-id list and reused-component list),
    /// cleared and refilled each decomposed round.
    ids_scratch: Vec<CoflowId>,
    fresh_scratch: Vec<usize>,
    /// Pooled per-task Γ-cache shards for parallel component solves:
    /// entries are moved out via [`GammaCache::extract_into`] and back via
    /// [`GammaCache::absorb_from`], so steady-state parallel rounds
    /// allocate no fresh cache maps.
    shard_bufs: Vec<GammaCache>,
    /// Engine-level instrumentation (component solve/reuse counters) merged
    /// into the policy's stats by [`RoundEngine::take_stats`].
    engine_stats: RoundStats,
    rounds: usize,
}

impl RoundEngine {
    /// Build an engine around a WAN and a policy; path sets are computed
    /// for the policy's k.
    pub fn new(wan: Wan, policy: Box<dyn Policy>, cfg: EngineConfig) -> RoundEngine {
        let k = policy.k_paths();
        RoundEngine::with_k(wan, policy, cfg, k)
    }

    /// [`RoundEngine::new`] with an explicit path count (the overlay
    /// testbed wires `k` persistent connections per agent pair, which may
    /// be fewer than the policy's default).
    pub fn with_k(
        wan: Wan,
        policy: Box<dyn Policy>,
        cfg: EngineConfig,
        k: usize,
    ) -> RoundEngine {
        let paths = PathSet::compute(&wan, k);
        let epoch_caps = wan.capacities();
        let comp_cache = ComponentCache::new(wan.num_edges());
        let estimator = CapacityEstimator::new(&cfg.telemetry, &epoch_caps);
        let workspaces =
            (0..cfg.workers.max(1)).map(|_| SolverWorkspace::new()).collect();
        RoundEngine {
            wan,
            paths,
            policy,
            cfg,
            k,
            active: Vec::new(),
            alloc: Allocation::default(),
            cache: GammaCache::new(),
            warm_valid: false,
            epoch_caps,
            comp_cache,
            estimator,
            workspaces,
            item_edges_buf: Vec::new(),
            decomp: DecomposeScratch::default(),
            partition_stale: true,
            ids_scratch: Vec::new(),
            fresh_scratch: Vec::new(),
            shard_bufs: Vec::new(),
            engine_stats: RoundStats::default(),
            rounds: 0,
        }
    }

    pub fn wan(&self) -> &Wan {
        &self.wan
    }

    pub fn paths(&self) -> &PathSet {
        &self.paths
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn k_paths(&self) -> usize {
        self.k
    }

    /// Current WAN capacity epoch (bumped by qualifying WAN events).
    pub fn epoch(&self) -> u64 {
        self.cache.epoch()
    }

    /// Rounds executed so far.
    pub fn rounds(&self) -> usize {
        self.rounds
    }

    /// The most recent allocation.
    pub fn alloc(&self) -> &Allocation {
        &self.alloc
    }

    /// All active (admitted, unfinished) coflows.
    pub fn active(&self) -> &[CoflowState] {
        &self.active
    }

    pub fn len(&self) -> usize {
        self.active.len()
    }

    pub fn is_empty(&self) -> bool {
        self.active.is_empty()
    }

    pub fn get(&self, id: CoflowId) -> Option<&CoflowState> {
        self.active.iter().find(|c| c.id == id)
    }

    /// Mutable access for drivers that extend coflows in place
    /// (`updateCoflow`, §5.2). Callers that change the group shape must
    /// [`RoundEngine::mark_dirty`] afterwards.
    pub fn get_mut(&mut self, id: CoflowId) -> Option<&mut CoflowState> {
        self.active.iter_mut().find(|c| c.id == id)
    }

    /// Add a coflow to the active table (does not run a round). The coflow
    /// starts dirty: the component it lands in must re-solve.
    pub fn insert(&mut self, st: CoflowState) {
        self.cache.invalidate(st.id);
        self.comp_cache.mark_dirty(st.id);
        self.partition_stale = true;
        self.active.push(st);
    }

    /// Drop a coflow's Γ-cache entry (and dirty its component) after a
    /// discontinuous change to its remaining volumes (group completion,
    /// update). Also invalidates the standing partition: the coflow's edge
    /// set may have changed shape.
    pub fn mark_dirty(&mut self, id: CoflowId) {
        self.cache.invalidate(id);
        self.comp_cache.mark_dirty(id);
        self.partition_stale = true;
    }

    /// Deadline admission control against the current active set (§3.2).
    ///
    /// Under a non-oracle estimator the scheduler's WAN holds the last
    /// ρ-gated belief refresh, which can sit *above* the current
    /// `mean − k·σ` headroom (a dip too small to pass the gate, or a
    /// stale-optimistic belief whose variance has since grown). Admission
    /// is a promise, so it runs against the fresh headroom instead:
    /// per edge, `min(gated avail, cap_used)`. Oracle mode takes the
    /// original path untouched.
    pub fn admit(&mut self, now: f64, candidate: &CoflowState) -> bool {
        let RoundEngine { wan, paths, policy, active, estimator, .. } = self;
        if !estimator.is_oracle() {
            let mut headroom = wan.clone();
            for e in 0..headroom.num_edges() {
                let cap = headroom.link(e).avail().min(estimator.cap_used(e));
                headroom.set_capacity(e, cap);
            }
            let net = NetView { wan: &headroom, paths };
            return policy.admit(now, candidate, active, &net);
        }
        let net = NetView { wan, paths };
        policy.admit(now, candidate, active, &net)
    }

    /// Minimum CCT of a coflow alone on the *full* WAN (for slowdown and
    /// deadline metrics). Not counted in policy LP stats, like the
    /// pre-engine simulator implementation.
    pub fn standalone_min_cct(&self, st: &CoflowState) -> f64 {
        let net = NetView { wan: &self.wan, paths: &self.paths };
        let (inst, _) =
            build_instance(&st.groups, &st.remaining, &self.wan.capacities(), &net, self.k);
        if inst.groups.is_empty() {
            return 0.0;
        }
        lp::max_concurrent(&inst, lp::SolverKind::Gk).map(|s| s.gamma()).unwrap_or(f64::INFINITY)
    }

    /// Apply a WAN event with ρ-dampened filtering (§3.1.3): structural
    /// events recompute paths and bump the capacity epoch; fluctuations ≥ ρ
    /// bump the epoch; smaller fluctuations clamp the current allocation —
    /// unless they have *accumulated*: once any edge's capacity has drifted
    /// ≥ ρ away from the last epoch's snapshot, the sub-ρ step is promoted
    /// to a re-optimization exactly like a single qualifying event.
    /// The caller runs a round iff [`WanReaction::trigger`] is `Some`.
    ///
    /// Under a non-oracle estimator, a `SetBandwidth` event is treated as
    /// an **authoritative measurement** (an operator-fed probe), not truth
    /// the scheduler may consume directly: it is fused into the belief and
    /// the resulting belief change — if any — is what flows through the ρ
    /// gate. Structural events are directly observable (BFD/SDN port
    /// state), so they apply identically in every mode.
    pub fn handle_wan_event(&mut self, ev: &LinkEvent) -> WanReaction {
        let t = self.estimator.clock();
        self.handle_wan_event_at(ev, t)
    }

    /// [`RoundEngine::handle_wan_event`] with the driver's clock: belief
    /// updates the event causes (operator priors, recovery re-anchors) are
    /// stamped `now`, so the edge does not look observation-stale the
    /// moment it was announced. Drivers with a clock (the controller, the
    /// simulator) should prefer this; the un-timed wrapper falls back to
    /// the estimator's latest observation time.
    pub fn handle_wan_event_at(&mut self, ev: &LinkEvent, now: f64) -> WanReaction {
        match *ev {
            LinkEvent::Fail(..) | LinkEvent::Recover(..) => {
                self.wan.apply_event(ev);
                if let LinkEvent::Recover(u, v) = *ev {
                    // Recovery restores base capacity and is observable:
                    // the belief re-anchors there too (its cap_used then
                    // matches the WAN, so no spurious refresh follows).
                    for (a, b) in [(u, v), (v, u)] {
                        if let Some(e) = self.wan.edge_between(a, b) {
                            let base = self.wan.link(e).base_capacity;
                            self.estimator.reset_edge(e, base, now);
                        }
                    }
                }
                // Recompute viable paths (§4.4); previous path indices are
                // meaningless now, so drop warm-start state too. The
                // decomposition itself is path-derived, so every component
                // allocation is stale.
                self.paths = PathSet::compute(&self.wan, self.k);
                self.bump_epoch();
                self.comp_cache.touch_all();
                self.warm_valid = false;
                self.partition_stale = true;
                WanReaction::Structural
            }
            LinkEvent::SetBandwidth(u, v, gbps) => {
                if self.estimator.is_oracle() {
                    self.apply_capacity(u, v, gbps)
                } else {
                    // Authoritative means authoritative: a prior, not a
                    // probe — a hold-down estimator must not demand three
                    // confirmations of an event the operator announced.
                    if let Some(e) = self.wan.edge_between(u, v) {
                        self.estimator.prior(e, gbps, now);
                    }
                    self.refresh_beliefs().unwrap_or(WanReaction::Clamped)
                }
            }
        }
    }

    /// Mark a set of *directed* edges failed (or restore them), the way a
    /// structural WAN event would — used when an agent is declared down
    /// and the site's incident edges must disappear from the path set.
    /// Unlike [`LinkEvent::Fail`] this is per-direction, so an asymmetric
    /// partition (only the edges *into* a site lost) is expressible.
    /// Restoring re-anchors the estimator at base capacity, matching
    /// recovery semantics. Always structural: the path set changed shape.
    pub fn set_edges_down(&mut self, edges: &[EdgeId], down: bool, now: f64) -> WanReaction {
        for &e in edges {
            self.wan.set_edge_up(e, !down);
            if !down {
                let base = self.wan.link(e).base_capacity;
                self.estimator.reset_edge(e, base, now);
            }
        }
        self.paths = PathSet::compute(&self.wan, self.k);
        self.bump_epoch();
        self.comp_cache.touch_all();
        self.warm_valid = false;
        self.partition_stale = true;
        WanReaction::Structural
    }

    /// The ρ-dampened capacity-change path shared by oracle truth events
    /// and belief refreshes: apply the new capacity to the scheduler's WAN
    /// and decide whether it warrants a round.
    fn apply_capacity(&mut self, u: NodeId, v: NodeId, gbps: f64) -> WanReaction {
        let ev = LinkEvent::SetBandwidth(u, v, gbps);
        let frac = self.wan.apply_event(&ev);
        if frac >= self.cfg.rho || self.epoch_drift(&ev) >= self.cfg.rho {
            // One big step, or many small ones that add up to one: either
            // way the capacities the touched edge's components were solved
            // against are off by ≥ ρ. Only those components re-solve, so
            // only *this* edge's drift snapshot re-anchors — every other
            // edge keeps its baseline (its components were not re-solved;
            // re-anchoring them here would let an untouched edge creep
            // arbitrarily far in sub-ρ steps without ever reaching the
            // drift trigger).
            self.cache.bump_epoch();
            if self.cfg.cold || !self.cfg.decompose {
                // Monolithic modes re-solve the *entire* active set at the
                // follow-up round, so every edge's baseline re-anchors
                // (the pre-decomposition behavior; keeping others stale
                // would promote spurious drift rounds later).
                self.epoch_caps = self.wan.capacities();
            } else if let Some(e) = self.wan.edge_between(u, v) {
                self.epoch_caps[e] = self.wan.link(e).avail();
                self.comp_cache.touch_edge(e);
            }
            WanReaction::Reoptimize
        } else {
            // Sub-ρ: clamp the live allocation back to feasibility — per
            // coflow, over the edges it actually uses, so carried-forward
            // components that never touch the dipped edge are unaffected.
            self.clamp_alloc();
            WanReaction::Clamped
        }
    }

    /// The engine's capacity estimator (read-only; feed it through
    /// [`RoundEngine::observe_edge`] / [`RoundEngine::probe_edge`]).
    pub fn estimator(&self) -> &CapacityEstimator {
        &self.estimator
    }

    /// Simulate a controller crash/restart over this engine's coflow set:
    /// drop everything a restarted process loses — the live allocation,
    /// the Γ- and component caches, solver workspaces, warm-start state,
    /// and (under a non-oracle estimator) the learned capacity beliefs,
    /// which re-anchor at base capacity exactly as a fresh estimator
    /// starts. The active table itself survives: in recovery the agents
    /// re-report their transfers, and remaining volumes are the
    /// reconstruction's input. Structurally-down links stay down (link
    /// state is observable; beliefs are not). Used by the simulator's
    /// `controller_chaos` axis.
    pub fn crash_reset(&mut self, now: f64) {
        self.alloc = Allocation::default();
        self.cache = GammaCache::new();
        self.comp_cache = ComponentCache::new(self.wan.num_edges());
        self.workspaces =
            (0..self.cfg.workers.max(1)).map(|_| SolverWorkspace::new()).collect();
        self.warm_valid = false;
        self.partition_stale = true;
        let ids: Vec<CoflowId> = self.active.iter().map(|c| c.id).collect();
        for id in ids {
            self.comp_cache.mark_dirty(id);
        }
        if !self.estimator.is_oracle() {
            let edges: Vec<(usize, NodeId, NodeId, f64, bool)> = self
                .wan
                .links()
                .iter()
                .enumerate()
                .map(|(e, l)| (e, l.src, l.dst, l.base_capacity, l.up))
                .collect();
            for (e, u, v, base, up) in edges {
                self.estimator.reset_edge(e, base, now);
                if up {
                    self.wan.apply_event(&LinkEvent::SetBandwidth(u, v, base));
                }
            }
        }
        self.bump_epoch();
        self.comp_cache.touch_all();
    }

    /// The engine's telemetry configuration.
    pub fn telemetry(&self) -> &TelemetryConfig {
        &self.cfg.telemetry
    }

    /// Passive throughput sample on edge `e`: `achieved` Gbps with
    /// `capped = true` when the link limited the sender. No-op under the
    /// oracle.
    pub fn observe_edge(&mut self, e: EdgeId, achieved: f64, capped: bool, now: f64) {
        self.estimator.observe(e, achieved, capped, now);
    }

    /// Active probe measurement on edge `e`. No-op under the oracle.
    pub fn probe_edge(&mut self, e: EdgeId, measured: f64, now: f64) {
        self.estimator.probe(e, measured, now);
    }

    /// Announced capacity prior (maintenance window) on edge `e`, pinned
    /// against samples/probes until `hold_until` (pass `now` for an
    /// unpinned prior). No-op under the oracle.
    pub fn announce_prior(&mut self, e: EdgeId, gbps: f64, now: f64, hold_until: f64) {
        self.estimator.prior_hold(e, gbps, now, hold_until);
    }

    /// Push accumulated belief changes into the scheduler's WAN through
    /// the same ρ-dampened gate as ground-truth events: each changed
    /// edge's `cap_used = max(0, mean − k·σ)` is applied in ascending edge
    /// order, qualifying changes (≥ ρ, or accumulated drift ≥ ρ) bump the
    /// capacity epoch exactly like an oracle fluctuation would. Returns
    /// the strongest reaction, or `None` when no belief moved (and always
    /// `None` under the oracle) — the caller runs a round iff the returned
    /// reaction's [`WanReaction::trigger`] is `Some`.
    pub fn refresh_beliefs(&mut self) -> Option<WanReaction> {
        if self.estimator.is_oracle() {
            return None;
        }
        let dirty = self.estimator.take_dirty();
        let mut best: Option<WanReaction> = None;
        for e in dirty {
            let link = self.wan.link(e);
            if !link.up {
                // A failed link is structurally down regardless of belief;
                // the belief will re-anchor on recovery.
                continue;
            }
            let cap = self.estimator.cap_used(e);
            if (cap - link.capacity).abs() <= 1e-9 * link.capacity.max(1.0) {
                continue;
            }
            let (u, v) = (link.src, link.dst);
            let r = self.apply_capacity(u, v, cap);
            best = Some(match (best, r) {
                (Some(WanReaction::Reoptimize), _) | (_, WanReaction::Reoptimize) => {
                    WanReaction::Reoptimize
                }
                (_, other) => other,
            });
        }
        best
    }

    /// Advance the Γ-cache epoch and re-anchor **every** edge's drift
    /// snapshot — structural events only, where paths are recomputed and
    /// all components re-solve.
    fn bump_epoch(&mut self) {
        self.cache.bump_epoch();
        self.epoch_caps = self.wan.capacities();
    }

    /// Accumulated drift of the edge a fluctuation touched: fractional
    /// deviation of its current available capacity from the edge's last
    /// re-anchor (its own last qualifying event, or the last structural
    /// event). O(1): every *other* edge was verified < ρ against its own
    /// baseline when its own last event was handled and is unchanged
    /// since, so only the touched edge can newly reach ρ.
    fn epoch_drift(&self, ev: &LinkEvent) -> f64 {
        let LinkEvent::SetBandwidth(u, v, _) = *ev else { return 0.0 };
        let Some(e) = self.wan.edge_between(u, v) else { return 0.0 };
        let c = self.wan.link(e).avail();
        let c0 = self.epoch_caps[e];
        (c - c0).abs() / c0.max(1e-9)
    }

    /// Run one scheduling round: partition the active set into
    /// edge-connected components, re-solve the dirty ones through the
    /// policy (with the Γ-cache and the previous allocation as a warm
    /// start), and carry every untouched component's allocation forward.
    pub fn round(&mut self, now: f64, trigger: RoundTrigger) -> &Allocation {
        let new_alloc = if self.cfg.cold {
            let RoundEngine { wan, paths, policy, active, .. } = self;
            let net = NetView { wan, paths };
            policy.allocate(now, trigger, active, &net)
        } else if !self.cfg.decompose {
            let RoundEngine {
                wan,
                paths,
                policy,
                active,
                alloc,
                cache,
                warm_valid,
                workspaces,
                ..
            } = self;
            let net = NetView { wan, paths };
            let warm = if *warm_valid && !alloc.rates.is_empty() { Some(&*alloc) } else { None };
            let ctx =
                RoundCtx { trigger, epoch: cache.epoch(), cache, warm, ws: &mut workspaces[0] };
            policy.allocate_with(now, ctx, active, &net)
        } else {
            // `None` means every component carried forward: the live
            // allocation already IS this round's allocation.
            match self.round_decomposed(now, trigger) {
                Some(a) => a,
                None => std::mem::take(&mut self.alloc),
            }
        };
        self.alloc = new_alloc;
        self.warm_valid = true;
        self.rounds += 1;
        if self.cfg.check_feasibility {
            let net = NetView { wan: &self.wan, paths: &self.paths };
            let usage = self.alloc.edge_usage(&self.active, &net, self.wan.num_edges());
            for (e, (&u, c)) in usage.iter().zip(self.wan.capacities()).enumerate() {
                assert!(
                    u <= c * (1.0 + 1e-4) + 1e-6,
                    "policy {} oversubscribed edge {e}: {u} > {c}",
                    self.policy.name()
                );
            }
        }
        &self.alloc
    }

    /// The decomposed round body: solve only what changed. Solving a
    /// component hands the policy exactly its member subset (in active-table
    /// order, so the policy-visible ordering matches the monolithic solve's
    /// restriction); since components share no edges, the union of the
    /// per-component allocations equals the monolithic allocation (the
    /// `prop_component_decomposition_*` property tests pin this).
    ///
    /// Returns `None` when every component was carried forward — the live
    /// allocation is already this round's answer, so the caller keeps it
    /// without rebuilding the rate table.
    fn round_decomposed(&mut self, now: f64, trigger: RoundTrigger) -> Option<Allocation> {
        self.comp_cache.begin_round();
        let RoundEngine {
            wan,
            paths,
            policy,
            active,
            alloc,
            cache,
            comp_cache,
            warm_valid,
            engine_stats,
            workspaces,
            item_edges_buf,
            decomp,
            partition_stale,
            ids_scratch,
            fresh_scratch,
            shard_bufs,
            cfg,
            k,
            ..
        } = self;
        let n = active.len();
        // Per-coflow edge sets over unfinished groups' k-truncated paths,
        // rebuilt into reused buffers — but only when the standing
        // partition is stale. Every mutation that can change a coflow's
        // edge set or the table's membership (insert, departure, group
        // completion, dirty mark, structural event, migration) raises
        // `partition_stale`; steady-state rounds (drains, clamps, capacity
        // fluctuations) reuse the previous round's components outright, so
        // the O(active · k · path-len) scan and the union-find rebuild are
        // paid only on rounds that actually changed shape (property-pinned
        // against the full rebuild by `prop_incremental_partition`).
        if *partition_stale {
            while item_edges_buf.len() < n {
                item_edges_buf.push(Vec::new());
            }
            for (cf, es) in active.iter().zip(item_edges_buf.iter_mut()) {
                es.clear();
                for (g, &rem) in cf.groups.iter().zip(&cf.remaining) {
                    if rem <= 1e-9 {
                        continue;
                    }
                    for p in paths.get(g.src, g.dst).iter().take(*k) {
                        es.extend_from_slice(&p.edges);
                    }
                }
                es.sort_unstable();
                es.dedup();
            }
            decompose::decompose_into(wan.num_edges(), &item_edges_buf[..n], decomp);
            *partition_stale = false;
        }
        let comps = decomp.components();
        debug_assert_eq!(comps.comp_of.len(), n, "partition out of step with active table");

        // Classify components: refresh clean ones, queue dirty ones as
        // solve tasks (in first-member order — the merge order, whatever
        // solves them). Carrying rates forward is deferred until we know
        // whether anything solves at all.
        fresh_scratch.clear();
        let mut tasks: Vec<(usize, Vec<CoflowId>)> = Vec::new();
        for (ci, members) in comps.members.iter().enumerate() {
            ids_scratch.clear();
            ids_scratch.extend(members.iter().map(|&i| active[i].id));
            ids_scratch.sort_unstable();
            if comp_cache.is_fresh(ids_scratch, &comps.edges[ci]) {
                // Untouched component: carry the live allocation forward
                // (clamping keeps it feasible between rounds; rates are
                // constant between rounds anyway, and equal-progress drain
                // is proportional, so a re-solve would return the same
                // Gbps rates).
                comp_cache.refresh(ids_scratch);
                fresh_scratch.push(ci);
                engine_stats.component_reuses += 1;
            } else {
                tasks.push((ci, ids_scratch.clone()));
            }
        }
        if tasks.is_empty() {
            // Nothing dirty: every component's rates carry forward, i.e.
            // the allocation is unchanged.
            comp_cache.end_round();
            return None;
        }

        let mut new_alloc = Allocation::default();
        let net = NetView { wan, paths };
        for &ci in fresh_scratch.iter() {
            for &i in &comps.members[ci] {
                if let Some(r) = alloc.rates.get(&active[i].id) {
                    new_alloc.rates.insert(active[i].id, r.clone());
                }
            }
        }

        let warm = if *warm_valid && !alloc.rates.is_empty() { Some(&*alloc) } else { None };
        let epoch = cache.epoch();
        // Parallel eligibility: >1 independent solves, >1 configured
        // workers, and a forkable policy. Each worker drives its own policy
        // fork and workspace over a disjoint chunk of tasks; every task
        // carries its members' Γ-cache shard. Solves are pure functions of
        // their component's subnetwork (GK is decomposition-invariant since
        // PR 3), so results are merged in task order below and the outcome
        // is bit-identical to the sequential path for any worker count.
        let nworkers = cfg.workers.max(1).min(tasks.len());
        let forks = if nworkers > 1 {
            (1..nworkers).map(|_| policy.fork()).collect::<Option<Vec<_>>>()
        } else {
            None
        };
        if let Some(mut forks) = forks {
            struct PTask<'a> {
                ids: Vec<CoflowId>,
                subset: Vec<CoflowState>,
                shard: &'a mut GammaCache,
                result: Option<Allocation>,
            }
            // Pooled Γ-cache shards: entries move out into a generation-
            // stamped reusable buffer and back, so steady-state parallel
            // rounds allocate no fresh cache maps.
            while shard_bufs.len() < tasks.len() {
                shard_bufs.push(GammaCache::new());
            }
            let mut ptasks: Vec<PTask> = tasks
                .into_iter()
                .zip(shard_bufs.iter_mut())
                .map(|((ci, ids), shard)| {
                    cache.extract_into(&ids, shard);
                    PTask {
                        subset: comps.members[ci].iter().map(|&i| active[i].clone()).collect(),
                        shard,
                        ids,
                        result: None,
                    }
                })
                .collect();
            let chunk = ptasks.len().div_ceil(nworkers);
            std::thread::scope(|s| {
                let mut worker_policies: Vec<&mut dyn Policy> = Vec::with_capacity(nworkers);
                worker_policies.push(&mut **policy);
                for f in forks.iter_mut() {
                    worker_policies.push(&mut **f);
                }
                let net = &net;
                for ((chunk_tasks, pol), ws) in
                    ptasks.chunks_mut(chunk).zip(worker_policies).zip(workspaces.iter_mut())
                {
                    s.spawn(move || {
                        for t in chunk_tasks {
                            let ctx = RoundCtx {
                                trigger,
                                epoch,
                                cache: &mut *t.shard,
                                warm,
                                ws: &mut *ws,
                            };
                            t.result = Some(pol.allocate_with(now, ctx, &t.subset, net));
                        }
                    });
                }
            });
            // Deterministic merge in component (first-member) order,
            // regardless of which worker finished when.
            for t in ptasks {
                cache.absorb_from(t.shard);
                if let Some(part) = t.result {
                    new_alloc.rates.extend(part.rates);
                }
                comp_cache.record_solved(t.ids);
                engine_stats.component_solves += 1;
            }
            for f in &mut forks {
                engine_stats.merge(&f.take_stats());
            }
        } else {
            for (ci, ids) in tasks {
                let members = &comps.members[ci];
                let ctx = RoundCtx {
                    trigger,
                    epoch,
                    cache: &mut *cache,
                    warm,
                    ws: &mut workspaces[0],
                };
                // The frequent everything-in-one-component case needs no
                // member clone — the component IS the active table.
                let part = if members.len() == active.len() {
                    policy.allocate_with(now, ctx, active, &net)
                } else {
                    let subset: Vec<CoflowState> =
                        members.iter().map(|&i| active[i].clone()).collect();
                    policy.allocate_with(now, ctx, &subset, &net)
                };
                new_alloc.rates.extend(part.rates);
                comp_cache.record_solved(ids);
                engine_stats.component_solves += 1;
            }
        }
        comp_cache.end_round();
        Some(new_alloc)
    }

    /// Scale down rates on edges whose capacity dropped below usage
    /// (sub-threshold fluctuations, no re-optimization).
    ///
    /// The factor is per **coflow** (min over the over-subscribed edges its
    /// nonzero rates traverse), not one global minimum: scaling a coflow
    /// uniformly preserves its equal-progress property, and feasibility
    /// holds because every coflow contributing to an over-capacity edge
    /// scales by at most that edge's cap/usage ratio. Crucially, coflows
    /// that never touch a shrunk edge keep their rates — decomposed rounds
    /// carry clean components' allocations forward verbatim, so a global
    /// clamp would otherwise degrade every untouched component a little
    /// more on each sub-ρ dip, with nothing ever re-solving them.
    ///
    /// Every coflow the clamp *did* scale is marked component-dirty: its
    /// rates no longer match any solve, so the next round re-optimizes its
    /// component against current capacities (as the monolithic path always
    /// did) instead of carrying the clamped rates forward forever — a dip
    /// followed by a sub-ρ recovery must not ratchet a component down to
    /// its historical capacity minimum.
    pub fn clamp_alloc(&mut self) {
        let caps = self.wan.capacities();
        let factors = self.throttle_factors(&caps);
        for (id, f) in factors {
            if let Some(rates) = self.alloc.rates.get_mut(&id) {
                for group in rates.iter_mut() {
                    for r in group {
                        *r *= f;
                    }
                }
            }
            self.comp_cache.mark_dirty(id);
        }
    }

    /// Per-coflow scale factors bringing the live allocation within
    /// `caps`: for every edge whose aggregate usage exceeds its capacity,
    /// every coflow crossing it scales by the worst cap/usage ratio over
    /// the edges its nonzero rates traverse. Only coflows that need
    /// scaling (factor < 1) appear in the result. Shared by the sub-ρ
    /// clamp (against believed capacities) and the simulator's
    /// ground-truth drain throttle (against true capacities) — one
    /// algorithm, two capacity sources.
    pub fn throttle_factors(&self, caps: &[f64]) -> HashMap<CoflowId, f64> {
        let RoundEngine { wan, paths, active, alloc, .. } = self;
        let net = NetView { wan, paths };
        let usage = alloc.edge_usage(active, &net, caps.len());
        let mut factors: Vec<f64> = vec![1.0; caps.len()];
        let mut any = false;
        for (e, (&u, &c)) in usage.iter().zip(caps).enumerate() {
            if u > c && u > 1e-12 {
                factors[e] = c / u;
                any = true;
            }
        }
        let mut out = HashMap::new();
        if !any {
            return out;
        }
        collect_throttle_factors(active, alloc, paths, &factors, &mut out);
        out
    }

    /// Drain every active FlowGroup at the current allocation for `dt`
    /// seconds. Remaining volumes are floored at `floor` (the controller
    /// keeps a 1e-6 trickle until the agent confirms completion; the
    /// simulator floors at 0). Returns the Gbit moved.
    pub fn drain(&mut self, dt: f64, floor: f64) -> f64 {
        self.drain_with(dt, floor, None)
    }

    /// [`RoundEngine::drain`] with optional per-coflow rate throttling:
    /// when the scheduler's WAN is a *belief*, the simulator caps each
    /// coflow's effective drain by what the **true** capacities admit
    /// (achieved = min(allocated, truth) — an over-optimistic belief must
    /// not move bytes the real network cannot carry). `throttle` maps
    /// coflow id → a factor in `[0, 1]`; absent ids drain at full rate.
    pub fn drain_with(
        &mut self,
        dt: f64,
        floor: f64,
        throttle: Option<&HashMap<CoflowId, f64>>,
    ) -> f64 {
        if dt <= 0.0 {
            return 0.0;
        }
        let mut moved = 0.0;
        let mut emptied: Vec<CoflowId> = Vec::new();
        for cf in &mut self.active {
            let Some(rates) = self.alloc.rates.get(&cf.id) else { continue };
            let scale = throttle
                .and_then(|t| t.get(&cf.id).copied())
                .unwrap_or(1.0)
                .clamp(0.0, 1.0);
            for (gi, rem) in cf.remaining.iter_mut().enumerate() {
                if *rem <= 1e-9 {
                    continue;
                }
                let rate: f64 =
                    rates.get(gi).map(|r| r.iter().sum::<f64>()).unwrap_or(0.0) * scale;
                if rate <= 0.0 {
                    continue;
                }
                let new = (*rem - rate * dt).max(floor.min(*rem));
                moved += *rem - new;
                *rem = new;
                if new <= 1e-9 {
                    // A FlowGroup just completed: the coflow's shape changed
                    // discontinuously, so its cached Γ (which rescales by
                    // *total* remaining, assuming proportional drain) is no
                    // longer valid — same dirty rule `complete_group`
                    // applies on the controller plane.
                    emptied.push(cf.id);
                }
            }
        }
        for id in emptied {
            // Group emptied: shape changed, so the standing partition no
            // longer reflects this coflow's edge set either.
            self.cache.invalidate(id);
            self.comp_cache.mark_dirty(id);
            self.partition_stale = true;
        }
        moved
    }

    /// Earliest absolute time any active FlowGroup empties at current
    /// rates, or `None` when nothing is draining.
    pub fn next_completion(&self, now: f64) -> Option<f64> {
        let mut best: Option<f64> = None;
        for cf in &self.active {
            let Some(rates) = self.alloc.rates.get(&cf.id) else { continue };
            for (gi, &rem) in cf.remaining.iter().enumerate() {
                if rem <= 1e-9 {
                    continue;
                }
                let rate: f64 = rates.get(gi).map(|r| r.iter().sum()).unwrap_or(0.0);
                if rate > 1e-12 {
                    let t = now + rem / rate;
                    best = Some(best.map_or(t, |b: f64| b.min(t)));
                }
            }
        }
        best
    }

    /// Record an agent-confirmed FlowGroup completion (controller driver).
    /// Returns true when the whole coflow is now done.
    pub fn complete_group(&mut self, id: CoflowId, src: usize, dst: usize) -> bool {
        let Some(cf) = self.active.iter_mut().find(|c| c.id == id) else { return false };
        let mut hit = false;
        for (gi, g) in cf.groups.iter().enumerate() {
            if g.src == src && g.dst == dst {
                cf.remaining[gi] = 0.0;
                hit = true;
            }
        }
        let done = cf.done();
        if hit {
            self.cache.invalidate(id);
            self.comp_cache.mark_dirty(id);
            self.partition_stale = true;
        }
        done
    }

    /// Remove all finished coflows from the active table (and their
    /// allocation and Γ-cache entries). Returns their ids.
    pub fn take_finished(&mut self) -> Vec<CoflowId> {
        let finished: Vec<CoflowId> =
            self.active.iter().filter(|c| c.done()).map(|c| c.id).collect();
        for id in &finished {
            self.alloc.rates.remove(id);
            self.cache.invalidate(*id);
            // A departure shrinks its component's member set, which misses
            // the component cache structurally; only the dirty flag needs
            // tidying so it cannot accumulate for dead ids.
            self.comp_cache.forget(*id);
            // Likewise the workspaces' cached CSR blocks.
            for ws in &mut self.workspaces {
                ws.forget(*id);
            }
        }
        if !finished.is_empty() {
            self.partition_stale = true;
        }
        self.active.retain(|c| !c.done());
        finished
    }

    /// Current total scheduled rate (Gbps) of a coflow.
    pub fn coflow_rate(&self, id: CoflowId) -> f64 {
        self.alloc.rates.get(&id).map(|g| g.iter().flatten().sum()).unwrap_or(0.0)
    }

    /// A coflow's full rate matrix from the last round, if any.
    pub fn coflow_rates(&self, id: CoflowId) -> Option<crate::scheduler::CoflowRates> {
        self.alloc.rates.get(&id).cloned()
    }

    /// Drain the policy's instrumentation counters, merged with the
    /// engine's own: component solve/reuse counters plus everything
    /// accumulated by forked parallel workers (their LP solves, Γ-cache
    /// hits, and timings land in `engine_stats` when a round merges them —
    /// only the main policy's counters flow through `policy.take_stats()`).
    pub fn take_stats(&mut self) -> RoundStats {
        let mut stats = self.policy.take_stats();
        stats.merge(&self.engine_stats);
        self.engine_stats = RoundStats::default();
        stats
    }

    /// The standing edge-connected partition of the active table, as of
    /// the last decomposed round (meaningless under `cold` or
    /// `decompose = false`). Exposed for the incremental-partition
    /// equivalence property test.
    pub fn partition(&self) -> &decompose::Components {
        self.decomp.components()
    }

    /// Whether the standing partition will be rebuilt at the next
    /// decomposed round (membership / edge-set / structural change since).
    pub fn partition_is_stale(&self) -> bool {
        self.partition_stale
    }

    /// Pull a coflow out of this engine for ownership migration to another
    /// shard: its state, live rates, Γ-cache entry, and component-dirty
    /// flag travel together so the receiving engine behaves exactly as if
    /// the coflow had always lived there.
    pub(crate) fn extract_coflow(&mut self, id: CoflowId) -> Option<MigratedCoflow> {
        let idx = self.active.iter().position(|c| c.id == id)?;
        let state = self.active.remove(idx);
        let rates = self.alloc.rates.remove(&id);
        let gamma = self.cache.export(id);
        let dirty = self.comp_cache.is_dirty(id);
        self.comp_cache.forget(id);
        for ws in &mut self.workspaces {
            ws.forget(id);
        }
        self.partition_stale = true;
        Some(MigratedCoflow { state, rates, gamma, dirty })
    }

    /// Adopt a migrated coflow at `pos` in the active table (the front-end
    /// computes `pos` so every shard's table stays a subsequence of the
    /// global arrival order — the determinism invariant; see
    /// [`sharded::ShardedEngine`]).
    pub(crate) fn adopt_coflow(&mut self, m: MigratedCoflow, pos: usize) {
        let id = m.state.id;
        self.cache.invalidate(id);
        if let Some(g) = m.gamma {
            self.cache.import(id, g);
        }
        if let Some(r) = m.rates {
            self.alloc.rates.insert(id, r);
        }
        if m.dirty {
            self.comp_cache.mark_dirty(id);
        }
        self.partition_stale = true;
        let pos = pos.min(self.active.len());
        self.active.insert(pos, m.state);
    }
}

/// A coflow in transit between engine shards: everything the receiving
/// engine needs to continue scheduling it as if it had arrived there.
pub(crate) struct MigratedCoflow {
    pub(crate) state: CoflowState,
    pub(crate) rates: Option<crate::scheduler::CoflowRates>,
    pub(crate) gamma: Option<cache::GammaExport>,
    pub(crate) dirty: bool,
}

/// Per-coflow min scale factor over the edges its nonzero rates traverse,
/// given per-edge factors (`< 1` on over-subscribed edges). Inserts only
/// coflows that need scaling. Shared by [`RoundEngine::throttle_factors`]
/// and the sharded front-end, which computes the edge factors from
/// *aggregate* usage across all shards.
fn collect_throttle_factors(
    active: &[CoflowState],
    alloc: &Allocation,
    paths: &PathSet,
    factors: &[f64],
    out: &mut HashMap<CoflowId, f64>,
) {
    for cf in active.iter() {
        let Some(rates) = alloc.rates.get(&cf.id) else { continue };
        let mut f = 1.0f64;
        for (gi, g) in cf.groups.iter().enumerate() {
            let pair_paths = paths.get(g.src, g.dst);
            for (pi, &r) in rates.get(gi).map(|v| v.as_slice()).unwrap_or(&[]).iter().enumerate()
            {
                if r <= 0.0 {
                    continue;
                }
                if let Some(p) = pair_paths.get(pi) {
                    for &e in &p.edges {
                        f = f.min(factors[e]);
                    }
                }
            }
        }
        if f < 1.0 {
            out.insert(cf.id, f);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coflow::{Coflow, Flow, GB};
    use crate::net::topologies;
    use crate::scheduler::terra::{TerraConfig, TerraPolicy};

    fn engine(cold: bool) -> RoundEngine {
        let wan = topologies::fig1a();
        let policy = TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() });
        RoundEngine::new(
            wan,
            Box::new(policy),
            EngineConfig { check_feasibility: true, cold, ..Default::default() },
        )
    }

    fn coflow(id: u64, s: usize, d: usize, gb: f64) -> CoflowState {
        CoflowState::from_coflow(&Coflow::new(
            id,
            vec![Flow { id: 0, src_dc: s, dst_dc: d, volume: gb * GB }],
        ))
    }

    #[test]
    fn round_drain_finish_lifecycle() {
        let mut e = engine(false);
        e.insert(coflow(1, 0, 1, 5.0)); // 40 Gbit, 20 Gbps across 2 paths
        e.round(0.0, RoundTrigger::CoflowArrival);
        assert_eq!(e.rounds(), 1);
        let r = e.coflow_rate(1);
        assert!((r - 20.0).abs() < 0.5, "rate={r}");
        let done_at = e.next_completion(0.0).unwrap();
        assert!((done_at - 2.0).abs() < 0.1, "t={done_at}");
        let moved = e.drain(done_at, 0.0);
        assert!((moved - 40.0).abs() < 1e-6, "moved={moved}");
        assert_eq!(e.take_finished(), vec![1]);
        assert!(e.is_empty());
        assert_eq!(e.coflow_rate(1), 0.0);
    }

    #[test]
    fn sub_rho_clamps_without_epoch_bump() {
        let mut e = engine(false);
        e.insert(coflow(1, 0, 1, 5.0));
        e.round(0.0, RoundTrigger::CoflowArrival);
        let epoch0 = e.epoch();
        // 10% drop < rho=0.25: clamp, same epoch, no round required.
        let reaction = e.handle_wan_event(&LinkEvent::SetBandwidth(0, 1, 9.0));
        assert_eq!(reaction, WanReaction::Clamped);
        assert!(reaction.trigger().is_none());
        assert_eq!(e.epoch(), epoch0);
        // Clamped allocation is feasible on the shrunk WAN.
        let net = NetView { wan: e.wan(), paths: e.paths() };
        let usage = e.alloc().edge_usage(e.active(), &net, e.wan().num_edges());
        for (u, c) in usage.iter().zip(e.wan().capacities()) {
            assert!(*u <= c + 1e-6, "{u} > {c}");
        }
    }

    #[test]
    fn accumulated_sub_rho_drift_reoptimizes() {
        let mut e = engine(false);
        e.insert(coflow(1, 0, 1, 5.0));
        e.round(0.0, RoundTrigger::CoflowArrival);
        let epoch0 = e.epoch();
        // A 20% drop is sub-ρ: clamp, no round, cache stays warm...
        assert_eq!(e.handle_wan_event(&LinkEvent::SetBandwidth(0, 1, 8.0)), WanReaction::Clamped);
        assert_eq!(e.epoch(), epoch0, "single sub-ρ event must keep the cache");
        // ...but a second 20% step has slid the edge 36% from the epoch
        // snapshot: the accumulated drift is a qualifying event — epoch
        // bump AND a re-optimization round.
        let reaction = e.handle_wan_event(&LinkEvent::SetBandwidth(0, 1, 6.4));
        assert_eq!(reaction, WanReaction::Reoptimize, "accumulated drift must trigger a round");
        assert!(reaction.trigger().is_some());
        assert_eq!(e.epoch(), epoch0 + 1, "cumulative drift must invalidate the Γ-cache");
        // The snapshot re-anchors at the bump: the next small step is sub-ρ
        // again relative to the new baseline.
        e.round(0.1, reaction.trigger().unwrap());
        assert_eq!(e.handle_wan_event(&LinkEvent::SetBandwidth(0, 1, 6.0)), WanReaction::Clamped);
        assert_eq!(e.epoch(), epoch0 + 1);
    }

    #[test]
    fn super_rho_and_structural_bump_epoch() {
        let mut e = engine(false);
        e.insert(coflow(1, 0, 1, 5.0));
        e.round(0.0, RoundTrigger::CoflowArrival);
        let epoch0 = e.epoch();
        let reaction = e.handle_wan_event(&LinkEvent::SetBandwidth(0, 1, 4.0)); // 60% drop
        assert_eq!(reaction, WanReaction::Reoptimize);
        assert_eq!(e.epoch(), epoch0 + 1);
        e.round(0.0, reaction.trigger().unwrap());
        let reaction = e.handle_wan_event(&LinkEvent::Fail(0, 1));
        assert_eq!(reaction, WanReaction::Structural);
        assert_eq!(e.epoch(), epoch0 + 2);
        e.round(0.0, RoundTrigger::WanChange);
        // Direct path is gone: everything routes via C at 10 Gbps.
        let r = e.coflow_rate(1);
        assert!((r - 10.0).abs() < 0.5, "rate={r}");
    }

    #[test]
    fn gamma_cache_cuts_lp_solves() {
        let run = |cold: bool| -> (usize, usize) {
            let mut e = engine(cold);
            for i in 0..6 {
                e.insert(coflow(i + 1, (i as usize) % 3, ((i as usize) + 1) % 3, 50.0));
            }
            e.round(0.0, RoundTrigger::CoflowArrival);
            let first = e.take_stats().lp_solves;
            // Re-rounds with no qualifying WAN change in between.
            e.drain(0.1, 0.0);
            e.round(0.1, RoundTrigger::CoflowArrival);
            e.drain(0.1, 0.0);
            e.round(0.2, RoundTrigger::CoflowArrival);
            (first, e.take_stats().lp_solves)
        };
        let (cold_first, cold_rest) = run(true);
        let (warm_first, warm_rest) = run(false);
        // First rounds cost the same (cache is empty).
        assert_eq!(cold_first, warm_first);
        // Cached re-rounds skip the per-coflow ordering solves.
        assert!(
            warm_rest < cold_rest,
            "cached rounds should solve fewer LPs: {warm_rest} vs {cold_rest}"
        );
    }

    #[test]
    fn drain_emptying_a_group_invalidates_gamma() {
        let mut e = engine(false);
        // Wildly unbalanced groups so one empties long before the other.
        e.insert(CoflowState::from_coflow(&Coflow::new(
            9,
            vec![
                Flow { id: 0, src_dc: 0, dst_dc: 1, volume: 4.0 },
                Flow { id: 1, src_dc: 2, dst_dc: 1, volume: 400.0 },
            ],
        )));
        e.round(0.0, RoundTrigger::CoflowArrival);
        e.take_stats();
        // No drain event in between: the whole component is untouched — the
        // engine carries the allocation forward without calling the policy.
        e.round(0.1, RoundTrigger::CoflowArrival);
        let reused = e.take_stats();
        assert_eq!(reused.lp_solves, 0, "clean component must not re-solve");
        assert_eq!(reused.component_reuses, 1);
        assert_eq!(reused.component_solves, 0);
        // Drain to the first group completion: the coflow's shape changed
        // discontinuously — its component re-solves and the cached Γ is
        // gone, so the next round pays a fresh Γ solve.
        let t = e.next_completion(0.0).expect("something is draining");
        e.drain(t, 0.0);
        e.round(t, RoundTrigger::FlowGroupFinish);
        let resolved = e.take_stats();
        assert_eq!(resolved.component_solves, 1);
        assert!(resolved.lp_solves > 0, "dirty component must re-solve");
        assert_eq!(
            resolved.gamma_cache_hits,
            0,
            "group completion via drain must invalidate the Γ entry"
        );
    }

    /// Two edge-disjoint triangles: coflows in different triangles are
    /// independent commodities — an arrival or a qualifying WAN event in
    /// one triangle must re-solve only that triangle's component, carrying
    /// the other's rates forward bit-identically.
    fn two_triangles() -> Wan {
        let mut w = Wan::new();
        for i in 0..6 {
            w.add_node(&format!("N{i}"), 0.0, i as f64);
        }
        w.add_link(0, 1, 10.0, Some(1.0));
        w.add_link(1, 2, 10.0, Some(1.0));
        w.add_link(0, 2, 10.0, Some(1.0));
        w.add_link(3, 4, 10.0, Some(1.0));
        w.add_link(4, 5, 10.0, Some(1.0));
        w.add_link(3, 5, 10.0, Some(1.0));
        w
    }

    #[test]
    fn disjoint_components_solve_and_reuse_independently() {
        let policy = TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() });
        let mut e = RoundEngine::new(
            two_triangles(),
            Box::new(policy),
            EngineConfig { check_feasibility: true, ..Default::default() },
        );
        e.insert(coflow(1, 0, 1, 5.0));
        e.insert(coflow(2, 3, 4, 5.0));
        e.round(0.0, RoundTrigger::CoflowArrival);
        let s = e.take_stats();
        assert_eq!((s.component_solves, s.component_reuses), (2, 0));
        let r2 = e.coflow_rates(2).unwrap();

        // An arrival in triangle A dirties only its component.
        e.insert(coflow(3, 1, 2, 5.0));
        e.round(0.1, RoundTrigger::CoflowArrival);
        let s = e.take_stats();
        assert_eq!((s.component_solves, s.component_reuses), (1, 1), "only triangle A re-solves");
        assert_eq!(e.coflow_rates(2).unwrap(), r2, "untouched component rates must not change");
        assert!(e.coflow_rate(3) > 0.0);

        // A qualifying fluctuation on a triangle-B edge re-solves only B.
        let r1 = e.coflow_rates(1).unwrap();
        let reaction = e.handle_wan_event(&LinkEvent::SetBandwidth(3, 4, 4.0)); // 60% ≥ ρ
        assert_eq!(reaction, WanReaction::Reoptimize);
        e.round(0.2, RoundTrigger::WanChange);
        let s = e.take_stats();
        assert_eq!((s.component_solves, s.component_reuses), (1, 1), "only triangle B re-solves");
        assert_eq!(e.coflow_rates(1).unwrap(), r1, "triangle A rates must carry forward");
        assert!(e.coflow_rate(2) > 0.0);

        // A sub-ρ dip on a triangle-B edge clamps only the coflows that
        // actually cross it: triangle A's carried-forward rates must stay
        // bit-identical (a single global clamp factor would decay every
        // clean component a little more on each dip, with nothing ever
        // re-solving them).
        let r1 = e.coflow_rates(1).unwrap();
        let r3 = e.coflow_rates(3).unwrap();
        let b_before = e.coflow_rate(2);
        assert_eq!(e.handle_wan_event(&LinkEvent::SetBandwidth(3, 5, 9.0)), WanReaction::Clamped);
        assert_eq!(e.coflow_rates(1).unwrap(), r1, "clamp leaked into an untouched component");
        assert_eq!(e.coflow_rates(3).unwrap(), r3, "clamp leaked into an untouched component");
        assert!(e.coflow_rate(2) <= b_before + 1e-9, "dipped component must not gain rate");
    }

    /// Per-edge drift baselines: a qualifying event on edge X must NOT
    /// re-anchor edge Y's baseline — Y's components were not re-solved, so
    /// Y's accumulated sub-ρ drift has to keep counting until it reaches ρ
    /// and forces a round of its own.
    #[test]
    fn drift_baseline_survives_other_edges_reoptimize() {
        let mut e = engine(false);
        e.insert(coflow(1, 0, 1, 5.0));
        e.round(0.0, RoundTrigger::CoflowArrival);
        // Edge (0,1) drifts 20% — sub-ρ, clamped, baseline stays at 10.
        assert_eq!(e.handle_wan_event(&LinkEvent::SetBandwidth(0, 1, 8.0)), WanReaction::Clamped);
        // Edge (0,2) takes a qualifying 50% hit: re-optimizes, but only
        // (0,2)'s baseline re-anchors.
        assert_eq!(
            e.handle_wan_event(&LinkEvent::SetBandwidth(0, 2, 5.0)),
            WanReaction::Reoptimize
        );
        e.round(0.1, RoundTrigger::WanChange);
        // Edge (0,1) drifts a further sub-ρ step to 6.9: 31% from ITS
        // baseline of 10 — must promote to a re-optimization. (A global
        // re-anchor at the (0,2) event would have reset (0,1)'s baseline
        // to 8.0 and silently clamped this forever.)
        let reaction = e.handle_wan_event(&LinkEvent::SetBandwidth(0, 1, 6.9));
        assert_eq!(reaction, WanReaction::Reoptimize, "accumulated drift lost its baseline");
    }

    /// Parallel component solves must be bit-identical to sequential ones:
    /// same WAN, same arrival schedule, engines differing only in
    /// `workers`, compared allocation-for-allocation after every round.
    #[test]
    fn parallel_workers_bit_identical_to_sequential() {
        let mk = |workers: usize| {
            let policy = TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() });
            RoundEngine::new(
                two_triangles(),
                Box::new(policy),
                EngineConfig { check_feasibility: true, workers, ..Default::default() },
            )
        };
        let mut seq = mk(1);
        let mut par = mk(4);
        let arrivals = [(1, 0, 1, 5.0), (2, 3, 4, 7.0), (3, 1, 2, 3.0), (4, 4, 5, 9.0)];
        let mut now = 0.0;
        for &(id, s, d, gb) in &arrivals {
            for e in [&mut seq, &mut par] {
                e.insert(coflow(id, s, d, gb));
                e.round(now, RoundTrigger::CoflowArrival);
            }
            assert_eq!(
                seq.alloc().rates,
                par.alloc().rates,
                "allocations diverged after arrival {id}"
            );
            for e in [&mut seq, &mut par] {
                e.drain(0.05, 0.0);
            }
            now += 0.05;
        }
        // A qualifying WAN event dirtying both triangles: both components
        // re-solve, in parallel on one engine, sequentially on the other.
        for e in [&mut seq, &mut par] {
            assert_eq!(
                e.handle_wan_event(&LinkEvent::SetBandwidth(0, 1, 4.0)),
                WanReaction::Reoptimize
            );
            assert_eq!(
                e.handle_wan_event(&LinkEvent::SetBandwidth(3, 4, 4.0)),
                WanReaction::Reoptimize
            );
            e.round(now, RoundTrigger::WanChange);
        }
        assert_eq!(seq.alloc().rates, par.alloc().rates, "post-WAN-event divergence");
        let (s1, s2) = (seq.take_stats(), par.take_stats());
        assert_eq!(s1.lp_solves, s2.lp_solves, "solve counts must match");
        assert_eq!(s1.component_solves, s2.component_solves);
        assert_eq!(s1.gamma_cache_hits, s2.gamma_cache_hits);
    }

    fn estimating_engine() -> RoundEngine {
        use crate::net::telemetry::{EstimatorKind, TelemetryConfig};
        let wan = topologies::fig1a();
        let policy = TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() });
        RoundEngine::new(
            wan,
            Box::new(policy),
            EngineConfig {
                check_feasibility: true,
                telemetry: TelemetryConfig {
                    estimator: EstimatorKind::Ewma { alpha: 0.5 },
                    ..TelemetryConfig::oracle()
                },
                ..Default::default()
            },
        )
    }

    /// Oracle telemetry is inert: feeding observations and refreshing
    /// beliefs must change nothing at all — same epoch, same WAN
    /// capacities, same allocation.
    #[test]
    fn oracle_telemetry_is_inert() {
        let mut e = engine(false);
        e.insert(coflow(1, 0, 1, 5.0));
        e.round(0.0, RoundTrigger::CoflowArrival);
        let epoch0 = e.epoch();
        let caps0 = e.wan().capacities();
        let alloc0 = e.alloc().rates.clone();
        for edge in 0..e.wan().num_edges() {
            e.observe_edge(edge, 1.0, true, 1.0);
            e.probe_edge(edge, 2.0, 1.0);
            e.announce_prior(edge, 3.0, 1.0, 5.0);
        }
        assert_eq!(e.refresh_beliefs(), None);
        assert_eq!(e.epoch(), epoch0);
        assert_eq!(e.wan().capacities(), caps0);
        assert_eq!(e.alloc().rates, alloc0);
    }

    /// Belief changes flow through the same ρ gate as oracle events: a
    /// collapsed belief on a used edge re-optimizes (epoch bump), a small
    /// belief wiggle only clamps, and the scheduler's WAN tracks cap_used.
    #[test]
    fn belief_refresh_routes_through_rho_gate() {
        let mut e = estimating_engine();
        e.insert(coflow(1, 0, 1, 5.0));
        e.round(0.0, RoundTrigger::CoflowArrival);
        let epoch0 = e.epoch();
        let edge = e.wan().edge_between(0, 1).unwrap();
        // Repeated capped samples at 3 Gbps collapse the 10 Gbps belief.
        for i in 0..6 {
            e.observe_edge(edge, 3.0, true, i as f64);
        }
        let reaction = e.refresh_beliefs().expect("belief moved");
        assert_eq!(reaction, WanReaction::Reoptimize, "≥ρ belief change must re-optimize");
        assert!(e.epoch() > epoch0, "belief change must bump the capacity epoch");
        let believed = e.wan().link(edge).capacity;
        assert!(
            (believed - e.estimator().cap_used(edge)).abs() < 1e-9,
            "scheduler WAN must hold cap_used: {believed}"
        );
        assert!(believed < 5.0, "belief should have collapsed: {believed}");
        e.round(1.0, reaction.trigger().unwrap());
        // A tiny wiggle (within ρ of the new level) only clamps.
        let epoch1 = e.epoch();
        let level = e.estimator().mean(edge);
        e.observe_edge(edge, level * 0.95, true, 10.0);
        match e.refresh_beliefs() {
            None | Some(WanReaction::Clamped) => {}
            other => panic!("sub-ρ belief wiggle must not re-optimize: {other:?}"),
        }
        assert_eq!(e.epoch(), epoch1);
    }

    /// A SetBandwidth event under a non-oracle estimator is an
    /// authoritative measurement, and structural recovery re-anchors the
    /// belief at base capacity.
    #[test]
    fn belief_mode_events_and_recovery_reanchor() {
        let mut e = estimating_engine();
        e.insert(coflow(1, 0, 1, 5.0));
        e.round(0.0, RoundTrigger::CoflowArrival);
        let edge = e.wan().edge_between(0, 1).unwrap();
        // The injected event is authoritative: the belief jumps to it
        // outright (a prior), regardless of estimator kind.
        e.handle_wan_event(&LinkEvent::SetBandwidth(0, 1, 2.0));
        let m = e.estimator().mean(edge);
        assert!((m - 2.0).abs() < 1e-9, "mean={m}");
        // Fail + recover: belief back at base, WAN at base.
        assert_eq!(e.handle_wan_event(&LinkEvent::Fail(0, 1)), WanReaction::Structural);
        assert_eq!(e.handle_wan_event(&LinkEvent::Recover(0, 1)), WanReaction::Structural);
        assert_eq!(e.estimator().mean(edge), 10.0);
        assert_eq!(e.wan().link(edge).capacity, 10.0);
        assert_eq!(e.refresh_beliefs(), None, "re-anchored belief must not re-fire");
    }

    /// Deadline admission must run against the *fresh* `mean − k·σ`
    /// headroom, not the scheduler's ρ-gated WAN view: when capped
    /// samples collapse the belief but no refresh has run yet, the WAN is
    /// stale-optimistic and must no longer over-admit.
    #[test]
    fn stale_optimistic_belief_does_not_over_admit() {
        let mut e = estimating_engine();
        let candidate = CoflowState::from_coflow(
            &Coflow::new(9, vec![Flow { id: 0, src_dc: 0, dst_dc: 1, volume: 16.0 }])
                .with_deadline(1.0),
        );
        // Fresh beliefs sit at base capacity: 16 Gbit over ~20 Gbps of
        // headroom makes the 1 s deadline comfortably.
        assert!(e.admit(0.0, &candidate), "full-capacity headroom must admit");
        // Capped samples collapse every edge's belief, but refresh_beliefs
        // never runs: the scheduler's WAN still holds base capacity.
        for edge in 0..e.wan().num_edges() {
            for i in 0..6 {
                e.observe_edge(edge, 2.0, true, i as f64);
            }
        }
        assert_eq!(
            e.wan().capacities(),
            topologies::fig1a().capacities(),
            "precondition: the gated WAN view must still be stale-optimistic"
        );
        assert!(!e.admit(0.0, &candidate), "stale-optimistic belief over-admitted");
        // An oracle engine is untouched by the same (ignored) samples.
        let mut oracle = engine(false);
        for edge in 0..oracle.wan().num_edges() {
            oracle.observe_edge(edge, 2.0, true, 1.0);
        }
        assert!(oracle.admit(0.0, &candidate), "oracle admission must be unchanged");
    }

    /// Truth-throttled drain: a coflow whose edges truly admit less than
    /// the (believed) allocation drains at the throttled rate.
    #[test]
    fn drain_with_throttles_per_coflow() {
        let mut e = engine(false);
        e.insert(coflow(1, 0, 1, 5.0)); // 40 Gbit at 20 Gbps believed
        e.round(0.0, RoundTrigger::CoflowArrival);
        let mut throttle = HashMap::new();
        throttle.insert(1u64, 0.5);
        let moved = e.drain_with(1.0, 0.0, Some(&throttle));
        assert!((moved - 10.0).abs() < 0.3, "moved={moved} (expected ~20·0.5)");
        let full = e.drain_with(1.0, 0.0, None);
        assert!((full - 20.0).abs() < 0.5, "moved={full}");
    }

    #[test]
    fn complete_group_marks_done_and_dirty() {
        let mut e = engine(false);
        let st = CoflowState::from_coflow(&Coflow::new(
            7,
            vec![
                Flow { id: 0, src_dc: 0, dst_dc: 1, volume: 8.0 },
                Flow { id: 1, src_dc: 2, dst_dc: 1, volume: 8.0 },
            ],
        ));
        e.insert(st);
        e.round(0.0, RoundTrigger::CoflowArrival);
        assert!(!e.complete_group(7, 0, 1), "one group left");
        assert!(e.get(7).unwrap().remaining[0] <= 1e-12);
        assert!(e.complete_group(7, 2, 1), "now done");
        assert_eq!(e.take_finished(), vec![7]);
    }
}
