//! The **RoundEngine**: the one shared implementation of Terra's scheduling
//! round, driven by both the flow-level simulator ([`crate::sim`]) and the
//! overlay controller ([`crate::overlay`]).
//!
//! Terra's online algorithm (§3.1.3) re-runs joint routing + scheduling on
//! every coflow arrival, FlowGroup/coflow completion, and significant WAN
//! event. The engine owns everything that decision loop needs:
//!
//! - the WAN view and its k-shortest-path sets (recomputed on structural
//!   events, §4.4),
//! - the active-coflow table ([`CoflowState`]s, with incremental draining),
//! - ρ-dampened WAN-event filtering: sub-threshold bandwidth fluctuations
//!   clamp the current allocation instead of re-optimizing (§3.1.3),
//! - round triggering and execution through the [`Policy`] interface,
//! - allocation feasibility checking (debug/tests),
//! - per-round instrumentation ([`RoundStats`]),
//! - **incremental re-optimization**: a [`GammaCache`] of standalone
//!   min-CCT solves keyed by `(coflow, WAN capacity epoch)` with dirty-set
//!   invalidation, plus warm-starting of the GK solver from the previous
//!   round's allocation.
//!
//! Drivers differ only in how they learn about time and events: the
//! simulator advances virtual time and feeds completions from its event
//! heap; the controller drains by wall-clock time and feeds agent reports.
//! Both call the same [`RoundEngine`] entry points, which is what keeps the
//! two planes behaviorally identical (the §6.1 methodology) and is enforced
//! by the `integration_engine` parity test.

pub mod cache;

pub use cache::GammaCache;

use crate::coflow::CoflowId;
use crate::lp;
use crate::net::paths::PathSet;
use crate::net::{LinkEvent, Wan};
use crate::scheduler::{
    build_instance, Allocation, CoflowState, NetView, Policy, RoundCtx, RoundStats, RoundTrigger,
};

/// Engine knobs shared by both drivers.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Bandwidth-fluctuation threshold ρ for re-optimization (§3.1.3);
    /// events below it clamp instead of re-optimizing.
    pub rho: f64,
    /// Assert allocation feasibility after every round (tests/debug).
    pub check_feasibility: bool,
    /// Disable the Γ-cache and GK warm starts (cold per-round solves, the
    /// pre-incremental behavior; used by the round-latency benchmarks).
    pub cold: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            rho: crate::scheduler::DEFAULT_RHO,
            check_feasibility: cfg!(debug_assertions),
            cold: false,
        }
    }
}

/// What [`RoundEngine::handle_wan_event`] did with an event; tells the
/// driver whether (and why) to run a round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WanReaction {
    /// Topology changed (fail/recover): paths recomputed, epoch bumped —
    /// run a round; the controller must also reinstall rules/peers.
    Structural,
    /// Capacity fluctuation ≥ ρ: epoch bumped — run a round.
    Reoptimize,
    /// Sub-ρ fluctuation: current allocation clamped back to feasibility,
    /// no round needed (and the Γ-cache stays warm).
    Clamped,
}

impl WanReaction {
    /// The round trigger this reaction implies, if any.
    pub fn trigger(&self) -> Option<RoundTrigger> {
        match self {
            WanReaction::Structural | WanReaction::Reoptimize => Some(RoundTrigger::WanChange),
            WanReaction::Clamped => None,
        }
    }
}

/// The shared round engine. See the module docs for responsibilities.
pub struct RoundEngine {
    wan: Wan,
    paths: PathSet,
    policy: Box<dyn Policy>,
    cfg: EngineConfig,
    k: usize,
    active: Vec<CoflowState>,
    alloc: Allocation,
    cache: GammaCache,
    /// False after a structural event until the next round: the previous
    /// allocation's path indices no longer match the path sets, so it must
    /// not seed warm starts.
    warm_valid: bool,
    /// Per-edge available-capacity snapshot taken at the last epoch bump.
    /// Individually ignorable fluctuations must not be collectively
    /// ignorable: once some edge's capacity has slid ≥ ρ away from this
    /// snapshot through sub-ρ steps, the accumulated drift is treated
    /// exactly like one qualifying event — epoch bump *and* a
    /// re-optimization round.
    epoch_caps: Vec<f64>,
    rounds: usize,
}

impl RoundEngine {
    /// Build an engine around a WAN and a policy; path sets are computed
    /// for the policy's k.
    pub fn new(wan: Wan, policy: Box<dyn Policy>, cfg: EngineConfig) -> RoundEngine {
        let k = policy.k_paths();
        RoundEngine::with_k(wan, policy, cfg, k)
    }

    /// [`RoundEngine::new`] with an explicit path count (the overlay
    /// testbed wires `k` persistent connections per agent pair, which may
    /// be fewer than the policy's default).
    pub fn with_k(
        wan: Wan,
        policy: Box<dyn Policy>,
        cfg: EngineConfig,
        k: usize,
    ) -> RoundEngine {
        let paths = PathSet::compute(&wan, k);
        let epoch_caps = wan.capacities();
        RoundEngine {
            wan,
            paths,
            policy,
            cfg,
            k,
            active: Vec::new(),
            alloc: Allocation::default(),
            cache: GammaCache::new(),
            warm_valid: false,
            epoch_caps,
            rounds: 0,
        }
    }

    pub fn wan(&self) -> &Wan {
        &self.wan
    }

    pub fn paths(&self) -> &PathSet {
        &self.paths
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn k_paths(&self) -> usize {
        self.k
    }

    /// Current WAN capacity epoch (bumped by qualifying WAN events).
    pub fn epoch(&self) -> u64 {
        self.cache.epoch()
    }

    /// Rounds executed so far.
    pub fn rounds(&self) -> usize {
        self.rounds
    }

    /// The most recent allocation.
    pub fn alloc(&self) -> &Allocation {
        &self.alloc
    }

    /// All active (admitted, unfinished) coflows.
    pub fn active(&self) -> &[CoflowState] {
        &self.active
    }

    pub fn len(&self) -> usize {
        self.active.len()
    }

    pub fn is_empty(&self) -> bool {
        self.active.is_empty()
    }

    pub fn get(&self, id: CoflowId) -> Option<&CoflowState> {
        self.active.iter().find(|c| c.id == id)
    }

    /// Mutable access for drivers that extend coflows in place
    /// (`updateCoflow`, §5.2). Callers that change the group shape must
    /// [`RoundEngine::mark_dirty`] afterwards.
    pub fn get_mut(&mut self, id: CoflowId) -> Option<&mut CoflowState> {
        self.active.iter_mut().find(|c| c.id == id)
    }

    /// Add a coflow to the active table (does not run a round).
    pub fn insert(&mut self, st: CoflowState) {
        self.cache.invalidate(st.id);
        self.active.push(st);
    }

    /// Drop a coflow's Γ-cache entry after a discontinuous change to its
    /// remaining volumes (group completion, update).
    pub fn mark_dirty(&mut self, id: CoflowId) {
        self.cache.invalidate(id);
    }

    /// Deadline admission control against the current active set (§3.2).
    pub fn admit(&mut self, now: f64, candidate: &CoflowState) -> bool {
        let RoundEngine { wan, paths, policy, active, .. } = self;
        let net = NetView { wan, paths };
        policy.admit(now, candidate, active, &net)
    }

    /// Minimum CCT of a coflow alone on the *full* WAN (for slowdown and
    /// deadline metrics). Not counted in policy LP stats, like the
    /// pre-engine simulator implementation.
    pub fn standalone_min_cct(&self, st: &CoflowState) -> f64 {
        let net = NetView { wan: &self.wan, paths: &self.paths };
        let (inst, _) =
            build_instance(&st.groups, &st.remaining, &self.wan.capacities(), &net, self.k);
        if inst.groups.is_empty() {
            return 0.0;
        }
        lp::max_concurrent(&inst, lp::SolverKind::Gk).map(|s| s.gamma()).unwrap_or(f64::INFINITY)
    }

    /// Apply a WAN event with ρ-dampened filtering (§3.1.3): structural
    /// events recompute paths and bump the capacity epoch; fluctuations ≥ ρ
    /// bump the epoch; smaller fluctuations clamp the current allocation —
    /// unless they have *accumulated*: once any edge's capacity has drifted
    /// ≥ ρ away from the last epoch's snapshot, the sub-ρ step is promoted
    /// to a re-optimization exactly like a single qualifying event.
    /// The caller runs a round iff [`WanReaction::trigger`] is `Some`.
    pub fn handle_wan_event(&mut self, ev: &LinkEvent) -> WanReaction {
        let frac = self.wan.apply_event(ev);
        let structural = matches!(ev, LinkEvent::Fail(..) | LinkEvent::Recover(..));
        if structural {
            // Recompute viable paths (§4.4); previous path indices are
            // meaningless now, so drop warm-start state too.
            self.paths = PathSet::compute(&self.wan, self.k);
            self.bump_epoch();
            self.warm_valid = false;
            WanReaction::Structural
        } else if frac >= self.cfg.rho || self.epoch_drift(ev) >= self.cfg.rho {
            // One big step, or many small ones that add up to one: either
            // way the capacities the last optimization (and every cached Γ)
            // was computed against are off by ≥ ρ somewhere.
            self.bump_epoch();
            WanReaction::Reoptimize
        } else {
            self.clamp_alloc();
            WanReaction::Clamped
        }
    }

    /// Advance the Γ-cache epoch and re-anchor the drift snapshot on the
    /// current available capacities.
    fn bump_epoch(&mut self) {
        self.cache.bump_epoch();
        self.epoch_caps = self.wan.capacities();
    }

    /// Accumulated drift of the edge a fluctuation touched: fractional
    /// deviation of its current available capacity from the last epoch's
    /// snapshot. O(1): every *other* edge was verified < ρ when its own
    /// last event was handled (and epoch bumps re-anchor the snapshot), so
    /// only the touched edge can newly reach ρ.
    fn epoch_drift(&self, ev: &LinkEvent) -> f64 {
        let LinkEvent::SetBandwidth(u, v, _) = *ev else { return 0.0 };
        let Some(e) = self.wan.edge_between(u, v) else { return 0.0 };
        let c = self.wan.link(e).avail();
        let c0 = self.epoch_caps[e];
        (c - c0).abs() / c0.max(1e-9)
    }

    /// Run one scheduling round: hand the policy the active set, the
    /// Γ-cache, and the previous allocation as a warm start.
    pub fn round(&mut self, now: f64, trigger: RoundTrigger) -> &Allocation {
        let RoundEngine { wan, paths, policy, cfg, active, alloc, cache, warm_valid, .. } = self;
        let net = NetView { wan, paths };
        let new_alloc = if cfg.cold {
            policy.allocate(now, trigger, active, &net)
        } else {
            let warm = if *warm_valid && !alloc.rates.is_empty() { Some(&*alloc) } else { None };
            let ctx = RoundCtx { trigger, epoch: cache.epoch(), cache, warm };
            policy.allocate_with(now, ctx, active, &net)
        };
        self.alloc = new_alloc;
        self.warm_valid = true;
        self.rounds += 1;
        if self.cfg.check_feasibility {
            let net = NetView { wan: &self.wan, paths: &self.paths };
            let usage = self.alloc.edge_usage(&self.active, &net, self.wan.num_edges());
            for (e, (&u, c)) in usage.iter().zip(self.wan.capacities()).enumerate() {
                assert!(
                    u <= c * (1.0 + 1e-4) + 1e-6,
                    "policy {} oversubscribed edge {e}: {u} > {c}",
                    self.policy.name()
                );
            }
        }
        &self.alloc
    }

    /// Scale down rates on edges whose capacity dropped below usage
    /// (sub-threshold fluctuations, no re-optimization).
    pub fn clamp_alloc(&mut self) {
        let net = NetView { wan: &self.wan, paths: &self.paths };
        let usage = self.alloc.edge_usage(&self.active, &net, self.wan.num_edges());
        let caps = self.wan.capacities();
        let mut worst = 1.0f64;
        for (&u, &c) in usage.iter().zip(&caps) {
            if u > c && u > 1e-12 {
                worst = worst.min(c / u);
            }
        }
        if worst < 1.0 {
            for rates in self.alloc.rates.values_mut() {
                for g in rates {
                    for r in g {
                        *r *= worst;
                    }
                }
            }
        }
    }

    /// Drain every active FlowGroup at the current allocation for `dt`
    /// seconds. Remaining volumes are floored at `floor` (the controller
    /// keeps a 1e-6 trickle until the agent confirms completion; the
    /// simulator floors at 0). Returns the Gbit moved.
    pub fn drain(&mut self, dt: f64, floor: f64) -> f64 {
        if dt <= 0.0 {
            return 0.0;
        }
        let mut moved = 0.0;
        let mut emptied: Vec<CoflowId> = Vec::new();
        for cf in &mut self.active {
            let Some(rates) = self.alloc.rates.get(&cf.id) else { continue };
            for (gi, rem) in cf.remaining.iter_mut().enumerate() {
                if *rem <= 1e-9 {
                    continue;
                }
                let rate: f64 = rates.get(gi).map(|r| r.iter().sum()).unwrap_or(0.0);
                if rate <= 0.0 {
                    continue;
                }
                let new = (*rem - rate * dt).max(floor.min(*rem));
                moved += *rem - new;
                *rem = new;
                if new <= 1e-9 {
                    // A FlowGroup just completed: the coflow's shape changed
                    // discontinuously, so its cached Γ (which rescales by
                    // *total* remaining, assuming proportional drain) is no
                    // longer valid — same dirty rule `complete_group`
                    // applies on the controller plane.
                    emptied.push(cf.id);
                }
            }
        }
        for id in emptied {
            self.cache.invalidate(id);
        }
        moved
    }

    /// Earliest absolute time any active FlowGroup empties at current
    /// rates, or `None` when nothing is draining.
    pub fn next_completion(&self, now: f64) -> Option<f64> {
        let mut best: Option<f64> = None;
        for cf in &self.active {
            let Some(rates) = self.alloc.rates.get(&cf.id) else { continue };
            for (gi, &rem) in cf.remaining.iter().enumerate() {
                if rem <= 1e-9 {
                    continue;
                }
                let rate: f64 = rates.get(gi).map(|r| r.iter().sum()).unwrap_or(0.0);
                if rate > 1e-12 {
                    let t = now + rem / rate;
                    best = Some(best.map_or(t, |b: f64| b.min(t)));
                }
            }
        }
        best
    }

    /// Record an agent-confirmed FlowGroup completion (controller driver).
    /// Returns true when the whole coflow is now done.
    pub fn complete_group(&mut self, id: CoflowId, src: usize, dst: usize) -> bool {
        let Some(cf) = self.active.iter_mut().find(|c| c.id == id) else { return false };
        let mut hit = false;
        for (gi, g) in cf.groups.iter().enumerate() {
            if g.src == src && g.dst == dst {
                cf.remaining[gi] = 0.0;
                hit = true;
            }
        }
        let done = cf.done();
        if hit {
            self.cache.invalidate(id);
        }
        done
    }

    /// Remove all finished coflows from the active table (and their
    /// allocation and Γ-cache entries). Returns their ids.
    pub fn take_finished(&mut self) -> Vec<CoflowId> {
        let finished: Vec<CoflowId> =
            self.active.iter().filter(|c| c.done()).map(|c| c.id).collect();
        for id in &finished {
            self.alloc.rates.remove(id);
            self.cache.invalidate(*id);
        }
        self.active.retain(|c| !c.done());
        finished
    }

    /// Current total scheduled rate (Gbps) of a coflow.
    pub fn coflow_rate(&self, id: CoflowId) -> f64 {
        self.alloc.rates.get(&id).map(|g| g.iter().flatten().sum()).unwrap_or(0.0)
    }

    /// A coflow's full rate matrix from the last round, if any.
    pub fn coflow_rates(&self, id: CoflowId) -> Option<crate::scheduler::CoflowRates> {
        self.alloc.rates.get(&id).cloned()
    }

    /// Drain the policy's instrumentation counters.
    pub fn take_stats(&mut self) -> RoundStats {
        self.policy.take_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coflow::{Coflow, Flow, GB};
    use crate::net::topologies;
    use crate::scheduler::terra::{TerraConfig, TerraPolicy};

    fn engine(cold: bool) -> RoundEngine {
        let wan = topologies::fig1a();
        let policy = TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() });
        RoundEngine::new(
            wan,
            Box::new(policy),
            EngineConfig { check_feasibility: true, cold, ..Default::default() },
        )
    }

    fn coflow(id: u64, s: usize, d: usize, gb: f64) -> CoflowState {
        CoflowState::from_coflow(&Coflow::new(
            id,
            vec![Flow { id: 0, src_dc: s, dst_dc: d, volume: gb * GB }],
        ))
    }

    #[test]
    fn round_drain_finish_lifecycle() {
        let mut e = engine(false);
        e.insert(coflow(1, 0, 1, 5.0)); // 40 Gbit, 20 Gbps across 2 paths
        e.round(0.0, RoundTrigger::CoflowArrival);
        assert_eq!(e.rounds(), 1);
        let r = e.coflow_rate(1);
        assert!((r - 20.0).abs() < 0.5, "rate={r}");
        let done_at = e.next_completion(0.0).unwrap();
        assert!((done_at - 2.0).abs() < 0.1, "t={done_at}");
        let moved = e.drain(done_at, 0.0);
        assert!((moved - 40.0).abs() < 1e-6, "moved={moved}");
        assert_eq!(e.take_finished(), vec![1]);
        assert!(e.is_empty());
        assert_eq!(e.coflow_rate(1), 0.0);
    }

    #[test]
    fn sub_rho_clamps_without_epoch_bump() {
        let mut e = engine(false);
        e.insert(coflow(1, 0, 1, 5.0));
        e.round(0.0, RoundTrigger::CoflowArrival);
        let epoch0 = e.epoch();
        // 10% drop < rho=0.25: clamp, same epoch, no round required.
        let reaction = e.handle_wan_event(&LinkEvent::SetBandwidth(0, 1, 9.0));
        assert_eq!(reaction, WanReaction::Clamped);
        assert!(reaction.trigger().is_none());
        assert_eq!(e.epoch(), epoch0);
        // Clamped allocation is feasible on the shrunk WAN.
        let net = NetView { wan: e.wan(), paths: e.paths() };
        let usage = e.alloc().edge_usage(e.active(), &net, e.wan().num_edges());
        for (u, c) in usage.iter().zip(e.wan().capacities()) {
            assert!(*u <= c + 1e-6, "{u} > {c}");
        }
    }

    #[test]
    fn accumulated_sub_rho_drift_reoptimizes() {
        let mut e = engine(false);
        e.insert(coflow(1, 0, 1, 5.0));
        e.round(0.0, RoundTrigger::CoflowArrival);
        let epoch0 = e.epoch();
        // A 20% drop is sub-ρ: clamp, no round, cache stays warm...
        assert_eq!(e.handle_wan_event(&LinkEvent::SetBandwidth(0, 1, 8.0)), WanReaction::Clamped);
        assert_eq!(e.epoch(), epoch0, "single sub-ρ event must keep the cache");
        // ...but a second 20% step has slid the edge 36% from the epoch
        // snapshot: the accumulated drift is a qualifying event — epoch
        // bump AND a re-optimization round.
        let reaction = e.handle_wan_event(&LinkEvent::SetBandwidth(0, 1, 6.4));
        assert_eq!(reaction, WanReaction::Reoptimize, "accumulated drift must trigger a round");
        assert!(reaction.trigger().is_some());
        assert_eq!(e.epoch(), epoch0 + 1, "cumulative drift must invalidate the Γ-cache");
        // The snapshot re-anchors at the bump: the next small step is sub-ρ
        // again relative to the new baseline.
        e.round(0.1, reaction.trigger().unwrap());
        assert_eq!(e.handle_wan_event(&LinkEvent::SetBandwidth(0, 1, 6.0)), WanReaction::Clamped);
        assert_eq!(e.epoch(), epoch0 + 1);
    }

    #[test]
    fn super_rho_and_structural_bump_epoch() {
        let mut e = engine(false);
        e.insert(coflow(1, 0, 1, 5.0));
        e.round(0.0, RoundTrigger::CoflowArrival);
        let epoch0 = e.epoch();
        let reaction = e.handle_wan_event(&LinkEvent::SetBandwidth(0, 1, 4.0)); // 60% drop
        assert_eq!(reaction, WanReaction::Reoptimize);
        assert_eq!(e.epoch(), epoch0 + 1);
        e.round(0.0, reaction.trigger().unwrap());
        let reaction = e.handle_wan_event(&LinkEvent::Fail(0, 1));
        assert_eq!(reaction, WanReaction::Structural);
        assert_eq!(e.epoch(), epoch0 + 2);
        e.round(0.0, RoundTrigger::WanChange);
        // Direct path is gone: everything routes via C at 10 Gbps.
        let r = e.coflow_rate(1);
        assert!((r - 10.0).abs() < 0.5, "rate={r}");
    }

    #[test]
    fn gamma_cache_cuts_lp_solves() {
        let run = |cold: bool| -> (usize, usize) {
            let mut e = engine(cold);
            for i in 0..6 {
                e.insert(coflow(i + 1, (i as usize) % 3, ((i as usize) + 1) % 3, 50.0));
            }
            e.round(0.0, RoundTrigger::CoflowArrival);
            let first = e.take_stats().lp_solves;
            // Re-rounds with no qualifying WAN change in between.
            e.drain(0.1, 0.0);
            e.round(0.1, RoundTrigger::CoflowArrival);
            e.drain(0.1, 0.0);
            e.round(0.2, RoundTrigger::CoflowArrival);
            (first, e.take_stats().lp_solves)
        };
        let (cold_first, cold_rest) = run(true);
        let (warm_first, warm_rest) = run(false);
        // First rounds cost the same (cache is empty).
        assert_eq!(cold_first, warm_first);
        // Cached re-rounds skip the per-coflow ordering solves.
        assert!(
            warm_rest < cold_rest,
            "cached rounds should solve fewer LPs: {warm_rest} vs {cold_rest}"
        );
    }

    #[test]
    fn drain_emptying_a_group_invalidates_gamma() {
        let mut e = engine(false);
        // Wildly unbalanced groups so one empties long before the other.
        e.insert(CoflowState::from_coflow(&Coflow::new(
            9,
            vec![
                Flow { id: 0, src_dc: 0, dst_dc: 1, volume: 4.0 },
                Flow { id: 1, src_dc: 2, dst_dc: 1, volume: 400.0 },
            ],
        )));
        e.round(0.0, RoundTrigger::CoflowArrival);
        e.take_stats();
        // No drain: the cached Γ is still valid.
        e.round(0.1, RoundTrigger::CoflowArrival);
        assert_eq!(e.take_stats().gamma_cache_hits, 1);
        // Drain to the first group completion: the coflow's shape changed
        // discontinuously, so the next round must re-solve Γ.
        let t = e.next_completion(0.0).expect("something is draining");
        e.drain(t, 0.0);
        e.round(t, RoundTrigger::FlowGroupFinish);
        assert_eq!(
            e.take_stats().gamma_cache_hits,
            0,
            "group completion via drain must invalidate the Γ entry"
        );
    }

    #[test]
    fn complete_group_marks_done_and_dirty() {
        let mut e = engine(false);
        let st = CoflowState::from_coflow(&Coflow::new(
            7,
            vec![
                Flow { id: 0, src_dc: 0, dst_dc: 1, volume: 8.0 },
                Flow { id: 1, src_dc: 2, dst_dc: 1, volume: 8.0 },
            ],
        ));
        e.insert(st);
        e.round(0.0, RoundTrigger::CoflowArrival);
        assert!(!e.complete_group(7, 0, 1), "one group left");
        assert!(e.get(7).unwrap().remaining[0] <= 1e-12);
        assert!(e.complete_group(7, 2, 1), "now done");
        assert_eq!(e.take_finished(), vec![7]);
    }
}
