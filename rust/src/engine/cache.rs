//! The Γ-cache: memoized standalone minimum-CCT solves keyed by
//! `(coflow id, WAN capacity epoch)`.
//!
//! Terra's round begins by computing every active coflow's standalone Γ (its
//! min CCT via Optimization (1)) just to *order* coflows — one LP per active
//! coflow per round, the dominant per-round cost at scale (§6.6, Fig 13–14).
//! Γ only depends on the coflow's FlowGroup shape and the WAN capacities, so
//! across rounds it changes in exactly three ways:
//!
//! 1. **WAN capacity epoch bump** — a qualifying WAN event (structural
//!    change, a fluctuation ≥ ρ, or accumulated sub-ρ drift reaching ρ)
//!    changed the capacities every solve was made against. Entries from
//!    older epochs are *lazily* invalid: the epoch is stored per entry and
//!    checked on lookup.
//! 2. **Dirty coflow** — a FlowGroup completed, the coflow was updated
//!    (`updateCoflow`, §5.2), or it finished: its group shape changed
//!    discontinuously, so its entry is dropped eagerly
//!    ([`GammaCache::invalidate`]).
//! 3. **Continuous drain** — remaining volume shrinks between rounds. Under
//!    equal-progress allocations every group of a coflow drains
//!    proportionally, and Optimization (1) is positively homogeneous in the
//!    volumes: Γ(c·rem) = c·Γ(rem). Lookups therefore rescale the cached Γ
//!    by `total_remaining_now / total_remaining_at_solve` instead of
//!    invalidating. (Work-conservation bonuses bend exact proportionality;
//!    the rescaled Γ is only used for SRTF *ordering*, where the small error
//!    is harmless — allocations themselves are always re-solved.)

use crate::coflow::CoflowId;
use std::collections::HashMap;

#[derive(Clone, Debug)]
struct Entry {
    epoch: u64,
    /// Total remaining volume (Gbit) at solve time, for the homogeneity
    /// rescale on lookup.
    total_remaining: f64,
    gamma: f64,
}

/// Cache of standalone Γ values, owned by the
/// [`crate::engine::RoundEngine`] and handed to cache-aware policies via
/// [`crate::scheduler::RoundCtx`].
#[derive(Clone, Debug, Default)]
pub struct GammaCache {
    epoch: u64,
    entries: HashMap<CoflowId, Entry>,
}

impl GammaCache {
    pub fn new() -> GammaCache {
        GammaCache::default()
    }

    /// Current WAN capacity epoch. Entries stored under older epochs never
    /// hit.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Invalidate every entry by advancing the epoch (qualifying WAN
    /// event). O(1): staleness is checked lazily on lookup.
    pub fn bump_epoch(&mut self) {
        self.epoch += 1;
    }

    /// Cached Γ for `id` rescaled to `total_remaining`, or `None` on a miss
    /// (absent, stale epoch, or a degenerate entry).
    pub fn lookup(&self, id: CoflowId, total_remaining: f64) -> Option<f64> {
        let e = self.entries.get(&id)?;
        if e.epoch != self.epoch {
            return None;
        }
        if !e.gamma.is_finite() {
            // Infeasible stays infeasible within an epoch (same capacities,
            // same paths): reuse without rescaling.
            return Some(e.gamma);
        }
        if e.total_remaining <= 1e-9 || total_remaining <= 0.0 {
            return None;
        }
        Some(e.gamma * total_remaining / e.total_remaining)
    }

    /// Record a fresh solve under the current epoch.
    pub fn store(&mut self, id: CoflowId, total_remaining: f64, gamma: f64) {
        self.entries.insert(id, Entry { epoch: self.epoch, total_remaining, gamma });
    }

    /// Drop one coflow's entry (FlowGroup completion, update, finish).
    pub fn invalidate(&mut self, id: CoflowId) {
        self.entries.remove(&id);
    }

    /// Drop everything (e.g. the path set changed structurally).
    pub fn clear(&mut self) {
        self.entries.clear();
    }

    /// Number of live (current-epoch) entries.
    pub fn len(&self) -> usize {
        self.entries.values().filter(|e| e.epoch == self.epoch).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_rescales_by_remaining() {
        let mut c = GammaCache::new();
        c.store(1, 100.0, 5.0);
        assert_eq!(c.lookup(1, 100.0), Some(5.0));
        // Half the volume remains => half the Γ (homogeneity).
        assert_eq!(c.lookup(1, 50.0), Some(2.5));
        assert_eq!(c.lookup(2, 50.0), None);
    }

    #[test]
    fn epoch_bump_invalidates_lazily() {
        let mut c = GammaCache::new();
        c.store(1, 100.0, 5.0);
        assert_eq!(c.len(), 1);
        c.bump_epoch();
        assert_eq!(c.lookup(1, 100.0), None);
        assert!(c.is_empty());
        // Re-store under the new epoch hits again.
        c.store(1, 80.0, 6.0);
        assert_eq!(c.lookup(1, 40.0), Some(3.0));
    }

    #[test]
    fn invalidate_drops_single_entry() {
        let mut c = GammaCache::new();
        c.store(1, 10.0, 1.0);
        c.store(2, 10.0, 2.0);
        c.invalidate(1);
        assert_eq!(c.lookup(1, 10.0), None);
        assert_eq!(c.lookup(2, 10.0), Some(2.0));
    }

    #[test]
    fn infinite_gamma_reused_within_epoch() {
        let mut c = GammaCache::new();
        c.store(1, 10.0, f64::INFINITY);
        assert_eq!(c.lookup(1, 5.0), Some(f64::INFINITY));
        c.bump_epoch();
        assert_eq!(c.lookup(1, 5.0), None);
    }
}
