//! The Γ-cache: memoized standalone minimum-CCT solves keyed by
//! `(coflow id, WAN capacity epoch)`.
//!
//! Terra's round begins by computing every active coflow's standalone Γ (its
//! min CCT via Optimization (1)) just to *order* coflows — one LP per active
//! coflow per round, the dominant per-round cost at scale (§6.6, Fig 13–14).
//! Γ only depends on the coflow's FlowGroup shape and the WAN capacities, so
//! across rounds it changes in exactly three ways:
//!
//! 1. **WAN capacity epoch bump** — a qualifying WAN event (structural
//!    change, a fluctuation ≥ ρ, or accumulated sub-ρ drift reaching ρ)
//!    changed the capacities every solve was made against. Entries from
//!    older epochs are *lazily* invalid: the epoch is stored per entry and
//!    checked on lookup.
//! 2. **Dirty coflow** — a FlowGroup completed, the coflow was updated
//!    (`updateCoflow`, §5.2), or it finished: its group shape changed
//!    discontinuously, so its entry is dropped eagerly
//!    ([`GammaCache::invalidate`]).
//! 3. **Continuous drain** — remaining volume shrinks between rounds. Under
//!    equal-progress allocations every group of a coflow drains
//!    proportionally, and Optimization (1) is positively homogeneous in the
//!    volumes: Γ(c·rem) = c·Γ(rem). Lookups therefore rescale the cached Γ
//!    by `total_remaining_now / total_remaining_at_solve` instead of
//!    invalidating. (Work-conservation bonuses bend exact proportionality;
//!    the rescaled Γ is only used for SRTF *ordering*, where the small error
//!    is harmless — allocations themselves are always re-solved.)

use crate::coflow::CoflowId;
use crate::net::topology::EdgeId;
use std::collections::{HashMap, HashSet};

#[derive(Clone, Debug)]
struct Entry {
    epoch: u64,
    /// Total remaining volume (Gbit) at solve time, for the homogeneity
    /// rescale on lookup.
    total_remaining: f64,
    gamma: f64,
}

/// Cache of standalone Γ values, owned by the
/// [`crate::engine::RoundEngine`] and handed to cache-aware policies via
/// [`crate::scheduler::RoundCtx`].
#[derive(Clone, Debug, Default)]
pub struct GammaCache {
    epoch: u64,
    entries: HashMap<CoflowId, Entry>,
}

impl GammaCache {
    pub fn new() -> GammaCache {
        GammaCache::default()
    }

    /// Current WAN capacity epoch. Entries stored under older epochs never
    /// hit.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Invalidate every entry by advancing the epoch (qualifying WAN
    /// event). O(1): staleness is checked lazily on lookup.
    pub fn bump_epoch(&mut self) {
        self.epoch += 1;
    }

    /// Cached Γ for `id` rescaled to `total_remaining`, or `None` on a miss
    /// (absent, stale epoch, or a degenerate entry).
    pub fn lookup(&self, id: CoflowId, total_remaining: f64) -> Option<f64> {
        let e = self.entries.get(&id)?;
        if e.epoch != self.epoch {
            return None;
        }
        if !e.gamma.is_finite() {
            // Infeasible stays infeasible within an epoch (same capacities,
            // same paths): reuse without rescaling.
            return Some(e.gamma);
        }
        if e.total_remaining <= 1e-9 || total_remaining <= 0.0 {
            return None;
        }
        Some(e.gamma * total_remaining / e.total_remaining)
    }

    /// Record a fresh solve under the current epoch.
    pub fn store(&mut self, id: CoflowId, total_remaining: f64, gamma: f64) {
        self.entries.insert(id, Entry { epoch: self.epoch, total_remaining, gamma });
    }

    /// Drop one coflow's entry (FlowGroup completion, update, finish).
    pub fn invalidate(&mut self, id: CoflowId) {
        self.entries.remove(&id);
    }

    /// Move the entries for `ids` into a standalone shard at the same
    /// epoch. Coflow ids partition across edge-connected components, so
    /// handing each parallel component solve its members' shard (and
    /// [`GammaCache::absorb`]-ing it back) is observationally identical to
    /// sequential solves against the whole cache: a component's solves only
    /// ever look up or store its own members' ids.
    pub fn extract(&mut self, ids: &[CoflowId]) -> GammaCache {
        let mut shard = GammaCache { epoch: self.epoch, entries: HashMap::new() };
        for id in ids {
            if let Some(e) = self.entries.remove(id) {
                shard.entries.insert(*id, e);
            }
        }
        shard
    }

    /// Merge a shard (from [`GammaCache::extract`], updated by a component
    /// solve) back in.
    pub fn absorb(&mut self, shard: GammaCache) {
        debug_assert_eq!(shard.epoch, self.epoch, "shard from a different epoch");
        self.entries.extend(shard.entries);
    }

    /// [`GammaCache::extract`] into a caller-owned shard, reusing its map
    /// storage. The shard is cleared (capacity preserved) and refilled, so
    /// steady-state rounds that recycle the same shard buffers do not
    /// allocate.
    pub fn extract_into(&mut self, ids: &[CoflowId], shard: &mut GammaCache) {
        shard.entries.clear();
        shard.epoch = self.epoch;
        for id in ids {
            if let Some(e) = self.entries.remove(id) {
                shard.entries.insert(*id, e);
            }
        }
    }

    /// [`GammaCache::absorb`] by draining — the shard keeps its map
    /// capacity for reuse next round.
    pub fn absorb_from(&mut self, shard: &mut GammaCache) {
        debug_assert_eq!(shard.epoch, self.epoch, "shard from a different epoch");
        for (id, e) in shard.entries.drain() {
            self.entries.insert(id, e);
        }
    }

    /// Take one coflow's entry out for migration to another engine shard.
    /// The entry travels opaquely (epoch included); under the lockstep
    /// epoch discipline every shard shares one epoch sequence, so the entry
    /// is exactly as (in)valid at the destination as it was here.
    pub fn export(&mut self, id: CoflowId) -> Option<GammaExport> {
        self.entries.remove(&id).map(|e| GammaExport {
            epoch: e.epoch,
            total_remaining: e.total_remaining,
            gamma: e.gamma,
        })
    }

    /// Install an entry exported from another shard.
    pub fn import(&mut self, id: CoflowId, e: GammaExport) {
        self.entries.insert(
            id,
            Entry { epoch: e.epoch, total_remaining: e.total_remaining, gamma: e.gamma },
        );
    }

    /// Drop everything (e.g. the path set changed structurally).
    pub fn clear(&mut self) {
        self.entries.clear();
    }

    /// Number of live (current-epoch) entries.
    pub fn len(&self) -> usize {
        self.entries.values().filter(|e| e.epoch == self.epoch).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A Γ-cache entry in transit between engine shards (coflow ownership
/// migration). Opaque outside this module.
#[derive(Clone, Debug)]
pub struct GammaExport {
    epoch: u64,
    total_remaining: f64,
    gamma: f64,
}

/// Validity cache for per-component allocations — the component-level
/// extension of the Γ-cache's epoch/dirty machinery.
///
/// The [`crate::engine::RoundEngine`] partitions every round into
/// edge-connected components ([`crate::lp::decompose`]) and re-solves only
/// the components something actually touched; every other component's
/// allocation is carried forward from the live [`Allocation`] unchanged
/// (rates are constant between rounds anyway, and sub-ρ clamping keeps the
/// live allocation feasible). This cache stores only validity metadata — no
/// rates — keyed by the component's sorted member ids. A component's
/// previous solve is reusable iff:
///
/// 1. its member set is unchanged (arrivals/departures change the key, so
///    they miss structurally),
/// 2. no member is **dirty** — no group completion or `updateCoflow` since
///    the solve (and a freshly inserted coflow is always dirty, which also
///    covers finish-then-revive reusing an id),
/// 3. no **qualifying WAN capacity change** (fluctuation ≥ ρ or promoted
///    accumulated drift) touched one of the component's edges since the
///    solve — tracked as a per-edge monotone tick; structural events touch
///    every edge and drop all entries (the path sets changed under the
///    decomposition).
///
/// Entries are mark-and-swept: anything not reused or re-solved in a round
/// (i.e. whose component no longer exists) is dropped at round end.
#[derive(Clone, Debug, Default)]
pub struct ComponentCache {
    /// Monotone change counter; bumped per qualifying capacity change.
    tick: u64,
    /// Tick of the last qualifying change per edge.
    edge_ticks: Vec<u64>,
    /// Coflows whose shape changed discontinuously since their component
    /// was last solved.
    dirty: HashSet<CoflowId>,
    /// Solved components keyed by sorted member ids.
    entries: HashMap<Vec<CoflowId>, CompEntry>,
    /// Current round generation (mark-and-sweep eviction).
    gen: u64,
}

#[derive(Clone, Debug)]
struct CompEntry {
    solve_tick: u64,
    gen: u64,
}

impl ComponentCache {
    pub fn new(num_edges: usize) -> ComponentCache {
        ComponentCache { edge_ticks: vec![0; num_edges], ..Default::default() }
    }

    /// A qualifying capacity change on one edge: components containing it
    /// must re-solve.
    pub fn touch_edge(&mut self, e: EdgeId) {
        self.tick += 1;
        if let Some(t) = self.edge_ticks.get_mut(e) {
            *t = self.tick;
        }
    }

    /// Structural change: paths (and thus the decomposition itself) are
    /// stale — everything re-solves.
    pub fn touch_all(&mut self) {
        self.tick += 1;
        for t in &mut self.edge_ticks {
            *t = self.tick;
        }
        self.entries.clear();
    }

    /// Record a discontinuous per-coflow change (arrival, group completion,
    /// update): the coflow's component must re-solve.
    pub fn mark_dirty(&mut self, id: CoflowId) {
        self.dirty.insert(id);
    }

    /// Drop a departed coflow's dirty flag (its old components' entries are
    /// swept by key mismatch at the next round).
    pub fn forget(&mut self, id: CoflowId) {
        self.dirty.remove(&id);
    }

    /// Is this coflow's discontinuous-change flag set? Used when migrating
    /// a coflow between engine shards: the flag must travel with it so the
    /// destination re-solves exactly when a single-shard engine would.
    pub fn is_dirty(&self, id: CoflowId) -> bool {
        self.dirty.contains(&id)
    }

    /// Start a round's mark-and-sweep generation.
    pub fn begin_round(&mut self) {
        self.gen += 1;
    }

    /// Is the previous allocation of the component with these **sorted**
    /// members (touching `edges`) still valid?
    pub fn is_fresh(&self, members: &[CoflowId], edges: &[EdgeId]) -> bool {
        let Some(entry) = self.entries.get(members) else { return false };
        members.iter().all(|id| !self.dirty.contains(id))
            && edges
                .iter()
                .all(|&e| self.edge_ticks.get(e).copied().unwrap_or(u64::MAX) <= entry.solve_tick)
    }

    /// Keep a fresh (reused) entry alive through this round's sweep.
    pub fn refresh(&mut self, members: &[CoflowId]) {
        let gen = self.gen;
        if let Some(e) = self.entries.get_mut(members) {
            e.gen = gen;
        }
    }

    /// Record that this component was (re)solved in the current round.
    pub fn record_solved(&mut self, members: Vec<CoflowId>) {
        for id in &members {
            self.dirty.remove(id);
        }
        let (solve_tick, gen) = (self.tick, self.gen);
        self.entries.insert(members, CompEntry { solve_tick, gen });
    }

    /// Sweep entries for components that no longer exist.
    pub fn end_round(&mut self) {
        let gen = self.gen;
        self.entries.retain(|_, e| e.gen == gen);
    }

    /// Number of live component entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Drop everything (fresh start; keeps the edge-tick clock monotone).
    pub fn clear(&mut self) {
        self.entries.clear();
        self.dirty.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_rescales_by_remaining() {
        let mut c = GammaCache::new();
        c.store(1, 100.0, 5.0);
        assert_eq!(c.lookup(1, 100.0), Some(5.0));
        // Half the volume remains => half the Γ (homogeneity).
        assert_eq!(c.lookup(1, 50.0), Some(2.5));
        assert_eq!(c.lookup(2, 50.0), None);
    }

    #[test]
    fn epoch_bump_invalidates_lazily() {
        let mut c = GammaCache::new();
        c.store(1, 100.0, 5.0);
        assert_eq!(c.len(), 1);
        c.bump_epoch();
        assert_eq!(c.lookup(1, 100.0), None);
        assert!(c.is_empty());
        // Re-store under the new epoch hits again.
        c.store(1, 80.0, 6.0);
        assert_eq!(c.lookup(1, 40.0), Some(3.0));
    }

    #[test]
    fn invalidate_drops_single_entry() {
        let mut c = GammaCache::new();
        c.store(1, 10.0, 1.0);
        c.store(2, 10.0, 2.0);
        c.invalidate(1);
        assert_eq!(c.lookup(1, 10.0), None);
        assert_eq!(c.lookup(2, 10.0), Some(2.0));
    }

    #[test]
    fn extract_and_absorb_roundtrip() {
        let mut c = GammaCache::new();
        c.store(1, 10.0, 1.0);
        c.store(2, 10.0, 2.0);
        c.store(3, 10.0, 3.0);
        let mut shard = c.extract(&[1, 3]);
        assert_eq!(shard.lookup(1, 10.0), Some(1.0));
        assert_eq!(shard.lookup(3, 10.0), Some(3.0));
        assert_eq!(c.lookup(1, 10.0), None, "extracted entries leave the main cache");
        assert_eq!(c.lookup(2, 10.0), Some(2.0));
        shard.store(4, 8.0, 4.0); // a solve inside the component
        shard.invalidate(1);
        c.absorb(shard);
        assert_eq!(c.lookup(1, 10.0), None);
        assert_eq!(c.lookup(3, 10.0), Some(3.0));
        assert_eq!(c.lookup(4, 8.0), Some(4.0));
    }

    /// Buffer-reusing extract/absorb behave exactly like the allocating
    /// pair, and export/import round-trips an entry across "shards".
    #[test]
    fn extract_into_and_export_roundtrip() {
        let mut c = GammaCache::new();
        c.store(1, 10.0, 1.0);
        c.store(2, 10.0, 2.0);
        let mut shard = GammaCache::new();
        c.extract_into(&[1], &mut shard);
        assert_eq!(shard.lookup(1, 10.0), Some(1.0));
        assert_eq!(c.lookup(1, 10.0), None);
        shard.store(1, 5.0, 0.5);
        c.absorb_from(&mut shard);
        assert!(shard.entries.is_empty(), "absorb_from drains the shard");
        assert_eq!(c.lookup(1, 5.0), Some(0.5));
        // Reuse the same shard buffer for a different member set.
        c.extract_into(&[2], &mut shard);
        assert_eq!(shard.lookup(2, 10.0), Some(2.0));
        assert_eq!(shard.lookup(1, 5.0), None, "stale entries cleared on reuse");
        c.absorb_from(&mut shard);

        let mut other = GammaCache::new();
        let e = c.export(2).expect("entry present");
        assert_eq!(c.lookup(2, 10.0), None, "export removes the entry");
        other.import(2, e);
        assert_eq!(other.lookup(2, 10.0), Some(2.0));
        assert!(c.export(99).is_none());
    }

    #[test]
    fn infinite_gamma_reused_within_epoch() {
        let mut c = GammaCache::new();
        c.store(1, 10.0, f64::INFINITY);
        assert_eq!(c.lookup(1, 5.0), Some(f64::INFINITY));
        c.bump_epoch();
        assert_eq!(c.lookup(1, 5.0), None);
    }

    /// One simulated round over the component cache: solve, then verify the
    /// four invalidation triggers (member change, dirty member, touched
    /// edge, structural touch-all) each force a re-solve.
    #[test]
    fn component_cache_invalidation_triggers() {
        let mut c = ComponentCache::new(4);
        c.begin_round();
        assert!(!c.is_fresh(&[1, 2], &[0, 1]), "nothing solved yet");
        c.record_solved(vec![1, 2]);
        c.record_solved(vec![3]);
        c.end_round();
        assert_eq!(c.len(), 2);
        assert!(c.is_fresh(&[1, 2], &[0, 1]));
        assert!(c.is_fresh(&[3], &[2]));

        // Member-set change misses structurally.
        assert!(!c.is_fresh(&[1, 2, 4], &[0, 1]));
        assert!(!c.is_fresh(&[1], &[0]));

        // Dirty member (group completion / update / re-insert).
        c.mark_dirty(2);
        assert!(c.is_dirty(2));
        assert!(!c.is_dirty(1));
        assert!(!c.is_fresh(&[1, 2], &[0, 1]));
        assert!(c.is_fresh(&[3], &[2]), "other components unaffected");
        c.begin_round();
        c.record_solved(vec![1, 2]); // re-solve clears the dirty flag
        c.refresh(&[3]);
        c.end_round();
        assert!(c.is_fresh(&[1, 2], &[0, 1]));

        // Qualifying capacity change on one edge dirties only components
        // containing it.
        c.touch_edge(1);
        assert!(!c.is_fresh(&[1, 2], &[0, 1]));
        assert!(c.is_fresh(&[3], &[2]));

        // Structural: everything goes.
        c.touch_all();
        assert!(!c.is_fresh(&[3], &[2]));
        assert!(c.is_empty());
    }

    /// Entries not reused or re-solved in a round (departed components) are
    /// swept; out-of-range edge ids never validate.
    #[test]
    fn component_cache_sweeps_and_bounds() {
        let mut c = ComponentCache::new(2);
        c.begin_round();
        c.record_solved(vec![1]);
        c.record_solved(vec![2]);
        c.end_round();
        assert_eq!(c.len(), 2);
        c.begin_round();
        c.refresh(&[1]); // coflow 2 departed: its entry is not marked
        c.end_round();
        assert_eq!(c.len(), 1);
        assert!(c.is_fresh(&[1], &[0]));
        assert!(!c.is_fresh(&[1], &[7]), "unknown edge id must not validate");
        c.forget(2); // departed coflow's dirty flag cannot accumulate
    }
}
