//! **ShardedEngine**: the scale-out control plane. A thin routing
//! front-end over `shards` independent [`RoundEngine`]s, each owning a
//! disjoint set of WAN edges and every active coflow whose k-path edge set
//! falls inside it — plus a *spill* engine for coflows the router declines
//! to merge (two-level solve fallback).
//!
//! ## Ownership model
//!
//! Edges are claimed lazily: an arrival whose edge set touches no owned
//! edge lands on the least-loaded shard and claims its edges; an arrival
//! inside one shard's territory joins that shard. An arrival whose edges
//! span *several* shards merges them: the shard owning most of the
//! arrival's edges becomes primary, and every coflow on the other owning
//! shards that is (transitively) edge-connected to the arrival migrates
//! there — state, live rates, Γ-cache entry, and dirty flag travel
//! together ([`RoundEngine::extract_coflow`] / `adopt_coflow`), so the
//! receiving engine behaves exactly as if the coflow had always lived
//! there. When one arrival would migrate more than
//! `EngineConfig::migrate_cap` coflows, it is **parked** in the spill
//! engine instead and served by a greedy residual solve (level 2) against
//! whatever capacity the shard solves (level 1) left behind.
//!
//! ## Pipeline phases
//!
//! [`ShardedEngine::round_with`] runs every shard's partition→solve round
//! concurrently on scoped threads and invokes the caller's enforcement
//! callback *per shard as it finishes* — solve and enforcement fan-out
//! overlap across shards instead of barriering globally. The spill solve
//! runs last (it consumes the shards' residual capacity).
//!
//! ## Determinism
//!
//! `shards = 1` (the default) delegates every call verbatim to the single
//! inner engine — bit-identical to the unsharded control plane by
//! construction (and property-pinned by `prop_sharded`). For `shards = N`:
//! every WAN event, telemetry observation, and belief refresh is broadcast
//! to all engines, so all WAN views, path sets, estimators, and capacity
//! epochs stay in lockstep; each shard's active table is kept a
//! subsequence of the global arrival order (adoption positions are
//! computed from per-coflow arrival sequence numbers), so the stable
//! tie-breaks inside the policy see the same relative order a single
//! engine would; and since components never span shards (the router merges
//! or parks), the union of the per-shard partitions *is* the global
//! partition — allocations and solve counts match the single-shard engine
//! exactly.

use super::{
    collect_throttle_factors, EngineConfig, MigratedCoflow, RoundEngine, WanReaction,
};
use crate::coflow::CoflowId;
use crate::lp;
use crate::lp::decompose;
use crate::net::paths::PathSet;
use crate::net::telemetry::{CapacityEstimator, TelemetryConfig};
use crate::net::{EdgeId, LinkEvent, Wan};
use crate::scheduler::{
    build_instance, expand_rates, CoflowRates, CoflowState, NetView, Policy, RoundStats,
    RoundTrigger,
};
use std::collections::{HashMap, HashSet};

/// Owner sentinel for parked (spill-engine) coflows.
const SPILL: u32 = u32::MAX;

/// Which directions of a down site's incident edges are lost. A dead agent
/// loses both ([`SitePartition::Full`]); an asymmetric partition can lose
/// only the edges *into* the site (receivers unreachable, senders fine) or
/// only the edges *out of* it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SitePartition {
    /// All incident directed edges down (agent dead).
    Full,
    /// Only edges into the site down: transfers *to* it stall.
    Inbound,
    /// Only edges out of the site down: transfers *from* it stall.
    Outbound,
}

#[derive(Clone, Copy, Debug)]
struct Owner {
    /// Owning shard index, or [`SPILL`].
    shard: u32,
    /// Global arrival sequence number — the position every shard-local
    /// active table is kept consistent with.
    seq: u64,
}

/// The sharded control-plane front-end. See the module docs.
pub struct ShardedEngine {
    shards: Vec<RoundEngine>,
    /// Parked cross-shard coflows (present only when `shards > 1`). Its
    /// `round()` is never called: rates are written by the two-level
    /// residual solve; drain / completion / finish mechanics are the
    /// engine's own.
    spill: Option<RoundEngine>,
    /// Edge → owning shard, claimed lazily by arrivals.
    edge_owner: Vec<Option<u32>>,
    owners: HashMap<CoflowId, Owner>,
    next_seq: u64,
    migrate_cap: usize,
    rounds: usize,
    /// Front-end instrumentation (migration counts, spill LP solves),
    /// merged into [`ShardedEngine::take_stats`].
    front_stats: RoundStats,
    /// Sites currently declared down by the liveness machinery, with the
    /// direction(s) of their incident edges that are lost.
    down_sites: HashMap<usize, SitePartition>,
    /// Coflows parked because a down site blocks one of their unfinished
    /// groups: `(arrival seq, extracted state)`. Their achieved progress
    /// (`state.remaining`) is preserved verbatim; they receive no rates
    /// (excluded from [`ShardedEngine::visit_allocations`], so enforcement
    /// revokes their agent entries) and re-admit in ascending id order on
    /// [`ShardedEngine::set_site_up`]. Distinct from the spill engine: it
    /// exists at every shard count (including 1) and its members are
    /// *blocked*, not merely unmergeable.
    parked_down: Vec<(u64, MigratedCoflow)>,
    /// Coflows that completed *while parked* (an agent's replayed
    /// `group_done` can land for a transfer that finished just before the
    /// site died): `(seq, id)`, drained by [`ShardedEngine::take_finished`].
    parked_finished: Vec<(u64, CoflowId)>,
}

impl ShardedEngine {
    /// Build a front-end around `cfg.shards` engine shards; path sets are
    /// computed for the policy's k.
    pub fn new(wan: Wan, policy: Box<dyn Policy>, cfg: EngineConfig) -> ShardedEngine {
        let k = policy.k_paths();
        ShardedEngine::with_k(wan, policy, cfg, k)
    }

    /// [`ShardedEngine::new`] with an explicit path count. Sharding needs
    /// a forkable policy (each shard and the spill engine drive their own
    /// instance); a non-forkable policy falls back to one shard.
    pub fn with_k(
        wan: Wan,
        policy: Box<dyn Policy>,
        cfg: EngineConfig,
        k: usize,
    ) -> ShardedEngine {
        let want = cfg.shards.max(1);
        let mut forks: Vec<Box<dyn Policy>> = Vec::new();
        let mut spill_policy: Option<Box<dyn Policy>> = None;
        if want > 1 {
            for _ in 1..want {
                match policy.fork() {
                    Some(f) => forks.push(f),
                    None => break,
                }
            }
            if forks.len() == want - 1 {
                spill_policy = policy.fork();
            }
            if spill_policy.is_none() {
                log::warn!(
                    "policy {} is not forkable; falling back to shards=1",
                    policy.name()
                );
                forks.clear();
            }
        }
        // Split the intra-round worker budget across the concurrent shard
        // rounds (workers never change results — PR 4's invariant).
        let n = forks.len() + 1;
        let migrate_cap = cfg.migrate_cap;
        let shard_cfg = EngineConfig {
            workers: if n > 1 { (cfg.workers / n).max(1) } else { cfg.workers },
            shards: 1,
            ..cfg
        };
        let num_edges = wan.num_edges();
        let spill =
            spill_policy.map(|p| RoundEngine::with_k(wan.clone(), p, shard_cfg.clone(), k));
        let mut shards = Vec::with_capacity(n);
        for f in forks {
            shards.push(RoundEngine::with_k(wan.clone(), f, shard_cfg.clone(), k));
        }
        shards.insert(0, RoundEngine::with_k(wan, policy, shard_cfg, k));
        ShardedEngine {
            shards,
            spill,
            edge_owner: vec![None; num_edges],
            owners: HashMap::new(),
            next_seq: 0,
            migrate_cap,
            rounds: 0,
            front_stats: RoundStats::default(),
            down_sites: HashMap::new(),
            parked_down: Vec::new(),
            parked_finished: Vec::new(),
        }
    }

    fn sharded(&self) -> bool {
        self.shards.len() > 1
    }

    /// All engines holding coflows: the shards, then the spill engine.
    fn engines(&self) -> impl Iterator<Item = &RoundEngine> {
        self.shards.iter().chain(self.spill.as_ref())
    }

    fn engines_mut(&mut self) -> impl Iterator<Item = &mut RoundEngine> {
        self.shards.iter_mut().chain(self.spill.as_mut())
    }

    /// The engine owning coflow `id`, if any.
    fn engine_of(&self, id: CoflowId) -> Option<&RoundEngine> {
        if !self.sharded() {
            return self.shards.first();
        }
        let o = self.owners.get(&id)?;
        if o.shard == SPILL {
            self.spill.as_ref()
        } else {
            self.shards.get(o.shard as usize)
        }
    }

    fn engine_of_mut(&mut self, id: CoflowId) -> Option<&mut RoundEngine> {
        if !self.sharded() {
            return self.shards.first_mut();
        }
        let o = *self.owners.get(&id)?;
        if o.shard == SPILL {
            self.spill.as_mut()
        } else {
            self.shards.get_mut(o.shard as usize)
        }
    }

    /// A coflow's candidate edge set: the union of its unfinished groups'
    /// k-truncated path edges (the same set the decomposed round scans).
    fn coflow_edges(&self, cf: &CoflowState) -> Vec<EdgeId> {
        let eng = &self.shards[0];
        let mut es: Vec<EdgeId> = Vec::new();
        for (g, &rem) in cf.groups.iter().zip(&cf.remaining) {
            if rem <= 1e-9 {
                continue;
            }
            for p in eng.paths.get(g.src, g.dst).iter().take(eng.k) {
                es.extend_from_slice(&p.edges);
            }
        }
        es.sort_unstable();
        es.dedup();
        es
    }

    fn least_loaded(&self) -> usize {
        let mut best = 0;
        for (i, s) in self.shards.iter().enumerate() {
            if s.active.len() < self.shards[best].active.len() {
                best = i;
            }
        }
        best
    }

    /// Insertion index keeping `shard`'s active table sorted by global
    /// arrival sequence. Fresh arrivals carry the maximum sequence number,
    /// so the common case is an O(1) append; the linear scan only runs for
    /// mid-table migrations.
    fn adopt_position(&self, shard: usize, seq: u64) -> usize {
        let active = &self.shards[shard].active;
        match active.last() {
            None => return 0,
            Some(c) if self.owners.get(&c.id).is_some_and(|o| o.seq < seq) => {
                return active.len();
            }
            _ => {}
        }
        active
            .iter()
            .take_while(|c| self.owners.get(&c.id).is_some_and(|o| o.seq < seq))
            .count()
    }

    /// Add a coflow (does not run a round). Routes to the owning shard,
    /// merging or parking cross-shard arrivals — see the module docs. An
    /// arrival blocked by a down site parks immediately with its full
    /// volume intact (submissions don't fail just because a site is dark;
    /// they wait for it).
    pub fn insert(&mut self, st: CoflowState) {
        if !self.down_sites.is_empty() && self.coflow_blocked(&st) {
            let seq = if self.sharded() {
                let s = self.next_seq;
                self.next_seq += 1;
                s
            } else {
                st.id
            };
            let m = MigratedCoflow { state: st, rates: None, gamma: None, dirty: true };
            self.parked_down.push((seq, m));
            return;
        }
        if !self.sharded() {
            self.shards[0].insert(st);
            return;
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        let m = MigratedCoflow { state: st, rates: None, gamma: None, dirty: true };
        self.route_in(m, seq);
    }

    fn route_in(&mut self, m: MigratedCoflow, seq: u64) {
        // Re-admission paths (crash readmit, structural redistribute…) can
        // route a coflow while a site is down: it parks like an arrival.
        if !self.down_sites.is_empty() && self.coflow_blocked(&m.state) {
            self.owners.remove(&m.state.id);
            self.parked_down.push((seq, m));
            return;
        }
        let id = m.state.id;
        let edges = self.coflow_edges(&m.state);
        let mut owner_set: Vec<u32> = edges.iter().filter_map(|&e| self.edge_owner[e]).collect();
        owner_set.sort_unstable();
        owner_set.dedup();
        let target = match owner_set.len() {
            0 => self.least_loaded(),
            1 => owner_set[0] as usize,
            _ => match self.merge_components(&owner_set, &edges) {
                Some(primary) => primary,
                None => {
                    // Merging would exceed migrate_cap: park it instead.
                    self.park(m, seq);
                    return;
                }
            },
        };
        for &e in &edges {
            self.edge_owner[e] = Some(target as u32);
        }
        let pos = self.adopt_position(target, seq);
        self.owners.insert(id, Owner { shard: target as u32, seq });
        self.shards[target].adopt_coflow(m, pos);
    }

    /// Merge the shard components a cross-shard arrival touches into one
    /// owning shard: primary = the owner of most of the arrival's edges
    /// (ties to the lowest shard id); every coflow on the other owning
    /// shards that is transitively edge-connected to the arrival migrates
    /// to it, in arrival order. Returns `None` — without mutating anything
    /// — when that would move more than `migrate_cap` coflows.
    fn merge_components(&mut self, owner_set: &[u32], cand_edges: &[EdgeId]) -> Option<usize> {
        let mut best = owner_set[0] as usize;
        let mut best_count = 0usize;
        for &s in owner_set {
            let count =
                cand_edges.iter().filter(|&&e| self.edge_owner[e] == Some(s)).count();
            if count > best_count {
                best = s as usize;
                best_count = count;
            }
        }
        // Transitive edge-connected closure of the candidate within each
        // secondary shard (a migrating coflow's edges can connect further
        // coflows of the same shard).
        let mut seen: HashSet<EdgeId> = cand_edges.iter().copied().collect();
        let mut moves: Vec<(u64, u32, CoflowId)> = Vec::new();
        for &s in owner_set {
            if s as usize == best {
                continue;
            }
            let mut taken: HashSet<CoflowId> = HashSet::new();
            let mut changed = true;
            while changed {
                changed = false;
                for cf in &self.shards[s as usize].active {
                    if taken.contains(&cf.id) {
                        continue;
                    }
                    let ces = self.coflow_edges(cf);
                    if ces.iter().any(|e| seen.contains(e)) {
                        taken.insert(cf.id);
                        seen.extend(ces);
                        changed = true;
                    }
                }
            }
            for cf in &self.shards[s as usize].active {
                if taken.contains(&cf.id) {
                    let seq = self.owners.get(&cf.id).map(|o| o.seq).unwrap_or(0);
                    moves.push((seq, s, cf.id));
                }
            }
        }
        if moves.len() > self.migrate_cap {
            return None;
        }
        moves.sort_unstable_by_key(|&(seq, _, _)| seq);
        for (seq, s, id) in moves {
            let m = self.shards[s as usize].extract_coflow(id).expect("closure member active");
            let pos = self.adopt_position(best, seq);
            self.owners.insert(id, Owner { shard: best as u32, seq });
            self.shards[best].adopt_coflow(m, pos);
            self.front_stats.shard_migrations += 1;
        }
        // Every touched edge that had an owner belongs to the primary now.
        for &e in &seen {
            if self.edge_owner[e].is_some() {
                self.edge_owner[e] = Some(best as u32);
            }
        }
        Some(best)
    }

    fn park(&mut self, m: MigratedCoflow, seq: u64) {
        let id = m.state.id;
        let pos = {
            let spill = self.spill.as_ref().expect("spill engine exists when sharded");
            let owners = &self.owners;
            match spill.active.last() {
                None => 0,
                Some(c) if owners.get(&c.id).is_some_and(|o| o.seq < seq) => spill.active.len(),
                _ => spill
                    .active
                    .iter()
                    .take_while(|c| owners.get(&c.id).is_some_and(|o| o.seq < seq))
                    .count(),
            }
        };
        self.owners.insert(id, Owner { shard: SPILL, seq });
        self.spill.as_mut().expect("spill engine exists when sharded").adopt_coflow(m, pos);
    }

    /// Coflows currently parked in the spill engine.
    pub fn parked(&self) -> usize {
        self.spill.as_ref().map(|s| s.active.len()).unwrap_or(0)
    }

    /// Coflows parked because a down site blocks them.
    pub fn parked_down_count(&self) -> usize {
        self.parked_down.len()
    }

    /// True while `site` is declared down (any partition direction).
    pub fn site_down(&self, site: usize) -> bool {
        self.down_sites.contains_key(&site)
    }

    /// Number of sites currently declared down.
    pub fn down_site_count(&self) -> usize {
        self.down_sites.len()
    }

    /// The directed edges `partition` takes down for `site`, sorted.
    fn site_edges(&self, site: usize, partition: SitePartition) -> Vec<EdgeId> {
        let wan = self.shards[0].wan();
        if site >= wan.num_nodes() {
            return Vec::new();
        }
        let mut es: Vec<EdgeId> = match partition {
            SitePartition::Full => {
                wan.out_edges(site).iter().chain(wan.in_edges(site)).copied().collect()
            }
            SitePartition::Inbound => wan.in_edges(site).to_vec(),
            SitePartition::Outbound => wan.out_edges(site).to_vec(),
        };
        es.sort_unstable();
        es
    }

    /// True when some *currently registered* down site claims edge `e`.
    fn edge_down_elsewhere(&self, e: EdgeId) -> bool {
        let l = self.shards[0].wan().link(e);
        self.down_sites.iter().any(|(&site, part)| match part {
            SitePartition::Full => l.src == site || l.dst == site,
            SitePartition::Inbound => l.dst == site,
            SitePartition::Outbound => l.src == site,
        })
    }

    /// Is a FlowGroup src→dst blocked by some down site?
    fn group_blocked(&self, src: usize, dst: usize) -> bool {
        self.down_sites.iter().any(|(&site, part)| match part {
            SitePartition::Full => src == site || dst == site,
            SitePartition::Inbound => dst == site,
            SitePartition::Outbound => src == site,
        })
    }

    /// A coflow is blocked when any *unfinished* group has a blocked
    /// endpoint — it cannot make full progress, so it parks whole (partial
    /// service of the unblocked groups would burn bandwidth the survivors
    /// can use, without finishing the coflow).
    fn coflow_blocked(&self, cf: &CoflowState) -> bool {
        cf.groups
            .iter()
            .zip(&cf.remaining)
            .any(|(g, &rem)| rem > 1e-9 && self.group_blocked(g.src, g.dst))
    }

    /// Declare `site` down: its incident directed edges (per `partition`)
    /// fail in every engine, and every active coflow with an unfinished
    /// group touching the dark side is extracted — achieved bytes intact —
    /// into the down-park, in ascending id order. Everything else re-solves
    /// around the hole (the caller runs the structural round). Idempotent
    /// for a repeated identical declaration ([`WanReaction::Clamped`], no
    /// state change); a *different* partition shape first restores the old
    /// claim, then applies the new one.
    pub fn set_site_down(
        &mut self,
        site: usize,
        partition: SitePartition,
        now: f64,
    ) -> WanReaction {
        if site >= self.shards[0].wan().num_nodes() {
            return WanReaction::Clamped;
        }
        if let Some(prev) = self.down_sites.get(&site).copied() {
            if prev == partition {
                return WanReaction::Clamped;
            }
            self.down_sites.remove(&site);
            let mut restore = self.site_edges(site, prev);
            restore.retain(|&e| !self.edge_down_elsewhere(e));
            for eng in self.engines_mut() {
                eng.set_edges_down(&restore, false, now);
            }
        }
        self.down_sites.insert(site, partition);
        let edges = self.site_edges(site, partition);
        for eng in self.engines_mut() {
            eng.set_edges_down(&edges, true, now);
        }
        let mut blocked: Vec<CoflowId> = Vec::new();
        for eng in self.engines() {
            for cf in &eng.active {
                if self.coflow_blocked(cf) {
                    blocked.push(cf.id);
                }
            }
        }
        blocked.sort_unstable();
        for id in blocked {
            let owner = if self.sharded() { self.owners.remove(&id) } else { None };
            let m = if !self.sharded() {
                self.shards[0].extract_coflow(id)
            } else {
                match owner {
                    Some(o) if o.shard == SPILL => {
                        self.spill.as_mut().and_then(|sp| sp.extract_coflow(id))
                    }
                    Some(o) => self.shards[o.shard as usize].extract_coflow(id),
                    None => None,
                }
            };
            let Some(mut m) = m else { continue };
            // Rates and caches are meaningless across the park; remaining
            // volumes (achieved progress) travel untouched.
            m.rates = None;
            m.gamma = None;
            m.dirty = true;
            let seq = owner.map(|o| o.seq).unwrap_or(id);
            self.parked_down.push((seq, m));
        }
        if self.sharded() {
            self.redistribute();
        }
        WanReaction::Structural
    }

    /// Declare `site` back up (hello/resync landed): restore its edges —
    /// minus any still claimed by *another* down site — and re-admit every
    /// parked coflow no longer blocked, in ascending id order, so the
    /// resulting ownership map is a pure function of the surviving set (the
    /// same determinism argument as [`ShardedEngine::readmit_in_id_order`]).
    /// No-op ([`WanReaction::Clamped`]) when the site was not down.
    pub fn set_site_up(&mut self, site: usize, now: f64) -> WanReaction {
        let Some(partition) = self.down_sites.remove(&site) else {
            return WanReaction::Clamped;
        };
        let mut restore = self.site_edges(site, partition);
        restore.retain(|&e| !self.edge_down_elsewhere(e));
        for eng in self.engines_mut() {
            eng.set_edges_down(&restore, false, now);
        }
        if self.sharded() {
            self.redistribute();
        }
        let mut parked = std::mem::take(&mut self.parked_down);
        parked.sort_by_key(|(_, m)| m.state.id);
        for (seq, mut m) in parked {
            if self.coflow_blocked(&m.state) {
                self.parked_down.push((seq, m));
                continue;
            }
            m.rates = None;
            m.gamma = None;
            m.dirty = true;
            if self.sharded() {
                self.route_in(m, seq);
            } else {
                // Unsharded active order is id order (ids are monotone at
                // submission), so insert at the id-ordered position.
                let id = m.state.id;
                let pos = self.shards[0].active.iter().take_while(|c| c.id < id).count();
                self.shards[0].adopt_coflow(m, pos);
            }
        }
        WanReaction::Structural
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Run one scheduling round on every shard (concurrently when
    /// sharded), then the spill's two-level residual solve.
    pub fn round(&mut self, now: f64, trigger: RoundTrigger) {
        self.round_with(now, trigger, |_, _| {});
    }

    /// [`ShardedEngine::round`] with a per-shard completion callback: the
    /// pipelined enforcement hook. `on_shard_done(i, shard)` runs on the
    /// caller's thread as shard `i` finishes its solve — while the other
    /// shards are still solving — so enforcement fan-out (e.g. the
    /// controller's delta pushes) overlaps the remaining solves instead of
    /// waiting for a global barrier. Callback order across shards is
    /// completion order; per-shard state is final when it fires.
    pub fn round_with<F>(&mut self, now: f64, trigger: RoundTrigger, mut on_shard_done: F)
    where
        F: FnMut(usize, &RoundEngine),
    {
        if !self.sharded() {
            self.shards[0].round(now, trigger);
            on_shard_done(0, &self.shards[0]);
        } else {
            let (tx, rx) = std::sync::mpsc::channel();
            std::thread::scope(|s| {
                for (i, eng) in self.shards.iter_mut().enumerate() {
                    let tx = tx.clone();
                    s.spawn(move || {
                        eng.round(now, trigger);
                        let eng: &RoundEngine = eng;
                        let _ = tx.send((i, eng));
                    });
                }
                drop(tx);
                for (i, eng) in rx {
                    on_shard_done(i, eng);
                }
            });
            self.solve_spill();
        }
        self.rounds += 1;
    }

    /// Level-2 solve for parked coflows: greedy per-coflow max-concurrent
    /// solves (in arrival order) against the residual capacity the shard
    /// allocations left behind. Parked coflows get best-effort service —
    /// they never preempt shard-owned coflows, and their rates are
    /// feasible by construction (each solve subtracts its usage from the
    /// residual).
    fn solve_spill(&mut self) {
        let Some(spill) = self.spill.as_mut() else { return };
        spill.alloc.rates.clear();
        if spill.active.is_empty() {
            return;
        }
        let num_edges = spill.wan.num_edges();
        let mut residual = spill.wan.capacities();
        for eng in &self.shards {
            let net = NetView { wan: &eng.wan, paths: &eng.paths };
            let usage = eng.alloc.edge_usage(&eng.active, &net, num_edges);
            for (r, u) in residual.iter_mut().zip(&usage) {
                *r = (*r - u).max(0.0);
            }
        }
        let k = spill.k;
        for cf in &spill.active {
            let net = NetView { wan: &spill.wan, paths: &spill.paths };
            let (inst, index) = build_instance(&cf.groups, &cf.remaining, &residual, &net, k);
            if inst.groups.is_empty() {
                continue;
            }
            self.front_stats.lp_solves += 1;
            let Some(sol) = lp::max_concurrent(&inst, lp::SolverKind::Gk) else { continue };
            for (u, r) in inst.edge_usage(&sol.rates).iter().zip(residual.iter_mut()) {
                *r = (*r - u).max(0.0);
            }
            let id = cf.id;
            let ngroups = cf.groups.len();
            let rates = expand_rates(ngroups, &index, &sol.rates);
            spill.alloc.rates.insert(id, rates);
        }
    }

    /// Apply a WAN event to every engine (lockstep broadcast — all WAN
    /// views and epochs stay identical). A structural event additionally
    /// redistributes every coflow: paths changed, so the ownership map is
    /// rebuilt from a global decomposition.
    pub fn handle_wan_event_at(&mut self, ev: &LinkEvent, now: f64) -> WanReaction {
        let mut reaction = WanReaction::Clamped;
        for eng in self.engines_mut() {
            reaction = eng.handle_wan_event_at(ev, now);
        }
        if reaction == WanReaction::Structural && self.sharded() {
            self.redistribute();
        }
        reaction
    }

    /// [`ShardedEngine::handle_wan_event_at`] at the estimator's clock.
    pub fn handle_wan_event(&mut self, ev: &LinkEvent) -> WanReaction {
        let t = self.shards[0].estimator.clock();
        self.handle_wan_event_at(ev, t)
    }

    /// Rebuild edge ownership from scratch after a structural event: pull
    /// every coflow (shards and spill) in arrival order, decompose the
    /// whole set on the new path set, and assign each component to the
    /// shard that previously owned most of its members (spill members
    /// don't vote; ties to the lowest shard). `migrate_cap` does not apply
    /// — a structural event re-solves everything anyway, and this is also
    /// the moment parked coflows get re-homed onto real shards.
    fn redistribute(&mut self) {
        let owners = std::mem::take(&mut self.owners);
        let mut all: Vec<(u64, u32, MigratedCoflow)> = Vec::new();
        for (si, eng) in self.shards.iter_mut().enumerate() {
            let ids: Vec<CoflowId> = eng.active.iter().map(|c| c.id).collect();
            for id in ids {
                let seq = owners.get(&id).map(|o| o.seq).unwrap_or(0);
                let m = eng.extract_coflow(id).expect("listed id is active");
                all.push((seq, si as u32, m));
            }
        }
        if let Some(sp) = self.spill.as_mut() {
            let ids: Vec<CoflowId> = sp.active.iter().map(|c| c.id).collect();
            for id in ids {
                let seq = owners.get(&id).map(|o| o.seq).unwrap_or(0);
                let m = sp.extract_coflow(id).expect("listed id is active");
                all.push((seq, SPILL, m));
            }
        }
        for o in self.edge_owner.iter_mut() {
            *o = None;
        }
        if all.is_empty() {
            return;
        }
        all.sort_by_key(|&(seq, _, _)| seq);
        let items: Vec<Vec<EdgeId>> =
            all.iter().map(|(_, _, m)| self.coflow_edges(&m.state)).collect();
        let comps = decompose::decompose(self.edge_owner.len(), &items);
        let mut assign: Vec<u32> = Vec::with_capacity(comps.len());
        for members in &comps.members {
            let mut counts = vec![0usize; self.shards.len()];
            for &i in members {
                let prev = all[i].1;
                if (prev as usize) < counts.len() {
                    counts[prev as usize] += 1;
                }
            }
            let mut best = 0usize;
            for (s, &c) in counts.iter().enumerate() {
                if c > counts[best] {
                    best = s;
                }
            }
            assign.push(best as u32);
        }
        for (i, (seq, prev, m)) in all.into_iter().enumerate() {
            let shard = assign[comps.comp_of[i]];
            for &e in &items[i] {
                self.edge_owner[e] = Some(shard);
            }
            if prev != shard {
                self.front_stats.shard_migrations += 1;
            }
            let pos = self.shards[shard as usize].active.len();
            self.owners.insert(m.state.id, Owner { shard, seq });
            self.shards[shard as usize].adopt_coflow(m, pos);
        }
    }

    /// Broadcast [`RoundEngine::crash_reset`] to every engine (lockstep
    /// beliefs stay lockstep), then rebuild shard ownership from the
    /// surviving coflow set exactly as reconstruction would.
    pub fn crash_reset(&mut self, now: f64) {
        for eng in self.engines_mut() {
            eng.crash_reset(now);
        }
        self.readmit_in_id_order();
    }

    /// Crash-recovery re-admission: rebuild shard ownership
    /// deterministically from the current coflow set alone. Extracts every
    /// coflow (shards + spill), clears edge claims, resets the
    /// arrival-sequence counter, and routes everything back in ascending
    /// coflow-id order — ids are assigned monotonically at submission, so
    /// id order *is* arrival order. A restarted controller reconstructing
    /// its world from agent `resync_state` reports calls this after each
    /// report: regardless of which agent happened to reconnect first, the
    /// final ownership map is a pure function of the reconstructed coflow
    /// set. No-op when unsharded (a single engine has no ownership).
    pub fn readmit_in_id_order(&mut self) {
        if !self.sharded() {
            return;
        }
        let mut all: Vec<MigratedCoflow> = Vec::new();
        for eng in self.shards.iter_mut().chain(self.spill.as_mut()) {
            let ids: Vec<CoflowId> = eng.active.iter().map(|c| c.id).collect();
            for id in ids {
                all.push(eng.extract_coflow(id).expect("listed id is active"));
            }
        }
        self.owners.clear();
        for o in self.edge_owner.iter_mut() {
            *o = None;
        }
        self.next_seq = 0;
        all.sort_by_key(|m| m.state.id);
        for m in all {
            let seq = self.next_seq;
            self.next_seq += 1;
            self.route_in(m, seq);
        }
    }

    /// Broadcast a belief refresh; returns the strongest reaction (all
    /// engines react identically — lockstep beliefs).
    pub fn refresh_beliefs(&mut self) -> Option<WanReaction> {
        let mut out = None;
        for eng in self.engines_mut() {
            let r = eng.refresh_beliefs();
            if out.is_none() {
                out = r;
            }
        }
        out
    }

    /// Broadcast a passive throughput sample (lockstep estimators).
    pub fn observe_edge(&mut self, e: EdgeId, achieved: f64, capped: bool, now: f64) {
        for eng in self.engines_mut() {
            eng.observe_edge(e, achieved, capped, now);
        }
    }

    /// Broadcast an active probe measurement.
    pub fn probe_edge(&mut self, e: EdgeId, measured: f64, now: f64) {
        for eng in self.engines_mut() {
            eng.probe_edge(e, measured, now);
        }
    }

    /// Broadcast an announced capacity prior.
    pub fn announce_prior(&mut self, e: EdgeId, gbps: f64, now: f64, hold_until: f64) {
        for eng in self.engines_mut() {
            eng.announce_prior(e, gbps, now, hold_until);
        }
    }

    /// Deadline/stream admission against the *global* active set: the
    /// policy's admission math (reserved-rate subtraction over
    /// deadline-bearing coflows, floor reservation over admitted streams,
    /// stable-sorted) needs the same view a single engine would have, so
    /// the front-end assembles the arrival-ordered union of all shards'
    /// (and the spill's) deadline- or floor-bearing actives and asks shard
    /// 0's policy. Unconstrained candidates skip the union (every policy
    /// admits them unconditionally).
    pub fn admit(&mut self, now: f64, candidate: &CoflowState) -> bool {
        if !self.sharded() {
            return self.shards[0].admit(now, candidate);
        }
        let mut merged: Vec<(u64, CoflowState)> = Vec::new();
        if candidate.deadline.is_some() || candidate.rate_floor().is_some() {
            for eng in self.engines() {
                for c in &eng.active {
                    if c.deadline.is_some() || c.rate_floor().is_some() {
                        let seq = self.owners.get(&c.id).map(|o| o.seq).unwrap_or(0);
                        merged.push((seq, c.clone()));
                    }
                }
            }
            merged.sort_by_key(|&(seq, _)| seq);
        }
        let coflows: Vec<CoflowState> = merged.into_iter().map(|(_, c)| c).collect();
        let RoundEngine { wan, paths, policy, estimator, .. } = &mut self.shards[0];
        if !estimator.is_oracle() {
            // Same fresh `mean − k·σ` headroom view as the single-engine
            // path (see [`RoundEngine::admit`]); estimators run in
            // lockstep across shards, so shard 0's belief is the belief.
            let mut headroom = wan.clone();
            for e in 0..headroom.num_edges() {
                let cap = headroom.link(e).avail().min(estimator.cap_used(e));
                headroom.set_capacity(e, cap);
            }
            let net = NetView { wan: &headroom, paths };
            return policy.admit(now, candidate, &coflows, &net);
        }
        let net = NetView { wan, paths };
        policy.admit(now, candidate, &coflows, &net)
    }

    /// Aggregate per-edge usage of all live allocations (shards + spill).
    pub fn edge_usage(&self, num_edges: usize) -> Vec<f64> {
        if !self.sharded() {
            let eng = &self.shards[0];
            let net = NetView { wan: &eng.wan, paths: &eng.paths };
            return eng.alloc.edge_usage(&eng.active, &net, num_edges);
        }
        let mut usage = vec![0.0; num_edges];
        for eng in self.engines() {
            let net = NetView { wan: &eng.wan, paths: &eng.paths };
            let u = eng.alloc.edge_usage(&eng.active, &net, num_edges);
            for (a, b) in usage.iter_mut().zip(&u) {
                *a += *b;
            }
        }
        usage
    }

    /// Per-coflow scale factors bringing the *aggregate* live allocation
    /// within `caps` — the sharded analogue of
    /// [`RoundEngine::throttle_factors`] (per-edge factors come from total
    /// usage across every engine; shard-disjointness makes the two
    /// identical when `shards = 1`).
    pub fn throttle_factors(&self, caps: &[f64]) -> HashMap<CoflowId, f64> {
        if !self.sharded() {
            return self.shards[0].throttle_factors(caps);
        }
        let usage = self.edge_usage(caps.len());
        let mut factors: Vec<f64> = vec![1.0; caps.len()];
        let mut any = false;
        for (e, (&u, &c)) in usage.iter().zip(caps).enumerate() {
            if u > c && u > 1e-12 {
                factors[e] = c / u;
                any = true;
            }
        }
        let mut out = HashMap::new();
        if any {
            for eng in self.engines() {
                collect_throttle_factors(&eng.active, &eng.alloc, &eng.paths, &factors, &mut out);
            }
        }
        out
    }

    /// Drain every engine at the current allocations for `dt` seconds.
    pub fn drain(&mut self, dt: f64, floor: f64) -> f64 {
        self.drain_with(dt, floor, None)
    }

    /// [`ShardedEngine::drain`] with per-coflow throttling.
    pub fn drain_with(
        &mut self,
        dt: f64,
        floor: f64,
        throttle: Option<&HashMap<CoflowId, f64>>,
    ) -> f64 {
        let mut moved = 0.0;
        for eng in self.engines_mut() {
            moved += eng.drain_with(dt, floor, throttle);
        }
        moved
    }

    /// Earliest absolute time any active FlowGroup empties, across all
    /// engines.
    pub fn next_completion(&self, now: f64) -> Option<f64> {
        let mut best: Option<f64> = None;
        for eng in self.engines() {
            if let Some(t) = eng.next_completion(now) {
                best = Some(best.map_or(t, |b: f64| b.min(t)));
            }
        }
        best
    }

    /// Record an agent-confirmed FlowGroup completion. Returns true when
    /// the whole coflow is done. A completion can land for a *parked*
    /// coflow (the bytes finished just before the site died, or the group
    /// doesn't touch the down site): the group zeroes in the park, and a
    /// fully-finished parked coflow moves to the finished queue instead of
    /// waiting for an un-park it no longer needs.
    pub fn complete_group(&mut self, id: CoflowId, src: usize, dst: usize) -> bool {
        if let Some(idx) = self.parked_down.iter().position(|(_, m)| m.state.id == id) {
            let (seq, m) = &mut self.parked_down[idx];
            let seq = *seq;
            let st = &mut m.state;
            for (g, rem) in st.groups.iter().zip(st.remaining.iter_mut()) {
                if g.src == src && g.dst == dst {
                    *rem = 0.0;
                }
            }
            let done = st.remaining.iter().all(|&r| r <= 1e-9);
            if done {
                self.parked_down.remove(idx);
                self.parked_finished.push((seq, id));
            }
            return done;
        }
        self.engine_of_mut(id).map(|e| e.complete_group(id, src, dst)).unwrap_or(false)
    }

    /// Remove all finished coflows everywhere; returns their ids in
    /// arrival order.
    pub fn take_finished(&mut self) -> Vec<CoflowId> {
        if !self.sharded() {
            let mut done = self.shards[0].take_finished();
            if !self.parked_finished.is_empty() {
                done.extend(self.parked_finished.drain(..).map(|(_, id)| id));
                // Unsharded arrival order is id order (monotone ids).
                done.sort_unstable();
            }
            return done;
        }
        let mut done: Vec<(u64, CoflowId)> = Vec::new();
        for eng in self.shards.iter_mut().chain(self.spill.as_mut()) {
            for id in eng.take_finished() {
                let seq = self.owners.remove(&id).map(|o| o.seq).unwrap_or(0);
                done.push((seq, id));
            }
        }
        done.extend(self.parked_finished.drain(..));
        done.sort_unstable_by_key(|&(seq, _)| seq);
        // An idle control plane owns nothing: reset edge claims so
        // ownership cannot drift arbitrarily far from current load.
        if self.owners.is_empty() {
            for o in self.edge_owner.iter_mut() {
                *o = None;
            }
        }
        done.into_iter().map(|(_, id)| id).collect()
    }

    /// Drop a coflow's caches after a discontinuous change, and re-route
    /// it if its edge set now crosses shard boundaries (`updateCoflow` can
    /// grow the edge set). Parked coflows stay parked until the next
    /// structural redistribute.
    pub fn mark_dirty(&mut self, id: CoflowId) {
        if !self.sharded() {
            self.shards[0].mark_dirty(id);
            return;
        }
        let Some(o) = self.owners.get(&id).copied() else { return };
        if o.shard == SPILL {
            if let Some(sp) = self.spill.as_mut() {
                sp.mark_dirty(id);
            }
            return;
        }
        let shard = o.shard as usize;
        self.shards[shard].mark_dirty(id);
        let Some(cf) = self.shards[shard].get(id) else { return };
        let edges = self.coflow_edges(cf);
        let crosses = edges
            .iter()
            .any(|&e| self.edge_owner[e].is_some_and(|s| s != o.shard));
        if !crosses {
            for &e in &edges {
                if self.edge_owner[e].is_none() {
                    self.edge_owner[e] = Some(o.shard);
                }
            }
            return;
        }
        // The grown edge set spans shards: re-route exactly like a fresh
        // cross-shard arrival, keeping the original arrival position.
        let m = self.shards[shard].extract_coflow(id).expect("owner table said so");
        self.owners.remove(&id);
        self.front_stats.shard_migrations += 1;
        self.route_in(m, o.seq);
    }

    pub fn get(&self, id: CoflowId) -> Option<&CoflowState> {
        self.engine_of(id).and_then(|e| e.get(id)).or_else(|| {
            self.parked_down.iter().find(|(_, m)| m.state.id == id).map(|(_, m)| &m.state)
        })
    }

    /// Mutable access for drivers that extend coflows in place; callers
    /// that change the group shape must [`ShardedEngine::mark_dirty`].
    pub fn get_mut(&mut self, id: CoflowId) -> Option<&mut CoflowState> {
        if self.engine_of(id).is_some_and(|e| e.get(id).is_some()) {
            return self.engine_of_mut(id).and_then(|e| e.get_mut(id));
        }
        self.parked_down.iter_mut().find(|(_, m)| m.state.id == id).map(|(_, m)| &mut m.state)
    }

    /// Current total scheduled rate (Gbps) of a coflow.
    pub fn coflow_rate(&self, id: CoflowId) -> f64 {
        self.engine_of(id).map(|e| e.coflow_rate(id)).unwrap_or(0.0)
    }

    /// A coflow's full rate matrix from the last round, if any.
    pub fn coflow_rates(&self, id: CoflowId) -> Option<CoflowRates> {
        self.engine_of(id).and_then(|e| e.coflow_rates(id))
    }

    /// Visit every active coflow with its live rate matrix (if any), across
    /// all engines — the enforcement plane's sweep over the allocation.
    pub fn visit_allocations<F>(&self, mut f: F)
    where
        F: FnMut(&CoflowState, Option<&CoflowRates>),
    {
        for eng in self.engines() {
            for cs in &eng.active {
                f(cs, eng.alloc.rates.get(&cs.id));
            }
        }
    }

    /// The union of every engine's live rate table (built fresh; the
    /// sharded plane has no single `Allocation`).
    pub fn rates_snapshot(&self) -> HashMap<CoflowId, CoflowRates> {
        let mut out = HashMap::new();
        for eng in self.engines() {
            for (id, r) in &eng.alloc.rates {
                out.insert(*id, r.clone());
            }
        }
        out
    }

    /// Minimum CCT of a coflow alone on the full WAN.
    pub fn standalone_min_cct(&self, st: &CoflowState) -> f64 {
        self.shards[0].standalone_min_cct(st)
    }

    pub fn len(&self) -> usize {
        self.engines().map(|e| e.active.len()).sum::<usize>() + self.parked_down.len()
    }

    pub fn is_empty(&self) -> bool {
        self.engines().all(|e| e.active.is_empty()) && self.parked_down.is_empty()
    }

    /// All lockstep-replicated read state comes from shard 0.
    pub fn wan(&self) -> &Wan {
        self.shards[0].wan()
    }

    pub fn paths(&self) -> &PathSet {
        self.shards[0].paths()
    }

    pub fn estimator(&self) -> &CapacityEstimator {
        self.shards[0].estimator()
    }

    pub fn telemetry(&self) -> &TelemetryConfig {
        self.shards[0].telemetry()
    }

    pub fn epoch(&self) -> u64 {
        self.shards[0].epoch()
    }

    pub fn k_paths(&self) -> usize {
        self.shards[0].k_paths()
    }

    pub fn policy_name(&self) -> &'static str {
        self.shards[0].policy_name()
    }

    /// Logical front-end rounds (each may span many concurrent shard
    /// rounds).
    pub fn rounds(&self) -> usize {
        if !self.sharded() {
            return self.shards[0].rounds();
        }
        self.rounds
    }

    /// Drain instrumentation from every engine plus the front-end's own
    /// counters (migrations, spill solves).
    pub fn take_stats(&mut self) -> RoundStats {
        let mut stats = RoundStats::default();
        for eng in self.shards.iter_mut().chain(self.spill.as_mut()) {
            stats.merge(&eng.take_stats());
        }
        stats.merge(&self.front_stats);
        self.front_stats = RoundStats::default();
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coflow::{Coflow, Flow, GB};
    use crate::scheduler::terra::{TerraConfig, TerraPolicy};

    /// A 4-node line: 0—1—2—3, one path per pair, so edge ownership is
    /// fully determined by which pairs a coflow uses.
    fn line4() -> Wan {
        let mut w = Wan::new();
        for i in 0..4 {
            w.add_node(&format!("N{i}"), 0.0, i as f64);
        }
        w.add_link(0, 1, 10.0, Some(1.0));
        w.add_link(1, 2, 10.0, Some(1.0));
        w.add_link(2, 3, 10.0, Some(1.0));
        w
    }

    fn mk(shards: usize, migrate_cap: usize) -> ShardedEngine {
        let policy = TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() });
        ShardedEngine::new(
            line4(),
            Box::new(policy),
            EngineConfig { check_feasibility: true, shards, migrate_cap, ..Default::default() },
        )
    }

    fn coflow(id: u64, s: usize, d: usize, gb: f64) -> CoflowState {
        CoflowState::from_coflow(&Coflow::new(
            id,
            vec![Flow { id: 0, src_dc: s, dst_dc: d, volume: gb * GB }],
        ))
    }

    /// Drive to completion: round / drain / sweep until empty.
    fn run_to_empty(e: &mut ShardedEngine, mut now: f64) -> f64 {
        for _ in 0..64 {
            if e.is_empty() {
                return now;
            }
            let Some(t) = e.next_completion(now) else { break };
            e.drain(t - now, 0.0);
            now = t;
            e.take_finished();
            if !e.is_empty() {
                e.round(now, RoundTrigger::FlowGroupFinish);
            }
        }
        assert!(e.is_empty(), "{} coflows never finished", e.len());
        now
    }

    /// A cross-shard arrival (edges spanning two shards) migrates the
    /// connected coflows onto one shard, keeps scheduling all of them, and
    /// everything completes.
    #[test]
    fn cross_shard_arrival_migrates_and_completes() {
        let mut e = mk(2, usize::MAX);
        assert_eq!(e.num_shards(), 2);
        e.insert(coflow(1, 0, 1, 1.0)); // claims edge 0 on one shard
        e.insert(coflow(2, 2, 3, 1.0)); // claims edge 2 on the other
        e.round(0.0, RoundTrigger::CoflowArrival);
        assert!(e.coflow_rate(1) > 0.0);
        assert!(e.coflow_rate(2) > 0.0);
        let o1 = e.owners[&1].shard;
        let o2 = e.owners[&2].shard;
        assert_ne!(o1, o2, "disjoint coflows should spread across shards");

        // 0 → 3 uses edges {0, 1, 2}: touches both shards → merge.
        e.insert(coflow(3, 0, 3, 2.0));
        e.round(0.0, RoundTrigger::CoflowArrival);
        let owners: Vec<u32> = [1u64, 2, 3].iter().map(|id| e.owners[id].shard).collect();
        assert_eq!(owners[0], owners[1], "merge must unify ownership");
        assert_eq!(owners[0], owners[2]);
        assert_eq!(e.parked(), 0);
        let s = e.take_stats();
        assert_eq!(s.shard_migrations, 1, "exactly the secondary shard's coflow moves");
        // The merged component keeps scheduling (SRTF may hold coflow 3
        // behind the shorter two, but routing must still resolve it).
        assert!(e.get(3).is_some());
        assert!(e.coflow_rate(1) > 0.0);
        run_to_empty(&mut e, 0.0);
    }

    /// With `migrate_cap = 0` the cross-shard arrival is parked and served
    /// by the two-level residual solve: nothing while the line is busy,
    /// full line rate once the shard-owned coflows finish.
    #[test]
    fn capped_migration_parks_and_residual_solves() {
        let mut e = mk(2, 0);
        e.insert(coflow(1, 0, 1, 1.0));
        e.insert(coflow(2, 2, 3, 1.0));
        e.round(0.0, RoundTrigger::CoflowArrival);
        e.insert(coflow(3, 0, 3, 2.0));
        assert_eq!(e.parked(), 1, "over-cap merge must park");
        e.round(0.0, RoundTrigger::CoflowArrival);
        let s = e.take_stats();
        assert_eq!(s.shard_migrations, 0);
        // Edges 0 and 2 are fully used by the shard coflows; the parked
        // coflow's path needs them, so the residual solve yields 0.
        assert_eq!(e.coflow_rate(3), 0.0);

        // 8 Gbit at 10 Gbps: both shard coflows finish at t = 0.8.
        let t = e.next_completion(0.0).expect("draining");
        e.drain(t, 0.0);
        assert_eq!(e.take_finished(), vec![1, 2]);
        e.round(t, RoundTrigger::FlowGroupFinish);
        // The line is free: the parked coflow now gets the full 10 Gbps
        // from the residual solve (and completes through normal drains).
        let r = e.coflow_rate(3);
        assert!((r - 10.0).abs() < 0.5, "residual solve rate = {r}");
        assert!(e.take_stats().lp_solves > 0, "spill solves must be counted");
        let end = run_to_empty(&mut e, t);
        assert!(end > t);
        assert_eq!(e.parked(), 0);
    }

    /// Crash reconstruction re-admission is deterministic: whatever order
    /// agents resynced coflows in, `readmit_in_id_order` rebuilds the same
    /// ownership map (and hence the same allocations).
    #[test]
    fn readmit_in_id_order_is_order_independent() {
        let mut a = mk(2, usize::MAX);
        let mut b = mk(2, usize::MAX);
        // Same coflow set, opposite insertion order — simulating agents
        // reconnecting in different orders after a controller crash.
        a.insert(coflow(1, 0, 1, 1.0));
        a.insert(coflow(2, 2, 3, 1.0));
        b.insert(coflow(2, 2, 3, 1.0));
        b.insert(coflow(1, 0, 1, 1.0));
        a.readmit_in_id_order();
        b.readmit_in_id_order();
        for id in [1u64, 2] {
            assert_eq!(a.owners[&id].shard, b.owners[&id].shard, "coflow {id} shard");
            assert_eq!(a.owners[&id].seq, b.owners[&id].seq, "coflow {id} seq");
        }
        a.round(0.0, RoundTrigger::CoflowArrival);
        b.round(0.0, RoundTrigger::CoflowArrival);
        assert_eq!(a.coflow_rate(1), b.coflow_rate(1));
        assert_eq!(a.coflow_rate(2), b.coflow_rate(2));
        run_to_empty(&mut a, 0.0);
        run_to_empty(&mut b, 0.0);
    }

    /// A site going down parks the coflows it blocks with their achieved
    /// progress intact; un-parking resumes from the preserved remaining
    /// volume and everything completes. Runs unsharded (shards = 1), where
    /// the PR 6 spill engine doesn't even exist — the down-park must work
    /// there too.
    #[test]
    fn site_down_parks_preserves_progress_and_unparks() {
        let mut e = mk(1, usize::MAX);
        e.insert(coflow(1, 0, 1, 8.0));
        e.insert(coflow(2, 2, 3, 8.0));
        e.round(0.0, RoundTrigger::CoflowArrival);
        // Half a second at 10 Gbps: 5 Gbit achieved on each.
        e.drain(0.5, 0.0);
        let before = e.get(2).unwrap().remaining.iter().sum::<f64>();
        assert!(before < 8.0 * GB, "some progress before the failure");

        let r = e.set_site_down(3, SitePartition::Full, 0.5);
        assert_eq!(r, WanReaction::Structural);
        assert!(e.site_down(3));
        assert_eq!(e.parked_down_count(), 1, "only the coflow touching site 3 parks");
        assert_eq!(e.len(), 2, "parked coflows still count as live");
        let parked = e.get(2).expect("parked coflow stays visible");
        assert_eq!(parked.remaining.iter().sum::<f64>(), before, "achieved bytes preserved");
        assert_eq!(e.coflow_rate(2), 0.0, "no allocation while parked");
        e.round(0.5, RoundTrigger::WanChange);
        assert!(e.coflow_rate(1) > 0.0, "survivors re-solve around the hole");
        // Repeated declaration is idempotent.
        assert_eq!(e.set_site_down(3, SitePartition::Full, 0.6), WanReaction::Clamped);
        assert_eq!(e.parked_down_count(), 1);

        let r = e.set_site_up(3, 1.0);
        assert_eq!(r, WanReaction::Structural);
        assert_eq!(e.parked_down_count(), 0, "un-park on recovery");
        assert_eq!(
            e.get(2).unwrap().remaining.iter().sum::<f64>(),
            before,
            "resumes from achieved bytes, not from zero"
        );
        e.round(1.0, RoundTrigger::WanChange);
        assert!(e.coflow_rate(2) > 0.0);
        run_to_empty(&mut e, 1.0);
    }

    /// Partition asymmetry: only the edges *into* a site fail. Coflows
    /// toward the site park; a coflow *out of* the same site keeps
    /// flowing; and a coflow between unaffected sites keeps bit-identical
    /// allocations (its component never touched the dark edges).
    #[test]
    fn inbound_partition_parks_only_traffic_into_the_site() {
        let mut e = mk(1, usize::MAX);
        e.insert(coflow(1, 0, 1, 4.0)); // unaffected pair
        e.insert(coflow(2, 2, 3, 4.0)); // into site 3: must park
        e.insert(coflow(3, 3, 2, 4.0)); // out of site 3: keeps flowing
        e.round(0.0, RoundTrigger::CoflowArrival);
        let before: Vec<u64> =
            e.coflow_rates(1).unwrap().iter().flatten().map(|r| r.to_bits()).collect();

        let r = e.set_site_down(3, SitePartition::Inbound, 0.1);
        assert_eq!(r, WanReaction::Structural);
        assert_eq!(e.parked_down_count(), 1, "only the inbound coflow parks");
        assert!(e.get(2).is_some());
        e.round(0.1, RoundTrigger::WanChange);
        assert_eq!(e.coflow_rate(2), 0.0, "inbound coflow parked");
        assert!(e.coflow_rate(3) > 0.0, "outbound transfer unaffected by an inbound partition");
        let after: Vec<u64> =
            e.coflow_rates(1).unwrap().iter().flatten().map(|r| r.to_bits()).collect();
        assert_eq!(before, after, "unaffected coflow's allocation is bit-identical");

        e.set_site_up(3, 0.2);
        e.round(0.2, RoundTrigger::WanChange);
        assert!(e.coflow_rate(2) > 0.0);
        run_to_empty(&mut e, 0.2);
    }

    /// Sharded: a down site parks across shards, re-admission on recovery
    /// is id-ordered and deterministic, and submissions that arrive while
    /// the site is dark park immediately (full volume intact).
    #[test]
    fn sharded_site_down_roundtrip_and_arrivals_while_down() {
        let mut e = mk(2, usize::MAX);
        e.insert(coflow(1, 0, 1, 1.0));
        e.insert(coflow(2, 2, 3, 1.0));
        e.round(0.0, RoundTrigger::CoflowArrival);
        e.set_site_down(3, SitePartition::Full, 0.1);
        assert_eq!(e.parked_down_count(), 1);
        // Arrival addressed to the dark site parks; an unrelated arrival
        // routes normally.
        e.insert(coflow(3, 2, 3, 1.0));
        e.insert(coflow(4, 1, 0, 1.0));
        assert_eq!(e.parked_down_count(), 2);
        assert!(e.owners.contains_key(&4));
        assert!(!e.owners.contains_key(&3), "parked coflows have no shard owner");
        e.round(0.1, RoundTrigger::WanChange);
        assert!(e.coflow_rate(1) > 0.0);
        assert!(e.coflow_rate(4) > 0.0);

        e.set_site_up(3, 0.2);
        assert_eq!(e.parked_down_count(), 0);
        for id in [2u64, 3] {
            assert!(e.owners.contains_key(&id), "coflow {id} re-admitted");
        }
        // Re-admission is id-ordered: seqs strictly increase with id.
        let mut ids: Vec<u64> = e.owners.keys().copied().collect();
        ids.sort_unstable();
        for w in ids.windows(2) {
            assert!(
                e.owners[&w[0]].seq < e.owners[&w[1]].seq,
                "id order must be seq order after un-park"
            );
        }
        e.round(0.2, RoundTrigger::WanChange);
        run_to_empty(&mut e, 0.2);
    }

    /// A completion replayed for a parked coflow zeroes the group in the
    /// park (and finishes the coflow if it was the last one) — it must not
    /// resurrect on un-park.
    #[test]
    fn completion_while_parked_finishes_without_unpark() {
        let mut e = mk(1, usize::MAX);
        e.insert(coflow(1, 2, 3, 1.0));
        e.round(0.0, RoundTrigger::CoflowArrival);
        e.set_site_down(3, SitePartition::Full, 0.1);
        assert_eq!(e.parked_down_count(), 1);
        // The agent's buffered group_done lands while the site is dark.
        assert!(e.complete_group(1, 2, 3), "last group completes the coflow");
        assert_eq!(e.parked_down_count(), 0);
        assert_eq!(e.take_finished(), vec![1]);
        e.set_site_up(3, 0.2);
        assert!(e.is_empty(), "nothing resurrects on un-park");
    }

    /// A structural event rebuilds ownership globally and re-homes parked
    /// coflows onto real shards.
    #[test]
    fn structural_event_redistributes_and_unparks() {
        let mut e = mk(2, 0);
        e.insert(coflow(1, 0, 1, 1.0));
        e.insert(coflow(2, 2, 3, 1.0));
        e.round(0.0, RoundTrigger::CoflowArrival);
        e.insert(coflow(3, 0, 3, 2.0));
        assert_eq!(e.parked(), 1);
        // Any structural event triggers the global redistribute; coflow 3
        // connects everything, so all three land on one shard.
        let r = e.handle_wan_event_at(&LinkEvent::Fail(1, 2), 0.1);
        assert_eq!(r, WanReaction::Structural);
        assert_eq!(e.parked(), 0, "redistribute must re-home parked coflows");
        e.round(0.1, RoundTrigger::WanChange);
        // Coflow 3 lost its only path (the line is cut), but 1 and 2 keep
        // their ends.
        assert!(e.coflow_rate(1) > 0.0);
        assert!(e.coflow_rate(2) > 0.0);
        assert_eq!(e.len(), 3);
    }
}
