//! The Terra client library (§5.2): the API job masters use to submit
//! coflows to the controller, poll their status, and update them as DAG
//! dependencies are met.
//!
//! ```text
//! val cId   = submitCoflow(Flows, [deadline])   // -1 if deadline rejected
//! val state = checkStatus(cId)
//! updateCoflow(cId, Flows)
//! ```

use crate::coflow::{CoflowId, ServiceClass};
use crate::net::LinkEvent;
use crate::overlay::protocol::{self, CoflowStatus, FlowSpec};
use crate::util::json::Json;
use crate::Result;
use std::net::{SocketAddr, TcpStream};

/// A connection to the Terra controller.
pub struct TerraClient {
    stream: TcpStream,
}

/// `submit_coflow` returns this sentinel when admission control rejects the
/// coflow's deadline (§5.2: "-1 if the coflow has a deadline that cannot be
/// met").
pub const REJECTED: i64 = -1;

impl TerraClient {
    pub fn connect(addr: SocketAddr) -> Result<TerraClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(TerraClient { stream })
    }

    /// Submit a coflow; returns its id, or [`REJECTED`] if a deadline was
    /// given and cannot be met.
    pub fn submit_coflow(&mut self, flows: &[FlowSpec], deadline_s: Option<f64>) -> Result<i64> {
        self.submit_coflow_class(flows, deadline_s, &ServiceClass::Batch)
    }

    /// Submit a coflow with an explicit service class; returns its id, or
    /// [`REJECTED`] when admission fails (a deadline that cannot be met, or
    /// a stream floor the believed headroom cannot cover). `Batch` puts no
    /// `class` key on the wire, so this is byte-identical to
    /// [`submit_coflow`] for the default class.
    pub fn submit_coflow_class(
        &mut self,
        flows: &[FlowSpec],
        deadline_s: Option<f64>,
        class: &ServiceClass,
    ) -> Result<i64> {
        let mut msg = Json::from_pairs([
            ("op", Json::from("submit")),
            ("flows", Json::Arr(flows.iter().map(|f| f.to_json()).collect())),
        ]);
        if let Some(d) = deadline_s {
            msg.set("deadline", d.into());
        }
        if let Some(c) = protocol::class_to_json(class) {
            msg.set("class", c);
        }
        protocol::write_msg(&mut self.stream, &msg)?;
        let reply = protocol::read_msg(&mut self.stream)?
            .ok_or_else(|| anyhow::anyhow!("controller closed connection"))?;
        reply
            .get("cid")
            .and_then(|c| c.as_f64())
            .map(|c| c as i64)
            .ok_or_else(|| anyhow::anyhow!("bad submit reply: {reply}"))
    }

    /// Check the status of a submitted coflow.
    pub fn check_status(&mut self, cid: CoflowId) -> Result<CoflowStatus> {
        let msg = Json::from_pairs([("op", Json::from("status")), ("cid", cid.into())]);
        protocol::write_msg(&mut self.stream, &msg)?;
        let reply = protocol::read_msg(&mut self.stream)?
            .ok_or_else(|| anyhow::anyhow!("controller closed connection"))?;
        Ok(CoflowStatus::from_json(&reply))
    }

    /// Add flows to an already-submitted coflow (e.g. as more upstream
    /// tasks finish, §3.2 "Supporting DAGs and Pipelined Workloads").
    pub fn update_coflow(&mut self, cid: CoflowId, flows: &[FlowSpec]) -> Result<()> {
        let msg = Json::from_pairs([
            ("op", Json::from("update")),
            ("cid", cid.into()),
            ("flows", Json::Arr(flows.iter().map(|f| f.to_json()).collect())),
        ]);
        protocol::write_msg(&mut self.stream, &msg)?;
        let reply = protocol::read_msg(&mut self.stream)?
            .ok_or_else(|| anyhow::anyhow!("controller closed connection"))?;
        if reply.get("error").is_some() {
            anyhow::bail!("update failed: {reply}");
        }
        Ok(())
    }

    /// Inject a WAN event (operator/testing API).
    pub fn wan_event(&mut self, ev: &LinkEvent) -> Result<()> {
        let msg = match *ev {
            LinkEvent::Fail(u, v) => Json::from_pairs([
                ("op", Json::from("wan_event")),
                ("kind", "fail".into()),
                ("u", u.into()),
                ("v", v.into()),
            ]),
            LinkEvent::Recover(u, v) => Json::from_pairs([
                ("op", Json::from("wan_event")),
                ("kind", "recover".into()),
                ("u", u.into()),
                ("v", v.into()),
            ]),
            LinkEvent::SetBandwidth(u, v, gbps) => Json::from_pairs([
                ("op", Json::from("wan_event")),
                ("kind", "bw".into()),
                ("u", u.into()),
                ("v", v.into()),
                ("gbps", gbps.into()),
            ]),
        };
        protocol::write_msg(&mut self.stream, &msg)?;
        protocol::read_msg(&mut self.stream)?;
        Ok(())
    }

    /// Block until the coflow completes; returns its CCT in seconds.
    pub fn wait_done(&mut self, cid: CoflowId, timeout_s: f64) -> Result<f64> {
        let t0 = std::time::Instant::now();
        loop {
            match self.check_status(cid)? {
                CoflowStatus::Done { cct_s } => return Ok(cct_s),
                CoflowStatus::Rejected => anyhow::bail!("coflow {cid} was rejected"),
                _ => {}
            }
            if t0.elapsed().as_secs_f64() > timeout_s {
                anyhow::bail!("timeout waiting for coflow {cid}");
            }
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
    }
}
