//! Dense two-phase primal simplex.
//!
//! A general-purpose exact LP solver used (a) to solve Optimization (1) on
//! small/medium instances, (b) as the correctness oracle for the
//! Garg–Könemann FPTAS and the JAX/PDHG artifact in tests. Bland's rule
//! guards against cycling; the tableau is dense, which is fine at Terra's
//! problem sizes (K·k variables, K+E rows — see §3.1.1).

/// Constraint sense.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Cmp {
    Le,
    Eq,
    Ge,
}

/// `maximize c'x  s.t.  A[i]·x (<=|=|>=) b[i],  x >= 0`.
#[derive(Clone, Debug, Default)]
pub struct Lp {
    pub objective: Vec<f64>,
    pub rows: Vec<(Vec<f64>, Cmp, f64)>,
}

/// Solution: optimal objective and the primal point.
#[derive(Clone, Debug)]
pub struct LpSolution {
    pub objective: f64,
    pub x: Vec<f64>,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum LpError {
    #[error("LP is infeasible")]
    Infeasible,
    #[error("LP is unbounded")]
    Unbounded,
    #[error("simplex iteration limit reached")]
    IterLimit,
}

const EPS: f64 = 1e-9;

impl Lp {
    pub fn new(num_vars: usize) -> Lp {
        Lp { objective: vec![0.0; num_vars], rows: Vec::new() }
    }

    pub fn num_vars(&self) -> usize {
        self.objective.len()
    }

    /// Add a constraint row. `coeffs` must have `num_vars` entries.
    pub fn constrain(&mut self, coeffs: Vec<f64>, cmp: Cmp, rhs: f64) {
        assert_eq!(coeffs.len(), self.num_vars());
        self.rows.push((coeffs, cmp, rhs));
    }

    /// Solve with two-phase simplex.
    pub fn solve(&self) -> Result<LpSolution, LpError> {
        let n = self.num_vars();
        let m = self.rows.len();

        // Normalize to b >= 0.
        let mut rows: Vec<(Vec<f64>, Cmp, f64)> = self.rows.clone();
        for (coeffs, cmp, rhs) in rows.iter_mut() {
            if *rhs < 0.0 {
                for c in coeffs.iter_mut() {
                    *c = -*c;
                }
                *rhs = -*rhs;
                *cmp = match *cmp {
                    Cmp::Le => Cmp::Ge,
                    Cmp::Ge => Cmp::Le,
                    Cmp::Eq => Cmp::Eq,
                };
            }
        }

        // Column layout: [structural n][slack/surplus s][artificial a][rhs].
        let num_slack = rows.iter().filter(|r| r.1 != Cmp::Eq).count();
        let num_art = rows.iter().filter(|r| r.1 != Cmp::Le).count();
        let total = n + num_slack + num_art;
        let rhs_col = total;

        let mut t = vec![vec![0.0f64; total + 1]; m];
        let mut basis = vec![0usize; m];
        let mut s_idx = n;
        let mut a_idx = n + num_slack;
        for (i, (coeffs, cmp, rhs)) in rows.iter().enumerate() {
            t[i][..n].copy_from_slice(coeffs);
            t[i][rhs_col] = *rhs;
            match cmp {
                Cmp::Le => {
                    t[i][s_idx] = 1.0;
                    basis[i] = s_idx;
                    s_idx += 1;
                }
                Cmp::Ge => {
                    t[i][s_idx] = -1.0;
                    s_idx += 1;
                    t[i][a_idx] = 1.0;
                    basis[i] = a_idx;
                    a_idx += 1;
                }
                Cmp::Eq => {
                    t[i][a_idx] = 1.0;
                    basis[i] = a_idx;
                    a_idx += 1;
                }
            }
        }

        // Phase 1: minimize sum of artificials (maximize -sum).
        if num_art > 0 {
            let mut obj = vec![0.0f64; total + 1];
            for j in n + num_slack..total {
                obj[j] = -1.0;
            }
            // Price out basic artificials.
            for i in 0..m {
                if basis[i] >= n + num_slack {
                    for j in 0..=total {
                        obj[j] += t[i][j];
                    }
                }
            }
            run_simplex(&mut t, &mut obj, &mut basis, total, rhs_col)?;
            // obj[rhs_col] tracks the *negated* phase-1 objective: it ends at
            // Σ artificials, which must hit zero for feasibility.
            if obj[rhs_col] > 1e-7 {
                return Err(LpError::Infeasible);
            }
            // Pivot remaining artificial basics out (degenerate rows).
            for i in 0..m {
                if basis[i] >= n + num_slack {
                    let piv = (0..n + num_slack).find(|&j| t[i][j].abs() > EPS);
                    if let Some(j) = piv {
                        pivot(&mut t, &mut obj, &mut basis, i, j, rhs_col);
                    }
                    // If no pivot column exists the row is all-zero
                    // (redundant); the artificial stays basic at value 0,
                    // which is harmless as its column is never re-entered.
                }
            }
        }

        // Phase 2: original objective (artificials excluded from pricing).
        let art_start = n + num_slack;
        let mut obj = vec![0.0f64; total + 1];
        obj[..n].copy_from_slice(&self.objective);
        // Price out basic variables.
        for i in 0..m {
            let b = basis[i];
            if obj[b].abs() > 0.0 {
                let coef = obj[b];
                for j in 0..=total {
                    obj[j] -= coef * t[i][j];
                }
            }
        }
        run_simplex_bounded(&mut t, &mut obj, &mut basis, art_start, rhs_col)?;

        let mut x = vec![0.0f64; n];
        for i in 0..m {
            if basis[i] < n {
                x[basis[i]] = t[i][rhs_col];
            }
        }
        let objective = self.objective.iter().zip(&x).map(|(c, v)| c * v).sum();
        Ok(LpSolution { objective, x })
    }
}

/// Simplex over all columns `< limit` (phase 1 uses every column).
fn run_simplex(
    t: &mut [Vec<f64>],
    obj: &mut [f64],
    basis: &mut [usize],
    limit: usize,
    rhs_col: usize,
) -> Result<(), LpError> {
    run_simplex_bounded(t, obj, basis, limit, rhs_col)
}

/// Simplex restricted to entering columns `< limit`.
fn run_simplex_bounded(
    t: &mut [Vec<f64>],
    obj: &mut [f64],
    basis: &mut [usize],
    limit: usize,
    rhs_col: usize,
) -> Result<(), LpError> {
    let m = t.len();
    let max_iters = 50 * (m + limit).max(100);
    for iter in 0..max_iters {
        // Entering column: Dantzig rule normally, Bland when stalling.
        let bland = iter > max_iters / 2;
        let mut enter: Option<usize> = None;
        if bland {
            enter = (0..limit).find(|&j| obj[j] > EPS);
        } else {
            let mut best = EPS;
            for (j, &o) in obj.iter().enumerate().take(limit) {
                if o > best {
                    best = o;
                    enter = Some(j);
                }
            }
        }
        let Some(e) = enter else { return Ok(()) };

        // Ratio test (Bland tie-break on basis index).
        let mut leave: Option<usize> = None;
        let mut best_ratio = f64::INFINITY;
        for i in 0..m {
            if t[i][e] > EPS {
                let r = t[i][rhs_col] / t[i][e];
                if r < best_ratio - EPS
                    || (r < best_ratio + EPS
                        && leave.map(|l| basis[i] < basis[l]).unwrap_or(false))
                {
                    best_ratio = r;
                    leave = Some(i);
                }
            }
        }
        let Some(l) = leave else { return Err(LpError::Unbounded) };
        pivot(t, obj, basis, l, e, rhs_col);
    }
    Err(LpError::IterLimit)
}

fn pivot(
    t: &mut [Vec<f64>],
    obj: &mut [f64],
    basis: &mut [usize],
    row: usize,
    col: usize,
    rhs_col: usize,
) {
    let m = t.len();
    let p = t[row][col];
    debug_assert!(p.abs() > EPS);
    for j in 0..=rhs_col {
        t[row][j] /= p;
    }
    for i in 0..m {
        if i != row && t[i][col].abs() > EPS {
            let f = t[i][col];
            for j in 0..=rhs_col {
                t[i][j] -= f * t[row][j];
            }
            t[i][col] = 0.0;
        }
    }
    if obj[col].abs() > EPS {
        let f = obj[col];
        for j in 0..=rhs_col {
            obj[j] -= f * t[row][j];
        }
        obj[col] = 0.0;
    }
    basis[row] = col;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-6, "{a} != {b}");
    }

    #[test]
    fn basic_max() {
        // max 3x + 2y s.t. x + y <= 4, x + 3y <= 6 => x=4, y=0, obj=12
        let mut lp = Lp::new(2);
        lp.objective = vec![3.0, 2.0];
        lp.constrain(vec![1.0, 1.0], Cmp::Le, 4.0);
        lp.constrain(vec![1.0, 3.0], Cmp::Le, 6.0);
        let s = lp.solve().unwrap();
        assert_close(s.objective, 12.0);
        assert_close(s.x[0], 4.0);
    }

    #[test]
    fn with_equality() {
        // max x + y s.t. x + y = 3, x <= 2 => obj = 3
        let mut lp = Lp::new(2);
        lp.objective = vec![1.0, 1.0];
        lp.constrain(vec![1.0, 1.0], Cmp::Eq, 3.0);
        lp.constrain(vec![1.0, 0.0], Cmp::Le, 2.0);
        let s = lp.solve().unwrap();
        assert_close(s.objective, 3.0);
        assert!(s.x[0] <= 2.0 + 1e-9);
    }

    #[test]
    fn with_ge() {
        // max -x s.t. x >= 5 => x = 5
        let mut lp = Lp::new(1);
        lp.objective = vec![-1.0];
        lp.constrain(vec![1.0], Cmp::Ge, 5.0);
        let s = lp.solve().unwrap();
        assert_close(s.x[0], 5.0);
    }

    #[test]
    fn infeasible_detected() {
        let mut lp = Lp::new(1);
        lp.objective = vec![1.0];
        lp.constrain(vec![1.0], Cmp::Le, 1.0);
        lp.constrain(vec![1.0], Cmp::Ge, 2.0);
        assert_eq!(lp.solve().unwrap_err(), LpError::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        let mut lp = Lp::new(2);
        lp.objective = vec![1.0, 0.0];
        lp.constrain(vec![0.0, 1.0], Cmp::Le, 1.0);
        assert_eq!(lp.solve().unwrap_err(), LpError::Unbounded);
    }

    #[test]
    fn negative_rhs_normalized() {
        // max -x - y s.t. -x - y <= -2 (i.e. x + y >= 2) => obj = -2
        let mut lp = Lp::new(2);
        lp.objective = vec![-1.0, -1.0];
        lp.constrain(vec![-1.0, -1.0], Cmp::Le, -2.0);
        let s = lp.solve().unwrap();
        assert_close(s.objective, -2.0);
    }

    #[test]
    fn degenerate_equalities() {
        // Redundant equality rows should not break phase 1.
        let mut lp = Lp::new(2);
        lp.objective = vec![1.0, 1.0];
        lp.constrain(vec![1.0, 0.0], Cmp::Eq, 1.0);
        lp.constrain(vec![2.0, 0.0], Cmp::Eq, 2.0);
        lp.constrain(vec![0.0, 1.0], Cmp::Le, 3.0);
        let s = lp.solve().unwrap();
        assert_close(s.objective, 4.0);
    }

    #[test]
    fn larger_random_lp_feasibility() {
        // Random LPs: verify the returned point satisfies all constraints
        // and is no worse than the all-zeros point.
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::new(77);
        for _ in 0..20 {
            let n = 1 + rng.below(8);
            let m = 1 + rng.below(8);
            let mut lp = Lp::new(n);
            for c in lp.objective.iter_mut() {
                *c = rng.uniform(-1.0, 1.0);
            }
            for _ in 0..m {
                let coeffs: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 1.0)).collect();
                lp.constrain(coeffs, Cmp::Le, rng.uniform(0.5, 4.0));
            }
            let s = lp.solve().unwrap();
            for (coeffs, _, rhs) in &lp.rows {
                let lhs: f64 = coeffs.iter().zip(&s.x).map(|(a, b)| a * b).sum();
                assert!(lhs <= rhs + 1e-6, "violated: {lhs} > {rhs}");
            }
            assert!(s.objective >= -1e-9);
        }
    }
}
