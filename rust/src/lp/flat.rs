//! Flat CSR representation of an MCF instance and the persistent solver
//! workspaces that make scheduling rounds allocation-free.
//!
//! [`McfInstance`] is a jagged `Vec<Vec<Vec<EdgeId>>>`: convenient to build,
//! hostile to the GK inner loop (every path hop chases two pointers) and
//! rebuilt from scratch on every solve. [`FlatMcf`] stores the same instance
//! as three CSR arrays over dense local ids:
//!
//! - **group → path**: group `k`'s flat path ids are
//!   `group_off[k]..group_off[k+1]` (paths are numbered group-major),
//! - **path → edge**: path `p`'s local edge ids are
//!   `path_edges[path_off[p]..path_off[p+1]]`, in path order,
//! - **edge → path** incidence (built once per instance): the flat path ids
//!   crossing local edge `e` are `inc_path[inc_off[e]..inc_off[e+1]]` — the
//!   reverse index GK's length updates walk.
//!
//! The **local edge universe** is the sorted set of global edge ids that
//! appear on any path; `cap` is dense over it and refreshed per solve via
//! [`FlatMcf::set_caps`], so re-solving the same structure against new
//! residual capacities costs one gather, not a nested rebuild. The ascending
//! local↔global order matters: the GK measure `D(l)` is an order-sensitive
//! f64 sum over edges, and keeping locals in global-id order makes the flat
//! solve bit-identical to the jagged reference (`gk::solve_warm_jagged`),
//! which the `prop_flat_solver` suite pins.
//!
//! [`SolverWorkspace`] owns everything a solver thread reuses across rounds:
//! the GK scratch buffers ([`GkScratch`]), the CSR [`FlatBuilder`] and its
//! [`EdgeMap`], scratch instances for work-conservation and one-off solves,
//! and a per-coflow [`CachedCsr`] block cache keyed by WAN-capacity epoch —
//! building a coflow's instance inside an epoch is a block copy plus a
//! capacity gather.

use super::McfInstance;
use crate::coflow::CoflowId;
use crate::net::topology::EdgeId;
use std::collections::HashMap;

/// A max-concurrent-flow instance in flat CSR form. See the module docs for
/// the layout. All id arrays are `u32` (4 G paths/edges is far beyond any
/// instance this system builds).
#[derive(Clone, Debug, Default)]
pub struct FlatMcf {
    /// Demand volume per group (Gbit); zero-volume groups are inactive.
    pub vols: Vec<f64>,
    /// Group → flat path id range; `len = groups + 1`, `group_off[0] = 0`.
    pub group_off: Vec<u32>,
    /// Path → local edge range; `len = paths + 1`, `path_off[0] = 0`.
    pub path_off: Vec<u32>,
    /// Local edge ids per path, in path (hop) order.
    pub path_edges: Vec<u32>,
    /// Owning group per flat path id.
    pub group_of_path: Vec<u32>,
    /// Capacity per local edge (refresh via [`FlatMcf::set_caps`]).
    pub cap: Vec<f64>,
    /// Local → global edge id, strictly ascending.
    pub global_edges: Vec<u32>,
    /// Edge → path incidence offsets; `len = local edges + 1`.
    pub inc_off: Vec<u32>,
    /// Flat path ids per local edge (group-major path order within an edge).
    pub inc_path: Vec<u32>,
}

impl FlatMcf {
    pub fn num_groups(&self) -> usize {
        self.vols.len()
    }

    pub fn num_paths(&self) -> usize {
        self.group_of_path.len()
    }

    pub fn num_edges(&self) -> usize {
        self.global_edges.len()
    }

    /// Flat path id range of group `k`.
    #[inline]
    pub fn paths(&self, k: usize) -> std::ops::Range<usize> {
        self.group_off[k] as usize..self.group_off[k + 1] as usize
    }

    /// Local edge ids of flat path `p`, in hop order.
    #[inline]
    pub fn edges(&self, p: usize) -> &[u32] {
        &self.path_edges[self.path_off[p] as usize..self.path_off[p + 1] as usize]
    }

    /// Flat path ids crossing local edge `e`.
    #[inline]
    pub fn incident(&self, e: usize) -> &[u32] {
        &self.inc_path[self.inc_off[e] as usize..self.inc_off[e + 1] as usize]
    }

    /// Gather this instance's capacities from a global capacity vector.
    pub fn set_caps(&mut self, caps: &[f64]) {
        for (c, &g) in self.cap.iter_mut().zip(&self.global_edges) {
            *c = caps[g as usize];
        }
    }

    /// Overwrite the per-group volumes (same group count).
    pub fn set_vols(&mut self, vols: impl IntoIterator<Item = f64>) {
        self.vols.clear();
        self.vols.extend(vols);
        debug_assert_eq!(self.vols.len() + 1, self.group_off.len());
    }

    /// Expand a flat per-path rate vector back to jagged per-group rates
    /// (the [`super::McfSolution`] layout).
    pub fn rates_to_jagged(&self, flat_rates: &[f64]) -> Vec<Vec<f64>> {
        (0..self.num_groups()).map(|k| flat_rates[self.paths(k)].to_vec()).collect()
    }

    /// Subtract a solution's edge usage from a **global** residual capacity
    /// vector, flooring at zero — the flat counterpart of the jagged
    /// `edge_usage` + subtract pattern, without allocating a
    /// global-edge-count vector. Usage accumulates per local edge in the
    /// same (group, path, hop) order as `McfInstance::edge_usage`, and each
    /// global entry is updated exactly once, so results are bit-identical
    /// to the jagged path (edges with zero usage are untouched, which is
    /// exact because residuals are non-negative).
    pub fn subtract_usage(
        &self,
        rates: &[Vec<f64>],
        residual: &mut [f64],
        usage: &mut Vec<f64>,
    ) {
        usage.clear();
        usage.resize(self.num_edges(), 0.0);
        for (k, rk) in rates.iter().enumerate() {
            for (i, p) in self.paths(k).enumerate() {
                let r = rk.get(i).copied().unwrap_or(0.0);
                for &e in self.edges(p) {
                    usage[e as usize] += r;
                }
            }
        }
        for (l, &g) in self.global_edges.iter().enumerate() {
            let r = &mut residual[g as usize];
            *r = (*r - usage[l]).max(0.0);
        }
    }

    /// Build from a jagged instance (convenience; allocates fresh scratch).
    pub fn from_instance(inst: &McfInstance) -> FlatMcf {
        let mut b = FlatBuilder::default();
        let mut map = EdgeMap::default();
        let mut out = FlatMcf::default();
        b.clear();
        for g in &inst.groups {
            b.push_group(g.volume, g.paths.iter().map(|p| p.as_slice()));
        }
        b.finish_into(&inst.cap, &mut map, &mut out);
        out
    }
}

/// Generation-stamped dense global→local edge map: interning is O(1) and
/// resetting between builds is O(1) (no clearing of the dense arrays).
#[derive(Clone, Debug, Default)]
pub struct EdgeMap {
    stamp: Vec<u32>,
    local: Vec<u32>,
    gen: u32,
}

impl EdgeMap {
    fn begin(&mut self, num_global: usize) {
        if self.stamp.len() < num_global {
            self.stamp.resize(num_global, 0);
            self.local.resize(num_global, 0);
        }
        self.gen = self.gen.wrapping_add(1);
        if self.gen == 0 {
            // Wrapped: stamps from 2^32 builds ago could alias. Reset once.
            self.stamp.fill(0);
            self.gen = 1;
        }
    }
}

/// Incremental builder for [`FlatMcf`]: push groups (from jagged path lists
/// or whole prebuilt CSR blocks), then `finish_into` interning the edge
/// universe. All buffers are reused across builds.
#[derive(Clone, Debug, Default)]
pub struct FlatBuilder {
    vols: Vec<f64>,
    group_off: Vec<u32>,
    path_off: Vec<u32>,
    /// Global edge ids during the build; localized at `finish_into`.
    path_edges_global: Vec<u32>,
    group_of_path: Vec<u32>,
    /// Incidence fill cursors (scratch for `finish_into`).
    cursor: Vec<u32>,
}

impl FlatBuilder {
    pub fn clear(&mut self) {
        self.vols.clear();
        self.group_off.clear();
        self.group_off.push(0);
        self.path_off.clear();
        self.path_off.push(0);
        self.path_edges_global.clear();
        self.group_of_path.clear();
    }

    /// Number of groups pushed so far.
    pub fn len(&self) -> usize {
        self.vols.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vols.is_empty()
    }

    /// Append one group with `vol` and its paths (global edge ids).
    pub fn push_group<'a>(&mut self, vol: f64, paths: impl IntoIterator<Item = &'a [EdgeId]>) {
        let k = self.vols.len() as u32;
        self.vols.push(vol);
        for path in paths {
            self.group_of_path.push(k);
            self.path_edges_global.extend(path.iter().map(|&e| e as u32));
            self.path_off.push(self.path_edges_global.len() as u32);
        }
        self.group_off.push(self.group_of_path.len() as u32);
    }

    /// Append every group of a prebuilt CSR block with volumes `vols`
    /// (block concatenation: local ids are re-expanded to global and
    /// re-interned at `finish_into`).
    pub fn push_block(&mut self, block: &FlatMcf, vols: &[f64]) {
        debug_assert_eq!(vols.len(), block.num_groups());
        for (k, &vol) in vols.iter().enumerate() {
            let kk = self.vols.len() as u32;
            self.vols.push(vol);
            for p in block.paths(k) {
                self.group_of_path.push(kk);
                self.path_edges_global
                    .extend(block.edges(p).iter().map(|&le| block.global_edges[le as usize]));
                self.path_off.push(self.path_edges_global.len() as u32);
            }
            self.group_off.push(self.group_of_path.len() as u32);
        }
    }

    /// Intern the edge universe (ascending global order), gather capacities
    /// from `caps`, build the edge→path incidence, and write the finished
    /// instance into `out` (buffers reused).
    pub fn finish_into(&mut self, caps: &[f64], map: &mut EdgeMap, out: &mut FlatMcf) {
        map.begin(caps.len());
        // Unique global edges, then sort ascending and assign local ids.
        out.global_edges.clear();
        for &g in &self.path_edges_global {
            let gi = g as usize;
            if map.stamp[gi] != map.gen {
                map.stamp[gi] = map.gen;
                out.global_edges.push(g);
            }
        }
        out.global_edges.sort_unstable();
        for (l, &g) in out.global_edges.iter().enumerate() {
            map.local[g as usize] = l as u32;
        }
        // Localize the path→edge array.
        out.path_edges.clear();
        out.path_edges.extend(self.path_edges_global.iter().map(|&g| map.local[g as usize]));
        // Copy the structural arrays.
        out.vols.clone_from(&self.vols);
        out.group_off.clone_from(&self.group_off);
        out.path_off.clone_from(&self.path_off);
        out.group_of_path.clone_from(&self.group_of_path);
        // Capacities.
        let ne = out.global_edges.len();
        out.cap.clear();
        out.cap.extend(out.global_edges.iter().map(|&g| caps[g as usize]));
        // Edge→path incidence: count, prefix-sum, fill (path order within
        // each edge, so CSR fill is deterministic).
        out.inc_off.clear();
        out.inc_off.resize(ne + 1, 0);
        for &le in &out.path_edges {
            out.inc_off[le as usize + 1] += 1;
        }
        for e in 0..ne {
            out.inc_off[e + 1] += out.inc_off[e];
        }
        self.cursor.clear();
        self.cursor.extend_from_slice(&out.inc_off[..ne]);
        out.inc_path.clear();
        out.inc_path.resize(out.path_edges.len(), 0);
        for p in 0..out.group_of_path.len() {
            for &le in
                &out.path_edges[out.path_off[p] as usize..out.path_off[p + 1] as usize]
            {
                let c = &mut self.cursor[le as usize];
                out.inc_path[*c as usize] = p as u32;
                *c += 1;
            }
        }
    }
}

/// Reusable scratch buffers for the flat GK solve ([`super::gk`]): every
/// per-solve array is `clear()`+refilled here, so a warm workspace performs
/// no heap allocation in the solver inner loops.
#[derive(Clone, Debug, Default)]
pub struct GkScratch {
    /// Per flat path: usable under the current capacities (active groups
    /// only; paths of inactive groups stay `false`).
    pub usable: Vec<bool>,
    /// Per local edge: lies on some usable path of an active group.
    pub relevant: Vec<bool>,
    /// Exponential edge lengths, per local edge.
    pub len: Vec<f64>,
    /// Cached path lengths, per flat path.
    pub plen: Vec<f64>,
    /// Accumulated (infeasible) flow, per flat path.
    pub x: Vec<f64>,
    /// Warm-start candidate rates, per flat path.
    pub xw: Vec<f64>,
    /// Edge usage scratch, per local edge.
    pub usage: Vec<f64>,
    /// Active (positive-volume) group ids.
    pub active: Vec<u32>,
    /// Normalized working volumes, per group.
    pub vols: Vec<f64>,
}

/// One coflow's cached CSR block: its unfinished FlowGroups' k-truncated
/// path structure, valid for one WAN-capacity epoch (paths can only change
/// across epoch bumps) and one unfinished-group shape.
#[derive(Clone, Debug, Default)]
pub struct CachedCsr {
    /// WAN-capacity epoch the block was built under.
    pub epoch: u64,
    /// Instance-group index → coflow group index (the unfinished groups at
    /// build time; doubles as the shape fingerprint).
    pub index: Vec<usize>,
    pub flat: FlatMcf,
}

/// Everything one solver thread reuses across rounds. Owned by the
/// [`crate::engine::RoundEngine`] (one per worker) and handed to policies
/// via [`crate::scheduler::RoundCtx`]; swept alongside the component cache
/// when coflows depart ([`SolverWorkspace::forget`]).
#[derive(Debug, Default)]
pub struct SolverWorkspace {
    /// GK scratch buffers.
    pub gk: GkScratch,
    /// CSR builder + its edge interner.
    pub builder: FlatBuilder,
    pub edge_map: EdgeMap,
    /// Per-coflow CSR block cache.
    pub csr: HashMap<CoflowId, CachedCsr>,
    /// Scratch instance for work-conservation max-min solves, and the
    /// builder that concatenates coflow CSR blocks into it (separate from
    /// `builder`, which may be rebuilding a block mid-concatenation).
    pub wc: FlatMcf,
    pub wc_builder: FlatBuilder,
}

impl SolverWorkspace {
    pub fn new() -> SolverWorkspace {
        SolverWorkspace::default()
    }

    /// Drop a departed coflow's CSR block. (Epoch-stale blocks need no
    /// sweep: they are rebuilt in place on next use — the freshness check
    /// compares the stored epoch — so the map is bounded by the departure
    /// sweep alone.)
    pub fn forget(&mut self, id: CoflowId) {
        self.csr.remove(&id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::GroupDemand;

    fn demo_inst() -> McfInstance {
        McfInstance {
            cap: vec![10.0, 20.0, 30.0, 40.0, 50.0, 60.0],
            groups: vec![
                GroupDemand { volume: 40.0, paths: vec![vec![0], vec![4, 3]] },
                GroupDemand { volume: 8.0, paths: vec![vec![3]] },
                GroupDemand { volume: 0.0, paths: vec![] },
            ],
        }
    }

    #[test]
    fn from_instance_layout() {
        let f = FlatMcf::from_instance(&demo_inst());
        assert_eq!(f.num_groups(), 3);
        assert_eq!(f.num_paths(), 3);
        // Edge universe = {0, 3, 4} ascending.
        assert_eq!(f.global_edges, vec![0, 3, 4]);
        assert_eq!(f.cap, vec![10.0, 40.0, 50.0]);
        assert_eq!(f.paths(0), 0..2);
        assert_eq!(f.paths(1), 2..3);
        assert_eq!(f.paths(2), 3..3);
        // Path 1 = global [4, 3] = local [2, 1], in hop order.
        assert_eq!(f.edges(1), &[2, 1]);
        assert_eq!(f.edges(2), &[1]);
        assert_eq!(f.group_of_path, vec![0, 0, 1]);
        // Incidence: local edge 1 (global 3) is crossed by paths 1 and 2.
        assert_eq!(f.incident(1), &[1, 2]);
        assert_eq!(f.incident(0), &[0]);
        assert_eq!(f.incident(2), &[1]);
    }

    #[test]
    fn set_caps_gathers() {
        let mut f = FlatMcf::from_instance(&demo_inst());
        let caps: Vec<f64> = (0..6).map(|e| 100.0 + e as f64).collect();
        f.set_caps(&caps);
        assert_eq!(f.cap, vec![100.0, 103.0, 104.0]);
    }

    #[test]
    fn block_concat_equals_direct_build() {
        let inst = demo_inst();
        let whole = FlatMcf::from_instance(&inst);
        // Build each group as its own block, then concatenate.
        let blocks: Vec<FlatMcf> = inst
            .groups
            .iter()
            .map(|g| {
                FlatMcf::from_instance(&McfInstance {
                    cap: inst.cap.clone(),
                    groups: vec![g.clone()],
                })
            })
            .collect();
        let mut b = FlatBuilder::default();
        let mut map = EdgeMap::default();
        let mut out = FlatMcf::default();
        b.clear();
        for (blk, g) in blocks.iter().zip(&inst.groups) {
            b.push_block(blk, &[g.volume]);
        }
        b.finish_into(&inst.cap, &mut map, &mut out);
        assert_eq!(out.vols, whole.vols);
        assert_eq!(out.group_off, whole.group_off);
        assert_eq!(out.path_off, whole.path_off);
        assert_eq!(out.path_edges, whole.path_edges);
        assert_eq!(out.global_edges, whole.global_edges);
        assert_eq!(out.cap, whole.cap);
        assert_eq!(out.inc_off, whole.inc_off);
        assert_eq!(out.inc_path, whole.inc_path);
    }

    #[test]
    fn builder_reuse_is_clean() {
        let mut b = FlatBuilder::default();
        let mut map = EdgeMap::default();
        let mut out = FlatMcf::default();
        let inst = demo_inst();
        for _ in 0..3 {
            b.clear();
            for g in &inst.groups {
                b.push_group(g.volume, g.paths.iter().map(|p| p.as_slice()));
            }
            b.finish_into(&inst.cap, &mut map, &mut out);
            let fresh = FlatMcf::from_instance(&inst);
            assert_eq!(out.path_edges, fresh.path_edges);
            assert_eq!(out.inc_path, fresh.inc_path);
            assert_eq!(out.global_edges, fresh.global_edges);
        }
    }

    #[test]
    fn rates_roundtrip() {
        let f = FlatMcf::from_instance(&demo_inst());
        let jag = f.rates_to_jagged(&[1.0, 2.0, 3.0]);
        assert_eq!(jag, vec![vec![1.0, 2.0], vec![3.0], vec![]]);
    }
}
