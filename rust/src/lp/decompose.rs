//! Edge-connected component decomposition of the active coflow set.
//!
//! Coflows whose k-shortest-path sets share no WAN edge are independent
//! commodities: Optimization (1), the sequential residual allocation, and
//! the work-conservation max-min all touch only the edges of a coflow's own
//! restricted path set, so a scheduling round over the whole active set
//! factors exactly into one sub-round per component. The
//! [`crate::engine::RoundEngine`] uses this to re-solve only the components
//! an event actually dirtied and to carry every untouched component's
//! allocation forward unchanged (see `engine/cache.rs`'s `ComponentCache`).
//!
//! The partition rule: union-find over the WAN's directed edge ids, where
//! each item (coflow) unions all edges appearing in any of its unfinished
//! FlowGroups' k paths. Two items land in the same component iff their edge
//! sets are connected through shared edges (directly or transitively).
//! Items with no usable edges (e.g. a partitioned WAN) become singleton
//! components.

use crate::net::topology::EdgeId;
use std::collections::HashMap;

/// Disjoint-set forest over edge ids with path halving. Union keeps the
/// smaller root id as representative, so component roots (and therefore
/// component enumeration) are a pure function of the input, independent of
/// union order.
#[derive(Clone, Debug, Default)]
pub struct UnionFind {
    parent: Vec<u32>,
}

impl UnionFind {
    pub fn new(n: usize) -> UnionFind {
        UnionFind { parent: (0..n as u32).collect() }
    }

    /// Reinitialize for `n` singleton sets, reusing the backing buffer.
    pub fn reset(&mut self, n: usize) {
        self.parent.clear();
        self.parent.extend(0..n as u32);
    }

    pub fn find(&mut self, mut x: usize) -> usize {
        while self.parent[x] as usize != x {
            let grand = self.parent[self.parent[x] as usize];
            self.parent[x] = grand;
            x = grand as usize;
        }
        x
    }

    /// Merge the sets of `a` and `b`; returns the surviving (smaller) root.
    pub fn union(&mut self, a: usize, b: usize) -> usize {
        let (ra, rb) = (self.find(a), self.find(b));
        let (lo, hi) = if ra <= rb { (ra, rb) } else { (rb, ra) };
        self.parent[hi] = lo as u32;
        lo
    }
}

/// The partition of a set of items (coflows) into edge-connected
/// components, in deterministic first-member order.
#[derive(Clone, Debug, Default)]
pub struct Components {
    /// Component index per input item.
    pub comp_of: Vec<usize>,
    /// Item indices per component, in input order.
    pub members: Vec<Vec<usize>>,
    /// Sorted, deduplicated edge ids per component (union over members).
    pub edges: Vec<Vec<EdgeId>>,
}

impl Components {
    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }
}

/// Reusable scratch for [`decompose_into`]: the union-find forest, the
/// root→component map, and the output [`Components`] are all recycled
/// across rounds, so a steady-state round (stable or growing component
/// count) performs no partition allocations — live slots are cleared and
/// refilled in place. When the component count *shrinks*, the trailing
/// slots are truncated away (their inner vectors drop; a later growth
/// round re-allocates those shells) to keep `Components`' public
/// `members`/`edges` lengths meaningful to consumers.
#[derive(Clone, Debug, Default)]
pub struct DecomposeScratch {
    uf: UnionFind,
    root_to_comp: HashMap<usize, usize>,
    out: Components,
}

impl DecomposeScratch {
    /// The partition produced by the last [`decompose_into`] call.
    pub fn components(&self) -> &Components {
        &self.out
    }
}

/// Partition items by edge connectivity. `item_edges[i]` is item `i`'s edge
/// set (any order, duplicates tolerated); `num_edges` bounds the edge id
/// space. O(total edges · α) plus the output construction.
pub fn decompose(num_edges: usize, item_edges: &[Vec<EdgeId>]) -> Components {
    let mut scratch = DecomposeScratch::default();
    decompose_into(num_edges, item_edges, &mut scratch);
    scratch.out
}

/// [`decompose`] into reused buffers: the partition lands in
/// `scratch.components()`. Identical output to [`decompose`] (which is now
/// a thin wrapper over this).
pub fn decompose_into<'a>(
    num_edges: usize,
    item_edges: &[Vec<EdgeId>],
    scratch: &'a mut DecomposeScratch,
) -> &'a Components {
    let DecomposeScratch { uf, root_to_comp, out } = scratch;
    uf.reset(num_edges);
    for es in item_edges {
        if let Some((&first, rest)) = es.split_first() {
            for &e in rest {
                uf.union(first, e);
            }
        }
    }
    root_to_comp.clear();
    out.comp_of.clear();
    out.comp_of.resize(item_edges.len(), 0);
    // Reuse the previous round's inner vectors: `used` counts live
    // components, slots past it are cleared on (re)allocation.
    let mut used = 0usize;
    let mut alloc_slot = |members: &mut Vec<Vec<usize>>, edges: &mut Vec<Vec<EdgeId>>| -> usize {
        if used < members.len() {
            members[used].clear();
            edges[used].clear();
        } else {
            members.push(Vec::new());
            edges.push(Vec::new());
        }
        used += 1;
        used - 1
    };
    for (i, es) in item_edges.iter().enumerate() {
        let c = match es.first() {
            // Edgeless item: its own singleton component.
            None => alloc_slot(&mut out.members, &mut out.edges),
            Some(&e0) => {
                let root = uf.find(e0);
                match root_to_comp.entry(root) {
                    std::collections::hash_map::Entry::Occupied(o) => *o.get(),
                    std::collections::hash_map::Entry::Vacant(v) => {
                        let c = alloc_slot(&mut out.members, &mut out.edges);
                        v.insert(c);
                        c
                    }
                }
            }
        };
        out.comp_of[i] = c;
        out.members[c].push(i);
        out.edges[c].extend_from_slice(es);
    }
    out.members.truncate(used);
    out.edges.truncate(used);
    for es in &mut out.edges {
        es.sort_unstable();
        es.dedup();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disjoint_items_stay_separate() {
        let c = decompose(6, &[vec![0, 1], vec![2], vec![3, 4, 5]]);
        assert_eq!(c.len(), 3);
        assert_eq!(c.comp_of, vec![0, 1, 2]);
        assert_eq!(c.members, vec![vec![0], vec![1], vec![2]]);
        assert_eq!(c.edges, vec![vec![0, 1], vec![2], vec![3, 4, 5]]);
    }

    #[test]
    fn shared_edge_merges_transitively() {
        // 0-{0,1}, 1-{1,2}, 2-{2,3}: one chain-connected component;
        // 3-{5} stays apart.
        let c = decompose(6, &[vec![0, 1], vec![1, 2], vec![2, 3], vec![5]]);
        assert_eq!(c.len(), 2);
        assert_eq!(c.comp_of, vec![0, 0, 0, 1]);
        assert_eq!(c.members[0], vec![0, 1, 2]);
        assert_eq!(c.edges[0], vec![0, 1, 2, 3]);
        assert_eq!(c.members[1], vec![3]);
    }

    #[test]
    fn edgeless_items_are_singletons() {
        let c = decompose(4, &[vec![], vec![0], vec![], vec![0]]);
        assert_eq!(c.len(), 3);
        assert_eq!(c.comp_of[0], 0);
        assert_eq!(c.comp_of[1], c.comp_of[3]);
        assert_ne!(c.comp_of[0], c.comp_of[2], "each edgeless item is its own component");
        assert!(c.edges[c.comp_of[0]].is_empty());
    }

    #[test]
    fn order_is_deterministic_in_first_member_order() {
        // Components enumerate in order of their first member, regardless of
        // edge ids.
        let c = decompose(10, &[vec![9], vec![1, 2], vec![2], vec![9]]);
        assert_eq!(c.comp_of, vec![0, 1, 1, 0]);
        assert_eq!(c.members, vec![vec![0, 3], vec![1, 2]]);
    }

    #[test]
    fn duplicates_are_deduped() {
        let c = decompose(3, &[vec![1, 1, 0, 1]]);
        assert_eq!(c.edges[0], vec![0, 1]);
    }

    /// A reused scratch yields the same partition as a fresh one, including
    /// when the component count shrinks and grows between calls (stale
    /// slots must not leak members or edges).
    #[test]
    fn scratch_reuse_matches_fresh() {
        let inputs: Vec<Vec<Vec<EdgeId>>> = vec![
            vec![vec![0, 1], vec![2], vec![3, 4, 5]],
            vec![vec![0, 1], vec![1, 2], vec![2, 3], vec![5]],
            vec![vec![9], vec![1, 2], vec![2], vec![9], vec![]],
            vec![vec![7]],
            vec![],
        ];
        let mut scratch = DecomposeScratch::default();
        for item_edges in &inputs {
            let fresh = decompose(10, item_edges);
            let reused = decompose_into(10, item_edges, &mut scratch);
            assert_eq!(reused.comp_of, fresh.comp_of);
            assert_eq!(reused.members, fresh.members);
            assert_eq!(reused.edges, fresh.edges);
        }
    }
}
