//! Optimization (1): the per-coflow minimum-CCT problem (§3.1.1).
//!
//! Given a coflow's FlowGroups and the residual WAN, Terra finds paths and
//! rates so all groups progress at rate `1/Γ` per second and Γ (the CCT) is
//! minimized. With FlowGroups the integral constraints vanish and the
//! problem becomes a **maximum concurrent flow** LP: maximize λ such that
//! group `k` ships `λ·|d_k|` Gbps from `src_k` to `dst_k` under joint edge
//! capacities; then `Γ = 1/λ`.
//!
//! Three interchangeable solvers:
//! - [`simplex`] — exact dense LP (oracle + small instances),
//! - [`gk`] — Garg–Könemann FPTAS on the k-shortest-path restriction
//!   (the controller's default; §4.3 restricts paths anyway),
//! - the AOT-compiled JAX/PDHG artifact executed via PJRT
//!   ([`crate::runtime`]).
//!
//! [`maxmin`] implements max-min fair MCF used for work conservation
//! (Pseudocode 1) and the SWAN-MCF baseline.

pub mod decompose;
pub mod flat;
pub mod gk;
pub mod maxmin;
pub mod simplex;

pub use flat::{FlatMcf, SolverWorkspace};

use crate::net::topology::EdgeId;

/// One FlowGroup's demand in an MCF instance: its volume and the restricted
/// path set (each path is a list of directed edge ids).
#[derive(Clone, Debug)]
pub struct GroupDemand {
    pub volume: f64,
    pub paths: Vec<Vec<EdgeId>>,
}

/// A max-concurrent-flow instance over the residual WAN.
#[derive(Clone, Debug)]
pub struct McfInstance {
    /// Residual capacity per directed edge (Gbps).
    pub cap: Vec<f64>,
    pub groups: Vec<GroupDemand>,
}

/// Solution: common progress rate λ (per second) and per-(group, path)
/// rates in Gbps. Group `k`'s total rate is exactly `lambda * volume_k`,
/// so its completion time is `1/lambda` (= Γ).
#[derive(Clone, Debug)]
pub struct McfSolution {
    pub lambda: f64,
    pub rates: Vec<Vec<f64>>,
}

impl McfInstance {
    /// Drop zero-volume groups (callers may pass them; they get empty rates).
    pub fn active_groups(&self) -> impl Iterator<Item = (usize, &GroupDemand)> {
        self.groups.iter().enumerate().filter(|(_, g)| g.volume > 0.0)
    }

    /// Per-edge bandwidth usage of a candidate solution.
    pub fn edge_usage(&self, rates: &[Vec<f64>]) -> Vec<f64> {
        let mut usage = vec![0.0; self.cap.len()];
        for (g, group_rates) in self.groups.iter().zip(rates) {
            for (p, &r) in g.paths.iter().zip(group_rates) {
                for &e in p {
                    usage[e] += r;
                }
            }
        }
        usage
    }

    /// Verify feasibility of a solution within tolerance `tol` and that all
    /// groups progress at `lambda`. Scans every (group, path, edge) triple
    /// plus a full-edge capacity pass — **tests and `debug_assertions`
    /// only**; release-path callers must stay behind a debug gate (audited:
    /// [`max_concurrent_warm`] and the runtime/integration tests are the
    /// only call sites).
    pub fn check(&self, sol: &McfSolution, tol: f64) -> Result<(), String> {
        let usage = self.edge_usage(&sol.rates);
        for (e, (&u, &c)) in usage.iter().zip(&self.cap).enumerate() {
            if u > c + tol * (1.0 + c) {
                return Err(format!("edge {e} over capacity: {u} > {c}"));
            }
        }
        for (k, g) in self.groups.iter().enumerate() {
            let rate: f64 = sol.rates[k].iter().sum();
            if g.volume > 0.0 {
                let want = sol.lambda * g.volume;
                if (rate - want).abs() > tol * (1.0 + want) {
                    return Err(format!("group {k} rate {rate} != lambda*v {want}"));
                }
            } else if rate > tol {
                return Err(format!("zero-volume group {k} has rate {rate}"));
            }
        }
        Ok(())
    }
}

impl McfSolution {
    /// The coflow completion time Γ implied by λ.
    pub fn gamma(&self) -> f64 {
        if self.lambda > 0.0 {
            1.0 / self.lambda
        } else {
            f64::INFINITY
        }
    }

    /// Scale all rates by `f` (used for deadline dilation Γ_i/D_i, §3.2,
    /// and the α starvation share).
    pub fn scale(&mut self, f: f64) {
        self.lambda *= f;
        for g in &mut self.rates {
            for r in g {
                *r *= f;
            }
        }
    }
}

/// Which solver backs Optimization (1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverKind {
    /// Exact dense simplex.
    Simplex,
    /// Garg–Könemann FPTAS (default).
    Gk,
}

/// Which data representation the GK solver iterates. Both run the identical
/// algorithm and return bit-identical results (property-tested); they differ
/// only in constant factors.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SolverRepr {
    /// Jagged `Vec<Vec<Vec<EdgeId>>>` instances rebuilt per solve — the
    /// pre-flat reference, kept for the equivalence suite and as the
    /// baseline axis of the scaling benches.
    Jagged,
    /// Flat CSR instances ([`FlatMcf`]) with persistent
    /// [`SolverWorkspace`] buffers and per-coflow CSR block caching
    /// (the default).
    #[default]
    Flat,
}

/// Solve Optimization (1) for one coflow. Returns `None` when some group has
/// no usable path (e.g. partitioned WAN) or all volumes are zero.
pub fn max_concurrent(inst: &McfInstance, kind: SolverKind) -> Option<McfSolution> {
    max_concurrent_warm(inst, kind, None)
}

/// [`max_concurrent`] with an optional warm start: `warm` is the previous
/// round's per-(group, path) rates for the *same* group order (extra or
/// missing paths are tolerated). Iterative solvers use it as a feasible
/// candidate to terminate early; exact solvers ignore it.
pub fn max_concurrent_warm(
    inst: &McfInstance,
    kind: SolverKind,
    warm: Option<&[Vec<f64>]>,
) -> Option<McfSolution> {
    max_concurrent_repr(inst, kind, warm, SolverRepr::Flat)
}

/// [`max_concurrent_warm`] with an explicit GK data representation. Both
/// representations return bit-identical solutions (property-tested); the
/// `Jagged` path exists for the equivalence suite and the benches'
/// pre-flat baseline axis.
pub fn max_concurrent_repr(
    inst: &McfInstance,
    kind: SolverKind,
    warm: Option<&[Vec<f64>]>,
    repr: SolverRepr,
) -> Option<McfSolution> {
    // Guard: every active group needs at least one path whose bottleneck
    // clears the degeneracy floor (gray-failure residuals count as down).
    let mut any = false;
    for (_, g) in inst.active_groups() {
        any = true;
        let ok = g
            .paths
            .iter()
            .any(|p| !p.is_empty() && p.iter().all(|&e| inst.cap[e] > gk::MIN_CAP));
        if !ok {
            return None;
        }
    }
    if !any {
        return None;
    }
    let sol = match kind {
        SolverKind::Simplex => solve_simplex(inst)?,
        SolverKind::Gk => match repr {
            SolverRepr::Flat => gk::solve_warm(inst, gk::DEFAULT_EPSILON, warm)?,
            SolverRepr::Jagged => gk::solve_warm_jagged(inst, gk::DEFAULT_EPSILON, warm)?,
        },
    };
    // `McfInstance::check` scans every (group, path, edge) triple — debug
    // builds and tests only, never the release round hot path.
    #[cfg(debug_assertions)]
    if let Err(e) = inst.check(&sol, 1e-6) {
        panic!("solver returned an invalid solution: {e}");
    }
    Some(sol)
}

/// Exact path-based formulation via the dense simplex.
pub fn solve_simplex(inst: &McfInstance) -> Option<McfSolution> {
    use simplex::{Cmp, Lp};
    // Variables: x_{k,p} laid out group-major, then λ last.
    let sizes: Vec<usize> = inst.groups.iter().map(|g| g.paths.len()).collect();
    let offsets: Vec<usize> = sizes
        .iter()
        .scan(0usize, |acc, s| {
            let o = *acc;
            *acc += s;
            Some(o)
        })
        .collect();
    let nx: usize = sizes.iter().sum();
    let n = nx + 1;
    let lam = nx;
    let mut lp = Lp::new(n);
    lp.objective[lam] = 1.0;
    // Group progress: sum_p x_{k,p} - v_k λ = 0 for active groups;
    // x_{k,p} = 0 rows are implicit (vars stay 0 since they only appear in
    // capacity rows; but pin them for zero-volume groups).
    for (k, g) in inst.groups.iter().enumerate() {
        if g.volume > 0.0 {
            let mut row = vec![0.0; n];
            for p in 0..g.paths.len() {
                row[offsets[k] + p] = 1.0;
            }
            row[lam] = -g.volume;
            lp.constrain(row, Cmp::Eq, 0.0);
        } else {
            for p in 0..g.paths.len() {
                let mut row = vec![0.0; n];
                row[offsets[k] + p] = 1.0;
                lp.constrain(row, Cmp::Le, 0.0);
            }
        }
    }
    // Capacity rows (only for edges actually used by some path).
    let mut edge_vars: std::collections::HashMap<EdgeId, Vec<usize>> = Default::default();
    for (k, g) in inst.groups.iter().enumerate() {
        for (p, path) in g.paths.iter().enumerate() {
            for &e in path {
                edge_vars.entry(e).or_default().push(offsets[k] + p);
            }
        }
    }
    for (e, vars) in &edge_vars {
        let mut row = vec![0.0; n];
        for &v in vars {
            row[v] += 1.0;
        }
        lp.constrain(row, Cmp::Le, inst.cap[*e]);
    }
    let sol = lp.solve().ok()?;
    let lambda = sol.x[lam];
    if !(lambda.is_finite() && lambda > 0.0) {
        return None;
    }
    let mut rates = Vec::with_capacity(inst.groups.len());
    for (k, g) in inst.groups.iter().enumerate() {
        rates.push(sol.x[offsets[k]..offsets[k] + g.paths.len()].to_vec());
    }
    Some(McfSolution { lambda, rates })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fig 1a WAN: edges 0:A->B 1:B->A 2:B->C 3:C->B 4:A->C 5:C->A, 10 Gbps.
    fn fig1a_caps() -> Vec<f64> {
        vec![10.0; 6]
    }

    fn paths_a_to_b() -> Vec<Vec<EdgeId>> {
        vec![vec![0], vec![4, 3]] // direct, via C
    }

    #[test]
    fn single_group_multipath_uses_both_paths() {
        // Coflow-1 of Fig 1: 5 GB = 40 Gbit from A to B; with both paths it
        // can get 20 Gbps total => Γ = 2 s.
        let inst = McfInstance {
            cap: fig1a_caps(),
            groups: vec![GroupDemand { volume: 40.0, paths: paths_a_to_b() }],
        };
        let sol = max_concurrent(&inst, SolverKind::Simplex).unwrap();
        assert!((sol.gamma() - 2.0).abs() < 1e-6, "gamma={}", sol.gamma());
        inst.check(&sol, 1e-7).unwrap();
    }

    #[test]
    fn two_groups_share_capacity() {
        // Two equal groups A->B; each can use both paths: total 20 Gbps
        // shared by demand => each gets 10, λ = 10/40.
        let g = GroupDemand { volume: 40.0, paths: paths_a_to_b() };
        let inst = McfInstance { cap: fig1a_caps(), groups: vec![g.clone(), g] };
        let sol = max_concurrent(&inst, SolverKind::Simplex).unwrap();
        assert!((sol.gamma() - 4.0).abs() < 1e-6);
        inst.check(&sol, 1e-7).unwrap();
    }

    #[test]
    fn no_path_infeasible() {
        let inst = McfInstance {
            cap: vec![0.0; 6],
            groups: vec![GroupDemand { volume: 1.0, paths: vec![vec![0]] }],
        };
        assert!(max_concurrent(&inst, SolverKind::Simplex).is_none());
    }

    #[test]
    fn zero_volume_groups_get_zero_rates() {
        let inst = McfInstance {
            cap: fig1a_caps(),
            groups: vec![
                GroupDemand { volume: 40.0, paths: paths_a_to_b() },
                GroupDemand { volume: 0.0, paths: paths_a_to_b() },
            ],
        };
        let sol = max_concurrent(&inst, SolverKind::Simplex).unwrap();
        assert!(sol.rates[1].iter().sum::<f64>() < 1e-9);
        assert!((sol.gamma() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn deadline_dilation_scale() {
        let inst = McfInstance {
            cap: fig1a_caps(),
            groups: vec![GroupDemand { volume: 40.0, paths: paths_a_to_b() }],
        };
        let mut sol = max_concurrent(&inst, SolverKind::Simplex).unwrap();
        let gamma = sol.gamma();
        sol.scale(gamma / 8.0); // dilate to an 8-second deadline
        assert!((sol.gamma() - 8.0).abs() < 1e-6);
        inst.check(&sol, 1e-7).unwrap();
    }

    #[test]
    fn empty_instance_none() {
        let inst = McfInstance { cap: fig1a_caps(), groups: vec![] };
        assert!(max_concurrent(&inst, SolverKind::Simplex).is_none());
    }
}
