//! Max-min fair multi-commodity flow via progressive filling.
//!
//! Two uses in Terra:
//! 1. **Work conservation** (Pseudocode 1, lines 14–15): after all
//!    schedulable coflows got their minimum-CCT allocation, leftover
//!    capacity is distributed max-min fairly across the remaining
//!    FlowGroups, prioritizing `C_Failed`.
//! 2. The **SWAN-MCF baseline** (§6.1): an application-agnostic WAN
//!    optimizer that max-min rate-allocates every active FlowGroup.
//!
//! Progressive filling: repeatedly solve a max concurrent flow with *unit*
//! demands over the residual capacity, freeze groups that can no longer
//! grow (every usable path crosses a saturated edge), subtract, repeat.

use super::flat::{FlatMcf, GkScratch};
use super::gk::Warm;
use super::{gk, GroupDemand, McfInstance, SolverRepr};

/// Rates per group per path (Gbps) — same layout as the instance's paths.
pub type Rates = Vec<Vec<f64>>;

/// Compute a max-min fair rate allocation for `groups` over `cap`.
/// `weights` biases fairness (rate_k proportional to weight under
/// contention); pass 1.0 for plain max-min. Groups with no usable path get
/// zero rate (not an error — work conservation must be best-effort).
pub fn max_min_rates(cap: &[f64], groups: &[GroupDemand], weights: &[f64]) -> Rates {
    max_min_rates_with(cap, groups, weights, SolverRepr::Flat)
}

/// [`max_min_rates`] with an explicit GK representation for the per-level
/// solves (results are bit-identical either way; `Jagged` exists so the
/// scaling benches can measure the full pre-flat pipeline).
pub fn max_min_rates_with(
    cap: &[f64],
    groups: &[GroupDemand],
    weights: &[f64],
    repr: SolverRepr,
) -> Rates {
    // Fast exact path: when every group is pinned to (at most) one path —
    // the per-flow/Varys single-path baselines — classic weighted
    // water-filling is exact and O(E·K) per level.
    if groups.iter().all(|g| g.paths.len() <= 1) {
        return water_fill_single_path(cap, groups, weights);
    }
    let mut residual = cap.to_vec();
    let mut rates: Rates = groups.iter().map(|g| vec![0.0; g.paths.len()]).collect();
    // Usability must match the GK solver's degeneracy floor: a group kept
    // "active" here on a residual the solver treats as down would make the
    // unit-demand solve infeasible and end filling for everyone.
    let mut active: Vec<usize> = (0..groups.len())
        .filter(|&k| {
            groups[k].volume > 0.0
                && groups[k]
                    .paths
                    .iter()
                    .any(|p| !p.is_empty() && p.iter().all(|&e| residual[e] > gk::MIN_CAP))
        })
        .collect();

    // Each round raises all active groups' rates by a common (weighted)
    // increment until some group saturates. Exact max-min needs up to
    // |groups| rounds; capping at MAX_FILL_ROUNDS loses <1% allocated
    // volume in practice (each round freezes at least one bottleneck
    // level) and keeps scheduling rounds fast.
    const MAX_FILL_ROUNDS: usize = 12;
    let mut first_lambda: Option<f64> = None;
    for _round in 0..groups.len().clamp(1, MAX_FILL_ROUNDS) {
        if active.is_empty() {
            break;
        }
        // Unit-demand (weighted) concurrent flow on the residual network.
        let inst = McfInstance {
            cap: residual.clone(),
            groups: active
                .iter()
                .map(|&k| GroupDemand { volume: weights[k], paths: groups[k].paths.clone() })
                .collect(),
        };
        let sol = match repr {
            SolverRepr::Flat => gk::solve(&inst, 0.05),
            SolverRepr::Jagged => gk::solve_warm_jagged(&inst, 0.05, None),
        };
        let Some(sol) = sol else { break };
        if sol.lambda <= 1e-9 {
            break;
        }
        // Diminishing returns: later levels add tiny increments.
        match first_lambda {
            None => first_lambda = Some(sol.lambda),
            Some(l0) if sol.lambda < 5e-3 * l0 => break,
            _ => {}
        }
        // Apply the increment and update residuals.
        for (i, &k) in active.iter().enumerate() {
            for (p, &r) in sol.rates[i].iter().enumerate() {
                rates[k][p] += r;
                for &e in &groups[k].paths[p] {
                    residual[e] = (residual[e] - r).max(0.0);
                }
            }
        }
        // Freeze groups with no remaining headroom on any path.
        active.retain(|&k| {
            groups[k]
                .paths
                .iter()
                .any(|p| !p.is_empty() && p.iter().all(|&e| residual[e] > gk::MIN_CAP))
        });
    }
    rates
}

/// Exact weighted max-min fairness when each group follows one fixed path:
/// progressively raise the common per-weight rate, freeze the groups
/// crossing each successive bottleneck edge.
fn water_fill_single_path(cap: &[f64], groups: &[GroupDemand], weights: &[f64]) -> Rates {
    let mut rates: Rates = groups.iter().map(|g| vec![0.0; g.paths.len()]).collect();
    let mut residual = cap.to_vec();
    let mut active: Vec<usize> = (0..groups.len())
        .filter(|&k| {
            groups[k].volume > 0.0
                && groups[k]
                    .paths
                    .first()
                    .map(|p| !p.is_empty() && p.iter().all(|&e| residual[e] > 1e-9))
                    .unwrap_or(false)
        })
        .collect();
    while !active.is_empty() {
        // Weighted load per edge.
        let mut load = vec![0.0f64; cap.len()];
        for &k in &active {
            for &e in &groups[k].paths[0] {
                load[e] += weights[k];
            }
        }
        // Tightest edge determines the next common increment per weight.
        let mut inc = f64::INFINITY;
        for (e, &l) in load.iter().enumerate() {
            if l > 1e-12 {
                inc = inc.min(residual[e] / l);
            }
        }
        if !inc.is_finite() || inc <= 1e-12 {
            break;
        }
        for &k in &active {
            rates[k][0] += weights[k] * inc;
            for &e in &groups[k].paths[0] {
                residual[e] = (residual[e] - weights[k] * inc).max(0.0);
            }
        }
        // Freeze groups touching a saturated edge.
        active.retain(|&k| groups[k].paths[0].iter().all(|&e| residual[e] > 1e-9));
    }
    rates
}

/// Flat-core progressive filling: the same algorithm as [`max_min_rates`],
/// executed on a prebuilt [`FlatMcf`] with reusable GK scratch. The instance
/// is built **once** (block concatenation in the workspace) and every
/// filling level reuses it — per-level work is zeroing volumes, one flat GK
/// solve, and in-place residual updates on the local capacity array,
/// instead of cloning every path list and the global capacity vector into a
/// fresh `McfInstance` per level.
///
/// `flat.vols` carries the demand volumes (activity filter), `flat.cap` the
/// leftover capacities; both are **consumed** — `cap` becomes the residual
/// and `vols` is used as the per-level working volume. `weights` biases
/// fairness exactly as in [`max_min_rates`]. Bit-identical to the jagged
/// implementation (pinned by `tests/prop_flat_solver.rs`): solving the full
/// instance with frozen groups' volumes zeroed performs the same
/// floating-point ops as the jagged per-level sub-instance, because GK
/// skips zero-volume groups everywhere and their zero rates add exactly
/// `0.0` to every usage accumulation.
pub fn max_min_rates_ws(flat: &mut FlatMcf, weights: &[f64], gk_ws: &mut GkScratch) -> Rates {
    let ng = flat.num_groups();
    // Fast exact path: when every group is pinned to (at most) one path —
    // the per-flow/Varys single-path baselines — classic weighted
    // water-filling is exact and O(E·K) per level.
    if (0..ng).all(|k| flat.paths(k).len() <= 1) {
        return water_fill_single_path_flat(flat, weights);
    }
    let mut rates: Rates = (0..ng).map(|k| vec![0.0; flat.paths(k).len()]).collect();
    // Usability must match the GK solver's degeneracy floor (see
    // `max_min_rates`).
    let mut active: Vec<usize> = (0..ng)
        .filter(|&k| {
            flat.vols[k] > 0.0
                && flat.paths(k).any(|p| {
                    let es = flat.edges(p);
                    !es.is_empty() && es.iter().all(|&e| flat.cap[e as usize] > gk::MIN_CAP)
                })
        })
        .collect();

    const MAX_FILL_ROUNDS: usize = 12;
    let mut first_lambda: Option<f64> = None;
    for _round in 0..ng.clamp(1, MAX_FILL_ROUNDS) {
        if active.is_empty() {
            break;
        }
        // Unit-demand (weighted) concurrent flow on the residual network:
        // frozen groups solve with zero volume (≡ excluded).
        for v in flat.vols.iter_mut() {
            *v = 0.0;
        }
        for &k in &active {
            flat.vols[k] = weights[k];
        }
        let Some(sol) = gk::solve_flat(flat, 0.05, Warm::None, gk_ws) else { break };
        if sol.lambda <= 1e-9 {
            break;
        }
        // Diminishing returns: later levels add tiny increments.
        match first_lambda {
            None => first_lambda = Some(sol.lambda),
            Some(l0) if sol.lambda < 5e-3 * l0 => break,
            _ => {}
        }
        // Apply the increment and update residuals in place (edge ids read
        // straight from the CSR field so the capacity array can be mutated
        // alongside — `FlatMcf::edges` would borrow the whole struct).
        for &k in &active {
            for (i, p) in flat.paths(k).enumerate() {
                let r = sol.rates[k][i];
                rates[k][i] += r;
                let (lo, hi) = (flat.path_off[p] as usize, flat.path_off[p + 1] as usize);
                for &e in &flat.path_edges[lo..hi] {
                    let c = &mut flat.cap[e as usize];
                    *c = (*c - r).max(0.0);
                }
            }
        }
        // Freeze groups with no remaining headroom on any path.
        active.retain(|&k| {
            flat.paths(k).any(|p| {
                let es = flat.edges(p);
                !es.is_empty() && es.iter().all(|&e| flat.cap[e as usize] > gk::MIN_CAP)
            })
        });
    }
    rates
}

/// Flat mirror of [`water_fill_single_path`] (identical thresholds and op
/// order; load accumulation and the increment minimum run over the dense
/// local edge universe, ascending in global-id order).
fn water_fill_single_path_flat(flat: &mut FlatMcf, weights: &[f64]) -> Rates {
    let ng = flat.num_groups();
    let ne = flat.num_edges();
    let mut rates: Rates = (0..ng).map(|k| vec![0.0; flat.paths(k).len()]).collect();
    let mut active: Vec<usize> = (0..ng)
        .filter(|&k| {
            flat.vols[k] > 0.0
                && flat
                    .paths(k)
                    .next()
                    .map(|p| {
                        let es = flat.edges(p);
                        !es.is_empty() && es.iter().all(|&e| flat.cap[e as usize] > 1e-9)
                    })
                    .unwrap_or(false)
        })
        .collect();
    let mut load = vec![0.0f64; ne];
    while !active.is_empty() {
        // Weighted load per edge.
        load.iter_mut().for_each(|l| *l = 0.0);
        for &k in &active {
            let p = flat.paths(k).start;
            for &e in flat.edges(p) {
                load[e as usize] += weights[k];
            }
        }
        // Tightest edge determines the next common increment per weight.
        let mut inc = f64::INFINITY;
        for (e, &l) in load.iter().enumerate() {
            if l > 1e-12 {
                inc = inc.min(flat.cap[e] / l);
            }
        }
        if !inc.is_finite() || inc <= 1e-12 {
            break;
        }
        for &k in &active {
            rates[k][0] += weights[k] * inc;
            let p = flat.paths(k).start;
            let (lo, hi) = (flat.path_off[p] as usize, flat.path_off[p + 1] as usize);
            for &e in &flat.path_edges[lo..hi] {
                let c = &mut flat.cap[e as usize];
                *c = (*c - weights[k] * inc).max(0.0);
            }
        }
        // Freeze groups touching a saturated edge.
        active.retain(|&k| {
            let p = flat.paths(k).start;
            flat.edges(p).iter().all(|&e| flat.cap[e as usize] > 1e-9)
        });
    }
    rates
}

/// Total rate per group.
pub fn group_rates(rates: &Rates) -> Vec<f64> {
    rates.iter().map(|g| g.iter().sum()).collect()
}

/// Level 1 of **two-level floor filling** for rate-floor service classes
/// (streaming coflows with minimum-rate requirements): reserve each
/// group's floor against `cap` *before* batch max-min filling distributes
/// the surplus (level 2 = the existing [`max_min_rates`] family on the
/// residual).
///
/// For each group in order, the floor is water-filled across its paths in
/// path order (greedy: each path takes as much of the outstanding floor as
/// its bottleneck residual allows) and subtracted from `cap` in place.
/// **Infeasible floors are not silently clamped**: whatever part of a
/// floor did not fit is returned as that group's shortfall (Gbps), so the
/// caller can surface it as an SLO violation while the reservation still
/// takes everything that *was* available.
///
/// Groups with `floor <= 0` are untouched and `cap` is not written for
/// them, so an all-zero floor vector leaves `cap` bit-identical — the
/// structural-inertness guarantee the class-free path relies on.
///
/// Returns `(reserved, shortfall)`: per-group per-path reserved Gbps
/// (same layout as [`Rates`]) and per-group unmet floor Gbps.
pub fn reserve_floors(
    cap: &mut [f64],
    groups: &[GroupDemand],
    floors: &[f64],
) -> (Rates, Vec<f64>) {
    let mut reserved: Rates = groups.iter().map(|g| vec![0.0; g.paths.len()]).collect();
    let mut shortfall = vec![0.0; groups.len()];
    for (k, g) in groups.iter().enumerate() {
        let floor = floors.get(k).copied().unwrap_or(0.0);
        if floor <= 0.0 || g.volume <= 0.0 {
            continue;
        }
        let mut need = floor;
        for (pi, p) in g.paths.iter().enumerate() {
            if need <= 1e-12 {
                break;
            }
            if p.is_empty() {
                continue;
            }
            // Bottleneck residual along this path; MIN_CAP-aligned with the
            // GK solver's degeneracy floor so a reservation never leaves an
            // edge the level-2 solve would treat as up but we drained dry.
            let avail = p.iter().map(|&e| cap[e]).fold(f64::INFINITY, f64::min);
            let take = need.min((avail - gk::MIN_CAP).max(0.0));
            if take <= 0.0 {
                continue;
            }
            reserved[k][pi] = take;
            for &e in p {
                cap[e] = (cap[e] - take).max(0.0);
            }
            need -= take;
        }
        if need > 1e-9 {
            shortfall[k] = need;
        }
    }
    (reserved, shortfall)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two groups share one 10 Gbps edge (single path each).
    #[test]
    fn equal_split_on_shared_edge() {
        let groups = vec![
            GroupDemand { volume: 1.0, paths: vec![vec![0]] },
            GroupDemand { volume: 1.0, paths: vec![vec![0]] },
        ];
        let rates = max_min_rates(&[10.0], &groups, &[1.0, 1.0]);
        let g = group_rates(&rates);
        assert!((g[0] - 5.0).abs() < 0.3, "g={g:?}");
        assert!((g[1] - 5.0).abs() < 0.3);
    }

    #[test]
    fn weighted_split() {
        let groups = vec![
            GroupDemand { volume: 1.0, paths: vec![vec![0]] },
            GroupDemand { volume: 1.0, paths: vec![vec![0]] },
        ];
        let rates = max_min_rates(&[9.0], &groups, &[2.0, 1.0]);
        let g = group_rates(&rates);
        assert!(g[0] > g[1], "g={g:?}");
        assert!((g[0] + g[1] - 9.0).abs() < 0.5);
    }

    #[test]
    fn unconstrained_group_fills_its_path() {
        // Group 0 shares edge 0 with group 1; group 1 also has private edge 1.
        let groups = vec![
            GroupDemand { volume: 1.0, paths: vec![vec![0]] },
            GroupDemand { volume: 1.0, paths: vec![vec![0], vec![1]] },
        ];
        let rates = max_min_rates(&[10.0, 10.0], &groups, &[1.0, 1.0]);
        let g = group_rates(&rates);
        // Max-min optimum: group1 takes its private edge (10), leaving the
        // shared edge to group0 (10) — no one can grow without shrinking
        // the other. Work conserving: total ≈ 20.
        assert!(g[0] + g[1] > 18.0, "g={g:?}");
        assert!(g[0] > 8.0 && g[1] > 8.0, "g={g:?}");
    }

    #[test]
    fn no_path_is_zero_not_error() {
        let groups = vec![
            GroupDemand { volume: 1.0, paths: vec![] },
            GroupDemand { volume: 1.0, paths: vec![vec![0]] },
        ];
        let rates = max_min_rates(&[10.0], &groups, &[1.0, 1.0]);
        let g = group_rates(&rates);
        assert_eq!(g[0], 0.0);
        assert!(g[1] > 9.0);
    }

    /// The flat workspace-backed filling is the same algorithm as the
    /// jagged one: identical rates, bit for bit, on both the GK path and
    /// the single-path water-fill fast path.
    #[test]
    fn flat_filling_matches_jagged() {
        let cases: Vec<(Vec<f64>, Vec<GroupDemand>)> = vec![
            // Multipath (GK levels).
            (
                vec![10.0, 10.0, 4.0],
                vec![
                    GroupDemand { volume: 3.0, paths: vec![vec![0], vec![1, 2]] },
                    GroupDemand { volume: 9.0, paths: vec![vec![1]] },
                    GroupDemand { volume: 0.0, paths: vec![vec![0]] },
                ],
            ),
            // Single-path (water-fill fast path), incl. a pathless group.
            (
                vec![9.0, 5.0],
                vec![
                    GroupDemand { volume: 1.0, paths: vec![vec![0]] },
                    GroupDemand { volume: 2.0, paths: vec![vec![0]] },
                    GroupDemand { volume: 1.0, paths: vec![] },
                    GroupDemand { volume: 1.0, paths: vec![vec![1]] },
                ],
            ),
        ];
        for (cap, groups) in cases {
            let weights: Vec<f64> = groups.iter().map(|g| g.volume.max(0.5)).collect();
            let jagged = max_min_rates(&cap, &groups, &weights);
            let inst = McfInstance { cap: cap.clone(), groups: groups.clone() };
            let mut flat = FlatMcf::from_instance(&inst);
            let mut ws = GkScratch::default();
            let flat_rates = max_min_rates_ws(&mut flat, &weights, &mut ws);
            assert_eq!(flat_rates, jagged);
        }
    }

    /// Two-level filling, level 1: floors are reserved in group order,
    /// infeasible remainders come back as shortfalls instead of clamping.
    #[test]
    fn floor_reservation_and_shortfall() {
        let groups = vec![
            GroupDemand { volume: 10.0, paths: vec![vec![0]] },
            GroupDemand { volume: 10.0, paths: vec![vec![0], vec![1, 2]] },
        ];
        let mut cap = vec![4.0, 10.0, 10.0];
        let (res, short) = reserve_floors(&mut cap, &groups, &[3.0, 5.0]);
        // Group 0 takes 3 of edge 0; group 1 gets what's left there
        // (~1 minus the MIN_CAP guard) and spills the rest onto [1,2].
        assert!((res[0][0] - 3.0).abs() < 1e-6, "res={res:?}");
        let g1: f64 = res[1].iter().sum();
        assert!((g1 - 5.0).abs() < 1e-6, "res={res:?}");
        assert!(short[0] == 0.0 && short[1] == 0.0, "short={short:?}");
        assert!(cap.iter().all(|&c| c >= 0.0));

        // Floors beyond total capacity surface as shortfall, not a clamp.
        let mut tight = vec![2.0];
        let one = vec![GroupDemand { volume: 1.0, paths: vec![vec![0]] }];
        let (res, short) = reserve_floors(&mut tight, &one, &[5.0]);
        assert!(res[0][0] < 2.0 + 1e-9);
        assert!((short[0] - (5.0 - res[0][0])).abs() < 1e-9, "short={short:?} res={res:?}");
    }

    /// Structural inertness: zero floors must not perturb capacities at
    /// all (bit-identical), so the class-free path is unchanged.
    #[test]
    fn zero_floors_leave_caps_bit_identical() {
        let groups = vec![
            GroupDemand { volume: 3.0, paths: vec![vec![0], vec![1, 2]] },
            GroupDemand { volume: 9.0, paths: vec![vec![1]] },
        ];
        let cap0 = vec![10.0, 7.5, 4.0 + 1e-13];
        let mut cap = cap0.clone();
        let (res, short) = reserve_floors(&mut cap, &groups, &[0.0, 0.0]);
        assert!(cap.iter().zip(&cap0).all(|(a, b)| a.to_bits() == b.to_bits()));
        assert!(res.iter().flatten().all(|&r| r == 0.0));
        assert!(short.iter().all(|&s| s == 0.0));
    }

    #[test]
    fn respects_capacity() {
        let groups: Vec<GroupDemand> = (0..5)
            .map(|_| GroupDemand { volume: 1.0, paths: vec![vec![0], vec![1, 2]] })
            .collect();
        let cap = vec![4.0, 6.0, 3.0];
        let rates = max_min_rates(&cap, &groups, &[1.0; 5]);
        let mut usage = vec![0.0; 3];
        for (g, group) in groups.iter().zip(&rates) {
            for (p, &r) in group.iter().enumerate() {
                for &e in &g.paths[p] {
                    usage[e] += r;
                }
            }
        }
        for (u, c) in usage.iter().zip(&cap) {
            assert!(u <= &(c + 1e-6), "usage={usage:?}");
        }
    }
}
