//! Max-min fair multi-commodity flow via progressive filling.
//!
//! Two uses in Terra:
//! 1. **Work conservation** (Pseudocode 1, lines 14–15): after all
//!    schedulable coflows got their minimum-CCT allocation, leftover
//!    capacity is distributed max-min fairly across the remaining
//!    FlowGroups, prioritizing `C_Failed`.
//! 2. The **SWAN-MCF baseline** (§6.1): an application-agnostic WAN
//!    optimizer that max-min rate-allocates every active FlowGroup.
//!
//! Progressive filling: repeatedly solve a max concurrent flow with *unit*
//! demands over the residual capacity, freeze groups that can no longer
//! grow (every usable path crosses a saturated edge), subtract, repeat.

use super::{gk, GroupDemand, McfInstance};

/// Rates per group per path (Gbps) — same layout as the instance's paths.
pub type Rates = Vec<Vec<f64>>;

/// Compute a max-min fair rate allocation for `groups` over `cap`.
/// `weights` biases fairness (rate_k proportional to weight under
/// contention); pass 1.0 for plain max-min. Groups with no usable path get
/// zero rate (not an error — work conservation must be best-effort).
pub fn max_min_rates(cap: &[f64], groups: &[GroupDemand], weights: &[f64]) -> Rates {
    // Fast exact path: when every group is pinned to (at most) one path —
    // the per-flow/Varys single-path baselines — classic weighted
    // water-filling is exact and O(E·K) per level.
    if groups.iter().all(|g| g.paths.len() <= 1) {
        return water_fill_single_path(cap, groups, weights);
    }
    let mut residual = cap.to_vec();
    let mut rates: Rates = groups.iter().map(|g| vec![0.0; g.paths.len()]).collect();
    // Usability must match the GK solver's degeneracy floor: a group kept
    // "active" here on a residual the solver treats as down would make the
    // unit-demand solve infeasible and end filling for everyone.
    let mut active: Vec<usize> = (0..groups.len())
        .filter(|&k| {
            groups[k].volume > 0.0
                && groups[k]
                    .paths
                    .iter()
                    .any(|p| !p.is_empty() && p.iter().all(|&e| residual[e] > gk::MIN_CAP))
        })
        .collect();

    // Each round raises all active groups' rates by a common (weighted)
    // increment until some group saturates. Exact max-min needs up to
    // |groups| rounds; capping at MAX_FILL_ROUNDS loses <1% allocated
    // volume in practice (each round freezes at least one bottleneck
    // level) and keeps scheduling rounds fast.
    const MAX_FILL_ROUNDS: usize = 12;
    let mut first_lambda: Option<f64> = None;
    for _round in 0..groups.len().clamp(1, MAX_FILL_ROUNDS) {
        if active.is_empty() {
            break;
        }
        // Unit-demand (weighted) concurrent flow on the residual network.
        let inst = McfInstance {
            cap: residual.clone(),
            groups: active
                .iter()
                .map(|&k| GroupDemand { volume: weights[k], paths: groups[k].paths.clone() })
                .collect(),
        };
        let Some(sol) = gk::solve(&inst, 0.05) else { break };
        if sol.lambda <= 1e-9 {
            break;
        }
        // Diminishing returns: later levels add tiny increments.
        match first_lambda {
            None => first_lambda = Some(sol.lambda),
            Some(l0) if sol.lambda < 5e-3 * l0 => break,
            _ => {}
        }
        // Apply the increment and update residuals.
        for (i, &k) in active.iter().enumerate() {
            for (p, &r) in sol.rates[i].iter().enumerate() {
                rates[k][p] += r;
                for &e in &groups[k].paths[p] {
                    residual[e] = (residual[e] - r).max(0.0);
                }
            }
        }
        // Freeze groups with no remaining headroom on any path.
        active.retain(|&k| {
            groups[k]
                .paths
                .iter()
                .any(|p| !p.is_empty() && p.iter().all(|&e| residual[e] > gk::MIN_CAP))
        });
    }
    rates
}

/// Exact weighted max-min fairness when each group follows one fixed path:
/// progressively raise the common per-weight rate, freeze the groups
/// crossing each successive bottleneck edge.
fn water_fill_single_path(cap: &[f64], groups: &[GroupDemand], weights: &[f64]) -> Rates {
    let mut rates: Rates = groups.iter().map(|g| vec![0.0; g.paths.len()]).collect();
    let mut residual = cap.to_vec();
    let mut active: Vec<usize> = (0..groups.len())
        .filter(|&k| {
            groups[k].volume > 0.0
                && groups[k]
                    .paths
                    .first()
                    .map(|p| !p.is_empty() && p.iter().all(|&e| residual[e] > 1e-9))
                    .unwrap_or(false)
        })
        .collect();
    while !active.is_empty() {
        // Weighted load per edge.
        let mut load = vec![0.0f64; cap.len()];
        for &k in &active {
            for &e in &groups[k].paths[0] {
                load[e] += weights[k];
            }
        }
        // Tightest edge determines the next common increment per weight.
        let mut inc = f64::INFINITY;
        for (e, &l) in load.iter().enumerate() {
            if l > 1e-12 {
                inc = inc.min(residual[e] / l);
            }
        }
        if !inc.is_finite() || inc <= 1e-12 {
            break;
        }
        for &k in &active {
            rates[k][0] += weights[k] * inc;
            for &e in &groups[k].paths[0] {
                residual[e] = (residual[e] - weights[k] * inc).max(0.0);
            }
        }
        // Freeze groups touching a saturated edge.
        active.retain(|&k| groups[k].paths[0].iter().all(|&e| residual[e] > 1e-9));
    }
    rates
}

/// Total rate per group.
pub fn group_rates(rates: &Rates) -> Vec<f64> {
    rates.iter().map(|g| g.iter().sum()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two groups share one 10 Gbps edge (single path each).
    #[test]
    fn equal_split_on_shared_edge() {
        let groups = vec![
            GroupDemand { volume: 1.0, paths: vec![vec![0]] },
            GroupDemand { volume: 1.0, paths: vec![vec![0]] },
        ];
        let rates = max_min_rates(&[10.0], &groups, &[1.0, 1.0]);
        let g = group_rates(&rates);
        assert!((g[0] - 5.0).abs() < 0.3, "g={g:?}");
        assert!((g[1] - 5.0).abs() < 0.3);
    }

    #[test]
    fn weighted_split() {
        let groups = vec![
            GroupDemand { volume: 1.0, paths: vec![vec![0]] },
            GroupDemand { volume: 1.0, paths: vec![vec![0]] },
        ];
        let rates = max_min_rates(&[9.0], &groups, &[2.0, 1.0]);
        let g = group_rates(&rates);
        assert!(g[0] > g[1], "g={g:?}");
        assert!((g[0] + g[1] - 9.0).abs() < 0.5);
    }

    #[test]
    fn unconstrained_group_fills_its_path() {
        // Group 0 shares edge 0 with group 1; group 1 also has private edge 1.
        let groups = vec![
            GroupDemand { volume: 1.0, paths: vec![vec![0]] },
            GroupDemand { volume: 1.0, paths: vec![vec![0], vec![1]] },
        ];
        let rates = max_min_rates(&[10.0, 10.0], &groups, &[1.0, 1.0]);
        let g = group_rates(&rates);
        // Max-min optimum: group1 takes its private edge (10), leaving the
        // shared edge to group0 (10) — no one can grow without shrinking
        // the other. Work conserving: total ≈ 20.
        assert!(g[0] + g[1] > 18.0, "g={g:?}");
        assert!(g[0] > 8.0 && g[1] > 8.0, "g={g:?}");
    }

    #[test]
    fn no_path_is_zero_not_error() {
        let groups = vec![
            GroupDemand { volume: 1.0, paths: vec![] },
            GroupDemand { volume: 1.0, paths: vec![vec![0]] },
        ];
        let rates = max_min_rates(&[10.0], &groups, &[1.0, 1.0]);
        let g = group_rates(&rates);
        assert_eq!(g[0], 0.0);
        assert!(g[1] > 9.0);
    }

    #[test]
    fn respects_capacity() {
        let groups: Vec<GroupDemand> = (0..5)
            .map(|_| GroupDemand { volume: 1.0, paths: vec![vec![0], vec![1, 2]] })
            .collect();
        let cap = vec![4.0, 6.0, 3.0];
        let rates = max_min_rates(&cap, &groups, &[1.0; 5]);
        let mut usage = vec![0.0; 3];
        for (g, group) in groups.iter().zip(&rates) {
            for (p, &r) in group.iter().enumerate() {
                for &e in &g.paths[p] {
                    usage[e] += r;
                }
            }
        }
        for (u, c) in usage.iter().zip(&cap) {
            assert!(u <= &(c + 1e-6), "usage={usage:?}");
        }
    }
}
