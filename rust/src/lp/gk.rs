//! Garg–Könemann FPTAS for maximum concurrent flow on restricted path sets.
//!
//! The controller's default solver for Optimization (1). The classical
//! algorithm (Garg & Könemann 1998, Fleischer 2000) maintains exponential
//! edge lengths `l_e` and repeatedly routes each commodity's demand along its
//! currently-shortest path; the accumulated (infeasible) flow, scaled by
//! `log_{1+ε}(1/δ)`, is a `(1-ε)³`-approximate concurrent flow.
//!
//! Terra restricts each FlowGroup to its k shortest paths (§4.3), so the
//! "shortest path under `l`" step is an argmin over ≤ k candidates rather
//! than a Dijkstra run — this is what makes scheduling rounds cheap (§6.6).
//!
//! We post-process for *exact* feasibility regardless of approximation
//! slack: usage is rescaled onto capacities, λ is set to the worst group's
//! progress, and every group is trimmed to exactly `λ·v_k` so all groups
//! finish together (the Optimization (1) equal-progress constraints).
//!
//! Two implementations of the identical algorithm live here:
//!
//! - [`solve_flat`] — the production core. It iterates a [`FlatMcf`]'s CSR
//!   arrays with all scratch in a reusable [`GkScratch`]: no per-iteration
//!   heap traffic, and no per-solve allocation once the workspace is warm
//!   (beyond the output rate matrix). [`solve`]/[`solve_warm`] wrap it for
//!   jagged [`McfInstance`] callers.
//! - [`solve_warm_jagged`] — the original jagged-`Vec` implementation, kept
//!   as the bit-for-bit reference: the `prop_flat_solver` property suite
//!   asserts `solve_flat` returns the *identical* λ and rates (f64-exact)
//!   on random instances, and the scaling bench exposes it as the
//!   `solver_repr = jagged` axis. Every floating-point operation in the
//!   flat core happens in the same order as here — local edge ids ascend in
//!   global-id order precisely so the order-sensitive `D(l)` sums match.

use super::flat::{FlatMcf, GkScratch};
use super::{McfInstance, McfSolution};

/// Default ε; gives λ within a few percent of optimal (validated against the
/// simplex in tests) at a fraction of the cost.
pub const DEFAULT_EPSILON: f64 = 0.05;

/// Minimum usable edge capacity in Gbps (1 kbps). Edges at or below this are
/// treated as down everywhere in the solver: a gray-failure residual like
/// 1e-10 Gbps must not pass the usability filter — routing a demand across
/// it produces pathological demand normalization (λ scaled by the degenerate
/// bottleneck) and exponential length updates, while contributing nothing to
/// real throughput. Applied consistently by the flat core and the jagged
/// reference (path usability and warm-rate sanitization), `quick_lambda`,
/// and `finalize`.
pub const MIN_CAP: f64 = 1e-6;

/// Warm-start source for [`solve_flat`]: the previous round's rates, either
/// already instance-group-indexed, or full-group-indexed with an
/// instance→full index map (the policy's layout — referenced in place, so
/// warm-starting copies no rate vectors).
#[derive(Clone, Copy, Debug, Default)]
pub enum Warm<'a> {
    #[default]
    None,
    /// `rates[k]` is instance group `k`'s previous path rates.
    Direct(&'a [Vec<f64>]),
    /// `(rates, index)`: instance group `k`'s rates are `rates[index[k]]`.
    Indexed(&'a [Vec<f64>], &'a [usize]),
}

impl<'a> Warm<'a> {
    #[inline]
    fn get(&self, k: usize) -> &'a [f64] {
        match self {
            Warm::None => &[],
            Warm::Direct(w) => w.get(k).map(|v| v.as_slice()).unwrap_or(&[]),
            Warm::Indexed(w, idx) => idx
                .get(k)
                .and_then(|&gi| w.get(gi))
                .map(|v| v.as_slice())
                .unwrap_or(&[]),
        }
    }

    fn is_none(&self) -> bool {
        matches!(self, Warm::None)
    }
}

/// Solve max concurrent flow. Returns `None` if some active group has no
/// path with positive capacity.
pub fn solve(inst: &McfInstance, eps: f64) -> Option<McfSolution> {
    solve_warm(inst, eps, None)
}

/// [`solve`] with an optional warm start: `warm[k][p]` is the previous
/// round's rate for group `k` on path `p` (extra/missing paths tolerated;
/// rates on now-unusable paths are dropped). The warm rates are rescaled
/// into an exactly-feasible equal-progress candidate whose λ (a) feeds the
/// duality-gap early exit — so a near-optimal warm start ends the phase
/// loop almost immediately — and (b) competes with the accumulated flow at
/// the end, so the result is never worse than a cold solve.
///
/// Convenience wrapper over [`solve_flat`] (flattens the instance and uses
/// one-shot scratch); hot paths hold a [`crate::lp::flat::SolverWorkspace`]
/// and call the flat core directly.
pub fn solve_warm(
    inst: &McfInstance,
    eps: f64,
    warm: Option<&[Vec<f64>]>,
) -> Option<McfSolution> {
    let flat = FlatMcf::from_instance(inst);
    let mut ws = GkScratch::default();
    let warm = match warm {
        Some(w) => Warm::Direct(w),
        None => Warm::None,
    };
    solve_flat(&flat, eps, warm, &mut ws)
}

/// The flat GK core: identical algorithm to [`solve_warm_jagged`], iterating
/// the instance's CSR arrays with all scratch in `ws`. Bit-identical output
/// to the jagged reference (pinned by `tests/prop_flat_solver.rs`).
pub fn solve_flat(
    flat: &FlatMcf,
    eps: f64,
    warm: Warm<'_>,
    ws: &mut GkScratch,
) -> Option<McfSolution> {
    let ng = flat.num_groups();
    let np = flat.num_paths();
    let ne = flat.num_edges();

    ws.active.clear();
    ws.active.extend((0..ng).filter(|&k| flat.vols[k] > 0.0).map(|k| k as u32));
    if ws.active.is_empty() {
        return None;
    }

    // Per-group usable paths (bottleneck above the degeneracy floor);
    // paths of inactive groups stay unusable.
    ws.usable.clear();
    ws.usable.resize(np, false);
    for &k in &ws.active {
        let mut any = false;
        for p in flat.paths(k as usize) {
            let es = flat.edges(p);
            if !es.is_empty() && es.iter().all(|&e| flat.cap[e as usize] > MIN_CAP) {
                ws.usable[p] = true;
                any = true;
            }
        }
        if !any {
            return None;
        }
    }

    // Demand normalization: GK's phase count scales with the optimal λ, so
    // solve with volumes scaled such that λ' = O(1): scale by
    // s = min_k (best path bottleneck / v_k), an upper bound on the rate
    // each group could get alone on one path. Rates are invariant; the
    // returned λ is rescaled by s at the end.
    let mut s = f64::INFINITY;
    for &k in &ws.active {
        let k = k as usize;
        let mut best_bneck = 0.0f64;
        for p in flat.paths(k) {
            if !ws.usable[p] {
                continue;
            }
            let bneck =
                flat.edges(p).iter().map(|&e| flat.cap[e as usize]).fold(f64::INFINITY, f64::min);
            best_bneck = best_bneck.max(bneck);
        }
        s = s.min(best_bneck / flat.vols[k]);
    }
    if !(s.is_finite() && s > 0.0) {
        return None;
    }
    ws.vols.clear();
    ws.vols.extend(flat.vols.iter().map(|&v| v * s));

    // Warm candidate: previous-round rates copied (not cloned per group)
    // into the flat buffer, sanitized (unusable paths and negative rates
    // zeroed), and rescaled onto the current capacities. `finalize_flat`
    // yields `None` when any active group lacks warm flow (e.g. a newly
    // arrived coflow), in which case the warm start is simply unused.
    let mut warm_lambda = 0.0f64;
    let mut have_warm_sol = false;
    if !warm.is_none() {
        ws.xw.clear();
        ws.xw.resize(np, 0.0);
        for k in 0..ng {
            let src = warm.get(k);
            for (i, p) in flat.paths(k).enumerate() {
                let r = src.get(i).copied().unwrap_or(0.0);
                let es = flat.edges(p);
                ws.xw[p] = if es.is_empty()
                    || es.iter().any(|&e| flat.cap[e as usize] <= MIN_CAP)
                    || r < 0.0
                {
                    0.0
                } else {
                    r
                };
            }
        }
        if let Some(l) = finalize_flat(flat, &ws.vols, &mut ws.xw, &mut ws.usage) {
            warm_lambda = l;
            have_warm_sol = true;
        }
    }

    // Edges that actually constrain this instance: those on some usable
    // path. Lengths, Fleischer's m, and the measure D(l) are restricted to
    // them, so the solve is a pure function of the instance's own
    // subnetwork — capacities of unrelated edges (e.g. other components'
    // residuals) cannot perturb δ or the termination test. This is what
    // makes the per-component decomposition of a round exactly equivalent
    // to the monolithic solve (see `lp::decompose`).
    ws.relevant.clear();
    ws.relevant.resize(ne, false);
    for &k in &ws.active {
        for p in flat.paths(k as usize) {
            if ws.usable[p] {
                for &e in flat.edges(p) {
                    ws.relevant[e as usize] = true;
                }
            }
        }
    }

    // Fleischer's δ with m = number of relevant capacitated edges:
    // guarantees the initial D(l) = m·δ < 1 so at least ~1/ε phases run.
    let m = ws.relevant.iter().filter(|&&r| r).count().max(1) as f64;
    let delta = (1.0 + eps) * ((1.0 + eps) * m).powf(-1.0 / eps);
    ws.len.clear();
    ws.len.extend(
        flat.cap
            .iter()
            .zip(&ws.relevant)
            .map(|(&c, &r)| if r { delta / c } else { f64::INFINITY }),
    );
    ws.x.clear();
    ws.x.resize(np, 0.0);

    // Cached path lengths; the prebuilt edge→path incidence CSR plays the
    // jagged reference's `edge_paths` role, so a length update touches only
    // the affected paths. (The incidence covers *all* paths, including
    // unusable ones — their cached lengths absorb updates but are never
    // read, so results are unaffected.)
    ws.plen.clear();
    ws.plen.extend(
        (0..np).map(|p| flat.edges(p).iter().map(|&e| ws.len[e as usize]).sum::<f64>()),
    );

    // D(l) = sum over relevant edges of l_e c_e, starting at m·δ. Local
    // edges ascend in global-id order, so this sum accumulates in exactly
    // the jagged reference's order (f64 addition is order-sensitive).
    let mut d: f64 = ws
        .len
        .iter()
        .zip(&flat.cap)
        .zip(&ws.relevant)
        .filter(|(_, &r)| r)
        .map(|((&l, &c), _)| l * c)
        .sum();

    let mut phases = 0usize;
    let max_phases = (((1.0 + eps) / delta).ln() / (1.0 + eps).ln()).ceil() as usize + 2;
    // Early termination via GK duality: for any length function l,
    // OPT <= D(l) / α(l) with α(l) = Σ_k d_k · dist_k(l). The theory runs
    // until D(l) >= 1, but the feasible λ extracted by `finalize` typically
    // reaches (1-ε)·OPT orders of magnitude sooner; checking the primal
    // against the dual bound lets us stop exactly when it does.
    while d < 1.0 && phases < max_phases {
        phases += 1;
        for &k in &ws.active {
            let k = k as usize;
            let mut remaining = ws.vols[k];
            while remaining > 1e-12 && d < 1.0 {
                // Shortest usable path under current (cached) lengths.
                let mut best_p = usize::MAX;
                let mut best_l = f64::INFINITY;
                for p in flat.paths(k) {
                    if !ws.usable[p] {
                        continue;
                    }
                    if best_p == usize::MAX || ws.plen[p] < best_l {
                        best_l = ws.plen[p];
                        best_p = p;
                    }
                }
                let es = flat.edges(best_p);
                let bottleneck =
                    es.iter().map(|&e| flat.cap[e as usize]).fold(f64::INFINITY, f64::min);
                let f = remaining.min(bottleneck);
                ws.x[best_p] += f;
                remaining -= f;
                for &e in es {
                    let e = e as usize;
                    let c = flat.cap[e];
                    let old = ws.len[e];
                    let new = old * (1.0 + eps * f / c);
                    ws.len[e] = new;
                    d += (new - old) * c;
                    let dl = new - old;
                    for &pp in flat.incident(e) {
                        ws.plen[pp as usize] += dl;
                    }
                }
            }
        }
        // Duality-gap check *after* this phase's length updates (the bound
        // is meaningless before any routing). With a warm candidate, check
        // already at the end of phase 1: one phase usually tightens the
        // dual enough to certify a near-optimal previous-round solution.
        if phases % 8 == 0 || (phases == 1 && warm_lambda > 0.0) {
            let lam = quick_lambda_flat(flat, &ws.vols, &ws.x, &mut ws.usage).max(warm_lambda);
            let alpha: f64 = ws
                .active
                .iter()
                .map(|&k| {
                    let k = k as usize;
                    let mut dist = f64::INFINITY;
                    for p in flat.paths(k) {
                        if ws.usable[p] {
                            dist = dist.min(ws.plen[p]);
                        }
                    }
                    ws.vols[k] * dist
                })
                .sum();
            if alpha > 0.0 && lam >= (d / alpha) * (1.0 - 0.75 * eps) {
                break;
            }
        }
    }

    // Return the better of the accumulated flow and the warm candidate —
    // both are exactly-feasible equal-progress allocations.
    let acc_lambda = finalize_flat(flat, &ws.vols, &mut ws.x, &mut ws.usage);
    let (lambda_scaled, rates_buf): (f64, &Vec<f64>) = match (acc_lambda, have_warm_sol) {
        (Some(a), true) => {
            if warm_lambda > a {
                (warm_lambda, &ws.xw)
            } else {
                (a, &ws.x)
            }
        }
        (Some(a), false) => (a, &ws.x),
        (None, true) => (warm_lambda, &ws.xw),
        (None, false) => return None,
    };
    // Undo the demand normalization: rates already satisfy
    // Σ_p rate = λ_scaled · (s·v_k), so the real progress rate is λ_scaled·s.
    let lambda = lambda_scaled * s;
    let rates = flat.rates_to_jagged(rates_buf);
    #[cfg(debug_assertions)]
    {
        // Feasibility self-check mirroring `McfInstance::check`, on the
        // local edge universe (debug builds only — never the release hot
        // path).
        let mut usage = vec![0.0; ne];
        for (p, &r) in rates_buf.iter().enumerate() {
            for &e in flat.edges(p) {
                usage[e as usize] += r;
            }
        }
        for (e, (&u, &c)) in usage.iter().zip(&flat.cap).enumerate() {
            debug_assert!(
                u <= c + 1e-6 * (1.0 + c),
                "flat GK oversubscribed local edge {e}: {u} > {c}"
            );
        }
    }
    Some(McfSolution { lambda, rates })
}

/// Feasible λ extractable from raw accumulated flow `x` (the same
/// computation `finalize_flat` performs, without trimming the rates).
/// Degenerate capacities (≤ [`MIN_CAP`]) count as zero: any usage on them
/// collapses θ — consistent with the usability filter treating them as down.
fn quick_lambda_flat(flat: &FlatMcf, vols: &[f64], x: &[f64], usage: &mut Vec<f64>) -> f64 {
    fill_usage(flat, x, usage);
    let mut theta = f64::INFINITY;
    for (&u, &c) in usage.iter().zip(&flat.cap) {
        if u > 1e-12 {
            theta = theta.min(if c > MIN_CAP { c / u } else { 0.0 });
        }
    }
    if !theta.is_finite() {
        return 0.0;
    }
    let mut lambda = f64::INFINITY;
    for (k, &v) in vols.iter().enumerate() {
        if v > 0.0 {
            let routed: f64 = x[flat.paths(k)].iter().sum();
            lambda = lambda.min(theta * routed / v);
        }
    }
    if lambda.is_finite() {
        lambda
    } else {
        0.0
    }
}

/// Rescale raw (possibly capacity-violating) flat path volumes `x` in place
/// into a feasible equal-progress rate allocation (in terms of the working
/// volumes `vols`), returning its λ. Degenerate capacities (≤ [`MIN_CAP`])
/// count as zero, mirroring the usability filter: flow routed over such an
/// edge makes the candidate infeasible rather than near-infinitely slow.
fn finalize_flat(flat: &FlatMcf, vols: &[f64], x: &mut [f64], usage: &mut Vec<f64>) -> Option<f64> {
    fill_usage(flat, x, usage);
    let mut theta = f64::INFINITY;
    for (&u, &c) in usage.iter().zip(&flat.cap) {
        if u > 1e-12 {
            theta = theta.min(if c > MIN_CAP { c / u } else { 0.0 });
        }
    }
    if !(theta.is_finite() && theta > 0.0) {
        return None;
    }
    // λ = worst group progress after scaling.
    let mut lambda = f64::INFINITY;
    for (k, &v) in vols.iter().enumerate() {
        if v > 0.0 {
            let routed: f64 = x[flat.paths(k)].iter().sum();
            lambda = lambda.min(theta * routed / v);
        }
    }
    if !(lambda.is_finite() && lambda > 0.0) {
        return None;
    }
    // Trim every group to exactly λ·v_k.
    for (k, &v) in vols.iter().enumerate() {
        let pr = flat.paths(k);
        let routed: f64 = x[pr.clone()].iter().sum();
        // factor ≤ theta by construction of λ, so capacities hold.
        let factor = if v > 0.0 && routed > 0.0 { lambda * v / routed } else { 0.0 };
        for r in &mut x[pr] {
            *r *= factor;
        }
    }
    Some(lambda)
}

/// Per-local-edge usage of a flat path rate vector (the flat counterpart of
/// `McfInstance::edge_usage` — fills a reused buffer instead of allocating a
/// global-edge-count `Vec` per call).
#[inline]
fn fill_usage(flat: &FlatMcf, x: &[f64], usage: &mut Vec<f64>) {
    usage.clear();
    usage.resize(flat.num_edges(), 0.0);
    for (p, &r) in x.iter().enumerate() {
        for &e in flat.edges(p) {
            usage[e as usize] += r;
        }
    }
}

/// The original jagged-`Vec` GK implementation, kept as the bit-for-bit
/// reference for [`solve_flat`] (property-tested equal) and as the
/// `solver_repr = jagged` axis of the scaling benches. Semantics are
/// documented on [`solve_warm`].
pub fn solve_warm_jagged(
    inst: &McfInstance,
    eps: f64,
    warm: Option<&[Vec<f64>]>,
) -> Option<McfSolution> {
    let active: Vec<usize> =
        inst.groups.iter().enumerate().filter(|(_, g)| g.volume > 0.0).map(|(k, _)| k).collect();
    if active.is_empty() {
        return None;
    }

    // Per-group usable paths (bottleneck above the degeneracy floor).
    let mut usable: Vec<Vec<usize>> = vec![Vec::new(); inst.groups.len()];
    for &k in &active {
        for (p, path) in inst.groups[k].paths.iter().enumerate() {
            if !path.is_empty() && path.iter().all(|&e| inst.cap[e] > MIN_CAP) {
                usable[k].push(p);
            }
        }
        if usable[k].is_empty() {
            return None;
        }
    }

    // Demand normalization (see solve_flat).
    let mut s = f64::INFINITY;
    for &k in &active {
        let g = &inst.groups[k];
        let best_bneck = usable[k]
            .iter()
            .map(|&p| g.paths[p].iter().map(|&e| inst.cap[e]).fold(f64::INFINITY, f64::min))
            .fold(0.0f64, f64::max);
        s = s.min(best_bneck / g.volume);
    }
    if !(s.is_finite() && s > 0.0) {
        return None;
    }
    let vols: Vec<f64> = inst.groups.iter().map(|g| g.volume * s).collect();

    // Warm candidate, copied (not cloned-then-resized) into place.
    let warm_sol: Option<McfSolution> = warm.and_then(|w| {
        let mut xw: Vec<Vec<f64>> = Vec::with_capacity(inst.groups.len());
        for (k, g) in inst.groups.iter().enumerate() {
            let src = w.get(k).map(|v| v.as_slice()).unwrap_or(&[]);
            let mut v = vec![0.0; g.paths.len()];
            for (p, r) in v.iter_mut().enumerate() {
                let path = &g.paths[p];
                let warm_r = src.get(p).copied().unwrap_or(0.0);
                *r = if path.is_empty()
                    || path.iter().any(|&e| inst.cap[e] <= MIN_CAP)
                    || warm_r < 0.0
                {
                    0.0
                } else {
                    warm_r
                };
            }
            xw.push(v);
        }
        finalize(inst, &vols, xw)
    });
    let warm_lambda = warm_sol.as_ref().map(|sol| sol.lambda).unwrap_or(0.0);

    // Relevant edges, δ, lengths (see solve_flat).
    let mut relevant = vec![false; inst.cap.len()];
    for &k in &active {
        for &p in &usable[k] {
            for &e in &inst.groups[k].paths[p] {
                relevant[e] = true;
            }
        }
    }
    let m = relevant.iter().filter(|&&r| r).count().max(1) as f64;
    let delta = (1.0 + eps) * ((1.0 + eps) * m).powf(-1.0 / eps);
    let mut len: Vec<f64> = inst
        .cap
        .iter()
        .zip(&relevant)
        .map(|(&c, &r)| if r { delta / c } else { f64::INFINITY })
        .collect();
    let mut x: Vec<Vec<f64>> = inst.groups.iter().map(|g| vec![0.0; g.paths.len()]).collect();

    // Cached path lengths + reverse index edge -> (group, path).
    let mut plen: Vec<Vec<f64>> = inst
        .groups
        .iter()
        .map(|g| g.paths.iter().map(|p| p.iter().map(|&e| len[e]).sum()).collect())
        .collect();
    let mut edge_paths: Vec<Vec<(u32, u32)>> = vec![Vec::new(); inst.cap.len()];
    for &k in &active {
        for &p in &usable[k] {
            for &e in &inst.groups[k].paths[p] {
                edge_paths[e].push((k as u32, p as u32));
            }
        }
    }

    // D(l) = sum over relevant edges of l_e c_e, starting at m·δ.
    let mut d: f64 = len
        .iter()
        .zip(&inst.cap)
        .zip(&relevant)
        .filter(|(_, &r)| r)
        .map(|((&l, &c), _)| l * c)
        .sum();

    let mut phases = 0usize;
    let max_phases = (((1.0 + eps) / delta).ln() / (1.0 + eps).ln()).ceil() as usize + 2;
    while d < 1.0 && phases < max_phases {
        phases += 1;
        for &k in &active {
            let mut remaining = vols[k];
            while remaining > 1e-12 && d < 1.0 {
                let g = &inst.groups[k];
                let mut best_p = usable[k][0];
                let mut best_l = plen[k][best_p];
                for &p in &usable[k][1..] {
                    if plen[k][p] < best_l {
                        best_l = plen[k][p];
                        best_p = p;
                    }
                }
                let path = &g.paths[best_p];
                let bottleneck =
                    path.iter().map(|&e| inst.cap[e]).fold(f64::INFINITY, f64::min);
                let f = remaining.min(bottleneck);
                x[k][best_p] += f;
                remaining -= f;
                for &e in path {
                    let old = len[e];
                    let new = old * (1.0 + eps * f / inst.cap[e]);
                    len[e] = new;
                    d += (new - old) * inst.cap[e];
                    let dl = new - old;
                    for &(pk, pp) in &edge_paths[e] {
                        plen[pk as usize][pp as usize] += dl;
                    }
                }
            }
        }
        if phases % 8 == 0 || (phases == 1 && warm_lambda > 0.0) {
            let lam = quick_lambda(inst, &vols, &x).max(warm_lambda);
            let alpha: f64 = active
                .iter()
                .map(|&k| {
                    let dist =
                        usable[k].iter().map(|&p| plen[k][p]).fold(f64::INFINITY, f64::min);
                    vols[k] * dist
                })
                .sum();
            if alpha > 0.0 && lam >= (d / alpha) * (1.0 - 0.75 * eps) {
                break;
            }
        }
    }

    let acc_sol = finalize(inst, &vols, x);
    let mut sol = match (acc_sol, warm_sol) {
        (Some(a), Some(w)) => {
            if w.lambda > a.lambda {
                w
            } else {
                a
            }
        }
        (Some(a), None) => a,
        (None, Some(w)) => w,
        (None, None) => return None,
    };
    sol.lambda *= s;
    Some(sol)
}

/// Feasible λ extractable from raw accumulated flow `x` (jagged reference).
fn quick_lambda(inst: &McfInstance, vols: &[f64], x: &[Vec<f64>]) -> f64 {
    let usage = inst.edge_usage(x);
    let mut theta = f64::INFINITY;
    for (&u, &c) in usage.iter().zip(&inst.cap) {
        if u > 1e-12 {
            theta = theta.min(if c > MIN_CAP { c / u } else { 0.0 });
        }
    }
    if !theta.is_finite() {
        return 0.0;
    }
    let mut lambda = f64::INFINITY;
    for (k, &v) in vols.iter().enumerate() {
        if v > 0.0 {
            let routed: f64 = x[k].iter().sum();
            lambda = lambda.min(theta * routed / v);
        }
    }
    if lambda.is_finite() {
        lambda
    } else {
        0.0
    }
}

/// Rescale raw path volumes into a feasible equal-progress rate allocation
/// (jagged reference; see `finalize_flat`).
fn finalize(inst: &McfInstance, vols: &[f64], x: Vec<Vec<f64>>) -> Option<McfSolution> {
    let usage = inst.edge_usage(&x);
    let mut theta = f64::INFINITY;
    for (&u, &c) in usage.iter().zip(&inst.cap) {
        if u > 1e-12 {
            theta = theta.min(if c > MIN_CAP { c / u } else { 0.0 });
        }
    }
    if !(theta.is_finite() && theta > 0.0) {
        return None;
    }
    let mut lambda = f64::INFINITY;
    for (k, &v) in vols.iter().enumerate() {
        if v > 0.0 {
            let routed: f64 = x[k].iter().sum();
            lambda = lambda.min(theta * routed / v);
        }
    }
    if !(lambda.is_finite() && lambda > 0.0) {
        return None;
    }
    let mut rates = x;
    for (k, &v) in vols.iter().enumerate() {
        let routed: f64 = rates[k].iter().sum();
        let factor = if v > 0.0 && routed > 0.0 { lambda * v / routed } else { 0.0 };
        for r in &mut rates[k] {
            *r *= factor;
        }
    }
    Some(McfSolution { lambda, rates })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::{solve_simplex, GroupDemand};
    use crate::util::rng::Pcg32;

    fn fig1a_inst(volumes: &[f64]) -> McfInstance {
        // edges 0:A->B 1:B->A 2:B->C 3:C->B 4:A->C 5:C->A @10
        let paths = vec![vec![0], vec![4, 3]];
        McfInstance {
            cap: vec![10.0; 6],
            groups: volumes
                .iter()
                .map(|&v| GroupDemand { volume: v, paths: paths.clone() })
                .collect(),
        }
    }

    #[test]
    fn matches_simplex_single_group() {
        let inst = fig1a_inst(&[40.0]);
        let gk = solve(&inst, 0.02).unwrap();
        let sx = solve_simplex(&inst).unwrap();
        assert!(
            (gk.lambda - sx.lambda).abs() / sx.lambda < 0.05,
            "gk={} simplex={}",
            gk.lambda,
            sx.lambda
        );
        inst.check(&gk, 1e-7).unwrap();
    }

    #[test]
    fn random_instances_close_to_simplex_and_feasible() {
        let mut rng = Pcg32::new(123);
        for trial in 0..25 {
            // Random small WAN: 4 nodes full mesh = 12 directed edges; paths
            // are direct or 2-hop.
            let ne = 12;
            let cap: Vec<f64> = (0..ne).map(|_| rng.uniform(1.0, 20.0)).collect();
            let edge = |u: usize, v: usize| -> usize {
                // pairs (u,v), u != v, lexicographic
                let mut i = 0;
                for a in 0..4 {
                    for b in 0..4 {
                        if a != b {
                            if a == u && b == v {
                                return i;
                            }
                            i += 1;
                        }
                    }
                }
                unreachable!()
            };
            let ng = 1 + rng.below(4);
            let mut groups = Vec::new();
            for _ in 0..ng {
                let s = rng.below(4);
                let mut t = rng.below(4);
                while t == s {
                    t = rng.below(4);
                }
                let mut paths = vec![vec![edge(s, t)]];
                for via in 0..4 {
                    if via != s && via != t {
                        paths.push(vec![edge(s, via), edge(via, t)]);
                    }
                }
                groups.push(GroupDemand { volume: rng.uniform(1.0, 50.0), paths });
            }
            let inst = McfInstance { cap, groups };
            let sx = solve_simplex(&inst).expect("simplex solves");
            let gk = solve(&inst, 0.02).expect("gk solves");
            inst.check(&gk, 1e-7).unwrap();
            assert!(
                gk.lambda >= sx.lambda * 0.90 && gk.lambda <= sx.lambda * (1.0 + 1e-6),
                "trial {trial}: gk={} simplex={}",
                gk.lambda,
                sx.lambda
            );
            // The flat core and the jagged reference are the same algorithm
            // executed in the same op order: results must be bit-identical.
            let jag = solve_warm_jagged(&inst, 0.02, None).expect("jagged solves");
            assert_eq!(gk.lambda.to_bits(), jag.lambda.to_bits(), "trial {trial}: λ diverged");
            assert_eq!(gk.rates, jag.rates, "trial {trial}: rates diverged");
        }
    }

    #[test]
    fn warm_start_never_worse_and_tracks_drain() {
        let inst = fig1a_inst(&[40.0, 80.0]);
        let cold = solve(&inst, 0.02).unwrap();
        // Same instance, warm-started from its own solution: identical or
        // better λ, still exactly feasible.
        let warm = solve_warm(&inst, 0.02, Some(&cold.rates)).unwrap();
        inst.check(&warm, 1e-7).unwrap();
        assert!(warm.lambda >= cold.lambda * (1.0 - 1e-9), "{} < {}", warm.lambda, cold.lambda);
        // Proportionally drained volumes (the between-rounds case): the
        // previous rates remain a valid warm start and quality holds.
        let mut drained = inst.clone();
        for g in &mut drained.groups {
            g.volume *= 0.5;
        }
        let cold2 = solve(&drained, 0.02).unwrap();
        let warm2 = solve_warm(&drained, 0.02, Some(&cold.rates)).unwrap();
        drained.check(&warm2, 1e-7).unwrap();
        assert!(warm2.lambda >= cold2.lambda * 0.95, "{} vs {}", warm2.lambda, cold2.lambda);
    }

    #[test]
    fn warm_start_ignored_for_new_groups() {
        // Warm rates cover only group 0; group 1 is new. The candidate
        // cannot serve group 1, so the solver must fall back to a full
        // solve and still satisfy both groups.
        let inst = fig1a_inst(&[40.0, 40.0]);
        let warm = vec![vec![5.0, 5.0]]; // only group 0
        let sol = solve_warm(&inst, 0.02, Some(&warm)).unwrap();
        inst.check(&sol, 1e-7).unwrap();
        assert!(sol.rates[1].iter().sum::<f64>() > 1e-6);
    }

    #[test]
    fn respects_zero_capacity_paths() {
        let mut inst = fig1a_inst(&[40.0]);
        inst.cap[0] = 0.0; // direct path down; must route via C
        let gk = solve(&inst, 0.05).unwrap();
        assert!(gk.rates[0][0] < 1e-9);
        assert!((gk.gamma() - 4.0).abs() < 0.4, "gamma={}", gk.gamma());
    }

    #[test]
    fn infeasible_when_no_usable_path() {
        let mut inst = fig1a_inst(&[40.0]);
        inst.cap = vec![0.0; 6];
        assert!(solve(&inst, 0.05).is_none());
    }

    /// Regression (gray failures): a 1e-10 Gbps residual capacity used to
    /// pass the `> 1e-12` usability filter, poisoning the demand
    /// normalization and the length updates. It must now be treated exactly
    /// like a down edge.
    #[test]
    fn degenerate_capacity_treated_as_down() {
        // Direct path bottlenecked at 1e-10: route everything via C.
        let mut inst = fig1a_inst(&[40.0]);
        inst.cap[0] = 1e-10;
        let sol = solve(&inst, 0.05).unwrap();
        assert!(sol.rates[0][0] < 1e-12, "routed over a degenerate edge");
        assert!((sol.gamma() - 4.0).abs() < 0.4, "gamma={}", sol.gamma());
        inst.check(&sol, 1e-7).unwrap();
        // Only degenerate paths left: infeasible, not a near-infinite solve.
        let mut dead = fig1a_inst(&[40.0]);
        dead.cap = vec![1e-10; 6];
        assert!(solve(&dead, 0.05).is_none());
        // A warm start whose rates ride a now-degenerate edge is sanitized,
        // not trusted.
        let mut shrunk = fig1a_inst(&[40.0]);
        let cold = solve(&shrunk, 0.05).unwrap();
        shrunk.cap[0] = 1e-10;
        let warm = solve_warm(&shrunk, 0.05, Some(&cold.rates)).unwrap();
        assert!(warm.rates[0][0] < 1e-12);
        shrunk.check(&warm, 1e-7).unwrap();
    }

    /// The measure D(l) and Fleischer's m are restricted to the instance's
    /// own (usable-path) edges: capacities of unrelated edges must not
    /// change the result at all — the decomposition-invariance the
    /// component solver relies on.
    #[test]
    fn solution_independent_of_unrelated_edges() {
        let inst = fig1a_inst(&[40.0, 80.0]);
        let base = solve(&inst, 0.05).unwrap();
        let mut noisy = inst.clone();
        noisy.cap[1] = 0.0; // B->A: on no path of this instance
        noisy.cap[2] = 3.7; // B->C: likewise
        let alt = solve(&noisy, 0.05).unwrap();
        assert_eq!(base.lambda, alt.lambda, "unrelated edges perturbed λ");
        assert_eq!(base.rates, alt.rates, "unrelated edges perturbed rates");
    }

    /// A warm workspace reused across solves yields the same answers as
    /// one-shot scratch (all per-solve state is cleared, not inherited).
    #[test]
    fn scratch_reuse_is_stateless() {
        let mut ws = GkScratch::default();
        let insts =
            [fig1a_inst(&[40.0]), fig1a_inst(&[40.0, 80.0]), fig1a_inst(&[8.0, 3.0, 99.0])];
        for inst in &insts {
            let flat = FlatMcf::from_instance(inst);
            let reused = solve_flat(&flat, 0.05, Warm::None, &mut ws).unwrap();
            let fresh = solve(inst, 0.05).unwrap();
            assert_eq!(reused.lambda.to_bits(), fresh.lambda.to_bits());
            assert_eq!(reused.rates, fresh.rates);
        }
    }

    /// The `Warm::Indexed` zero-copy projection is equivalent to manually
    /// projecting the full-group rate matrix onto the instance subset.
    #[test]
    fn warm_indexed_matches_direct() {
        let inst = fig1a_inst(&[40.0, 80.0]);
        let cold = solve(&inst, 0.02).unwrap();
        // Full-group layout: [finished, g0, finished, g1]; instance groups
        // 0 and 1 map to full indices 1 and 3.
        let full = vec![Vec::new(), cold.rates[0].clone(), Vec::new(), cold.rates[1].clone()];
        let index = vec![1usize, 3usize];
        let flat = FlatMcf::from_instance(&inst);
        let mut ws = GkScratch::default();
        let a = solve_flat(&flat, 0.02, Warm::Indexed(&full, &index), &mut ws).unwrap();
        let b = solve_warm(&inst, 0.02, Some(&cold.rates)).unwrap();
        assert_eq!(a.lambda.to_bits(), b.lambda.to_bits());
        assert_eq!(a.rates, b.rates);
    }
}
