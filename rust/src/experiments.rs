//! Reproduction harness: one function per paper table/figure (§6).
//!
//! Shared by the `terra reproduce` CLI subcommand (full scale) and the
//! `cargo bench` targets (scaled-down, same code paths). Each function
//! returns structured rows so callers can render paper-style tables and
//! record results in EXPERIMENTS.md.

use crate::baselines;
use crate::coflow::GB;
use crate::net::{topologies, LinkEvent, Wan};
use crate::scheduler::terra::{TerraConfig, TerraPolicy};
use crate::scheduler::Policy;
use crate::sim::{foi, foi_volume_correlation, Job, Report, SimConfig, Simulation};
use crate::workloads::{assign_deadlines, WorkloadConfig, WorkloadGen, WorkloadKind};

/// Topologies in the paper's order.
pub fn eval_topologies() -> Vec<(&'static str, Wan)> {
    vec![("swan", topologies::swan()), ("gscale", topologies::gscale()), ("att", topologies::att())]
}

/// Run one ⟨topology, workload, policy⟩ combination.
pub fn run_combo(
    wan: &Wan,
    kind: WorkloadKind,
    policy: Box<dyn Policy>,
    jobs: usize,
    seed: u64,
) -> Report {
    let mut cfg = WorkloadConfig::new(kind, seed);
    cfg.machines_per_dc = 100; // §6.3 simulations use 100 machines per DC
    let jobs = WorkloadGen::with_config(cfg).jobs(wan, jobs);
    let mut sim = Simulation::new(wan.clone(), policy, SimConfig::default());
    sim.run_jobs(jobs)
}

/// One Table 3 cell: FoI of Terra vs `baseline` for avg and p95 JCT.
#[derive(Clone, Debug)]
pub struct FoiRow {
    pub topology: String,
    pub workload: String,
    pub baseline: String,
    pub foi_avg_jct: f64,
    pub foi_p95_jct: f64,
    pub foi_util: f64,
    pub terra_slowdown: f64,
    pub baseline_slowdown: f64,
    pub volume_corr: f64,
}

/// Tables 3 + 4 (and the §6.3 slowdown/correlation analyses): simulate all
/// ⟨topology, workload⟩ combinations against all five baselines.
pub fn table3(jobs: usize, seed: u64, topologies_filter: Option<&str>) -> Vec<FoiRow> {
    let mut rows = Vec::new();
    for (tname, wan) in eval_topologies() {
        if let Some(f) = topologies_filter {
            if f != tname {
                continue;
            }
        }
        for kind in WorkloadKind::all() {
            let n = if kind == WorkloadKind::Fb { jobs * 4 / 3 } else { jobs };
            let terra_rep = run_combo(&wan, kind, Box::new(TerraPolicy::default()), n, seed);
            for bname in ["per-flow", "varys", "swan-mcf", "multipath", "rapier"] {
                let policy = baselines::by_name(bname).unwrap();
                let rep = run_combo(&wan, kind, policy, n, seed);
                rows.push(FoiRow {
                    topology: tname.to_string(),
                    workload: kind.name().to_string(),
                    baseline: bname.to_string(),
                    foi_avg_jct: foi(rep.avg_jct(), terra_rep.avg_jct()),
                    foi_p95_jct: foi(rep.p95_jct(), terra_rep.p95_jct()),
                    foi_util: foi(terra_rep.utilization(), rep.utilization()).recip(),
                    terra_slowdown: terra_rep.avg_slowdown(),
                    baseline_slowdown: rep.avg_slowdown(),
                    volume_corr: foi_volume_correlation(&terra_rep, &rep),
                });
            }
        }
    }
    rows
}

/// Figure 6 / Table 2 (testbed-style, simulated with the controller
/// feedback delay): Terra vs per-flow on SWAN across all four workloads.
pub struct TestbedRow {
    pub workload: String,
    pub foi_avg_jct: f64,
    pub foi_p95_jct: f64,
    pub foi_avg_cct: f64,
    pub foi_util: f64,
    /// (jct of every job, terra then per-flow) for CDF plotting (Fig 7).
    pub terra_jcts: Vec<f64>,
    pub perflow_jcts: Vec<f64>,
}

pub fn fig6_testbed(jobs: usize, seed: u64) -> Vec<TestbedRow> {
    let wan = topologies::swan_with_capacity(topologies::SWAN_TESTBED_GBPS);
    let mut out = Vec::new();
    for kind in WorkloadKind::all() {
        let mk_jobs = |seed| {
            let mut cfg = WorkloadConfig::new(kind, seed);
            cfg.machines_per_dc = 10; // testbed: 10 machines per DC
            cfg.volume_scale = 0.1; // 1 Gbps links
            WorkloadGen::with_config(cfg).jobs(&wan, jobs)
        };
        let sim_cfg = SimConfig { coordination_delay_s: 0.08, ..Default::default() };
        let mut terra_sim =
            Simulation::new(wan.clone(), Box::new(TerraPolicy::default()), sim_cfg.clone());
        let t = terra_sim.run_jobs(mk_jobs(seed));
        let mut fair_sim = Simulation::new(
            wan.clone(),
            baselines::by_name("per-flow").unwrap(),
            SimConfig::default(),
        );
        let f = fair_sim.run_jobs(mk_jobs(seed));
        out.push(TestbedRow {
            workload: kind.name().to_string(),
            foi_avg_jct: foi(f.avg_jct(), t.avg_jct()),
            foi_p95_jct: foi(f.p95_jct(), t.p95_jct()),
            foi_avg_cct: foi(f.avg_cct(), t.avg_cct()),
            foi_util: t.utilization() / f.utilization().max(1e-12),
            terra_jcts: t.jobs.iter().filter_map(|j| j.jct()).collect(),
            perflow_jcts: f.jobs.iter().filter_map(|j| j.jct()).collect(),
        });
    }
    out
}

/// Figure 8: deadline-sensitive coflows — % meeting `d x min-CCT` deadlines
/// under Terra vs a baseline, d in 2..=6.
pub struct DeadlineRow {
    pub d: f64,
    pub terra_met: f64,
    pub baseline_met: f64,
}

pub fn fig8_deadlines(jobs: usize, seed: u64, baseline: &str) -> Vec<DeadlineRow> {
    let wan = topologies::swan();
    let mut out = Vec::new();
    for d in [2.0, 3.0, 4.0, 5.0, 6.0] {
        let mk_jobs = |seed| {
            let mut cfg = WorkloadConfig::new(WorkloadKind::BigBench, seed);
            cfg.machines_per_dc = 100;
            let mut jobs = WorkloadGen::with_config(cfg).jobs(&wan, jobs);
            assign_deadlines(&mut jobs, &wan, d);
            jobs
        };
        let mut terra_sim =
            Simulation::new(wan.clone(), Box::new(TerraPolicy::default()), SimConfig::default());
        let t = terra_sim.run_jobs(mk_jobs(seed));
        let mut base_sim =
            Simulation::new(wan.clone(), baselines::by_name(baseline).unwrap(), SimConfig::default());
        let b = base_sim.run_jobs(mk_jobs(seed));
        out.push(DeadlineRow {
            d,
            terra_met: t.deadline_met_fraction(),
            baseline_met: b.deadline_met_fraction(),
        });
    }
    out
}

/// Figure 11 / Fig 3 / §6.6: scheduling overhead — time and LP count per
/// round, Terra vs Rapier, per topology.
pub struct OverheadRow {
    pub topology: String,
    pub policy: String,
    pub rounds: usize,
    pub lp_per_round: f64,
    pub ms_per_round: f64,
}

pub fn fig11_overhead(jobs: usize, seed: u64) -> Vec<OverheadRow> {
    let mut out = Vec::new();
    for (tname, wan) in eval_topologies() {
        for pname in ["terra", "rapier"] {
            let rep = run_combo(
                &wan,
                WorkloadKind::BigBench,
                baselines::by_name(pname).unwrap(),
                jobs,
                seed,
            );
            out.push(OverheadRow {
                topology: tname.to_string(),
                policy: pname.to_string(),
                rounds: rep.rounds,
                lp_per_round: rep.lp_solves as f64 / rep.rounds.max(1) as f64,
                ms_per_round: 1e3 * rep.round_time_s / rep.rounds.max(1) as f64,
            });
        }
    }
    out
}

/// Figure 12: sensitivity to the number of paths k on ATT.
pub struct PathsRow {
    pub k: usize,
    pub foi_avg_jct: f64,
    pub foi_util: f64,
}

pub fn fig12_paths(jobs: usize, seed: u64, kind: WorkloadKind) -> Vec<PathsRow> {
    let wan = topologies::att();
    let fair = run_combo(&wan, kind, baselines::by_name("per-flow").unwrap(), jobs, seed);
    let mut out = Vec::new();
    for k in [1, 2, 5, 10, 15] {
        let t = run_combo(&wan, kind, Box::new(TerraPolicy::with_k(k)), jobs, seed);
        out.push(PathsRow {
            k,
            foi_avg_jct: foi(fair.avg_jct(), t.avg_jct()),
            foi_util: t.utilization() / fair.utilization().max(1e-12),
        });
    }
    out
}

/// Figure 13: load scaling (arrival-rate multipliers) on SWAN.
pub struct LoadRow {
    pub arrival_scale: f64,
    pub foi_avg_jct: f64,
}

pub fn fig13_load(jobs: usize, seed: u64) -> Vec<LoadRow> {
    let wan = topologies::swan();
    let mut out = Vec::new();
    for scale in [0.5, 1.0, 2.0, 4.0] {
        let mk = |policy: Box<dyn Policy>| {
            let mut cfg = WorkloadConfig::new(WorkloadKind::BigBench, seed);
            cfg.arrival_scale = scale;
            let jobs = WorkloadGen::with_config(cfg).jobs(&wan, jobs);
            let mut sim = Simulation::new(wan.clone(), policy, SimConfig::default());
            sim.run_jobs(jobs)
        };
        let t = mk(Box::new(TerraPolicy::default()));
        let f = mk(baselines::by_name("per-flow").unwrap());
        out.push(LoadRow { arrival_scale: scale, foi_avg_jct: foi(f.avg_jct(), t.avg_jct()) });
    }
    out
}

/// Figure 14: machines per datacenter (computation vs communication).
pub struct MachinesRow {
    pub machines: usize,
    pub foi_avg_jct: f64,
}

pub fn fig14_machines(jobs: usize, seed: u64) -> Vec<MachinesRow> {
    let wan = topologies::swan();
    let mut out = Vec::new();
    for machines in [10, 20, 50, 100, 200] {
        let mk = |policy: Box<dyn Policy>| {
            let mut cfg = WorkloadConfig::new(WorkloadKind::BigBench, seed);
            cfg.machines_per_dc = machines;
            let jobs = WorkloadGen::with_config(cfg).jobs(&wan, jobs);
            let mut sim = Simulation::new(wan.clone(), policy, SimConfig::default());
            sim.run_jobs(jobs)
        };
        let t = mk(Box::new(TerraPolicy::default()));
        let f = mk(baselines::by_name("per-flow").unwrap());
        out.push(MachinesRow { machines, foi_avg_jct: foi(f.avg_jct(), t.avg_jct()) });
    }
    out
}

/// §6.7 α sensitivity: avg JCT for α values on BigBench/SWAN.
pub fn alpha_sensitivity(jobs: usize, seed: u64) -> Vec<(f64, f64)> {
    let wan = topologies::swan();
    [0.0, 0.1, 0.2, 0.4]
        .iter()
        .map(|&alpha| {
            let rep = run_combo(
                &wan,
                WorkloadKind::BigBench,
                Box::new(TerraPolicy::with_alpha(alpha)),
                jobs,
                seed,
            );
            (alpha, rep.avg_jct())
        })
        .collect()
}

/// Figure 1: the motivating example — average CCT of the two coflows under
/// the four policies of Fig 1c–1f. Returns (policy name, avg CCT seconds).
pub fn fig1_motivation() -> Vec<(String, f64)> {
    let wan = topologies::fig1a();
    let mk_jobs = || {
        vec![
            Job::map_reduce(
                1,
                0.0,
                0.0,
                vec![crate::coflow::Flow { id: 0, src_dc: 0, dst_dc: 1, volume: 5.0 * GB }],
            ),
            Job::map_reduce(
                2,
                0.0,
                0.0,
                vec![
                    crate::coflow::Flow { id: 0, src_dc: 0, dst_dc: 1, volume: 5.0 * GB },
                    crate::coflow::Flow { id: 1, src_dc: 2, dst_dc: 1, volume: 25.0 * GB },
                ],
            ),
        ]
    };
    let mut out = Vec::new();
    for pname in ["per-flow", "multipath", "varys", "terra"] {
        let policy: Box<dyn Policy> = if pname == "terra" {
            Box::new(TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() }))
        } else {
            baselines::by_name(pname).unwrap()
        };
        let mut sim = Simulation::new(wan.clone(), policy, SimConfig::default());
        let rep = sim.run_jobs(mk_jobs());
        out.push((pname.to_string(), rep.avg_cct()));
    }
    out
}

/// Figure 2: re-optimization under failure. Returns (scenario, avg CCT):
/// no failure (8 s), failure + Terra re-optimization (≈14 s paper-optimal).
pub fn fig2_reopt() -> Vec<(String, f64)> {
    let mk_jobs = || {
        vec![
            Job::map_reduce(
                1,
                0.0,
                0.0,
                vec![crate::coflow::Flow { id: 0, src_dc: 0, dst_dc: 1, volume: 10.0 * GB }],
            ),
            Job::map_reduce(
                2,
                0.0,
                0.0,
                vec![
                    crate::coflow::Flow { id: 0, src_dc: 2, dst_dc: 1, volume: 10.0 * GB },
                    crate::coflow::Flow { id: 1, src_dc: 0, dst_dc: 2, volume: 10.0 * GB },
                ],
            ),
        ]
    };
    let mut out = Vec::new();
    // Scenario A: no failure.
    let mut sim = Simulation::new(
        topologies::fig1a(),
        Box::new(TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() })),
        SimConfig::default(),
    );
    out.push(("no-failure".into(), sim.run_jobs(mk_jobs()).avg_cct()));
    // Scenario B: the A-C link fails right after scheduling; Terra
    // re-optimizes (application-aware).
    let mut sim = Simulation::new(
        topologies::fig1a(),
        Box::new(TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() })),
        SimConfig::default(),
    );
    for j in mk_jobs() {
        sim.add_job(j);
    }
    sim.add_wan_event(0.05, LinkEvent::Fail(0, 2));
    out.push(("failure+reopt".into(), sim.run().avg_cct()));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_ordering_matches_paper() {
        let rows = fig1_motivation();
        let get = |n: &str| rows.iter().find(|(p, _)| p == n).unwrap().1;
        let (fair, mp, varys, terra) = (get("per-flow"), get("multipath"), get("varys"), get("terra"));
        // Paper: 14 / 10.6 / 12 / 7.15 — exact values depend on fairness
        // refinements; the ORDERING is the claim.
        assert!(terra < mp && terra < varys && terra < fair, "{rows:?}");
        assert!(mp < fair, "{rows:?}");
        assert!(varys < fair, "{rows:?}");
        assert!((fair - 14.0).abs() < 1.0, "{rows:?}");
        // Paper's joint optimum is 7.15 s; the GK ε-approximation lands
        // within ~10% (≈8.0 s), still well ahead of every baseline.
        assert!(terra < 8.3, "{rows:?}");
    }

    #[test]
    fn fig2_failure_recovers() {
        let rows = fig2_reopt();
        let no_fail = rows[0].1;
        let with_fail = rows[1].1;
        assert!(no_fail < with_fail, "{rows:?}");
        // Paper: 8 s -> 14 s optimal after failure (18 s without
        // app-aware re-optimization).
        assert!(no_fail < 10.0, "{rows:?}");
        assert!(with_fail < 17.0, "failure handling too slow: {rows:?}");
    }

    #[test]
    fn small_table3_terra_wins_mostly() {
        let rows = table3(6, 7, Some("swan"));
        assert_eq!(rows.len(), 20); // 4 workloads x 5 baselines
        let wins = rows.iter().filter(|r| r.foi_avg_jct > 1.0).count();
        assert!(wins * 10 >= rows.len() * 7, "terra should win most cells: {wins}/{}", rows.len());
    }

    #[test]
    fn fig8_terra_meets_more_deadlines() {
        let rows = fig8_deadlines(8, 3, "per-flow");
        let t_avg: f64 = rows.iter().map(|r| r.terra_met).sum::<f64>() / rows.len() as f64;
        let b_avg: f64 = rows.iter().map(|r| r.baseline_met).sum::<f64>() / rows.len() as f64;
        assert!(t_avg > b_avg, "terra {t_avg} vs baseline {b_avg}");
    }
}
