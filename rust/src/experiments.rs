//! Reproduction harness: one function per paper table/figure (§6).
//!
//! Shared by the `terra reproduce` CLI subcommand (full scale) and the
//! `cargo bench` targets (scaled-down, same code paths). Each function
//! returns structured rows so callers can render paper-style tables and
//! record results in EXPERIMENTS.md.

use crate::baselines;
use crate::coflow::GB;
use crate::net::dynamics::{self, DynamicsProfile};
use crate::net::telemetry::TelemetryConfig;
use crate::net::{topologies, LinkEvent, Wan};
use crate::scheduler::terra::{TerraConfig, TerraPolicy};
use crate::scheduler::Policy;
use crate::sim::{foi, foi_volume_correlation, Job, Report, SimConfig, Simulation};
use crate::util::json::Json;
use crate::util::rng::splitmix64;
use crate::util::stats;
use crate::workloads::{
    assign_deadlines, ml_sync_jobs, stream_jobs, Interarrival, OpenLoopConfig, OpenLoopGen,
    WorkloadConfig, WorkloadGen, WorkloadKind, WorkloadProfile,
};

/// Topologies in the paper's order.
pub fn eval_topologies() -> Vec<(&'static str, Wan)> {
    vec![("swan", topologies::swan()), ("gscale", topologies::gscale()), ("att", topologies::att())]
}

/// Run one ⟨topology, workload, policy⟩ combination.
pub fn run_combo(
    wan: &Wan,
    kind: WorkloadKind,
    policy: Box<dyn Policy>,
    jobs: usize,
    seed: u64,
) -> Report {
    let mut cfg = WorkloadConfig::new(kind, seed);
    cfg.machines_per_dc = 100; // §6.3 simulations use 100 machines per DC
    let jobs = WorkloadGen::with_config(cfg).jobs(wan, jobs);
    let mut sim = Simulation::new(wan.clone(), policy, SimConfig::default());
    sim.run_jobs(jobs)
}

/// One Table 3 cell: FoI of Terra vs `baseline` for avg and p95 JCT.
#[derive(Clone, Debug)]
pub struct FoiRow {
    pub topology: String,
    pub workload: String,
    pub baseline: String,
    pub foi_avg_jct: f64,
    pub foi_p95_jct: f64,
    pub foi_util: f64,
    pub terra_slowdown: f64,
    pub baseline_slowdown: f64,
    pub volume_corr: f64,
}

/// Tables 3 + 4 (and the §6.3 slowdown/correlation analyses): simulate all
/// ⟨topology, workload⟩ combinations against all five baselines.
pub fn table3(jobs: usize, seed: u64, topologies_filter: Option<&str>) -> Vec<FoiRow> {
    let mut rows = Vec::new();
    for (tname, wan) in eval_topologies() {
        if let Some(f) = topologies_filter {
            if f != tname {
                continue;
            }
        }
        for kind in WorkloadKind::all() {
            let n = if kind == WorkloadKind::Fb { jobs * 4 / 3 } else { jobs };
            let terra_rep = run_combo(&wan, kind, Box::new(TerraPolicy::default()), n, seed);
            for bname in ["per-flow", "varys", "swan-mcf", "multipath", "rapier"] {
                let policy = baselines::by_name(bname).unwrap();
                let rep = run_combo(&wan, kind, policy, n, seed);
                rows.push(FoiRow {
                    topology: tname.to_string(),
                    workload: kind.name().to_string(),
                    baseline: bname.to_string(),
                    foi_avg_jct: foi(rep.avg_jct(), terra_rep.avg_jct()),
                    foi_p95_jct: foi(rep.p95_jct(), terra_rep.p95_jct()),
                    foi_util: foi(terra_rep.utilization(), rep.utilization()).recip(),
                    terra_slowdown: terra_rep.avg_slowdown(),
                    baseline_slowdown: rep.avg_slowdown(),
                    volume_corr: foi_volume_correlation(&terra_rep, &rep),
                });
            }
        }
    }
    rows
}

/// Figure 6 / Table 2 (testbed-style, simulated with the controller
/// feedback delay): Terra vs per-flow on SWAN across all four workloads.
pub struct TestbedRow {
    pub workload: String,
    pub foi_avg_jct: f64,
    pub foi_p95_jct: f64,
    pub foi_avg_cct: f64,
    pub foi_util: f64,
    /// (jct of every job, terra then per-flow) for CDF plotting (Fig 7).
    pub terra_jcts: Vec<f64>,
    pub perflow_jcts: Vec<f64>,
}

pub fn fig6_testbed(jobs: usize, seed: u64) -> Vec<TestbedRow> {
    let wan = topologies::swan_with_capacity(topologies::SWAN_TESTBED_GBPS);
    let mut out = Vec::new();
    for kind in WorkloadKind::all() {
        let mk_jobs = |seed| {
            let mut cfg = WorkloadConfig::new(kind, seed);
            cfg.machines_per_dc = 10; // testbed: 10 machines per DC
            cfg.volume_scale = 0.1; // 1 Gbps links
            WorkloadGen::with_config(cfg).jobs(&wan, jobs)
        };
        let sim_cfg = SimConfig { coordination_delay_s: 0.08, ..Default::default() };
        let mut terra_sim =
            Simulation::new(wan.clone(), Box::new(TerraPolicy::default()), sim_cfg.clone());
        let t = terra_sim.run_jobs(mk_jobs(seed));
        let mut fair_sim = Simulation::new(
            wan.clone(),
            baselines::by_name("per-flow").unwrap(),
            SimConfig::default(),
        );
        let f = fair_sim.run_jobs(mk_jobs(seed));
        out.push(TestbedRow {
            workload: kind.name().to_string(),
            foi_avg_jct: foi(f.avg_jct(), t.avg_jct()),
            foi_p95_jct: foi(f.p95_jct(), t.p95_jct()),
            foi_avg_cct: foi(f.avg_cct(), t.avg_cct()),
            foi_util: t.utilization() / f.utilization().max(1e-12),
            terra_jcts: t.jobs.iter().filter_map(|j| j.jct()).collect(),
            perflow_jcts: f.jobs.iter().filter_map(|j| j.jct()).collect(),
        });
    }
    out
}

/// Figure 8: deadline-sensitive coflows — % meeting `d x min-CCT` deadlines
/// under Terra vs a baseline, d in 2..=6.
pub struct DeadlineRow {
    pub d: f64,
    pub terra_met: f64,
    pub baseline_met: f64,
}

pub fn fig8_deadlines(jobs: usize, seed: u64, baseline: &str) -> Vec<DeadlineRow> {
    let wan = topologies::swan();
    let mut out = Vec::new();
    for d in [2.0, 3.0, 4.0, 5.0, 6.0] {
        let mk_jobs = |seed| {
            let mut cfg = WorkloadConfig::new(WorkloadKind::BigBench, seed);
            cfg.machines_per_dc = 100;
            let mut jobs = WorkloadGen::with_config(cfg).jobs(&wan, jobs);
            assign_deadlines(&mut jobs, &wan, d);
            jobs
        };
        let mut terra_sim =
            Simulation::new(wan.clone(), Box::new(TerraPolicy::default()), SimConfig::default());
        let t = terra_sim.run_jobs(mk_jobs(seed));
        let mut base_sim =
            Simulation::new(wan.clone(), baselines::by_name(baseline).unwrap(), SimConfig::default());
        let b = base_sim.run_jobs(mk_jobs(seed));
        out.push(DeadlineRow {
            d,
            terra_met: t.deadline_met_fraction(),
            baseline_met: b.deadline_met_fraction(),
        });
    }
    out
}

/// Figure 11 / Fig 3 / §6.6: scheduling overhead — time and LP count per
/// round, Terra vs Rapier, per topology.
pub struct OverheadRow {
    pub topology: String,
    pub policy: String,
    pub rounds: usize,
    pub lp_per_round: f64,
    pub ms_per_round: f64,
}

pub fn fig11_overhead(jobs: usize, seed: u64) -> Vec<OverheadRow> {
    let mut out = Vec::new();
    for (tname, wan) in eval_topologies() {
        for pname in ["terra", "rapier"] {
            let rep = run_combo(
                &wan,
                WorkloadKind::BigBench,
                baselines::by_name(pname).unwrap(),
                jobs,
                seed,
            );
            out.push(OverheadRow {
                topology: tname.to_string(),
                policy: pname.to_string(),
                rounds: rep.rounds,
                lp_per_round: rep.lp_solves as f64 / rep.rounds.max(1) as f64,
                ms_per_round: 1e3 * rep.round_time_s / rep.rounds.max(1) as f64,
            });
        }
    }
    out
}

/// Figure 12: sensitivity to the number of paths k on ATT.
pub struct PathsRow {
    pub k: usize,
    pub foi_avg_jct: f64,
    pub foi_util: f64,
}

pub fn fig12_paths(jobs: usize, seed: u64, kind: WorkloadKind) -> Vec<PathsRow> {
    let wan = topologies::att();
    let fair = run_combo(&wan, kind, baselines::by_name("per-flow").unwrap(), jobs, seed);
    let mut out = Vec::new();
    for k in [1, 2, 5, 10, 15] {
        let t = run_combo(&wan, kind, Box::new(TerraPolicy::with_k(k)), jobs, seed);
        out.push(PathsRow {
            k,
            foi_avg_jct: foi(fair.avg_jct(), t.avg_jct()),
            foi_util: t.utilization() / fair.utilization().max(1e-12),
        });
    }
    out
}

/// Figure 13: load scaling (arrival-rate multipliers) on SWAN.
pub struct LoadRow {
    pub arrival_scale: f64,
    pub foi_avg_jct: f64,
}

pub fn fig13_load(jobs: usize, seed: u64) -> Vec<LoadRow> {
    let wan = topologies::swan();
    let mut out = Vec::new();
    for scale in [0.5, 1.0, 2.0, 4.0] {
        let mk = |policy: Box<dyn Policy>| {
            let mut cfg = WorkloadConfig::new(WorkloadKind::BigBench, seed);
            cfg.arrival_scale = scale;
            let jobs = WorkloadGen::with_config(cfg).jobs(&wan, jobs);
            let mut sim = Simulation::new(wan.clone(), policy, SimConfig::default());
            sim.run_jobs(jobs)
        };
        let t = mk(Box::new(TerraPolicy::default()));
        let f = mk(baselines::by_name("per-flow").unwrap());
        out.push(LoadRow { arrival_scale: scale, foi_avg_jct: foi(f.avg_jct(), t.avg_jct()) });
    }
    out
}

/// Figure 14: machines per datacenter (computation vs communication).
pub struct MachinesRow {
    pub machines: usize,
    pub foi_avg_jct: f64,
}

pub fn fig14_machines(jobs: usize, seed: u64) -> Vec<MachinesRow> {
    let wan = topologies::swan();
    let mut out = Vec::new();
    for machines in [10, 20, 50, 100, 200] {
        let mk = |policy: Box<dyn Policy>| {
            let mut cfg = WorkloadConfig::new(WorkloadKind::BigBench, seed);
            cfg.machines_per_dc = machines;
            let jobs = WorkloadGen::with_config(cfg).jobs(&wan, jobs);
            let mut sim = Simulation::new(wan.clone(), policy, SimConfig::default());
            sim.run_jobs(jobs)
        };
        let t = mk(Box::new(TerraPolicy::default()));
        let f = mk(baselines::by_name("per-flow").unwrap());
        out.push(MachinesRow { machines, foi_avg_jct: foi(f.avg_jct(), t.avg_jct()) });
    }
    out
}

/// §6.7 α sensitivity: avg JCT for α values on BigBench/SWAN.
pub fn alpha_sensitivity(jobs: usize, seed: u64) -> Vec<(f64, f64)> {
    let wan = topologies::swan();
    [0.0, 0.1, 0.2, 0.4]
        .iter()
        .map(|&alpha| {
            let rep = run_combo(
                &wan,
                WorkloadKind::BigBench,
                Box::new(TerraPolicy::with_alpha(alpha)),
                jobs,
                seed,
            );
            (alpha, rep.avg_jct())
        })
        .collect()
}

/// Configuration of the workload × topology × policy × dynamics sweep.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// Jobs per scenario (FB is not inflated here, unlike Table 3).
    pub jobs: usize,
    /// Root seed: workloads and every scenario's event stream derive from
    /// it deterministically, so the same seed reproduces identical streams.
    pub seed: u64,
    /// Dynamics generation horizon (seconds of simulated time).
    pub horizon_s: f64,
    /// Dynamics profiles to sweep ([`DynamicsProfile::by_name`] names).
    pub profiles: Vec<String>,
    /// Policies to sweep ([`baselines::by_name`] names).
    pub policies: Vec<String>,
    /// Restrict to one topology / workload (sweep all when `None`).
    pub topology: Option<String>,
    pub workload: Option<String>,
    /// When > 0, assign every coflow a deadline of `deadline_d ×` its
    /// standalone min CCT (Fig 8 style), so the deadlines-met column is
    /// populated. 0 disables deadlines.
    pub deadline_d: f64,
    /// Control-plane shard count for every scheduled run. Sharding is
    /// bit-identical to `shards = 1` by construction (property-pinned),
    /// so results only differ in control-plane latency, never in CCTs.
    pub shards: usize,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            jobs: 6,
            seed: 7,
            horizon_s: 420.0,
            profiles: DynamicsProfile::all().into_iter().map(|p| p.name).collect(),
            policies: vec![
                "terra".into(),
                "per-flow".into(),
                "varys".into(),
                "rapier".into(),
                "swan-mcf".into(),
            ],
            topology: None,
            workload: None,
            deadline_d: 0.0,
            shards: 1,
        }
    }
}

/// One scenario outcome: a ⟨topology, workload, policy, dynamics⟩ cell.
#[derive(Clone, Debug)]
pub struct ScenarioRow {
    pub topology: String,
    pub workload: String,
    pub policy: String,
    pub profile: String,
    pub avg_cct: f64,
    pub p99_cct: f64,
    pub avg_jct: f64,
    /// Fraction of deadline-bearing coflows meeting their deadline (0 when
    /// the sweep runs without deadlines).
    pub deadline_met: f64,
    pub rounds: usize,
    pub lp_solves: usize,
    /// Components re-solved / carried forward across rounds (decomposed
    /// round accounting: solves + reuses ≈ components per round · rounds).
    pub component_solves: usize,
    pub component_reuses: usize,
    /// WAN events delivered / rounds they triggered (reaction coverage).
    pub wan_events: usize,
    pub wan_rounds: usize,
    /// Mean / worst wall-clock latency of a WAN-triggered round — how fast
    /// the scheduler reacts after a failure or qualifying fluctuation.
    pub reaction_ms_avg: f64,
    pub reaction_ms_max: f64,
    pub unfinished: usize,
    pub makespan: f64,
}

/// Deterministic per-scenario sub-seed (same for every policy of a
/// scenario, so all policies face the identical workload + event stream).
fn scenario_seed(root: u64, topo: usize, workload: usize, profile: usize) -> u64 {
    let mut s = root
        ^ (topo as u64).wrapping_add(1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (workload as u64).wrapping_add(1).wrapping_mul(0xC2B2_AE3D_27D4_EB4F)
        ^ (profile as u64).wrapping_add(1).wrapping_mul(0x1656_67B1_9E37_79F9);
    splitmix64(&mut s)
}

/// The scenario sweep: run every ⟨topology, workload, policy, dynamics
/// profile⟩ combination through the simulator (and thus the shared
/// `RoundEngine`), replaying the profile's generated WAN event stream.
/// Rows come back in deterministic sweep order.
pub fn scenario_sweep(cfg: &SweepConfig) -> Vec<ScenarioRow> {
    let mut rows = Vec::new();
    for (ti, (tname, wan)) in eval_topologies().into_iter().enumerate() {
        if let Some(f) = &cfg.topology {
            if f != tname {
                continue;
            }
        }
        for (wi, kind) in WorkloadKind::all().into_iter().enumerate() {
            if let Some(f) = &cfg.workload {
                if f != kind.name() {
                    continue;
                }
            }
            // Workload seed is profile-independent: every profile and
            // every policy schedules the exact same jobs, generated once
            // per (topology, workload) cell and cloned per run.
            let wseed = scenario_seed(cfg.seed, ti, wi, usize::MAX);
            let wcfg = WorkloadConfig::new(kind, wseed); // machines_per_dc: 100 (§6.3 default)
            let mut jobs = WorkloadGen::with_config(wcfg).jobs(&wan, cfg.jobs);
            if cfg.deadline_d > 0.0 {
                assign_deadlines(&mut jobs, &wan, cfg.deadline_d);
            }
            for (pi, pname) in cfg.profiles.iter().enumerate() {
                let Some(profile) = DynamicsProfile::by_name(pname) else {
                    log::warn!("unknown dynamics profile {pname}; skipping");
                    continue;
                };
                let sseed = scenario_seed(cfg.seed, ti, wi, pi);
                let events = dynamics::generate(&wan, &profile, cfg.horizon_s, sseed);
                for policy_name in &cfg.policies {
                    let Some(policy) = baselines::by_name(policy_name) else {
                        log::warn!("unknown policy {policy_name}; skipping");
                        continue;
                    };
                    let sim_cfg = SimConfig { shards: cfg.shards.max(1), ..Default::default() };
                    let mut sim = Simulation::new(wan.clone(), policy, sim_cfg);
                    for ev in &events {
                        sim.add_wan_event(ev.t, ev.ev.clone());
                    }
                    let rep = sim.run_jobs(jobs.clone());
                    rows.push(ScenarioRow {
                        topology: tname.to_string(),
                        workload: kind.name().to_string(),
                        policy: policy_name.clone(),
                        profile: profile.name.clone(),
                        avg_cct: rep.avg_cct(),
                        p99_cct: rep.p99_cct(),
                        avg_jct: rep.avg_jct(),
                        deadline_met: rep.deadline_met_fraction(),
                        rounds: rep.rounds,
                        lp_solves: rep.lp_solves,
                        component_solves: rep.component_solves,
                        component_reuses: rep.component_reuses,
                        wan_events: rep.wan_events,
                        wan_rounds: rep.wan_rounds,
                        reaction_ms_avg: rep.avg_reaction_ms(),
                        reaction_ms_max: 1e3 * rep.max_reaction_s,
                        unfinished: rep.unfinished(),
                        makespan: rep.makespan,
                    });
                }
            }
        }
    }
    rows
}

/// Serialize sweep results for `BENCH_scenarios.json`.
pub fn scenarios_json(cfg: &SweepConfig, rows: &[ScenarioRow]) -> Json {
    let rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::from_pairs([
                ("topology", Json::from(r.topology.clone())),
                ("workload", r.workload.clone().into()),
                ("policy", r.policy.clone().into()),
                ("profile", r.profile.clone().into()),
                ("avg_cct_s", r.avg_cct.into()),
                ("p99_cct_s", r.p99_cct.into()),
                ("avg_jct_s", r.avg_jct.into()),
                ("deadline_met", r.deadline_met.into()),
                ("rounds", r.rounds.into()),
                ("lp_solves", r.lp_solves.into()),
                ("component_solves", r.component_solves.into()),
                ("component_reuses", r.component_reuses.into()),
                ("wan_events", r.wan_events.into()),
                ("wan_rounds", r.wan_rounds.into()),
                ("reaction_ms_avg", r.reaction_ms_avg.into()),
                ("reaction_ms_max", r.reaction_ms_max.into()),
                ("unfinished", r.unfinished.into()),
                ("makespan_s", r.makespan.into()),
            ])
        })
        .collect();
    Json::from_pairs([
        ("seed", Json::from(cfg.seed)),
        ("jobs", cfg.jobs.into()),
        ("horizon_s", cfg.horizon_s.into()),
        ("deadline_d", cfg.deadline_d.into()),
        ("shards", cfg.shards.into()),
        ("profiles", cfg.profiles.iter().map(|p| Json::from(p.clone())).collect::<Vec<_>>().into()),
        ("policies", cfg.policies.iter().map(|p| Json::from(p.clone())).collect::<Vec<_>>().into()),
        ("rows", Json::Arr(rows)),
    ])
}

/// Configuration of the **estimation sweep**: dynamics profiles ×
/// capacity estimators on one ⟨topology, workload⟩, with the Terra policy
/// throughout — the axis under study is how well the scheduler performs
/// when it must *estimate* WAN capacity instead of reading it from the
/// dynamics oracle.
#[derive(Clone, Debug)]
pub struct EstimationSweepConfig {
    pub jobs: usize,
    pub seed: u64,
    pub horizon_s: f64,
    pub topology: String,
    pub workload: String,
    /// Dynamics profiles ([`DynamicsProfile::by_name`]); must include the
    /// estimator stress tests.
    pub profiles: Vec<String>,
    /// Estimator presets ([`TelemetryConfig::by_name`]).
    pub estimators: Vec<String>,
    /// When > 0, every coflow gets a deadline of `deadline_d ×` its
    /// standalone min CCT, so `deadline_met` is populated per
    /// (profile, estimator) cell.
    pub deadline_d: f64,
}

impl Default for EstimationSweepConfig {
    fn default() -> Self {
        EstimationSweepConfig {
            jobs: 6,
            seed: 7,
            horizon_s: 420.0,
            topology: "swan".into(),
            workload: "bigbench".into(),
            profiles: vec![
                "flaky".into(),
                "gray".into(),
                "maintenance".into(),
                "maintenance-unannounced".into(),
            ],
            estimators: TelemetryConfig::preset_names().iter().map(|s| s.to_string()).collect(),
            deadline_d: 3.0,
        }
    }
}

/// One estimation-sweep cell: a ⟨profile, estimator⟩ outcome.
#[derive(Clone, Debug)]
pub struct EstimationRow {
    pub topology: String,
    pub workload: String,
    pub profile: String,
    pub estimator: String,
    pub avg_cct: f64,
    pub p99_cct: f64,
    /// CCT inflation vs the oracle on the identical scenario (1.0 = no
    /// cost of estimation; the oracle row is 1.0 by construction).
    pub cct_vs_oracle: f64,
    /// Mean per-edge absolute percentage error of believed vs true
    /// capacity, sampled at telemetry ticks (0 for the oracle).
    pub est_mape: f64,
    pub est_samples: usize,
    pub est_probes: usize,
    /// Staleness episodes (truth ≥ ρ away from belief) opened / resolved,
    /// and the mean simulated latency to resolution.
    pub stale_events: usize,
    pub stale_resolved: usize,
    pub stale_reaction_s_avg: f64,
    pub deadline_met: f64,
    pub rounds: usize,
    pub wan_events: usize,
    pub wan_rounds: usize,
    pub unfinished: usize,
    pub makespan: f64,
}

/// Run the estimation sweep: every profile × estimator cell replays the
/// *identical* workload and ground-truth event stream; only the
/// scheduler's view of capacity differs. Rows come back in deterministic
/// sweep order, oracle baselines computed per profile regardless of the
/// estimator list (they anchor `cct_vs_oracle`).
pub fn estimation_sweep(cfg: &EstimationSweepConfig) -> Vec<EstimationRow> {
    let Some(wan) = topologies::by_name(&cfg.topology) else {
        log::warn!("unknown topology {}; empty estimation sweep", cfg.topology);
        return Vec::new();
    };
    let Some(kind) = WorkloadKind::by_name(&cfg.workload) else {
        log::warn!("unknown workload {}; empty estimation sweep", cfg.workload);
        return Vec::new();
    };
    let wseed = scenario_seed(cfg.seed, 0, 0, usize::MAX);
    let wcfg = WorkloadConfig::new(kind, wseed);
    let mut jobs = WorkloadGen::with_config(wcfg).jobs(&wan, cfg.jobs);
    if cfg.deadline_d > 0.0 {
        assign_deadlines(&mut jobs, &wan, cfg.deadline_d);
    }
    let mut rows = Vec::new();
    for (pi, pname) in cfg.profiles.iter().enumerate() {
        let Some(profile) = DynamicsProfile::by_name(pname) else {
            log::warn!("unknown dynamics profile {pname}; skipping");
            continue;
        };
        let sseed = scenario_seed(cfg.seed, 0, 0, pi);
        let stream = dynamics::generate_stream(&wan, &profile, cfg.horizon_s, sseed);
        let run = |telemetry: TelemetryConfig| -> Report {
            let sim_cfg = SimConfig { telemetry, ..Default::default() };
            let mut sim =
                Simulation::new(wan.clone(), Box::new(TerraPolicy::default()), sim_cfg);
            for ev in &stream.events {
                sim.add_wan_event(ev.t, ev.ev.clone());
            }
            for w in &stream.announcements {
                sim.add_announcement(w);
            }
            sim.run_jobs(jobs.clone())
        };
        let oracle = run(TelemetryConfig::oracle());
        for ename in &cfg.estimators {
            let Some(telemetry) = TelemetryConfig::by_name(ename) else {
                log::warn!("unknown estimator {ename}; skipping");
                continue;
            };
            let rep = if telemetry.is_oracle() { oracle.clone() } else { run(telemetry) };
            rows.push(EstimationRow {
                topology: cfg.topology.clone(),
                workload: cfg.workload.clone(),
                profile: profile.name.clone(),
                estimator: ename.clone(),
                avg_cct: rep.avg_cct(),
                p99_cct: rep.p99_cct(),
                cct_vs_oracle: rep.avg_cct() / oracle.avg_cct().max(1e-9),
                est_mape: rep.est_mape(),
                est_samples: rep.est_samples,
                est_probes: rep.est_probes,
                stale_events: rep.stale_events,
                stale_resolved: rep.stale_resolved,
                stale_reaction_s_avg: rep.avg_stale_reaction_s(),
                deadline_met: rep.deadline_met_fraction(),
                rounds: rep.rounds,
                wan_events: rep.wan_events,
                wan_rounds: rep.wan_rounds,
                unfinished: rep.unfinished(),
                makespan: rep.makespan,
            });
        }
    }
    rows
}

/// Serialize estimation-sweep results for `BENCH_estimation.json`.
pub fn estimation_json(cfg: &EstimationSweepConfig, rows: &[EstimationRow]) -> Json {
    let rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::from_pairs([
                ("topology", Json::from(r.topology.clone())),
                ("workload", r.workload.clone().into()),
                ("profile", r.profile.clone().into()),
                ("estimator", r.estimator.clone().into()),
                ("avg_cct_s", r.avg_cct.into()),
                ("p99_cct_s", r.p99_cct.into()),
                ("cct_vs_oracle", r.cct_vs_oracle.into()),
                ("est_mape", r.est_mape.into()),
                ("est_samples", r.est_samples.into()),
                ("est_probes", r.est_probes.into()),
                ("stale_events", r.stale_events.into()),
                ("stale_resolved", r.stale_resolved.into()),
                ("stale_reaction_s_avg", r.stale_reaction_s_avg.into()),
                ("deadline_met", r.deadline_met.into()),
                ("rounds", r.rounds.into()),
                ("wan_events", r.wan_events.into()),
                ("wan_rounds", r.wan_rounds.into()),
                ("unfinished", r.unfinished.into()),
                ("makespan_s", r.makespan.into()),
            ])
        })
        .collect();
    Json::from_pairs([
        ("seed", Json::from(cfg.seed)),
        ("jobs", cfg.jobs.into()),
        ("horizon_s", cfg.horizon_s.into()),
        ("deadline_d", cfg.deadline_d.into()),
        ("topology", cfg.topology.clone().into()),
        ("workload", cfg.workload.clone().into()),
        (
            "profiles",
            cfg.profiles.iter().map(|p| Json::from(p.clone())).collect::<Vec<_>>().into(),
        ),
        (
            "estimators",
            cfg.estimators.iter().map(|p| Json::from(p.clone())).collect::<Vec<_>>().into(),
        ),
        ("rows", Json::Arr(rows)),
    ])
}

/// Configuration of the **recovery sweep** (the `controller_chaos` axis):
/// a controller kill/restart injected while WAN dynamics are active,
/// comparing resync state reconstruction against an always-up controller
/// and a restart-from-zero strawman. Terra policy throughout — the axis
/// under study is what a controller crash costs, not which policy wins.
#[derive(Clone, Debug)]
pub struct RecoverySweepConfig {
    pub jobs: usize,
    pub seed: u64,
    /// Dynamics generation horizon (seconds of simulated time).
    pub horizon_s: f64,
    pub topology: String,
    pub workload: String,
    /// Dynamics profiles active while the controller dies. Defaults to the
    /// paper's failure cases: calm anchor, regional outages, gray failures
    /// — the crash lands *during* the network trouble.
    pub profiles: Vec<String>,
    /// Controller kill / restart instants (simulated seconds). Defaults
    /// land mid-workload: BigBench jobs run for minutes.
    pub kill_t: f64,
    pub restart_t: f64,
}

impl Default for RecoverySweepConfig {
    fn default() -> Self {
        RecoverySweepConfig {
            jobs: 6,
            seed: 7,
            horizon_s: 420.0,
            topology: "swan".into(),
            workload: "bigbench".into(),
            profiles: vec!["calm".into(), "regional".into(), "gray".into()],
            kill_t: 30.0,
            restart_t: 35.0,
        }
    }
}

/// The controller-availability modes the recovery sweep compares.
pub const RECOVERY_MODES: [&str; 3] = ["always-up", "resync", "from-zero"];

fn chaos_for_mode(mode: &str, cfg: &RecoverySweepConfig) -> Option<crate::sim::ChaosConfig> {
    use crate::sim::{ChaosConfig, RecoveryMode};
    match mode {
        "always-up" => None,
        "resync" => Some(ChaosConfig::new(cfg.kill_t, cfg.restart_t, RecoveryMode::Resync)),
        "from-zero" => Some(ChaosConfig::new(cfg.kill_t, cfg.restart_t, RecoveryMode::FromZero)),
        other => panic!("unknown recovery mode {other}"),
    }
}

/// One recovery-sweep cell: a ⟨profile, availability mode⟩ outcome.
#[derive(Clone, Debug)]
pub struct RecoveryRow {
    pub topology: String,
    pub workload: String,
    pub profile: String,
    /// One of [`RECOVERY_MODES`].
    pub mode: String,
    pub avg_cct: f64,
    pub p99_cct: f64,
    /// CCT inflation vs the always-up controller on the identical
    /// scenario (1.0 = the crash cost nothing; always-up is 1.0 by
    /// construction).
    pub cct_vs_always_up: f64,
    /// In-flight volume preserved across the restart
    /// ([`Report::preserved_fraction`]): 1.0 for resync, < 1.0 for
    /// from-zero by exactly the progress thrown away.
    pub preserved_fraction: f64,
    pub inflight_at_kill_gbit: f64,
    /// Gbit agents kept draining in degraded mode during the outage.
    pub drained_degraded_gbit: f64,
    pub downtime_s: f64,
    /// Wall-clock cost (ms) of the restarted controller's reconstruction
    /// round — the recovery-time metric.
    pub recovery_round_ms: f64,
    pub rounds: usize,
    pub unfinished: usize,
    pub makespan: f64,
}

/// Run the recovery sweep: every ⟨profile, mode⟩ cell replays the
/// *identical* workload and ground-truth event stream; only controller
/// availability differs. Rows come back in deterministic sweep order,
/// the always-up baseline computed per profile to anchor
/// `cct_vs_always_up`.
pub fn recovery_sweep(cfg: &RecoverySweepConfig) -> Vec<RecoveryRow> {
    let Some(wan) = topologies::by_name(&cfg.topology) else {
        log::warn!("unknown topology {}; empty recovery sweep", cfg.topology);
        return Vec::new();
    };
    let Some(kind) = WorkloadKind::by_name(&cfg.workload) else {
        log::warn!("unknown workload {}; empty recovery sweep", cfg.workload);
        return Vec::new();
    };
    let wseed = scenario_seed(cfg.seed, 0, 0, usize::MAX);
    let wcfg = WorkloadConfig::new(kind, wseed);
    let jobs = WorkloadGen::with_config(wcfg).jobs(&wan, cfg.jobs);
    let mut rows = Vec::new();
    for (pi, pname) in cfg.profiles.iter().enumerate() {
        let Some(profile) = DynamicsProfile::by_name(pname) else {
            log::warn!("unknown dynamics profile {pname}; skipping");
            continue;
        };
        let sseed = scenario_seed(cfg.seed, 0, 0, pi);
        let events = dynamics::generate(&wan, &profile, cfg.horizon_s, sseed);
        let run = |chaos: Option<crate::sim::ChaosConfig>| -> Report {
            let sim_cfg = SimConfig { chaos, ..Default::default() };
            let mut sim =
                Simulation::new(wan.clone(), Box::new(TerraPolicy::default()), sim_cfg);
            for ev in &events {
                sim.add_wan_event(ev.t, ev.ev.clone());
            }
            sim.run_jobs(jobs.clone())
        };
        let always_up = run(None);
        for mode in RECOVERY_MODES {
            let rep = if mode == "always-up" {
                always_up.clone()
            } else {
                run(chaos_for_mode(mode, cfg))
            };
            rows.push(RecoveryRow {
                topology: cfg.topology.clone(),
                workload: cfg.workload.clone(),
                profile: profile.name.clone(),
                mode: mode.to_string(),
                avg_cct: rep.avg_cct(),
                p99_cct: rep.p99_cct(),
                cct_vs_always_up: rep.avg_cct() / always_up.avg_cct().max(1e-9),
                preserved_fraction: rep.preserved_fraction(),
                inflight_at_kill_gbit: rep.inflight_at_kill_gbit,
                drained_degraded_gbit: rep.drained_degraded_gbit,
                downtime_s: rep.chaos_downtime_s,
                recovery_round_ms: 1e3 * rep.recovery_round_s,
                rounds: rep.rounds,
                unfinished: rep.unfinished(),
                makespan: rep.makespan,
            });
        }
    }
    rows
}

/// Serialize recovery-sweep results for `BENCH_recovery.json`.
pub fn recovery_json(cfg: &RecoverySweepConfig, rows: &[RecoveryRow]) -> Json {
    let rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::from_pairs([
                ("topology", Json::from(r.topology.clone())),
                ("workload", r.workload.clone().into()),
                ("profile", r.profile.clone().into()),
                ("mode", r.mode.clone().into()),
                ("avg_cct_s", r.avg_cct.into()),
                ("p99_cct_s", r.p99_cct.into()),
                ("cct_vs_always_up", r.cct_vs_always_up.into()),
                ("preserved_fraction", r.preserved_fraction.into()),
                ("inflight_at_kill_gbit", r.inflight_at_kill_gbit.into()),
                ("drained_degraded_gbit", r.drained_degraded_gbit.into()),
                ("downtime_s", r.downtime_s.into()),
                ("recovery_round_ms", r.recovery_round_ms.into()),
                ("rounds", r.rounds.into()),
                ("unfinished", r.unfinished.into()),
                ("makespan_s", r.makespan.into()),
            ])
        })
        .collect();
    Json::from_pairs([
        ("seed", Json::from(cfg.seed)),
        ("jobs", cfg.jobs.into()),
        ("horizon_s", cfg.horizon_s.into()),
        ("topology", cfg.topology.clone().into()),
        ("workload", cfg.workload.clone().into()),
        ("kill_t", cfg.kill_t.into()),
        ("restart_t", cfg.restart_t.into()),
        (
            "profiles",
            cfg.profiles.iter().map(|p| Json::from(p.clone())).collect::<Vec<_>>().into(),
        ),
        (
            "modes",
            RECOVERY_MODES.iter().map(|m| Json::from(m.to_string())).collect::<Vec<_>>().into(),
        ),
        ("rows", Json::Arr(rows)),
    ])
}

/// Configuration of the **agent-chaos sweep** (the data-plane half of the
/// fault-tolerance axis): one site's agent dies — or its data plane is
/// partitioned — mid-workload while WAN dynamics are active, and the
/// controller must detect it, park the touched coflows with their progress
/// intact, re-solve the survivors, and resume the victims at the heal.
/// Terra policy throughout; the axis under study is what a data-plane
/// failure costs, not which policy wins.
#[derive(Clone, Debug)]
pub struct AgentChaosSweepConfig {
    pub jobs: usize,
    pub seed: u64,
    /// Dynamics generation horizon (seconds of simulated time).
    pub horizon_s: f64,
    pub topology: String,
    pub workload: String,
    /// Dynamics profiles active while the site dies — same failure cases
    /// as the recovery sweep, so the two axes compose.
    pub profiles: Vec<String>,
    /// Site kill / heal instants (simulated seconds), mid-workload.
    pub kill_t: f64,
    pub restart_t: f64,
    /// The site whose agent (or data plane) fails.
    pub site: usize,
    /// Failure-detection latency: the liveness deadline (agent kill) or
    /// stall-watchdog horizon (partition) the controller needs before it
    /// declares the site down.
    pub detection_s: f64,
}

impl Default for AgentChaosSweepConfig {
    fn default() -> Self {
        AgentChaosSweepConfig {
            jobs: 6,
            seed: 7,
            horizon_s: 420.0,
            topology: "swan".into(),
            workload: "bigbench".into(),
            profiles: vec!["calm".into(), "regional".into(), "gray".into()],
            kill_t: 30.0,
            restart_t: 35.0,
            site: 1,
            detection_s: 2.0,
        }
    }
}

/// The data-plane availability modes the agent-chaos sweep compares. The
/// always-up anchor replays the identical scenario with no failure — its
/// cell doubles as the proof that the chaos machinery is structurally
/// inert when nothing fails.
pub const AGENT_CHAOS_MODES: [&str; 3] = ["always-up", "agent-kill", "partition"];

fn agent_chaos_for_mode(
    mode: &str,
    cfg: &AgentChaosSweepConfig,
) -> Option<crate::sim::ChaosConfig> {
    use crate::sim::{ChaosConfig, ChaosTarget, RecoveryMode};
    let base = || {
        ChaosConfig::new(cfg.kill_t, cfg.restart_t, RecoveryMode::Resync)
            .with_detection_s(cfg.detection_s)
    };
    match mode {
        "always-up" => None,
        "agent-kill" => Some(base().with_target(ChaosTarget::Agent { site: cfg.site })),
        "partition" => Some(base().with_target(ChaosTarget::Partition { site: cfg.site })),
        other => panic!("unknown agent-chaos mode {other}"),
    }
}

/// One agent-chaos cell: a ⟨profile, mode⟩ outcome.
#[derive(Clone, Debug)]
pub struct AgentChaosRow {
    pub topology: String,
    pub workload: String,
    pub profile: String,
    /// One of [`AGENT_CHAOS_MODES`].
    pub mode: String,
    pub avg_cct: f64,
    pub p99_cct: f64,
    /// CCT inflation vs the always-up data plane on the identical
    /// scenario (always-up is 1.0 by construction).
    pub cct_vs_always_up: f64,
    /// Site-down declarations the controller made (0 when the failure was
    /// a blip shorter than the detector).
    pub agent_downs: usize,
    /// Summed kill → declaration latency (seconds).
    pub detection_s: f64,
    /// Coflows parked at those declarations.
    pub parked: usize,
    /// Coflow·seconds of allocated-but-stalled traffic before detection.
    pub stall_s: f64,
    pub rounds: usize,
    pub unfinished: usize,
    pub makespan: f64,
}

/// Run the agent-chaos sweep: every ⟨profile, mode⟩ cell replays the
/// *identical* workload and ground-truth event stream; only the data-plane
/// failure differs. Rows come back in deterministic sweep order, the
/// always-up baseline computed per profile to anchor `cct_vs_always_up`.
pub fn agent_chaos_sweep(cfg: &AgentChaosSweepConfig) -> Vec<AgentChaosRow> {
    let Some(wan) = topologies::by_name(&cfg.topology) else {
        log::warn!("unknown topology {}; empty agent-chaos sweep", cfg.topology);
        return Vec::new();
    };
    let Some(kind) = WorkloadKind::by_name(&cfg.workload) else {
        log::warn!("unknown workload {}; empty agent-chaos sweep", cfg.workload);
        return Vec::new();
    };
    assert!(cfg.site < wan.num_nodes(), "chaos site outside the topology");
    let wseed = scenario_seed(cfg.seed, 0, 0, usize::MAX);
    let wcfg = WorkloadConfig::new(kind, wseed);
    let jobs = WorkloadGen::with_config(wcfg).jobs(&wan, cfg.jobs);
    let mut rows = Vec::new();
    for (pi, pname) in cfg.profiles.iter().enumerate() {
        let Some(profile) = DynamicsProfile::by_name(pname) else {
            log::warn!("unknown dynamics profile {pname}; skipping");
            continue;
        };
        let sseed = scenario_seed(cfg.seed, 0, 0, pi);
        let events = dynamics::generate(&wan, &profile, cfg.horizon_s, sseed);
        let run = |chaos: Option<crate::sim::ChaosConfig>| -> Report {
            let sim_cfg = SimConfig { chaos, ..Default::default() };
            let mut sim =
                Simulation::new(wan.clone(), Box::new(TerraPolicy::default()), sim_cfg);
            for ev in &events {
                sim.add_wan_event(ev.t, ev.ev.clone());
            }
            sim.run_jobs(jobs.clone())
        };
        let always_up = run(None);
        for mode in AGENT_CHAOS_MODES {
            let rep = if mode == "always-up" {
                always_up.clone()
            } else {
                run(agent_chaos_for_mode(mode, cfg))
            };
            rows.push(AgentChaosRow {
                topology: cfg.topology.clone(),
                workload: cfg.workload.clone(),
                profile: profile.name.clone(),
                mode: mode.to_string(),
                avg_cct: rep.avg_cct(),
                p99_cct: rep.p99_cct(),
                cct_vs_always_up: rep.avg_cct() / always_up.avg_cct().max(1e-9),
                agent_downs: rep.agent_downs,
                detection_s: rep.agent_detection_s,
                parked: rep.agent_parked,
                stall_s: rep.agent_stall_s,
                rounds: rep.rounds,
                unfinished: rep.unfinished(),
                makespan: rep.makespan,
            });
        }
    }
    rows
}

/// Serialize agent-chaos results for `BENCH_agent_chaos.json`.
pub fn agent_chaos_json(cfg: &AgentChaosSweepConfig, rows: &[AgentChaosRow]) -> Json {
    let rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::from_pairs([
                ("topology", Json::from(r.topology.clone())),
                ("workload", r.workload.clone().into()),
                ("profile", r.profile.clone().into()),
                ("mode", r.mode.clone().into()),
                ("avg_cct_s", r.avg_cct.into()),
                ("p99_cct_s", r.p99_cct.into()),
                ("cct_vs_always_up", r.cct_vs_always_up.into()),
                ("agent_downs", r.agent_downs.into()),
                ("detection_s", r.detection_s.into()),
                ("parked", r.parked.into()),
                ("stall_s", r.stall_s.into()),
                ("rounds", r.rounds.into()),
                ("unfinished", r.unfinished.into()),
                ("makespan_s", r.makespan.into()),
            ])
        })
        .collect();
    Json::from_pairs([
        ("seed", Json::from(cfg.seed)),
        ("jobs", cfg.jobs.into()),
        ("horizon_s", cfg.horizon_s.into()),
        ("topology", cfg.topology.clone().into()),
        ("workload", cfg.workload.clone().into()),
        ("kill_t", cfg.kill_t.into()),
        ("restart_t", cfg.restart_t.into()),
        ("site", cfg.site.into()),
        ("detection_s", cfg.detection_s.into()),
        (
            "profiles",
            cfg.profiles.iter().map(|p| Json::from(p.clone())).collect::<Vec<_>>().into(),
        ),
        (
            "modes",
            AGENT_CHAOS_MODES.iter().map(|m| Json::from(m.to_string())).collect::<Vec<_>>().into(),
        ),
        ("rows", Json::Arr(rows)),
    ])
}

/// Configuration of the **multi-tenant sweep** (the service-class axis):
/// batch GDA jobs, streaming rate-floor coflows, and recurring geo-ML
/// aggregation-tree jobs sharing one WAN while dynamics profiles inject
/// gray failures and regional outages. Terra policy throughout — the axis
/// under study is per-class outcomes (batch CCT, stream
/// violation-seconds, ML iteration time) under cross-class contention.
#[derive(Clone, Debug)]
pub struct MultitenantSweepConfig {
    /// Batch jobs generated from `workload`.
    pub jobs: usize,
    /// Streaming coflows ([`stream_jobs`]).
    pub streams: usize,
    /// Geo-ML jobs and synchronization iterations per job
    /// ([`ml_sync_jobs`]).
    pub ml_jobs: usize,
    pub ml_iters: usize,
    pub seed: u64,
    /// Dynamics generation horizon (seconds of simulated time).
    pub horizon_s: f64,
    pub topology: String,
    pub workload: String,
    /// Dynamics profiles to sweep; ≥ 2 so per-class behavior is observed
    /// both at rest and under gray failure pressure.
    pub profiles: Vec<String>,
}

impl Default for MultitenantSweepConfig {
    fn default() -> Self {
        MultitenantSweepConfig {
            jobs: 6,
            streams: 8,
            ml_jobs: 3,
            ml_iters: 4,
            seed: 7,
            horizon_s: 420.0,
            topology: "swan".into(),
            workload: "bigbench".into(),
            profiles: vec!["calm".into(), "gray".into(), "regional".into()],
        }
    }
}

/// The service classes the multitenant sweep reports on (one row per
/// ⟨profile, class⟩ cell).
pub const MULTITENANT_CLASSES: [&str; 3] = ["batch", "stream", "ml-sync"];

/// One multitenant-sweep cell: a ⟨profile, class⟩ outcome.
#[derive(Clone, Debug)]
pub struct MultitenantRow {
    pub topology: String,
    pub workload: String,
    pub profile: String,
    /// One of [`MULTITENANT_CLASSES`].
    pub class: String,
    /// Coflows of this class (every stream and every ML iteration counts
    /// once), including rejected ones.
    pub coflows: usize,
    /// Admission-rejected coflows of this class.
    pub rejected: usize,
    pub unfinished: usize,
    /// Average CCT of this class; for `ml-sync` this *is* the average
    /// synchronization iteration time.
    pub avg_cct: f64,
    /// Stream rows: total violation-seconds (seconds × streams spent below
    /// the rate floor). 0 elsewhere.
    pub violation_s: f64,
    /// ml-sync rows: tree edges re-parented to the root because their link
    /// had degraded when the iteration was submitted. 0 elsewhere.
    pub tree_reshapes: usize,
    /// Stream rows: integral of unreservable floor demand over rounds
    /// (Gbps·rounds). 0 elsewhere.
    pub floor_shortfall_gbps: f64,
    pub makespan: f64,
}

/// Run the multitenant sweep: one mixed workload (batch + streams + ML
/// sync), generated once and replayed per profile so every profile
/// schedules the identical job mix against its own event stream. Rows come
/// back in deterministic sweep order, [`MULTITENANT_CLASSES`] per profile.
pub fn multitenant_sweep(cfg: &MultitenantSweepConfig) -> Vec<MultitenantRow> {
    let Some(wan) = topologies::by_name(&cfg.topology) else {
        log::warn!("unknown topology {}; empty multitenant sweep", cfg.topology);
        return Vec::new();
    };
    let Some(kind) = WorkloadKind::by_name(&cfg.workload) else {
        log::warn!("unknown workload {}; empty multitenant sweep", cfg.workload);
        return Vec::new();
    };
    let wseed = scenario_seed(cfg.seed, 0, 0, usize::MAX);
    let mut jobs = WorkloadGen::with_config(WorkloadConfig::new(kind, wseed)).jobs(&wan, cfg.jobs);
    // Id bases keep the three generators' job ids disjoint.
    jobs.extend(stream_jobs(&wan, cfg.streams, 10_000, wseed));
    jobs.extend(ml_sync_jobs(&wan, cfg.ml_jobs, cfg.ml_iters, 20_000, wseed));
    let mut rows = Vec::new();
    for (pi, pname) in cfg.profiles.iter().enumerate() {
        let Some(profile) = DynamicsProfile::by_name(pname) else {
            log::warn!("unknown dynamics profile {pname}; skipping");
            continue;
        };
        let sseed = scenario_seed(cfg.seed, 0, 0, pi);
        let events = dynamics::generate(&wan, &profile, cfg.horizon_s, sseed);
        let mut sim =
            Simulation::new(wan.clone(), Box::new(TerraPolicy::default()), SimConfig::default());
        for ev in &events {
            sim.add_wan_event(ev.t, ev.ev.clone());
        }
        let rep = sim.run_jobs(jobs.clone());
        for class in MULTITENANT_CLASSES {
            rows.push(MultitenantRow {
                topology: cfg.topology.clone(),
                workload: cfg.workload.clone(),
                profile: profile.name.clone(),
                class: class.to_string(),
                coflows: rep.class_count(class),
                rejected: rep.coflows.iter().filter(|c| c.class == class && !c.admitted).count(),
                unfinished: rep
                    .coflows
                    .iter()
                    .filter(|c| c.class == class && c.admitted && c.finish.is_none())
                    .count(),
                avg_cct: rep.avg_cct_class(class),
                violation_s: if class == "stream" { rep.stream_violation_s } else { 0.0 },
                tree_reshapes: if class == "ml-sync" { rep.tree_reshapes } else { 0 },
                floor_shortfall_gbps: if class == "stream" {
                    rep.floor_shortfall_gbps
                } else {
                    0.0
                },
                makespan: rep.makespan,
            });
        }
    }
    rows
}

/// Serialize multitenant-sweep results for `BENCH_multitenant.json`.
pub fn multitenant_json(cfg: &MultitenantSweepConfig, rows: &[MultitenantRow]) -> Json {
    let rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::from_pairs([
                ("topology", Json::from(r.topology.clone())),
                ("workload", r.workload.clone().into()),
                ("profile", r.profile.clone().into()),
                ("class", r.class.clone().into()),
                ("coflows", r.coflows.into()),
                ("rejected", r.rejected.into()),
                ("unfinished", r.unfinished.into()),
                ("avg_cct_s", r.avg_cct.into()),
                ("violation_s", r.violation_s.into()),
                ("tree_reshapes", r.tree_reshapes.into()),
                ("floor_shortfall_gbps", r.floor_shortfall_gbps.into()),
                ("makespan_s", r.makespan.into()),
            ])
        })
        .collect();
    Json::from_pairs([
        ("seed", Json::from(cfg.seed)),
        ("jobs", cfg.jobs.into()),
        ("streams", cfg.streams.into()),
        ("ml_jobs", cfg.ml_jobs.into()),
        ("ml_iters", cfg.ml_iters.into()),
        ("horizon_s", cfg.horizon_s.into()),
        ("topology", cfg.topology.clone().into()),
        ("workload", cfg.workload.clone().into()),
        (
            "profiles",
            cfg.profiles.iter().map(|p| Json::from(p.clone())).collect::<Vec<_>>().into(),
        ),
        (
            "classes",
            MULTITENANT_CLASSES.iter().map(|c| Json::from(c.to_string())).collect::<Vec<_>>().into(),
        ),
        ("rows", Json::Arr(rows)),
    ])
}

/// Configuration of the **saturation sweep**: open-loop arrivals sampled
/// from empirical workload histograms ([`WorkloadProfile`]), ramped
/// geometrically and then bisected to the *knee* — the highest coflow
/// arrival rate λ (coflows/s) a ⟨policy, topology, dynamics profile,
/// shard count⟩ cell sustains without violating the windowed SLOs
/// (p99 slowdown and deadline-miss rate over the measurement window).
#[derive(Clone, Debug)]
pub struct SaturationSweepConfig {
    pub seed: u64,
    pub topologies: Vec<String>,
    /// Fixed workload whose job set the empirical histograms are fitted to.
    pub workload: String,
    pub policies: Vec<String>,
    pub profiles: Vec<String>,
    pub shard_counts: Vec<usize>,
    pub estimator: String,
    /// Interarrival shape (`poisson` / `pareto` / `lognormal`).
    pub interarrival: String,
    /// Independent open-loop submission streams (Pcg32-forked per stream).
    pub streams: usize,
    /// Jobs sampled from the fixed generator to fit the histograms.
    pub profile_samples: usize,
    /// Arrivals in `[0, warmup)` fill the pipe but are not measured.
    pub warmup_s: f64,
    /// SLOs are judged on coflows arriving in `[warmup, warmup+measure)`.
    pub measure_s: f64,
    /// No new arrivals in the drain tail; in-flight work may finish.
    pub drain_s: f64,
    /// Relative deadline factor (`deadline = d × min CCT`); 0 disables.
    pub deadline_d: f64,
    /// Starting arrival rate of the ramp (coflows/s, all streams summed).
    pub lambda0: f64,
    /// Geometric ramp factor (λ ×= growth until unsustainable).
    pub growth: f64,
    /// Ramp cap. A cell still sustainable here reports the cap as a
    /// *lower bound* on its knee (`saturated = false`).
    pub max_lambda: f64,
    /// Geometric-bisection refinements after the ramp brackets the knee.
    pub bisect_iters: usize,
    /// Sustainable ⇔ windowed p99 slowdown ≤ this …
    pub p99_slowdown_limit: f64,
    /// … AND windowed deadline-miss rate ≤ this.
    pub miss_limit: f64,
}

impl Default for SaturationSweepConfig {
    fn default() -> SaturationSweepConfig {
        SaturationSweepConfig {
            seed: 7,
            topologies: vec!["swan".into()],
            workload: "fb".into(),
            policies: vec!["terra".into()],
            profiles: vec!["calm".into(), "flaky".into()],
            shard_counts: vec![1, 2],
            estimator: "oracle".into(),
            interarrival: "poisson".into(),
            streams: 4,
            profile_samples: 60,
            warmup_s: 60.0,
            measure_s: 120.0,
            drain_s: 60.0,
            deadline_d: 3.0,
            lambda0: 0.05,
            growth: 2.0,
            max_lambda: 6.4,
            bisect_iters: 5,
            p99_slowdown_limit: 8.0,
            miss_limit: 0.1,
        }
    }
}

impl SaturationSweepConfig {
    /// CI-sized cell: one calm profile, short windows, low ramp cap.
    pub fn quick() -> SaturationSweepConfig {
        SaturationSweepConfig {
            profiles: vec!["calm".into()],
            profile_samples: 30,
            warmup_s: 20.0,
            measure_s: 60.0,
            drain_s: 30.0,
            lambda0: 0.1,
            max_lambda: 1.6,
            bisect_iters: 3,
            ..SaturationSweepConfig::default()
        }
    }

    fn horizon(&self) -> f64 {
        self.warmup_s + self.measure_s + self.drain_s
    }
}

/// One saturation cell: the knee plus the SLO metrics measured *at* the
/// knee (the highest sustainable λ evaluated).
#[derive(Clone, Debug)]
pub struct SaturationRow {
    pub topology: String,
    pub workload: String,
    pub policy: String,
    pub profile: String,
    pub shards: usize,
    pub estimator: String,
    pub interarrival: String,
    /// Max sustainable coflows/s (0 if even `lambda0` is unsustainable).
    pub knee_lambda: f64,
    /// Simulation runs spent locating the knee.
    pub evals: usize,
    /// False ⇔ the ramp cap was still sustainable (knee is a lower bound).
    pub saturated: bool,
    pub offered: usize,
    pub admitted: usize,
    pub rejected: usize,
    pub backlog_p99: f64,
    pub p99_slowdown: f64,
    pub miss_rate: f64,
    pub avg_cct: f64,
    pub deadline_met: f64,
    /// Estimation-quality column: belief error at the knee …
    pub est_mape: f64,
    /// … and how fast stale beliefs were corrected (0 if none went stale).
    pub stale_reaction_s: f64,
    pub unfinished: usize,
}

/// Windowed sustainability verdict of one open-loop run.
#[derive(Clone, Debug)]
struct SatEval {
    sustainable: bool,
    offered: usize,
    admitted: usize,
    rejected: usize,
    backlog_p99: f64,
    p99_slowdown: f64,
    miss_rate: f64,
    avg_cct: f64,
    deadline_met: f64,
    est_mape: f64,
    stale_reaction_s: f64,
    unfinished: usize,
}

/// Judge one run over the measurement window `[w0, w1)` (by coflow
/// arrival time). Censoring keeps the verdict honest at the horizon: a
/// coflow still in flight contributes its *measured lower bound*
/// `(horizon − arrival) / min_cct` as slowdown (a huge transfer that
/// simply ran out of drain time does not fake an overload, while a small
/// coflow stuck behind a real backlog does trip the limit), and a
/// deadline-bearing coflow only enters the miss rate once its outcome is
/// decided (finished, rejected, or deadline already expired).
fn saturation_window_eval(
    rep: &Report,
    w0: f64,
    w1: f64,
    horizon: f64,
    cfg: &SaturationSweepConfig,
) -> SatEval {
    let mut offered = 0usize;
    let mut admitted = 0usize;
    let mut rejected = 0usize;
    let mut unfinished = 0usize;
    let mut slowdowns = Vec::new();
    let mut ccts = Vec::new();
    let (mut with_deadline, mut met) = (0usize, 0usize);
    for c in rep.coflows.iter().filter(|c| c.arrival >= w0 && c.arrival < w1) {
        offered += 1;
        if c.admitted {
            admitted += 1;
            match c.slowdown() {
                Some(s) => slowdowns.push(s),
                None => {
                    unfinished += 1;
                    slowdowns.push((horizon - c.arrival) / c.min_cct.max(1e-9));
                }
            }
            if let Some(cct) = c.cct() {
                ccts.push(cct);
            }
        } else {
            rejected += 1;
        }
        if let Some(d) = c.deadline {
            let decided = c.finish.is_some() || !c.admitted || d <= horizon;
            if decided {
                with_deadline += 1;
                if c.met_deadline() {
                    met += 1;
                }
            }
        }
    }
    let p99_slowdown = stats::percentile(&slowdowns, 99.0);
    let (miss_rate, deadline_met) = if with_deadline == 0 {
        (0.0, 1.0)
    } else {
        let met_frac = met as f64 / with_deadline as f64;
        (1.0 - met_frac, met_frac)
    };
    SatEval {
        sustainable: p99_slowdown <= cfg.p99_slowdown_limit && miss_rate <= cfg.miss_limit,
        offered,
        admitted,
        rejected,
        backlog_p99: rep.backlog_p99_between(w0, w1),
        p99_slowdown,
        miss_rate,
        avg_cct: stats::mean(&ccts),
        deadline_met,
        est_mape: rep.est_mape(),
        stale_reaction_s: rep.avg_stale_reaction_s(),
        unfinished,
    }
}

/// The load-ramp controller: step λ geometrically from `lambda0` until a
/// run goes unsustainable (or the cap is hit), then geometrically bisect
/// (`mid = √(lo·hi)`) the bracket. Returns `(knee, saturated, eval at the
/// knee, evaluations spent)`; the knee is the highest λ *evaluated as
/// sustainable*, so the reported metrics always come from a real run.
fn find_knee<F: FnMut(f64) -> SatEval>(
    mut eval: F,
    cfg: &SaturationSweepConfig,
) -> (f64, bool, SatEval, usize) {
    let mut evals = 1usize;
    let first = eval(cfg.lambda0);
    if !first.sustainable {
        return (0.0, true, first, evals);
    }
    let mut lo = cfg.lambda0;
    let mut lo_eval = first;
    let mut hi = None;
    let mut l = cfg.lambda0;
    while hi.is_none() {
        l *= cfg.growth;
        let capped = l >= cfg.max_lambda;
        let probe = if capped { cfg.max_lambda } else { l };
        if probe <= lo {
            // Degenerate ramp (growth ≤ 1 or cap ≤ lambda0): nothing above
            // lo to probe, report lo as an unsaturated lower bound.
            return (lo, false, lo_eval, evals);
        }
        let e = eval(probe);
        evals += 1;
        if e.sustainable {
            if capped {
                return (cfg.max_lambda, false, e, evals);
            }
            lo = probe;
            lo_eval = e;
        } else {
            hi = Some(probe);
        }
    }
    let mut hi = hi.unwrap();
    for _ in 0..cfg.bisect_iters {
        let mid = (lo * hi).sqrt();
        if !(mid > lo && mid < hi) {
            break;
        }
        let e = eval(mid);
        evals += 1;
        if e.sustainable {
            lo = mid;
            lo_eval = e;
        } else {
            hi = mid;
        }
    }
    (lo, true, lo_eval, evals)
}

/// The saturation sweep: locate the knee of every ⟨topology, dynamics
/// profile, policy, shard count⟩ cell. The arrival stream for a cell is a
/// pure function of the cell seed and λ — and the cell seed deliberately
/// **excludes the shard count**, so every shard count in a cell faces the
/// byte-identical offered load (the shards>1 ≥ shards=1 comparison is
/// apples to apples, and with property-pinned identical allocations the
/// knees match exactly).
pub fn saturation_sweep(cfg: &SaturationSweepConfig) -> Vec<SaturationRow> {
    let Some(kind) = WorkloadKind::by_name(&cfg.workload) else {
        log::warn!("unknown workload {}; empty saturation sweep", cfg.workload);
        return Vec::new();
    };
    if Interarrival::by_name(&cfg.interarrival, 1.0).is_none() {
        log::warn!("unknown interarrival {}; empty saturation sweep", cfg.interarrival);
        return Vec::new();
    }
    let Some(telemetry) = TelemetryConfig::by_name(&cfg.estimator) else {
        log::warn!("unknown estimator {}; empty saturation sweep", cfg.estimator);
        return Vec::new();
    };
    let horizon = cfg.horizon();
    let mut rows = Vec::new();
    for (ti, tname) in cfg.topologies.iter().enumerate() {
        let Some(wan) = topologies::by_name(tname) else {
            log::warn!("unknown topology {tname}; skipping");
            continue;
        };
        // Empirical histograms fitted once per topology from the fixed
        // generator's job set (volume / width / src / dst / class mix).
        let pseed = scenario_seed(cfg.seed, ti, 0, usize::MAX);
        let wprofile = WorkloadProfile::from_kind(kind, &wan, pseed, cfg.profile_samples);
        for (di, pname) in cfg.profiles.iter().enumerate() {
            let Some(profile) = DynamicsProfile::by_name(pname) else {
                log::warn!("unknown dynamics profile {pname}; skipping");
                continue;
            };
            let sseed = scenario_seed(cfg.seed, ti, di, 0);
            let stream = dynamics::generate_stream(&wan, &profile, horizon, sseed);
            for (pi, polname) in cfg.policies.iter().enumerate() {
                if baselines::by_name(polname).is_none() {
                    log::warn!("unknown policy {polname}; skipping");
                    continue;
                }
                // Shard-independent cell seed (see the function doc).
                let cell_seed = scenario_seed(cfg.seed, ti, di, pi + 1);
                for &shards in &cfg.shard_counts {
                    let eval = |lambda: f64| -> SatEval {
                        let gen_cfg = OpenLoopConfig {
                            seed: cell_seed,
                            lambda,
                            interarrival: cfg.interarrival.clone(),
                            streams: cfg.streams,
                            // No new arrivals in the drain tail.
                            horizon_s: cfg.warmup_s + cfg.measure_s,
                            base_id: 1_000_000,
                        };
                        let mut jobs = OpenLoopGen::new(wprofile.clone(), gen_cfg).jobs();
                        if cfg.deadline_d > 0.0 {
                            assign_deadlines(&mut jobs, &wan, cfg.deadline_d);
                        }
                        let sim_cfg = SimConfig {
                            shards: shards.max(1),
                            telemetry: telemetry.clone(),
                            max_time: horizon,
                            ..Default::default()
                        };
                        let mut sim = Simulation::new(
                            wan.clone(),
                            baselines::by_name(polname).unwrap(),
                            sim_cfg,
                        );
                        for ev in &stream.events {
                            sim.add_wan_event(ev.t, ev.ev.clone());
                        }
                        for w in &stream.announcements {
                            sim.add_announcement(w);
                        }
                        let rep = sim.run_jobs(jobs);
                        saturation_window_eval(
                            &rep,
                            cfg.warmup_s,
                            cfg.warmup_s + cfg.measure_s,
                            horizon,
                            cfg,
                        )
                    };
                    let (knee, saturated, at_knee, evals) = find_knee(eval, cfg);
                    rows.push(SaturationRow {
                        topology: tname.clone(),
                        workload: cfg.workload.clone(),
                        policy: polname.clone(),
                        profile: profile.name.clone(),
                        shards,
                        estimator: cfg.estimator.clone(),
                        interarrival: cfg.interarrival.clone(),
                        knee_lambda: knee,
                        evals,
                        saturated,
                        offered: at_knee.offered,
                        admitted: at_knee.admitted,
                        rejected: at_knee.rejected,
                        backlog_p99: at_knee.backlog_p99,
                        p99_slowdown: at_knee.p99_slowdown,
                        miss_rate: at_knee.miss_rate,
                        avg_cct: at_knee.avg_cct,
                        deadline_met: at_knee.deadline_met,
                        est_mape: at_knee.est_mape,
                        stale_reaction_s: at_knee.stale_reaction_s,
                        unfinished: at_knee.unfinished,
                    });
                }
            }
        }
    }
    rows
}

/// Serialize saturation-sweep results for `BENCH_saturation.json`.
pub fn saturation_json(cfg: &SaturationSweepConfig, rows: &[SaturationRow]) -> Json {
    let rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::from_pairs([
                ("topology", Json::from(r.topology.clone())),
                ("workload", r.workload.clone().into()),
                ("policy", r.policy.clone().into()),
                ("profile", r.profile.clone().into()),
                ("shards", r.shards.into()),
                ("estimator", r.estimator.clone().into()),
                ("interarrival", r.interarrival.clone().into()),
                ("knee_lambda", r.knee_lambda.into()),
                ("evals", r.evals.into()),
                ("saturated", r.saturated.into()),
                ("offered", r.offered.into()),
                ("admitted", r.admitted.into()),
                ("rejected", r.rejected.into()),
                ("backlog_p99", r.backlog_p99.into()),
                ("p99_slowdown", r.p99_slowdown.into()),
                ("miss_rate", r.miss_rate.into()),
                ("avg_cct_s", r.avg_cct.into()),
                ("deadline_met", r.deadline_met.into()),
                ("est_mape", r.est_mape.into()),
                ("stale_reaction_s", r.stale_reaction_s.into()),
                ("unfinished", r.unfinished.into()),
            ])
        })
        .collect();
    Json::from_pairs([
        ("seed", Json::from(cfg.seed)),
        ("workload", cfg.workload.clone().into()),
        ("estimator", cfg.estimator.clone().into()),
        ("interarrival", cfg.interarrival.clone().into()),
        ("streams", cfg.streams.into()),
        ("warmup_s", cfg.warmup_s.into()),
        ("measure_s", cfg.measure_s.into()),
        ("drain_s", cfg.drain_s.into()),
        ("deadline_d", cfg.deadline_d.into()),
        ("lambda0", cfg.lambda0.into()),
        ("growth", cfg.growth.into()),
        ("max_lambda", cfg.max_lambda.into()),
        ("bisect_iters", cfg.bisect_iters.into()),
        ("p99_slowdown_limit", cfg.p99_slowdown_limit.into()),
        ("miss_limit", cfg.miss_limit.into()),
        (
            "topologies",
            cfg.topologies.iter().map(|p| Json::from(p.clone())).collect::<Vec<_>>().into(),
        ),
        (
            "policies",
            cfg.policies.iter().map(|p| Json::from(p.clone())).collect::<Vec<_>>().into(),
        ),
        (
            "profiles",
            cfg.profiles.iter().map(|p| Json::from(p.clone())).collect::<Vec<_>>().into(),
        ),
        (
            "shard_counts",
            cfg.shard_counts.iter().map(|&s| Json::from(s)).collect::<Vec<_>>().into(),
        ),
        ("rows", Json::Arr(rows)),
    ])
}

/// Figure 1: the motivating example — average CCT of the two coflows under
/// the four policies of Fig 1c–1f. Returns (policy name, avg CCT seconds).
pub fn fig1_motivation() -> Vec<(String, f64)> {
    let wan = topologies::fig1a();
    let mk_jobs = || {
        vec![
            Job::map_reduce(
                1,
                0.0,
                0.0,
                vec![crate::coflow::Flow { id: 0, src_dc: 0, dst_dc: 1, volume: 5.0 * GB }],
            ),
            Job::map_reduce(
                2,
                0.0,
                0.0,
                vec![
                    crate::coflow::Flow { id: 0, src_dc: 0, dst_dc: 1, volume: 5.0 * GB },
                    crate::coflow::Flow { id: 1, src_dc: 2, dst_dc: 1, volume: 25.0 * GB },
                ],
            ),
        ]
    };
    let mut out = Vec::new();
    for pname in ["per-flow", "multipath", "varys", "terra"] {
        let policy: Box<dyn Policy> = if pname == "terra" {
            Box::new(TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() }))
        } else {
            baselines::by_name(pname).unwrap()
        };
        let mut sim = Simulation::new(wan.clone(), policy, SimConfig::default());
        let rep = sim.run_jobs(mk_jobs());
        out.push((pname.to_string(), rep.avg_cct()));
    }
    out
}

/// Figure 2: re-optimization under failure. Returns (scenario, avg CCT):
/// no failure (8 s), failure + Terra re-optimization (≈14 s paper-optimal).
pub fn fig2_reopt() -> Vec<(String, f64)> {
    let mk_jobs = || {
        vec![
            Job::map_reduce(
                1,
                0.0,
                0.0,
                vec![crate::coflow::Flow { id: 0, src_dc: 0, dst_dc: 1, volume: 10.0 * GB }],
            ),
            Job::map_reduce(
                2,
                0.0,
                0.0,
                vec![
                    crate::coflow::Flow { id: 0, src_dc: 2, dst_dc: 1, volume: 10.0 * GB },
                    crate::coflow::Flow { id: 1, src_dc: 0, dst_dc: 2, volume: 10.0 * GB },
                ],
            ),
        ]
    };
    let mut out = Vec::new();
    // Scenario A: no failure.
    let mut sim = Simulation::new(
        topologies::fig1a(),
        Box::new(TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() })),
        SimConfig::default(),
    );
    out.push(("no-failure".into(), sim.run_jobs(mk_jobs()).avg_cct()));
    // Scenario B: the A-C link fails right after scheduling; Terra
    // re-optimizes (application-aware).
    let mut sim = Simulation::new(
        topologies::fig1a(),
        Box::new(TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() })),
        SimConfig::default(),
    );
    for j in mk_jobs() {
        sim.add_job(j);
    }
    sim.add_wan_event(0.05, LinkEvent::Fail(0, 2));
    out.push(("failure+reopt".into(), sim.run().avg_cct()));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_ordering_matches_paper() {
        let rows = fig1_motivation();
        let get = |n: &str| rows.iter().find(|(p, _)| p == n).unwrap().1;
        let (fair, mp, varys, terra) = (get("per-flow"), get("multipath"), get("varys"), get("terra"));
        // Paper: 14 / 10.6 / 12 / 7.15 — exact values depend on fairness
        // refinements; the ORDERING is the claim.
        assert!(terra < mp && terra < varys && terra < fair, "{rows:?}");
        assert!(mp < fair, "{rows:?}");
        assert!(varys < fair, "{rows:?}");
        assert!((fair - 14.0).abs() < 1.0, "{rows:?}");
        // Paper's joint optimum is 7.15 s; the GK ε-approximation lands
        // within ~10% (≈8.0 s), still well ahead of every baseline.
        assert!(terra < 8.3, "{rows:?}");
    }

    #[test]
    fn fig2_failure_recovers() {
        let rows = fig2_reopt();
        let no_fail = rows[0].1;
        let with_fail = rows[1].1;
        assert!(no_fail < with_fail, "{rows:?}");
        // Paper: 8 s -> 14 s optimal after failure (18 s without
        // app-aware re-optimization).
        assert!(no_fail < 10.0, "{rows:?}");
        assert!(with_fail < 17.0, "failure handling too slow: {rows:?}");
    }

    #[test]
    fn small_table3_terra_wins_mostly() {
        let rows = table3(6, 7, Some("swan"));
        assert_eq!(rows.len(), 20); // 4 workloads x 5 baselines
        let wins = rows.iter().filter(|r| r.foi_avg_jct > 1.0).count();
        assert!(wins * 10 >= rows.len() * 7, "terra should win most cells: {wins}/{}", rows.len());
    }

    #[test]
    fn scenario_sweep_is_deterministic_and_covers_the_grid() {
        let cfg = SweepConfig {
            jobs: 2,
            seed: 7,
            // > one diurnal interval (75 s), so every edge emits at least
            // one fluctuation and the flaky rows are guaranteed non-empty.
            horizon_s: 160.0,
            profiles: vec!["calm".into(), "flaky".into()],
            policies: vec!["terra".into(), "per-flow".into()],
            topology: Some("swan".into()),
            // BigBench jobs run for minutes, so the workload is still busy
            // when the first dynamics events land (the simulator stops
            // delivering WAN events once all jobs finish).
            workload: Some("bigbench".into()),
            deadline_d: 0.0,
            shards: 1,
        };
        let a = scenario_sweep(&cfg);
        assert_eq!(a.len(), 4, "1 topo x 1 workload x 2 profiles x 2 policies");
        let b = scenario_sweep(&cfg);
        for (x, y) in a.iter().zip(&b) {
            // Virtual-time metrics are bit-deterministic given the seed
            // (wall-clock reaction latencies are not compared).
            assert_eq!(x.avg_cct.to_bits(), y.avg_cct.to_bits(), "{x:?} vs {y:?}");
            assert_eq!(x.rounds, y.rounds);
            assert_eq!(x.wan_events, y.wan_events);
            assert_eq!(x.wan_rounds, y.wan_rounds);
        }
        // The calm baseline sees no WAN events; flaky must deliver some.
        let calm: Vec<&ScenarioRow> = a.iter().filter(|r| r.profile == "calm").collect();
        let flaky: Vec<&ScenarioRow> = a.iter().filter(|r| r.profile == "flaky").collect();
        assert!(calm.iter().all(|r| r.wan_events == 0));
        assert!(flaky.iter().all(|r| r.wan_events > 0), "{flaky:?}");
    }

    #[test]
    fn estimation_sweep_covers_grid_oracle_anchors_baseline() {
        let cfg = EstimationSweepConfig {
            jobs: 2,
            horizon_s: 160.0,
            profiles: vec!["gray".into(), "maintenance".into()],
            estimators: vec!["oracle".into(), "ewma".into()],
            deadline_d: 3.0,
            ..Default::default()
        };
        let rows = estimation_sweep(&cfg);
        assert_eq!(rows.len(), 4, "2 profiles x 2 estimators");
        for r in &rows {
            assert_eq!(r.unfinished, 0, "{}/{} left work unfinished", r.profile, r.estimator);
            if r.estimator == "oracle" {
                assert_eq!(r.est_mape, 0.0, "the oracle has no estimation error");
                assert!((r.cct_vs_oracle - 1.0).abs() < 1e-12);
                assert_eq!(r.stale_reaction_s_avg, 0.0);
                assert_eq!(r.est_samples, 0);
            } else {
                assert!(r.est_samples > 0, "{}/{} ingested no samples", r.profile, r.estimator);
                assert!(r.cct_vs_oracle.is_finite());
            }
        }
        // Deadline-bearing workloads are wired through every cell.
        assert!(rows.iter().all(|r| r.deadline_met >= 0.0));
        // Deterministic: virtual-time metrics are bit-reproducible.
        let again = estimation_sweep(&cfg);
        for (a, b) in rows.iter().zip(&again) {
            assert_eq!(a.avg_cct.to_bits(), b.avg_cct.to_bits());
            assert_eq!(a.est_samples, b.est_samples);
            assert_eq!(a.stale_events, b.stale_events);
        }
    }

    #[test]
    fn recovery_sweep_covers_grid_resync_beats_from_zero() {
        let cfg = RecoverySweepConfig {
            jobs: 2,
            horizon_s: 160.0,
            profiles: vec!["calm".into()],
            kill_t: 20.0,
            restart_t: 24.0,
            ..Default::default()
        };
        let rows = recovery_sweep(&cfg);
        assert_eq!(rows.len(), 3, "1 profile x 3 availability modes");
        let get = |m: &str| rows.iter().find(|r| r.mode == m).unwrap();
        let (up, resync, zero) = (get("always-up"), get("resync"), get("from-zero"));
        assert!((up.cct_vs_always_up - 1.0).abs() < 1e-12);
        assert_eq!(up.downtime_s, 0.0);
        assert_eq!(up.preserved_fraction, 1.0);
        // The crash landed mid-workload: both chaos modes saw the outage.
        assert!((resync.downtime_s - 4.0).abs() < 1e-9, "{resync:?}");
        assert!(resync.drained_degraded_gbit > 0.0, "{resync:?}");
        assert!(resync.inflight_at_kill_gbit > 0.0, "{resync:?}");
        // Resync preserves progress; from-zero throws it away.
        assert!((resync.preserved_fraction - 1.0).abs() < 1e-9, "{resync:?}");
        assert!(zero.preserved_fraction < 1.0, "{zero:?}");
        // CCT cost orders: always-up ≤ resync ≤ from-zero.
        assert!(up.avg_cct <= resync.avg_cct + 1e-6, "{up:?} vs {resync:?}");
        assert!(resync.avg_cct <= zero.avg_cct + 1e-6, "{resync:?} vs {zero:?}");
        // Everything still finishes and the sweep is deterministic.
        assert!(rows.iter().all(|r| r.unfinished == 0), "{rows:?}");
        let again = recovery_sweep(&cfg);
        for (a, b) in rows.iter().zip(&again) {
            assert_eq!(a.avg_cct.to_bits(), b.avg_cct.to_bits());
            assert_eq!(a.preserved_fraction.to_bits(), b.preserved_fraction.to_bits());
        }
    }

    #[test]
    fn agent_chaos_sweep_covers_grid_and_is_deterministic() {
        let cfg = AgentChaosSweepConfig {
            jobs: 2,
            horizon_s: 160.0,
            profiles: vec!["calm".into()],
            kill_t: 20.0,
            restart_t: 26.0,
            detection_s: 1.0,
            ..Default::default()
        };
        let rows = agent_chaos_sweep(&cfg);
        assert_eq!(rows.len(), 3, "1 profile x 3 data-plane modes");
        let get = |m: &str| rows.iter().find(|r| r.mode == m).unwrap();
        let (up, kill, part) = (get("always-up"), get("agent-kill"), get("partition"));
        // Always-up anchors the inflation ratio and emits no chaos metrics.
        assert!((up.cct_vs_always_up - 1.0).abs() < 1e-12);
        assert_eq!(up.agent_downs, 0);
        assert_eq!(up.parked, 0);
        assert_eq!(up.stall_s, 0.0);
        // The outage outlives the detector, so both modes declare the site
        // down exactly once, at the configured latency.
        for r in [kill, part] {
            assert_eq!(r.agent_downs, 1, "{r:?}");
            assert!((r.detection_s - cfg.detection_s).abs() < 1e-9, "{r:?}");
        }
        // Agent kill and partition share flow-level semantics: identical
        // cells by construction (only the modeled detector differs).
        assert_eq!(kill.avg_cct.to_bits(), part.avg_cct.to_bits());
        // Everything still finishes and the sweep is deterministic.
        assert!(rows.iter().all(|r| r.unfinished == 0), "{rows:?}");
        let again = agent_chaos_sweep(&cfg);
        for (a, b) in rows.iter().zip(&again) {
            assert_eq!(a.avg_cct.to_bits(), b.avg_cct.to_bits());
            assert_eq!(a.stall_s.to_bits(), b.stall_s.to_bits());
        }
    }

    #[test]
    fn multitenant_sweep_covers_classes_and_is_deterministic() {
        let cfg = MultitenantSweepConfig {
            jobs: 2,
            streams: 3,
            ml_jobs: 2,
            ml_iters: 2,
            horizon_s: 160.0,
            profiles: vec!["calm".into(), "gray".into()],
            ..Default::default()
        };
        let rows = multitenant_sweep(&cfg);
        assert_eq!(rows.len(), 6, "2 profiles x 3 classes");
        for class in MULTITENANT_CLASSES {
            let of_class: Vec<&MultitenantRow> =
                rows.iter().filter(|r| r.class == class).collect();
            assert_eq!(of_class.len(), 2, "one {class} row per profile");
            assert!(of_class.iter().all(|r| r.coflows > 0), "{class} rows are empty");
        }
        // Every ML iteration is one coflow; each finished class reports a
        // positive average CCT.
        let ml = rows.iter().find(|r| r.class == "ml-sync").unwrap();
        assert_eq!(ml.coflows, 4, "2 jobs x 2 iterations");
        for r in &rows {
            if r.coflows > r.rejected + r.unfinished {
                assert!(r.avg_cct > 0.0, "{}/{} has no CCT", r.profile, r.class);
            }
            if r.class != "stream" {
                assert_eq!(r.violation_s, 0.0);
                assert_eq!(r.floor_shortfall_gbps, 0.0);
            }
        }
        // Deterministic: virtual-time metrics are bit-reproducible.
        let again = multitenant_sweep(&cfg);
        for (a, b) in rows.iter().zip(&again) {
            assert_eq!(a.avg_cct.to_bits(), b.avg_cct.to_bits());
            assert_eq!(a.violation_s.to_bits(), b.violation_s.to_bits());
            assert_eq!(a.coflows, b.coflows);
            assert_eq!(a.tree_reshapes, b.tree_reshapes);
        }
    }

    #[test]
    fn fig8_terra_meets_more_deadlines() {
        let rows = fig8_deadlines(8, 3, "per-flow");
        let t_avg: f64 = rows.iter().map(|r| r.terra_met).sum::<f64>() / rows.len() as f64;
        let b_avg: f64 = rows.iter().map(|r| r.baseline_met).sum::<f64>() / rows.len() as f64;
        assert!(t_avg > b_avg, "terra {t_avg} vs baseline {b_avg}");
    }

    fn tiny_saturation_cfg() -> SaturationSweepConfig {
        SaturationSweepConfig {
            topologies: vec!["swan".into()],
            profiles: vec!["calm".into()],
            policies: vec!["terra".into()],
            shard_counts: vec![1, 2],
            streams: 2,
            profile_samples: 20,
            warmup_s: 10.0,
            measure_s: 30.0,
            drain_s: 20.0,
            lambda0: 0.1,
            growth: 2.0,
            max_lambda: 0.8,
            bisect_iters: 2,
            // Generous limits: this test pins grid coverage, determinism
            // and the cross-shard guarantee, not the exact knee value.
            p99_slowdown_limit: 25.0,
            miss_limit: 0.5,
            ..SaturationSweepConfig::default()
        }
    }

    #[test]
    fn saturation_sweep_covers_grid_and_shards_sustain() {
        let cfg = tiny_saturation_cfg();
        let rows = saturation_sweep(&cfg);
        assert_eq!(rows.len(), 2, "1 topo x 1 profile x 1 policy x 2 shard counts");
        assert_eq!(rows[0].shards, 1);
        assert_eq!(rows[1].shards, 2);
        for r in &rows {
            assert!(r.knee_lambda > 0.0, "calm swan should sustain lambda0: {r:?}");
            assert!(r.knee_lambda <= cfg.max_lambda);
            assert!(r.evals >= 2, "{r:?}");
            assert!(r.offered > 0 && r.offered == r.admitted + r.rejected, "{r:?}");
            assert!(r.backlog_p99 > 0.0, "submissions sample a positive depth: {r:?}");
        }
        // Sharding never lowers the sustainable rate; with property-pinned
        // identical allocations the knees match exactly (the cell seed
        // excludes the shard count, so both face the same arrival stream).
        assert!(
            rows[1].knee_lambda >= rows[0].knee_lambda,
            "shards=2 knee {} < shards=1 knee {}",
            rows[1].knee_lambda,
            rows[0].knee_lambda
        );
    }

    #[test]
    fn saturation_sweep_is_bit_deterministic() {
        let cfg = tiny_saturation_cfg();
        let a = saturation_sweep(&cfg);
        let b = saturation_sweep(&cfg);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.knee_lambda.to_bits(), y.knee_lambda.to_bits());
            assert_eq!(x.avg_cct.to_bits(), y.avg_cct.to_bits());
            assert_eq!(x.p99_slowdown.to_bits(), y.p99_slowdown.to_bits());
            assert_eq!(x.backlog_p99.to_bits(), y.backlog_p99.to_bits());
            assert_eq!(x.est_mape.to_bits(), y.est_mape.to_bits());
            assert_eq!((x.offered, x.admitted, x.rejected), (y.offered, y.admitted, y.rejected));
            assert_eq!(x.evals, y.evals);
            assert_eq!(x.saturated, y.saturated);
        }
    }

    #[test]
    fn knee_finder_brackets_and_bisects() {
        // Synthetic SLO: sustainable iff lambda <= 1.0. The ramp doubles
        // past 1.0 at 1.6 (0.1 -> 0.2 -> 0.4 -> 0.8 -> 1.6 X), then two
        // geometric bisections tighten [0.8, 1.6).
        let cfg = SaturationSweepConfig {
            lambda0: 0.1,
            growth: 2.0,
            max_lambda: 6.4,
            bisect_iters: 2,
            ..SaturationSweepConfig::default()
        };
        let fake = |sustainable: bool| SatEval {
            sustainable,
            offered: 1,
            admitted: 1,
            rejected: 0,
            backlog_p99: 0.0,
            p99_slowdown: 1.0,
            miss_rate: 0.0,
            avg_cct: 1.0,
            deadline_met: 1.0,
            est_mape: 0.0,
            stale_reaction_s: 0.0,
            unfinished: 0,
        };
        let mut probes = Vec::new();
        let (knee, saturated, _, evals) = find_knee(
            |l| {
                probes.push(l);
                fake(l <= 1.0)
            },
            &cfg,
        );
        assert!(saturated);
        assert_eq!(evals, probes.len());
        assert_eq!(evals, 7, "ramp 0.1..1.6 is 5 evals + 2 bisections: {probes:?}");
        assert!(knee <= 1.0 && knee >= 0.8, "knee {knee} not in the final bracket");
        // Unsustainable from the start: knee is 0.
        let (knee0, sat0, _, e0) = find_knee(|_| fake(false), &cfg);
        assert_eq!((knee0, sat0, e0), (0.0, true, 1));
        // Never saturates below the cap: the cap is a lower bound.
        let (kneecap, satcap, _, _) = find_knee(|_| fake(true), &cfg);
        assert_eq!(kneecap, cfg.max_lambda);
        assert!(!satcap, "cap still sustainable must report saturated=false");
    }
}
