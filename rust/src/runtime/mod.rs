//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas LP solver.
//!
//! `make artifacts` lowers the L2 PDHG max-concurrent-flow solver
//! (`python/compile/model.py`) to HLO **text** per shape variant; this
//! module loads those artifacts on the PJRT CPU client once at startup and
//! executes them from the controller's scheduling rounds — Python is never
//! on the request path.
//!
//! The artifact solves the *edge-based* LP (flows may route anywhere); the
//! controller enforces per-path rates over the overlay, so
//! [`JaxSolver::solve`] peels the returned edge flows onto the coflow's
//! k-shortest-path set and re-trims to equal progress — the same
//! post-processing the native GK solver applies.

pub mod pack;

use crate::lp::{McfInstance, McfSolution};
use crate::net::Wan;
use crate::Result;
#[cfg(feature = "pjrt")]
use anyhow::{bail, Context};
#[cfg(not(feature = "pjrt"))]
use std::marker::PhantomData;
use std::path::Path;

/// Stub [`JaxSolver`] compiled when the `pjrt` feature (and therefore the
/// external `xla` crate + XLA shared libraries) is absent: loading reports
/// a clear error and callers fall back to the native solvers, keeping the
/// whole stack buildable in the offline image.
#[cfg(not(feature = "pjrt"))]
pub struct JaxSolver {
    /// PDHG iterations per solve (kept for API parity with the real
    /// solver).
    pub iters: i32,
    _no_backend: PhantomData<()>,
}

#[cfg(not(feature = "pjrt"))]
impl JaxSolver {
    /// Always fails: the crate was built without the `pjrt` feature.
    pub fn load(_dir: impl AsRef<Path>) -> Result<JaxSolver> {
        Err(anyhow::anyhow!(
            "terra was built without the `pjrt` feature; add the `xla` \
             crate to Cargo.toml [dependencies] and rebuild with \
             `--features pjrt` to load AOT LP artifacts"
        ))
    }

    /// No variants without a backend.
    pub fn variants(&self) -> Vec<(String, usize, usize, usize)> {
        Vec::new()
    }

    /// No backend: callers fall back to the native solver.
    pub fn solve(&self, _wan: &Wan, _inst: &McfInstance) -> Option<McfSolution> {
        None
    }
}

/// One loaded artifact variant (padded problem shape).
#[cfg(feature = "pjrt")]
struct Variant {
    name: String,
    v: usize,
    e: usize,
    k: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT-backed Optimization (1) solver.
#[cfg(feature = "pjrt")]
pub struct JaxSolver {
    variants: Vec<Variant>,
    /// PDHG iterations per solve (runtime input to the artifact).
    pub iters: i32,
}

// SAFETY: the wrapped PJRT CPU client and loaded executables are internally
// synchronized (PJRT's C API is thread-safe for execution); the `xla` crate
// just doesn't mark its raw-pointer wrappers. We only ever call `execute`
// and read-only accessors after construction.
#[cfg(feature = "pjrt")]
unsafe impl Send for JaxSolver {}
#[cfg(feature = "pjrt")]
unsafe impl Sync for JaxSolver {}

#[cfg(feature = "pjrt")]
impl JaxSolver {
    /// Load every variant listed in `artifacts/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<JaxSolver> {
        let dir = dir.as_ref();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?}; run `make artifacts`"))?;
        let manifest = crate::util::json::Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("bad manifest.json: {e}"))?;
        let client = xla::PjRtClient::cpu()?;
        let mut variants = Vec::new();
        if let crate::util::json::Json::Obj(map) = &manifest {
            for (name, spec) in map {
                let file = spec
                    .get("file")
                    .and_then(|f| f.as_str())
                    .context("manifest entry missing `file`")?;
                let v = spec.get("v").and_then(|x| x.as_u64()).context("missing v")? as usize;
                let e = spec.get("e").and_then(|x| x.as_u64()).context("missing e")? as usize;
                let k = spec.get("k").and_then(|x| x.as_u64()).context("missing k")? as usize;
                let proto = xla::HloModuleProto::from_text_file(
                    dir.join(file).to_str().context("non-utf8 path")?,
                )?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client.compile(&comp)?;
                variants.push(Variant { name: name.clone(), v, e, k, exe });
            }
        } else {
            bail!("manifest.json is not an object");
        }
        // Prefer smaller variants (cheaper executions) when they fit.
        variants.sort_by_key(|va| va.v * va.e * va.k);
        log::info!(
            "loaded {} LP artifact variant(s): {:?}",
            variants.len(),
            variants.iter().map(|v| v.name.as_str()).collect::<Vec<_>>()
        );
        Ok(JaxSolver { variants, iters: 600 })
    }

    /// Names and shapes `(name, V, E, K)` of the loaded variants.
    pub fn variants(&self) -> Vec<(String, usize, usize, usize)> {
        self.variants.iter().map(|v| (v.name.clone(), v.v, v.e, v.k)).collect()
    }

    /// Solve Optimization (1) for `inst` (FlowGroups with path sets over
    /// `wan`). Returns `None` when no variant fits or the solve degenerates
    /// (callers fall back to the native solver).
    pub fn solve(&self, wan: &Wan, inst: &McfInstance) -> Option<McfSolution> {
        let groups: Vec<(usize, usize, f64)> = pack::group_endpoints(wan, inst)?;
        let nv = wan.num_nodes();
        let ne = wan.num_edges();
        let nk = groups.len();
        let variant = self.variants.iter().find(|va| va.v >= nv && va.e >= ne && va.k >= nk)?;
        let (a, b, c) = pack::pack_instance(wan, inst, &groups, variant.v, variant.e, variant.k);

        let lit_a = xla::Literal::vec1(&a).reshape(&[variant.v as i64, variant.e as i64]).ok()?;
        let lit_b = xla::Literal::vec1(&b).reshape(&[variant.k as i64, variant.v as i64]).ok()?;
        let lit_c = xla::Literal::vec1(&c);
        let lit_iters = xla::Literal::scalar(self.iters);
        let (f_lit, _lam, _res) = self
            .exe_run(variant, &[lit_a, lit_b, lit_c, lit_iters])
            .map_err(|e| log::warn!("jax solve failed: {e}"))
            .ok()?;
        let f: Vec<f32> = f_lit.to_vec().ok()?;
        pack::peel_solution(inst, &groups, &f, variant.e)
    }

    fn exe_run(
        &self,
        variant: &Variant,
        args: &[xla::Literal],
    ) -> Result<(xla::Literal, xla::Literal, xla::Literal)> {
        let out = variant.exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
        Ok(out.to_tuple3()?)
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;
    use crate::coflow::GB;
    use crate::lp::{self, GroupDemand};
    use crate::net::paths::PathSet;
    use crate::net::topologies;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_and_solves_fig1a() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let solver = JaxSolver::load(artifacts_dir()).unwrap();
        assert!(!solver.variants().is_empty());
        let wan = topologies::fig1a();
        let paths = PathSet::compute(&wan, 3);
        let inst = McfInstance {
            cap: wan.capacities(),
            groups: vec![GroupDemand {
                volume: 5.0 * GB,
                paths: paths.get(0, 1).iter().map(|p| p.edges.clone()).collect(),
            }],
        };
        let sol = solver.solve(&wan, &inst).expect("jax solve");
        inst.check(&sol, 1e-3).unwrap();
        // 40 Gbit over two 10 Gbps paths: Γ = 2 s (λ = 0.5).
        let native = lp::max_concurrent(&inst, lp::SolverKind::Simplex).unwrap();
        assert!(
            (sol.lambda - native.lambda).abs() / native.lambda < 0.08,
            "jax λ {} vs native λ {}",
            sol.lambda,
            native.lambda
        );
    }

    #[test]
    fn agrees_with_native_on_swan_instances() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let solver = JaxSolver::load(artifacts_dir()).unwrap();
        let wan = topologies::swan();
        let paths = PathSet::compute(&wan, 15);
        let mut rng = crate::util::rng::Pcg32::new(31);
        for trial in 0..5 {
            let ng = 1 + rng.below(6);
            let mut groups = Vec::new();
            for _ in 0..ng {
                let s = rng.below(wan.num_nodes());
                let mut d = rng.below(wan.num_nodes());
                while d == s {
                    d = rng.below(wan.num_nodes());
                }
                groups.push(GroupDemand {
                    volume: rng.uniform(8.0, 200.0),
                    paths: paths.get(s, d).iter().map(|p| p.edges.clone()).collect(),
                });
            }
            let inst = McfInstance { cap: wan.capacities(), groups };
            let jax = solver.solve(&wan, &inst).expect("jax solve");
            inst.check(&jax, 1e-3).unwrap();
            let native = lp::max_concurrent(&inst, lp::SolverKind::Simplex).unwrap();
            // The edge-based artifact can route off the k-path set, and the
            // peeling is greedy — allow a modest band around the path LP.
            assert!(
                jax.lambda >= 0.7 * native.lambda && jax.lambda <= 1.05 * native.lambda,
                "trial {trial}: jax {} native {}",
                jax.lambda,
                native.lambda
            );
        }
    }
}
