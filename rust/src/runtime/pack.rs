//! Instance packing/unpacking for the AOT artifact: path-based FlowGroup
//! instances -> padded edge-based arrays -> peeled per-path rates.

use crate::lp::{McfInstance, McfSolution};
use crate::net::Wan;

/// Recover each group's `(src, dst, volume)` from its path set (all paths
/// of a FlowGroup share endpoints). Returns `None` if any active group has
/// no path (the artifact cannot express it; fall back to native).
pub fn group_endpoints(wan: &Wan, inst: &McfInstance) -> Option<Vec<(usize, usize, f64)>> {
    let mut out = Vec::with_capacity(inst.groups.len());
    for g in &inst.groups {
        let first = g.paths.iter().find(|p| !p.is_empty())?;
        let src = wan.link(first[0]).src;
        let dst = wan.link(*first.last().unwrap()).dst;
        out.push((src, dst, g.volume));
    }
    Some(out)
}

/// Build the padded `(a, b, c)` f32 arrays (row-major) for a variant of
/// shape `(pv, pe, pk)`.
pub fn pack_instance(
    wan: &Wan,
    inst: &McfInstance,
    groups: &[(usize, usize, f64)],
    pv: usize,
    pe: usize,
    pk: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let ne = wan.num_edges();
    let mut a = vec![0f32; pv * pe];
    for (e, link) in wan.links().iter().enumerate() {
        a[link.src * pe + e] = 1.0;
        a[link.dst * pe + e] = -1.0;
    }
    let mut b = vec![0f32; pk * pv];
    for (k, &(src, dst, vol)) in groups.iter().enumerate() {
        if vol > 0.0 {
            b[k * pv + src] = vol as f32;
            b[k * pv + dst] = -(vol as f32);
        }
    }
    let mut c = vec![0f32; pe];
    for (e, cap) in inst.cap.iter().enumerate().take(ne) {
        c[e] = *cap as f32;
    }
    (a, b, c)
}

/// Peel the artifact's per-edge flows onto each group's path set and trim
/// to an equal-progress [`McfSolution`]. Two greedy passes per group: the
/// first pass drains bottlenecks, the second picks up remainders.
pub fn peel_solution(
    inst: &McfInstance,
    groups: &[(usize, usize, f64)],
    f: &[f32],
    pe: usize,
) -> Option<McfSolution> {
    let mut rates: Vec<Vec<f64>> = inst.groups.iter().map(|g| vec![0.0; g.paths.len()]).collect();
    for (k, g) in inst.groups.iter().enumerate() {
        let mut w: Vec<f64> = (0..pe).map(|e| f[k * pe + e].max(0.0) as f64).collect();
        for _pass in 0..2 {
            for (pi, path) in g.paths.iter().enumerate() {
                if path.is_empty() {
                    continue;
                }
                let r = path.iter().map(|&e| w[e]).fold(f64::INFINITY, f64::min);
                if r > 1e-9 {
                    rates[k][pi] += r;
                    for &e in path {
                        w[e] -= r;
                    }
                }
            }
        }
    }
    // λ = worst group's progress; trim everyone to λ·v for equal progress.
    let mut lambda = f64::INFINITY;
    for (k, &(_, _, vol)) in groups.iter().enumerate() {
        if vol > 0.0 {
            let total: f64 = rates[k].iter().sum();
            lambda = lambda.min(total / vol);
        }
    }
    if !(lambda.is_finite() && lambda > 1e-12) {
        return None;
    }
    for (k, &(_, _, vol)) in groups.iter().enumerate() {
        let total: f64 = rates[k].iter().sum();
        let factor = if vol > 0.0 && total > 0.0 { lambda * vol / total } else { 0.0 };
        for r in &mut rates[k] {
            *r *= factor;
        }
    }
    Some(McfSolution { lambda, rates })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::GroupDemand;
    use crate::net::topologies;

    #[test]
    fn pack_shapes_and_padding() {
        let wan = topologies::fig1a(); // V=3, E=6
        let inst = McfInstance {
            cap: wan.capacities(),
            groups: vec![GroupDemand { volume: 40.0, paths: vec![vec![0]] }],
        };
        let groups = group_endpoints(&wan, &inst).unwrap();
        assert_eq!(groups, vec![(0, 1, 40.0)]);
        let (a, b, c) = pack_instance(&wan, &inst, &groups, 8, 16, 4);
        assert_eq!(a.len(), 8 * 16);
        assert_eq!(b.len(), 4 * 8);
        assert_eq!(c.len(), 16);
        // Incidence of edge 0 (A->B).
        assert_eq!(a[0 * 16 + 0], 1.0);
        assert_eq!(a[1 * 16 + 0], -1.0);
        // Padding columns are zero.
        assert!(c[6..].iter().all(|&x| x == 0.0));
        assert_eq!(b[0 * 8 + 0], 40.0);
        assert_eq!(b[0 * 8 + 1], -40.0);
        // Padded group rows zero.
        assert!(b[8..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn peel_extracts_multipath() {
        let wan = topologies::fig1a();
        // Group A->B with direct (edge 0) and via-C (edges 4, 3) paths.
        let inst = McfInstance {
            cap: wan.capacities(),
            groups: vec![GroupDemand { volume: 40.0, paths: vec![vec![0], vec![4, 3]] }],
        };
        let groups = vec![(0usize, 1usize, 40.0f64)];
        // Edge flows: 10 on direct, 10 on each leg of the via-C path.
        let pe = 8;
        let mut f = vec![0f32; pe];
        f[0] = 10.0;
        f[4] = 10.0;
        f[3] = 10.0;
        let sol = peel_solution(&inst, &groups, &f, pe).unwrap();
        assert!((sol.lambda - 0.5).abs() < 1e-9, "lambda={}", sol.lambda);
        assert!((sol.rates[0][0] - 10.0).abs() < 1e-9);
        assert!((sol.rates[0][1] - 10.0).abs() < 1e-9);
    }

    #[test]
    fn peel_handles_zero_flow() {
        let wan = topologies::fig1a();
        let inst = McfInstance {
            cap: wan.capacities(),
            groups: vec![GroupDemand { volume: 40.0, paths: vec![vec![0]] }],
        };
        let groups = vec![(0usize, 1usize, 40.0f64)];
        assert!(peel_solution(&inst, &groups, &[0f32; 8], 8).is_none());
    }
}
