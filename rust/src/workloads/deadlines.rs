//! Deadline assignment for the Fig 8 experiments: each coflow's deadline is
//! set to `d ×` its minimum CCT in an empty WAN (§6.4), computed with the
//! same Optimization (1) solver the controller uses.

use crate::lp;
use crate::net::paths::PathSet;
use crate::net::Wan;
use crate::scheduler::{build_instance, CoflowState, NetView, DEFAULT_K};
use crate::sim::Job;

/// Set `stage.deadline = d * min_cct(stage coflow)` for every WAN stage of
/// every job. Stages without WAN flows keep no deadline.
pub fn assign_deadlines(jobs: &mut [Job], wan: &Wan, d: f64) {
    let paths = PathSet::compute(wan, DEFAULT_K);
    let net = NetView { wan, paths: &paths };
    let caps = wan.capacities();
    for job in jobs.iter_mut() {
        for stage in job.stages.iter_mut() {
            let coflow = crate::coflow::Coflow::new(0, stage.flows.clone());
            let st = CoflowState::from_coflow(&coflow);
            if st.groups.is_empty() {
                continue;
            }
            let (inst, _) = build_instance(&st.groups, &st.remaining, &caps, &net, DEFAULT_K);
            if let Some(sol) = lp::max_concurrent(&inst, lp::SolverKind::Gk) {
                stage.deadline = Some(d * sol.gamma());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coflow::GB;
    use crate::net::topologies;

    #[test]
    fn deadlines_scale_with_d() {
        let wan = topologies::fig1a();
        let mk = || {
            vec![Job::map_reduce(
                1,
                0.0,
                0.0,
                vec![crate::coflow::Flow { id: 0, src_dc: 0, dst_dc: 1, volume: 5.0 * GB }],
            )]
        };
        let mut j2 = mk();
        assign_deadlines(&mut j2, &wan, 2.0);
        let mut j4 = mk();
        assign_deadlines(&mut j4, &wan, 4.0);
        let d2 = j2[0].stages[0].deadline.unwrap();
        let d4 = j4[0].stages[0].deadline.unwrap();
        // min CCT = 2 s on fig1a (two 10 Gbps paths for 40 Gbit); the GK
        // solver is an ε-approximation, so allow its tolerance band.
        assert!((d2 - 4.0).abs() < 0.3, "d2={d2}");
        assert!((d4 / d2 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn stages_without_wan_flows_skipped() {
        let wan = topologies::fig1a();
        let mut jobs = vec![Job::map_reduce(1, 0.0, 5.0, vec![])];
        assign_deadlines(&mut jobs, &wan, 3.0);
        assert!(jobs[0].stages[0].deadline.is_none());
    }

    /// Every WAN stage of a generated workload gets a finite, strictly
    /// positive deadline (the values `Coflow::with_deadline` accepts), and
    /// assignment is deterministic for a fixed workload.
    #[test]
    fn generated_workload_deadlines_valid_and_deterministic() {
        let wan = topologies::swan();
        let mk = || crate::workloads::WorkloadGen::new(crate::workloads::WorkloadKind::TpcDs, 11)
            .jobs(&wan, 10);
        let mut a = mk();
        assign_deadlines(&mut a, &wan, 2.5);
        let mut b = mk();
        assign_deadlines(&mut b, &wan, 2.5);
        let mut assigned = 0;
        for (ja, jb) in a.iter().zip(&b) {
            for (sa, sb) in ja.stages.iter().zip(&jb.stages) {
                let wan_flows = sa.flows.iter().any(|f| f.src_dc != f.dst_dc);
                match sa.deadline {
                    Some(d) => {
                        assert!(wan_flows, "deadline on a WAN-free stage");
                        assert!(d.is_finite() && d > 0.0, "invalid deadline {d}");
                        assert_eq!(Some(d).map(f64::to_bits), sb.deadline.map(f64::to_bits));
                        assigned += 1;
                    }
                    None => assert_eq!(sb.deadline, None),
                }
            }
        }
        assert!(assigned > 0, "no deadlines assigned at all");
    }

    /// Doubling `d` doubles every assigned deadline across a whole
    /// multi-stage workload, not just a single synthetic job.
    #[test]
    fn scale_factor_is_linear_across_workload() {
        let wan = topologies::swan();
        let mk = || crate::workloads::WorkloadGen::new(crate::workloads::WorkloadKind::TpcH, 3)
            .jobs(&wan, 6);
        let mut j1 = mk();
        assign_deadlines(&mut j1, &wan, 1.5);
        let mut j3 = mk();
        assign_deadlines(&mut j3, &wan, 3.0);
        for (a, b) in j1.iter().zip(&j3) {
            for (sa, sb) in a.stages.iter().zip(&b.stages) {
                if let (Some(da), Some(db)) = (sa.deadline, sb.deadline) {
                    assert!((db / da - 2.0).abs() < 1e-9, "da={da} db={db}");
                }
            }
        }
    }
}
