//! Facebook-trace workload: 526 simple MapReduce jobs with the published
//! coflow benchmark's heavy skew — "most jobs have little to no traffic,
//! while a few have most of the tasks and account for almost all the
//! volume" (§6.2.1). We reproduce the skew with a three-band mixture whose
//! tail is bounded-Pareto, consistent with the SWIM/coflow-benchmark
//! statistics (>50% of coflows under 10 MB; the top few percent carrying
//! ~99% of bytes).

use super::WorkloadConfig;
use crate::coflow::MB;
use crate::net::Wan;
use crate::sim::Job;
use crate::util::rng::Pcg32;
use crate::workloads::dag::{shuffle_flows, table_placement};

/// Number of jobs in the paper's FB workload.
pub const FB_NUM_JOBS: usize = 526;

/// Draw a coflow volume (Gbit) with the FB trace's skew.
pub fn fb_volume(rng: &mut Pcg32) -> f64 {
    let r = rng.f64();
    let mb = if r < 0.52 {
        // Short control/metadata shuffles.
        rng.uniform(0.5, 10.0)
    } else if r < 0.90 {
        // Mid-size shuffles.
        rng.uniform(10.0, 1_000.0)
    } else {
        // Heavy tail: up to ~2 TB, Pareto-shaped.
        rng.pareto(1_000.0, 2_000_000.0, 0.65)
    };
    mb * MB
}

/// Number of mapper/reducer tasks correlates with volume in the trace.
fn width_for(volume_gbit: f64, machines_per_dc: usize, rng: &mut Pcg32) -> usize {
    let base = (volume_gbit / 4.0).sqrt().ceil() as usize;
    (base + rng.below(3)).clamp(1, machines_per_dc.max(1))
}

/// One FB MapReduce job: a single shuffle stage, negligible compute.
pub fn fb_job(id: u64, arrival: f64, wan: &Wan, cfg: &WorkloadConfig, rng: &mut Pcg32) -> Job {
    let volume = fb_volume(rng) * cfg.volume_scale;
    let src_dcs = table_placement(wan, rng);
    let dst_span = 1 + rng.below((wan.num_nodes() / 2).max(1));
    let dst_dcs = rng.sample_indices(wan.num_nodes(), dst_span);
    let per_dc_tasks = width_for(volume, cfg.machines_per_dc, rng);
    let flows = shuffle_flows(&src_dcs, &dst_dcs, per_dc_tasks, per_dc_tasks.min(4), volume, rng);
    // FB jobs in the trace are communication-dominated; tiny map time.
    let compute_s = rng.uniform(0.5, 3.0);
    Job::map_reduce(id, arrival, compute_s, flows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::topologies;
    use crate::util::stats;

    #[test]
    fn volume_distribution_is_skewed() {
        let mut rng = Pcg32::new(99);
        let vols: Vec<f64> = (0..5_000).map(|_| fb_volume(&mut rng)).collect();
        let mean = stats::mean(&vols);
        let med = stats::median(&vols);
        // Heavy tail: mean far above median.
        assert!(mean > 8.0 * med, "mean={mean} median={med}");
        // Top 10% should carry the overwhelming share of bytes.
        let mut sorted = vols.clone();
        sorted.sort_by(f64::total_cmp);
        let total: f64 = sorted.iter().sum();
        let top10: f64 = sorted[sorted.len() * 9 / 10..].iter().sum();
        assert!(top10 / total > 0.85, "top10 share = {}", top10 / total);
    }

    #[test]
    fn fb_jobs_single_stage() {
        let wan = topologies::swan();
        let cfg = WorkloadConfig::new(super::super::WorkloadKind::Fb, 3);
        let mut rng = Pcg32::new(5);
        for i in 0..50 {
            let j = fb_job(i, 1.0, &wan, &cfg, &mut rng);
            assert_eq!(j.stages.len(), 1);
            j.validate().unwrap();
        }
    }
}
