//! Open-loop load generation for the saturation harness.
//!
//! Every sweep before this module replayed *fixed job sets*; the
//! production question — how many coflows per second can a
//! ⟨policy, topology, dynamics, shards, estimator⟩ cell sustain — needs
//! open-loop arrivals whose statistics stay faithful to the traces. Three
//! pieces:
//!
//! - [`RvHisto`]: a histogram-valued random variate sampled in O(1) with
//!   the Vose/Walker weighted-alias method. Histograms are *derived* from
//!   the existing `workloads/{fb,tpcds,…}` generators ([`WorkloadProfile`]
//!   measures per-coflow size, WAN width, source/destination skew, and
//!   service-class mix over a sample job set), so open-loop traffic is
//!   distributionally faithful to the fixed evaluation workloads.
//! - [`Interarrival`]: seeded interarrival processes (Poisson, Pareto,
//!   log-normal) with rate rescaling that preserves the shape while the
//!   load ramp sets the aggregate arrival rate λ.
//! - [`OpenLoopGen`]: merges `streams` independent Pcg32-forked arrival
//!   streams (the `net/dynamics` idiom) into one deterministic job
//!   sequence over `[0, horizon_s)`. The output is a pure function of the
//!   profile and [`OpenLoopConfig`] — notably independent of shard count
//!   and of anything the simulator later does with the jobs, which is what
//!   makes the "same seed ⇒ byte-identical arrival stream across shard
//!   counts" property hold by construction.

use crate::coflow::Flow;
use crate::net::Wan;
use crate::sim::Job;
use crate::util::rng::Pcg32;

use super::{WorkloadConfig, WorkloadGen, WorkloadKind};

/// One histogram bin: values are drawn uniformly from `[lo, hi)` (or
/// exactly `lo` when `lo == hi`) with probability proportional to
/// `weight`.
#[derive(Clone, Debug, PartialEq)]
pub struct HistoBin {
    pub lo: f64,
    pub hi: f64,
    pub weight: f64,
}

impl HistoBin {
    pub fn new(lo: f64, hi: f64, weight: f64) -> HistoBin {
        HistoBin { lo, hi, weight }
    }
}

/// A histogram-valued random variate with O(1) weighted-alias sampling
/// (Vose 1991). Construction validates the histogram and precomputes the
/// alias table; sampling costs one `below` + one or two `f64` draws.
#[derive(Clone, Debug)]
pub struct RvHisto {
    bins: Vec<HistoBin>,
    /// Vose alias table: `prob[i]` is the probability of keeping column
    /// `i`; otherwise the draw is redirected to `alias[i]`.
    prob: Vec<f64>,
    alias: Vec<usize>,
}

impl RvHisto {
    /// Build the alias table. Rejects histograms the sampler cannot give a
    /// meaning to: empty or degenerate one-bin lists, non-finite bounds or
    /// weights, negative weights, inverted bins, and all-zero weight.
    pub fn new(bins: Vec<HistoBin>) -> Result<RvHisto, String> {
        if bins.is_empty() {
            return Err("empty histogram".into());
        }
        if bins.len() < 2 {
            return Err("degenerate one-bin histogram (a constant, not a distribution)".into());
        }
        for (i, b) in bins.iter().enumerate() {
            if !b.lo.is_finite() || !b.hi.is_finite() || !b.weight.is_finite() {
                return Err(format!("bin {i} has non-finite fields: {b:?}"));
            }
            if b.weight < 0.0 {
                return Err(format!("bin {i} has negative weight {}", b.weight));
            }
            if b.lo > b.hi {
                return Err(format!("bin {i} is inverted: [{}, {})", b.lo, b.hi));
            }
        }
        let total: f64 = bins.iter().map(|b| b.weight).sum();
        if total <= 0.0 {
            return Err("histogram has zero total weight".into());
        }
        let n = bins.len();
        let mut prob: Vec<f64> = bins.iter().map(|b| b.weight * n as f64 / total).collect();
        let mut alias: Vec<usize> = (0..n).collect();
        let mut small: Vec<usize> = Vec::new();
        let mut large: Vec<usize> = Vec::new();
        for (i, &p) in prob.iter().enumerate() {
            if p < 1.0 {
                small.push(i);
            } else {
                large.push(i);
            }
        }
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            large.pop();
            alias[s] = l;
            prob[l] = (prob[l] + prob[s]) - 1.0;
            if prob[l] < 1.0 {
                small.push(l);
            } else {
                large.push(l);
            }
        }
        // Numerical leftovers on either worklist have probability 1.
        for &i in small.iter().chain(large.iter()) {
            prob[i] = 1.0;
        }
        Ok(RvHisto { bins, prob, alias })
    }

    /// Log-spaced histogram fitted to positive samples (`nbins >= 2`).
    /// Used for heavy-tailed coflow volumes, where linear bins would put
    /// everything in the first bucket. When all samples are equal the
    /// histogram still carries `nbins` bins with the mass concentrated in
    /// the sample's bucket (never a rejected one-bin degenerate).
    pub fn log_bins(samples: &[f64], nbins: usize) -> Result<RvHisto, String> {
        let nbins = nbins.max(2);
        let pos: Vec<f64> = samples.iter().copied().filter(|&v| v > 0.0 && v.is_finite()).collect();
        if pos.is_empty() {
            return Err("no positive samples to fit".into());
        }
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for &v in &pos {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if hi <= lo {
            // Constant sample set: widen around the value; only the bin
            // containing it carries weight.
            lo *= 0.5;
            hi = lo * 3.0;
        }
        let (llo, lhi) = (lo.ln(), hi.ln());
        let step = (lhi - llo) / nbins as f64;
        let mut weights = vec![0.0f64; nbins];
        for &v in &pos {
            let idx = (((v.ln() - llo) / step) as usize).min(nbins - 1);
            weights[idx] += 1.0;
        }
        let bins = (0..nbins)
            .map(|i| {
                let blo = (llo + i as f64 * step).exp();
                let bhi = (llo + (i + 1) as f64 * step).exp();
                HistoBin::new(blo, bhi, weights[i])
            })
            .collect();
        RvHisto::new(bins)
    }

    /// Unit-width histogram over indices `0..weights.len()`: bin `i` is
    /// `[i, i+1)` with the given weight. Used for discrete draws — WAN
    /// widths, datacenter skew, service-class slots. A single-element
    /// weight vector is padded with a zero-weight sibling so a constant
    /// still round-trips through the (≥ 2 bins) validator.
    pub fn indexed(weights: &[f64]) -> Result<RvHisto, String> {
        if weights.is_empty() {
            return Err("no index weights".into());
        }
        let mut bins: Vec<HistoBin> = weights
            .iter()
            .enumerate()
            .map(|(i, &w)| HistoBin::new(i as f64, (i + 1) as f64, w))
            .collect();
        if bins.len() < 2 {
            bins.push(HistoBin::new(1.0, 2.0, 0.0));
        }
        RvHisto::new(bins)
    }

    pub fn len(&self) -> usize {
        self.bins.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bins.is_empty()
    }

    pub fn bins(&self) -> &[HistoBin] {
        &self.bins
    }

    /// Probability mass of bin `i` (normalized weights).
    pub fn mass(&self, i: usize) -> f64 {
        let total: f64 = self.bins.iter().map(|b| b.weight).sum();
        self.bins[i].weight / total
    }

    /// Expected value under uniform-within-bin sampling.
    pub fn mean(&self) -> f64 {
        let total: f64 = self.bins.iter().map(|b| b.weight).sum();
        self.bins.iter().map(|b| 0.5 * (b.lo + b.hi) * b.weight).sum::<f64>() / total
    }

    /// Draw a bin index with probability proportional to its weight.
    pub fn sample_index(&self, rng: &mut Pcg32) -> usize {
        let col = rng.below(self.prob.len());
        if rng.f64() < self.prob[col] {
            col
        } else {
            self.alias[col]
        }
    }

    /// Draw a value: alias-pick a bin, then uniform within it.
    pub fn sample(&self, rng: &mut Pcg32) -> f64 {
        let b = &self.bins[self.sample_index(rng)];
        if b.hi > b.lo {
            b.lo + (b.hi - b.lo) * rng.f64()
        } else {
            b.lo
        }
    }
}

/// Service-class slots of [`WorkloadProfile::class_mix`], in index order.
pub const CLASS_SLOTS: [&str; 4] = ["batch", "deadline", "stream", "ml-sync"];

/// Empirical distributions of one evaluation workload, measured over a
/// sample job set from the fixed generators. Open-loop jobs are sampled
/// from these histograms instead of replaying the trace.
#[derive(Clone, Debug)]
pub struct WorkloadProfile {
    /// Source workload name (`fb`, `bigbench`, …).
    pub workload: String,
    /// Datacenter count of the WAN the profile was measured on (the skew
    /// histograms are indexed by DC).
    pub num_dcs: usize,
    /// Per-coflow total WAN volume (Gbit), log-spaced bins.
    pub volume: RvHisto,
    /// WAN flows per coflow, unit bins over the width value.
    pub width: RvHisto,
    /// Byte-weighted source / destination datacenter popularity, unit bins
    /// over DC index.
    pub src_skew: RvHisto,
    pub dst_skew: RvHisto,
    /// Coflow count per service-class slot ([`CLASS_SLOTS`]); a stage with
    /// a deadline counts as the "deadline" slot regardless of class.
    pub class_mix: RvHisto,
}

impl WorkloadProfile {
    /// Measure a profile by generating `sample_jobs` jobs from the fixed
    /// generator for `kind` (deterministic in `seed`).
    pub fn from_kind(
        kind: WorkloadKind,
        wan: &Wan,
        seed: u64,
        sample_jobs: usize,
    ) -> WorkloadProfile {
        let cfg = WorkloadConfig::new(kind, seed);
        let jobs = WorkloadGen::with_config(cfg).jobs(wan, sample_jobs.max(1));
        WorkloadProfile::from_jobs(kind.name(), &jobs, wan.num_nodes())
            .expect("fixed workload sample produced no WAN coflows")
    }

    /// Measure a profile over an explicit job set (one histogram entry per
    /// WAN coflow, i.e. per stage with at least one inter-DC flow).
    pub fn from_jobs(
        workload: &str,
        jobs: &[Job],
        num_dcs: usize,
    ) -> Result<WorkloadProfile, String> {
        let mut volumes: Vec<f64> = Vec::new();
        let mut max_width = 0usize;
        let mut widths: Vec<usize> = Vec::new();
        let mut src_w = vec![0.0f64; num_dcs];
        let mut dst_w = vec![0.0f64; num_dcs];
        let mut class_w = vec![0.0f64; CLASS_SLOTS.len()];
        for job in jobs {
            for st in &job.stages {
                let wan_flows: Vec<&Flow> =
                    st.flows.iter().filter(|f| f.src_dc != f.dst_dc).collect();
                if wan_flows.is_empty() {
                    continue;
                }
                volumes.push(wan_flows.iter().map(|f| f.volume).sum());
                widths.push(wan_flows.len());
                max_width = max_width.max(wan_flows.len());
                for f in &wan_flows {
                    src_w[f.src_dc] += f.volume;
                    dst_w[f.dst_dc] += f.volume;
                }
                let slot = if st.deadline.is_some() {
                    1
                } else {
                    match st.class.name() {
                        "deadline" => 1,
                        "stream" => 2,
                        "ml-sync" => 3,
                        _ => 0,
                    }
                };
                class_w[slot] += 1.0;
            }
        }
        if volumes.is_empty() {
            return Err(format!("job set for {workload} has no WAN coflows"));
        }
        let mut width_w = vec![0.0f64; max_width + 1];
        for &w in &widths {
            width_w[w] += 1.0;
        }
        Ok(WorkloadProfile {
            workload: workload.to_string(),
            num_dcs,
            volume: RvHisto::log_bins(&volumes, 16)?,
            width: RvHisto::indexed(&width_w)?,
            src_skew: RvHisto::indexed(&src_w)?,
            dst_skew: RvHisto::indexed(&dst_w)?,
            class_mix: RvHisto::indexed(&class_w)?,
        })
    }
}

/// Seeded interarrival process. All variants expose their mean so the load
/// ramp can rescale any shape to a target rate with [`Interarrival::with_rate`].
#[derive(Clone, Copy, Debug)]
pub enum Interarrival {
    /// Exponential gaps — a Poisson arrival process at `lambda`/s.
    Poisson { lambda: f64 },
    /// Heavy-tailed gaps: `scale · U^{-1/alpha}` (minimum `scale`; the
    /// mean is finite only for `alpha > 1`, which `with_rate` requires).
    Pareto { alpha: f64, scale: f64 },
    /// Log-normal gaps with underlying normal `(mu, sigma)`.
    LogNormal { mu: f64, sigma: f64 },
}

impl Interarrival {
    /// Canonical shape for a CLI name, rescaled to `rate` arrivals/s.
    pub fn by_name(name: &str, rate: f64) -> Option<Interarrival> {
        let shape = match name.to_ascii_lowercase().as_str() {
            "poisson" | "exp" => Interarrival::Poisson { lambda: 1.0 },
            "pareto" | "heavy" => Interarrival::Pareto { alpha: 1.5, scale: 1.0 },
            "lognormal" | "log-normal" => Interarrival::LogNormal { mu: 0.0, sigma: 1.0 },
            _ => return None,
        };
        Some(shape.with_rate(rate))
    }

    /// Mean gap in seconds.
    pub fn mean(&self) -> f64 {
        match *self {
            Interarrival::Poisson { lambda } => 1.0 / lambda,
            Interarrival::Pareto { alpha, scale } => scale * alpha / (alpha - 1.0),
            Interarrival::LogNormal { mu, sigma } => (mu + 0.5 * sigma * sigma).exp(),
        }
    }

    /// Arrival rate in events/s.
    pub fn rate(&self) -> f64 {
        1.0 / self.mean()
    }

    /// Same shape, rescaled so the mean gap is `1/rate`. Poisson adjusts
    /// `lambda`, Pareto its `scale` (tail index preserved), log-normal its
    /// `mu` (log-space spread preserved).
    pub fn with_rate(self, rate: f64) -> Interarrival {
        assert!(rate > 0.0 && rate.is_finite(), "arrival rate must be positive");
        let mean = 1.0 / rate;
        match self {
            Interarrival::Poisson { .. } => Interarrival::Poisson { lambda: rate },
            Interarrival::Pareto { alpha, .. } => {
                assert!(alpha > 1.0, "Pareto interarrivals need alpha > 1 for a finite mean");
                Interarrival::Pareto { alpha, scale: mean * (alpha - 1.0) / alpha }
            }
            Interarrival::LogNormal { sigma, .. } => {
                Interarrival::LogNormal { mu: mean.ln() - 0.5 * sigma * sigma, sigma }
            }
        }
    }

    /// Draw one gap (seconds, strictly positive).
    pub fn sample(&self, rng: &mut Pcg32) -> f64 {
        match *self {
            Interarrival::Poisson { lambda } => rng.exp(1.0 / lambda),
            Interarrival::Pareto { alpha, scale } => {
                let u = 1.0 - rng.f64(); // (0, 1]: avoid the infinite tail point
                scale * u.powf(-1.0 / alpha)
            }
            Interarrival::LogNormal { mu, sigma } => rng.lognormal(mu, sigma),
        }
    }
}

/// Open-loop generator knobs.
#[derive(Clone, Debug)]
pub struct OpenLoopConfig {
    pub seed: u64,
    /// Aggregate arrival rate λ (coflows/s) across all streams. `<= 0`
    /// disables the generator entirely: no jobs, no RNG draws — the
    /// open-loop inertness guarantee for fixed-job-set paths.
    pub lambda: f64,
    /// Interarrival shape name ([`Interarrival::by_name`]).
    pub interarrival: String,
    /// Independent arrival streams, each at λ/streams (Pcg32-forked per
    /// stream like `net/dynamics`).
    pub streams: usize,
    /// Arrivals are generated in `[0, horizon_s)`.
    pub horizon_s: f64,
    /// First job id (keeps open-loop ids disjoint from fixed job sets when
    /// the two are mixed in one simulation).
    pub base_id: u64,
}

impl Default for OpenLoopConfig {
    fn default() -> Self {
        OpenLoopConfig {
            seed: 7,
            lambda: 1.0,
            interarrival: "poisson".into(),
            streams: 4,
            horizon_s: 300.0,
            base_id: 1_000_000,
        }
    }
}

/// The open-loop job generator: per-stream interarrival processes merged
/// into one arrival-ordered sequence of single-stage coflow jobs sampled
/// from a [`WorkloadProfile`].
pub struct OpenLoopGen {
    profile: WorkloadProfile,
    cfg: OpenLoopConfig,
}

impl OpenLoopGen {
    pub fn new(profile: WorkloadProfile, cfg: OpenLoopConfig) -> OpenLoopGen {
        OpenLoopGen { profile, cfg }
    }

    pub fn profile(&self) -> &WorkloadProfile {
        &self.profile
    }

    /// Generate the arrival stream. Deterministic in `(profile, cfg)`;
    /// `lambda <= 0` or a zero horizon yields the empty stream without
    /// touching any RNG.
    pub fn jobs(&self) -> Vec<Job> {
        if self.cfg.lambda <= 0.0 || self.cfg.horizon_s <= 0.0 {
            return Vec::new();
        }
        let streams = self.cfg.streams.max(1);
        let per_stream_rate = self.cfg.lambda / streams as f64;
        let Some(gap) = Interarrival::by_name(&self.cfg.interarrival, per_stream_rate) else {
            log::warn!("unknown interarrival shape {}; empty stream", self.cfg.interarrival);
            return Vec::new();
        };
        let mut root = Pcg32::new(self.cfg.seed ^ 0x0BE4_10AD);
        // (arrival, stream, per-job rng) tuples, then a stable merge by
        // (time, stream) — ties across streams resolve deterministically.
        let mut arrivals: Vec<(f64, usize, Pcg32)> = Vec::new();
        for s in 0..streams {
            let mut srng = root.fork(s as u64);
            let mut t = 0.0;
            let mut k = 0u64;
            loop {
                t += gap.sample(&mut srng);
                if !(t < self.cfg.horizon_s) {
                    break;
                }
                let jrng = srng.fork(k);
                arrivals.push((t, s, jrng));
                k += 1;
            }
        }
        arrivals.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        arrivals
            .into_iter()
            .enumerate()
            .map(|(i, (t, _s, mut jrng))| {
                self.sample_job(self.cfg.base_id + i as u64, t, &mut jrng)
            })
            .collect()
    }

    /// Sample one single-stage coflow job from the profile histograms.
    fn sample_job(&self, id: u64, arrival: f64, rng: &mut Pcg32) -> Job {
        let total = self.profile.volume.sample(rng).max(1e-3);
        let width = (self.profile.width.sample(rng).floor() as usize).max(1);
        // Exponential proportions split the total over the flows (skewed,
        // like real shuffles, but always strictly positive).
        let props: Vec<f64> = (0..width).map(|_| rng.exp(1.0).max(1e-9)).collect();
        let psum: f64 = props.iter().sum();
        let num_dcs = self.profile.num_dcs;
        let flows: Vec<Flow> = props
            .iter()
            .enumerate()
            .map(|(fi, &p)| {
                let src = self.profile.src_skew.sample_index(rng).min(num_dcs - 1);
                let mut dst = self.profile.dst_skew.sample_index(rng).min(num_dcs - 1);
                // Bounded resample keeps the flow inter-DC without an
                // unbounded loop on pathological skew.
                for _ in 0..4 {
                    if dst != src {
                        break;
                    }
                    dst = self.profile.dst_skew.sample_index(rng).min(num_dcs - 1);
                }
                if dst == src {
                    dst = (src + 1) % num_dcs;
                }
                Flow { id: fi as u64, src_dc: src, dst_dc: dst, volume: total * p / psum }
            })
            .collect();
        // Non-batch class slots are *measured* in the profile but emitted
        // as batch: the fixed evaluation traces the profiles derive from
        // are batch-only, so the mix draw is exercised (keeping the stream
        // deterministic in its presence) while floors/trees stay the
        // multitenant sweep's concern. See DESIGN.md "known limitations".
        let _class_slot = self.profile.class_mix.sample_index(rng);
        Job::map_reduce(id, arrival, 0.0, flows)
    }
}

/// Canonical byte encoding of a job stream — little-endian bit patterns of
/// every id, arrival, and flow tuple. Two streams are the same workload
/// if and only if their fingerprints are equal byte-for-byte; the
/// open-loop property tests pin cross-run and cross-shard identity on it.
pub fn stream_fingerprint(jobs: &[Job]) -> Vec<u8> {
    let mut out = Vec::new();
    for j in jobs {
        out.extend_from_slice(&j.id.to_le_bytes());
        out.extend_from_slice(&j.arrival.to_bits().to_le_bytes());
        for st in &j.stages {
            out.extend_from_slice(&st.compute_s.to_bits().to_le_bytes());
            for f in &st.flows {
                out.extend_from_slice(&f.id.to_le_bytes());
                out.extend_from_slice(&(f.src_dc as u64).to_le_bytes());
                out.extend_from_slice(&(f.dst_dc as u64).to_le_bytes());
                out.extend_from_slice(&f.volume.to_bits().to_le_bytes());
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::topologies;

    fn two_bins() -> Vec<HistoBin> {
        vec![HistoBin::new(0.0, 1.0, 1.0), HistoBin::new(1.0, 2.0, 3.0)]
    }

    #[test]
    fn alias_rejects_invalid_histograms() {
        assert!(RvHisto::new(vec![]).is_err(), "empty");
        assert!(RvHisto::new(vec![HistoBin::new(0.0, 1.0, 1.0)]).is_err(), "one-bin degenerate");
        assert!(
            RvHisto::new(vec![HistoBin::new(0.0, 1.0, 0.0), HistoBin::new(1.0, 2.0, 0.0)]).is_err(),
            "zero total weight"
        );
        assert!(
            RvHisto::new(vec![HistoBin::new(0.0, 1.0, -1.0), HistoBin::new(1.0, 2.0, 2.0)])
                .is_err(),
            "negative weight"
        );
        assert!(
            RvHisto::new(vec![HistoBin::new(0.0, 1.0, f64::NAN), HistoBin::new(1.0, 2.0, 1.0)])
                .is_err(),
            "NaN weight"
        );
        assert!(
            RvHisto::new(vec![HistoBin::new(2.0, 1.0, 1.0), HistoBin::new(1.0, 2.0, 1.0)]).is_err(),
            "inverted bin"
        );
        assert!(RvHisto::new(two_bins()).is_ok());
    }

    #[test]
    fn alias_samples_inside_bins_and_respects_weights() {
        let h = RvHisto::new(two_bins()).unwrap();
        let mut rng = Pcg32::new(11);
        let mut hits = [0usize; 2];
        for _ in 0..20_000 {
            let idx = h.sample_index(&mut rng);
            hits[idx] += 1;
            let v = h.sample(&mut rng);
            assert!((0.0..2.0).contains(&v));
        }
        let f1 = hits[1] as f64 / 20_000.0;
        assert!((f1 - 0.75).abs() < 0.02, "bin-1 frequency {f1} vs weight 0.75");
    }

    #[test]
    fn indexed_pads_constants_instead_of_rejecting() {
        let h = RvHisto::indexed(&[5.0]).unwrap();
        assert_eq!(h.len(), 2);
        let mut rng = Pcg32::new(3);
        for _ in 0..100 {
            assert_eq!(h.sample_index(&mut rng), 0, "all mass on the only real bin");
        }
    }

    #[test]
    fn interarrival_rescaling_hits_the_target_rate() {
        let mut rng = Pcg32::new(21);
        for name in ["poisson", "pareto", "lognormal"] {
            let ia = Interarrival::by_name(name, 2.0).unwrap();
            assert!((ia.rate() - 2.0).abs() < 1e-12, "{name} analytic rate");
            let n = 60_000;
            let sum: f64 = (0..n).map(|_| ia.sample(&mut rng)).sum();
            let emp = sum / n as f64;
            // The α=1.5 Pareto mean converges at n^(1/3): loose tolerance
            // there, tight elsewhere — both catch a wrong rescaling
            // (which would be off by 2x).
            let tol = if name == "pareto" { 0.2 } else { 0.05 };
            assert!((emp - 0.5).abs() < tol, "{name}: empirical mean {emp} vs 0.5");
        }
        assert!(Interarrival::by_name("bogus", 1.0).is_none());
    }

    #[test]
    fn profile_measures_the_fixed_workload() {
        let wan = topologies::swan();
        let p = WorkloadProfile::from_kind(WorkloadKind::Fb, &wan, 42, 40);
        assert_eq!(p.num_dcs, wan.num_nodes());
        assert_eq!(p.src_skew.len(), wan.num_nodes());
        assert!(p.volume.mean() > 0.0);
        // FB is batch-only: all class mass on slot 0.
        assert!((p.class_mix.mass(0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn generator_is_deterministic_and_disabled_means_empty() {
        let wan = topologies::swan();
        let profile = WorkloadProfile::from_kind(WorkloadKind::Fb, &wan, 42, 30);
        let cfg = OpenLoopConfig { lambda: 0.8, horizon_s: 120.0, ..Default::default() };
        let a = OpenLoopGen::new(profile.clone(), cfg.clone()).jobs();
        let b = OpenLoopGen::new(profile.clone(), cfg.clone()).jobs();
        assert!(!a.is_empty());
        assert_eq!(stream_fingerprint(&a), stream_fingerprint(&b));
        let mut last = 0.0;
        for j in &a {
            j.validate().unwrap();
            assert!(j.arrival >= last && j.arrival < cfg.horizon_s);
            last = j.arrival;
            assert_eq!(j.stages.len(), 1, "open-loop jobs are single-stage");
            assert!(j.total_volume() > 0.0);
        }
        let off = OpenLoopConfig { lambda: 0.0, ..cfg };
        assert!(OpenLoopGen::new(profile, off).jobs().is_empty());
    }

    #[test]
    fn stream_count_changes_the_interleave_not_the_rate() {
        let wan = topologies::swan();
        let profile = WorkloadProfile::from_kind(WorkloadKind::Fb, &wan, 42, 30);
        let mk = |streams| {
            let cfg = OpenLoopConfig {
                lambda: 1.0,
                horizon_s: 400.0,
                streams,
                ..Default::default()
            };
            OpenLoopGen::new(profile.clone(), cfg).jobs().len() as f64
        };
        let (one, four) = (mk(1), mk(4));
        // Both target λ·horizon = 400 arrivals in expectation.
        assert!((one - 400.0).abs() < 80.0, "1 stream: {one}");
        assert!((four - 400.0).abs() < 80.0, "4 streams: {four}");
    }
}
