//! Generators for the non-batch service classes: long-running streaming
//! coflows with minimum-rate floors (SDN-allocated stream analytics over
//! the WAN) and recurring geo-distributed ML synchronization jobs
//! structured as aggregation trees.
//!
//! Both follow the crate's generator idiom — a `Pcg32` root stream seeded
//! from the caller's seed, one forked child stream per job — so the output
//! is a deterministic function of `(wan, n, seed)` alone.

use crate::coflow::{AggTree, Flow, ServiceClass};
use crate::net::Wan;
use crate::sim::{Job, Stage};
use crate::util::rng::Pcg32;

/// Generate `n` streaming jobs with Poisson arrivals: each is one
/// long-lived single-pair coflow with a rate floor in `[0.5, 2.0]` Gbps
/// and a nominal duration in `[60, 180]` s. The volume is
/// `floor × duration` — the stream that receives exactly its floor "keeps
/// up" for its whole duration; work-conservation surplus finishes it
/// early. Job ids start at `base_id`.
pub fn stream_jobs(wan: &Wan, n: usize, base_id: u64, seed: u64) -> Vec<Job> {
    let mut rng = Pcg32::new(seed ^ 0x7E44A);
    let num = wan.num_nodes();
    assert!(num >= 2, "streams need at least two datacenters");
    let mut t = 0.0;
    (0..n)
        .map(|i| {
            t += rng.exp(5.0);
            let mut r = rng.fork(i as u64);
            let src = r.below(num);
            let mut dst = r.below(num - 1);
            if dst >= src {
                dst += 1;
            }
            let floor = r.uniform(0.5, 2.0);
            let duration_s = r.uniform(60.0, 180.0);
            let flow = Flow { id: 0, src_dc: src, dst_dc: dst, volume: floor * duration_s };
            let mut job = Job::map_reduce(base_id + i as u64, t, 0.0, vec![flow]);
            job.stages[0].class = ServiceClass::Stream { rate_floor_gbps: floor };
            job
        })
        .collect()
}

/// Generate `n` geo-ML synchronization jobs with Poisson arrivals: each
/// samples 3–6 participating datacenters (fewer on tiny WANs), builds a
/// random recursive aggregation tree rooted at the first, and runs
/// `iters` chained iterations — each iteration is one stage whose coflow
/// ships `iteration_gbit` up every tree edge (child → parent), gated on
/// the previous iteration plus a per-job compute time. Job ids start at
/// `base_id`.
pub fn ml_sync_jobs(wan: &Wan, n: usize, iters: usize, base_id: u64, seed: u64) -> Vec<Job> {
    // Salted so the same seed gives streams and ML jobs independent draws.
    let mut rng = Pcg32::new(seed ^ 0x7E44A ^ 0x4D5359);
    let num = wan.num_nodes();
    assert!(num >= 2, "aggregation trees need at least two datacenters");
    assert!(iters >= 1, "ml_sync_jobs needs at least one iteration");
    let mut t = 0.0;
    (0..n)
        .map(|i| {
            t += rng.exp(20.0);
            let mut r = rng.fork(i as u64);
            let k = (r.range(3, 6) as usize).min(num);
            let members = r.sample_indices(num, k);
            let root = members[0];
            let mut edges: Vec<(usize, usize)> = Vec::with_capacity(members.len() - 1);
            for (mi, &node) in members.iter().enumerate().skip(1) {
                // Random recursive tree: parent uniformly among the
                // already-placed members, so depth grows logarithmically.
                let parent = members[r.below(mi)];
                edges.push((node, parent));
            }
            let tree = AggTree { root, edges: edges.clone() };
            let iteration_gbit = r.uniform(4.0, 16.0);
            let compute_s = r.uniform(1.0, 5.0);
            let flows: Vec<Flow> = edges
                .iter()
                .enumerate()
                .map(|(fi, &(child, parent))| Flow {
                    id: fi as u64,
                    src_dc: child,
                    dst_dc: parent,
                    volume: iteration_gbit,
                })
                .collect();
            let stages: Vec<Stage> = (0..iters)
                .map(|s| Stage {
                    deps: if s == 0 { vec![] } else { vec![s - 1] },
                    compute_s,
                    flows: flows.clone(),
                    deadline: None,
                    class: ServiceClass::MlSync { tree: tree.clone(), iteration_gbit },
                })
                .collect();
            Job { id: base_id + i as u64, arrival: t, stages }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::topologies;

    #[test]
    fn stream_jobs_deterministic_and_plumbed() {
        let wan = topologies::swan();
        let a = stream_jobs(&wan, 12, 100, 7);
        let b = stream_jobs(&wan, 12, 100, 7);
        assert_eq!(a.len(), 12);
        let mut last = 0.0;
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits(), "not deterministic");
            assert_eq!(x.total_volume().to_bits(), y.total_volume().to_bits());
            assert!(x.arrival >= last);
            last = x.arrival;
            x.validate().unwrap();
            assert_eq!(x.stages.len(), 1);
            let st = &x.stages[0];
            let ServiceClass::Stream { rate_floor_gbps } = st.class else {
                panic!("stream stage must carry the Stream class: {:?}", st.class);
            };
            assert!((0.5..2.0).contains(&rate_floor_gbps));
            assert_eq!(st.class.rate_floor(), Some(rate_floor_gbps));
            assert_eq!(st.flows.len(), 1);
            assert_ne!(st.flows[0].src_dc, st.flows[0].dst_dc);
            // volume = floor × duration, duration ∈ [60, 180].
            let dur = st.flows[0].volume / rate_floor_gbps;
            assert!((60.0..180.0).contains(&dur), "duration={dur}");
        }
        let c = stream_jobs(&wan, 12, 100, 8);
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.arrival != y.arrival),
            "different seeds must differ"
        );
        assert_eq!(a[0].id, 100, "base_id must offset job ids");
    }

    #[test]
    fn ml_sync_jobs_deterministic_tree_structure() {
        let wan = topologies::swan();
        let a = ml_sync_jobs(&wan, 8, 3, 500, 7);
        let b = ml_sync_jobs(&wan, 8, 3, 500, 7);
        assert_eq!(a.len(), 8);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
            x.validate().unwrap();
            assert_eq!(x.stages.len(), 3, "one stage per iteration");
            for (si, st) in x.stages.iter().enumerate() {
                let ServiceClass::MlSync { tree, iteration_gbit } = &st.class else {
                    panic!("ml stage must carry the MlSync class: {:?}", st.class);
                };
                let yt = match &y.stages[si].class {
                    ServiceClass::MlSync { tree, .. } => tree,
                    _ => unreachable!(),
                };
                assert_eq!(tree, yt, "tree must be seed-deterministic");
                // Iterations chain: stage s depends exactly on s-1.
                if si == 0 {
                    assert!(st.deps.is_empty());
                } else {
                    assert_eq!(st.deps, vec![si - 1]);
                }
                // One flow per tree edge, child → parent, volume =
                // iteration_gbit.
                assert_eq!(st.flows.len(), tree.edges.len());
                for (f, &(c, p)) in st.flows.iter().zip(&tree.edges) {
                    assert_eq!((f.src_dc, f.dst_dc), (c, p));
                    assert!((f.volume - iteration_gbit).abs() < 1e-12);
                }
                // Tree is rooted and connected: every participant except
                // the root appears exactly once as a child.
                let parts = tree.participants();
                assert!(parts.contains(&tree.root));
                let mut children: Vec<usize> = tree.edges.iter().map(|&(c, _)| c).collect();
                children.sort_unstable();
                children.dedup();
                assert_eq!(children.len(), tree.edges.len(), "each child parented once");
                assert!(!children.contains(&tree.root), "root is nobody's child");
            }
        }
        assert_eq!(a[0].id, 500);
    }
}
