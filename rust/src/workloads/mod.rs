//! Workload generators for the paper's four evaluation workloads (§6.1):
//! BigBench, TPC-DS, TPC-H (complex DAG jobs with scale factors 40–100,
//! lasting minutes to tens of minutes) and the Facebook trace (526 simple
//! MapReduce jobs with heavily-skewed coflow sizes).
//!
//! We do not run the SQL engines; what the WAN scheduler sees is the DAG of
//! stages, task placements, and shuffle byte volumes. The generators
//! reproduce those statistics:
//!
//! - **DAG shapes** per benchmark (chains for TPC-H, bushier join trees for
//!   TPC-DS, widest for BigBench) as produced by Calcite/Tez query plans;
//! - **placement**: each input table spans at most `N/2 + 1` of `N`
//!   datacenters; tasks run datacenter-local (§6.1);
//! - **volumes**: per-stage shuffles scaled by a per-job scale factor in
//!   [40, 100]; FB volumes follow the published trace's heavy tail (most
//!   coflows are tiny, a few carry nearly all bytes);
//! - **arrivals**: Poisson, matching "an arrival distribution similar to
//!   that in production traces".

pub mod classes;
pub mod dag;
pub mod deadlines;
pub mod fb;
pub mod openloop;

pub use classes::{ml_sync_jobs, stream_jobs};
pub use deadlines::assign_deadlines;
pub use openloop::{
    stream_fingerprint, HistoBin, Interarrival, OpenLoopConfig, OpenLoopGen, RvHisto,
    WorkloadProfile,
};

use crate::net::Wan;
use crate::sim::Job;
use crate::util::rng::Pcg32;

/// Which workload to generate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadKind {
    BigBench,
    TpcDs,
    TpcH,
    Fb,
}

impl WorkloadKind {
    pub fn all() -> [WorkloadKind; 4] {
        [WorkloadKind::BigBench, WorkloadKind::Fb, WorkloadKind::TpcDs, WorkloadKind::TpcH]
    }

    pub fn name(&self) -> &'static str {
        match self {
            WorkloadKind::BigBench => "bigbench",
            WorkloadKind::TpcDs => "tpcds",
            WorkloadKind::TpcH => "tpch",
            WorkloadKind::Fb => "fb",
        }
    }

    pub fn by_name(s: &str) -> Option<WorkloadKind> {
        match s.to_ascii_lowercase().as_str() {
            "bigbench" | "bb" => Some(WorkloadKind::BigBench),
            "tpcds" | "tpc-ds" => Some(WorkloadKind::TpcDs),
            "tpch" | "tpc-h" => Some(WorkloadKind::TpcH),
            "fb" | "facebook" => Some(WorkloadKind::Fb),
            _ => None,
        }
    }
}

/// Generation knobs. Defaults follow §6.1.
#[derive(Clone, Debug)]
pub struct WorkloadConfig {
    pub kind: WorkloadKind,
    pub seed: u64,
    /// Machines per datacenter (10 on the testbed, 100 in simulations).
    pub machines_per_dc: usize,
    /// Multiplier on the Poisson arrival rate (Fig 13 load scaling).
    pub arrival_scale: f64,
    /// Multiplier on shuffle volumes ("increasing load by making jobs
    /// larger", §6.7).
    pub volume_scale: f64,
}

impl WorkloadConfig {
    pub fn new(kind: WorkloadKind, seed: u64) -> WorkloadConfig {
        WorkloadConfig {
            kind,
            seed,
            machines_per_dc: 100,
            arrival_scale: 1.0,
            volume_scale: 1.0,
        }
    }
}

/// The workload generator.
pub struct WorkloadGen {
    cfg: WorkloadConfig,
    rng: Pcg32,
}

impl WorkloadGen {
    pub fn new(kind: WorkloadKind, seed: u64) -> WorkloadGen {
        WorkloadGen::with_config(WorkloadConfig::new(kind, seed))
    }

    pub fn with_config(cfg: WorkloadConfig) -> WorkloadGen {
        let rng = Pcg32::new(cfg.seed ^ 0x7E44A);
        WorkloadGen { cfg, rng }
    }

    /// Generate `n` jobs over the given WAN with Poisson arrivals.
    pub fn jobs(&mut self, wan: &Wan, n: usize) -> Vec<Job> {
        // Mean inter-arrival tuned so a few jobs overlap at any time
        // (matching the production-trace-like arrival pattern): benchmark
        // jobs take minutes, FB jobs are shorter and arrive denser.
        let base_iat = match self.cfg.kind {
            WorkloadKind::Fb => 12.0,
            _ => 30.0,
        };
        let mut t = 0.0;
        let mut out = Vec::with_capacity(n);
        for id in 0..n {
            t += self.rng.exp(base_iat / self.cfg.arrival_scale);
            let mut job_rng = self.rng.fork(id as u64);
            let job = match self.cfg.kind {
                WorkloadKind::Fb => fb::fb_job(id as u64, t, wan, &self.cfg, &mut job_rng),
                kind => dag::benchmark_job(id as u64, t, wan, kind, &self.cfg, &mut job_rng),
            };
            out.push(job);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::topologies;

    #[test]
    fn generates_requested_count_deterministically() {
        let wan = topologies::swan();
        for kind in WorkloadKind::all() {
            let a = WorkloadGen::new(kind, 42).jobs(&wan, 20);
            let b = WorkloadGen::new(kind, 42).jobs(&wan, 20);
            assert_eq!(a.len(), 20);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.arrival, y.arrival, "{kind:?} not deterministic");
                assert_eq!(x.total_volume(), y.total_volume());
            }
            // All DAGs valid, arrivals increasing.
            let mut last = 0.0;
            for j in &a {
                j.validate().unwrap();
                assert!(j.arrival >= last);
                last = j.arrival;
            }
        }
    }

    #[test]
    fn kinds_have_distinct_shapes() {
        let wan = topologies::swan();
        let avg_stages = |kind| {
            let jobs = WorkloadGen::new(kind, 7).jobs(&wan, 40);
            jobs.iter().map(|j| j.stages.len()).sum::<usize>() as f64 / 40.0
        };
        let fb = avg_stages(WorkloadKind::Fb);
        let tpch = avg_stages(WorkloadKind::TpcH);
        let bb = avg_stages(WorkloadKind::BigBench);
        assert!((fb - 1.0).abs() < 1e-9, "FB jobs are single-stage MapReduce");
        assert!(tpch > 1.5, "tpch={tpch}");
        assert!(bb > tpch, "bigbench ({bb}) should be more complex than tpch ({tpch})");
    }

    #[test]
    fn by_name_roundtrip() {
        for kind in WorkloadKind::all() {
            assert_eq!(WorkloadKind::by_name(kind.name()), Some(kind));
        }
    }

    #[test]
    fn volume_scale_scales() {
        let wan = topologies::swan();
        let mut c1 = WorkloadConfig::new(WorkloadKind::BigBench, 5);
        c1.volume_scale = 1.0;
        let mut c2 = c1.clone();
        c2.volume_scale = 3.0;
        let v1: f64 =
            WorkloadGen::with_config(c1).jobs(&wan, 20).iter().map(|j| j.total_volume()).sum();
        let v2: f64 =
            WorkloadGen::with_config(c2).jobs(&wan, 20).iter().map(|j| j.total_volume()).sum();
        assert!((v2 / v1 - 3.0).abs() < 0.01, "ratio={}", v2 / v1);
    }

    #[test]
    fn arrival_scale_compresses() {
        let wan = topologies::swan();
        let mut c1 = WorkloadConfig::new(WorkloadKind::TpcDs, 5);
        c1.arrival_scale = 1.0;
        let mut c2 = c1.clone();
        c2.arrival_scale = 2.0;
        let last1 = WorkloadGen::with_config(c1).jobs(&wan, 30).last().unwrap().arrival;
        let last2 = WorkloadGen::with_config(c2).jobs(&wan, 30).last().unwrap().arrival;
        assert!(last2 < last1, "{last2} < {last1}");
    }
}
