//! DAG-shaped benchmark jobs (BigBench / TPC-DS / TPC-H) and task placement.
//!
//! Query plans compiled by Calcite and executed by Tez form DAGs whose
//! shape depends on the benchmark: TPC-H queries are mostly scan→join→agg
//! chains; TPC-DS adds more multi-way joins; BigBench ("BB") adds the
//! widest plans (UDF/ML stages over many tables). Volumes scale with the
//! per-job scale factor drawn from [40, 100] (§6.1).

use super::{WorkloadConfig, WorkloadKind};
use crate::coflow::{Flow, GB};
use crate::net::Wan;
use crate::sim::{Job, Stage};
use crate::util::rng::Pcg32;

/// Stage-count range per benchmark (inclusive).
fn stage_range(kind: WorkloadKind) -> (usize, usize) {
    match kind {
        WorkloadKind::TpcH => (2, 5),
        WorkloadKind::TpcDs => (3, 8),
        WorkloadKind::BigBench => (4, 12),
        WorkloadKind::Fb => (1, 1),
    }
}

/// Probability a non-root stage has two parents (join) instead of one.
fn join_prob(kind: WorkloadKind) -> f64 {
    match kind {
        WorkloadKind::TpcH => 0.25,
        WorkloadKind::TpcDs => 0.4,
        WorkloadKind::BigBench => 0.5,
        WorkloadKind::Fb => 0.0,
    }
}

/// Pick the datacenters holding a table: a random subset of size
/// 1..=(N/2 + 1) (§6.1 input placement).
pub fn table_placement(wan: &Wan, rng: &mut Pcg32) -> Vec<usize> {
    let n = wan.num_nodes();
    let max_span = n / 2 + 1;
    let span = 1 + rng.below(max_span);
    rng.sample_indices(n, span)
}

/// Build the shuffle flows for one stage: every source task in the source
/// datacenters sends to every destination task (hash partitioning), with
/// datacenter locality for the tasks themselves.
#[allow(clippy::too_many_arguments)]
pub fn shuffle_flows(
    src_dcs: &[usize],
    dst_dcs: &[usize],
    tasks_per_src_dc: usize,
    tasks_per_dst_dc: usize,
    total_volume: f64,
    rng: &mut Pcg32,
) -> Vec<Flow> {
    let m = src_dcs.len() * tasks_per_src_dc;
    let r = dst_dcs.len() * tasks_per_dst_dc;
    if m == 0 || r == 0 || total_volume <= 0.0 {
        return Vec::new();
    }
    let mut flows = Vec::with_capacity(m * r);
    let mut id = 0u64;
    // Mapper outputs are roughly balanced; add ±25% jitter per flow and
    // renormalize to the stage volume.
    let mut raw = Vec::with_capacity(m * r);
    for &s in src_dcs {
        for _ in 0..tasks_per_src_dc {
            for &d in dst_dcs {
                for _ in 0..tasks_per_dst_dc {
                    raw.push((s, d, rng.uniform(0.75, 1.25)));
                }
            }
        }
    }
    let sum: f64 = raw.iter().map(|r| r.2).sum();
    for (s, d, w) in raw {
        flows.push(Flow { id, src_dc: s, dst_dc: d, volume: total_volume * w / sum });
        id += 1;
    }
    flows
}

/// Generate one benchmark job.
pub fn benchmark_job(
    id: u64,
    arrival: f64,
    wan: &Wan,
    kind: WorkloadKind,
    cfg: &WorkloadConfig,
    rng: &mut Pcg32,
) -> Job {
    let (lo, hi) = stage_range(kind);
    let num_stages = rng.range(lo as i64, hi as i64) as usize;
    // Scale factor 40..=100 drives volumes (§6.1).
    let scale = rng.uniform(40.0, 100.0);
    // Tasks per datacenter: bounded by machines (one task per machine wave).
    let tasks_per_dc = (cfg.machines_per_dc / 10).clamp(1, 16);

    let mut stages: Vec<Stage> = Vec::with_capacity(num_stages);
    // Each stage's output lives where its (reduce) tasks ran.
    let mut out_dcs: Vec<Vec<usize>> = Vec::with_capacity(num_stages);
    for s in 0..num_stages {
        let deps: Vec<usize> = if s == 0 {
            vec![]
        } else if s >= 2 && rng.chance(join_prob(kind)) {
            let a = rng.below(s);
            let mut b = rng.below(s);
            while b == a {
                b = rng.below(s);
            }
            vec![a.min(b), a.max(b)]
        } else {
            vec![rng.below(s)]
        };
        // Source datacenters: where the inputs live (tables for roots,
        // parent outputs otherwise).
        let src_dcs: Vec<usize> = if deps.is_empty() {
            table_placement(wan, rng)
        } else {
            let mut v: Vec<usize> = deps.iter().flat_map(|&d| out_dcs[d].clone()).collect();
            v.sort_unstable();
            v.dedup();
            v
        };
        // Destination: later stages aggregate toward fewer datacenters.
        let dst_span = if s + 1 == num_stages { 1 } else { 1 + rng.below(2.min(src_dcs.len())) };
        let dst_dcs = rng.sample_indices(wan.num_nodes(), dst_span);

        // Per-stage shuffle volume: the scale factor sets the base table
        // size; intermediate data shrinks as the plan aggregates.
        let depth_shrink = 0.7f64.powi(s as i32);
        let gb = scale * rng.lognormal(0.0, 0.6) * depth_shrink * cfg.volume_scale;
        let flows =
            shuffle_flows(&src_dcs, &dst_dcs, tasks_per_dc, tasks_per_dc, gb * GB, rng);

        // Computation time: total work divided over the machines running
        // tasks (Fig 14's T_comp).
        let work_machine_seconds = scale * rng.uniform(1.0, 3.0);
        let machines = (src_dcs.len() * cfg.machines_per_dc).max(1);
        let compute_s = work_machine_seconds * 10.0 / machines as f64;

        stages.push(Stage { deps, compute_s, flows, ..Default::default() });
        out_dcs.push(dst_dcs);
    }
    Job { id, arrival, stages }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::topologies;

    #[test]
    fn placement_respects_span_limit() {
        let wan = topologies::swan(); // N=5 -> max span 3
        let mut rng = Pcg32::new(3);
        for _ in 0..200 {
            let p = table_placement(&wan, &mut rng);
            assert!(!p.is_empty() && p.len() <= 3, "{p:?}");
            let mut q = p.clone();
            q.sort_unstable();
            q.dedup();
            assert_eq!(q.len(), p.len(), "duplicate DCs");
        }
    }

    #[test]
    fn shuffle_flow_volume_conserved() {
        let mut rng = Pcg32::new(5);
        let flows = shuffle_flows(&[0, 1], &[2], 3, 2, 100.0, &mut rng);
        assert_eq!(flows.len(), 2 * 3 * 2);
        let total: f64 = flows.iter().map(|f| f.volume).sum();
        assert!((total - 100.0).abs() < 1e-9);
    }

    #[test]
    fn benchmark_job_reasonable() {
        let wan = topologies::swan();
        let cfg = WorkloadConfig::new(WorkloadKind::BigBench, 1);
        let mut rng = Pcg32::new(17);
        for i in 0..30 {
            let j = benchmark_job(i, 0.0, &wan, WorkloadKind::BigBench, &cfg, &mut rng);
            j.validate().unwrap();
            assert!(!j.stages.is_empty());
            assert!(j.stages.iter().all(|s| s.compute_s >= 0.0));
            // Jobs should have meaningful WAN traffic most of the time.
        }
        // At least some jobs have WAN volume.
        let total: f64 = (0..20)
            .map(|i| {
                benchmark_job(100 + i, 0.0, &wan, WorkloadKind::BigBench, &cfg, &mut rng)
                    .total_volume()
            })
            .sum();
        assert!(total > 0.0);
    }
}
