//! Simulation outputs: per-coflow and per-job records plus the aggregate
//! metrics the paper reports (average/95th-percentile JCT and CCT, WAN
//! utilization, deadline-met fraction, slowdowns).

use crate::coflow::CoflowId;
use crate::util::stats;

/// Lifecycle record of one coflow.
#[derive(Clone, Debug)]
pub struct CoflowRecord {
    pub id: CoflowId,
    /// Owning job, if the coflow came from a job DAG.
    pub job: Option<u64>,
    pub arrival: f64,
    pub finish: Option<f64>,
    /// Total WAN volume (Gbit).
    pub volume: f64,
    /// Minimum CCT in an empty WAN (for slowdown + deadline metrics).
    pub min_cct: f64,
    /// Absolute deadline if any.
    pub deadline: Option<f64>,
    /// False when admission control rejected the coflow.
    pub admitted: bool,
    /// Service class name ("batch" / "deadline" / "stream" / "ml-sync").
    pub class: &'static str,
    /// Seconds the coflow's achieved rate spent below its rate floor
    /// (streams only; 0 for every other class).
    pub violation_s: f64,
}

impl CoflowRecord {
    pub fn cct(&self) -> Option<f64> {
        self.finish.map(|f| f - self.arrival)
    }

    /// CCT / minimum CCT in an empty network (§6.3 "how far from optimal").
    pub fn slowdown(&self) -> Option<f64> {
        self.cct().map(|c| if self.min_cct > 0.0 { c / self.min_cct } else { 1.0 })
    }

    pub fn met_deadline(&self) -> bool {
        match (self.deadline, self.finish) {
            (Some(d), Some(f)) => self.admitted && f <= d + 1e-6,
            _ => false,
        }
    }
}

/// Lifecycle record of one job.
#[derive(Clone, Debug)]
pub struct JobRecord {
    pub id: u64,
    pub arrival: f64,
    pub finish: Option<f64>,
    pub volume: f64,
}

impl JobRecord {
    pub fn jct(&self) -> Option<f64> {
        self.finish.map(|f| f - self.arrival)
    }
}

/// Aggregate simulation report.
#[derive(Clone, Debug, Default)]
pub struct Report {
    pub policy: String,
    pub coflows: Vec<CoflowRecord>,
    pub jobs: Vec<JobRecord>,
    /// Gbit actually transferred over the WAN.
    pub transferred_gbit: f64,
    /// Integral of total WAN capacity over the busy period (Gbit).
    pub capacity_gbit: f64,
    /// Scheduling rounds executed.
    pub rounds: usize,
    /// Total LP solves and solver time across rounds.
    pub lp_solves: usize,
    pub lp_time_s: f64,
    pub round_time_s: f64,
    /// Standalone-Γ solves served from the engine's Γ-cache instead of an
    /// LP solve (incremental re-optimization).
    pub gamma_cache_hits: usize,
    /// Edge-connected components re-solved across rounds, and components
    /// whose allocation was carried forward unchanged (decomposed rounds).
    pub component_solves: usize,
    pub component_reuses: usize,
    /// Coflows moved between engine shards (sharded front-end only).
    pub shard_migrations: usize,
    /// WAN events delivered to the engine (fail / recover / fluctuation).
    pub wan_events: usize,
    /// Rounds triggered by WAN changes (structural, ≥ ρ, or accumulated
    /// drift) — sub-ρ clamps don't count.
    pub wan_rounds: usize,
    /// Total / worst wall-clock time of WAN-triggered rounds: how long the
    /// scheduler takes to react to a failure or qualifying fluctuation.
    pub reaction_time_s: f64,
    pub max_reaction_s: f64,
    /// Telemetry (belief mode): passive throughput samples and active
    /// probes ingested by the capacity estimator.
    pub est_samples: usize,
    pub est_probes: usize,
    /// Estimation error: sum / count of per-edge absolute percentage
    /// error `|believed − truth| / truth`, sampled at telemetry ticks over
    /// up edges. Zero under the oracle (the belief *is* the truth).
    pub est_mape_sum: f64,
    pub est_mape_samples: usize,
    /// Capacity staleness episodes: a ground-truth bandwidth change left
    /// the scheduler's believed capacity ≥ ρ away from reality
    /// (`stale_events`), and how many of those episodes closed
    /// (`stale_resolved`) after accumulating `stale_reaction_s_sum`
    /// **simulated** seconds of staleness. The oracle resolves every
    /// episode at latency 0 by construction.
    pub stale_events: usize,
    pub stale_resolved: usize,
    pub stale_reaction_s_sum: f64,
    /// Controller chaos (`controller_chaos` axis): injected crash/restart
    /// cycles, total simulated downtime, Gbit the agents kept draining in
    /// degraded mode while the controller was down, bytes-in-flight at the
    /// kill and at the restart (their ratio is the preserved fraction —
    /// 1.0 under resync reconstruction, collapsing toward 0 under a
    /// restart-from-zero strawman), and the wall-clock cost of the first
    /// post-restart reconstruction round.
    pub chaos_kills: usize,
    pub chaos_downtime_s: f64,
    pub drained_degraded_gbit: f64,
    pub inflight_at_kill_gbit: f64,
    pub inflight_at_restart_gbit: f64,
    pub recovery_round_s: f64,
    /// Data-plane chaos (`agent_chaos` axis): agent/partition failures
    /// the controller *detected* (declared down, parked the touched
    /// coflows, re-solved the survivors), summed detection latency
    /// (kill → declaration; the liveness deadline or the stall-watchdog
    /// horizon, whichever detector the target models), coflows parked at
    /// those declarations, and coflow·seconds the touched traffic sat
    /// stalled before detection (allocated but moving nothing — the
    /// window rescheduling cannot reclaim).
    pub agent_downs: usize,
    pub agent_detection_s: f64,
    pub agent_parked: usize,
    pub agent_stall_s: f64,
    /// Service classes: total seconds × coflows that streams spent below
    /// their rate floor (violation-seconds), and how many times an MlSync
    /// iteration re-shaped its aggregation tree because a tree link had
    /// degraded below the reshape threshold.
    pub stream_violation_s: f64,
    pub tree_reshapes: usize,
    /// Integral over rounds of unreservable floor demand (Gbps·rounds):
    /// > 0 means some round could not fit every admitted floor.
    pub floor_shortfall_gbps: f64,
    /// Offered-vs-admitted accounting (the open-loop saturation harness):
    /// WAN coflows submitted to the control plane, how many entered
    /// scheduling, and how many admission control turned away. Always
    /// `offered == admitted + rejected`.
    pub offered: usize,
    pub admitted: usize,
    pub rejected: usize,
    /// `(sim time, active coflows)` sampled at every coflow submission —
    /// the instantaneous control-plane backlog. Under open-loop overload
    /// this grows without bound; its windowed p99 is the saturation
    /// sweep's queue-depth signal.
    pub backlog: Vec<(f64, usize)>,
    /// Simulated makespan.
    pub makespan: f64,
}

impl Report {
    fn jcts(&self) -> Vec<f64> {
        self.jobs.iter().filter_map(|j| j.jct()).collect()
    }

    fn ccts(&self) -> Vec<f64> {
        self.coflows.iter().filter_map(|c| c.cct()).collect()
    }

    pub fn avg_jct(&self) -> f64 {
        stats::mean(&self.jcts())
    }

    pub fn p95_jct(&self) -> f64 {
        stats::percentile(&self.jcts(), 95.0)
    }

    pub fn avg_cct(&self) -> f64 {
        stats::mean(&self.ccts())
    }

    pub fn p95_cct(&self) -> f64 {
        stats::percentile(&self.ccts(), 95.0)
    }

    pub fn p99_cct(&self) -> f64 {
        stats::percentile(&self.ccts(), 99.0)
    }

    /// Mean wall-clock latency (ms) of rounds reacting to WAN changes.
    pub fn avg_reaction_ms(&self) -> f64 {
        if self.wan_rounds == 0 {
            0.0
        } else {
            1e3 * self.reaction_time_s / self.wan_rounds as f64
        }
    }

    /// Mean absolute percentage error of the scheduler's believed edge
    /// capacities vs ground truth (0 when nothing was sampled — e.g. the
    /// oracle, whose belief is the truth).
    pub fn est_mape(&self) -> f64 {
        if self.est_mape_samples == 0 {
            0.0
        } else {
            self.est_mape_sum / self.est_mape_samples as f64
        }
    }

    /// Mean simulated latency (s) from a ground-truth capacity change
    /// drifting ≥ ρ out of the scheduler's view to the belief closing back
    /// within ρ. 0 for the oracle by construction.
    pub fn avg_stale_reaction_s(&self) -> f64 {
        if self.stale_resolved == 0 {
            0.0
        } else {
            self.stale_reaction_s_sum / self.stale_resolved as f64
        }
    }

    /// Average WAN utilization over the busy period.
    pub fn utilization(&self) -> f64 {
        if self.capacity_gbit > 0.0 {
            self.transferred_gbit / self.capacity_gbit
        } else {
            0.0
        }
    }

    /// Fraction of deadline-bearing coflows that met their deadline.
    pub fn deadline_met_fraction(&self) -> f64 {
        let with_d: Vec<&CoflowRecord> =
            self.coflows.iter().filter(|c| c.deadline.is_some()).collect();
        if with_d.is_empty() {
            return 0.0;
        }
        with_d.iter().filter(|c| c.met_deadline()).count() as f64 / with_d.len() as f64
    }

    /// Average CCT restricted to one service class (0 when the class has
    /// no finished coflows).
    pub fn avg_cct_class(&self, class: &str) -> f64 {
        let ccts: Vec<f64> =
            self.coflows.iter().filter(|c| c.class == class).filter_map(|c| c.cct()).collect();
        stats::mean(&ccts)
    }

    /// Average ML synchronization iteration time: each MlSync iteration is
    /// one coflow, so this is the mean CCT over "ml-sync" records.
    pub fn avg_iteration_s(&self) -> f64 {
        self.avg_cct_class("ml-sync")
    }

    /// Number of coflows of a given service class.
    pub fn class_count(&self, class: &str) -> usize {
        self.coflows.iter().filter(|c| c.class == class).count()
    }

    /// Average coflow slowdown vs an empty WAN.
    pub fn avg_slowdown(&self) -> f64 {
        stats::mean(&self.coflows.iter().filter_map(|c| c.slowdown()).collect::<Vec<_>>())
    }

    /// Number of coflows that never finished (starved / partitioned).
    pub fn unfinished(&self) -> usize {
        self.coflows.iter().filter(|c| c.admitted && c.finish.is_none()).count()
    }

    /// p99 of the sampled control-plane backlog (active coflows at
    /// submission time), optionally restricted to a `[lo, hi)` window of
    /// simulated time. 0.0 when nothing was sampled in the window.
    pub fn backlog_p99_between(&self, lo: f64, hi: f64) -> f64 {
        let depths: Vec<f64> = self
            .backlog
            .iter()
            .filter(|&&(t, _)| t >= lo && t < hi)
            .map(|&(_, d)| d as f64)
            .collect();
        stats::percentile(&depths, 99.0)
    }

    /// p99 backlog over the whole run.
    pub fn backlog_p99(&self) -> f64 {
        self.backlog_p99_between(f64::NEG_INFINITY, f64::INFINITY)
    }

    /// How much transfer progress survived the controller restart, as
    /// `min(1, remaining_at_kill / remaining_at_restart)`. Resync
    /// reconstruction keeps (or shrinks, via degraded drains) the
    /// remaining volume, so this is 1.0; a restart-from-zero strawman
    /// re-inflates remaining back to full volume and the fraction drops
    /// by exactly the progress thrown away. 1.0 when no kill was
    /// injected.
    pub fn preserved_fraction(&self) -> f64 {
        if self.chaos_kills == 0 || self.inflight_at_restart_gbit <= 0.0 {
            return 1.0;
        }
        (self.inflight_at_kill_gbit / self.inflight_at_restart_gbit).min(1.0)
    }

    /// Pearson correlation between per-job total WAN bytes and JCT-based
    /// factor-of-improvement requires two reports; see
    /// [`foi_volume_correlation`].
    pub fn job_jct_map(&self) -> std::collections::HashMap<u64, f64> {
        self.jobs.iter().filter_map(|j| j.jct().map(|t| (j.id, t))).collect()
    }
}

/// Factor of improvement of `ours` w.r.t. `baseline`
/// (`FoI = T_baseline / T_ours`, > 1 means `ours` wins).
pub fn foi(baseline: f64, ours: f64) -> f64 {
    if ours > 0.0 {
        baseline / ours
    } else {
        f64::INFINITY
    }
}

/// Pearson r between job volume and per-job FoI (paper §6.3 reports
/// -0.05..-0.39: smaller jobs benefit more).
pub fn foi_volume_correlation(ours: &Report, baseline: &Report) -> f64 {
    let base = baseline.job_jct_map();
    let mut vols = Vec::new();
    let mut fois = Vec::new();
    for j in &ours.jobs {
        if let (Some(jct), Some(&bjct)) = (j.jct(), base.get(&j.id)) {
            vols.push(j.volume);
            fois.push(bjct / jct.max(1e-9));
        }
    }
    stats::pearson(&vols, &fois)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(arrival: f64, finish: f64, min_cct: f64, deadline: Option<f64>) -> CoflowRecord {
        CoflowRecord {
            id: 0,
            job: None,
            arrival,
            finish: Some(finish),
            volume: 1.0,
            min_cct,
            deadline,
            admitted: true,
            class: "batch",
            violation_s: 0.0,
        }
    }

    #[test]
    fn cct_and_slowdown() {
        let r = rec(10.0, 18.0, 4.0, None);
        assert!((r.cct().unwrap() - 8.0).abs() < 1e-12);
        assert!((r.slowdown().unwrap() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn deadline_met() {
        assert!(rec(0.0, 5.0, 1.0, Some(6.0)).met_deadline());
        assert!(!rec(0.0, 7.0, 1.0, Some(6.0)).met_deadline());
        let mut r = rec(0.0, 5.0, 1.0, Some(6.0));
        r.admitted = false;
        assert!(!r.met_deadline());
    }

    #[test]
    fn report_aggregates() {
        let mut rep = Report::default();
        rep.coflows.push(rec(0.0, 4.0, 2.0, Some(10.0)));
        rep.coflows.push(rec(0.0, 12.0, 2.0, Some(10.0)));
        rep.jobs.push(JobRecord { id: 1, arrival: 0.0, finish: Some(10.0), volume: 5.0 });
        rep.transferred_gbit = 50.0;
        rep.capacity_gbit = 100.0;
        assert!((rep.avg_cct() - 8.0).abs() < 1e-12);
        assert!((rep.avg_jct() - 10.0).abs() < 1e-12);
        assert!((rep.utilization() - 0.5).abs() < 1e-12);
        assert!((rep.deadline_met_fraction() - 0.5).abs() < 1e-12);
        assert!((rep.avg_slowdown() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn backlog_percentiles_window() {
        let mut rep = Report::default();
        assert_eq!(rep.backlog_p99(), 0.0, "no samples");
        rep.backlog = vec![(1.0, 2), (5.0, 10), (9.0, 4)];
        assert!((rep.backlog_p99() - 9.88).abs() < 1e-9);
        assert!((rep.backlog_p99_between(4.0, 10.0) - 9.94).abs() < 1e-9);
        assert_eq!(rep.backlog_p99_between(20.0, 30.0), 0.0);
    }

    #[test]
    fn foi_direction() {
        assert!((foi(14.0, 7.0) - 2.0).abs() < 1e-12);
        assert!(foi(7.0, 14.0) < 1.0);
    }
}
