//! Flow-level event-driven simulator (§6.1 "Simulator").
//!
//! Runs the same policy logic as the controller over a simulated WAN: jobs
//! arrive, their DAG stages compute and submit coflows, the policy
//! reallocates rates on every scheduling round (coflow arrival, FlowGroup /
//! coflow completion, significant WAN events), and FlowGroups drain at the
//! allocated rates between rounds. As in the paper, controller-agent
//! communication is instantaneous unless a coordination delay is configured
//! (used to mimic the testbed's feedback loops).

pub mod job;
pub mod report;

pub use job::{Job, Stage};
pub use report::{foi, foi_volume_correlation, CoflowRecord, JobRecord, Report};

use crate::coflow::{Coflow, CoflowId};
use crate::lp;
use crate::net::paths::PathSet;
use crate::net::{LinkEvent, Wan};
use crate::scheduler::{build_instance, Allocation, CoflowState, NetView, Policy, RoundTrigger};
use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashMap};

/// Simulator knobs.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Bandwidth-fluctuation threshold ρ for re-optimization (§3.1.3).
    pub rho: f64,
    /// Latency between coflow submission and participation in scheduling
    /// (models the controller feedback loop; 0 = the paper's simulator).
    pub coordination_delay_s: f64,
    /// Hard stop (simulated seconds).
    pub max_time: f64,
    /// Verify allocation feasibility every round (tests/debug).
    pub check_feasibility: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            rho: crate::scheduler::DEFAULT_RHO,
            coordination_delay_s: 0.0,
            max_time: 1e7,
            check_feasibility: cfg!(debug_assertions),
        }
    }
}

#[derive(Clone, Debug)]
enum EvKind {
    JobArrival(usize),
    /// All deps of (job, stage) finished and compute elapsed; submit the
    /// stage's coflow.
    CoflowSubmit { job: usize, stage: usize },
    /// Force-complete a stage (fallback path for rejected coflows and
    /// WAN-free stages finishing asynchronously).
    StageDone { job: usize, stage: usize },
    /// A submitted coflow becomes schedulable after the coordination delay.
    Activate(Box<CoflowState>),
    Wan(LinkEvent),
}

#[derive(Clone, Debug)]
struct TimedEvent {
    t: f64,
    seq: u64,
    kind: EvKind,
}

impl PartialEq for TimedEvent {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for TimedEvent {}
impl Ord for TimedEvent {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap: earliest time first, then insertion order.
        other.t.partial_cmp(&self.t).unwrap_or(Ordering::Equal).then(other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for TimedEvent {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

struct JobState {
    deps_remaining: Vec<usize>,
    stage_done: Vec<bool>,
}

/// The simulator.
pub struct Simulation {
    wan: Wan,
    policy: Box<dyn Policy>,
    cfg: SimConfig,
    paths: PathSet,
    now: f64,
    seq: u64,
    events: BinaryHeap<TimedEvent>,
    jobs: Vec<Job>,
    job_states: Vec<JobState>,
    active: Vec<CoflowState>,
    /// Coflow id -> (job idx, stage idx).
    owners: HashMap<CoflowId, (usize, usize)>,
    alloc: Allocation,
    next_coflow_id: CoflowId,
    report: Report,
    record_idx: HashMap<CoflowId, usize>,
}

impl Simulation {
    pub fn new(wan: Wan, policy: Box<dyn Policy>, cfg: SimConfig) -> Simulation {
        let paths = PathSet::compute(&wan, policy.k_paths());
        let name = policy.name().to_string();
        Simulation {
            wan,
            policy,
            cfg,
            paths,
            now: 0.0,
            seq: 0,
            events: BinaryHeap::new(),
            jobs: Vec::new(),
            job_states: Vec::new(),
            active: Vec::new(),
            owners: HashMap::new(),
            alloc: Allocation::default(),
            next_coflow_id: 1,
            report: Report { policy: name, ..Default::default() },
            record_idx: HashMap::new(),
        }
    }

    /// Access the WAN (e.g. to inspect capacities in tests).
    pub fn wan(&self) -> &Wan {
        &self.wan
    }

    fn push_event(&mut self, t: f64, kind: EvKind) {
        self.seq += 1;
        self.events.push(TimedEvent { t, seq: self.seq, kind });
    }

    /// Register a job before (or during) the run.
    pub fn add_job(&mut self, job: Job) {
        job.validate().expect("invalid job DAG");
        let idx = self.jobs.len();
        self.push_event(job.arrival.max(self.now), EvKind::JobArrival(idx));
        self.job_states.push(JobState {
            deps_remaining: job.stages.iter().map(|s| s.deps.len()).collect(),
            stage_done: vec![false; job.stages.len()],
        });
        self.report.jobs.push(JobRecord {
            id: job.id,
            arrival: job.arrival,
            finish: None,
            volume: job.total_volume(),
        });
        self.jobs.push(job);
    }

    /// Schedule a WAN event at absolute time `t`.
    pub fn add_wan_event(&mut self, t: f64, ev: LinkEvent) {
        self.push_event(t, EvKind::Wan(ev));
    }

    /// Convenience: add all jobs and run to completion.
    pub fn run_jobs(&mut self, jobs: Vec<Job>) -> Report {
        for j in jobs {
            self.add_job(j);
        }
        self.run()
    }

    /// Minimum CCT of a coflow alone on the *full* WAN (for slowdown and
    /// deadline metrics).
    pub fn standalone_min_cct(&self, st: &CoflowState) -> f64 {
        let net = NetView { wan: &self.wan, paths: &self.paths };
        let (inst, _) = build_instance(
            &st.groups,
            &st.remaining,
            &self.wan.capacities(),
            &net,
            self.policy.k_paths(),
        );
        if inst.groups.is_empty() {
            return 0.0;
        }
        lp::max_concurrent(&inst, lp::SolverKind::Gk).map(|s| s.gamma()).unwrap_or(f64::INFINITY)
    }

    /// Current total rate (Gbps) of a coflow, for live inspection (used by
    /// the failure case study, Fig 10).
    pub fn coflow_rate(&self, id: CoflowId) -> f64 {
        self.alloc.rates.get(&id).map(|g| g.iter().flatten().sum()).unwrap_or(0.0)
    }

    /// Drive the simulation until all jobs finish or `max_time`.
    pub fn run(&mut self) -> Report {
        self.run_until(f64::INFINITY)
    }

    /// Run until simulated time `stop` (or completion). Can be called
    /// repeatedly for timeline inspection (Fig 10 throughput traces).
    pub fn run_until(&mut self, stop: f64) -> Report {
        let mut needs_round: Option<RoundTrigger> = None;
        let mut starving_rounds = 0usize;
        loop {
            let completion = self.next_completion();
            let next_event_t = self.events.peek().map(|e| e.t);
            let target = match (completion, next_event_t) {
                (Some(c), Some(e)) => c.min(e),
                (Some(c), None) => c,
                (None, Some(e)) => e,
                (None, None) => {
                    if self.active.is_empty() || starving_rounds > 0 {
                        break;
                    }
                    // Active coflows, no rates, no events: force one round;
                    // if still no progress the WAN is partitioned for them.
                    starving_rounds += 1;
                    self.round(RoundTrigger::WanChange);
                    continue;
                }
            };
            if target > stop {
                self.advance(stop.min(self.cfg.max_time));
                break;
            }
            if target > self.cfg.max_time {
                log::warn!("hit max_time with {} active coflows", self.active.len());
                break;
            }
            starving_rounds = 0;
            self.advance(target);

            if self.process_completions() {
                needs_round = Some(RoundTrigger::CoflowFinish);
            }
            while self.events.peek().map(|e| e.t <= self.now + 1e-12).unwrap_or(false) {
                let ev = self.events.pop().unwrap();
                match ev.kind {
                    EvKind::JobArrival(j) => self.on_job_arrival(j),
                    EvKind::CoflowSubmit { job, stage } => {
                        if self.on_coflow_submit(job, stage) {
                            needs_round = Some(RoundTrigger::CoflowArrival);
                        }
                    }
                    EvKind::StageDone { job, stage } => self.complete_stage(job, stage),
                    EvKind::Activate(state) => {
                        self.active.push(*state);
                        needs_round = Some(RoundTrigger::CoflowArrival);
                    }
                    EvKind::Wan(wev) => {
                        let frac = self.wan.apply_event(&wev);
                        let structural =
                            matches!(wev, LinkEvent::Fail(..) | LinkEvent::Recover(..));
                        if structural {
                            // Recompute viable paths (§4.4).
                            self.paths = PathSet::compute(&self.wan, self.policy.k_paths());
                            needs_round = Some(RoundTrigger::WanChange);
                        } else if frac >= self.cfg.rho {
                            needs_round = Some(RoundTrigger::WanChange);
                        } else {
                            // Below-threshold fluctuation (§3.1.3): clamp the
                            // current allocation, no re-optimization.
                            self.clamp_alloc();
                        }
                    }
                }
            }

            if let Some(trigger) = needs_round.take() {
                self.round(trigger);
            }
        }
        // Finalize.
        self.report.makespan = self.now;
        let st = self.policy.take_stats();
        self.report.lp_solves += st.lp_solves;
        self.report.lp_time_s += st.lp_time_s;
        self.report.round_time_s += st.round_time_s;
        self.report.clone()
    }

    /// Earliest time any active FlowGroup empties at current rates.
    fn next_completion(&self) -> Option<f64> {
        let mut best: Option<f64> = None;
        for cf in &self.active {
            let Some(rates) = self.alloc.rates.get(&cf.id) else { continue };
            for (gi, &rem) in cf.remaining.iter().enumerate() {
                if rem <= 1e-9 {
                    continue;
                }
                let rate: f64 = rates.get(gi).map(|r| r.iter().sum()).unwrap_or(0.0);
                if rate > 1e-12 {
                    let t = self.now + rem / rate;
                    best = Some(best.map_or(t, |b: f64| b.min(t)));
                }
            }
        }
        best
    }

    /// Advance simulated time, draining FlowGroups and integrating
    /// utilization over the busy period.
    fn advance(&mut self, target: f64) {
        let dt = (target - self.now).max(0.0);
        if dt > 0.0 && !self.active.is_empty() {
            let mut moved = 0.0;
            for cf in &mut self.active {
                let Some(rates) = self.alloc.rates.get(&cf.id) else { continue };
                for (gi, rem) in cf.remaining.iter_mut().enumerate() {
                    if *rem <= 1e-9 {
                        continue;
                    }
                    let rate: f64 = rates.get(gi).map(|r| r.iter().sum()).unwrap_or(0.0);
                    let delta = (rate * dt).min(*rem);
                    *rem -= delta;
                    moved += delta;
                }
            }
            self.report.transferred_gbit += moved;
            self.report.capacity_gbit += self.wan.total_capacity() * dt;
        }
        self.now = target;
    }

    /// Remove finished coflows; update job DAGs. Returns true if anything
    /// finished.
    fn process_completions(&mut self) -> bool {
        let finished: Vec<CoflowId> =
            self.active.iter().filter(|c| c.done()).map(|c| c.id).collect();
        for id in &finished {
            let idx = self.record_idx[id];
            self.report.coflows[idx].finish = Some(self.now);
            self.alloc.rates.remove(id);
        }
        self.active.retain(|c| !c.done());
        for id in &finished {
            if let Some(&(job, stage)) = self.owners.get(id) {
                self.complete_stage(job, stage);
            }
        }
        !finished.is_empty()
    }

    fn on_job_arrival(&mut self, j: usize) {
        let stages: Vec<usize> = (0..self.jobs[j].stages.len())
            .filter(|&s| self.jobs[j].stages[s].deps.is_empty())
            .collect();
        for s in stages {
            let t = self.now + self.jobs[j].stages[s].compute_s;
            self.push_event(t, EvKind::CoflowSubmit { job: j, stage: s });
        }
    }

    /// Submit stage (job, stage)'s coflow. Returns true if a schedulable
    /// coflow entered the system.
    fn on_coflow_submit(&mut self, job: usize, stage: usize) -> bool {
        let st = &self.jobs[job].stages[stage];
        let wan_flows = st.flows.iter().filter(|f| f.src_dc != f.dst_dc).count();
        if wan_flows == 0 {
            self.complete_stage(job, stage);
            return false;
        }
        let id = self.next_coflow_id;
        self.next_coflow_id += 1;
        let mut coflow =
            Coflow::new(id, st.flows.clone()).with_arrival(self.now);
        if let Some(d) = st.deadline {
            coflow = coflow.with_deadline(d);
        }
        let mut state = CoflowState::from_coflow(&coflow);
        // Coordination delay: the coflow is known to the controller but no
        // bandwidth flows until the next round after the delay elapses; we
        // model it as added arrival latency on the record.
        let min_cct = self.standalone_min_cct(&state);

        let mut admitted = true;
        if state.deadline.is_some() {
            let net = NetView { wan: &self.wan, paths: &self.paths };
            admitted = self.policy.admit(self.now, &state, &self.active, &net);
        }
        state.admitted = admitted;

        self.owners.insert(id, (job, stage));
        self.record_idx.insert(id, self.report.coflows.len());
        self.report.coflows.push(CoflowRecord {
            id,
            job: Some(self.jobs[job].id),
            arrival: self.now,
            finish: None,
            volume: state.total_remaining(),
            min_cct,
            deadline: state.deadline,
            admitted,
        });
        if !admitted {
            // Rejected coflows fall back to the framework's default
            // transfer (§4.4); the stage completes after the standalone
            // minimum CCT without occupying Terra-scheduled bandwidth, and
            // the coflow counts as missing its deadline.
            let t = (self.now + min_cct.max(0.0)).min(self.cfg.max_time);
            self.push_event(t, EvKind::StageDone { job, stage });
            return false;
        }
        if self.cfg.coordination_delay_s > 0.0 {
            // Controller feedback loop: the coflow is recorded now (its CCT
            // clock is ticking) but receives bandwidth only after the
            // coordination delay — this is what penalizes sub-second
            // coflows under centralized scheduling (Fig 7d).
            let t = self.now + self.cfg.coordination_delay_s;
            self.push_event(t, EvKind::Activate(Box::new(state)));
            return false;
        }
        self.active.push(state);
        true
    }

    fn complete_stage(&mut self, job: usize, stage: usize) {
        if self.job_states[job].stage_done[stage] {
            return;
        }
        self.job_states[job].stage_done[stage] = true;
        let num_stages = self.jobs[job].stages.len();
        for s in 0..num_stages {
            if self.jobs[job].stages[s].deps.contains(&stage) {
                self.job_states[job].deps_remaining[s] -= 1;
                if self.job_states[job].deps_remaining[s] == 0 {
                    let t = self.now + self.jobs[job].stages[s].compute_s;
                    self.push_event(t, EvKind::CoflowSubmit { job, stage: s });
                }
            }
        }
        if self.job_states[job].stage_done.iter().all(|&d| d) {
            self.report.jobs[job].finish = Some(self.now);
        }
    }

    /// Run one scheduling round.
    fn round(&mut self, trigger: RoundTrigger) {
        let net = NetView { wan: &self.wan, paths: &self.paths };
        self.alloc = self.policy.allocate(self.now, trigger, &self.active, &net);
        self.report.rounds += 1;
        if self.cfg.check_feasibility {
            let usage = self.alloc.edge_usage(&self.active, &net, self.wan.num_edges());
            for (e, (&u, c)) in usage.iter().zip(self.wan.capacities()).enumerate() {
                assert!(
                    u <= c * (1.0 + 1e-4) + 1e-6,
                    "policy {} oversubscribed edge {e}: {u} > {c}",
                    self.report.policy
                );
            }
        }
    }

    /// Scale down rates on edges whose capacity dropped below usage
    /// (sub-threshold fluctuations, no re-optimization).
    fn clamp_alloc(&mut self) {
        let net = NetView { wan: &self.wan, paths: &self.paths };
        let usage = self.alloc.edge_usage(&self.active, &net, self.wan.num_edges());
        let caps = self.wan.capacities();
        let mut worst = 1.0f64;
        for (&u, &c) in usage.iter().zip(&caps) {
            if u > c && u > 1e-12 {
                worst = worst.min(c / u);
            }
        }
        if worst < 1.0 {
            for rates in self.alloc.rates.values_mut() {
                for g in rates {
                    for r in g {
                        *r *= worst;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coflow::{Flow, GB};
    use crate::net::topologies;
    use crate::scheduler::terra::{TerraConfig, TerraPolicy};

    fn mk_flow(id: u64, s: usize, d: usize, gb: f64) -> Flow {
        Flow { id, src_dc: s, dst_dc: d, volume: gb * GB }
    }

    fn terra0() -> Box<dyn Policy> {
        Box::new(TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() }))
    }

    #[test]
    fn single_coflow_min_cct() {
        // 5 GB A->B on fig1a: 40 Gbit over 20 Gbps (two paths) = 2 s.
        let wan = topologies::fig1a();
        let mut sim = Simulation::new(wan, terra0(), SimConfig::default());
        let job = Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 5.0)]);
        let rep = sim.run_jobs(vec![job]);
        assert_eq!(rep.jobs.len(), 1);
        let jct = rep.jobs[0].jct().unwrap();
        assert!((jct - 2.0).abs() < 0.1, "jct={jct}");
        assert_eq!(rep.unfinished(), 0);
    }

    #[test]
    fn fig1_average_cct_near_optimal() {
        // Paper Fig 1f: joint solution averages 7.15 s for Coflow-1 (5 GB
        // A->B) and Coflow-2 (5 GB A->B + 25 GB C->B).
        let wan = topologies::fig1a();
        let mut sim = Simulation::new(wan, terra0(), SimConfig::default());
        let j1 = Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 5.0)]);
        let j2 = Job::map_reduce(
            2,
            0.0,
            0.0,
            vec![mk_flow(0, 0, 1, 5.0), mk_flow(1, 2, 1, 25.0)],
        );
        let rep = sim.run_jobs(vec![j1, j2]);
        let avg = rep.avg_cct();
        // Terra should beat flow fair sharing (14 s), multipath (10.6 s) and
        // coflow-only (12 s); optimum is 7.15 s.
        assert!(avg < 10.0, "avg CCT {avg}");
        assert!(avg > 6.9, "cannot beat the offline optimum: {avg}");
    }

    #[test]
    fn compute_time_adds_to_jct() {
        let wan = topologies::fig1a();
        let mut sim = Simulation::new(wan, terra0(), SimConfig::default());
        let job = Job::map_reduce(1, 5.0, 3.0, vec![mk_flow(0, 0, 1, 5.0)]);
        let rep = sim.run_jobs(vec![job]);
        let jct = rep.jobs[0].jct().unwrap();
        assert!((jct - 5.0).abs() < 0.1, "jct={jct} (3 compute + 2 transfer)");
        // Coflow record arrival is after compute.
        assert!((rep.coflows[0].arrival - 8.0).abs() < 1e-6);
    }

    #[test]
    fn dag_dependencies_sequence() {
        let wan = topologies::fig1a();
        let mut sim = Simulation::new(wan, terra0(), SimConfig::default());
        // Two-stage DAG: stage0 5 GB A->B (2 s), then stage1 5 GB B->C (2 s).
        let job = Job {
            id: 1,
            arrival: 0.0,
            stages: vec![
                Stage { deps: vec![], compute_s: 0.0, flows: vec![mk_flow(0, 0, 1, 5.0)], deadline: None },
                Stage { deps: vec![0], compute_s: 1.0, flows: vec![mk_flow(0, 1, 2, 5.0)], deadline: None },
            ],
        };
        let rep = sim.run_jobs(vec![job]);
        let jct = rep.jobs[0].jct().unwrap();
        assert!((jct - 5.0).abs() < 0.2, "jct={jct} (2 + 1 + 2)");
        assert_eq!(rep.coflows.len(), 2);
    }

    #[test]
    fn link_failure_triggers_reroute() {
        let wan = topologies::fig1a();
        let mut sim = Simulation::new(wan, terra0(), SimConfig::default());
        let job = Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 25.0)]); // 200 Gbit
        sim.add_job(job);
        // Direct A-B link fails at t=1; Terra must continue via C.
        sim.add_wan_event(1.0, LinkEvent::Fail(0, 1));
        let rep = sim.run();
        assert_eq!(rep.unfinished(), 0);
        let jct = rep.jobs[0].jct().unwrap();
        // 20 Gbps for 1 s, then 10 Gbps via C: 1 + 180/10 = 19 s.
        assert!((jct - 19.0).abs() < 0.5, "jct={jct}");
    }

    #[test]
    fn small_fluctuation_ignored_large_reacts() {
        let wan = topologies::fig1a();
        let mut sim = Simulation::new(wan, terra0(), SimConfig::default());
        let job = Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 25.0)]);
        sim.add_job(job);
        // 10% drop on A->B at t=1 (< rho): no re-optimization round.
        sim.add_wan_event(1.0, LinkEvent::SetBandwidth(0, 1, 9.0));
        let rep = sim.run();
        assert_eq!(rep.unfinished(), 0);
        // The clamp still keeps the allocation feasible; JCT grows slightly.
        let jct = rep.jobs[0].jct().unwrap();
        assert!(jct > 10.0 && jct < 12.0, "jct={jct}");
    }

    #[test]
    fn deadline_admission_and_completion() {
        let wan = topologies::fig1a();
        let mut sim = Simulation::new(
            wan,
            Box::new(TerraPolicy::new(TerraConfig { alpha: 0.0, ..Default::default() })),
            SimConfig::default(),
        );
        // Feasible deadline: min CCT 2 s, deadline 4 s.
        let mut j1 = Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 5.0)]);
        j1.stages[0].deadline = Some(4.0);
        // Infeasible deadline: min CCT 10 s (25 GB on 20 Gbps), deadline 3 s.
        let mut j2 = Job::map_reduce(2, 0.0, 0.0, vec![mk_flow(0, 0, 1, 25.0)]);
        j2.stages[0].deadline = Some(3.0);
        let rep = sim.run_jobs(vec![j1, j2]);
        let d1 = rep.coflows.iter().find(|c| c.job == Some(1)).unwrap();
        let d2 = rep.coflows.iter().find(|c| c.job == Some(2)).unwrap();
        assert!(d1.admitted && d1.met_deadline(), "{d1:?}");
        assert!(!d2.admitted && !d2.met_deadline(), "{d2:?}");
        // Rejected job still completes via fallback.
        assert!(rep.jobs[1].finish.is_some());
    }

    #[test]
    fn utilization_bounded() {
        let wan = topologies::fig1a();
        let mut sim = Simulation::new(wan, terra0(), SimConfig::default());
        let job = Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 5.0)]);
        let rep = sim.run_jobs(vec![job]);
        let u = rep.utilization();
        assert!(u > 0.0 && u <= 1.0, "utilization={u}");
        // 40 Gbit transferred.
        assert!((rep.transferred_gbit - 40.0).abs() < 1e-6);
    }

    #[test]
    fn partitioned_wan_starves_gracefully() {
        let mut wan = topologies::fig1a();
        wan.apply_event(&LinkEvent::Fail(0, 1));
        wan.apply_event(&LinkEvent::Fail(0, 2));
        let mut sim = Simulation::new(wan, terra0(), SimConfig::default());
        let job = Job::map_reduce(1, 0.0, 0.0, vec![mk_flow(0, 0, 1, 5.0)]);
        let rep = sim.run_jobs(vec![job]);
        assert_eq!(rep.unfinished(), 1);
        assert!(rep.jobs[0].finish.is_none());
    }
}
